"""repro.launch"""
