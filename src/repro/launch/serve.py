"""Batched decode driver: prefill a request batch, then step the decoder.

    PYTHONPATH=src python -m repro.launch.serve --arch mamba2-1.3b --smoke \
        --prompt-len 64 --decode-tokens 32 --batch 4
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.launch import steps as steps_lib
from repro.models.registry import build_bundle


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b",
                    choices=configs.ARCH_IDS)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--decode-tokens", type=int, default=32)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = configs.get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    bundle = build_bundle(cfg, tp=1, dp=1)
    params = bundle.init(jax.random.PRNGKey(args.seed))
    print(f"arch={cfg.name} params={bundle.num_params / 1e6:.1f}M")

    key = jax.random.PRNGKey(args.seed + 1)
    max_len = args.prompt_len + args.decode_tokens
    b = args.batch
    if cfg.is_enc_dec:
        frames = jax.random.normal(key, (b, args.prompt_len, cfg.d_model))
        prompts = jax.random.randint(key, (b, args.prompt_len), 0,
                                     cfg.vocab_size)
        inputs = (frames, prompts)
    else:
        prompts = jax.random.randint(key, (b, args.prompt_len), 0,
                                     cfg.vocab_size)
        inputs = prompts

    caches = bundle.init_caches(b, max_len)
    prefill = jax.jit(steps_lib.make_prefill_step(bundle))
    serve = jax.jit(steps_lib.make_serve_step(bundle))

    t0 = time.time()
    logits, caches = prefill(params, inputs, caches)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0
    tok = jnp.argmax(logits[:, -1:, :], axis=-1)

    outs = [np.asarray(tok)]
    t0 = time.time()
    for i in range(args.decode_tokens - 1):
        tok, caches = serve(params, caches, tok,
                            jnp.asarray(args.prompt_len + i))
    jax.block_until_ready(tok)
    t_decode = time.time() - t0
    outs.append(np.asarray(tok))

    n_dec = (args.decode_tokens - 1) * b
    print(f"prefill: {t_prefill * 1e3:.1f} ms "
          f"({b * args.prompt_len / max(t_prefill, 1e-9):.0f} tok/s)")
    print(f"decode:  {t_decode * 1e3:.1f} ms "
          f"({n_dec / max(t_decode, 1e-9):.0f} tok/s, batch={b})")
    print("sample next tokens:", outs[0][:, 0].tolist())


if __name__ == "__main__":
    main()
