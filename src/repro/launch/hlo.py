"""HLO text utilities: collective-byte accounting for the roofline.

collective_bytes is NOT in cost_analysis(); we parse the compiled per-device
HLO module and sum result-shape bytes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute.  ``-start`` async variants
are counted, ``-done`` are not (no double counting).
"""
from __future__ import annotations

import re

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3": 1,
                "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
                "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1, "c64": 8,
                "c128": 16}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"=\s*([^=]*?)\s*"
    r"(all-gather-start|all-gather-done|all-gather|"
    r"all-reduce-start|all-reduce-done|all-reduce|"
    r"reduce-scatter|all-to-all|"
    r"collective-permute-start|collective-permute-done|collective-permute)"
    r"\(")


def cost_analysis_dict(compiled) -> dict:
    """``compiled.cost_analysis()`` normalized across jax versions: older
    releases return a one-element list of per-program dicts, newer ones a
    plain dict.  Always returns a dict (possibly empty)."""
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return dict(cost)


def shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> dict:
    out: dict = {}
    for m in _COLL_RE.finditer(hlo_text):
        type_str, op = m.group(1), m.group(2)
        if op.endswith("-done"):
            continue
        op = op.replace("-start", "")
        out[op] = out.get(op, 0) + shape_bytes(type_str)
    out["total"] = sum(v for k, v in out.items() if k != "total")
    return out


def count_op(hlo_text: str, opname: str) -> int:
    return len(re.findall(rf"\b{re.escape(opname)}\(", hlo_text))
