"""Distributed step functions: OTA-FL train step, prefill, decode.

The train step implements the paper's update (7) in the pjit-native
weighted-loss form (DESIGN.md §3): FL clients are the (pod, data) batch
slices; per-round fading draws the coefficients s_m = chi_{m,t} gamma_m / alpha
from the bound scheme; client-weighted loss makes XLA's gradient all-reduce
compute the OTA superposition; receiver noise is added to the aggregated
gradient; the PS update is plain SGD (paper) or any optim/ optimizer.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import distributed as dist
from repro.core import ota
from repro.core.power_control import PowerControl
from repro.launch import mesh as mesh_lib
from repro.models.param import abstract_params, param_specs
from repro.models.registry import ModelBundle

PyTree = Any


# ---------------------------------------------------------------------------
# Sharding helpers
# ---------------------------------------------------------------------------

def _filter_spec(spec: P, mesh: Mesh) -> P:
    axes = []
    for ax in spec:
        if ax is None:
            axes.append(None)
        elif isinstance(ax, (tuple, list)):
            keep = tuple(a for a in ax if a in mesh.axis_names)
            axes.append(keep if keep else None)
        else:
            axes.append(ax if ax in mesh.axis_names else None)
    return P(*axes)


def named(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, _filter_spec(spec, mesh))


def param_shardings(bundle: ModelBundle, mesh: Mesh):
    return jax.tree.map(lambda s: named(mesh, s), param_specs(bundle.defs))


def batch_spec(mesh: Mesh, global_batch: int, extra_dims: int = 1) -> P:
    axes = mesh_lib.batch_axes(mesh)
    total = 1
    for a in axes:
        total *= mesh.shape[a]
    lead = axes if global_batch % total == 0 else None
    return P(lead, *([None] * extra_dims))


# --- cache sharding rules, keyed on leaf name (see models/*/init_*_cache) ---

_CACHE_BASE_NDIM = {"k": 4, "v": 4, "ckv": 3, "krope": 3,
                    "ssm": 4, "conv": 3, "h": 2}


def _cache_leaf_spec(name: str, shape: tuple, mesh: Mesh,
                     batch_div: bool) -> P:
    base = _CACHE_BASE_NDIM[name]
    extra = len(shape) - base          # stacked layer axes (scan groups)
    core = shape[extra:]
    m = mesh.shape.get("model", 1)
    d = mesh.shape.get("data", 1)
    baxes = mesh_lib.batch_axes(mesh)
    b_ax = baxes if batch_div else None
    if name in ("k", "v"):
        b, s, kh, dh = core
        seq_ax = "data" if (not batch_div and s % d == 0) else None
        head_ax = "model" if kh % m == 0 else None
        spec = (b_ax, seq_ax, head_ax, None)
    elif name in ("ckv", "krope"):
        b, s, r = core
        seq_ax = "data" if (not batch_div and s % d == 0) else None
        spec = (b_ax, seq_ax, "model" if r % m == 0 else None)
    elif name == "ssm":
        b, h, pd, n = core
        spec = (b_ax, "model" if h % m == 0 else None, None, None)
    elif name == "conv":
        b, k, c = core
        spec = (b_ax, None, "model" if c % m == 0 else None)
    else:  # "h"
        b, w = core
        spec = (b_ax, "model" if w % m == 0 else None)
    return P(*([None] * extra + list(spec)))


def cache_shardings(abstract_caches: PyTree, mesh: Mesh, global_batch: int):
    baxes = mesh_lib.batch_axes(mesh)
    total = 1
    for a in baxes:
        total *= mesh.shape[a]
    batch_div = global_batch % total == 0

    def leaf(path, x):
        name = None
        for pp in reversed(path):
            key = str(getattr(pp, "key", getattr(pp, "idx", "")))
            if key in _CACHE_BASE_NDIM:
                name = key
                break
        if name is None:
            raise ValueError(f"unrecognized cache leaf at {path}")
        return named(mesh, _cache_leaf_spec(name, x.shape, mesh, batch_div))

    return jax.tree_util.tree_map_with_path(leaf, abstract_caches)


# ---------------------------------------------------------------------------
# OTA-FL train step
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class TrainStepConfig:
    eta: float = 1e-2
    optimizer: str = "sgd"          # paper: plain SGD (eq. 7)


def make_train_step(bundle: ModelBundle, scheme: PowerControl,
                    gains: np.ndarray, tcfg: TrainStepConfig):
    """(params, batch, key) -> (params, metrics).  Pure; pjit-ready."""
    gains_j = jnp.asarray(np.asarray(gains), jnp.float32)
    n_clients = int(gains_j.shape[0])

    def train_step(params, batch, key):
        k_fade, k_coeff, k_noise = jax.random.split(key, 3)
        h = ota.draw_fading(k_fade, gains_j)
        s, noise_scale = scheme.round_coeffs(h, k_coeff)
        w = ota.per_client_loss_weights(s)                  # [N]
        tokens = batch[1] if isinstance(batch, tuple) else batch
        gb = tokens.shape[0]
        client_ids = jnp.arange(gb) // (gb // n_clients)
        sample_w = w[client_ids]

        loss, grads = jax.value_and_grad(bundle.loss)(params, batch,
                                                      sample_w)
        grads = ota.add_receiver_noise(grads, noise_scale, k_noise)
        new_params = jax.tree.map(
            lambda p, g: (p.astype(jnp.float32)
                          - tcfg.eta * g.astype(jnp.float32)).astype(p.dtype),
            params, grads)
        metrics = {"loss": loss,
                   "active_clients": jnp.sum((s > 0).astype(jnp.float32)),
                   "noise_scale": noise_scale.astype(jnp.float32)}
        return new_params, metrics

    return train_step


def make_ideal_train_step(bundle: ModelBundle, tcfg: TrainStepConfig):
    """Noiseless FedAvg reference (eq. (2)) — also the plain-SGD baseline."""

    def train_step(params, batch, key):
        loss, grads = jax.value_and_grad(bundle.loss)(params, batch)
        new_params = jax.tree.map(
            lambda p, g: (p.astype(jnp.float32)
                          - tcfg.eta * g.astype(jnp.float32)).astype(p.dtype),
            params, grads)
        return new_params, {"loss": loss}

    return train_step


# ---------------------------------------------------------------------------
# Serving steps
# ---------------------------------------------------------------------------

def make_prefill_step(bundle: ModelBundle):
    def prefill_step(params, inputs, caches):
        return bundle.prefill(params, inputs, caches)
    return prefill_step


def make_serve_step(bundle: ModelBundle):
    """One decode step: token [B,1] against a seq_len KV cache/state."""
    def serve_step(params, caches, token, pos):
        logits, caches = bundle.decode(params, caches, token, pos)
        next_token = jnp.argmax(logits[:, -1, :], axis=-1)[:, None]
        return next_token, caches
    return serve_step


# ---------------------------------------------------------------------------
# Abstract inputs per (arch x shape) — ShapeDtypeStructs, never allocated
# ---------------------------------------------------------------------------

def input_specs(bundle: ModelBundle, shape, mesh: Mesh):
    """Returns (args tuple of ShapeDtypeStruct, in_shardings tuple) for the
    step matching shape.kind: train | prefill | decode.
    """
    cfg = bundle.cfg
    gb, s = shape.global_batch, shape.seq_len
    tok = jnp.int32
    bspec1 = named(mesh, batch_spec(mesh, gb, 1))
    bspec2 = named(mesh, batch_spec(mesh, gb, 2))
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    key_sh = named(mesh, P())

    if shape.kind == "train":
        if cfg.is_enc_dec:
            frames = jax.ShapeDtypeStruct((gb, s, cfg.d_model),
                                          cfg.compute_dtype)
            tokens = jax.ShapeDtypeStruct((gb, s + 1), tok)
            return ((frames, tokens), key), ((bspec2, bspec1), key_sh)
        tokens = jax.ShapeDtypeStruct((gb, s + 1), tok)
        return (tokens, key), (bspec1, key_sh)

    if shape.kind == "prefill":
        caches = jax.eval_shape(lambda: bundle.init_caches(gb, s))
        c_sh = cache_shardings(caches, mesh, gb)
        if cfg.is_enc_dec:
            frames = jax.ShapeDtypeStruct((gb, s, cfg.d_model),
                                          cfg.compute_dtype)
            dec = jax.ShapeDtypeStruct((gb, s), tok)
            return ((frames, dec), caches), ((bspec2, bspec1), c_sh)
        tokens = jax.ShapeDtypeStruct((gb, s), tok)
        return (tokens, caches), (bspec1, c_sh)

    if shape.kind == "decode":
        if cfg.is_enc_dec:
            self_c = jax.eval_shape(lambda: bundle.init_caches(gb, s))
            cross_c = jax.eval_shape(
                lambda: _abstract_cross_caches(bundle, gb, s))
            caches = (self_c, cross_c)
            c_sh = (cache_shardings(self_c, mesh, gb),
                    cache_shardings(cross_c, mesh, gb))
        else:
            caches = jax.eval_shape(lambda: bundle.init_caches(gb, s))
            c_sh = cache_shardings(caches, mesh, gb)
        token = jax.ShapeDtypeStruct((gb, 1), tok)
        pos = jax.ShapeDtypeStruct((), jnp.int32)
        return (caches, token, pos), (c_sh, bspec1, named(mesh, P()))

    raise ValueError(shape.kind)


def _abstract_cross_caches(bundle: ModelBundle, gb: int, s: int):
    cfg = bundle.cfg
    dh = cfg.resolved_head_dim
    kv = jnp.zeros((cfg.n_layers, gb, s, cfg.n_kv_heads, dh),
                   cfg.compute_dtype)
    return {"k": kv, "v": kv}
