"""Loop-corrected cost extraction for the roofline analysis.

XLA's ``compiled.cost_analysis()`` counts a while/scan body ONCE, regardless
of trip count (verified empirically; see EXPERIMENTS.md §Dry-run notes).  The
production steps scan over layer groups, so raw module FLOPs/bytes/collective
counts under-report by ~n_layers.  This module compiles each scan-unit body
standalone (tiny HLO, same mesh + shardings) and corrects:

    corrected = module_cost + sum_groups (trip_g - 1) * unit_cost_g

For train steps the scanned backward body includes the remat recompute, so
the unit cost is measured through value_and_grad of the unit (fwd+recompute+
bwd ~= what each backward iteration executes), matching the formula
F_full + (T-1) * F_grad_unit.

Attention inside unit compiles runs in ANALYSIS_DIRECT_ATTENTION mode
(full-score materialization) because the blocked lax.map form has the same
once-counted-body problem.
"""
from __future__ import annotations

import contextlib
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import distributed as dist
from repro.launch import mesh as mesh_lib
from repro.launch import steps as steps_lib
from repro.models import attention as attn_mod
from repro.models import encdec as encdec_mod
from repro.models import transformer as tfm
from repro.models.config import ModelConfig
from repro.models.param import abstract_params, param_specs


@contextlib.contextmanager
def _direct_attention():
    prev = attn_mod.ANALYSIS_DIRECT_ATTENTION
    attn_mod.ANALYSIS_DIRECT_ATTENTION = True
    try:
        yield
    finally:
        attn_mod.ANALYSIS_DIRECT_ATTENTION = prev


def _cost_of(compiled) -> dict:
    from repro.launch.hlo import collective_bytes, cost_analysis_dict
    cost = cost_analysis_dict(compiled)
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "collective_bytes": float(
            collective_bytes(compiled.as_text())["total"]),
    }


def _compile_unit(unit_fn, unit_defs, x_abs, x_sharding, mesh, extra_args=(),
                  extra_shardings=()):
    p_abs = abstract_params(unit_defs)
    p_sh = jax.tree.map(lambda s: steps_lib.named(mesh, s),
                        param_specs(unit_defs))
    jitted = jax.jit(unit_fn, in_shardings=(p_sh, x_sharding)
                     + tuple(extra_shardings))
    lowered = jitted.lower(p_abs, x_abs, *extra_args)
    return _cost_of(lowered.compile())


def _decoder_unit_costs(cfg: ModelConfig, shape, mesh) -> list:
    """[(trip_count, unit_cost_dict)] for each scanned group of the step."""
    tp = mesh.shape.get("model", 1)
    dp = mesh.shape.get("data", 1)
    lead, unit, n_rep, tail = tfm.layer_plan(cfg)
    if n_rep <= 1:
        return []
    unit_defs = {f"u{i}": tfm.layer_def(cfg, s, tp, dp)
                 for i, s in enumerate(unit)}
    gb, s = shape.global_batch, shape.seq_len
    bspec = steps_lib.named(mesh, steps_lib.batch_spec(mesh, gb, 2))

    def unit_fwd(p, x, caches=None):
        for i, sig in enumerate(unit):
            c = caches[f"u{i}"] if caches is not None else None
            x, _, _ = tfm.apply_layer(p[f"u{i}"], x, cfg, sig, cache=c,
                                      decode=(shape.kind == "decode"),
                                      pos_offset=0)
        return x

    if shape.kind == "train":
        x_abs = jax.ShapeDtypeStruct((gb, s, cfg.d_model), cfg.compute_dtype)

        def unit_grad(p, x):
            def scalar(p_, x_):
                return jnp.sum(unit_fwd(p_, x_).astype(jnp.float32))
            # return BOTH cotangents: dropping gp would let XLA dead-code-
            # eliminate the weight-gradient matmuls (1/3 of backward FLOPs)
            return jax.grad(scalar, argnums=(0, 1))(p, x)

        with _direct_attention():
            # per scan iteration the step executes one fwd body (forward
            # while loop) AND one remat fwd+bwd body (backward while loop)
            c_fwd = _compile_unit(unit_fwd, unit_defs, x_abs, bspec, mesh)
            c_grad = _compile_unit(unit_grad, unit_defs, x_abs, bspec, mesh)
        cost = {k: c_fwd[k] + c_grad[k] for k in c_fwd}
        return [(n_rep, cost)]

    seq = 1 if shape.kind == "decode" else s
    x_abs = jax.ShapeDtypeStruct((gb, seq, cfg.d_model), cfg.compute_dtype)
    caches_abs = jax.eval_shape(
        lambda: {f"u{i}": tfm._mixer_cache(cfg, sig[0], gb, s)
                 for i, sig in enumerate(unit)})
    c_sh = steps_lib.cache_shardings(caches_abs, mesh, gb)
    with _direct_attention():
        cost = _compile_unit(unit_fwd, unit_defs, x_abs, bspec, mesh,
                             extra_args=(caches_abs,),
                             extra_shardings=(c_sh,))
    return [(n_rep, cost)]


def _encdec_unit_costs(cfg: ModelConfig, shape, mesh) -> list:
    tp = mesh.shape.get("model", 1)
    dp = mesh.shape.get("data", 1)
    gb, s = shape.global_batch, shape.seq_len
    bspec = steps_lib.named(mesh, steps_lib.batch_spec(mesh, gb, 2))
    enc_defs = {"u0": tfm.layer_def(cfg, ("enc_attn", "dense"), tp, dp)}
    dec_defs = {"u0": tfm.layer_def(cfg, ("attn", "dense"), tp, dp,
                                    cross=True)}
    out = []

    def enc_fwd(p, x):
        x, _, _ = tfm.apply_layer(p["u0"], x, cfg, ("enc_attn", "dense"))
        return x

    def dec_fwd(p, x, mem):
        x, _, _ = tfm.apply_layer(p["u0"], x, cfg, ("attn", "dense"),
                                  memory=mem)
        return x

    x_abs = jax.ShapeDtypeStruct((gb, s, cfg.d_model), cfg.compute_dtype)
    if shape.kind == "train":
        def enc_grad(p, x):
            return jax.grad(lambda p_, x_: jnp.sum(
                enc_fwd(p_, x_).astype(jnp.float32)), argnums=(0, 1))(p, x)

        def dec_grad(p, x, mem):
            return jax.grad(lambda p_, x_, m_: jnp.sum(
                dec_fwd(p_, x_, m_).astype(jnp.float32)),
                argnums=(0, 1, 2))(p, x, mem)

        with _direct_attention():
            enc_f = _compile_unit(enc_fwd, enc_defs, x_abs, bspec, mesh)
            enc_g = _compile_unit(enc_grad, enc_defs, x_abs, bspec, mesh)
            dec_f = _compile_unit(dec_fwd, dec_defs, x_abs, bspec, mesh,
                                  extra_args=(x_abs,),
                                  extra_shardings=(bspec,))
            dec_g = _compile_unit(dec_grad, dec_defs, x_abs, bspec, mesh,
                                  extra_args=(x_abs,),
                                  extra_shardings=(bspec,))
        out.append((cfg.encoder_layers,
                    {k: enc_f[k] + enc_g[k] for k in enc_f}))
        out.append((cfg.n_layers,
                    {k: dec_f[k] + dec_g[k] for k in dec_f}))
        return out

    if shape.kind == "prefill":
        with _direct_attention():
            out.append((cfg.encoder_layers,
                        _compile_unit(enc_fwd, enc_defs, x_abs, bspec, mesh)))
            out.append((cfg.n_layers,
                        _compile_unit(dec_fwd, dec_defs, x_abs, bspec, mesh,
                                      extra_args=(x_abs,),
                                      extra_shardings=(bspec,))))
        return out

    # decode: self-attn against cache + cross-attn against cached enc K/V
    x1 = jax.ShapeDtypeStruct((gb, 1, cfg.d_model), cfg.compute_dtype)
    caches_abs = jax.eval_shape(
        lambda: attn_mod.init_kv_cache(cfg, gb, s, "attn"))
    cross_abs = jax.eval_shape(
        lambda: attn_mod.init_kv_cache(cfg, gb, s, "attn"))
    c_sh = steps_lib.cache_shardings(caches_abs, mesh, gb)
    cc_sh = steps_lib.cache_shardings(cross_abs, mesh, gb)

    def dec_step(p, x, cache, cross):
        x, _, _ = tfm.apply_layer(p["u0"], x, cfg, ("attn", "dense"),
                                  pos_offset=0, cache=cache, decode=True,
                                  cross_cache=cross)
        return x

    with _direct_attention():
        out.append((cfg.n_layers,
                    _compile_unit(dec_step, dec_defs, x1, bspec, mesh,
                                  extra_args=(caches_abs, cross_abs),
                                  extra_shardings=(c_sh, cc_sh))))
    return out


def corrected_costs(record: dict, cfg: ModelConfig, shape, mesh) -> dict:
    """Apply the (trip-1)*unit correction to a dryrun record's raw costs."""
    groups = (_encdec_unit_costs(cfg, shape, mesh) if cfg.is_enc_dec
              else _decoder_unit_costs(cfg, shape, mesh))
    flops = record["flops_per_device"]
    byts = record["bytes_accessed_per_device"]
    coll = record["collective_bytes_per_device"]["total"]
    per_unit = []
    for trip, cost in groups:
        flops += (trip - 1) * cost["flops"]
        byts += (trip - 1) * cost["bytes"]
        coll += (trip - 1) * cost["collective_bytes"]
        per_unit.append({"trip": trip, **cost})
    return {
        "flops_per_device_corrected": flops,
        "bytes_per_device_corrected": byts,
        "collective_bytes_corrected": coll,
        "units": per_unit,
    }
