"""Production mesh construction (TPU v5e target).

A FUNCTION, not a module-level constant, so importing this module never
touches jax device state (smoke tests must keep seeing 1 CPU device).
"""
from __future__ import annotations

import jax

# TPU v5e hardware constants (per chip) — used by the roofline analysis.
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # bytes/s
ICI_BW = 50e9                   # bytes/s per link


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(data: int = 2, model: int = 2, *, multi_pod: bool = False):
    """Tiny mesh for CI-scale dry-run tests (requires >= data*model devices,
    e.g. via XLA_FLAGS=--xla_force_host_platform_device_count=8)."""
    if multi_pod:
        return jax.make_mesh((2, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))


def num_clients(mesh) -> int:
    """FL clients = pod x data slices (DESIGN.md §5)."""
    n = mesh.shape["data"]
    if "pod" in mesh.axis_names:
        n *= mesh.shape["pod"]
    return int(n)


def batch_axes(mesh) -> tuple:
    return (("pod", "data") if "pod" in mesh.axis_names else ("data",))


def grid_axes(mesh) -> tuple:
    """Mesh axes a flattened sweep grid shards over (fl.placement,
    DESIGN.md §Placement).  Fleet cells are independent programs, so the
    whole mesh — every axis, pods included — serves as one flat pool of
    cell slots."""
    return tuple(mesh.axis_names)
