"""End-to-end OTA-FL training driver.

    PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b --smoke \
        --steps 50 --scheme sca

Runs the paper's OTA-FL SGD (launch/steps.make_train_step) on a synthetic
token stream partitioned across FL clients.  On this CPU container use
--smoke (reduced config); on a real TPU mesh drop --smoke and the same code
path pjit-shards across the production mesh.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro import distributed as dist
from repro.checkpoint import checkpoint as ckpt
from repro.core import power_control as pcm
from repro.core.channel import WirelessConfig, deploy
from repro.core.theory import OTAParams
from repro.data.synthetic import token_stream
from repro.launch import mesh as mesh_lib
from repro.launch import steps as steps_lib
from repro.models.registry import build_bundle


def make_batches(vocab: int, num_clients: int, per_client: int, seq: int,
                 steps: int, seed: int = 0):
    """Non-iid client shards: each client's stream uses a shifted vocab slice
    (heterogeneity analogous to the paper's label split)."""
    streams = []
    for m in range(num_clients):
        toks = token_stream(steps * per_client * (seq + 1), vocab,
                            seed=seed * 1000 + m)
        # rotate into a client-specific band to induce heterogeneity
        band = vocab // max(num_clients, 1)
        toks = (toks + m * band) % vocab
        streams.append(toks.reshape(steps, per_client, seq + 1))
    return np.stack(streams, axis=1)  # [steps, N, per_client, seq+1]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b",
                    choices=configs.ARCH_IDS)
    ap.add_argument("--scheme", default="sca", choices=pcm.SCHEMES)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--per-client-batch", type=int, default=1)
    ap.add_argument("--eta", type=float, default=0.02)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-sized)")
    ap.add_argument("--d-model", type=int, default=0,
                    help="override d_model for --smoke")
    ap.add_argument("--layers", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--checkpoint", default="")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = configs.get_config(args.arch)
    if args.smoke:
        over = {}
        if args.d_model:
            over.update(d_model=args.d_model,
                        n_heads=max(4, args.d_model // 64),
                        n_kv_heads=max(2, args.d_model // 128),
                        d_ff=args.d_model * 3, vocab_size=8192)
        if args.layers:
            over["n_layers"] = args.layers
        cfg = cfg.smoke(**over)
    bundle = build_bundle(cfg, tp=1, dp=1)
    print(f"arch={cfg.name} params={bundle.num_params / 1e6:.1f}M "
          f"clients={args.clients}")

    wcfg = WirelessConfig(num_devices=args.clients, seed=args.seed)
    dep = deploy(wcfg)
    prm = OTAParams(d=bundle.num_params, gmax=10.0,
                    es=wcfg.energy_per_sample, n0=wcfg.noise_psd,
                    gains=dep.gains, sigma_sq=np.zeros(args.clients),
                    eta=args.eta, lsmooth=1.0, kappa_sq=4.0)
    scheme = pcm.make_power_control(args.scheme, dep, prm)
    if scheme.p is not None:
        print("participation p:", np.round(scheme.p, 3))

    step = steps_lib.make_train_step(
        bundle, scheme, dep.gains, steps_lib.TrainStepConfig(eta=args.eta))
    step = jax.jit(step, donate_argnums=(0,))

    params = bundle.init(jax.random.PRNGKey(args.seed))
    data = make_batches(cfg.vocab_size, args.clients, args.per_client_batch,
                        args.seq, args.steps, args.seed)
    key = jax.random.PRNGKey(args.seed + 1)
    losses = []
    t0 = time.time()
    for t in range(args.steps):
        key, sub = jax.random.split(key)
        batch = jnp.asarray(data[t].reshape(-1, args.seq + 1))
        params, metrics = step(params, batch, sub)
        losses.append(float(metrics["loss"]))
        if t % args.log_every == 0 or t == args.steps - 1:
            dt = time.time() - t0
            print(f"step {t:4d} loss {losses[-1]:.4f} "
                  f"active {float(metrics['active_clients']):.0f}/"
                  f"{args.clients} {dt / (t + 1):.2f}s/step", flush=True)

    if args.checkpoint:
        ckpt.save(args.checkpoint, params,
                  meta={"arch": cfg.name, "steps": args.steps,
                        "scheme": args.scheme, "final_loss": losses[-1]})
        print("checkpoint saved to", args.checkpoint)
    print(f"final_loss={losses[-1]:.4f} first_loss={losses[0]:.4f} "
          f"improved={losses[-1] < losses[0]}")
    return losses


if __name__ == "__main__":
    main()
