"""End-to-end OTA-FL training driver.

    PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b --smoke \
        --steps 50 --scheme sca

Runs the paper's OTA-FL SGD (launch/steps.make_train_step) on the
``token_stream`` LM workload from the task registry (repro.tasks,
DESIGN.md §Tasks): the model bundle, the non-iid vocab-band client shards
and the held-out eval all come from the Task — no private data wiring
here.  On this CPU container use --smoke (reduced config); on a real TPU
mesh drop --smoke and the same code path pjit-shards across the
production mesh.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro import distributed as dist
from repro import tasks as task_registry
from repro.checkpoint import checkpoint as ckpt
from repro.core import power_control as pcm
from repro.core.channel import WirelessConfig, deploy
from repro.core.theory import OTAParams
from repro.launch import mesh as mesh_lib
from repro.launch import steps as steps_lib


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--task", default="token_stream",
                    help="registered LM task (DESIGN.md §Tasks)")
    ap.add_argument("--arch", default="qwen1.5-0.5b",
                    choices=configs.ARCH_IDS)
    ap.add_argument("--scheme", default="sca", choices=pcm.SCHEMES)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--per-client-batch", type=int, default=1)
    ap.add_argument("--eta", type=float, default=0.02)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-sized)")
    ap.add_argument("--d-model", type=int, default=0,
                    help="override d_model for --smoke")
    ap.add_argument("--layers", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--checkpoint", default="")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    try:
        task = task_registry.get(
            args.task, expect_runtime="steps", arch=args.arch,
            smoke=args.smoke, d_model=args.d_model, n_layers=args.layers,
            clients=args.clients, per_client_batch=args.per_client_batch,
            seq=args.seq)
    except (KeyError, ValueError) as e:
        raise SystemExit(f"{e} (fleet tasks go through benchmarks/fig2.py "
                         f"or examples/quickstart.py)")
    bundle, cfg = task.aux["bundle"], task.aux["cfg"]
    print(f"arch={cfg.name} params={bundle.num_params / 1e6:.1f}M "
          f"clients={args.clients}")

    wcfg = WirelessConfig(num_devices=args.clients, seed=args.seed)
    dep = deploy(wcfg)
    prm = OTAParams(d=bundle.num_params, gmax=10.0,
                    es=wcfg.energy_per_sample, n0=wcfg.noise_psd,
                    gains=dep.gains, sigma_sq=np.zeros(args.clients),
                    eta=args.eta, lsmooth=1.0, kappa_sq=4.0)
    scheme = pcm.make_power_control(args.scheme, dep, prm)
    if scheme.p is not None:
        print("participation p:", np.round(scheme.p, 3))

    step = steps_lib.make_train_step(
        bundle, scheme, dep.gains, steps_lib.TrainStepConfig(eta=args.eta))
    step = jax.jit(step, donate_argnums=(0,))

    params = task.init_params(args.seed)
    td = task.build_data(args.seed, steps=args.steps)
    data = td.train
    eval_fn = jax.jit(task.make_eval(td))
    key = jax.random.PRNGKey(args.seed + 1)
    losses = []
    t0 = time.time()
    for t in range(args.steps):
        key, sub = jax.random.split(key)
        batch = jnp.asarray(data[t].reshape(-1, args.seq + 1))
        params, metrics = step(params, batch, sub)
        losses.append(float(metrics["loss"]))
        if t % args.log_every == 0 or t == args.steps - 1:
            dt = time.time() - t0
            print(f"step {t:4d} loss {losses[-1]:.4f} "
                  f"active {float(metrics['active_clients']):.0f}/"
                  f"{args.clients} {dt / (t + 1):.2f}s/step", flush=True)

    if args.checkpoint:
        ckpt.save(args.checkpoint, params,
                  meta={"arch": cfg.name, "steps": args.steps,
                        "scheme": args.scheme, "final_loss": losses[-1]})
        print("checkpoint saved to", args.checkpoint)
    held_out = float(eval_fn(params)["loss"])
    print(f"final_loss={losses[-1]:.4f} first_loss={losses[0]:.4f} "
          f"held_out_loss={held_out:.4f} improved={losses[-1] < losses[0]}")
    return losses


if __name__ == "__main__":
    main()
