"""Multi-pod dry-run: AOT-lower + compile every (arch x shape x mesh)
combination and extract roofline terms — no real TPU, no allocation.

MUST be run as a fresh process (jax locks device count on first init):

    PYTHONPATH=src python -m repro.launch.dryrun --arch granite-8b \
        --shape train_4k [--multi-pod] [--all]
"""
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

# ruff: noqa: E402
import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro import distributed as dist
from repro.core import power_control as pcm
from repro.core.channel import WirelessConfig, deploy
from repro.core.theory import OTAParams
from repro.launch import mesh as mesh_lib
from repro.launch import steps as steps_lib
from repro.models.param import param_bytes, param_count
from repro.models.registry import build_bundle

from repro.launch.hlo import collective_bytes, cost_analysis_dict  # noqa: E402

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                            "experiments", "dryrun")


def _scheme_for(bundle, mesh, scheme_name: str, eta: float):
    """Build the OTA power-control scheme for the mesh's FL clients."""
    n = mesh_lib.num_clients(mesh)
    wcfg = WirelessConfig(num_devices=n, seed=0)
    dep = deploy(wcfg)
    prm = OTAParams(d=max(bundle.num_params, 1), gmax=10.0,
                    es=wcfg.energy_per_sample, n0=wcfg.noise_psd,
                    gains=dep.gains, sigma_sq=np.zeros(n), eta=eta,
                    lsmooth=1.0, kappa_sq=4.0)
    return pcm.make_power_control(scheme_name, dep, prm), dep


def build_step_and_args(arch: str, shape_name: str, mesh,
                        scheme_name: str = "sca", eta: float = 1e-2):
    """Returns (step_fn, args, in_shardings, donate) ready to jit."""
    shape = configs.get_shape(shape_name)
    cfg = (configs.long_context_config(arch) if shape_name == "long_500k"
           else configs.get_config(arch))
    tp = mesh.shape.get("model", 1)
    dp = mesh.shape.get("data", 1)
    bundle = build_bundle(cfg, tp=tp, dp=dp)
    pshard = steps_lib.param_shardings(bundle, mesh)
    abstract = bundle.abstract()

    (step_args, arg_shardings) = steps_lib.input_specs(bundle, shape, mesh)

    if shape.kind == "train":
        scheme, dep = _scheme_for(bundle, mesh, scheme_name, eta)
        step = steps_lib.make_train_step(bundle, scheme, dep.gains,
                                         steps_lib.TrainStepConfig(eta=eta))
        args = (abstract,) + tuple(step_args)
        shardings = (pshard,) + tuple(arg_shardings)
        donate = (0,)
    elif shape.kind == "prefill":
        step = steps_lib.make_prefill_step(bundle)
        tokens_or_inputs, caches = step_args
        args = (abstract, tokens_or_inputs, caches)
        shardings = (pshard, arg_shardings[0], arg_shardings[1])
        donate = (2,)
    else:  # decode
        step = steps_lib.make_serve_step(bundle)
        caches, token, pos = step_args
        args = (abstract, caches, token, pos)
        shardings = (pshard,) + tuple(arg_shardings)
        donate = (1,)
    return step, args, shardings, donate, bundle


def run_one(arch: str, shape_name: str, *, multi_pod: bool,
            scheme: str = "sca", save: bool = True,
            mesh=None, correct_costs: bool = True) -> dict:
    mesh = mesh if mesh is not None else mesh_lib.make_production_mesh(
        multi_pod=multi_pod)
    t0 = time.time()
    with dist.mesh_rules(mesh):
        step, args, shardings, donate, bundle = build_step_and_args(
            arch, shape_name, mesh, scheme)
        jitted = jax.jit(step, in_shardings=shardings,
                         donate_argnums=donate)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    try:
        mem = compiled.memory_analysis()
        mem_info = {
            "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
            "generated_code_bytes": int(
                getattr(mem, "generated_code_size_in_bytes", 0)),
        }
    except Exception as e:  # CPU backend may not implement it
        mem_info = {"error": str(e)}
    cost = cost_analysis_dict(compiled)
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)

    n_dev = mesh.devices.size
    record = {
        "arch": arch,
        "shape": shape_name,
        "mesh": dict(zip(mesh.axis_names, [int(mesh.shape[a])
                                           for a in mesh.axis_names])),
        "devices": int(n_dev),
        "scheme": scheme,
        "num_params": int(bundle.num_params),
        "param_bytes_total": int(param_bytes(bundle.defs)),
        "flops_per_device": float(cost.get("flops", -1.0)),
        "bytes_accessed_per_device": float(cost.get("bytes accessed", -1.0)),
        "collective_bytes_per_device": coll,
        "memory_analysis": mem_info,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
    }
    if correct_costs:
        from repro.launch.cost import corrected_costs
        shape = configs.get_shape(shape_name)
        cfg = (configs.long_context_config(arch) if shape_name == "long_500k"
               else configs.get_config(arch))
        try:
            with dist.mesh_rules(mesh):
                record.update(corrected_costs(record, cfg, shape, mesh))
        except Exception as e:
            record["cost_correction_error"] = repr(e)
    if save:
        os.makedirs(ARTIFACT_DIR, exist_ok=True)
        tag = f"{arch}_{shape_name}_{'multipod' if multi_pod else 'pod'}"
        with open(os.path.join(ARTIFACT_DIR, tag + ".json"), "w") as f:
            json.dump(record, f, indent=1)
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=configs.ARCH_IDS)
    ap.add_argument("--shape", default=None,
                    choices=tuple(configs.SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--scheme", default="sca", choices=pcm.SCHEMES)
    ap.add_argument("--all", action="store_true",
                    help="run every supported (arch x shape)")
    ap.add_argument("--no-correct", action="store_true",
                    help="skip loop-corrected cost extraction (faster; used "
                         "for the multi-pod pass — roofline is single-pod)")
    args = ap.parse_args()

    pairs = []
    if args.all:
        for arch in configs.ARCH_IDS:
            for shp in configs.supported_shapes(arch):
                pairs.append((arch, shp))
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required unless --all")
        pairs = [(args.arch, args.shape)]

    failures = []
    for arch, shp in pairs:
        try:
            rec = run_one(arch, shp, multi_pod=args.multi_pod,
                          scheme=args.scheme,
                          correct_costs=not args.no_correct)
            fl = rec.get("flops_per_device_corrected",
                         rec["flops_per_device"])
            cl = rec.get("collective_bytes_corrected",
                         rec["collective_bytes_per_device"]["total"])
            print(f"OK   {arch:22s} {shp:12s} "
                  f"flops/dev={fl:.3e} coll/dev={cl:.3e}B "
                  f"compile={rec['compile_s']}s", flush=True)
        except Exception as e:
            failures.append((arch, shp, repr(e)))
            traceback.print_exc()
            print(f"FAIL {arch:22s} {shp:12s} {e!r}", flush=True)
    if failures:
        raise SystemExit(f"{len(failures)} dry-run failures: {failures}")


if __name__ == "__main__":
    main()
