"""repro.checkpoint"""
