"""Sharded-aware checkpointing: pytree -> npz + structure manifest.

Arrays are gathered to host (fine for the CPU/reduced paths; the full-size
configs only ever exist abstractly).  Keys are '/'-joined pytree paths, so
restore round-trips through arbitrary nested dict/list/tuple structures.
"""
from __future__ import annotations

import json
import os
from typing import Any

import jax
import numpy as np

PyTree = Any


def _flatten(tree: PyTree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = np.asarray(leaf)
    return out


def save(path: str, tree: PyTree, meta: dict | None = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(tree)
    np.savez(path if path.endswith(".npz") else path + ".npz", **flat)
    manifest = {"keys": sorted(flat), "meta": meta or {}}
    with open(_manifest_path(path), "w") as f:
        json.dump(manifest, f, indent=1)


def _manifest_path(path: str) -> str:
    base = path[:-4] if path.endswith(".npz") else path
    return base + ".manifest.json"


def restore(path: str, like: PyTree) -> PyTree:
    """Restore into the structure of ``like`` (values ignored)."""
    npz = np.load(path if path.endswith(".npz") else path + ".npz")
    flat_like, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for pth, leaf in flat_like:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in pth)
        if key not in npz:
            raise KeyError(f"checkpoint missing key {key!r}")
        arr = npz[key]
        if tuple(arr.shape) != tuple(np.shape(leaf)):
            raise ValueError(f"shape mismatch for {key}: "
                             f"{arr.shape} vs {np.shape(leaf)}")
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def load_meta(path: str) -> dict:
    with open(_manifest_path(path)) as f:
        return json.load(f)["meta"]
