"""Sharded-aware checkpointing: pytree -> npz + structure manifest.

Arrays are gathered to host (fine for the CPU/reduced paths; the full-size
configs only ever exist abstractly).  Keys are '/'-joined pytree paths, so
restore round-trips through arbitrary nested dict/list/tuple structures.

``restore_flat`` walks the CALLER's template, so an archive may carry
extra keys the template doesn't name and they are simply ignored — the
population-mode fleet leans on this: its checkpoints add the streaming
cursor (``pop_last`` / ``pop_state`` re-entry table, ``cohorts_t`` /
``cohorts_idx`` draw history) next to the carry, and a non-population
restore of the same layout never trips over them.
"""
from __future__ import annotations

import json
import os
from typing import Any

import jax
import numpy as np

PyTree = Any


def _flatten(tree: PyTree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = np.asarray(leaf)
    return out


# npz key carrying the JSON-encoded meta dict; lives INSIDE the archive so
# meta and arrays are one atomic unit (see save()).
_META_KEY = "__meta__"


def save(path: str, tree: PyTree, meta: dict | None = None) -> None:
    """Atomic write.  ``meta`` rides INSIDE the npz (as a JSON byte array
    under ``__meta__``), so the arrays and the meta that describes them —
    e.g. the fleet driver's chunks_done counter — are one atomic
    os.replace: a kill at any point leaves either the previous complete
    checkpoint or the new one, never a fresh carry with a stale counter
    (which would make a resumed fleet re-run a chunk from an
    already-advanced carry and silently drift off the uninterrupted run).
    The human-readable manifest is written after the npz and is advisory
    only — readers take meta from the archive."""
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(tree)
    if _META_KEY in flat:
        raise ValueError(f"pytree path collides with {_META_KEY!r}")
    npz_path = path if path.endswith(".npz") else path + ".npz"
    tmp = npz_path + ".tmp.npz"
    meta_bytes = np.frombuffer(json.dumps(meta or {}).encode(), np.uint8)
    np.savez(tmp, **flat, **{_META_KEY: meta_bytes})
    os.replace(tmp, npz_path)
    manifest = {"keys": sorted(flat), "meta": meta or {}}
    tmp_manifest = _manifest_path(path) + ".tmp"
    with open(tmp_manifest, "w") as f:
        json.dump(manifest, f, indent=1)
    os.replace(tmp_manifest, _manifest_path(path))


def _manifest_path(path: str) -> str:
    base = path[:-4] if path.endswith(".npz") else path
    return base + ".manifest.json"


def restore(path: str, like: PyTree) -> PyTree:
    """Restore into the structure of ``like`` (values ignored)."""
    return restore_flat(load_flat(path), like)


def _normalize(arr: np.ndarray, leaf):
    """Return ``arr`` in the exact operand form of the template ``leaf``:
    same dtype AND same container class (np.ndarray vs jax.Array).

    The container class matters for compile caches: jit keys committed
    ``jax.Array`` and host ``np.ndarray`` operands differently even at
    identical avals, so a carry restored as raw npz arrays makes the first
    resumed chunk call compile a second program for a computation that is
    already cached for the live-carry form — the resumed-``adaptive_sca``
    retrace the recompilation audit used to flag.  Values are never
    touched: the dtype cast is a no-op for every round-trip the fleet
    writes (npz preserves dtypes), and re-wrapping bits in a jax.Array is
    exact, so the bitwise-resume contract is unaffected."""
    arr = np.asarray(arr, dtype=np.asarray(leaf).dtype)
    if isinstance(leaf, jax.Array):
        return jax.numpy.asarray(arr)
    return arr


def restore_flat(flat: dict, like: PyTree) -> PyTree:
    """``restore`` from an already-loaded ``load_flat`` dict — callers that
    need both the structured carry and the variable-length extras (the
    fleet driver) read the archive once and reuse it.  Restored leaves are
    normalized to the template's dtype and container class (see
    ``_normalize``) so a resumed run's operands are indistinguishable —
    compile-cache-wise — from an uninterrupted run's."""
    flat_like, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for pth, leaf in flat_like:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in pth)
        if key not in flat:
            raise KeyError(f"checkpoint missing key {key!r}")
        arr = flat[key]
        if tuple(arr.shape) != tuple(np.shape(leaf)):
            raise ValueError(f"shape mismatch for {key}: "
                             f"{arr.shape} vs {np.shape(leaf)}")
        leaves.append(_normalize(arr, leaf))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def load_flat(path: str) -> dict:
    """Load a checkpoint as the flat {'/'-joined-path: array} dict.

    For callers whose restore target has variable-length structure a
    ``restore(like=...)`` template can't express ahead of time — e.g. the
    fleet driver's metric traces / eval history / adaptive-design
    trajectories, whose lengths depend on how many chunks had completed
    when the sweep was preempted (fl.driver, DESIGN.md §Placement).
    """
    npz = np.load(path if path.endswith(".npz") else path + ".npz")
    return {k: npz[k] for k in npz.files if k != _META_KEY}


def load_meta(path: str) -> dict:
    """Meta from inside the npz (atomic with the arrays); checkpoints
    written before meta moved into the archive fall back to the
    manifest."""
    npz = np.load(path if path.endswith(".npz") else path + ".npz")
    if _META_KEY in npz.files:
        return json.loads(bytes(npz[_META_KEY]).decode())
    with open(_manifest_path(path)) as f:
        return json.load(f)["meta"]
