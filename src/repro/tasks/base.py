"""The Task bundle: one FL workload as data x model x eval (DESIGN.md §Tasks).

The paper runs ONE experiment — a synthetic MNIST-like MLP — but nothing in
the bias-variance machinery is workload-specific: the fleet engine consumes
a ``(loss_fn, params, data, run, eval_fn)`` bundle and the OTA math only
needs the parameter dimension ``d``.  A :class:`Task` packages that bundle
behind a stable contract so benchmarks, examples and the fleet executor's
task-first entry points (``fl.driver.run_fleet_task``) never hand-wire a
workload again:

    dataset builder     ``build_data(seed, **kw) -> TaskData`` — fully
                        deterministic in ``seed`` (synthetic, no downloads)
    non-iid partitioner baked into ``build_data`` (ring protocol for the
                        paper task, Dirichlet(α) for cifar_conv, vocab-band
                        rotation for the LM task)
    param init          ``init_params(seed)`` = the task's ParamDef tree
                        materialized from ``jax.random.PRNGKey(seed)``
    loss_fn             ``loss_fn(params, batch) -> scalar`` — pure jnp,
                        jit/vmap/grad-safe (the engine differentiates it
                        inside a scanned, vmapped round body)
    eval_fn             ``make_eval(td)(params) -> {name: scalar}``
    RunConfig defaults  ``run_config(**overrides) -> fl.server.FLRunConfig``
                        plus per-scheme step sizes (``eta_for``)

Tasks are looked up by name through ``repro.tasks.get`` (see registry.py).
The ``paper_mlp`` task through ``run_fleet_task`` is bit-identical to the
pre-task hand-wired path (same key streams, same params — regression-tested
in tests/test_tasks.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax

PyTree = Any


@dataclasses.dataclass(frozen=True)
class TaskData:
    """A materialized workload instance (one ``build_data(seed)`` call).

    train   what the task's runtime consumes: for fleet tasks the stacked
            per-device arrays (x [N, D, ...], y [N, D]) that
            ``run_fleet`` takes as ``data``; the LM task stacks per-step
            client batches [steps, N, per_client, seq+1] instead.
    test    held-out arrays for evaluation (host-resident).
    extras  task-specific payloads (e.g. the global-loss subsample).
    """
    train: Any
    test: Any = None
    extras: dict = dataclasses.field(default_factory=dict)


@dataclasses.dataclass(frozen=True)
class Task:
    """One pluggable FL workload; see the module docstring for the contract.

    The underscored callables are the raw builders a factory wires in;
    consumers go through the public methods, which fix the seed/key
    conventions (init key = PRNGKey(seed), the same convention the
    pre-task benchmarks used — load-bearing for bit-identity).
    """
    name: str
    num_devices: int
    param_dim: int                       # d in the paper's OTA math
    loss_fn: Callable                    # (params, batch) -> scalar
    defaults: dict                       # FLRunConfig kwargs
    _build_data: Callable                # (seed, **kw) -> TaskData
    _init_fn: Callable                   # (key) -> params pytree
    _make_eval: Callable                 # (TaskData) -> eval_fn | None
    scheme_etas: dict = dataclasses.field(default_factory=dict)
    artifact_tag: str = ""               # experiments/<tag>/ for benchmarks
    # which runtime consumes the bundle: "fleet" tasks stack (x, y) device
    # shards for run_fleet_task; "steps" tasks (the LM workload) feed the
    # pjit train step in launch/train.py — the CLIs guard on this so a
    # mismatched --task fails with a clear message, not deep in the engine
    runtime: str = "fleet"
    _sample_batch: Optional[Callable] = None   # (TaskData) -> loss-ready batch
    aux: dict = dataclasses.field(default_factory=dict)

    def build_data(self, seed: int = 0, **kw) -> TaskData:
        return self._build_data(seed, **kw)

    def init_params(self, seed: int = 0) -> PyTree:
        return self._init_fn(jax.random.PRNGKey(seed))

    def make_eval(self, td: TaskData):
        return self._make_eval(td)

    def run_config(self, **overrides):
        """The task's preferred FLRunConfig, with per-call overrides."""
        from repro.fl.server import FLRunConfig  # fl never imports tasks
        kw = dict(self.defaults)
        kw.update(overrides)
        return FLRunConfig(**kw)

    def eta_for(self, scheme_name: str, default: float) -> float:
        """Per-scheme step size (grid-searched once per task, as in the
        paper); schemes without an entry fall back to ``default``."""
        return float(self.scheme_etas.get(scheme_name, default))

    def sample_batch(self, td: TaskData):
        """One loss_fn-ready batch from built data (registry smoke tests)."""
        if self._sample_batch is not None:
            return self._sample_batch(td)
        x_dev, y_dev = td.train
        return x_dev[0], y_dev[0]
