"""repro.tasks — pluggable FL workloads (DESIGN.md §Tasks).

    from repro import tasks
    task = tasks.get("cifar_conv")          # or "paper_mlp" / "token_stream"
    td = task.build_data(seed=0)
    res = run_fleet_task(task, schemes, gains, task.run_config())

Built-in tasks register here; a new workload plugs in by calling
``tasks.register(name, factory)`` with a factory returning a
:class:`~repro.tasks.base.Task`.
"""
from repro.tasks.base import Task, TaskData
from repro.tasks.registry import get, names, register

from repro.tasks.image import make_cifar_conv, make_paper_mlp
from repro.tasks.lm import make_token_stream

register("paper_mlp", make_paper_mlp)
register("cifar_conv", make_cifar_conv)
register("token_stream", make_token_stream, runtime="steps")

__all__ = ["Task", "TaskData", "get", "names", "register",
           "make_paper_mlp", "make_cifar_conv", "make_token_stream"]
