"""String registry of Task factories (DESIGN.md §Tasks).

Factories, not instances: ``get("cifar_conv", samples_per_class=20)``
builds a fresh Task with the overrides applied, so tests and smoke runs
can shrink a workload without a parallel config system.  Building a Task
is cheap (ParamDef trees only); data materializes at ``build_data``.

Each registration records which runtime consumes the bundle ("fleet" for
run_fleet_task workloads, "steps" for the LM/pjit train driver) so CLIs
can list only the tasks they can actually run, without building any.
"""
from __future__ import annotations

from typing import Callable, Dict, Optional, Tuple

from repro.tasks.base import Task

_FACTORIES: Dict[str, Tuple[Callable[..., Task], str]] = {}


def register(name: str, factory: Callable[..., Task],
             runtime: str = "fleet") -> None:
    if name in _FACTORIES:
        raise ValueError(f"task {name!r} already registered")
    _FACTORIES[name] = (factory, runtime)


def get(name: str, *, expect_runtime: Optional[str] = None,
        **overrides) -> Task:
    """Build the named task, passing ``overrides`` to its factory.

    ``expect_runtime`` is the one shared guard for runtime-specific
    consumers (fleet CLIs, the LM train driver): it is checked against
    the REGISTERED runtime before the factory runs, so a mismatched
    ``--task`` fails with this message rather than a factory TypeError
    on runtime-specific overrides.
    """
    if name not in _FACTORIES:
        raise KeyError(f"unknown task {name!r}; available: {names()}")
    factory, runtime = _FACTORIES[name]
    if expect_runtime is not None and runtime != expect_runtime:
        raise ValueError(
            f"task {name!r} is a {runtime!r}-runtime workload; this "
            f"consumer needs one of {names(runtime=expect_runtime)}")
    task = factory(**overrides)
    if task.name != name:
        raise ValueError(f"factory for {name!r} built task {task.name!r}")
    if task.runtime != runtime:
        raise ValueError(f"task {name!r} declares runtime "
                         f"{task.runtime!r} but registered as {runtime!r}")
    return task


def names(runtime: Optional[str] = None) -> tuple:
    """Registered task names, optionally only those a runtime can consume."""
    return tuple(sorted(n for n, (_, rt) in _FACTORIES.items()
                        if runtime is None or rt == runtime))
