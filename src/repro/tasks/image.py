"""Image-classification tasks: the paper's MLP and the CIFAR-class convnet.

``paper_mlp`` is the §IV experiment exactly as the pre-task benchmarks
wired it — mnist_like data, the ring label partition, the 814,090-param
MLP, acc + global-loss eval — so routing it through ``run_fleet_task`` is
bit-identical to the historical ``run_fleet(mlp.mlp_loss, ...)`` path.

``cifar_conv`` is the harder non-iid vision workload the ROADMAP asks
for: deterministic 32x32x3 10-class data, Dirichlet(α) label partition,
a small f32 convnet (models/conv.py), minibatch + flat aggregation as its
preferred sweep mode.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.configs.paper_mlp import CONFIG as PAPER
from repro.data import partition, synthetic
from repro.models import conv, mlp
from repro.models.param import init_params, param_count
from repro.tasks.base import Task, TaskData

# constant step sizes per scheme (grid-searched once, as in the paper);
# the fig2 benchmark historically carried this map — it lives with the
# task now so every consumer of paper_mlp sweeps the same operating points
PAPER_ETAS = {"ideal": 0.08, "opc": 0.06, "sca": 0.06, "lcpc": 0.05,
              "vanilla": 0.05, "bbfl_interior": 0.06,
              "bbfl_alternative": 0.06}

# the convnet reuses the paper task's operating points as-is — they train
# stably under the G_max clip (see the fig2 --task cifar_conv curves); a
# cifar-specific grid search is future work, and would only update this map
CIFAR_ETAS = dict(PAPER_ETAS)


def _image_eval(loss_fn, acc_fn, td: TaskData):
    xt_j, yt_j = jnp.asarray(td.test[0]), jnp.asarray(td.test[1])
    xg, yg = (jnp.asarray(a) for a in td.extras["global"])

    def evals(params):
        return {"acc": acc_fn(params, xt_j, yt_j),
                "global_loss": loss_fn(params, (xg, yg))}
    return evals


def make_paper_mlp(hidden: int = mlp.HIDDEN_DIM,
                   samples_per_class: int = PAPER.samples_per_class,
                   noise: float = 0.75, test_per_class: int = 100,
                   global_eval: int = 4000) -> Task:
    """The paper's §IV workload (defaults = the committed fig2 world)."""
    defs = mlp.mlp_defs(hidden=hidden)

    def build(seed: int = 0) -> TaskData:
        x, y, xt, yt = synthetic.mnist_like(
            samples_per_class, noise=noise, seed=seed,
            test_per_class=test_per_class)
        shards = partition.partition_by_label(
            x, y, PAPER.num_devices, PAPER.labels_per_device,
            PAPER.max_devices_per_label, seed=seed)
        return TaskData(train=partition.stack_shards(shards), test=(xt, yt),
                        extras={"global": (x[:global_eval], y[:global_eval])})

    return Task(
        name="paper_mlp", num_devices=PAPER.num_devices,
        param_dim=param_count(defs), loss_fn=mlp.mlp_loss,
        defaults=dict(eta=0.05, num_rounds=150, eval_every=10,
                      gmax=PAPER.gmax, batch_size=PAPER.local_batch),
        scheme_etas=dict(PAPER_ETAS), artifact_tag="fig2",
        _build_data=build, _init_fn=lambda key: init_params(defs, key),
        _make_eval=lambda td: _image_eval(mlp.mlp_loss, mlp.accuracy, td))


def make_cifar_conv(channels: tuple = (16, 32), hidden: int = 128,
                    num_devices: int = 10, samples_per_class: int = 500,
                    noise: float = 0.25, alpha: float = 0.3,
                    test_per_class: int = 100,
                    global_eval: int = 2000) -> Task:
    """CIFAR-class conv workload: Dirichlet(α) non-iid split, f32 convnet.

    Preferred sweep mode is minibatch + flat (batch_size=32 in the
    defaults): the Dirichlet split makes shard sizes unequal, and
    on-device minibatch sampling (uniform with replacement) decouples the
    round cost from the rectangularized shard length.
    """
    defs = conv.conv_defs(channels, hidden)

    def build(seed: int = 0) -> TaskData:
        x, y, xt, yt = synthetic.cifar_like(
            samples_per_class, noise=noise, seed=seed,
            test_per_class=test_per_class)
        shards = partition.partition_dirichlet(x, y, num_devices,
                                               alpha=alpha, seed=seed)
        # pad=True: Dirichlet shards are unequal; cyclic padding keeps
        # every sample instead of truncating to the smallest shard
        return TaskData(train=partition.stack_shards(shards, pad=True),
                        test=(xt, yt),
                        extras={"global": (x[:global_eval], y[:global_eval])})

    return Task(
        name="cifar_conv", num_devices=num_devices,
        param_dim=param_count(defs), loss_fn=conv.conv_loss,
        defaults=dict(eta=0.05, num_rounds=120, eval_every=10, gmax=10.0,
                      batch_size=32),
        scheme_etas=dict(CIFAR_ETAS), artifact_tag="cifar",
        _build_data=build, _init_fn=lambda key: init_params(defs, key),
        _make_eval=lambda td: _image_eval(conv.conv_loss, conv.accuracy, td))
