"""The token_stream LM task: synthetic-corpus FL language modelling.

Wraps a transformer ModelBundle (models.registry) plus the non-iid client
sharding that ``launch/train.py`` used to hand-roll: each client's Zipf
token stream is rotated into a client-specific vocab band — heterogeneity
analogous to the paper's label split.  The bundle itself rides in
``task.aux["bundle"]`` for runtimes that need more than loss/init (the
pjit train step builds against it).
"""
from __future__ import annotations

import numpy as np

from repro import configs
from repro.data.synthetic import token_stream
from repro.models.registry import build_bundle
from repro.tasks.base import Task, TaskData


def client_batches(vocab: int, num_clients: int, per_client: int, seq: int,
                   steps: int, seed: int = 0) -> np.ndarray:
    """Non-iid client shards [steps, N, per_client, seq+1]: each client's
    stream uses a shifted vocab slice (the band rotation previously wired
    privately inside launch/train.py)."""
    streams = []
    for m in range(num_clients):
        toks = token_stream(steps * per_client * (seq + 1), vocab,
                            seed=seed * 1000 + m)
        band = vocab // max(num_clients, 1)
        toks = (toks + m * band) % vocab
        streams.append(toks.reshape(steps, per_client, seq + 1))
    return np.stack(streams, axis=1)


def make_token_stream(arch: str = "qwen1.5-0.5b", smoke: bool = True,
                      d_model: int = 64, n_layers: int = 2,
                      clients: int = 4, per_client_batch: int = 1,
                      seq: int = 32) -> Task:
    """LM task factory.  Defaults are CPU-tiny (registry smoke scale);
    ``launch/train.py`` passes its CLI sizes through.  ``d_model=0`` /
    ``n_layers=0`` keep the arch's own smoke dimensions."""
    cfg = configs.get_config(arch)
    if smoke:
        over = {}
        if d_model:
            over.update(d_model=d_model, n_heads=max(4, d_model // 64),
                        n_kv_heads=max(2, d_model // 128),
                        d_ff=d_model * 3, vocab_size=8192)
        if n_layers:
            over["n_layers"] = n_layers
        cfg = cfg.smoke(**over)
    bundle = build_bundle(cfg, tp=1, dp=1)

    def build(seed: int = 0, steps: int = 8) -> TaskData:
        # one extra step's worth of tokens becomes the held-out eval batch
        data = client_batches(cfg.vocab_size, clients, per_client_batch,
                              seq, steps + 1, seed)
        test = data[-1].reshape(-1, seq + 1)
        return TaskData(train=data[:steps], test=test,
                        extras={"steps": steps})

    def make_eval(td: TaskData):
        import jax.numpy as jnp
        test = jnp.asarray(td.test)
        return lambda params: {"loss": bundle.loss(params, test)}

    def sample_batch(td: TaskData):
        import jax.numpy as jnp
        return jnp.asarray(td.train[0].reshape(-1, seq + 1))

    return Task(
        name="token_stream", num_devices=clients,
        param_dim=bundle.num_params,
        loss_fn=lambda params, batch: bundle.loss(params, batch),
        defaults=dict(eta=0.05, num_rounds=50, eval_every=10, gmax=10.0,
                      batch_size=0),
        artifact_tag="lm", runtime="steps", _build_data=build,
        _init_fn=bundle.init, _make_eval=make_eval,
        _sample_batch=sample_batch, aux={"bundle": bundle, "cfg": cfg})
