"""Wireless channel model for OTA-FL (paper §II; DESIGN.md §Scenarios).

Baseline: flat Rayleigh fading MAC, h_{m,t} ~ CN(0, Lambda_m), i.i.d. over
rounds, independent across devices.  Lambda_m (average channel gain) follows
the log-distance path-loss model of §IV:

    PL(dist)[dB] = PL0 + 10 * beta * log10(dist / d0)

with PL0 = 50 dB at d0 = 1 m and path-loss exponent beta = 2.2.

Beyond the paper's Rayleigh baseline, ``FadingSpec`` describes the
small-scale fading *family* (Rayleigh / Rician with per-device K-factor /
Nakagami-m), always normalized so E|h_m|^2 = Lambda_m.  The statistical-CSI
quantities the power-control designs need — the magnitude survival function
P(|h| >= x) and magnitude quantiles — have per-family closed forms here,
with a Monte-Carlo fallback for families without one.  Scenario composition
(deployment geometries, shadowing, round dynamics) lives in
``repro.core.scenarios``.

All power-control math is done in float64 numpy (the physical scales are
~1e-9 .. 1e-21); the training path consumes the resulting dimensionless
per-round coefficients in float32.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

# ---------------------------------------------------------------------------
# Paper §IV physical constants (defaults; all overridable via WirelessConfig).
# ---------------------------------------------------------------------------
DEFAULT_PL0_DB = 50.0          # path loss at reference distance (dB)
DEFAULT_PL_EXPONENT = 2.2      # path loss exponent
DEFAULT_R_MAX = 1750.0         # deployment radius (m)
DEFAULT_BANDWIDTH = 1e6        # B = 1 MHz
DEFAULT_PTX_DBM = 0.0          # transmit power, 0 dBm
DEFAULT_N0_DBM_HZ = -173.0     # noise PSD at the PS, -173 dBm/Hz


def dbm_to_watt(dbm: float) -> float:
    return 10.0 ** (dbm / 10.0) * 1e-3


def path_loss_db(dist_m: np.ndarray, pl0_db: float = DEFAULT_PL0_DB,
                 exponent: float = DEFAULT_PL_EXPONENT) -> np.ndarray:
    """Log-distance path loss in dB at distance ``dist_m`` meters."""
    dist_m = np.asarray(dist_m, dtype=np.float64)
    return pl0_db + 10.0 * exponent * np.log10(np.maximum(dist_m, 1.0))


def average_gain(dist_m: np.ndarray, pl0_db: float = DEFAULT_PL0_DB,
                 exponent: float = DEFAULT_PL_EXPONENT) -> np.ndarray:
    """Lambda_m: linear average channel power gain."""
    return 10.0 ** (-path_loss_db(dist_m, pl0_db, exponent) / 10.0)


# ---------------------------------------------------------------------------
# Small-scale fading families (DESIGN.md §Scenarios).
# ---------------------------------------------------------------------------

FADING_FAMILIES = ("rayleigh", "rician", "nakagami")


@dataclasses.dataclass(frozen=True)
class FadingSpec:
    """Small-scale fading family, normalized so E|h_m|^2 = Lambda_m.

    rayleigh    h ~ CN(0, Lambda)                       (paper baseline)
    rician      h = sqrt(K Lambda/(K+1)) + CN(0, Lambda/(K+1)); the K-factor
                may be a scalar or a per-device [N] array (LOS-rich near
                devices, scattered far devices).
    nakagami    |h|^2 ~ Gamma(m, Lambda/m), uniform phase; m >= 0.5 scalar
                or per-device [N].  m=1 recovers Rayleigh.
    """
    family: str = "rayleigh"
    rician_k: object = 5.0       # K-factor (linear), scalar or [N]
    nakagami_m: object = 2.0     # shape m >= 0.5, scalar or [N]

    def __post_init__(self):
        if self.family not in FADING_FAMILIES:
            raise ValueError(f"unknown fading family {self.family!r}; "
                             f"available: {FADING_FAMILIES}")


RAYLEIGH = FadingSpec()


def _per_device(param, shape) -> np.ndarray:
    """Broadcast a scalar or per-device [N] parameter to ``shape``, where the
    leading axis of ``shape`` is the device axis (e.g. [N, G] grids)."""
    p = np.asarray(param, dtype=np.float64)
    if p.ndim == 1 and len(shape) > 1 and p.shape[0] == shape[0]:
        p = p.reshape((shape[0],) + (1,) * (len(shape) - 1))
    return np.broadcast_to(p, shape)


def _rician_nu_sigma(gains: np.ndarray, k: np.ndarray):
    """Rice parameters: LOS amplitude nu and diffuse per-component std sigma."""
    nu = np.sqrt(gains * k / (k + 1.0))
    sigma = np.sqrt(gains / (2.0 * (k + 1.0)))
    return nu, sigma


@dataclasses.dataclass(frozen=True)
class WirelessConfig:
    """Statistical description of the heterogeneous wireless deployment.

    This is the *statistical CSI* the PS is allowed to know ({Lambda_m});
    instantaneous CSI {h_{m,t}} is drawn per round and only visible to the
    owning device (and to baselines that explicitly require global CSI).
    """
    num_devices: int = 10
    r_max: float = DEFAULT_R_MAX
    pl0_db: float = DEFAULT_PL0_DB
    pl_exponent: float = DEFAULT_PL_EXPONENT
    bandwidth_hz: float = DEFAULT_BANDWIDTH
    ptx_dbm: float = DEFAULT_PTX_DBM
    n0_dbm_hz: float = DEFAULT_N0_DBM_HZ
    seed: int = 0

    @property
    def ptx_watt(self) -> float:
        return dbm_to_watt(self.ptx_dbm)

    @property
    def energy_per_sample(self) -> float:
        """E_s: max per-sample (per-symbol) energy budget = Ptx / B [J]."""
        return self.ptx_watt / self.bandwidth_hz

    @property
    def noise_psd(self) -> float:
        """N0 in W/Hz == J (noise energy per symbol at unit bandwidth)."""
        return dbm_to_watt(self.n0_dbm_hz)


@dataclasses.dataclass(frozen=True)
class Deployment:
    """A realized device deployment: distances and average gains.

    ``fading`` (None = Rayleigh, the paper baseline) carries the small-scale
    family so power-control designs built from this deployment use the right
    statistical-CSI formulas; ``shadowing_db`` keeps the realized log-normal
    shadowing offsets (already folded into ``gains``) for inspection.
    """
    cfg: WirelessConfig
    distances: np.ndarray    # [N] meters
    gains: np.ndarray        # [N] Lambda_m (linear)
    fading: Optional[FadingSpec] = None
    shadowing_db: Optional[np.ndarray] = None   # [N] dB, already in gains
    p_dropout: float = 0.0   # per-round device dropout prob (scenario dynamics)

    @property
    def num_devices(self) -> int:
        return int(self.gains.shape[0])

    @property
    def fading_spec(self) -> FadingSpec:
        return self.fading if self.fading is not None else RAYLEIGH


def deploy(cfg: WirelessConfig, distances: Optional[np.ndarray] = None) -> Deployment:
    """Uniformly deploy ``cfg.num_devices`` devices in a disk of radius r_max.

    Area-uniform: r = r_max * sqrt(U).  Deterministic given cfg.seed.
    """
    if distances is None:
        rng = np.random.default_rng(cfg.seed)
        u = rng.uniform(size=cfg.num_devices)
        distances = cfg.r_max * np.sqrt(u)
        # Keep devices at least 1 m away from the PS (reference distance).
        distances = np.maximum(distances, 1.0)
    distances = np.asarray(distances, dtype=np.float64)
    gains = average_gain(distances, cfg.pl0_db, cfg.pl_exponent)
    return Deployment(cfg=cfg, distances=distances, gains=gains)


def draw_fading(rng: np.random.Generator, gains: np.ndarray,
                num_rounds: int = 1,
                spec: Optional[FadingSpec] = None) -> np.ndarray:
    """Draw h_{m,t} per ``spec``, shape [num_rounds, N] complex128.

    Default (spec None / rayleigh): h ~ CN(0, L), real/imag each N(0, L/2)
    so that E|h|^2 = L.  All families preserve E|h|^2 = L exactly.
    """
    gains = np.asarray(gains, dtype=np.float64)
    n = gains.shape[0]
    if spec is None or spec.family == "rayleigh":
        scale = np.sqrt(gains / 2.0)
        re = rng.standard_normal((num_rounds, n)) * scale
        im = rng.standard_normal((num_rounds, n)) * scale
        return re + 1j * im
    if spec.family == "rician":
        k = _per_device(spec.rician_k, (n,))
        nu, sigma = _rician_nu_sigma(gains, k)
        re = nu + rng.standard_normal((num_rounds, n)) * sigma
        im = rng.standard_normal((num_rounds, n)) * sigma
        return re + 1j * im
    if spec.family == "nakagami":
        m = _per_device(spec.nakagami_m, (n,))
        power = rng.gamma(shape=np.broadcast_to(m, (num_rounds, n)),
                          scale=np.broadcast_to(gains / m, (num_rounds, n)))
        phase = rng.uniform(0.0, 2.0 * np.pi, size=(num_rounds, n))
        return np.sqrt(power) * np.exp(1j * phase)
    raise ValueError(f"unknown fading family {spec.family!r}")


def fading_magnitude_sf(gains: np.ndarray, x: np.ndarray,
                        spec: Optional[FadingSpec] = None) -> np.ndarray:
    """Survival function P(|h_m| >= x) per device (broadcasts gains vs x).

    This is the E[chi] primitive of the truncated-inversion designs
    (theory.expected_participation_indicator) for every fading family:

      rayleigh   exp(-x^2 / L)
      rician     Marcum-Q_1(nu/sigma, x/sigma)         (scipy.stats.rice)
      nakagami   Gamma(m, m x^2 / L) / Gamma(m)        (regularized upper)
    """
    g0 = np.asarray(gains, dtype=np.float64)
    x = np.asarray(x, dtype=np.float64)
    if spec is None or spec.family == "rayleigh":
        return np.exp(-x**2 / g0)
    if spec.family == "rician":
        from scipy.stats import rice
        k = _per_device(spec.rician_k, g0.shape)
        gains, x, k = np.broadcast_arrays(g0, x, k)
        nu, sigma = _rician_nu_sigma(gains, k)
        return rice.sf(x / sigma, nu / sigma)
    if spec.family == "nakagami":
        from scipy.special import gammaincc
        m = _per_device(spec.nakagami_m, g0.shape)
        gains, x, m = np.broadcast_arrays(g0, x, m)
        return gammaincc(m, m * x**2 / gains)
    raise ValueError(f"unknown fading family {spec.family!r}")


def fading_magnitude_quantile(gains: np.ndarray, q: float,
                              spec: Optional[FadingSpec] = None) -> np.ndarray:
    """q-quantile of |h_m| per fading family (closed forms).

    Rayleigh (default): P(|h| <= x) = 1 - exp(-x^2/L) => x_q = sqrt(-L ln(1-q)).
    Rician: scipy rice.ppf.  Nakagami: x_q = sqrt(L P^{-1}(m, q) / m) with
    P the regularized lower incomplete gamma.  Any future family without a
    closed form can use ``fading_magnitude_quantile_mc``.
    """
    gains = np.asarray(gains, dtype=np.float64)
    if spec is None or spec.family == "rayleigh":
        return np.sqrt(-gains * np.log1p(-q))
    if spec.family == "rician":
        from scipy.stats import rice
        k = _per_device(spec.rician_k, gains.shape)
        nu, sigma = _rician_nu_sigma(gains, k)
        return rice.ppf(q, nu / sigma) * sigma
    if spec.family == "nakagami":
        from scipy.special import gammaincinv
        m = _per_device(spec.nakagami_m, gains.shape)
        return np.sqrt(gains * gammaincinv(m, q) / m)
    raise ValueError(f"unknown fading family {spec.family!r}")


def fading_magnitude_quantile_mc(gains: np.ndarray, q: float,
                                 spec: Optional[FadingSpec] = None,
                                 num_draws: int = 200_000,
                                 seed: int = 0) -> np.ndarray:
    """Monte-Carlo magnitude quantile — fallback/cross-check for any family
    ``draw_fading`` can sample (used by tests to validate the closed forms)."""
    rng = np.random.default_rng(seed)
    h = np.abs(draw_fading(rng, gains, num_rounds=num_draws, spec=spec))
    return np.quantile(h, q, axis=0)
