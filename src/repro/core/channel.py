"""Wireless channel model for OTA-FL (paper §II).

Flat Rayleigh fading MAC: h_{m,t} ~ CN(0, Lambda_m), i.i.d. over rounds,
independent across devices.  Lambda_m (average channel gain) follows the
log-distance path-loss model of §IV:

    PL(dist)[dB] = PL0 + 10 * beta * log10(dist / d0)

with PL0 = 50 dB at d0 = 1 m and path-loss exponent beta = 2.2.

All power-control math is done in float64 numpy (the physical scales are
~1e-9 .. 1e-21); the training path consumes the resulting dimensionless
per-round coefficients in float32.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

# ---------------------------------------------------------------------------
# Paper §IV physical constants (defaults; all overridable via WirelessConfig).
# ---------------------------------------------------------------------------
DEFAULT_PL0_DB = 50.0          # path loss at reference distance (dB)
DEFAULT_PL_EXPONENT = 2.2      # path loss exponent
DEFAULT_R_MAX = 1750.0         # deployment radius (m)
DEFAULT_BANDWIDTH = 1e6        # B = 1 MHz
DEFAULT_PTX_DBM = 0.0          # transmit power, 0 dBm
DEFAULT_N0_DBM_HZ = -173.0     # noise PSD at the PS, -173 dBm/Hz


def dbm_to_watt(dbm: float) -> float:
    return 10.0 ** (dbm / 10.0) * 1e-3


def path_loss_db(dist_m: np.ndarray, pl0_db: float = DEFAULT_PL0_DB,
                 exponent: float = DEFAULT_PL_EXPONENT) -> np.ndarray:
    """Log-distance path loss in dB at distance ``dist_m`` meters."""
    dist_m = np.asarray(dist_m, dtype=np.float64)
    return pl0_db + 10.0 * exponent * np.log10(np.maximum(dist_m, 1.0))


def average_gain(dist_m: np.ndarray, pl0_db: float = DEFAULT_PL0_DB,
                 exponent: float = DEFAULT_PL_EXPONENT) -> np.ndarray:
    """Lambda_m: linear average channel power gain."""
    return 10.0 ** (-path_loss_db(dist_m, pl0_db, exponent) / 10.0)


@dataclasses.dataclass(frozen=True)
class WirelessConfig:
    """Statistical description of the heterogeneous wireless deployment.

    This is the *statistical CSI* the PS is allowed to know ({Lambda_m});
    instantaneous CSI {h_{m,t}} is drawn per round and only visible to the
    owning device (and to baselines that explicitly require global CSI).
    """
    num_devices: int = 10
    r_max: float = DEFAULT_R_MAX
    pl0_db: float = DEFAULT_PL0_DB
    pl_exponent: float = DEFAULT_PL_EXPONENT
    bandwidth_hz: float = DEFAULT_BANDWIDTH
    ptx_dbm: float = DEFAULT_PTX_DBM
    n0_dbm_hz: float = DEFAULT_N0_DBM_HZ
    seed: int = 0

    @property
    def ptx_watt(self) -> float:
        return dbm_to_watt(self.ptx_dbm)

    @property
    def energy_per_sample(self) -> float:
        """E_s: max per-sample (per-symbol) energy budget = Ptx / B [J]."""
        return self.ptx_watt / self.bandwidth_hz

    @property
    def noise_psd(self) -> float:
        """N0 in W/Hz == J (noise energy per symbol at unit bandwidth)."""
        return dbm_to_watt(self.n0_dbm_hz)


@dataclasses.dataclass(frozen=True)
class Deployment:
    """A realized device deployment: distances and average gains."""
    cfg: WirelessConfig
    distances: np.ndarray    # [N] meters
    gains: np.ndarray        # [N] Lambda_m (linear)

    @property
    def num_devices(self) -> int:
        return int(self.gains.shape[0])


def deploy(cfg: WirelessConfig, distances: Optional[np.ndarray] = None) -> Deployment:
    """Uniformly deploy ``cfg.num_devices`` devices in a disk of radius r_max.

    Area-uniform: r = r_max * sqrt(U).  Deterministic given cfg.seed.
    """
    if distances is None:
        rng = np.random.default_rng(cfg.seed)
        u = rng.uniform(size=cfg.num_devices)
        distances = cfg.r_max * np.sqrt(u)
        # Keep devices at least 1 m away from the PS (reference distance).
        distances = np.maximum(distances, 1.0)
    distances = np.asarray(distances, dtype=np.float64)
    gains = average_gain(distances, cfg.pl0_db, cfg.pl_exponent)
    return Deployment(cfg=cfg, distances=distances, gains=gains)


def draw_fading(rng: np.random.Generator, gains: np.ndarray,
                num_rounds: int = 1) -> np.ndarray:
    """Draw h_{m,t} ~ CN(0, Lambda_m), shape [num_rounds, N] complex128.

    CN(0, L): real/imag each N(0, L/2) so that E|h|^2 = L.
    """
    gains = np.asarray(gains, dtype=np.float64)
    n = gains.shape[0]
    scale = np.sqrt(gains / 2.0)
    re = rng.standard_normal((num_rounds, n)) * scale
    im = rng.standard_normal((num_rounds, n)) * scale
    return re + 1j * im


def fading_magnitude_quantile(gains: np.ndarray, q: float) -> np.ndarray:
    """q-quantile of |h_m| under Rayleigh fading: |h| ~ Rayleigh(sqrt(L/2)).

    P(|h| <= x) = 1 - exp(-x^2 / L)  =>  x_q = sqrt(-L * ln(1-q)).
    """
    gains = np.asarray(gains, dtype=np.float64)
    return np.sqrt(-gains * np.log1p(-q))
