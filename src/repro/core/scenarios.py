"""Scenario engine for heterogeneous wireless deployments (DESIGN.md §Scenarios).

The paper's experiments realize exactly one scenario family: devices
area-uniform in a disk, log-distance path loss, i.i.d. flat Rayleigh fading.
The bias-variance trade-off it studies, however, is driven by *wireless
heterogeneity* — which has four largely independent axes.  A ``Scenario``
composes one choice per axis:

    geometry     where devices sit: uniform disk (baseline), annular ring,
                 two-cluster near/far, fixed-distance grid
    large-scale  log-distance path loss, optionally with log-normal
                 shadowing (ShadowingSpec, sigma in dB)
    small-scale  fading family: Rayleigh / Rician(K) / Nakagami-m
                 (channel.FadingSpec, per-device parameters allowed)
    dynamics     round-to-round behaviour: i.i.d. (baseline), Gauss-Markov
                 correlated fading (rho), round-level device dropout

``realize`` turns a Scenario into an ordinary ``channel.Deployment`` — the
(gains, fading-spec) interface every PowerControl scheme and ``fl.server``
round function already consumes — so SCA/LCPC/vanilla/OPC/BB-FL run
unchanged on any scenario.  ``make_fading_process`` builds the matching
jit-friendly per-round sampler (stateful for Gauss-Markov / dropout).  The
baseline ``disk_rayleigh`` scenario reproduces ``channel.deploy`` and the
pre-scenario training path bit-for-bit.

A registry of named scenarios (``get_scenario`` / ``register_scenario``)
feeds the sweep runner in ``benchmarks/scenario_sweep.py``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import channel, ota
from repro.core.channel import (Deployment, FadingSpec, RAYLEIGH,
                                WirelessConfig)
from repro.core.theory import OTAParams

# ---------------------------------------------------------------------------
# Axis specs
# ---------------------------------------------------------------------------

GEOMETRIES = ("disk", "ring", "two_cluster", "grid")


@dataclasses.dataclass(frozen=True)
class GeometrySpec:
    """Deployment geometry.  Distances are in meters, relative to the PS.

    disk         area-uniform in [0, r_max] (identical sampling to
                 channel.deploy — the paper baseline)
    ring         area-uniform in the annulus [r_min, r_max]
    two_cluster  near_frac of devices ~ N(near_center, cluster_spread),
                 the rest ~ N(far_center, cluster_spread)
    grid         deterministic distances: ``distances`` if given, else
                 linspace(max(r_min, 1), r_max, N)
    """
    kind: str = "disk"
    r_min: float = 0.0
    near_frac: float = 0.5
    near_center: float = 150.0
    far_center: float = 1600.0
    cluster_spread: float = 50.0
    distances: Optional[Tuple[float, ...]] = None

    def __post_init__(self):
        if self.kind not in GEOMETRIES:
            raise ValueError(f"unknown geometry {self.kind!r}; "
                             f"available: {GEOMETRIES}")


@dataclasses.dataclass(frozen=True)
class ShadowingSpec:
    """Log-normal shadowing on top of path loss: PL_dB += N(0, sigma_db^2)."""
    sigma_db: float = 8.0


@dataclasses.dataclass(frozen=True)
class DynamicsSpec:
    """Round-to-round channel dynamics.

    rho        Gauss-Markov correlation of the scattered component across
               rounds: d_t = rho d_{t-1} + sqrt(1-rho^2) w_t (stationary
               marginal preserved; rho=0 is the i.i.d. paper baseline).
               Supported for rayleigh/rician (Gaussian scattered part).
    p_dropout  probability a device drops out of a round entirely
               (straggler/outage model): its channel is observed as h=0,
               which every scheme maps to non-participation.
    """
    rho: float = 0.0
    p_dropout: float = 0.0

    def __post_init__(self):
        if not (0.0 <= self.rho < 1.0):
            raise ValueError("rho in [0, 1)")
        if not (0.0 <= self.p_dropout < 1.0):
            raise ValueError("p_dropout in [0, 1)")


@dataclasses.dataclass(frozen=True)
class Scenario:
    """Composable (geometry x large-scale x small-scale x dynamics) spec."""
    name: str
    geometry: GeometrySpec = GeometrySpec()
    fading: FadingSpec = RAYLEIGH
    shadowing: Optional[ShadowingSpec] = None
    dynamics: DynamicsSpec = DynamicsSpec()
    wireless: WirelessConfig = WirelessConfig()
    description: str = ""

    def __post_init__(self):
        if self.fading.family == "nakagami" and self.dynamics.rho > 0:
            raise ValueError("Gauss-Markov dynamics need a Gaussian scattered "
                             "component (rayleigh/rician); nakagami has none")
        n = self.wireless.num_devices
        for pname in ("rician_k", "nakagami_m"):
            v = np.asarray(getattr(self.fading, pname), dtype=np.float64)
            if v.ndim > 0 and v.shape != (n,):
                raise ValueError(
                    f"per-device {pname} has shape {v.shape} but the "
                    f"scenario deploys {n} devices")

    def replace(self, **kw) -> "Scenario":
        return dataclasses.replace(self, **kw)

    @property
    def is_baseline(self) -> bool:
        """True iff this is the paper's disk-Rayleigh-iid family."""
        return (self.geometry.kind == "disk" and self.shadowing is None
                and self.fading.family == "rayleigh"
                and self.dynamics == DynamicsSpec())


# ---------------------------------------------------------------------------
# Realization: Scenario -> Deployment
# ---------------------------------------------------------------------------

def sample_distances(geom: GeometrySpec, cfg: WirelessConfig,
                     rng: np.random.Generator) -> np.ndarray:
    """Draw [N] device distances for the given geometry.

    The disk branch consumes the rng stream exactly like channel.deploy so
    the baseline scenario reproduces the paper deployment bit-for-bit.
    """
    n, r_max = cfg.num_devices, cfg.r_max
    if geom.kind == "disk":
        u = rng.uniform(size=n)
        dist = r_max * np.sqrt(u)
    elif geom.kind == "ring":
        u = rng.uniform(size=n)
        dist = np.sqrt(geom.r_min**2 + u * (r_max**2 - geom.r_min**2))
    elif geom.kind == "two_cluster":
        n_near = int(np.clip(round(geom.near_frac * n), 1, n - 1))
        centers = np.where(np.arange(n) < n_near, geom.near_center,
                           geom.far_center)
        dist = centers + rng.standard_normal(n) * geom.cluster_spread
        dist = np.minimum(dist, r_max)
    elif geom.kind == "grid":
        if geom.distances is not None:
            dist = np.asarray(geom.distances, dtype=np.float64)
            if dist.shape != (n,):
                raise ValueError(f"grid distances {dist.shape} != ({n},)")
        else:
            dist = np.linspace(max(geom.r_min, 1.0), r_max, n)
    else:  # unreachable: GeometrySpec validates kind
        raise ValueError(geom.kind)
    return np.maximum(np.asarray(dist, dtype=np.float64), 1.0)


def realize(scenario: Scenario, seed: Optional[int] = None) -> Deployment:
    """Sample a concrete Deployment: distances, (shadowed) gains, fading spec.

    Deterministic given the wireless seed; pass ``seed`` to override it.
    """
    cfg = scenario.wireless
    if seed is not None:
        cfg = dataclasses.replace(cfg, seed=seed)
    rng = np.random.default_rng(cfg.seed)
    distances = sample_distances(scenario.geometry, cfg, rng)
    gains = channel.average_gain(distances, cfg.pl0_db, cfg.pl_exponent)
    shadow_db = None
    if scenario.shadowing is not None and scenario.shadowing.sigma_db > 0:
        shadow_db = rng.normal(0.0, scenario.shadowing.sigma_db,
                               size=cfg.num_devices)
        gains = gains * 10.0 ** (-shadow_db / 10.0)
    return Deployment(cfg=cfg, distances=distances, gains=gains,
                      fading=scenario.fading, shadowing_db=shadow_db,
                      p_dropout=scenario.dynamics.p_dropout)


def make_ota_params(dep: Deployment, d: int, gmax: float,
                    sigma_sq: Optional[np.ndarray] = None,
                    **kw) -> OTAParams:
    """Family-aware OTAParams from a realized deployment (carries the
    scenario's fading spec and dropout rate into the statistical CSI)."""
    spec = dep.fading
    if spec is not None and spec.family == "rayleigh":
        spec = None   # keep the exact Rayleigh closed-form fast path
    if sigma_sq is None:
        sigma_sq = np.zeros(dep.num_devices)
    return OTAParams(d=d, gmax=gmax, es=dep.cfg.energy_per_sample,
                     n0=dep.cfg.noise_psd, gains=dep.gains,
                     sigma_sq=sigma_sq, fading=spec,
                     dropout=dep.p_dropout, **kw)


# ---------------------------------------------------------------------------
# Per-round fading process (jit-friendly; duck-typed by fl.server)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class FadingProcess:
    """Stateful per-round sampler h_t for a realized deployment.

    ``init(key) -> state`` and ``step(state, key) -> (state, h)`` embed in a
    jit'd round function; ``state`` is the scattered (Gauss-Markov) channel
    component, a complex [N] array (unused but threaded for the i.i.d. case
    so the round-function signature is static).

    For rho == 0 and p_dropout == 0, ``step`` consumes the key exactly like
    ``ota.draw_fading`` in the pre-scenario path — the baseline training
    trajectory is bit-for-bit identical.
    """
    gains: jnp.ndarray
    family: str = "rayleigh"
    k_factor: Optional[jnp.ndarray] = None    # rician
    m: Optional[jnp.ndarray] = None           # nakagami
    rho: float = 0.0
    p_dropout: float = 0.0

    def _draw_iid(self, key: jax.Array) -> jax.Array:
        if self.family == "rayleigh":
            return ota.draw_fading(key, self.gains)
        if self.family == "rician":
            return ota.draw_fading_rician(key, self.gains, self.k_factor)
        return ota.draw_fading_nakagami(key, self.gains, self.m)

    def _diffuse_gains(self) -> jnp.ndarray:
        if self.family == "rician":
            return self.gains / (self.k_factor + 1.0)
        return self.gains

    def _los(self) -> jnp.ndarray:
        if self.family == "rician":
            return jnp.sqrt(self.gains * self.k_factor / (self.k_factor + 1.0))
        return jnp.zeros_like(self.gains)

    def init(self, key: jax.Array) -> jax.Array:
        """Stationary scattered-component draw (state for Markov dynamics)."""
        return ota.draw_fading(key, self._diffuse_gains())

    def init_batch(self, keys: jax.Array) -> jax.Array:
        """Batched ``init`` for the vmapped experiment engine: ``keys`` has
        arbitrary leading axes [..., 2] and the returned state carries the
        matching leading batch axes [..., N].  Each batch cell consumes its
        key exactly like a standalone ``init`` call, so a fleet cell's
        fading stream is identical to the corresponding single run's."""
        flat = keys.reshape((-1,) + keys.shape[-1:])
        states = jax.vmap(self.init)(flat)
        return states.reshape(keys.shape[:-1] + states.shape[1:])

    def step_batch(self, state: jax.Array, keys: jax.Array):
        """Batched ``step`` over matching leading axes of state [..., N]
        and keys [..., 2] (i.e. the engine's [K, S] grid)."""
        batch = state.shape[:-1]
        flat_s = state.reshape((-1,) + state.shape[-1:])
        flat_k = keys.reshape((-1,) + keys.shape[-1:])
        flat_s, h = jax.vmap(self.step)(flat_s, flat_k)
        return (flat_s.reshape(state.shape),
                h.reshape(batch + h.shape[-1:]))

    def step(self, state: jax.Array, key: jax.Array):
        if self.rho == 0.0 and self.p_dropout == 0.0:
            return state, self._draw_iid(key)
        k_fade, k_drop = jax.random.split(key)
        if self.rho > 0.0:
            w = ota.draw_fading(k_fade, self._diffuse_gains())
            state = self.rho * state + np.sqrt(1.0 - self.rho**2) * w
            h = jax.lax.complex(self._los() + state.real, state.imag)
        else:
            h = self._draw_iid(k_fade)
        if self.p_dropout > 0.0:
            keep = jax.random.bernoulli(k_drop, 1.0 - self.p_dropout,
                                        self.gains.shape)
            h = jnp.where(keep, h, jnp.zeros_like(h))
        return state, h


def make_fading_process(dep: Deployment,
                        dynamics: Optional[DynamicsSpec] = None
                        ) -> FadingProcess:
    """Build the jit-friendly sampler matching a deployment's fading spec."""
    spec = dep.fading_spec
    dyn = dynamics if dynamics is not None else DynamicsSpec()
    if spec.family == "nakagami" and dyn.rho > 0:
        raise ValueError("Gauss-Markov dynamics unsupported for nakagami")
    n = dep.num_devices
    gains = jnp.asarray(dep.gains)
    k_factor = m = None
    if spec.family == "rician":
        k_factor = jnp.asarray(np.broadcast_to(
            np.asarray(spec.rician_k, np.float64), (n,)))
    if spec.family == "nakagami":
        m = jnp.asarray(np.broadcast_to(
            np.asarray(spec.nakagami_m, np.float64), (n,)))
    return FadingProcess(gains=gains, family=spec.family, k_factor=k_factor,
                         m=m, rho=dyn.rho, p_dropout=dyn.p_dropout)


def scenario_fading_process(scenario: Scenario,
                            dep: Optional[Deployment] = None) -> FadingProcess:
    if dep is None:
        dep = realize(scenario)
    return make_fading_process(dep, scenario.dynamics)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Scenario] = {}


def register_scenario(sc: Scenario, overwrite: bool = False) -> Scenario:
    if sc.name in _REGISTRY and not overwrite:
        raise ValueError(f"scenario {sc.name!r} already registered")
    _REGISTRY[sc.name] = sc
    return sc


def get_scenario(name: str) -> Scenario:
    if name not in _REGISTRY:
        raise ValueError(f"unknown scenario {name!r}; "
                         f"available: {scenario_names()}")
    return _REGISTRY[name]


def scenario_names() -> Tuple[str, ...]:
    return tuple(_REGISTRY)


register_scenario(Scenario(
    name="disk_rayleigh",
    description="Paper baseline: area-uniform disk, log-distance path loss, "
                "i.i.d. Rayleigh (bit-identical to channel.deploy)."))

register_scenario(Scenario(
    name="disk_rician",
    fading=FadingSpec(family="rician", rician_k=5.0),
    description="Disk deployment with LOS-rich Rician fading, K = 5."))

register_scenario(Scenario(
    name="disk_rician_mixed",
    fading=FadingSpec(family="rician",
                      rician_k=(10.0, 10.0, 10.0, 10.0, 10.0,
                                0.5, 0.5, 0.5, 0.5, 0.5)),
    description="Per-device K-factor: half the fleet near-LOS (K=10), half "
                "heavily scattered (K=0.5)."))

register_scenario(Scenario(
    name="disk_nakagami",
    fading=FadingSpec(family="nakagami", nakagami_m=2.0),
    description="Disk deployment with milder-than-Rayleigh Nakagami-2 fading."))

register_scenario(Scenario(
    name="disk_shadowed",
    shadowing=ShadowingSpec(sigma_db=8.0),
    description="Disk + 8 dB log-normal shadowing on top of path loss."))

register_scenario(Scenario(
    name="two_cluster",
    geometry=GeometrySpec(kind="two_cluster"),
    description="Near/far clusters (150 m vs 1600 m): the extreme "
                "heterogeneity regime where bias control matters most."))

register_scenario(Scenario(
    name="ring",
    geometry=GeometrySpec(kind="ring", r_min=1000.0),
    fading=FadingSpec(family="nakagami", nakagami_m=1.5),
    description="Cell-edge annulus (1000-1750 m) with Nakagami-1.5 fading: "
                "homogeneous gains, weak channels."))

register_scenario(Scenario(
    name="disk_markov",
    dynamics=DynamicsSpec(rho=0.95),
    description="Disk-Rayleigh with Gauss-Markov round correlation rho=0.95 "
                "(slow fading relative to the round cadence)."))

register_scenario(Scenario(
    name="disk_dropout",
    dynamics=DynamicsSpec(p_dropout=0.1),
    description="Disk-Rayleigh where each device independently drops out of "
                "10% of rounds (outage/straggler model)."))

register_scenario(Scenario(
    name="urban_canyon",
    geometry=GeometrySpec(kind="two_cluster", near_center=120.0,
                          far_center=1500.0, cluster_spread=80.0),
    fading=FadingSpec(family="rician",
                      rician_k=(8.0, 8.0, 8.0, 8.0, 8.0,
                                0.8, 0.8, 0.8, 0.8, 0.8)),
    shadowing=ShadowingSpec(sigma_db=6.0),
    dynamics=DynamicsSpec(rho=0.9, p_dropout=0.05),
    description="Everything at once: clustered geometry, shadowing, mixed "
                "Rician K, correlated fading, 5% dropout."))

# The default grid the benchmarks sweep (>= 4 families, baseline first).
SWEEP_FAMILIES = ("disk_rayleigh", "disk_rician", "disk_shadowed",
                  "two_cluster")
