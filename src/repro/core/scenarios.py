"""Scenario engine for heterogeneous wireless deployments (DESIGN.md §Scenarios).

The paper's experiments realize exactly one scenario family: devices
area-uniform in a disk, log-distance path loss, i.i.d. flat Rayleigh fading.
The bias-variance trade-off it studies, however, is driven by *wireless
heterogeneity* — which has four largely independent axes.  A ``Scenario``
composes one choice per axis:

    geometry     where devices sit: uniform disk (baseline), annular ring,
                 two-cluster near/far, fixed-distance grid
    large-scale  log-distance path loss, optionally with log-normal
                 shadowing (ShadowingSpec, sigma in dB)
    small-scale  fading family: Rayleigh / Rician(K) / Nakagami-m
                 (channel.FadingSpec, per-device parameters allowed)
    dynamics     round-to-round behaviour: i.i.d. (baseline), Gauss-Markov
                 correlated fading (rho), round-level device dropout

``realize`` turns a Scenario into an ordinary ``channel.Deployment`` — the
(gains, fading-spec) interface every PowerControl scheme and ``fl.server``
round function already consumes — so SCA/LCPC/vanilla/OPC/BB-FL run
unchanged on any scenario.  ``make_fading_process`` builds the matching
jit-friendly per-round sampler (stateful for Gauss-Markov / dropout).  The
baseline ``disk_rayleigh`` scenario reproduces ``channel.deploy`` and the
pre-scenario training path bit-for-bit.

A registry of named scenarios (``get_scenario`` / ``register_scenario``)
feeds the sweep runner in ``benchmarks/scenario_sweep.py``.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import channel, ota
from repro.core.channel import (Deployment, FadingSpec, RAYLEIGH,
                                WirelessConfig)
from repro.core.theory import OTAParams

# ---------------------------------------------------------------------------
# Axis specs
# ---------------------------------------------------------------------------

GEOMETRIES = ("disk", "ring", "two_cluster", "grid")


@dataclasses.dataclass(frozen=True)
class GeometrySpec:
    """Deployment geometry.  Distances are in meters, relative to the PS.

    disk         area-uniform in [0, r_max] (identical sampling to
                 channel.deploy — the paper baseline)
    ring         area-uniform in the annulus [r_min, r_max]
    two_cluster  near_frac of devices ~ N(near_center, cluster_spread),
                 the rest ~ N(far_center, cluster_spread)
    grid         deterministic distances: ``distances`` if given, else
                 linspace(max(r_min, 1), r_max, N)
    """
    kind: str = "disk"
    r_min: float = 0.0
    near_frac: float = 0.5
    near_center: float = 150.0
    far_center: float = 1600.0
    cluster_spread: float = 50.0
    distances: Optional[Tuple[float, ...]] = None

    def __post_init__(self):
        if self.kind not in GEOMETRIES:
            raise ValueError(f"unknown geometry {self.kind!r}; "
                             f"available: {GEOMETRIES}")


@dataclasses.dataclass(frozen=True)
class ShadowingSpec:
    """Log-normal shadowing on top of path loss: PL_dB += N(0, sigma_db^2)."""
    sigma_db: float = 8.0


@dataclasses.dataclass(frozen=True)
class DynamicsSpec:
    """Round-to-round channel dynamics.

    rho        Gauss-Markov correlation of the scattered component across
               rounds: d_t = rho d_{t-1} + sqrt(1-rho^2) w_t (stationary
               marginal preserved; rho=0 is the i.i.d. paper baseline).
               Supported for rayleigh/rician (Gaussian scattered part).
    p_dropout  probability a device drops out of a round entirely
               (straggler/outage model): its channel is observed as h=0,
               which every scheme maps to non-participation.
    """
    rho: float = 0.0
    p_dropout: float = 0.0

    def __post_init__(self):
        if not (0.0 <= self.rho < 1.0):
            raise ValueError("rho in [0, 1)")
        if not (0.0 <= self.p_dropout < 1.0):
            raise ValueError("p_dropout in [0, 1)")


@dataclasses.dataclass(frozen=True)
class Scenario:
    """Composable (geometry x large-scale x small-scale x dynamics) spec."""
    name: str
    geometry: GeometrySpec = GeometrySpec()
    fading: FadingSpec = RAYLEIGH
    shadowing: Optional[ShadowingSpec] = None
    dynamics: DynamicsSpec = DynamicsSpec()
    wireless: WirelessConfig = WirelessConfig()
    description: str = ""

    def __post_init__(self):
        if self.fading.family == "nakagami" and self.dynamics.rho > 0:
            raise ValueError("Gauss-Markov dynamics need a Gaussian scattered "
                             "component (rayleigh/rician); nakagami has none")
        n = self.wireless.num_devices
        for pname in ("rician_k", "nakagami_m"):
            v = np.asarray(getattr(self.fading, pname), dtype=np.float64)
            if v.ndim > 0 and v.shape != (n,):
                raise ValueError(
                    f"per-device {pname} has shape {v.shape} but the "
                    f"scenario deploys {n} devices")

    def replace(self, **kw) -> "Scenario":
        return dataclasses.replace(self, **kw)

    @property
    def is_baseline(self) -> bool:
        """True iff this is the paper's disk-Rayleigh-iid family."""
        return (self.geometry.kind == "disk" and self.shadowing is None
                and self.fading.family == "rayleigh"
                and self.dynamics == DynamicsSpec())


# ---------------------------------------------------------------------------
# Realization: Scenario -> Deployment
# ---------------------------------------------------------------------------

def sample_distances(geom: GeometrySpec, cfg: WirelessConfig,
                     rng: np.random.Generator) -> np.ndarray:
    """Draw [N] device distances for the given geometry.

    The disk branch consumes the rng stream exactly like channel.deploy so
    the baseline scenario reproduces the paper deployment bit-for-bit.
    """
    n, r_max = cfg.num_devices, cfg.r_max
    if geom.kind == "disk":
        u = rng.uniform(size=n)
        dist = r_max * np.sqrt(u)
    elif geom.kind == "ring":
        u = rng.uniform(size=n)
        dist = np.sqrt(geom.r_min**2 + u * (r_max**2 - geom.r_min**2))
    elif geom.kind == "two_cluster":
        n_near = int(np.clip(round(geom.near_frac * n), 1, n - 1))
        centers = np.where(np.arange(n) < n_near, geom.near_center,
                           geom.far_center)
        dist = centers + rng.standard_normal(n) * geom.cluster_spread
        dist = np.minimum(dist, r_max)
    elif geom.kind == "grid":
        if geom.distances is not None:
            dist = np.asarray(geom.distances, dtype=np.float64)
            if dist.shape != (n,):
                raise ValueError(f"grid distances {dist.shape} != ({n},)")
        else:
            dist = np.linspace(max(geom.r_min, 1.0), r_max, n)
    else:  # unreachable: GeometrySpec validates kind
        raise ValueError(geom.kind)
    return np.maximum(np.asarray(dist, dtype=np.float64), 1.0)


def realize(scenario: Scenario, seed: Optional[int] = None) -> Deployment:
    """Sample a concrete Deployment: distances, (shadowed) gains, fading spec.

    Deterministic given the wireless seed; pass ``seed`` to override it.
    """
    cfg = scenario.wireless
    if seed is not None:
        cfg = dataclasses.replace(cfg, seed=seed)
    rng = np.random.default_rng(cfg.seed)
    distances = sample_distances(scenario.geometry, cfg, rng)
    gains = channel.average_gain(distances, cfg.pl0_db, cfg.pl_exponent)
    shadow_db = None
    if scenario.shadowing is not None and scenario.shadowing.sigma_db > 0:
        shadow_db = rng.normal(0.0, scenario.shadowing.sigma_db,
                               size=cfg.num_devices)
        gains = gains * 10.0 ** (-shadow_db / 10.0)
    return Deployment(cfg=cfg, distances=distances, gains=gains,
                      fading=scenario.fading, shadowing_db=shadow_db,
                      p_dropout=scenario.dynamics.p_dropout)


def make_ota_params(dep: Deployment, d: int, gmax: float,
                    sigma_sq: Optional[np.ndarray] = None,
                    **kw) -> OTAParams:
    """Family-aware OTAParams from a realized deployment (carries the
    scenario's fading spec and dropout rate into the statistical CSI)."""
    spec = dep.fading
    if spec is not None and spec.family == "rayleigh":
        spec = None   # keep the exact Rayleigh closed-form fast path
    if sigma_sq is None:
        sigma_sq = np.zeros(dep.num_devices)
    return OTAParams(d=d, gmax=gmax, es=dep.cfg.energy_per_sample,
                     n0=dep.cfg.noise_psd, gains=dep.gains,
                     sigma_sq=sigma_sq, fading=spec,
                     dropout=dep.p_dropout, **kw)


# ---------------------------------------------------------------------------
# Per-round fading process (jit-friendly; duck-typed by fl.server)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class FadingProcess:
    """Stateful per-round sampler h_t for a realized deployment.

    ``init(key) -> state`` and ``step(state, key) -> (state, h)`` embed in a
    jit'd round function; ``state`` is the scattered (Gauss-Markov) channel
    component, a complex [N] array (unused but threaded for the i.i.d. case
    so the round-function signature is static).

    For rho == 0 and p_dropout == 0, ``step`` consumes the key exactly like
    ``ota.draw_fading`` in the pre-scenario path — the baseline training
    trajectory is bit-for-bit identical.

    The per-draw internals take the gains vector explicitly (defaulting to
    the deployment's ``self.gains``), so the same process serves cohort
    runs where the active gains change every chunk (``step_cohort``):
    population-backed processes are built with ``gains=None`` and only ever
    see cohort gains as operands.
    """
    gains: Optional[jnp.ndarray] = None
    family: str = "rayleigh"
    k_factor: Optional[jnp.ndarray] = None    # rician
    m: Optional[jnp.ndarray] = None           # nakagami
    rho: float = 0.0
    p_dropout: float = 0.0

    def _draw_iid(self, key: jax.Array, gains=None) -> jax.Array:
        g = self.gains if gains is None else gains
        if self.family == "rayleigh":
            return ota.draw_fading(key, g)
        if self.family == "rician":
            return ota.draw_fading_rician(key, g, self.k_factor)
        return ota.draw_fading_nakagami(key, g, self.m)

    def _diffuse_gains(self, gains=None) -> jnp.ndarray:
        g = self.gains if gains is None else gains
        if self.family == "rician":
            return g / (self.k_factor + 1.0)
        return g

    def _los(self, gains=None) -> jnp.ndarray:
        g = self.gains if gains is None else gains
        if self.family == "rician":
            return jnp.sqrt(g * self.k_factor / (self.k_factor + 1.0))
        return jnp.zeros_like(g)

    def init(self, key: jax.Array) -> jax.Array:
        """Stationary scattered-component draw (state for Markov dynamics)."""
        return ota.draw_fading(key, self._diffuse_gains())

    def init_batch(self, keys: jax.Array) -> jax.Array:
        """Batched ``init`` for the vmapped experiment engine: ``keys`` has
        arbitrary leading axes [..., 2] and the returned state carries the
        matching leading batch axes [..., N].  Each batch cell consumes its
        key exactly like a standalone ``init`` call, so a fleet cell's
        fading stream is identical to the corresponding single run's."""
        flat = keys.reshape((-1,) + keys.shape[-1:])
        states = jax.vmap(self.init)(flat)
        return states.reshape(keys.shape[:-1] + states.shape[1:])

    def step_batch(self, state: jax.Array, keys: jax.Array):
        """Batched ``step`` over matching leading axes of state [..., N]
        and keys [..., 2] (i.e. the engine's [K, S] grid)."""
        batch = state.shape[:-1]
        flat_s = state.reshape((-1,) + state.shape[-1:])
        flat_k = keys.reshape((-1,) + keys.shape[-1:])
        flat_s, h = jax.vmap(self.step)(flat_s, flat_k)
        return (flat_s.reshape(state.shape),
                h.reshape(batch + h.shape[-1:]))

    def step(self, state: jax.Array, key: jax.Array):
        return self.step_cohort(state, key, self.gains)

    def step_cohort(self, state: jax.Array, key: jax.Array, gains):
        """``step`` on an explicit gains vector (the active cohort's): the
        key splits and draw order are identical, so with ``gains`` equal to
        the deployment gains this IS ``step``, bit for bit."""
        if self.rho == 0.0 and self.p_dropout == 0.0:
            return state, self._draw_iid(key, gains)
        k_fade, k_drop = jax.random.split(key)
        if self.rho > 0.0:
            w = ota.draw_fading(k_fade, self._diffuse_gains(gains))
            state = self.rho * state + np.sqrt(1.0 - self.rho**2) * w
            h = jax.lax.complex(self._los(gains) + state.real, state.imag)
        else:
            h = self._draw_iid(k_fade, gains)
        if self.p_dropout > 0.0:
            keep = jax.random.bernoulli(k_drop, 1.0 - self.p_dropout,
                                        jnp.shape(gains))
            h = jnp.where(keep, h, jnp.zeros_like(h))
        return state, h


# ---------------------------------------------------------------------------
# Scenario stacks (DESIGN.md §Grid): C realized deployments as ONE pytree
# whose leaves carry a leading [C] scenario axis, so a [C x K x S] fleet
# runs as a single compiled program.  Family-heterogeneous stacks dispatch
# per row through a lax.switch union — the same idiom power_control
# .SchemeBatch uses for heterogeneous scheme stacks — with every branch
# body the SAME ops a standalone FadingProcess would trace for that row's
# static (family, dynamics), so each grid row reproduces the per-scenario
# fleet bit-for-bit (pinned in tests/test_grid.py).
# ---------------------------------------------------------------------------

# per-row dispatch kinds: one per distinct FadingProcess trace shape
_SK_IID_RAYLEIGH, _SK_IID_RICIAN, _SK_IID_NAKAGAMI = 0, 1, 2
_SK_MARKOV = 3                       # rho > 0 (rayleigh/rician via K-factor)
_SK_DROP_RAYLEIGH, _SK_DROP_RICIAN, _SK_DROP_NAKAGAMI = 4, 5, 6

_FAMILY_INDEX = {"rayleigh": 0, "rician": 1, "nakagami": 2}


@dataclasses.dataclass
class ScenarioStack:
    """C stacked deployments for the scenario-axis grid fleet.

    Leaves carry a leading [C] axis (gains [C, N]; per-device fading
    parameters [C, N]; dynamics scalars [C]); ``kind`` [C] selects each
    row's ``lax.switch`` branch.  Rows with a family that doesn't use a
    parameter hold benign fillers (K = 0, m = 1) chosen so the dead
    branches stay finite under the vmapped select AND so the live branch's
    arithmetic is bitwise the standalone FadingProcess's (x / (0 + 1.0)
    and sqrt(x * 0) are exact in IEEE, so a Rayleigh row through the
    Rician-shaped formulas reproduces the Rayleigh fast path bit-for-bit).

    ``init``/``step`` are per-row methods (use under vmap with the stack
    mapped at axis 0); ``gm_scale`` = sqrt(1 - rho^2) is precomputed
    host-side in float64 exactly like FadingProcess's ``np.sqrt`` so the
    Gauss-Markov update rounds identically.
    """
    names: tuple = ()
    num_devices: int = 0
    gains: Optional[jnp.ndarray] = None       # [C, N]
    kind: Optional[jnp.ndarray] = None        # [C] int32
    k_factor: Optional[jnp.ndarray] = None    # [C, N] (0 filler)
    m: Optional[jnp.ndarray] = None           # [C, N] (1 filler)
    rho: Optional[jnp.ndarray] = None         # [C]
    gm_scale: Optional[jnp.ndarray] = None    # [C] sqrt(1 - rho^2)
    p_dropout: Optional[jnp.ndarray] = None   # [C]

    def __len__(self):
        return len(self.names)

    # -- per-row sampler (mirror FadingProcess bitwise) ------------------

    def _drop(self, k_drop, h):
        keep = jax.random.bernoulli(k_drop, 1.0 - self.p_dropout,
                                    jnp.shape(h))
        return jnp.where(keep, h, jnp.zeros_like(h))

    def init(self, key: jax.Array) -> jax.Array:
        """Stationary scattered-component draw for ONE row ([N] leaves)."""
        return ota.draw_fading(key, self.gains / (self.k_factor + 1.0))

    def step(self, state: jax.Array, key: jax.Array):
        """One row's ``FadingProcess.step``, dispatched on ``kind``.

        Each branch consumes ``key`` exactly like the standalone process
        with that row's static config (i.i.d. rows draw from the key
        directly; dynamic rows split it) — under vmap the switch becomes a
        select over all branches, and the selected branch's values are the
        standalone ops on the same operands, hence bitwise.
        """
        g, kf, m = self.gains, self.k_factor, self.m

        def iid(draw):
            return lambda op: (op[0], draw(op[1]))

        def markov(op):
            state, key = op
            k_fade, k_drop = jax.random.split(key)
            w = ota.draw_fading(k_fade, g / (kf + 1.0))
            st = self.rho * state + self.gm_scale * w
            los = jnp.sqrt(g * kf / (kf + 1.0))
            h = jax.lax.complex(los + st.real, st.imag)
            # p_dropout == 0 rows keep everything: bernoulli(k, 1.0) is
            # all-true (uniform in [0, 1) < 1.0), bitwise the no-drop path
            return st, self._drop(k_drop, h)

        def drop_iid(draw):
            def branch(op):
                state, key = op
                k_fade, k_drop = jax.random.split(key)
                return state, self._drop(k_drop, draw(k_fade))
            return branch

        draw_ray = lambda k: ota.draw_fading(k, g)
        draw_ric = lambda k: ota.draw_fading_rician(k, g, kf)
        draw_nak = lambda k: ota.draw_fading_nakagami(k, g, m)
        branches = (iid(draw_ray), iid(draw_ric), iid(draw_nak), markov,
                    drop_iid(draw_ray), drop_iid(draw_ric),
                    drop_iid(draw_nak))
        return jax.lax.switch(self.kind, branches, (state, key))

    # -- grid layout helpers ---------------------------------------------

    def init_grid(self, keys: jax.Array) -> jax.Array:
        """[C, S, N] initial states from per-seed keys [S, 2]: row c with
        seed key s consumes the key exactly like scenario c's standalone
        ``FadingProcess.init`` — the fleet/per-scenario bitwise anchor."""
        return jax.vmap(lambda row: jax.vmap(row.init)(keys))(self)

    def tile_over_schemes(self, k: int) -> "ScenarioStack":
        """Repeat each scenario row k times -> leaves [C*k, ...], matching
        the scenario-major flattened cell axis (cell c*k + j is scenario c,
        scheme j).  Host-resident numpy, like ``tile_over_seeds``."""
        return jax.tree.map(
            lambda a: np.repeat(np.asarray(a), k, axis=0), self)

    def row(self, c: int) -> "ScenarioStack":
        """Length-1 stack holding scenario ``c`` (the C=1 slice)."""
        sliced = jax.tree.map(lambda a: np.asarray(a)[c:c + 1], self)
        sliced.names = (self.names[c],)
        return sliced

    def describe(self) -> str:
        """Stable identity string for fleet checkpoints: a resume against a
        different scenario axis (names, gains, families or dynamics) must
        be rejected, not silently mixed."""
        h = hashlib.sha1()
        for leaf in (self.gains, self.kind, self.k_factor, self.m,
                     self.rho, self.p_dropout):
            a = np.ascontiguousarray(np.asarray(leaf))
            h.update(str(a.dtype).encode())
            h.update(a.tobytes())
        return (f"scenarios[{','.join(self.names)};n={self.num_devices};"
                f"{h.hexdigest()[:12]}]")


jax.tree_util.register_pytree_node(
    ScenarioStack,
    lambda st: (tuple(getattr(st, f) for f in
                      ("gains", "kind", "k_factor", "m", "rho", "gm_scale",
                       "p_dropout")),
                (st.names, st.num_devices)),
    lambda aux, ch: ScenarioStack(*aux, *ch),
)


def stack_deployments(deps, dynamics=None, names=None) -> ScenarioStack:
    """Stack C realized Deployments (+ per-scenario DynamicsSpec) into one
    :class:`ScenarioStack` — the stacked-deployment builder behind
    ``stack_scenarios``.  All deployments must agree on the device count
    (the grid shares one task partition)."""
    deps = list(deps)
    if not deps:
        raise ValueError("stack_deployments needs at least one deployment")
    c = len(deps)
    dyns = list(dynamics) if dynamics is not None else [DynamicsSpec()] * c
    if len(dyns) != c:
        raise ValueError(f"{c} deployments but {len(dyns)} dynamics specs")
    names = tuple(names) if names is not None \
        else tuple(f"scenario{i}" for i in range(c))
    if len(names) != c:
        raise ValueError(f"{c} deployments but {len(names)} names")
    n = deps[0].num_devices
    if any(d.num_devices != n for d in deps):
        raise ValueError("deployments disagree on device count")

    gains = np.stack([np.asarray(d.gains, np.float64) for d in deps])
    kind = np.zeros(c, np.int32)
    k_factor = np.zeros((c, n), np.float64)
    m = np.ones((c, n), np.float64)
    rho = np.zeros(c, np.float64)
    p_drop = np.zeros(c, np.float64)
    for i, (dep, dyn) in enumerate(zip(deps, dyns)):
        spec = dep.fading_spec
        if spec.family == "nakagami" and dyn.rho > 0:
            raise ValueError("Gauss-Markov dynamics unsupported for nakagami")
        if spec.family == "rician":
            k_factor[i] = np.broadcast_to(
                np.asarray(spec.rician_k, np.float64), (n,))
        if spec.family == "nakagami":
            m[i] = np.broadcast_to(
                np.asarray(spec.nakagami_m, np.float64), (n,))
        rho[i], p_drop[i] = dyn.rho, dyn.p_dropout
        if dyn.rho > 0:
            kind[i] = _SK_MARKOV
        elif dyn.p_dropout > 0:
            kind[i] = _SK_DROP_RAYLEIGH + _FAMILY_INDEX[spec.family]
        else:
            kind[i] = _FAMILY_INDEX[spec.family]
    return ScenarioStack(names=names, num_devices=n, gains=gains, kind=kind,
                         k_factor=k_factor, m=m, rho=rho,
                         gm_scale=np.sqrt(1.0 - rho**2), p_dropout=p_drop)


def stack_scenarios(scenarios, seed: Optional[int] = None) -> ScenarioStack:
    """Realize + stack scenarios (names or Scenario objects) for the grid
    fleet: ``run_fleet(..., scenarios=stack_scenarios(SWEEP_FAMILIES))``
    runs every (scenario, scheme, seed) cell as one compiled program."""
    scs = [get_scenario(s) if isinstance(s, str) else s for s in scenarios]
    deps = [realize(sc, seed=seed) for sc in scs]
    return stack_deployments(deps, [sc.dynamics for sc in scs],
                             names=[sc.name for sc in scs])


def make_fading_process(dep: Deployment,
                        dynamics: Optional[DynamicsSpec] = None
                        ) -> FadingProcess:
    """Build the jit-friendly sampler matching a deployment's fading spec."""
    spec = dep.fading_spec
    dyn = dynamics if dynamics is not None else DynamicsSpec()
    if spec.family == "nakagami" and dyn.rho > 0:
        raise ValueError("Gauss-Markov dynamics unsupported for nakagami")
    n = dep.num_devices
    gains = jnp.asarray(dep.gains)
    k_factor = m = None
    if spec.family == "rician":
        k_factor = jnp.asarray(np.broadcast_to(
            np.asarray(spec.rician_k, np.float64), (n,)))
    if spec.family == "nakagami":
        m = jnp.asarray(np.broadcast_to(
            np.asarray(spec.nakagami_m, np.float64), (n,)))
    return FadingProcess(gains=gains, family=spec.family, k_factor=k_factor,
                         m=m, rho=dyn.rho, p_dropout=dyn.p_dropout)


def scenario_fading_process(scenario: Scenario,
                            dep: Optional[Deployment] = None) -> FadingProcess:
    if dep is None:
        dep = realize(scenario)
    return make_fading_process(dep, scenario.dynamics)


# ---------------------------------------------------------------------------
# Population layer (DESIGN.md §Population): a parametric device universe of
# up to ~1M devices, materialized lazily per cohort draw.  Per-device
# large-scale parameters are pure counter-based hashes of (population seed,
# device index), so nothing is stored per device until a cohort indexes in;
# cohort draws are pure functions of (population seed, run seed, tick), so
# a resumed stream redraws identical cohorts without any RNG cursor.
# ---------------------------------------------------------------------------

_COHORT_SALT = 0xC040  # draw_cohort rng lane
_AGE_SALT = 0xA6ED     # stage_states innovation lane

# hash lanes per derived per-device quantity (normals consume lane, lane+1)
_LANE_GEOM, _LANE_CLUSTER, _LANE_SHADOW = 0, 1, 2
_LANE_TRAFFIC, _LANE_SPREAD = 4, 6


def _splitmix64(x: np.ndarray) -> np.ndarray:
    """Vectorized splitmix64 finalizer: well-mixed uint64 from uint64."""
    x = np.asarray(x, np.uint64)
    with np.errstate(over="ignore"):
        x = x + np.uint64(0x9E3779B97F4A7C15)
        x = (x ^ (x >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
        x = (x ^ (x >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    return x ^ (x >> np.uint64(31))


def _hash_u01(seed: int, idx: np.ndarray, lane: int) -> np.ndarray:
    """Uniform(0, 1) doubles, a pure function of (seed, device idx, lane)."""
    x = np.asarray(idx, np.uint64)
    with np.errstate(over="ignore"):
        x = x * np.uint64(0xD1342543DE82EF95)
        x = x ^ (np.uint64(seed & 0xFFFFFFFFFFFFFFFF)
                 * np.uint64(0x9E3779B97F4A7C15))
        x = x + np.uint64(lane) * np.uint64(0xBF58476D1CE4E5B9)
    x = _splitmix64(_splitmix64(x))
    return (x >> np.uint64(11)).astype(np.float64) * 2.0 ** -53


def _hash_normal(seed: int, idx: np.ndarray, lane: int) -> np.ndarray:
    """Standard normals via Box-Muller on lanes (lane, lane + 1)."""
    u1 = np.maximum(_hash_u01(seed, idx, lane), 2.0 ** -53)
    u2 = _hash_u01(seed, idx, lane + 1)
    return np.sqrt(-2.0 * np.log(u1)) * np.cos(2.0 * np.pi * u2)


SAMPLINGS = ("uniform", "traffic")


@dataclasses.dataclass(frozen=True)
class PopulationSpec:
    """A parametric device population: the Scenario axes minus per-device
    realization, plus a sampling model for cohort draws.

    sampling       "uniform" — every device equally likely per round;
                   "traffic" — arrival-weighted: device weights are
                   log-normal(0, traffic_sigma²) (heavy-tailed activity,
                   the Gumbel-top-k draw in ``Population.draw_cohort``).
    seed           the population's own seed: all per-device hashes and
                   cohort draws derive from it (independent of run seeds).
    """
    size: int = 1_000_000
    geometry: GeometrySpec = GeometrySpec()
    shadowing: Optional[ShadowingSpec] = None
    fading: FadingSpec = RAYLEIGH
    dynamics: DynamicsSpec = DynamicsSpec()
    wireless: WirelessConfig = WirelessConfig()
    sampling: str = "uniform"
    traffic_sigma: float = 1.0
    seed: int = 0

    def __post_init__(self):
        if self.size <= 0:
            raise ValueError("population size must be positive")
        if self.sampling not in SAMPLINGS:
            raise ValueError(f"unknown sampling {self.sampling!r}; "
                             f"available: {SAMPLINGS}")
        for pname in ("rician_k", "nakagami_m"):
            if np.asarray(getattr(self.fading, pname)).ndim > 0:
                raise ValueError(
                    f"parametric populations need a scalar {pname} (per-"
                    f"device arrays cannot be materialized lazily)")


@dataclasses.dataclass
class Population:
    """Lazily materialized device population (DESIGN.md §Population).

    Two flavours share one interface:

    * parametric — built from a :class:`PopulationSpec`; ``gains_of(idx)``
      hashes (seed, idx) into geometry/shadowing and is O(len(idx)),
      whatever ``size`` says, so 1M devices cost nothing until drawn;
    * tabular — explicit [P] gains (``from_deployment``), the anchor for
      the cohort == population bitwise-equivalence contract.

    ``draw_cohort(n, tick, seed)`` is a pure function of its arguments
    (counter-based ``np.random.default_rng`` keying; Gumbel-top-k without
    replacement under traffic weighting), so streaming resume re-derives
    every draw instead of checkpointing an RNG cursor.  The Gauss-Markov
    re-entry table (``init_table`` / ``stage_states`` / ``commit_states``)
    ages a returning device's scattered state by its absence:
    d = rho^m d0 + sqrt(1 - rho^(2m)) w over m missed rounds — m = 0 is an
    exact pass-through (back-to-back cohorts keep their trajectory) and a
    never-seen device gets a fresh stationary draw.
    """
    spec: Optional[PopulationSpec] = None
    gains_table: Optional[np.ndarray] = None      # [P] tabular gains
    weights_table: Optional[np.ndarray] = None    # [P] tabular weights
    fading: FadingSpec = RAYLEIGH
    dynamics: DynamicsSpec = DynamicsSpec()
    seed: int = 0
    name: str = "population"

    def __post_init__(self):
        if (self.spec is None) == (self.gains_table is None):
            raise ValueError("exactly one of spec / gains_table required")
        if self.spec is not None:
            self.fading = self.spec.fading
            self.dynamics = self.spec.dynamics
            self.seed = self.spec.seed
        else:
            self.gains_table = np.asarray(self.gains_table, np.float64)
            for pname in ("rician_k", "nakagami_m"):
                if np.asarray(getattr(self.fading, pname)).ndim > 0:
                    raise ValueError(f"populations need a scalar {pname}")
        if self.fading.family == "nakagami" and self.dynamics.rho > 0:
            raise ValueError("Gauss-Markov dynamics unsupported for nakagami")
        self._weights = None

    @classmethod
    def from_deployment(cls, dep: Deployment,
                        dynamics: Optional[DynamicsSpec] = None,
                        weights: Optional[np.ndarray] = None) -> "Population":
        """Wrap a realized Deployment as a (tabular) population — with
        cohort_size == dep.num_devices this reproduces the full-
        participation engine path bitwise."""
        return cls(gains_table=np.asarray(dep.gains, np.float64),
                   weights_table=weights, fading=dep.fading_spec,
                   dynamics=(dynamics if dynamics is not None
                             else DynamicsSpec(p_dropout=dep.p_dropout)),
                   name=f"deployment[{dep.num_devices}]")

    @property
    def size(self) -> int:
        return (self.spec.size if self.spec is not None
                else int(self.gains_table.shape[0]))

    # -- lazy per-device parameters -------------------------------------

    def distances_of(self, idx: np.ndarray) -> np.ndarray:
        """Parametric geometry at device indices (hash-derived)."""
        if self.spec is None:
            raise ValueError("tabular populations have no geometry")
        geom, cfg, p = self.spec.geometry, self.spec.wireless, self.size
        idx = np.asarray(idx, np.int64)
        u = _hash_u01(self.seed, idx, _LANE_GEOM)
        if geom.kind == "disk":
            dist = cfg.r_max * np.sqrt(u)
        elif geom.kind == "ring":
            dist = np.sqrt(geom.r_min**2 + u * (cfg.r_max**2 - geom.r_min**2))
        elif geom.kind == "two_cluster":
            near = _hash_u01(self.seed, idx, _LANE_CLUSTER) < geom.near_frac
            centers = np.where(near, geom.near_center, geom.far_center)
            dist = centers + (_hash_normal(self.seed, idx, _LANE_SPREAD)
                              * geom.cluster_spread)
            dist = np.minimum(dist, cfg.r_max)
        else:  # grid: deterministic linspace over the whole population
            lo = max(geom.r_min, 1.0)
            dist = lo + idx * (cfg.r_max - lo) / max(p - 1, 1)
        return np.maximum(dist, 1.0)

    def gains_of(self, idx: np.ndarray) -> np.ndarray:
        """Average channel gains at device indices, [len(idx)] float64."""
        idx = np.asarray(idx, np.int64)
        if self.spec is None:
            return self.gains_table[idx]
        cfg = self.spec.wireless
        gains = channel.average_gain(self.distances_of(idx), cfg.pl0_db,
                                     cfg.pl_exponent)
        if self.spec.shadowing is not None \
                and self.spec.shadowing.sigma_db > 0:
            db = (_hash_normal(self.seed, idx, _LANE_SHADOW)
                  * self.spec.shadowing.sigma_db)
            gains = gains * 10.0 ** (-db / 10.0)
        return gains

    def weights(self) -> Optional[np.ndarray]:
        """[P] sampling weights (None = uniform).  Materialized once and
        cached — the only O(P) array a parametric population ever builds."""
        if self.spec is not None and self.spec.sampling == "uniform":
            return None
        if self._weights is None:
            if self.spec is not None:
                z = _hash_normal(self.seed, np.arange(self.size, dtype=np.int64),
                                 _LANE_TRAFFIC)
                self._weights = np.exp(self.spec.traffic_sigma * z)
            else:
                self._weights = (None if self.weights_table is None
                                 else np.asarray(self.weights_table,
                                                 np.float64))
        return self._weights

    # -- cohort draws ----------------------------------------------------

    def draw_cohort(self, n: int, tick: int, seed: int = 0) -> np.ndarray:
        """Sorted [n] device indices for cohort ``tick`` of run ``seed``.

        Pure in (population seed, seed, tick): counter-based rng keying, no
        mutable stream — a resumed driver re-derives any draw.  n == size
        returns arange (the full-participation identity path).  Weighted
        sampling is Gumbel-top-k on log-weights — exact sampling without
        replacement proportional to weights at each slot.
        """
        p = self.size
        if not 0 < n <= p:
            raise ValueError(f"cohort size {n} not in [1, {p}]")
        if n == p:
            return np.arange(p, dtype=np.int64)
        rng = np.random.default_rng(
            (self.seed, int(seed), int(tick), _COHORT_SALT))
        w = self.weights()
        if w is None:
            idx = rng.choice(p, size=n, replace=False)
        else:
            keys = np.log(w) + rng.gumbel(size=p)
            idx = np.argpartition(keys, p - n)[p - n:]
        return np.sort(idx.astype(np.int64))

    # -- Gauss-Markov re-entry state ------------------------------------

    def init_table(self, num_rows: int) -> dict:
        """Host-side per-(seed-row, device) fading memory: round last seen
        (-1 = never) and the scattered state as of that round."""
        return {"last": np.full((num_rows, self.size), -1, np.int64),
                "state": np.zeros((num_rows, self.size), np.complex64)}

    def stage_states(self, table: dict, row: int, idx: np.ndarray, t0: int,
                     seed: int = 0) -> np.ndarray:
        """Scattered states for cohort ``idx`` entering at round ``t0``,
        aged from the table by each device's absence (see class docstring).
        Pure in (table contents, row, idx, t0, seed) — recomputed
        identically on resume.  [len(idx)] complex64."""
        rho = float(self.dynamics.rho)
        idx = np.asarray(idx, np.int64)
        last = table["last"][row, idx]
        old = table["state"][row, idx].astype(np.complex128)
        missed = np.maximum(t0 - 1 - last, 0)
        decay = np.where(last < 0, 0.0,
                         rho ** missed if rho > 0.0 else (missed == 0))
        rng = np.random.default_rng(
            (self.seed, int(seed), int(t0), _AGE_SALT))
        z = rng.standard_normal((2, idx.shape[0]))
        k = float(np.asarray(self.fading.rician_k)) \
            if self.fading.family == "rician" else 0.0
        diffuse = self.gains_of(idx) / (k + 1.0)
        w = (z[0] + 1j * z[1]) * np.sqrt(diffuse / 2.0)
        state = decay * old + np.sqrt(np.maximum(1.0 - decay**2, 0.0)) * w
        return state.astype(np.complex64)

    def commit_states(self, table: dict, row: int, idx: np.ndarray,
                      t_end: int, state: np.ndarray) -> None:
        """Write a finished chunk's final states back: cohort ``idx`` was
        last seen at round ``t_end`` with scattered state ``state``."""
        idx = np.asarray(idx, np.int64)
        table["last"][row, idx] = int(t_end)
        table["state"][row, idx] = np.asarray(state, np.complex64)

    # -- glue ------------------------------------------------------------

    def fading_process(self) -> Optional[FadingProcess]:
        """The cohort-run per-round sampler (``step_cohort`` consumes the
        staged cohort gains); None when the population is the paper's
        i.i.d.-Rayleigh baseline — the engine's fading=None fast path,
        which is what the bitwise full-participation contract pins."""
        dyn = self.dynamics
        if self.fading.family == "rayleigh" and dyn == DynamicsSpec():
            return None
        k_factor = m = None
        if self.fading.family == "rician":
            k_factor = jnp.asarray(float(np.asarray(self.fading.rician_k)))
        if self.fading.family == "nakagami":
            m = jnp.asarray(float(np.asarray(self.fading.nakagami_m)))
        return FadingProcess(gains=None, family=self.fading.family,
                             k_factor=k_factor, m=m, rho=dyn.rho,
                             p_dropout=dyn.p_dropout)

    def describe(self) -> str:
        """Stable identity string for fleet checkpoints (a resume against
        a different population must be rejected, not silently mixed)."""
        dyn = self.dynamics
        tail = (f"fading={self.fading.family},rho={dyn.rho}"
                f",drop={dyn.p_dropout},seed={self.seed}")
        if self.spec is not None:
            sp = self.spec
            return (f"pop(size={sp.size},geom={sp.geometry.kind},"
                    f"shadow={sp.shadowing is not None},"
                    f"sampling={sp.sampling},sigma={sp.traffic_sigma},{tail})")
        h = hashlib.sha1(self.gains_table.tobytes()).hexdigest()[:12]
        w = self.weights()
        wh = "none" if w is None else hashlib.sha1(w.tobytes()).hexdigest()[:12]
        return f"pop(table={h},weights={wh},{tail})"


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Scenario] = {}


def register_scenario(sc: Scenario, overwrite: bool = False) -> Scenario:
    if sc.name in _REGISTRY and not overwrite:
        raise ValueError(f"scenario {sc.name!r} already registered")
    _REGISTRY[sc.name] = sc
    return sc


def get_scenario(name: str) -> Scenario:
    if name not in _REGISTRY:
        raise ValueError(f"unknown scenario {name!r}; "
                         f"available: {scenario_names()}")
    return _REGISTRY[name]


def scenario_names() -> Tuple[str, ...]:
    return tuple(_REGISTRY)


register_scenario(Scenario(
    name="disk_rayleigh",
    description="Paper baseline: area-uniform disk, log-distance path loss, "
                "i.i.d. Rayleigh (bit-identical to channel.deploy)."))

register_scenario(Scenario(
    name="disk_rician",
    fading=FadingSpec(family="rician", rician_k=5.0),
    description="Disk deployment with LOS-rich Rician fading, K = 5."))

register_scenario(Scenario(
    name="disk_rician_mixed",
    fading=FadingSpec(family="rician",
                      rician_k=(10.0, 10.0, 10.0, 10.0, 10.0,
                                0.5, 0.5, 0.5, 0.5, 0.5)),
    description="Per-device K-factor: half the fleet near-LOS (K=10), half "
                "heavily scattered (K=0.5)."))

register_scenario(Scenario(
    name="disk_nakagami",
    fading=FadingSpec(family="nakagami", nakagami_m=2.0),
    description="Disk deployment with milder-than-Rayleigh Nakagami-2 fading."))

register_scenario(Scenario(
    name="disk_shadowed",
    shadowing=ShadowingSpec(sigma_db=8.0),
    description="Disk + 8 dB log-normal shadowing on top of path loss."))

register_scenario(Scenario(
    name="two_cluster",
    geometry=GeometrySpec(kind="two_cluster"),
    description="Near/far clusters (150 m vs 1600 m): the extreme "
                "heterogeneity regime where bias control matters most."))

register_scenario(Scenario(
    name="ring",
    geometry=GeometrySpec(kind="ring", r_min=1000.0),
    fading=FadingSpec(family="nakagami", nakagami_m=1.5),
    description="Cell-edge annulus (1000-1750 m) with Nakagami-1.5 fading: "
                "homogeneous gains, weak channels."))

register_scenario(Scenario(
    name="disk_markov",
    dynamics=DynamicsSpec(rho=0.95),
    description="Disk-Rayleigh with Gauss-Markov round correlation rho=0.95 "
                "(slow fading relative to the round cadence)."))

register_scenario(Scenario(
    name="disk_dropout",
    dynamics=DynamicsSpec(p_dropout=0.1),
    description="Disk-Rayleigh where each device independently drops out of "
                "10% of rounds (outage/straggler model)."))

register_scenario(Scenario(
    name="urban_canyon",
    geometry=GeometrySpec(kind="two_cluster", near_center=120.0,
                          far_center=1500.0, cluster_spread=80.0),
    fading=FadingSpec(family="rician",
                      rician_k=(8.0, 8.0, 8.0, 8.0, 8.0,
                                0.8, 0.8, 0.8, 0.8, 0.8)),
    shadowing=ShadowingSpec(sigma_db=6.0),
    dynamics=DynamicsSpec(rho=0.9, p_dropout=0.05),
    description="Everything at once: clustered geometry, shadowing, mixed "
                "Rician K, correlated fading, 5% dropout."))

# The default grid the benchmarks sweep (>= 4 families, baseline first).
SWEEP_FAMILIES = ("disk_rayleigh", "disk_rician", "disk_shadowed",
                  "two_cluster")
