"""Successive convex approximation for the OTA power-control design (P1).

Paper §III-B: minimize over pre-scalers {gamma_m}

    J(gamma) = 2 eta L zeta(gamma) + 2 N kappa^2 sum_m (p_m(gamma) - 1/N)^2

The problem is rewritten over coupled variables X = ({gamma_m},{p_m},alpha)
with coupling alpha_m(gamma_m) = alpha p_m, and solved by SCA: each iteration
solves the convex surrogate (11a)-(11e) around the current anchor.

Implementation notes (this container has no CVX):
  * The epigraph variable z_m of (11b) is eliminated in closed form — the
    objective is increasing in z_m, so at the optimum (11b) is tight:
        z_m = exp( ln(g_bar p_bar) + gamma/g_bar + p/p_bar - 2 ) / alpha,
    which is jointly convex in (gamma, p, alpha) (exp of affine minus
    log-concave alpha).
  * Each convex subproblem is solved with scipy SLSQP in *scaled* variables
    (gamma_hat = gamma/gamma_max in (0,1], alpha_hat = alpha/sum(alpha_max))
    so all decision variables are O(1) despite physical scales ~1e-9.
  * After each subproblem we restore the exact coupling by recomputing
    (alpha_m, alpha, p) from gamma, evaluate the TRUE objective, and
    backtrack toward the anchor if the surrogate step overshot — SCA descent
    is therefore guaranteed monotone.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np
from scipy.optimize import minimize

from repro.core import theory
from repro.core.theory import OTAParams

_EPS = 1e-12


@dataclasses.dataclass
class SCAResult:
    gamma: np.ndarray          # [N] optimized pre-scalers (physical units)
    p: np.ndarray              # [N] participation levels
    alpha: float               # post-scaler
    objective: float           # true (P1) objective at gamma
    history: list              # per-iteration true objective
    converged: bool
    iterations: int


def _pack(gh: np.ndarray, p: np.ndarray, ah: float) -> np.ndarray:
    return np.concatenate([gh, p, [ah]])


def _unpack(x: np.ndarray, n: int):
    return x[:n], x[n:2 * n], x[2 * n]


def _subproblem(anchor_gh, anchor_p, anchor_ah, prm: OTAParams,
                gmax_arr, amax_arr, a0, maxiter=200):
    """Solve the convex surrogate (11) around the given anchor (scaled vars).

    Returns scaled solution (gh, p, ah).
    """
    n = prm.num_devices
    eta_l = prm.eta * prm.lsmooth
    g2 = prm.gmax**2
    sig = np.asarray(prm.sigma_sq, dtype=np.float64)
    # physical anchors
    g_bar = anchor_gh * gmax_arr
    a_bar = anchor_ah * a0
    p_bar = np.maximum(anchor_p, 1e-9)

    def split(x):
        gh, p, ah = _unpack(x, n)
        return np.maximum(gh, _EPS), np.maximum(p, _EPS), max(ah, _EPS)

    def objective(x):
        gh, p, ah = split(x)
        gamma = gh * gmax_arr
        alpha = ah * a0
        # z_m eliminated via tight (11b)
        logz = (np.log(g_bar * p_bar) + gamma / g_bar + p / p_bar - 2.0
                - np.log(alpha))
        z = np.exp(logz)
        lin_p2 = p_bar * (2.0 * p - p_bar)           # linearized -p^2 (sign folded below)
        obj = eta_l * (g2 * np.sum(z) + prm.d * prm.n0 / alpha**2
                       + np.sum(p**2 * sig) - g2 * np.sum(lin_p2))
        obj += prm.num_devices * prm.kappa_sq * np.sum((p - 1.0 / n) ** 2)
        return obj

    def con_11c(x):
        # ln alpha_m(gamma) - ln(a_bar p_bar) - a/a_bar - p/p_bar + 2 >= 0
        # (Rayleigh: ln alpha_m = ln gamma - gamma^2 G^2/(d Lam Es) exactly;
        # other fading families use their closed-form E[chi].)
        gh, p, ah = split(x)
        gamma = gh * gmax_arr
        alpha = ah * a0
        rhs = theory.log_alpha_of_gamma(gamma, prm)
        lhs = np.log(a_bar * p_bar) + alpha / a_bar + p / p_bar - 2.0
        return rhs - lhs

    def con_11d(x):
        # (2 a_bar - alpha)/a_bar^2 - p/alpha_max >= 0
        gh, p, ah = split(x)
        alpha = ah * a0
        return (2.0 * a_bar - alpha) / a_bar**2 - p / amax_arr

    def con_simplex(x):
        _, p, _ = split(x)
        return np.sum(p) - 1.0

    x0 = _pack(anchor_gh, anchor_p, anchor_ah)
    bounds = ([(1e-6, 1.0)] * n) + ([(1e-9, 1.0)] * n) + [(1e-6, 2.0)]
    cons = [
        {"type": "ineq", "fun": con_11c},
        {"type": "ineq", "fun": con_11d},
        {"type": "eq", "fun": con_simplex},
    ]
    res = minimize(objective, x0, method="SLSQP", bounds=bounds,
                   constraints=cons, options={"maxiter": maxiter,
                                              "ftol": 1e-12})
    gh, p, ah = split(res.x)
    return gh, p, ah


def _coupled_state(gamma: np.ndarray, prm: OTAParams):
    """Restore the exact coupling: (p, alpha) implied by gamma."""
    am, a, pm = theory.participation(gamma, prm)
    return pm, a


def solve_sca(prm: OTAParams, gamma0: Optional[np.ndarray] = None,
              max_iters: int = 30, tol: float = 1e-6,
              backtracks: int = 12) -> SCAResult:
    """Run the SCA loop of §III-B. Monotone descent on the true objective."""
    gmax_arr = theory.gamma_max(prm)
    amax_arr = theory.alpha_max(prm)
    a0 = float(np.sum(amax_arr))

    if gamma0 is None:
        gamma0 = gmax_arr.copy()          # max-participation feasible start
    gamma = np.asarray(gamma0, dtype=np.float64)
    pm, a = _coupled_state(gamma, prm)
    obj = theory.p1_objective(gamma, prm)
    history = [obj]

    converged = False
    it = 0
    for it in range(1, max_iters + 1):
        gh, p_s, ah = _subproblem(gamma / gmax_arr, pm, a / a0, prm,
                                  gmax_arr, amax_arr, a0)
        cand = gh * gmax_arr
        # Backtracking line search between anchor and subproblem solution,
        # evaluating the TRUE objective with exact coupling restored.
        theta = 1.0
        best_gamma, best_obj = gamma, obj
        for _ in range(backtracks):
            trial = theta * cand + (1.0 - theta) * gamma
            trial_obj = theory.p1_objective(trial, prm)
            if trial_obj < best_obj:
                best_gamma, best_obj = trial, trial_obj
                break
            theta *= 0.5
        if best_obj >= obj - tol * max(1.0, abs(obj)):
            converged = True
            gamma, obj = best_gamma, best_obj
            pm, a = _coupled_state(gamma, prm)
            history.append(obj)
            break
        gamma, obj = best_gamma, best_obj
        pm, a = _coupled_state(gamma, prm)
        history.append(obj)

    return SCAResult(gamma=gamma, p=pm, alpha=a, objective=obj,
                     history=history, converged=converged, iterations=it)


def solve_direct(prm: OTAParams, num_starts: int = 8,
                 seed: int = 0) -> SCAResult:
    """Direct multi-start box-constrained minimization of the true (P1)
    objective over gamma_hat in (0,1]^N.  Used as an oracle to validate the
    SCA solution quality in tests/benchmarks (not part of the paper's method).
    """
    gmax_arr = theory.gamma_max(prm)
    rng = np.random.default_rng(seed)
    n = prm.num_devices

    def f(gh):
        return theory.p1_objective(np.maximum(gh, 1e-6) * gmax_arr, prm)

    best = None
    starts = [np.ones(n), np.full(n, 0.5)]
    starts += [rng.uniform(0.05, 1.0, size=n) for _ in range(num_starts - 2)]
    for x0 in starts:
        res = minimize(f, x0, method="L-BFGS-B",
                       bounds=[(1e-6, 1.0)] * n,
                       options={"maxiter": 500})
        if best is None or res.fun < best.fun:
            best = res
    gamma = np.maximum(best.x, 1e-6) * gmax_arr
    pm, a = _coupled_state(gamma, prm)
    return SCAResult(gamma=gamma, p=pm, alpha=a,
                     objective=theory.p1_objective(gamma, prm),
                     history=[best.fun], converged=True, iterations=1)
