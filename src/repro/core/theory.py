"""Theorem-1 quantities for biased OTA-FL (paper §II-B, §III).

Everything here is closed-form float64 numpy over the *statistical* CSI
{Lambda_m}; these functions define both the convergence bound and the SCA
objective.

Key maps (paper eqs. (5)-(10)):

    chi threshold:  |h| >= Gmax * gamma_m / sqrt(d * Es)
    E[chi_m]      = P(|h_m| >= threshold)
                  = exp(-gamma_m^2 Gmax^2 / (d Lambda_m Es))      (Rayleigh)

Off-Rayleigh (OTAParams.fading set to a rician/nakagami FadingSpec —
DESIGN.md §Scenarios), E[chi_m] comes from the family's magnitude survival
function (channel.fading_magnitude_sf) and the alpha_m maximizer gamma_max
is found numerically on the same increasing-then-decreasing branch; the
rest of the Theorem-1 algebra (zeta, bias, the (P1) objective) only sees
alpha_m and is family-agnostic.
    alpha_m(gamma)= gamma_m * E[chi_m]
    alpha         = sum_m alpha_m          (PS post-scaler)
    p_m           = alpha_m / alpha        (average participation level)

    zeta = Gmax^2 * sum_m (p_m gamma_m / alpha - p_m^2)     transmission var
         + sum_m p_m^2 sigma_m^2                            mini-batch var
         + d N0 / alpha^2                                   receiver noise

    bias = 2 N kappa^2 sum_m (p_m - 1/N)^2

    Theorem 1:  (1/T) sum_t E||grad F||^2
        <= 4 max_m (f_m(w0)-f_m^inf) / (eta T) + 2 eta L zeta + bias
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core.channel import FadingSpec, fading_magnitude_sf


@dataclasses.dataclass(frozen=True)
class OTAParams:
    """Problem constants entering the bound and the power-control design."""
    d: int                    # model dimension
    gmax: float               # G_max: uniform bound on sample gradients
    es: float                 # E_s: per-sample energy budget
    n0: float                 # N0: receiver noise PSD
    gains: np.ndarray         # [N] Lambda_m
    sigma_sq: np.ndarray      # [N] per-device mini-batch gradient variance bound
    eta: float = 0.01         # learning rate (enters P1 objective weight)
    lsmooth: float = 1.0      # L-smoothness constant
    kappa_sq: float = 1.0     # kappa^2: gradient dissimilarity bound
    fading: Optional[FadingSpec] = None   # None = Rayleigh (paper baseline)
    dropout: float = 0.0      # per-round device dropout prob (scenario dynamics)

    @property
    def num_devices(self) -> int:
        return int(np.asarray(self.gains).shape[0])

    @property
    def is_rayleigh(self) -> bool:
        return self.fading is None or self.fading.family == "rayleigh"

    def replace(self, **kw) -> "OTAParams":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# alpha_m(gamma) and its extremes
# ---------------------------------------------------------------------------

def trunc_exponent(gamma: np.ndarray, p: OTAParams) -> np.ndarray:
    """gamma^2 Gmax^2 / (d Lambda Es)  — the exponent in E[chi]."""
    gamma = np.asarray(gamma, dtype=np.float64)
    return gamma**2 * p.gmax**2 / (p.d * p.gains * p.es)


def expected_participation_indicator(gamma: np.ndarray, p: OTAParams) -> np.ndarray:
    """E[chi_{m,t}] = (1 - p_dropout) * P(|h_m| >= chi_threshold(gamma_m)).

    A dropped-out device presents h = 0 and never clears the threshold, so
    round dropout scales E[chi] by (1 - p_dropout).  Rayleigh keeps the
    exact paper eq. (5) closed form exp(-gamma^2 Gmax^2 / (d Lambda Es));
    other families use the FadingSpec's magnitude survival function
    (channel.fading_magnitude_sf).
    """
    if p.is_rayleigh:
        sf = np.exp(-trunc_exponent(gamma, p))
    else:
        sf = fading_magnitude_sf(p.gains, chi_threshold(gamma, p), p.fading)
    if p.dropout > 0:
        sf = (1.0 - p.dropout) * sf
    return sf


def log_alpha_of_gamma(gamma: np.ndarray, p: OTAParams) -> np.ndarray:
    """ln alpha_m(gamma).  Rayleigh keeps the exact cancellation-free form
    ln(gamma) - trunc_exponent used by the SCA constraint (11c)."""
    gamma = np.asarray(gamma, dtype=np.float64)
    if p.is_rayleigh:
        out = np.log(gamma) - trunc_exponent(gamma, p)
        if p.dropout > 0:
            out = out + np.log1p(-p.dropout)
        return out
    return np.log(np.maximum(alpha_of_gamma(gamma, p), 1e-300))


def alpha_of_gamma(gamma: np.ndarray, p: OTAParams) -> np.ndarray:
    """alpha_m = gamma_m * E[chi_m]."""
    return np.asarray(gamma, dtype=np.float64) * expected_participation_indicator(gamma, p)


def _rayleigh_gamma_max(p: OTAParams) -> np.ndarray:
    return np.sqrt(p.d * p.gains * p.es / (2.0 * p.gmax**2))


# Two-stage log grid for the numeric (non-Rayleigh) gamma_max search:
# (lo, hi, points) multipliers around the previous stage's maximizer.  Shared
# with the jnp port in repro.solvers.theory_jax so both backends pick the
# same grid candidate (parity to float rounding, not just grid resolution).
GAMMA_MAX_GRID_COARSE = (0.05, 20.0, 241)
GAMMA_MAX_GRID_FINE = (0.95, 1.05, 101)


def gamma_max(p: OTAParams) -> np.ndarray:
    """Maximizer of alpha_m(gamma) per device.

    Rayleigh: closed form gamma_{m,max} = sqrt(d Lambda Es / (2 Gmax^2)).
    Other families: alpha_m(gamma) = gamma * SF(c gamma) is still unimodal
    (SF log-concave for Rician and Nakagami m >= 1/2), so a two-stage log
    grid around the Rayleigh maximizer finds it to ~1e-4 relative accuracy.
    """
    g_ray = _rayleigh_gamma_max(p)
    if p.is_rayleigh:
        return g_ray

    def argmax_on(grid):  # grid: [N, G]
        chi = chi_threshold(grid, p)
        vals = grid * fading_magnitude_sf(p.gains[:, None], chi, p.fading)
        return grid[np.arange(grid.shape[0]), np.argmax(vals, axis=1)]

    coarse = argmax_on(g_ray[:, None]
                       * np.geomspace(*GAMMA_MAX_GRID_COARSE)[None, :])
    fine = argmax_on(coarse[:, None]
                     * np.geomspace(*GAMMA_MAX_GRID_FINE)[None, :])
    return fine


def alpha_max(p: OTAParams) -> np.ndarray:
    """alpha_{m,max} = alpha_m(gamma_{m,max})  (= sqrt(d Lambda Es / (2 e
    Gmax^2)) in closed form under Rayleigh; dropout scales it by 1-p since
    it rescales alpha_m uniformly without moving the maximizer)."""
    if p.is_rayleigh:
        amax = np.sqrt(p.d * p.gains * p.es / (2.0 * np.e * p.gmax**2))
        return (1.0 - p.dropout) * amax if p.dropout > 0 else amax
    return alpha_of_gamma(gamma_max(p), p)


def chi_threshold(gamma: np.ndarray, p: OTAParams) -> np.ndarray:
    """|h| threshold below which device m stays silent: Gmax gamma / sqrt(d Es)."""
    return p.gmax * np.asarray(gamma, dtype=np.float64) / np.sqrt(p.d * p.es)


def invert_alpha(alpha_target: np.ndarray, p: OTAParams) -> np.ndarray:
    """Smaller root gamma_{m,1} of alpha_m(gamma) = alpha_target (per device).

    alpha_m(gamma) is quasi-concave with max at gamma_max; the paper restricts
    to the branch gamma <= gamma_max (constraint (ii)), where the map is
    increasing.  Solved by bisection.
    """
    alpha_target = np.asarray(alpha_target, dtype=np.float64)
    amax = alpha_max(p)
    if np.any(alpha_target > amax * (1 + 1e-12)):
        raise ValueError("alpha_target exceeds alpha_max; infeasible")
    gmax_arr = gamma_max(p)
    lo = np.zeros_like(gmax_arr)
    hi = gmax_arr.copy()
    for _ in range(200):
        mid = 0.5 * (lo + hi)
        val = alpha_of_gamma(mid, p)
        go_up = val < alpha_target
        lo = np.where(go_up, mid, lo)
        hi = np.where(go_up, hi, mid)
    return 0.5 * (lo + hi)


# ---------------------------------------------------------------------------
# Participation, variance and the bound
# ---------------------------------------------------------------------------

def participation(gamma: np.ndarray, p: OTAParams):
    """Return (alpha_m[N], alpha, p_m[N]) for pre-scalers gamma."""
    am = alpha_of_gamma(gamma, p)
    a = float(np.sum(am))
    if a <= 0:
        raise ValueError("alpha = 0: all devices silent")
    return am, a, am / a


def zeta_terms(gamma: np.ndarray, p: OTAParams):
    """The three components of the gradient-estimation variance zeta (eq. 10).

    Returns dict with 'transmission', 'minibatch', 'noise', 'total'.
    """
    _, a, pm = participation(gamma, p)
    gamma = np.asarray(gamma, dtype=np.float64)
    tx = p.gmax**2 * float(np.sum(pm * gamma / a - pm**2))
    mb = float(np.sum(pm**2 * np.asarray(p.sigma_sq, dtype=np.float64)))
    nz = p.d * p.n0 / a**2
    return {"transmission": tx, "minibatch": mb, "noise": nz,
            "total": tx + mb + nz}


def bias_term(pm: np.ndarray, p: OTAParams) -> float:
    """2 N kappa^2 sum_m (p_m - 1/N)^2."""
    n = p.num_devices
    pm = np.asarray(pm, dtype=np.float64)
    return 2.0 * n * p.kappa_sq * float(np.sum((pm - 1.0 / n) ** 2))


def p1_objective(gamma: np.ndarray, p: OTAParams) -> float:
    """The (P1) objective: 2 eta L zeta + bias  (Theorem 1 minus init term)."""
    z = zeta_terms(gamma, p)["total"]
    _, _, pm = participation(gamma, p)
    return 2.0 * p.eta * p.lsmooth * z + bias_term(pm, p)


def theorem1_bound(gamma: np.ndarray, p: OTAParams, init_gap: float,
                   num_rounds: int) -> dict:
    """Full Theorem-1 bound, split into its three components.

    init_gap = max_m (f_m(w0) - f_m^inf).
    """
    z = zeta_terms(gamma, p)
    _, _, pm = participation(gamma, p)
    opt = 4.0 * init_gap / (p.eta * num_rounds)
    var = 2.0 * p.eta * p.lsmooth * z["total"]
    bias = bias_term(pm, p)
    return {"optimization": opt, "variance": var, "bias": bias,
            "total": opt + var + bias, "zeta": z, "p": pm}


def uniform_feasible(p: OTAParams) -> bool:
    """Whether the zero-bias point p_m = 1/N is feasible, i.e. there exists
    alpha with alpha/N <= alpha_{m,max} for all m: alpha <= N * min alpha_max."""
    return bool(np.min(alpha_max(p)) > 0)


def zero_bias_gamma(p: OTAParams, slack: float = 1.0) -> np.ndarray:
    """Pre-scalers enforcing zero average bias (p_m = 1/N exactly).

    Sets every alpha_m to the same value slack * min_m alpha_{m,max} (the
    weakest device binds — the paper's 'constrained by the worst channel'
    regime), and inverts alpha_m(gamma) on the increasing branch.
    """
    if not (0.0 < slack <= 1.0):
        raise ValueError("slack in (0, 1]")
    target = slack * float(np.min(alpha_max(p)))
    return invert_alpha(np.full(p.num_devices, target), p)
