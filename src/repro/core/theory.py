"""Theorem-1 quantities for biased OTA-FL (paper §II-B, §III).

Everything here is closed-form float64 numpy over the *statistical* CSI
{Lambda_m}; these functions define both the convergence bound and the SCA
objective.

Key maps (paper eqs. (5)-(10)):

    chi threshold:  |h| >= Gmax * gamma_m / sqrt(d * Es)
    E[chi_m]      = exp(-gamma_m^2 Gmax^2 / (d Lambda_m Es))      (Rayleigh)
    alpha_m(gamma)= gamma_m * E[chi_m]
    alpha         = sum_m alpha_m          (PS post-scaler)
    p_m           = alpha_m / alpha        (average participation level)

    zeta = Gmax^2 * sum_m (p_m gamma_m / alpha - p_m^2)     transmission var
         + sum_m p_m^2 sigma_m^2                            mini-batch var
         + d N0 / alpha^2                                   receiver noise

    bias = 2 N kappa^2 sum_m (p_m - 1/N)^2

    Theorem 1:  (1/T) sum_t E||grad F||^2
        <= 4 max_m (f_m(w0)-f_m^inf) / (eta T) + 2 eta L zeta + bias
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class OTAParams:
    """Problem constants entering the bound and the power-control design."""
    d: int                    # model dimension
    gmax: float               # G_max: uniform bound on sample gradients
    es: float                 # E_s: per-sample energy budget
    n0: float                 # N0: receiver noise PSD
    gains: np.ndarray         # [N] Lambda_m
    sigma_sq: np.ndarray      # [N] per-device mini-batch gradient variance bound
    eta: float = 0.01         # learning rate (enters P1 objective weight)
    lsmooth: float = 1.0      # L-smoothness constant
    kappa_sq: float = 1.0     # kappa^2: gradient dissimilarity bound

    @property
    def num_devices(self) -> int:
        return int(np.asarray(self.gains).shape[0])

    def replace(self, **kw) -> "OTAParams":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# alpha_m(gamma) and its extremes
# ---------------------------------------------------------------------------

def trunc_exponent(gamma: np.ndarray, p: OTAParams) -> np.ndarray:
    """gamma^2 Gmax^2 / (d Lambda Es)  — the exponent in E[chi]."""
    gamma = np.asarray(gamma, dtype=np.float64)
    return gamma**2 * p.gmax**2 / (p.d * p.gains * p.es)


def expected_participation_indicator(gamma: np.ndarray, p: OTAParams) -> np.ndarray:
    """E[chi_{m,t}] = exp(-gamma^2 Gmax^2 / (d Lambda Es)) under Rayleigh."""
    return np.exp(-trunc_exponent(gamma, p))


def alpha_of_gamma(gamma: np.ndarray, p: OTAParams) -> np.ndarray:
    """alpha_m = gamma_m * E[chi_m]."""
    return np.asarray(gamma, dtype=np.float64) * expected_participation_indicator(gamma, p)


def gamma_max(p: OTAParams) -> np.ndarray:
    """Maximizer of alpha_m(gamma): gamma_{m,max} = sqrt(d Lambda Es / (2 Gmax^2))."""
    return np.sqrt(p.d * p.gains * p.es / (2.0 * p.gmax**2))


def alpha_max(p: OTAParams) -> np.ndarray:
    """alpha_{m,max} = alpha_m(gamma_{m,max}) = sqrt(d Lambda Es / (2 e Gmax^2))."""
    return np.sqrt(p.d * p.gains * p.es / (2.0 * np.e * p.gmax**2))


def chi_threshold(gamma: np.ndarray, p: OTAParams) -> np.ndarray:
    """|h| threshold below which device m stays silent: Gmax gamma / sqrt(d Es)."""
    return p.gmax * np.asarray(gamma, dtype=np.float64) / np.sqrt(p.d * p.es)


def invert_alpha(alpha_target: np.ndarray, p: OTAParams) -> np.ndarray:
    """Smaller root gamma_{m,1} of alpha_m(gamma) = alpha_target (per device).

    alpha_m(gamma) is quasi-concave with max at gamma_max; the paper restricts
    to the branch gamma <= gamma_max (constraint (ii)), where the map is
    increasing.  Solved by bisection.
    """
    alpha_target = np.asarray(alpha_target, dtype=np.float64)
    amax = alpha_max(p)
    if np.any(alpha_target > amax * (1 + 1e-12)):
        raise ValueError("alpha_target exceeds alpha_max; infeasible")
    gmax_arr = gamma_max(p)
    lo = np.zeros_like(gmax_arr)
    hi = gmax_arr.copy()
    for _ in range(200):
        mid = 0.5 * (lo + hi)
        val = alpha_of_gamma(mid, p)
        go_up = val < alpha_target
        lo = np.where(go_up, mid, lo)
        hi = np.where(go_up, hi, mid)
    return 0.5 * (lo + hi)


# ---------------------------------------------------------------------------
# Participation, variance and the bound
# ---------------------------------------------------------------------------

def participation(gamma: np.ndarray, p: OTAParams):
    """Return (alpha_m[N], alpha, p_m[N]) for pre-scalers gamma."""
    am = alpha_of_gamma(gamma, p)
    a = float(np.sum(am))
    if a <= 0:
        raise ValueError("alpha = 0: all devices silent")
    return am, a, am / a


def zeta_terms(gamma: np.ndarray, p: OTAParams):
    """The three components of the gradient-estimation variance zeta (eq. 10).

    Returns dict with 'transmission', 'minibatch', 'noise', 'total'.
    """
    _, a, pm = participation(gamma, p)
    gamma = np.asarray(gamma, dtype=np.float64)
    tx = p.gmax**2 * float(np.sum(pm * gamma / a - pm**2))
    mb = float(np.sum(pm**2 * np.asarray(p.sigma_sq, dtype=np.float64)))
    nz = p.d * p.n0 / a**2
    return {"transmission": tx, "minibatch": mb, "noise": nz,
            "total": tx + mb + nz}


def bias_term(pm: np.ndarray, p: OTAParams) -> float:
    """2 N kappa^2 sum_m (p_m - 1/N)^2."""
    n = p.num_devices
    pm = np.asarray(pm, dtype=np.float64)
    return 2.0 * n * p.kappa_sq * float(np.sum((pm - 1.0 / n) ** 2))


def p1_objective(gamma: np.ndarray, p: OTAParams) -> float:
    """The (P1) objective: 2 eta L zeta + bias  (Theorem 1 minus init term)."""
    z = zeta_terms(gamma, p)["total"]
    _, _, pm = participation(gamma, p)
    return 2.0 * p.eta * p.lsmooth * z + bias_term(pm, p)


def theorem1_bound(gamma: np.ndarray, p: OTAParams, init_gap: float,
                   num_rounds: int) -> dict:
    """Full Theorem-1 bound, split into its three components.

    init_gap = max_m (f_m(w0) - f_m^inf).
    """
    z = zeta_terms(gamma, p)
    _, _, pm = participation(gamma, p)
    opt = 4.0 * init_gap / (p.eta * num_rounds)
    var = 2.0 * p.eta * p.lsmooth * z["total"]
    bias = bias_term(pm, p)
    return {"optimization": opt, "variance": var, "bias": bias,
            "total": opt + var + bias, "zeta": z, "p": pm}


def uniform_feasible(p: OTAParams) -> bool:
    """Whether the zero-bias point p_m = 1/N is feasible, i.e. there exists
    alpha with alpha/N <= alpha_{m,max} for all m: alpha <= N * min alpha_max."""
    return bool(np.min(alpha_max(p)) > 0)


def zero_bias_gamma(p: OTAParams, slack: float = 1.0) -> np.ndarray:
    """Pre-scalers enforcing zero average bias (p_m = 1/N exactly).

    Sets every alpha_m to the same value slack * min_m alpha_{m,max} (the
    weakest device binds — the paper's 'constrained by the worst channel'
    regime), and inverts alpha_m(gamma) on the increasing branch.
    """
    if not (0.0 < slack <= 1.0):
        raise ValueError("slack in (0, 1]")
    target = slack * float(np.min(alpha_max(p)))
    return invert_alpha(np.full(p.num_devices, target), p)
