"""OTA power-control schemes: the paper's SCA design + all Fig.-2 baselines.

Every scheme reduces, per FL round, to a pair of coefficients

    g_hat = sum_m s_m * g_m  +  noise_scale * z,     z ~ N(0, I_d)

where ``s_m`` absorbs the device pre-scaler, the (truncated) channel
inversion, the transmission indicator chi_{m,t}, and the PS post-scaler; and
``noise_scale`` is the effective receiver-noise amplitude per gradient
component.  ``round_coeffs`` is pure jnp so schemes embed directly in a
jit'd/pjit'd train step.

Schemes are scenario-agnostic (DESIGN.md §Scenarios): they consume a
Deployment's (gains, fading-spec) statistics at build time — the truncated
family via the family-aware theory module — and the per-round complex h at
run time, whatever scenario produced it.  Global-CSI schemes become
dropout-aware automatically when the Deployment's scenario dynamics include
device dropout (h = 0 rounds), so their channel-inversion minima bind on
the active devices only; the ``dropout_aware`` kwarg overrides.

Schemes (paper §IV):
  sca               proposed: per-device gamma_m from the SCA solver,
                    truncated channel inversion, statistical CSI at PS.
  lcpc              LCPC OTA-Comp [13]: truncated inversion with a COMMON
                    pre-scaler, grid-optimized with statistical CSI.
  vanilla           Vanilla OTA-FL [5]: full channel inversion, common scale
                    set by the weakest instantaneous channel (zero inst. bias,
                    needs global instantaneous CSI).
  opc               OPC OTA-Comp [13]: per-round MSE-optimal power control
                    (threshold structure), needs global instantaneous CSI.
  bbfl_interior     BB-FL [11]: schedule only devices within R_in.
  bbfl_alternative  BB-FL [11]: randomly alternate full/interior scheduling.
  ideal             noiseless FedAvg (upper reference, eq. (2)).
  zero_bias         structured zero-average-bias truncated inversion
                    (p_m = 1/N exactly; the 'weakest channel binds' regime).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import sca as sca_mod
from repro.core import theory
from repro.core.channel import Deployment
from repro.core.theory import OTAParams

# ---------------------------------------------------------------------------


@dataclasses.dataclass
class PowerControl:
    """Base: time-invariant design state + per-round coefficient map."""
    name: str = "base"
    requires_global_csi: bool = False
    # Time-invariant design (populated where applicable):
    gamma: Optional[np.ndarray] = None   # [N] device pre-scalers
    alpha: Optional[float] = None        # PS post-scaler
    p: Optional[np.ndarray] = None       # [N] avg participation levels

    def round_coeffs(self, h: jnp.ndarray, key: jax.Array):
        """(s[N], noise_scale) for one round given complex fading h[N]."""
        raise NotImplementedError


def _bmax(prm: OTAParams) -> float:
    """Max transmit amplitude per unit gradient: sqrt(d Es)/Gmax."""
    return float(np.sqrt(prm.d * prm.es) / prm.gmax)


# ---------------------------------------------------------------------------
# Truncated-channel-inversion family (time-invariant gamma): SCA / LCPC /
# zero-bias.  s_m = chi_m gamma_m / alpha,  noise = sqrt(N0)/alpha.
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class TruncatedInversion(PowerControl):
    thresholds: Optional[np.ndarray] = None   # [N] chi thresholds on |h|
    n0: float = 0.0

    def round_coeffs(self, h: jnp.ndarray, key: jax.Array):
        habs = jnp.abs(h)
        chi = (habs >= jnp.asarray(self.thresholds)).astype(h.real.dtype)
        s = chi * jnp.asarray(self.gamma) / self.alpha
        noise_scale = jnp.asarray(np.sqrt(self.n0) / self.alpha,
                                  dtype=h.real.dtype)
        return s, noise_scale


def _make_truncated(name: str, gamma: np.ndarray, prm: OTAParams) -> TruncatedInversion:
    am, a, pm = theory.participation(gamma, prm)
    return TruncatedInversion(
        name=name, requires_global_csi=False,
        gamma=np.asarray(gamma, np.float64), alpha=a, p=pm,
        thresholds=theory.chi_threshold(gamma, prm), n0=prm.n0)


def make_sca(deployment: Deployment, prm: OTAParams, **kw) -> TruncatedInversion:
    res = sca_mod.solve_sca(prm, **kw)
    pc = _make_truncated("sca", res.gamma, prm)
    pc.sca_result = res  # attach for inspection
    return pc


def make_lcpc(deployment: Deployment, prm: OTAParams,
              grid_size: int = 512) -> TruncatedInversion:
    """Common pre-scaler, grid-optimized expected-MSE with statistical CSI."""
    gmax_arr = theory.gamma_max(prm)
    grid = np.geomspace(1e-3 * gmax_arr.min(), gmax_arr.max(), grid_size)
    best_g, best_v = None, np.inf
    n = prm.num_devices
    for g in grid:
        gamma = np.full(n, g)
        am = theory.alpha_of_gamma(gamma, prm)
        a = am.sum()
        if a <= 0:
            continue
        pm = am / a
        z = theory.zeta_terms(gamma, prm)
        # expected MSE proxy: variance + squared-bias (G^2-scaled; LCPC has no
        # access to the true dissimilarity kappa -> 'less controllable bias')
        v = z["total"] + prm.gmax**2 * n * np.sum((pm - 1.0 / n) ** 2)
        if v < best_v:
            best_g, best_v = g, v
    return _make_truncated("lcpc", np.full(n, best_g), prm)


def make_zero_bias(deployment: Deployment, prm: OTAParams,
                   slack: float = 1.0) -> TruncatedInversion:
    return _make_truncated("zero_bias", theory.zero_bias_gamma(prm, slack), prm)


# ---------------------------------------------------------------------------
# Vanilla OTA-FL [5]: zero instantaneous bias; common scale c_t bound by the
# weakest instantaneous channel.  Needs global instantaneous CSI.
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class VanillaOTA(PowerControl):
    bmax: float = 0.0
    n0: float = 0.0
    num_devices: int = 0
    dropout_aware: bool = False   # scenarios with p_dropout > 0 observe h=0

    def round_coeffs(self, h: jnp.ndarray, key: jax.Array):
        habs = jnp.abs(h)
        n = self.num_devices
        if not self.dropout_aware:  # paper baseline: exact pre-scenario graph
            c_t = self.bmax * jnp.min(habs)
            s = jnp.full((n,), 1.0 / n, dtype=h.real.dtype)
            noise_scale = jnp.sqrt(self.n0) / (n * c_t)
            return s, noise_scale.astype(h.real.dtype)
        # Dropped devices (h = 0) are excluded from the inversion: the scale
        # binds on the weakest *active* channel and only active devices are
        # averaged (uniform over the k participants).
        active = (habs > 0).astype(h.real.dtype)
        k = jnp.maximum(jnp.sum(active), 1.0)
        c_t = self.bmax * jnp.min(jnp.where(habs > 0, habs, jnp.inf))
        s = active / k
        noise_scale = jnp.sqrt(self.n0) / (k * c_t)
        return s, noise_scale.astype(h.real.dtype)


def _dropout_aware(deployment: Deployment, override) -> bool:
    """Default the flag from the deployment's scenario dynamics so schemes
    built on a dropout scenario never hit the h=0 division-by-zero path."""
    if override is not None:
        return bool(override)
    return getattr(deployment, "p_dropout", 0.0) > 0


def make_vanilla(deployment: Deployment, prm: OTAParams,
                 dropout_aware: Optional[bool] = None) -> VanillaOTA:
    n = prm.num_devices
    return VanillaOTA(name="vanilla", requires_global_csi=True,
                      p=np.full(n, 1.0 / n), bmax=_bmax(prm), n0=prm.n0,
                      num_devices=n,
                      dropout_aware=_dropout_aware(deployment, dropout_aware))


# ---------------------------------------------------------------------------
# OPC OTA-Comp [13]: per-round MSE-optimal (threshold structure).  For a
# denoising scale c, the MSE-optimal amplitudes are b_m = min(c/(N|h_m|),
# bmax): strong channels invert to the common target, weak channels transmit
# at full power.  c is optimized on a fixed log grid (jit-friendly).
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class OPC(PowerControl):
    bmax: float = 0.0
    n0: float = 0.0
    gmax: float = 0.0
    num_devices: int = 0
    grid_size: int = 128
    dropout_aware: bool = False   # scenarios with p_dropout > 0 observe h=0

    def round_coeffs(self, h: jnp.ndarray, key: jax.Array):
        habs = jnp.abs(h)
        n = self.num_devices
        base = self.bmax * habs * n                  # c at which device m leaves inversion
        if self.dropout_aware:
            # dropped devices have base = 0: b_m = min(c/(n*0), bmax) = bmax
            # but s_m = b_m * 0 / c = 0, so they only matter for the grid
            # bounds — anchor those on the active channels.  An all-dropped
            # round would give (c_lo, c_hi) = (inf, 0) and a NaN grid, so it
            # falls back to a dummy finite bracket; s is identically 0 there
            # and the noise is zeroed below — a no-op round, like Vanilla.
            any_active = jnp.any(base > 0)
            c_lo = jnp.where(any_active,
                             0.02 * jnp.min(jnp.where(base > 0, base,
                                                      jnp.inf)), 1.0)
            c_hi = jnp.where(any_active, 50.0 * jnp.max(base), 2.0)
        else:
            c_lo = 0.02 * jnp.min(base)
            c_hi = 50.0 * jnp.max(base)
        grid = jnp.exp(jnp.linspace(jnp.log(c_lo), jnp.log(c_hi),
                                    self.grid_size))

        def mse(c):
            b = jnp.minimum(c / (n * habs), self.bmax)
            sig = jnp.sum((b * habs / c - 1.0 / n) ** 2) * self.gmax**2
            return sig + self.n0 / c**2

        vals = jax.vmap(mse)(grid)
        c_star = grid[jnp.argmin(vals)]
        # zoom refinement around the coarse optimum
        for _ in range(2):
            fine = c_star * jnp.exp(jnp.linspace(-0.15, 0.15, 33))
            c_star = fine[jnp.argmin(jax.vmap(mse)(fine))]
        b = jnp.minimum(c_star / (n * habs), self.bmax)
        s = (b * habs / c_star).astype(h.real.dtype)
        noise_scale = (jnp.sqrt(self.n0) / c_star).astype(h.real.dtype)
        if self.dropout_aware:
            noise_scale = jnp.where(any_active, noise_scale, 0.0)
        return s, noise_scale


def make_opc(deployment: Deployment, prm: OTAParams,
             dropout_aware: Optional[bool] = None) -> OPC:
    n = prm.num_devices
    return OPC(name="opc", requires_global_csi=True, p=np.full(n, 1.0 / n),
               bmax=_bmax(prm), n0=prm.n0, gmax=prm.gmax, num_devices=n,
               dropout_aware=_dropout_aware(deployment, dropout_aware))


# ---------------------------------------------------------------------------
# BB-FL [11]: interior scheduling within R_in (and the alternating variant).
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class BBFL(PowerControl):
    mask: Optional[np.ndarray] = None    # [N] 1 if within R_in
    alternative: bool = False
    bmax: float = 0.0
    n0: float = 0.0
    num_devices: int = 0
    dropout_aware: bool = False   # scenarios with p_dropout > 0 observe h=0

    def _coeffs_for_mask(self, habs, mask):
        if self.dropout_aware:
            # scheduled devices that dropped out (h = 0) cannot transmit
            mask = mask * (habs > 0).astype(habs.dtype)
        # make_bbfl guarantees >= 1 scheduled device, so the max() guard only
        # binds in the dropout case (all scheduled devices out this round)
        k = jnp.maximum(jnp.sum(mask), 1.0)
        c_t = self.bmax * jnp.min(jnp.where(mask > 0, habs, jnp.inf))
        s = mask / k
        noise_scale = jnp.sqrt(self.n0) / (k * c_t)
        return s.astype(habs.dtype), noise_scale.astype(habs.dtype)

    def round_coeffs(self, h: jnp.ndarray, key: jax.Array):
        habs = jnp.abs(h)
        interior = jnp.asarray(self.mask, dtype=habs.dtype)
        if not self.alternative:
            return self._coeffs_for_mask(habs, interior)
        full = jnp.ones_like(interior)
        use_full = jax.random.bernoulli(key, 0.5)
        s_i, ns_i = self._coeffs_for_mask(habs, interior)
        s_f, ns_f = self._coeffs_for_mask(habs, full)
        s = jnp.where(use_full, s_f, s_i)
        ns = jnp.where(use_full, ns_f, ns_i)
        return s, ns


def make_bbfl(deployment: Deployment, prm: OTAParams, alternative: bool,
              r_in_frac: float = 0.6,
              dropout_aware: Optional[bool] = None) -> BBFL:
    r_in = r_in_frac * deployment.cfg.r_max
    mask = (deployment.distances <= r_in).astype(np.float64)
    if mask.sum() == 0:  # degenerate deployment: keep the closest device
        mask[np.argmin(deployment.distances)] = 1.0
    n = prm.num_devices
    name = "bbfl_alternative" if alternative else "bbfl_interior"
    # average participation: interior always on; alternative: 0.5 full + 0.5 interior
    k = mask.sum()
    p = (mask / k) if not alternative else 0.5 * (mask / k) + 0.5 / n
    return BBFL(name=name, requires_global_csi=True, p=p, mask=mask,
                alternative=alternative, bmax=_bmax(prm), n0=prm.n0,
                num_devices=n,
                dropout_aware=_dropout_aware(deployment, dropout_aware))


# ---------------------------------------------------------------------------
# Ideal FedAvg: noiseless uniform aggregation (eq. (2)).
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Ideal(PowerControl):
    num_devices: int = 0

    def round_coeffs(self, h: jnp.ndarray, key: jax.Array):
        n = self.num_devices
        s = jnp.full((n,), 1.0 / n, dtype=h.real.dtype)
        return s, jnp.zeros((), dtype=h.real.dtype)


def make_ideal(deployment: Deployment, prm: OTAParams) -> Ideal:
    n = prm.num_devices
    return Ideal(name="ideal", p=np.full(n, 1.0 / n), num_devices=n)


# ---------------------------------------------------------------------------

SCHEMES = ("sca", "lcpc", "vanilla", "opc", "bbfl_interior",
           "bbfl_alternative", "ideal", "zero_bias")


def make_power_control(name: str, deployment: Deployment, prm: OTAParams,
                       **kw) -> PowerControl:
    if name == "sca":
        return make_sca(deployment, prm, **kw)
    if name == "lcpc":
        return make_lcpc(deployment, prm, **kw)
    if name == "vanilla":
        return make_vanilla(deployment, prm, **kw)
    if name == "opc":
        return make_opc(deployment, prm, **kw)
    if name == "bbfl_interior":
        return make_bbfl(deployment, prm, alternative=False, **kw)
    if name == "bbfl_alternative":
        return make_bbfl(deployment, prm, alternative=True, **kw)
    if name == "ideal":
        return make_ideal(deployment, prm)
    if name == "zero_bias":
        return make_zero_bias(deployment, prm, **kw)
    raise ValueError(f"unknown power-control scheme: {name!r}; "
                     f"available: {SCHEMES}")
