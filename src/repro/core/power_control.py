"""OTA power-control schemes: the paper's SCA design + all Fig.-2 baselines.

Every scheme reduces, per FL round, to a pair of coefficients

    g_hat = sum_m s_m * g_m  +  noise_scale * z,     z ~ N(0, I_d)

where ``s_m`` absorbs the device pre-scaler, the (truncated) channel
inversion, the transmission indicator chi_{m,t}, and the PS post-scaler; and
``noise_scale`` is the effective receiver-noise amplitude per gradient
component.  ``round_coeffs`` is pure jnp so schemes embed directly in a
jit'd/pjit'd train step.

Schemes are scenario-agnostic (DESIGN.md §Scenarios): they consume a
Deployment's (gains, fading-spec) statistics at build time — the truncated
family via the family-aware theory module — and the per-round complex h at
run time, whatever scenario produced it.  Global-CSI schemes become
dropout-aware automatically when the Deployment's scenario dynamics include
device dropout (h = 0 rounds), so their channel-inversion minima bind on
the active devices only; the ``dropout_aware`` kwarg overrides.

Schemes (paper §IV):
  sca               proposed: per-device gamma_m from the SCA solver,
                    truncated channel inversion, statistical CSI at PS.
  lcpc              LCPC OTA-Comp [13]: truncated inversion with a COMMON
                    pre-scaler, grid-optimized with statistical CSI.
  vanilla           Vanilla OTA-FL [5]: full channel inversion, common scale
                    set by the weakest instantaneous channel (zero inst. bias,
                    needs global instantaneous CSI).
  opc               OPC OTA-Comp [13]: per-round MSE-optimal power control
                    (threshold structure), needs global instantaneous CSI.
  bbfl_interior     BB-FL [11]: schedule only devices within R_in.
  bbfl_alternative  BB-FL [11]: randomly alternate full/interior scheduling.
  ideal             noiseless FedAvg (upper reference, eq. (2)).
  zero_bias         structured zero-average-bias truncated inversion
                    (p_m = 1/N exactly; the 'weakest channel binds' regime).

Beyond the paper grid, ``adaptive_sca`` (class ``AdaptiveSCA``) re-solves
the SCA design between fl.engine scan chunks from the scenario's current
statistical CSI (DESIGN.md §Solvers) — the compiled batched solver in
``repro.solvers`` is what makes the in-training re-design affordable.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import sca as sca_mod
from repro.core import theory
from repro.core.channel import Deployment
from repro.core.theory import OTAParams

# ---------------------------------------------------------------------------


@dataclasses.dataclass
class PowerControl:
    """Base: time-invariant design state + per-round coefficient map.

    Every concrete scheme is registered as a JAX pytree (see
    ``_register_scheme_pytrees`` at the bottom of this module): its numeric
    design state (gamma, alpha, thresholds, ...) are the array leaves and
    its name/config flags are static aux data.  A scheme object can
    therefore cross jit boundaries as an argument, and same-structure
    schemes can be stacked along a leading [K] axis (``stack_schemes``) and
    run as one vmapped fleet — the substrate of the batched experiment
    engine (DESIGN.md §Engine).  ``round_coeffs`` is pure jnp on the leaf
    fields, so it traces with either concrete numpy state or batched
    tracers.
    """
    name: str = "base"
    requires_global_csi: bool = False
    # Time-invariant design (populated where applicable):
    gamma: Optional[np.ndarray] = None   # [N] device pre-scalers
    alpha: Optional[float] = None        # PS post-scaler
    p: Optional[np.ndarray] = None       # [N] avg participation levels

    def round_coeffs(self, h: jnp.ndarray, key: jax.Array):
        """(s[N], noise_scale) for one round given complex fading h[N]."""
        raise NotImplementedError


def _bmax(prm: OTAParams) -> float:
    """Max transmit amplitude per unit gradient: sqrt(d Es)/Gmax."""
    return float(np.sqrt(prm.d * prm.es) / prm.gmax)


# ---------------------------------------------------------------------------
# Truncated-channel-inversion family (time-invariant gamma): SCA / LCPC /
# zero-bias.  s_m = chi_m gamma_m / alpha,  noise = sqrt(N0)/alpha.
# ---------------------------------------------------------------------------

def _truncated_coeffs(habs, gamma, alpha, thresholds, noise_over_alpha):
    """chi-truncated inversion coefficients (shared by the class and the
    SchemeBatch union branch — one definition, bitwise-identical paths)."""
    dt = habs.dtype
    chi = (habs >= jnp.asarray(thresholds, dt)).astype(dt)
    s = chi * jnp.asarray(gamma, dt) / jnp.asarray(alpha, dt)
    return s, jnp.asarray(noise_over_alpha, dt)


@dataclasses.dataclass
class TruncatedInversion(PowerControl):
    thresholds: Optional[np.ndarray] = None   # [N] chi thresholds on |h|
    n0: float = 0.0
    # sqrt(n0)/alpha, precomputed in float64 at build time so round_coeffs
    # never does host math on (possibly traced) leaves.
    noise_over_alpha: Optional[float] = None

    def __post_init__(self):
        if self.noise_over_alpha is None and self.alpha is not None:
            self.noise_over_alpha = float(np.sqrt(self.n0) / self.alpha)

    def round_coeffs(self, h: jnp.ndarray, key: jax.Array):
        return _truncated_coeffs(jnp.abs(h), self.gamma, self.alpha,
                                 self.thresholds, self.noise_over_alpha)


def _make_truncated(name: str, gamma: np.ndarray, prm: OTAParams) -> TruncatedInversion:
    am, a, pm = theory.participation(gamma, prm)
    return TruncatedInversion(
        name=name, requires_global_csi=False,
        gamma=np.asarray(gamma, np.float64), alpha=a, p=pm,
        thresholds=theory.chi_threshold(gamma, prm), n0=prm.n0)


def make_sca(deployment: Deployment, prm: OTAParams, method: str = "jax",
             **kw) -> TruncatedInversion:
    """The paper's SCA design.  ``method="jax"`` (default) runs the compiled
    batched solver (repro.solvers, DESIGN.md §Solvers); ``method="scipy"``
    runs the host SLSQP reference oracle (core.sca.solve_sca).  Both descend
    the same (P1) objective from the same start and agree to ~1e-6 relative
    on the reference cases (benchmarks/sca_bench.py tracks the gap)."""
    if method == "scipy":
        res = sca_mod.solve_sca(prm, **kw)
    elif method == "jax":
        from repro import solvers  # deferred: keep core importable fast
        # translate the legacy solve_sca budget kwargs onto SolverConfig so
        # pre-existing make_power_control("sca", ..., max_iters=...) callers
        # keep working across the default-path switch
        legacy = {k: kw.pop(k) for k in ("max_iters", "tol", "backtracks")
                  if k in kw}
        cfg = kw.pop("cfg", solvers.DEFAULT_CONFIG)
        if legacy:
            cfg = dataclasses.replace(cfg, **legacy)
        res = solvers.solve(prm, cfg=cfg, **kw)
    else:
        raise ValueError(f"unknown sca method {method!r} (jax|scipy)")
    pc = _make_truncated("sca", res.gamma, prm)
    pc.sca_result = res  # attach for inspection
    return pc


def make_lcpc(deployment: Deployment, prm: OTAParams,
              grid_size: int = 512) -> TruncatedInversion:
    """Common pre-scaler, grid-optimized expected-MSE with statistical CSI."""
    gmax_arr = theory.gamma_max(prm)
    grid = np.geomspace(1e-3 * gmax_arr.min(), gmax_arr.max(), grid_size)
    best_g, best_v = None, np.inf
    n = prm.num_devices
    for g in grid:
        gamma = np.full(n, g)
        am = theory.alpha_of_gamma(gamma, prm)
        a = am.sum()
        if a <= 0:
            continue
        pm = am / a
        z = theory.zeta_terms(gamma, prm)
        # expected MSE proxy: variance + squared-bias (G^2-scaled; LCPC has no
        # access to the true dissimilarity kappa -> 'less controllable bias')
        v = z["total"] + prm.gmax**2 * n * np.sum((pm - 1.0 / n) ** 2)
        if v < best_v:
            best_g, best_v = g, v
    return _make_truncated("lcpc", np.full(n, best_g), prm)


def make_zero_bias(deployment: Deployment, prm: OTAParams,
                   slack: float = 1.0) -> TruncatedInversion:
    return _make_truncated("zero_bias", theory.zero_bias_gamma(prm, slack), prm)


# ---------------------------------------------------------------------------
# AdaptiveSCA: truncated inversion whose design re-solves DURING training
# (between fl.engine scan chunks) from the scenario's current statistical
# CSI.  DESIGN.md §Solvers.
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class AdaptiveSCA(TruncatedInversion):
    """SCA design that tracks time-varying statistical CSI.

    Round coefficients are plain truncated inversion (inherited), so inside
    a scan chunk the scheme is indistinguishable from ``sca``.  Between
    chunks the engine calls ``redesign_fn(scheme, fading, state)`` — for a
    Gauss-Markov scenario this maps the current scattered state d_t to the
    one-step conditional channel law (Rician: mean rho d_t + LOS, diffuse
    variance (1-rho^2) Lambda_d), batch-solves (P1) under that conditional
    CSI with the compiled solver, and swaps in the new design.  On static
    CSI (``fading=None`` or rho=0) the redesign is a no-op, so static runs
    are bit-identical to the ``sca`` scheme built by the same solver.

    The design leaves carry whatever leading batch axes the engine's fleet
    grid has ([K, S] after the first redesign) — ``round_coeffs`` is
    per-cell under vmap either way.

    ``redesign_cohort_fn(pc, gains)`` is the population-mode sibling
    (DESIGN.md §Population): it re-solves (P1) on an incoming cohort's
    STATIONARY statistical CSI (``gains`` [..., N], any leading batch
    axes).  It is pure in ``gains`` — no dependence on the live fading
    state or current design — which is what lets the streaming driver run
    it for cohort c+1 while chunk c is still executing.
    """
    redesign_fn: Optional[object] = None   # static aux: (pc, fading, state)
    redesign_cohort_fn: Optional[object] = None   # static aux: (pc, gains)


# K-factors above this are effectively deterministic channels; the cap keeps
# the conditional-CSI solve inside the Marcum-series accuracy envelope
# (theory_jax._MARCUM_TERMS).
_ADAPTIVE_K_CAP = 50.0


def make_adaptive_sca(deployment: Deployment, prm: OTAParams,
                      **kw) -> AdaptiveSCA:
    """Build the adaptive scheme: initial design = the static solve on the
    deployment's stationary CSI (identical to ``make_sca(..., "jax")``).

    When K same-class AdaptiveSCA schemes are stacked into one fleet, the
    first scheme's redesign hook serves every row — the hook reads the
    per-row fading state for gains, but problem constants (d, Gmax, Es,
    N0, eta, L, kappa^2, sigma^2) come from ITS ``prm``, so rows of one
    adaptive fleet should share those constants."""
    from repro import solvers
    from repro.solvers import theory_jax as tjx
    from jax.experimental import enable_x64

    cfg = kw.pop("cfg", solvers.DEFAULT_CONFIG)
    res = solvers.solve(prm, cfg=cfg, **kw)
    base = _make_truncated("adaptive_sca", res.gamma, prm)

    def redesign(pc: AdaptiveSCA, fading, state):
        rho = float(getattr(fading, "rho", 0.0))
        if state is None or rho == 0.0:
            return pc      # static CSI: nothing to track
        with enable_x64():
            n = prm.num_devices
            state64 = jnp.asarray(state)                     # [..., N] complex
            batch = state64.shape[:-1]
            diffuse = (1.0 - rho**2) * jnp.asarray(
                np.asarray(fading._diffuse_gains(), np.float64))
            los = jnp.asarray(np.asarray(fading._los(), np.float64))
            mean = los + rho * state64       # one-step conditional mean
            nu2 = jnp.abs(mean) ** 2
            gains_eff = (nu2 + diffuse).reshape((-1, n))     # [B, N]
            k_eff = jnp.minimum(nu2 / diffuse,
                                _ADAPTIVE_K_CAP).reshape((-1, n))
            b = gains_eff.shape[0]

            def row(v):
                return jnp.broadcast_to(jnp.asarray(v, jnp.float64), (b,))

            prm_b = tjx.SolverParams(
                d=row(prm.d), gmax=row(prm.gmax), es=row(prm.es),
                n0=row(prm.n0), gains=gains_eff,
                sigma_sq=jnp.broadcast_to(
                    jnp.asarray(prm.sigma_sq, jnp.float64), (b, n)),
                eta=row(prm.eta), lsmooth=row(prm.lsmooth),
                kappa_sq=row(prm.kappa_sq), dropout=row(prm.dropout),
                fading_param=k_eff, family="rician")
            out = solvers.solve_batch_device(prm_b, cfg)
            shape = batch + (n,)
            gamma = np.asarray(out["gamma"]).reshape(shape)
            p = np.asarray(out["p"]).reshape(shape)
            alpha = np.asarray(out["alpha"]).reshape(batch)
        return dataclasses.replace(
            pc, gamma=gamma, alpha=alpha, p=p,
            thresholds=np.asarray(theory.chi_threshold(gamma, prm)),
            noise_over_alpha=np.sqrt(prm.n0) / alpha)

    # population cohorts: same solver, but the CSI is the incoming
    # cohort's stationary gains (family from prm, scalar parameter) —
    # pure in `gains`, safe to run ahead of the executing chunk
    family = "rayleigh" if prm.is_rayleigh else prm.fading.family
    if family == "rician":
        fparam = float(np.asarray(prm.fading.rician_k))
    elif family == "nakagami":
        fparam = float(np.asarray(prm.fading.nakagami_m))
    else:
        fparam = 1.0

    def redesign_cohort(pc: AdaptiveSCA, gains):
        with enable_x64():
            n = prm.num_devices
            g = np.asarray(gains, np.float64)
            if g.shape[-1] != n:
                raise ValueError(f"cohort gains have {g.shape[-1]} devices "
                                 f"but the design was built for {n}")
            batch = g.shape[:-1]
            gb = jnp.asarray(g.reshape((-1, n)))
            b = gb.shape[0]

            def row(v):
                return jnp.broadcast_to(jnp.asarray(v, jnp.float64), (b,))

            prm_b = tjx.SolverParams(
                d=row(prm.d), gmax=row(prm.gmax), es=row(prm.es),
                n0=row(prm.n0), gains=gb,
                sigma_sq=jnp.broadcast_to(
                    jnp.asarray(prm.sigma_sq, jnp.float64), (b, n)),
                eta=row(prm.eta), lsmooth=row(prm.lsmooth),
                kappa_sq=row(prm.kappa_sq), dropout=row(prm.dropout),
                fading_param=jnp.full((b, n), fparam, jnp.float64),
                family=family)
            out = solvers.solve_batch_device(prm_b, cfg)
            shape = batch + (n,)
            gamma = np.asarray(out["gamma"]).reshape(shape)
            p = np.asarray(out["p"]).reshape(shape)
            alpha = np.asarray(out["alpha"]).reshape(batch)
        return dataclasses.replace(
            pc, gamma=gamma, alpha=alpha, p=p,
            thresholds=np.asarray(theory.chi_threshold(gamma, prm)),
            noise_over_alpha=np.sqrt(prm.n0) / alpha)

    return AdaptiveSCA(
        name="adaptive_sca", requires_global_csi=False, gamma=base.gamma,
        alpha=base.alpha, p=base.p, thresholds=base.thresholds, n0=prm.n0,
        noise_over_alpha=base.noise_over_alpha, redesign_fn=redesign,
        redesign_cohort_fn=redesign_cohort)


# ---------------------------------------------------------------------------
# Vanilla OTA-FL [5]: zero instantaneous bias; common scale c_t bound by the
# weakest instantaneous channel.  Needs global instantaneous CSI.
# ---------------------------------------------------------------------------

def _vanilla_coeffs(habs, n, bmax, n0, dropout_aware: bool):
    dt = habs.dtype
    if not dropout_aware:  # paper baseline: exact pre-scenario graph
        c_t = bmax * jnp.min(habs)
        s = jnp.full((n,), 1.0 / n, dtype=dt)
        noise_scale = jnp.sqrt(n0) / (n * c_t)
        return s, noise_scale.astype(dt)
    # Dropped devices (h = 0) are excluded from the inversion: the scale
    # binds on the weakest *active* channel and only active devices are
    # averaged (uniform over the k participants).
    active = (habs > 0).astype(dt)
    k = jnp.maximum(jnp.sum(active), 1.0)
    c_t = bmax * jnp.min(jnp.where(habs > 0, habs, jnp.inf))
    s = active / k
    noise_scale = jnp.sqrt(n0) / (k * c_t)
    return s, noise_scale.astype(dt)


@dataclasses.dataclass
class VanillaOTA(PowerControl):
    bmax: float = 0.0
    n0: float = 0.0
    num_devices: int = 0
    dropout_aware: bool = False   # scenarios with p_dropout > 0 observe h=0

    def round_coeffs(self, h: jnp.ndarray, key: jax.Array):
        return _vanilla_coeffs(jnp.abs(h), self.num_devices, self.bmax,
                               self.n0, self.dropout_aware)


def _dropout_aware(deployment: Deployment, override) -> bool:
    """Default the flag from the deployment's scenario dynamics so schemes
    built on a dropout scenario never hit the h=0 division-by-zero path."""
    if override is not None:
        return bool(override)
    return getattr(deployment, "p_dropout", 0.0) > 0


def make_vanilla(deployment: Deployment, prm: OTAParams,
                 dropout_aware: Optional[bool] = None) -> VanillaOTA:
    n = prm.num_devices
    return VanillaOTA(name="vanilla", requires_global_csi=True,
                      p=np.full(n, 1.0 / n), bmax=_bmax(prm), n0=prm.n0,
                      num_devices=n,
                      dropout_aware=_dropout_aware(deployment, dropout_aware))


# ---------------------------------------------------------------------------
# OPC OTA-Comp [13]: per-round MSE-optimal (threshold structure).  For a
# denoising scale c, the MSE-optimal amplitudes are b_m = min(c/(N|h_m|),
# bmax): strong channels invert to the common target, weak channels transmit
# at full power.  c is optimized on a fixed log grid (jit-friendly).
# ---------------------------------------------------------------------------

def _opc_coeffs(habs, n, bmax, n0, gmax, grid_size: int,
                dropout_aware: bool):
    dt = habs.dtype
    base = bmax * habs * n                  # c at which device m leaves inversion
    if dropout_aware:
        # dropped devices have base = 0: b_m = min(c/(n*0), bmax) = bmax
        # but s_m = b_m * 0 / c = 0, so they only matter for the grid
        # bounds — anchor those on the active channels.  An all-dropped
        # round would give (c_lo, c_hi) = (inf, 0) and a NaN grid, so it
        # falls back to a dummy finite bracket; s is identically 0 there
        # and the noise is zeroed below — a no-op round, like Vanilla.
        any_active = jnp.any(base > 0)
        c_lo = jnp.where(any_active,
                         0.02 * jnp.min(jnp.where(base > 0, base,
                                                  jnp.inf)), 1.0)
        c_hi = jnp.where(any_active, 50.0 * jnp.max(base), 2.0)
    else:
        c_lo = 0.02 * jnp.min(base)
        c_hi = 50.0 * jnp.max(base)
    grid = jnp.exp(jnp.linspace(jnp.log(c_lo), jnp.log(c_hi), grid_size))

    def mse(c):
        b = jnp.minimum(c / (n * habs), bmax)
        sig = jnp.sum((b * habs / c - 1.0 / n) ** 2) * gmax**2
        return sig + n0 / c**2

    vals = jax.vmap(mse)(grid)
    c_star = grid[jnp.argmin(vals)]
    # zoom refinement around the coarse optimum
    for _ in range(2):
        fine = c_star * jnp.exp(jnp.linspace(-0.15, 0.15, 33))
        c_star = fine[jnp.argmin(jax.vmap(mse)(fine))]
    b = jnp.minimum(c_star / (n * habs), bmax)
    s = (b * habs / c_star).astype(dt)
    noise_scale = (jnp.sqrt(n0) / c_star).astype(dt)
    if dropout_aware:
        noise_scale = jnp.where(any_active, noise_scale, 0.0)
    return s, noise_scale


@dataclasses.dataclass
class OPC(PowerControl):
    bmax: float = 0.0
    n0: float = 0.0
    gmax: float = 0.0
    num_devices: int = 0
    grid_size: int = 128
    dropout_aware: bool = False   # scenarios with p_dropout > 0 observe h=0

    def round_coeffs(self, h: jnp.ndarray, key: jax.Array):
        return _opc_coeffs(jnp.abs(h), self.num_devices, self.bmax, self.n0,
                           self.gmax, self.grid_size, self.dropout_aware)


def make_opc(deployment: Deployment, prm: OTAParams,
             dropout_aware: Optional[bool] = None) -> OPC:
    n = prm.num_devices
    return OPC(name="opc", requires_global_csi=True, p=np.full(n, 1.0 / n),
               bmax=_bmax(prm), n0=prm.n0, gmax=prm.gmax, num_devices=n,
               dropout_aware=_dropout_aware(deployment, dropout_aware))


# ---------------------------------------------------------------------------
# BB-FL [11]: interior scheduling within R_in (and the alternating variant).
# ---------------------------------------------------------------------------

def _bbfl_mask_coeffs(habs, mask, bmax, n0, dropout_aware: bool):
    if dropout_aware:
        # scheduled devices that dropped out (h = 0) cannot transmit
        mask = mask * (habs > 0).astype(habs.dtype)
    # make_bbfl guarantees >= 1 scheduled device, so the max() guard only
    # binds in the dropout case (all scheduled devices out this round)
    k = jnp.maximum(jnp.sum(mask), 1.0)
    c_t = bmax * jnp.min(jnp.where(mask > 0, habs, jnp.inf))
    s = mask / k
    noise_scale = jnp.sqrt(n0) / (k * c_t)
    return s.astype(habs.dtype), noise_scale.astype(habs.dtype)


def _bbfl_coeffs(habs, key, mask, alternative, bmax, n0,
                 dropout_aware: bool):
    """``alternative`` may be a python bool (class path, branch folded at
    trace time) or a traced scalar (SchemeBatch union path, folded into the
    select so interior/alternative rows share one graph)."""
    interior = jnp.asarray(mask, dtype=habs.dtype)
    if isinstance(alternative, bool) and not alternative:
        return _bbfl_mask_coeffs(habs, interior, bmax, n0, dropout_aware)
    full = jnp.ones_like(interior)
    use_full = jax.random.bernoulli(key, 0.5)
    if not isinstance(alternative, bool):
        use_full = jnp.logical_and(use_full, alternative > 0)
    s_i, ns_i = _bbfl_mask_coeffs(habs, interior, bmax, n0, dropout_aware)
    s_f, ns_f = _bbfl_mask_coeffs(habs, full, bmax, n0, dropout_aware)
    s = jnp.where(use_full, s_f, s_i)
    ns = jnp.where(use_full, ns_f, ns_i)
    return s, ns


@dataclasses.dataclass
class BBFL(PowerControl):
    mask: Optional[np.ndarray] = None    # [N] 1 if within R_in
    alternative: bool = False
    bmax: float = 0.0
    n0: float = 0.0
    num_devices: int = 0
    dropout_aware: bool = False   # scenarios with p_dropout > 0 observe h=0

    def round_coeffs(self, h: jnp.ndarray, key: jax.Array):
        return _bbfl_coeffs(jnp.abs(h), key, self.mask, self.alternative,
                            self.bmax, self.n0, self.dropout_aware)


def make_bbfl(deployment: Deployment, prm: OTAParams, alternative: bool,
              r_in_frac: float = 0.6,
              dropout_aware: Optional[bool] = None) -> BBFL:
    r_in = r_in_frac * deployment.cfg.r_max
    mask = (deployment.distances <= r_in).astype(np.float64)
    if mask.sum() == 0:  # degenerate deployment: keep the closest device
        mask[np.argmin(deployment.distances)] = 1.0
    n = prm.num_devices
    name = "bbfl_alternative" if alternative else "bbfl_interior"
    # average participation: interior always on; alternative: 0.5 full + 0.5 interior
    k = mask.sum()
    p = (mask / k) if not alternative else 0.5 * (mask / k) + 0.5 / n
    return BBFL(name=name, requires_global_csi=True, p=p, mask=mask,
                alternative=alternative, bmax=_bmax(prm), n0=prm.n0,
                num_devices=n,
                dropout_aware=_dropout_aware(deployment, dropout_aware))


# ---------------------------------------------------------------------------
# Ideal FedAvg: noiseless uniform aggregation (eq. (2)).
# ---------------------------------------------------------------------------

def _ideal_coeffs(habs, n):
    s = jnp.full((n,), 1.0 / n, dtype=habs.dtype)
    return s, jnp.zeros((), dtype=habs.dtype)


@dataclasses.dataclass
class Ideal(PowerControl):
    num_devices: int = 0

    def round_coeffs(self, h: jnp.ndarray, key: jax.Array):
        return _ideal_coeffs(jnp.abs(h), self.num_devices)


def make_ideal(deployment: Deployment, prm: OTAParams) -> Ideal:
    n = prm.num_devices
    return Ideal(name="ideal", p=np.full(n, 1.0 / n), num_devices=n)


# ---------------------------------------------------------------------------

SCHEMES = ("sca", "lcpc", "vanilla", "opc", "bbfl_interior",
           "bbfl_alternative", "ideal", "zero_bias")


def make_power_control(name: str, deployment: Deployment, prm: OTAParams,
                       **kw) -> PowerControl:
    if name == "sca":
        return make_sca(deployment, prm, **kw)
    if name == "lcpc":
        return make_lcpc(deployment, prm, **kw)
    if name == "vanilla":
        return make_vanilla(deployment, prm, **kw)
    if name == "opc":
        return make_opc(deployment, prm, **kw)
    if name == "bbfl_interior":
        return make_bbfl(deployment, prm, alternative=False, **kw)
    if name == "bbfl_alternative":
        return make_bbfl(deployment, prm, alternative=True, **kw)
    if name == "ideal":
        return make_ideal(deployment, prm)
    if name == "zero_bias":
        return make_zero_bias(deployment, prm, **kw)
    if name == "adaptive_sca":
        return make_adaptive_sca(deployment, prm, **kw)
    raise ValueError(f"unknown power-control scheme: {name!r}; "
                     f"available: {SCHEMES + ('adaptive_sca',)}")


# ---------------------------------------------------------------------------
# Pytree registration + scheme stacking (DESIGN.md §Engine).
#
# Every concrete scheme is a pytree: numeric design state = leaves, name and
# config flags = static aux.  ``stack_schemes`` turns a list of schemes into
# one object whose leaves carry a leading [K] axis, so a single vmapped
# program evaluates all K schemes' round coefficients — the [K-scheme x
# S-seed] fleet of fl.engine rides on this.
# ---------------------------------------------------------------------------

# leaf (array) fields per class; every other dataclass field is static aux.
_SCHEME_LEAVES = {
    TruncatedInversion: ("gamma", "alpha", "p", "thresholds", "n0",
                         "noise_over_alpha"),
    AdaptiveSCA: ("gamma", "alpha", "p", "thresholds", "n0",
                  "noise_over_alpha"),
    VanillaOTA: ("gamma", "alpha", "p", "bmax", "n0"),
    OPC: ("gamma", "alpha", "p", "bmax", "n0", "gmax"),
    BBFL: ("gamma", "alpha", "p", "mask", "bmax", "n0"),
    Ideal: ("gamma", "alpha", "p"),
}


def _scheme_statics(cls):
    leaves = _SCHEME_LEAVES[cls]
    return tuple(f.name for f in dataclasses.fields(cls)
                 if f.name not in leaves)


def _register_scheme_pytree(cls):
    leaf_fields = _SCHEME_LEAVES[cls]
    static_fields = _scheme_statics(cls)

    def flatten(obj):
        children = tuple(getattr(obj, f) for f in leaf_fields)
        aux = tuple(getattr(obj, f) for f in static_fields)
        return children, aux

    def unflatten(aux, children):
        kw = dict(zip(static_fields, aux))
        kw.update(zip(leaf_fields, children))
        return cls(**kw)

    jax.tree_util.register_pytree_node(cls, flatten, unflatten)


for _cls in _SCHEME_LEAVES:
    _register_scheme_pytree(_cls)


_UNION_KIND_OF = {TruncatedInversion: 0, VanillaOTA: 1, OPC: 2, BBFL: 3,
                  Ideal: 4}


@dataclasses.dataclass
class SchemeBatch:
    """Union representation of K *heterogeneous* schemes, stacked [K].

    Each row carries the superset of all kinds' design state (unused fields
    hold benign fillers) plus a ``kind`` index; ``round_coeffs`` on one row
    dispatches through ``lax.switch``, which under vmap becomes a select
    over all kind branches — one compiled program runs an arbitrary mix of
    truncated-inversion / vanilla / OPC / BB-FL / ideal rows.  The branch
    bodies are the *same* module-level coefficient functions the scheme
    classes call, so a SchemeBatch row reproduces the standalone scheme
    run-for-run.
    """
    names: tuple = ()
    num_devices: int = 0
    grid_size: int = 128
    dropout_aware: bool = False
    kind: Optional[np.ndarray] = None            # [K] int32
    gamma: Optional[np.ndarray] = None           # [K, N]
    alpha: Optional[np.ndarray] = None           # [K]
    p: Optional[np.ndarray] = None               # [K, N]
    thresholds: Optional[np.ndarray] = None      # [K, N]
    noise_over_alpha: Optional[np.ndarray] = None  # [K]
    mask: Optional[np.ndarray] = None            # [K, N]
    alternative: Optional[np.ndarray] = None     # [K] (0/1)
    bmax: Optional[np.ndarray] = None            # [K]
    n0: Optional[np.ndarray] = None              # [K]
    gmax: Optional[np.ndarray] = None            # [K]

    def __len__(self):
        return len(self.names)

    @property
    def name(self):
        return "+".join(self.names)

    def round_coeffs(self, h: jnp.ndarray, key: jax.Array):
        """Per-row coefficients (use under vmap; rows are scalar/[N])."""
        habs = jnp.abs(h)
        n = self.num_devices
        branches = (
            lambda op: _truncated_coeffs(op[0], self.gamma, self.alpha,
                                         self.thresholds,
                                         self.noise_over_alpha),
            lambda op: _vanilla_coeffs(op[0], n, self.bmax, self.n0,
                                       self.dropout_aware),
            lambda op: _opc_coeffs(op[0], n, self.bmax, self.n0, self.gmax,
                                   self.grid_size, self.dropout_aware),
            lambda op: _bbfl_coeffs(op[0], op[1], self.mask,
                                    self.alternative, self.bmax, self.n0,
                                    self.dropout_aware),
            lambda op: _ideal_coeffs(op[0], n),
        )
        return jax.lax.switch(self.kind, branches, (habs, key))


jax.tree_util.register_pytree_node(
    SchemeBatch,
    lambda sb: (tuple(getattr(sb, f) for f in
                      ("kind", "gamma", "alpha", "p", "thresholds",
                       "noise_over_alpha", "mask", "alternative", "bmax",
                       "n0", "gmax")),
                (sb.names, sb.num_devices, sb.grid_size, sb.dropout_aware)),
    lambda aux, ch: SchemeBatch(*aux, *ch),
)


def _union_row(pc: PowerControl, n: int) -> dict:
    """One SchemeBatch row from a concrete scheme (fillers keep every dead
    branch finite so the vmapped select never sees NaN/Inf)."""
    def arr(v, default):
        return np.asarray(default if v is None else v, np.float64)
    return dict(
        kind=np.int32(_UNION_KIND_OF[type(pc)]),
        gamma=arr(pc.gamma, np.zeros(n)),
        alpha=arr(pc.alpha, 1.0),
        p=arr(pc.p, np.full(n, 1.0 / n)),
        thresholds=arr(getattr(pc, "thresholds", None), np.zeros(n)),
        noise_over_alpha=arr(getattr(pc, "noise_over_alpha", None), 0.0),
        mask=arr(getattr(pc, "mask", None), np.ones(n)),
        alternative=arr(float(getattr(pc, "alternative", False)), 0.0),
        bmax=arr(getattr(pc, "bmax", None), 1.0),
        n0=arr(getattr(pc, "n0", None), 0.0),
        gmax=arr(getattr(pc, "gmax", None), 1.0),
    )


def _scheme_n(pc: PowerControl) -> int:
    for f in ("p", "gamma", "mask", "thresholds"):
        v = getattr(pc, f, None)
        if v is not None:
            return int(np.asarray(v).shape[-1])
    n = getattr(pc, "num_devices", 0)
    if n:
        return int(n)
    raise ValueError(f"cannot infer device count for scheme {pc.name!r}")


def stack_schemes(schemes):
    """Stack K PowerControl schemes for a vmapped fleet (DESIGN.md §Engine).

    Same-class schemes with identical static config (name aside) stack
    directly: the result is one instance of that class whose array leaves
    have a leading [K] axis, ready for ``jax.vmap`` with in_axes=0 on the
    scheme argument.  Any mix of classes (or of static configs) falls back
    to the ``SchemeBatch`` union with per-row lax.switch dispatch.  Either
    way the result duck-types ``round_coeffs`` per row and exposes
    ``.names``.
    """
    schemes = list(schemes)
    if not schemes:
        raise ValueError("stack_schemes needs at least one scheme")
    names = tuple(pc.name for pc in schemes)
    n = _scheme_n(schemes[0])
    if any(_scheme_n(pc) != n for pc in schemes):
        raise ValueError("schemes disagree on device count")

    cls = type(schemes[0])
    homogeneous = (cls in _SCHEME_LEAVES
                   and all(type(pc) is cls for pc in schemes))
    if homogeneous:
        # redesign_fn closures are per-instance and never compare equal;
        # same-class adaptive schemes stack with the FIRST scheme's hook
        # (rows share the fleet's fading process and problem constants —
        # per-row state is what the redesign actually consumes).
        statics = [f for f in _scheme_statics(cls)
                   if f not in ("name", "redesign_fn", "redesign_cohort_fn")]
        s0 = {f: getattr(schemes[0], f) for f in statics}
        homogeneous = all(
            all(getattr(pc, f) == s0[f] for f in statics)
            for pc in schemes[1:])
    if homogeneous:
        kw = dict(s0, name="+".join(names))
        fields = tuple(f.name for f in dataclasses.fields(cls))
        for hook in ("redesign_fn", "redesign_cohort_fn"):
            if hook in fields:
                kw[hook] = getattr(schemes[0], hook)
        for f in _SCHEME_LEAVES[cls]:
            vals = [getattr(pc, f) for pc in schemes]
            if all(v is None for v in vals):
                kw[f] = None
            elif any(v is None for v in vals):
                raise ValueError(f"inconsistent leaf {f!r} across schemes")
            else:
                kw[f] = np.stack([np.asarray(v, np.float64) for v in vals])
        stacked = cls(**kw)
        stacked.names = names
        return stacked

    unsupported = sorted({type(pc).__name__ for pc in schemes
                          if type(pc) not in _UNION_KIND_OF})
    if unsupported:
        raise ValueError(
            f"schemes of type {unsupported} cannot join a heterogeneous "
            f"SchemeBatch union (AdaptiveSCA re-designs between chunks and "
            f"must be stacked with same-class schemes only)")
    # only schemes that have the flag vote: truncated-inversion/ideal rows
    # are dropout-agnostic (h=0 -> chi=0 / uniform average regardless)
    dropout = {bool(pc.dropout_aware) for pc in schemes
               if hasattr(pc, "dropout_aware")} or {False}
    if len(dropout) > 1:
        raise ValueError("cannot stack schemes with mixed dropout_aware")
    grid = {int(getattr(pc, "grid_size", 128)) for pc in schemes}
    if len(grid) > 1:
        raise ValueError("cannot stack OPC schemes with mixed grid_size")
    rows = [_union_row(pc, n) for pc in schemes]
    stacked = {f: np.stack([r[f] for r in rows]) for f in rows[0]}
    return SchemeBatch(names=names, num_devices=n, grid_size=grid.pop(),
                       dropout_aware=dropout.pop(), **stacked)


def tile_over_seeds(stacked, s_axis: int):
    """Tile a stacked fleet's design leaves over a seed axis: [K, ...] ->
    [K, S, ...].

    Gives every (scheme, seed) cell its own copy of the design state.
    Adaptive schemes need this so each cell can track its own channel
    trajectory (the re-design between scan chunks is per cell); sharded
    placements (fl.placement.ShardedPlacement) need it so EVERY scheme leaf
    carries the grid axes and can be flattened to the [K*S] cell axis that
    shards over the mesh.  Leaves come back as numpy (host-resident design
    state, like ``stack_schemes``); static aux (name, redesign_fn, ...) is
    preserved through the pytree treedef.
    """
    return jax.tree.map(
        lambda a: np.repeat(np.asarray(a)[:, None], s_axis, axis=1),
        stacked)


def round_coeffs_fleet(stacked, h: jnp.ndarray, keys: jax.Array):
    """Vmapped coefficients for a stacked fleet.

    h: [N] (shared channel draw) or [K, N] per-scheme; keys: [K, 2].
    Returns (s [K, N], noise_scale [K]).
    """
    in_h = 0 if jnp.ndim(h) == 2 else None
    return jax.vmap(lambda pc, hh, kk: pc.round_coeffs(hh, kk),
                    in_axes=(0, in_h, 0))(stacked, h, keys)
