"""OTA aggregation operators (paper eqs. (3)-(6)) in JAX.

Two equivalent implementations (tested against each other):

1. ``ota_aggregate`` — stacked form: per-client gradients live on one host
   with a leading client axis [N, ...].  Used by the FL simulator (the
   paper-scale N=10 experiments) and as the reference semantics.

2. ``ota_aggregate_shmap`` — shard_map collective: each client owns its
   gradient shard along a mesh axis; the psum over the client axes IS the
   wireless superposition (DESIGN.md §3).  Used by the production
   train_step.

Both consume the per-round coefficients (s, noise_scale) produced by a
PowerControl scheme, so every baseline (vanilla/OPC/BB-FL/...) rides the
same operators.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp

PyTree = Any

# wire dtypes a device may transmit on the uplink (kernels.ops owns the
# quantization contract; re-exported here because core/ota is the layer
# callers configure)
UPLINK_DTYPES = ("f32", "bf16", "int8")


def draw_fading(key: jax.Array, gains: jax.Array) -> jax.Array:
    """h_m ~ CN(0, Lambda_m): complex [N]."""
    kr, ki = jax.random.split(key)
    scale = jnp.sqrt(gains / 2.0)
    re = jax.random.normal(kr, gains.shape) * scale
    im = jax.random.normal(ki, gains.shape) * scale
    return jax.lax.complex(re, im)


def draw_fading_rician(key: jax.Array, gains: jax.Array,
                       k_factor: jax.Array) -> jax.Array:
    """Rician: deterministic LOS sqrt(K L/(K+1)) + diffuse CN(0, L/(K+1)).

    ``k_factor`` is the per-device K (linear), broadcast against gains;
    E|h|^2 = Lambda exactly.  Jit-friendly: params are plain arrays (the
    scenario layer converts a channel.FadingSpec into them).
    """
    los = jnp.sqrt(gains * k_factor / (k_factor + 1.0))
    diffuse = draw_fading(key, gains / (k_factor + 1.0))
    return jax.lax.complex(los + diffuse.real, diffuse.imag)


def draw_fading_nakagami(key: jax.Array, gains: jax.Array,
                         m: jax.Array) -> jax.Array:
    """Nakagami-m: |h|^2 ~ Gamma(m, Lambda/m), uniform phase; E|h|^2 = Lambda."""
    kp, kph = jax.random.split(key)
    power = jax.random.gamma(kp, m, shape=gains.shape) * gains / m
    mag = jnp.sqrt(power)
    phase = jax.random.uniform(kph, gains.shape, minval=0.0,
                               maxval=2.0 * jnp.pi)
    return jax.lax.complex(mag * jnp.cos(phase), mag * jnp.sin(phase))


def add_receiver_noise(tree: PyTree, noise_scale, key: jax.Array) -> PyTree:
    """g + noise_scale * z per component (z ~ N(0, I))."""
    leaves, treedef = jax.tree.flatten(tree)
    keys = jax.random.split(key, len(leaves))
    out = [l + (noise_scale * jax.random.normal(k, l.shape)).astype(l.dtype)
           for l, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, out)


def weighted_sum(stacked: PyTree, s: jax.Array) -> PyTree:
    """sum_m s_m * g_m over the leading client axis of every leaf.

    Accumulates in float32 and casts on write (matching the Pallas kernel's
    semantics): casting ``s`` to a low-precision leaf dtype before the
    reduction would throw away coefficient precision — the coefficients span
    many orders of magnitude across a heterogeneous deployment while bf16
    has an 8-bit mantissa.
    """
    def one(leaf):
        w = s.astype(jnp.float32).reshape((-1,) + (1,) * (leaf.ndim - 1))
        acc = jnp.sum(w * leaf.astype(jnp.float32), axis=0)
        return acc.astype(leaf.dtype)
    return jax.tree.map(one, stacked)


def split_ota_key(key: jax.Array):
    """The canonical (k_coeff, k_noise) split every aggregation path uses.

    Exposed so callers that need the coefficients outside the aggregation
    (round metrics, the engine's traces) can derive them from the *same*
    key the aggregation consumes — computing them from a different split
    would silently disagree with the applied coefficients for schemes whose
    ``round_coeffs`` is randomized (bbfl_alternative).
    """
    return jax.random.split(key)


def apply_round_coeffs(stacked_grads: PyTree, s: jax.Array, noise_scale,
                       k_noise: jax.Array, flat: bool = False,
                       uplink_dtype: str = "f32") -> PyTree:
    """Aggregate with precomputed per-round coefficients.

    flat=False: the per-leaf tree-map path (reference oracle).
    flat=True:  ravel the pytree once and run one fused flattened
                aggregation (kernels.ops.ota_aggregate_pytree — the Pallas
                kernel on TPU, the flattened jnp oracle on CPU) with f32
                accumulation and a single fused noise draw whose per-leaf
                keying reproduces the tree path's realizations.  ~1e-7
                relative fp difference from the oracle (fusion/FMA
                ordering), tested in tests/test_engine.py.

    ``uplink_dtype`` (flat only): devices transmit f32/bf16/int8 symbols
    (kernels.ops.quantize_uplink); the receiver dequantizes and
    f32-accumulates.  "f32" is bitwise today's path.
    """
    if flat:
        from repro.kernels import ops as kops
        return kops.ota_aggregate_pytree(stacked_grads, s, noise_scale,
                                         k_noise, uplink_dtype=uplink_dtype)
    if uplink_dtype != "f32":
        raise ValueError("quantized uplink requires the flat aggregation "
                         f"path (flat=True), got uplink_dtype={uplink_dtype!r}")
    agg = weighted_sum(stacked_grads, s)
    return add_receiver_noise(agg, noise_scale, k_noise)


def fused_round_step(stacked_grads: PyTree, s: jax.Array, noise_scale,
                     k_noise: jax.Array, params: PyTree, eta,
                     uplink_dtype: str = "f32") -> PyTree:
    """The whole flat-path round tail — quantized uplink, superposition,
    receiver noise, SGD step — as one fused launch; returns updated params
    (kernels.ops.ota_round_step_pytree: Pallas kernel on TPU, flattened
    jnp oracle on CPU).  With ``uplink_dtype="f32"`` this is bitwise the
    two-step ``apply_round_coeffs(flat=True)`` + tree-map SGD update."""
    from repro.kernels import ops as kops
    return kops.ota_round_step_pytree(stacked_grads, s, noise_scale,
                                      k_noise, params, eta,
                                      uplink_dtype=uplink_dtype)


def ota_aggregate(stacked_grads: PyTree, scheme, h: jax.Array,
                  key: jax.Array, flat: bool = False) -> PyTree:
    """Full OTA round on stacked per-client grads [N, ...].

    h: complex fading [N] (the devices' local instantaneous CSI);
    scheme: a PowerControl; key: receiver-noise randomness.
    """
    k_coeff, k_noise = split_ota_key(key)
    s, noise_scale = scheme.round_coeffs(h, k_coeff)
    return apply_round_coeffs(stacked_grads, s, noise_scale, k_noise,
                              flat=flat)


# ---------------------------------------------------------------------------
# shard_map collective form
# ---------------------------------------------------------------------------

def client_index(axis_names: Sequence[str]) -> jax.Array:
    """Flat client id across the given mesh axes (row-major)."""
    idx = jnp.zeros((), jnp.int32)
    for name in axis_names:
        idx = idx * jax.lax.axis_size(name) + jax.lax.axis_index(name)
    return idx


def ota_aggregate_shmap(local_grad: PyTree, s_all: jax.Array, noise_scale,
                        key: jax.Array, axis_names: Sequence[str]) -> PyTree:
    """Inside shard_map: each client scales its local gradient by its own
    coefficient, the psum superposes (the MAC), noise is added identically
    everywhere (same key => same z, exactly one PS noise draw).
    """
    me = client_index(axis_names)
    s_me = s_all[me]
    scaled = jax.tree.map(lambda g: g * s_me.astype(g.dtype), local_grad)
    summed = jax.tree.map(lambda g: jax.lax.psum(g, tuple(axis_names)),
                          scaled)
    return add_receiver_noise(summed, noise_scale, key)


# ---------------------------------------------------------------------------
# Weighted-loss helpers (pjit-native formulation; see DESIGN.md §3)
# ---------------------------------------------------------------------------

def per_client_loss_weights(s: jax.Array) -> jax.Array:
    """Weights w_m = N * s_m so that mean_m(w_m * f_m) = sum_m s_m f_m.

    Under data-parallel autodiff the gradient of the mean per-client loss is
    (1/N) sum_m grad f_m; scaling client m's loss by N*s_m makes the native
    all-reduce compute sum_m s_m grad f_m — the OTA superposition — with no
    extra collective.
    """
    n = s.shape[0]
    return n * s
