"""JAX-native batched SCA solver subsystem (DESIGN.md §Solvers).

``theory_jax``  jnp port of the Theorem-1 statistical-CSI quantities
                (all fading families), jit/vmap/grad-ready.
``sca_jax``     the compiled SCA solver: ``solve`` (single scenario,
                drop-in for ``core.sca.solve_sca``) and ``solve_batch``
                (one compiled program over a stacked scenario batch).

``core/sca.py`` (scipy SLSQP) remains the reference oracle.
"""
from repro.solvers.sca_jax import (BatchResult, DEFAULT_CONFIG, SolverConfig,
                                   set_trace_hook, solve, solve_batch,
                                   solve_batch_device)
from repro.solvers.theory_jax import SolverParams, from_ota, stack_params

__all__ = [
    "BatchResult", "DEFAULT_CONFIG", "SolverConfig", "SolverParams",
    "from_ota", "set_trace_hook", "solve", "solve_batch",
    "solve_batch_device", "stack_params",
]
