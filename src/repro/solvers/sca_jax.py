"""JAX-native batched SCA solver for the (P1) power-control design.

Same algorithm as ``core/sca.py`` (paper §III-B), re-expressed so the whole
solve — outer SCA loop, convex inner subproblems, monotone-descent
backtracking — is ONE jit-compiled program that ``vmap``s over a scenario
batch (``solve_batch``).  The scipy SLSQP path stays as the reference
oracle; this path is the default design engine (``power_control.make_sca``)
and the only one fast enough to re-design powers *during* training
(``AdaptiveSCA``).

Structure (DESIGN.md §Solvers):

* Scaled variables, identical to ``core/sca.py``: gamma_hat = gamma /
  gamma_max in (0, 1], p on the simplex, alpha_hat = alpha / sum(alpha_max)
  — every decision variable O(1) despite physical scales ~1e-9.
* Inner solver: each SCA iteration minimizes the convex surrogate (11a-11e)
  (epigraph variable eliminated via tight (11b), exactly like the scipy
  path) with a projected-gradient method: constraints (11c)/(11d) enter as
  smooth quadratic penalties on an escalating schedule, the simplex /
  box constraints by exact projection (sort-based simplex projection), and
  every step is Armijo-backtracked — a fixed iteration budget so the loop
  is a ``lax.scan``.
* Monotone descent is preserved *outside* the inner solver, as in scipy:
  after each subproblem the exact coupling (p, alpha from gamma) is
  restored and the candidate is backtracked toward the anchor on the TRUE
  objective; a step is only taken if it strictly improves.
* A final polish stage descends the true objective itself (smooth in
  gamma_hat over the box, with (p, alpha) restored by exact coupling): an
  adaptive best-iterate-tracked stage rides the ill-conditioned tail, an
  Armijo stage finishes.  Both return iterates no worse than their input,
  so monotonicity survives and ``solve_batch`` tracks the SLSQP oracle to
  ~1e-6 relative on the reference cases (asserted in tests and
  benchmarks/sca_bench.py).

Everything runs under ``jax.experimental.enable_x64``: the *scaled*
variables are O(1) but intermediate quantities (alpha ~ 1e-8, alpha^2 in
the noise term) need f64 headroom.  The x64 scope is entered per public
call and never leaks into the (f32) training path.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64

from repro.core.sca import SCAResult
from repro.core.theory import OTAParams
from repro.solvers import theory_jax as tj
from repro.solvers.theory_jax import SolverParams

_EPS = 1e-12


@dataclasses.dataclass(frozen=True)
class SolverConfig:
    """Fixed iteration budgets (static jit config; hashable)."""
    max_iters: int = 16           # outer SCA iterations
    inner_iters: int = 100        # projected-gradient steps per penalty stage
    inner_lr: float = 0.03        # inner per-coordinate adaptive step size
    penalties: tuple = (1e2, 1e4, 1e6)   # (11c)/(11d) penalty schedule
    backtracks: int = 12          # true-objective backtracking halvings
    armijo_halvings: int = 20     # polish line-search halvings
    polish_adam_iters: int = 400  # adaptive polish steps (best-iterate kept)
    polish_adam_lr: float = 0.01
    polish_iters: int = 120       # Armijo polish steps (finisher)
    tol: float = 1e-6             # convergence tolerance (reported only)


DEFAULT_CONFIG = SolverConfig()


@dataclasses.dataclass
class BatchResult:
    """``solve_batch`` output: leading [B] axis on every field (numpy)."""
    gamma: np.ndarray        # [B, N] physical pre-scalers
    p: np.ndarray            # [B, N] participation levels
    alpha: np.ndarray        # [B] post-scalers
    objective: np.ndarray    # [B] true (P1) objectives
    history: np.ndarray      # [B, max_iters + 2]: start, outer iterates,
    #                          post-polish objective (monotone)
    converged: np.ndarray    # [B] bool: the outer SCA loop plateaued


# ---------------------------------------------------------------------------
# projections
# ---------------------------------------------------------------------------

def project_simplex(v: jnp.ndarray) -> jnp.ndarray:
    """Euclidean projection onto the probability simplex (sort-based)."""
    n = v.shape[-1]
    u = jnp.sort(v)[..., ::-1]
    css = jnp.cumsum(u, axis=-1) - 1.0
    idx = jnp.arange(1, n + 1, dtype=v.dtype)
    cond = u - css / idx > 0
    rho = jnp.sum(cond, axis=-1)
    theta = jnp.take_along_axis(css, rho[..., None] - 1, axis=-1)[..., 0] \
        / rho.astype(v.dtype)
    return jnp.maximum(v - theta[..., None], 0.0)


def _project(x, n):
    gh = jnp.clip(x[:n], 1e-6, 1.0)
    p = jnp.maximum(project_simplex(x[n:2 * n]), _EPS)
    ah = jnp.clip(x[2 * n:], 1e-6, 2.0)
    return jnp.concatenate([gh, p, ah])


# ---------------------------------------------------------------------------
# the convex surrogate (11) around an anchor, penalized form
# ---------------------------------------------------------------------------

def _surrogate_fn(prm: SolverParams, gmax_arr, amax_arr, a0,
                  anchor_gh, anchor_p, anchor_ah, mu):
    """Penalized surrogate phi(x) for x = [gh(N), p(N), ah(1)] (scaled)."""
    n = gmax_arr.shape[0]
    eta_l = prm.eta * prm.lsmooth
    g2 = prm.gmax**2
    g_bar = anchor_gh * gmax_arr
    a_bar = anchor_ah * a0
    p_bar = jnp.maximum(anchor_p, 1e-9)

    def phi(x):
        gh = jnp.maximum(x[:n], _EPS)
        p = jnp.maximum(x[n:2 * n], _EPS)
        ah = jnp.maximum(x[2 * n], _EPS)
        gamma = gh * gmax_arr
        alpha = ah * a0
        # z_m eliminated via tight (11b)
        logz = (jnp.log(g_bar * p_bar) + gamma / g_bar + p / p_bar - 2.0
                - jnp.log(alpha))
        z = jnp.exp(logz)
        lin_p2 = p_bar * (2.0 * p - p_bar)
        obj = eta_l * (g2 * jnp.sum(z) + prm.d * prm.n0 / alpha**2
                       + jnp.sum(p**2 * prm.sigma_sq)
                       - g2 * jnp.sum(lin_p2))
        obj += n * prm.kappa_sq * jnp.sum((p - 1.0 / n) ** 2)
        # (11c): ln alpha_m(gamma) >= linearized ln(alpha p_m)
        c11c = tj.log_alpha_of_gamma(gamma, prm) \
            - (jnp.log(a_bar * p_bar) + alpha / a_bar + p / p_bar - 2.0)
        # (11d): concave 1/alpha bound, alpha-scaled to O(1)
        c11d = a0 * ((2.0 * a_bar - alpha) / a_bar**2 - p / amax_arr)
        pen = jnp.sum(jnp.minimum(c11c, 0.0) ** 2) \
            + jnp.sum(jnp.minimum(c11d, 0.0) ** 2)
        return obj + mu * pen

    return phi


def _inner_pgd(phi, x0, n, num_iters: int, lr: float):
    """Projected per-coordinate-adaptive gradient descent on the penalized
    surrogate (Adam-style moments + exact simplex/box projection).

    The penalty valley is stiff — plain Armijo gradient steps stall at the
    anchor — so the inner solver uses adaptive per-coordinate scaling and a
    fixed budget instead of a line search.  It need not be monotone: SCA
    descent is enforced OUTSIDE, by the true-objective backtracking that
    only accepts improving candidates (exactly the scipy path's safeguard).
    """
    grad = jax.grad(phi)
    b1, b2 = 0.9, 0.999

    def step(carry, _):
        x, m, v, t = carry
        g = grad(x)
        m = b1 * m + (1.0 - b1) * g
        v = b2 * v + (1.0 - b2) * g * g
        t = t + 1
        mh = m / (1.0 - b1**t)
        vh = v / (1.0 - b2**t)
        x = _project(x - lr * mh / (jnp.sqrt(vh) + 1e-12), n)
        return (x, m, v, t), None

    zero = jnp.zeros_like(x0)
    (x, _, _, _), _ = jax.lax.scan(
        step, (x0, zero, zero, jnp.asarray(0, jnp.int32)), None,
        length=num_iters)
    return x


# ---------------------------------------------------------------------------
# the solve: SCA outer loop + polish, all inside one jit
# ---------------------------------------------------------------------------

def _true_objective(gh, prm: SolverParams, gmax_arr):
    return tj.p1_objective(jnp.maximum(gh, 1e-6) * gmax_arr, prm)


def _solve_one(prm: SolverParams, gamma0: Optional[jnp.ndarray],
               cfg: SolverConfig):
    n = prm.gains.shape[0]
    gmax_arr = tj.gamma_max(prm)
    amax_arr = tj.alpha_max(prm)
    a0 = jnp.sum(amax_arr)

    gh0 = jnp.ones(n, gmax_arr.dtype) if gamma0 is None \
        else jnp.asarray(gamma0) / gmax_arr
    true_obj = lambda gh: _true_objective(gh, prm, gmax_arr)

    def coupled(gh):
        _, a, pm = tj.participation(gh * gmax_arr, prm)
        return pm, a / a0

    def outer(carry, _):
        gh, pm, ah, obj = carry
        x = jnp.concatenate([gh, pm, ah[None]])
        for mu in cfg.penalties:
            phi = _surrogate_fn(prm, gmax_arr, amax_arr, a0, gh, pm, ah,
                                jnp.asarray(mu, x.dtype))
            x = _inner_pgd(phi, x, n, cfg.inner_iters, cfg.inner_lr)
        cand = jnp.clip(x[:n], 1e-6, 1.0)
        # true-objective backtracking toward the anchor: accept the first
        # (largest) theta that strictly improves, else stay (scipy logic).
        thetas = 0.5 ** jnp.arange(cfg.backtracks, dtype=gh.dtype)
        trials = thetas[:, None] * cand[None, :] \
            + (1.0 - thetas[:, None]) * gh[None, :]
        objs = jax.vmap(true_obj)(trials)
        improves = objs < obj
        any_imp = jnp.any(improves)
        first = jnp.argmax(improves)          # first True = largest theta
        gh_next = jnp.where(any_imp, trials[first], gh)
        obj_next = jnp.where(any_imp, objs[first], obj)
        pm_next, ah_next = coupled(gh_next)
        return (gh_next, pm_next, ah_next, obj_next), obj_next

    pm0, ah0 = coupled(gh0)
    obj0 = true_obj(gh0)
    (gh, pm, ah, obj), hist = jax.lax.scan(
        outer, (gh0, pm0, ah0, obj0), None, length=cfg.max_iters)

    # polish on the true objective: a best-iterate-tracked adaptive stage
    # rides down the ill-conditioned tail, an Armijo stage finishes.  Both
    # only ever return iterates at least as good as their input, so the
    # overall descent stays monotone.
    if cfg.polish_adam_iters > 0:
        gh = _polish_adam(true_obj, gh, cfg.polish_adam_iters,
                          cfg.polish_adam_lr)
    if cfg.polish_iters > 0:
        gh = _polish(true_obj, gh, cfg.polish_iters, cfg.armijo_halvings)
    obj = true_obj(gh)
    pm, ah = coupled(gh)

    # history = [start, outer iterates..., post-polish objective]; converged
    # reports the OUTER loop's plateau (the polish may still refine the
    # returned objective — its result is history's last entry).
    history = jnp.concatenate([obj0[None], hist, obj[None]])
    converged = jnp.abs(hist[-1] - hist[-2]) \
        <= cfg.tol * jnp.maximum(1.0, jnp.abs(hist[-1]))
    gamma = gh * gmax_arr
    return dict(gamma=gamma, p=pm, alpha=ah * a0, objective=obj,
                history=history, converged=converged)


def _polish_adam(true_obj, gh0, num_iters: int, lr: float):
    """Box-projected adaptive descent on the true objective, returning the
    best iterate seen (never worse than gh0)."""
    grad = jax.grad(true_obj)
    b1, b2 = 0.9, 0.999

    def step(carry, _):
        x, m, v, t, best_x, best_f = carry
        g = grad(x)
        m = b1 * m + (1.0 - b1) * g
        v = b2 * v + (1.0 - b2) * g * g
        t = t + 1
        x = jnp.clip(
            x - lr * (m / (1.0 - b1**t))
            / (jnp.sqrt(v / (1.0 - b2**t)) + 1e-12), 1e-6, 1.0)
        fx = true_obj(x)
        better = fx < best_f
        best_x = jnp.where(better, x, best_x)
        best_f = jnp.where(better, fx, best_f)
        return (x, m, v, t, best_x, best_f), None

    zero = jnp.zeros_like(gh0)
    (_, _, _, _, best_x, _), _ = jax.lax.scan(
        step, (gh0, zero, zero, jnp.asarray(0, jnp.int32), gh0,
               true_obj(gh0)), None, length=num_iters)
    return best_x


def _polish(true_obj, gh0, num_iters: int, halvings: int):
    """Box-projected Armijo gradient descent on the true objective."""
    grad = jax.grad(true_obj)

    def step(carry, _):
        gh, t = carry
        g = grad(gh)
        f0 = true_obj(gh)

        def try_step(tt):
            xn = jnp.clip(gh - tt * g, 1e-6, 1.0)
            return xn, true_obj(xn)

        def cond(state):
            tt, _, fn, k = state
            return jnp.logical_and(fn > f0 - 1e-4 * tt * jnp.sum(g * g),
                                   k < halvings)

        def body(state):
            tt, _, _, k = state
            tt = 0.5 * tt
            xn, fn = try_step(tt)
            return tt, xn, fn, k + 1

        x1, f1 = try_step(t)
        t_fin, x_fin, f_fin, _ = jax.lax.while_loop(
            cond, body, (t, x1, f1, 0))
        ok = f_fin < f0
        gh_next = jnp.where(ok, x_fin, gh)
        t_next = jnp.maximum(
            jnp.where(ok, jnp.minimum(t_fin * 2.0, 1.0), 0.25 * t), 1e-12)
        return (gh_next, t_next), None

    (gh, _), _ = jax.lax.scan(step, (gh0, jnp.asarray(0.1, gh0.dtype)),
                              None, length=num_iters)
    return gh


@functools.partial(jax.jit, static_argnames=("cfg", "with_gamma0"))
def _solve_single_jit(prm, gamma0, cfg, with_gamma0):
    return _solve_one(prm, gamma0 if with_gamma0 else None, cfg)


@functools.partial(jax.jit, static_argnames=("cfg",))
def _solve_batch_jit(prm_b, cfg):
    return jax.vmap(lambda p: _solve_one(p, None, cfg))(prm_b)


@functools.lru_cache(maxsize=None)
def _placed_batch_solver(placement, cfg):
    """Compiled batch solve on a placement, cached per (placement, cfg) so
    repeated placed solves reuse the jit trace exactly like the default
    ``_solve_batch_jit`` path (a fresh closure per call would retrace —
    and recompile the whole SSCA scan — every time)."""
    return placement.compile_batch(lambda p: _solve_one(p, None, cfg))


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------

def solve(prm: OTAParams, gamma0: Optional[np.ndarray] = None,
          cfg: SolverConfig = DEFAULT_CONFIG) -> SCAResult:
    """Single-scenario compiled SCA solve; drop-in for ``sca.solve_sca``.

    Returns the same ``SCAResult`` (numpy, physical units); ``iterations``
    reports the fixed outer budget (the loop is compiled, not early-exited).
    """
    with enable_x64():
        pj = tj.from_ota(prm)
        g0 = None if gamma0 is None else jnp.asarray(gamma0, jnp.float64)
        out = _solve_single_jit(pj, g0, cfg, gamma0 is not None)
        out = {k: np.asarray(v) for k, v in out.items()}
    return SCAResult(gamma=out["gamma"], p=out["p"],
                     alpha=float(out["alpha"]),
                     objective=float(out["objective"]),
                     history=[float(h) for h in out["history"]],
                     converged=bool(out["converged"]),
                     iterations=cfg.max_iters)


def _as_f64(pj: SolverParams) -> SolverParams:
    """Recast every leaf to f64 (must run inside an x64 scope).  Guards the
    pre-stacked path: ``stack_params`` called OUTSIDE an x64 scope silently
    builds f32 leaves, which would crash the scan carry dtype check."""
    return jax.tree.map(lambda a: jnp.asarray(a, jnp.float64), pj)


def solve_batch(prms, cfg: SolverConfig = DEFAULT_CONFIG,
                placement=None) -> BatchResult:
    """Design powers for a batch of scenarios in ONE compiled program.

    ``prms``: a sequence of ``OTAParams`` (stacked here), or an already
    stacked ``SolverParams`` with a leading [B] batch axis (e.g. from
    ``theory_jax.stack_params`` or built on device by ``AdaptiveSCA``).
    All rows share the fading family and device count; gains / noise /
    dropout / family parameters / objective weights vary per row.

    ``placement``: optional ``fl.placement`` object mapping the batch axis
    onto hardware — ``ShardedPlacement(mesh)`` shards a thousand-scenario
    design batch over the ``("data", "model")`` mesh exactly like the
    fleet grid shards (rows are independent; the shard_map is psum-free,
    with the same pad-with-row-0 rule when B doesn't divide the device
    count).  ``None`` (default) keeps the single-device vmap program.
    """
    with enable_x64():
        pj = _as_f64(prms if isinstance(prms, SolverParams) else stack(prms))
        if placement is None:
            out = _solve_batch_jit(pj, cfg)
        else:
            out = _placed_batch_solver(placement, cfg)(pj)
        out = {k: np.asarray(v) for k, v in out.items()}
    return BatchResult(gamma=out["gamma"], p=out["p"], alpha=out["alpha"],
                       objective=out["objective"], history=out["history"],
                       converged=out["converged"])


def stack(prms: Sequence[OTAParams]) -> SolverParams:
    return tj.stack_params(prms)


# Per-solve telemetry hook (DESIGN.md §Telemetry).  The driver installs
# one around telemetry-enabled runs; unset (the default) the solve path
# is untouched — no timing calls, no host syncs.
_TRACE_HOOK: Optional[Callable] = None


def set_trace_hook(hook: Optional[Callable]) -> Optional[Callable]:
    """Install ``hook(record: dict)`` called once per device-resident
    batched SCA solve with {batch, iters, objective_mean, converged, dur}.
    Returns the previous hook so callers can restore it (try/finally).
    The hook host-syncs the solve outputs to report the objective, so it
    belongs in observability paths only."""
    global _TRACE_HOOK
    prev = _TRACE_HOOK
    _TRACE_HOOK = hook
    return prev


def solve_batch_device(prm_b: SolverParams,
                       cfg: SolverConfig = DEFAULT_CONFIG) -> dict:
    """Device-resident batch solve: jnp in, jnp out (no host round-trip).

    Used by the in-training re-design path (``AdaptiveSCA``), where the
    batch of scenarios is derived from the live fading state.  Caller is
    responsible for the x64 scope semantics: this enters it too, so the
    returned arrays are f64.
    """
    with enable_x64():
        hook = _TRACE_HOOK
        t0 = time.monotonic() if hook is not None else 0.0
        out = _solve_batch_jit(_as_f64(prm_b), cfg)
        if hook is not None:
            obj = np.asarray(out["objective"])
            conv = np.asarray(out["converged"])
            hook({"batch": int(obj.shape[0]) if obj.ndim else 1,
                  "iters": int(cfg.max_iters),
                  "objective_mean": float(np.mean(obj)),
                  "converged": int(np.sum(conv)),
                  "dur": round(time.monotonic() - t0, 6)})
        return out
