"""jnp port of the Theorem-1 quantities (DESIGN.md §Solvers).

``core/theory.py`` is float64 numpy/scipy — exact, host-bound, one scenario
at a time.  This module re-expresses the same maps as pure ``jax.numpy`` on
a pytree parameter container (``SolverParams``) so they jit, vmap over
scenario batches, and differentiate — the substrate of the batched SCA
solver (``repro.solvers.sca_jax``) and of in-training power re-design
(``power_control.AdaptiveSCA``).

Numerical contract (tests/test_solvers.py): with x64 enabled, every function
here agrees with its ``core/theory.py`` counterpart to <= 1e-6 relative
across all three fading families and random ``OTAParams``.  The only
implementation divergence is the Rician magnitude survival function: scipy
evaluates Marcum Q_1 through the non-central chi-square CDF, while here it
is the canonical Poisson-mixture series

    Q_1(a, b) = sum_k e^{-a^2/2} (a^2/2)^k / k! * Q(k+1, b^2/2)

with Q the regularized upper incomplete gamma (jax.scipy.special.gammaincc)
and a fixed term count — exact to ~1e-12 for the K-factors the scenario
engine uses (the Poisson(a^2/2 = K) tail at ``_MARCUM_TERMS`` is
negligible for K <~ 40).

All functions follow input dtype; the public solver entry points run them
under ``jax.experimental.enable_x64`` because the physical scales
(gains ~1e-9..1e-13, N0 ~1e-21) need f64 headroom even though the *scaled*
SCA variables are O(1).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.scipy import special as jsp

from repro.core.theory import (GAMMA_MAX_GRID_COARSE, GAMMA_MAX_GRID_FINE,
                               OTAParams)

# Terms in the Marcum-Q_1 Poisson-mixture series (Rician SF).  The k-th
# weight is Poisson(K)(k), so 96 terms cover K-factors to ~40 at f64.
_MARCUM_TERMS = 96


# ---------------------------------------------------------------------------
# Parameter container: one pytree, vmappable over a leading scenario batch.
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class SolverParams:
    """Array view of ``theory.OTAParams`` (+ fading family parameters).

    Every numeric field is a pytree leaf, so ``jax.vmap`` over a stacked
    instance (``stack_params``) batches whole scenarios; ``family`` is
    static aux data, so one compiled solve serves any batch of scenarios
    that share a fading family (the batch layout of DESIGN.md §Solvers).

    ``fading_param`` holds the per-device family parameter ([N]): the
    Rician K-factor or Nakagami m; ones (unused) for Rayleigh.
    """
    d: jnp.ndarray              # scalar (f64 under the solver's x64 scope)
    gmax: jnp.ndarray           # scalar
    es: jnp.ndarray             # scalar
    n0: jnp.ndarray             # scalar
    gains: jnp.ndarray          # [N]
    sigma_sq: jnp.ndarray       # [N]
    eta: jnp.ndarray            # scalar
    lsmooth: jnp.ndarray        # scalar
    kappa_sq: jnp.ndarray       # scalar
    dropout: jnp.ndarray        # scalar
    fading_param: jnp.ndarray   # [N]
    family: str = "rayleigh"

    _LEAVES = ("d", "gmax", "es", "n0", "gains", "sigma_sq", "eta",
               "lsmooth", "kappa_sq", "dropout", "fading_param")

    def tree_flatten(self):
        return tuple(getattr(self, f) for f in self._LEAVES), self.family

    @classmethod
    def tree_unflatten(cls, family, leaves):
        return cls(*leaves, family=family)

    @property
    def num_devices(self) -> int:
        return int(self.gains.shape[-1])

    @property
    def is_rayleigh(self) -> bool:
        return self.family == "rayleigh"


def from_ota(p: OTAParams) -> SolverParams:
    """Lift a (numpy) ``OTAParams`` into the jnp parameter pytree."""
    n = p.num_devices
    family = "rayleigh" if p.is_rayleigh else p.fading.family
    if family == "rician":
        fparam = np.broadcast_to(
            np.asarray(p.fading.rician_k, np.float64), (n,))
    elif family == "nakagami":
        fparam = np.broadcast_to(
            np.asarray(p.fading.nakagami_m, np.float64), (n,))
    else:
        fparam = np.ones(n)
    as_a = lambda v: jnp.asarray(v, jnp.float64)
    return SolverParams(
        d=as_a(p.d), gmax=as_a(p.gmax), es=as_a(p.es), n0=as_a(p.n0),
        gains=as_a(p.gains), sigma_sq=as_a(p.sigma_sq), eta=as_a(p.eta),
        lsmooth=as_a(p.lsmooth), kappa_sq=as_a(p.kappa_sq),
        dropout=as_a(p.dropout), fading_param=as_a(np.asarray(fparam)),
        family=family)


def stack_params(prms: Sequence[OTAParams]) -> SolverParams:
    """Stack scenarios into one SolverParams with a leading [B] batch axis.

    All scenarios must share the fading family and device count (the static
    parts of the pytree); everything else — gains, noise, dropout, Rician K,
    weights — varies per batch row.  ``solve_batch`` vmaps over the result.
    """
    ps = [from_ota(p) for p in prms]
    if not ps:
        raise ValueError("stack_params needs at least one OTAParams")
    fam = {p.family for p in ps}
    if len(fam) > 1:
        raise ValueError(f"cannot stack mixed fading families {sorted(fam)}")
    return jax.tree.map(lambda *ls: jnp.stack(ls), *ps)


# ---------------------------------------------------------------------------
# Fading-family survival functions
# ---------------------------------------------------------------------------

def marcum_q1(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Marcum Q_1(a, b) by the Poisson-mixture series (see module doc)."""
    a, b = jnp.broadcast_arrays(a, b)
    lam = 0.5 * a**2                       # Poisson mean
    x = 0.5 * b**2
    k = jnp.arange(_MARCUM_TERMS, dtype=a.dtype)
    shape = (1,) * a.ndim + (_MARCUM_TERMS,)
    k = k.reshape(shape)
    logw = k * jnp.log(jnp.maximum(lam[..., None], 1e-300)) \
        - lam[..., None] - jsp.gammaln(k + 1.0)
    # lam == 0 (K = 0, pure Rayleigh limit): only the k = 0 term survives.
    w = jnp.where(lam[..., None] > 0, jnp.exp(logw),
                  jnp.where(k == 0, 1.0, 0.0))
    tails = jsp.gammaincc(k + 1.0, x[..., None])
    return jnp.clip(jnp.sum(w * tails, axis=-1), 0.0, 1.0)


def _rician_nu_sigma(gains, k):
    nu = jnp.sqrt(gains * k / (k + 1.0))
    sigma = jnp.sqrt(gains / (2.0 * (k + 1.0)))
    return nu, sigma


def magnitude_sf(gains: jnp.ndarray, x: jnp.ndarray, p: SolverParams
                 ) -> jnp.ndarray:
    """P(|h_m| >= x): jnp mirror of ``channel.fading_magnitude_sf``."""
    if p.family == "rician":
        k = jnp.broadcast_to(p.fading_param, jnp.shape(gains)) \
            if jnp.ndim(gains) <= 1 else p.fading_param[:, None]
        nu, sigma = _rician_nu_sigma(gains, k)
        return marcum_q1(nu / sigma, x / sigma)
    if p.family == "nakagami":
        m = jnp.broadcast_to(p.fading_param, jnp.shape(gains)) \
            if jnp.ndim(gains) <= 1 else p.fading_param[:, None]
        return jsp.gammaincc(m, m * x**2 / gains)
    return jnp.exp(-x**2 / gains)


# ---------------------------------------------------------------------------
# alpha_m(gamma) and its extremes — mirrors core/theory.py one-for-one
# ---------------------------------------------------------------------------

def trunc_exponent(gamma, p: SolverParams):
    return gamma**2 * p.gmax**2 / (p.d * p.gains * p.es)


def chi_threshold(gamma, p: SolverParams):
    return p.gmax * gamma / jnp.sqrt(p.d * p.es)


def expected_participation_indicator(gamma, p: SolverParams):
    if p.is_rayleigh:
        sf = jnp.exp(-trunc_exponent(gamma, p))
    else:
        sf = magnitude_sf(p.gains, chi_threshold(gamma, p), p)
    return (1.0 - p.dropout) * sf


def alpha_of_gamma(gamma, p: SolverParams):
    return gamma * expected_participation_indicator(gamma, p)


def log_alpha_of_gamma(gamma, p: SolverParams):
    """ln alpha_m(gamma); Rayleigh keeps the cancellation-free closed form
    used by the SCA constraint (11c)."""
    if p.is_rayleigh:
        return jnp.log(gamma) - trunc_exponent(gamma, p) \
            + jnp.log1p(-p.dropout)
    return jnp.log(jnp.maximum(alpha_of_gamma(gamma, p), 1e-300))


def _rayleigh_gamma_max(p: SolverParams):
    return jnp.sqrt(p.d * p.gains * p.es / (2.0 * p.gmax**2))


def gamma_max(p: SolverParams):
    """Per-device maximizer of alpha_m; same two-stage log grid as the
    numpy path (shared ``GAMMA_MAX_GRID_*`` constants) off-Rayleigh."""
    g_ray = _rayleigh_gamma_max(p)
    if p.is_rayleigh:
        return g_ray

    def argmax_on(grid):          # [N, G]
        vals = grid * magnitude_sf(p.gains[:, None],
                                   chi_threshold(grid, p), p)
        return jnp.take_along_axis(
            grid, jnp.argmax(vals, axis=1)[:, None], axis=1)[:, 0]

    lo, hi, num = GAMMA_MAX_GRID_COARSE
    coarse = argmax_on(g_ray[:, None]
                       * jnp.asarray(np.geomspace(lo, hi, num))[None, :])
    lo, hi, num = GAMMA_MAX_GRID_FINE
    return argmax_on(coarse[:, None]
                     * jnp.asarray(np.geomspace(lo, hi, num))[None, :])


def alpha_max(p: SolverParams):
    if p.is_rayleigh:
        amax = jnp.sqrt(p.d * p.gains * p.es / (2.0 * np.e * p.gmax**2))
        return (1.0 - p.dropout) * amax
    return alpha_of_gamma(gamma_max(p), p)


# ---------------------------------------------------------------------------
# Participation, variance, objective
# ---------------------------------------------------------------------------

def participation(gamma, p: SolverParams):
    am = alpha_of_gamma(gamma, p)
    a = jnp.sum(am)
    return am, a, am / a


def zeta_terms(gamma, p: SolverParams):
    _, a, pm = participation(gamma, p)
    tx = p.gmax**2 * jnp.sum(pm * gamma / a - pm**2)
    mb = jnp.sum(pm**2 * p.sigma_sq)
    nz = p.d * p.n0 / a**2
    return {"transmission": tx, "minibatch": mb, "noise": nz,
            "total": tx + mb + nz}


def bias_term(pm, p: SolverParams):
    n = pm.shape[-1]
    return 2.0 * n * p.kappa_sq * jnp.sum((pm - 1.0 / n) ** 2)


def p1_objective(gamma, p: SolverParams):
    z = zeta_terms(gamma, p)["total"]
    _, _, pm = participation(gamma, p)
    return 2.0 * p.eta * p.lsmooth * z + bias_term(pm, p)
