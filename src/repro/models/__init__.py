"""repro.models"""
