"""Decoder-only transformer assembler for all assigned families.

Layers are grouped by repeating block signature and executed with
jax.lax.scan over stacked parameters (+ per-layer remat), so the HLO stays
O(one pattern unit) even for 61-layer configs — essential for 80 AOT
compiles on one CPU core.

Supported mixers (cfg.block_pattern): attn | swa | local | rglru | ssd.
FFN per layer: dense MLP, MoE (after cfg.moe_first_dense), or none (mamba2).
Optional MTP (DeepSeek-V3 multi-token prediction) head at training time.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import distributed as dist
from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import rglru as rglru_mod
from repro.models import ssm as ssm_mod
from repro.models.config import ModelConfig
from repro.models.layers import (embed, embedding_def, mlp, mlp_def, rmsnorm,
                                 rmsnorm_def, unembed, unembed_def)
from repro.models.param import ParamDef, is_def

# ---------------------------------------------------------------------------
# Layer signatures and grouping
# ---------------------------------------------------------------------------


def _layer_sig(cfg: ModelConfig, idx: int, kinds) -> tuple:
    kind = kinds[idx]
    if kind == "ssd" and cfg.ffn_kind == "none":
        ffn = "none"
    elif cfg.layer_is_moe(idx):
        ffn = "moe"
    else:
        ffn = "dense"
    return (kind, ffn)


def layer_plan(cfg: ModelConfig, n_layers: Optional[int] = None):
    """Split layers into (lead_sigs, unit_sigs, n_rep, tail_sigs).

    lead = leading layers that do not fit the repeating unit (e.g. DeepSeek's
    dense-FFN head layers); unit repeats n_rep times; tail is the remainder.
    """
    n = n_layers if n_layers is not None else cfg.n_layers
    kinds = cfg.block_kinds(n)
    sigs = [_layer_sig(cfg, i, kinds) for i in range(n)]
    lead = cfg.moe_first_dense if cfg.moe_num_experts else 0
    lead = min(lead, n)
    unit_len = len(cfg.block_pattern)
    body = sigs[lead:]
    if not body:
        return sigs, [], 0, []
    unit = body[:unit_len]
    n_rep = 0
    pos = 0
    while pos + unit_len <= len(body) and body[pos:pos + unit_len] == unit:
        n_rep += 1
        pos += unit_len
    return sigs[:lead], unit, n_rep, body[pos:]


# ---------------------------------------------------------------------------
# Per-layer parameter defs
# ---------------------------------------------------------------------------

def _mixer_def(cfg: ModelConfig, kind: str, tp: int):
    if kind in ("attn", "swa", "local", "enc_attn"):
        if cfg.attn_kind == "mla":
            return attn_mod.mla_def(cfg, tp)
        return attn_mod.gqa_def(cfg, tp)
    if kind == "ssd":
        return ssm_mod.ssd_def(cfg, tp)
    if kind == "rglru":
        return rglru_mod.rglru_def(cfg, tp)
    raise ValueError(f"unknown mixer kind {kind!r}")


def layer_def(cfg: ModelConfig, sig: tuple, tp: int = 16, dp: int = 16,
              cross: bool = False):
    kind, ffn = sig
    d = {"ln1": rmsnorm_def(cfg.d_model, cfg.param_dtype),
         "mixer": _mixer_def(cfg, kind, tp)}
    if cross:
        d["ln_cross"] = rmsnorm_def(cfg.d_model, cfg.param_dtype)
        d["cross"] = attn_mod.cross_def(cfg, tp)
    if ffn != "none":
        d["ln2"] = rmsnorm_def(cfg.d_model, cfg.param_dtype)
        d["ffn"] = (moe_mod.moe_def(cfg, tp, dp) if ffn == "moe"
                    else mlp_def(cfg, tp=tp))
    return d


def _stack_defs(defs, n: int):
    def stack_one(d: ParamDef) -> ParamDef:
        fan = d.fan_in
        if fan is None and d.init in ("normal", "scaled"):
            fan = d.shape[-2] if len(d.shape) >= 2 else max(1, d.shape[-1])
        return ParamDef((n,) + d.shape, init=d.init,
                        spec=P(*((None,) + tuple(d.spec))), dtype=d.dtype,
                        fan_in=fan)
    return jax.tree.map(stack_one, defs, is_leaf=is_def)


def model_defs(cfg: ModelConfig, tp: int = 16, dp: int = 16):
    """Full parameter-definition tree for a decoder-only LM."""
    lead, unit, n_rep, tail = layer_plan(cfg)
    defs: dict = {}
    if cfg.input_mode == "tokens":
        defs["embed"] = embedding_def(cfg, tp)
    defs["lead"] = [layer_def(cfg, s, tp, dp) for s in lead]
    if n_rep:
        unit_defs = {f"u{i}": layer_def(cfg, s, tp, dp)
                     for i, s in enumerate(unit)}
        defs["scan"] = _stack_defs(unit_defs, n_rep)
    defs["tail"] = [layer_def(cfg, s, tp, dp) for s in tail]
    defs["ln_f"] = rmsnorm_def(cfg.d_model, cfg.param_dtype)
    if not cfg.tie_embeddings:
        defs["unembed"] = unembed_def(cfg, tp)
    if cfg.mtp_depth:
        defs["mtp"] = {
            "proj": ParamDef((2 * cfg.d_model, cfg.d_model), init="scaled",
                             spec=P(None, "data"), dtype=cfg.param_dtype,
                             fan_in=2 * cfg.d_model),
            "ln_in": rmsnorm_def(cfg.d_model, cfg.param_dtype),
            "layer": layer_def(cfg, ("attn", "dense"), tp, dp),
            "ln_out": rmsnorm_def(cfg.d_model, cfg.param_dtype),
        }
    return defs


# ---------------------------------------------------------------------------
# Caches / recurrent state
# ---------------------------------------------------------------------------

def _mixer_cache(cfg: ModelConfig, kind: str, batch: int, max_len: int):
    if kind in ("attn", "swa", "local"):
        if cfg.attn_kind == "mla":
            return attn_mod.init_mla_cache(cfg, batch, max_len)
        return attn_mod.init_kv_cache(cfg, batch, max_len, kind)
    if kind == "ssd":
        return ssm_mod.init_ssd_state(cfg, batch)
    if kind == "rglru":
        return rglru_mod.init_rglru_state(cfg, batch)
    raise ValueError(kind)


def init_caches(cfg: ModelConfig, batch: int, max_len: int):
    """Cache pytree matching the layer grouping (lead/scan/tail)."""
    lead, unit, n_rep, tail = layer_plan(cfg)
    caches: dict = {}
    caches["lead"] = [_mixer_cache(cfg, s[0], batch, max_len) for s in lead]
    if n_rep:
        unit_caches = {f"u{i}": _mixer_cache(cfg, s[0], batch, max_len)
                       for i, s in enumerate(unit)}
        caches["scan"] = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (n_rep,) + x.shape).copy(),
            unit_caches)
    caches["tail"] = [_mixer_cache(cfg, s[0], batch, max_len) for s in tail]
    return caches


# ---------------------------------------------------------------------------
# Layer application
# ---------------------------------------------------------------------------

def _apply_mixer(p, h, cfg: ModelConfig, kind: str, *, pos_offset, cache,
                 decode):
    if kind in ("attn", "swa", "local", "enc_attn"):
        if cfg.attn_kind == "mla":
            return attn_mod.mla_apply(p, h, cfg, pos_offset=pos_offset,
                                      cache=cache, decode=decode)
        return attn_mod.gqa_apply(p, h, cfg, kind=kind,
                                  pos_offset=pos_offset, cache=cache,
                                  decode=decode)
    if kind == "ssd":
        return ssm_mod.ssd_apply(p, h, cfg, state=cache, decode=decode)
    if kind == "rglru":
        return rglru_mod.rglru_apply(p, h, cfg, state=cache, decode=decode)
    raise ValueError(kind)


def apply_layer(p, x, cfg: ModelConfig, sig: tuple, *, pos_offset=0,
                cache=None, decode=False, memory=None, cross_cache=None):
    """One transformer block. Returns (x, new_cache, aux)."""
    kind, ffn = sig
    h = rmsnorm(p["ln1"], x, cfg.norm_eps)
    mix, new_cache = _apply_mixer(p["mixer"], h, cfg, kind,
                                  pos_offset=pos_offset, cache=cache,
                                  decode=decode)
    x = x + mix
    if "cross" in p and (memory is not None or cross_cache is not None):
        hc = rmsnorm(p["ln_cross"], x, cfg.norm_eps)
        x = x + attn_mod.cross_apply(p["cross"], hc, memory, cfg,
                                     cache=cross_cache)
    aux = jnp.zeros((), jnp.float32)
    if ffn != "none":
        h2 = rmsnorm(p["ln2"], x, cfg.norm_eps)
        if ffn == "moe":
            y, aux = moe_mod.moe_apply(p["ffn"], h2, cfg)
        else:
            y = mlp(p["ffn"], h2, cfg)
        x = x + y
    x = dist.constrain(x, (dist.batch_logical(), "seq", None))
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# Full forward
# ---------------------------------------------------------------------------

def _run_stack(params, x, cfg: ModelConfig, *, pos_offset, caches, decode):
    """Lead (unrolled) -> scan groups -> tail (unrolled)."""
    lead, unit, n_rep, tail = layer_plan(cfg)
    aux_total = jnp.zeros((), jnp.float32)
    new_caches = {"lead": [], "tail": []}

    for i, sig in enumerate(lead):
        c = caches["lead"][i] if caches is not None else None
        x, nc, aux = jax.checkpoint(
            lambda p_, x_, c_, sig_=sig: apply_layer(
                p_, x_, cfg, sig_, pos_offset=pos_offset, cache=c_,
                decode=decode))(params["lead"][i], x, c)
        new_caches["lead"].append(nc)
        aux_total = aux_total + aux

    if n_rep:
        scan_caches = caches["scan"] if caches is not None else None

        def body(carry, xs):
            xc, aux_c = carry
            p_unit, c_unit = xs
            ncs = {}
            for i, sig in enumerate(unit):
                key = f"u{i}"
                c = c_unit[key] if c_unit is not None else None
                xc, nc, aux = apply_layer(p_unit[key], xc, cfg, sig,
                                          pos_offset=pos_offset, cache=c,
                                          decode=decode)
                ncs[key] = nc
                aux_c = aux_c + aux
            return (xc, aux_c), ncs

        body_ckpt = jax.checkpoint(body)
        (x, aux_total), scan_nc = jax.lax.scan(
            body_ckpt, (x, aux_total), (params["scan"], scan_caches))
        new_caches["scan"] = scan_nc

    for i, sig in enumerate(tail):
        c = caches["tail"][i] if caches is not None else None
        x, nc, aux = jax.checkpoint(
            lambda p_, x_, c_, sig_=sig: apply_layer(
                p_, x_, cfg, sig_, pos_offset=pos_offset, cache=c_,
                decode=decode))(params["tail"][i], x, c)
        new_caches["tail"].append(nc)
        aux_total = aux_total + aux

    return x, (new_caches if caches is not None else None), aux_total


def forward(params, inputs, cfg: ModelConfig, *, pos_offset=0, caches=None,
            decode=False, return_hidden=False):
    """inputs: int tokens [B,S] (input_mode=tokens) or embeddings [B,S,D].

    Returns (logits [B,S,V], new_caches, aux).
    """
    if cfg.input_mode == "tokens":
        x = embed(params["embed"], inputs, cfg.compute_dtype)
    else:
        x = inputs.astype(cfg.compute_dtype)
    x = dist.constrain(x, (dist.batch_logical(), "seq", None))

    x, new_caches, aux = _run_stack(params, x, cfg, pos_offset=pos_offset,
                                    caches=caches, decode=decode)
    h = rmsnorm(params["ln_f"], x, cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = unembed(params["embed"].T, h, cfg)
    else:
        logits = unembed(params["unembed"], h, cfg)
    if return_hidden:
        return logits, new_caches, aux, h
    return logits, new_caches, aux


def mtp_logits(params, h, tokens, cfg: ModelConfig):
    """DeepSeek-V3 MTP: predict token t+2 from (h_t, emb(token_{t+1})).

    h: [B,S,D] final hidden; tokens: [B,S]. Returns logits [B,S-1,V]
    aligned so position i predicts tokens[i+2].
    """
    p = params["mtp"]
    emb_next = embed(params["embed"], tokens[:, 1:], cfg.compute_dtype)
    h_in = rmsnorm(p["ln_in"], h[:, :-1], cfg.norm_eps)
    fused = jnp.concatenate([h_in, emb_next], axis=-1)
    x = jnp.einsum("bsk,kd->bsd", fused, p["proj"].astype(cfg.compute_dtype))
    x, _, _ = apply_layer(p["layer"], x, cfg, ("attn", "dense"))
    h_out = rmsnorm(p["ln_out"], x, cfg.norm_eps)
    if cfg.tie_embeddings:
        return unembed(params["embed"].T, h_out, cfg)
    return unembed(params["unembed"], h_out, cfg)


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------

def softmax_xent(logits, labels, vocab_size: int, sample_weights=None):
    """Mean cross-entropy, ignoring label == -1. logits fp32 [B, S, V].

    sample_weights [B] (optional): per-sample loss weights — the pjit-native
    OTA-FL formulation rides these (core/ota.per_client_loss_weights).
    """
    mask = (labels >= 0).astype(jnp.float32)
    labels_safe = jnp.maximum(labels, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels_safe[..., None],
                               axis=-1)[..., 0]
    nll = (logz - gold) * mask
    if sample_weights is not None:
        w = sample_weights.astype(jnp.float32)
        per_sample = jnp.sum(nll, axis=-1) / jnp.maximum(
            jnp.sum(mask, axis=-1), 1)
        return jnp.mean(w * per_sample)
    return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1)


def lm_loss(params, tokens, cfg: ModelConfig, labels=None,
            sample_weights=None):
    """Next-token LM loss (+ router aux + optional MTP)."""
    if labels is None:
        inputs, labels = tokens[:, :-1], tokens[:, 1:]
    else:
        inputs = tokens
    need_h = bool(cfg.mtp_depth)
    out = forward(params, inputs, cfg, return_hidden=need_h)
    logits, _, aux = out[0], out[1], out[2]
    loss = softmax_xent(logits, labels, cfg.padded_vocab, sample_weights)
    if cfg.moe_num_experts:
        loss = loss + cfg.router_aux_weight * aux
    if cfg.mtp_depth:
        h = out[3]
        mtp_lg = mtp_logits(params, h, inputs, cfg)
        mtp_labels = labels[:, 2:] if labels.shape[1] > 2 else labels[:, :0]
        mtp_lg = mtp_lg[:, :mtp_labels.shape[1]]
        if mtp_labels.shape[1] > 0:
            loss = loss + cfg.mtp_loss_weight * softmax_xent(
                mtp_lg, mtp_labels, cfg.padded_vocab, sample_weights)
    return loss
