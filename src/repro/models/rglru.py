"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Real-Gated Linear Recurrent Unit:

    r_t = sigmoid(W_a x_t + b_a)          recurrence gate
    i_t = sigmoid(W_x x_t + b_x)          input gate
    a_t = exp(c * r_t * log sigmoid(lam))  per-channel learned decay, c = 8
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

The recurrence is linear given the gates, so train/prefill uses
jax.lax.associative_scan (log-depth), and decode is a single O(1) update —
RecurrentGemma therefore runs the long_500k shape.

Block structure (Griffin recurrent block): two input linears (branch +
gelu-gate), short causal conv on the branch, RG-LRU, multiplicative merge,
output linear.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.config import ModelConfig
from repro.models.param import ParamDef, divisible

C_FACTOR = 8.0
CONV_K = 4


def _width(cfg: ModelConfig) -> int:
    return cfg.lru_width or cfg.d_model


def rglru_def(cfg: ModelConfig, tp: int = 16):
    d, w = cfg.d_model, _width(cfg)
    in_spec = P("data" if divisible(d, tp) else None,
                "model" if divisible(w, tp) else None)
    diag = P("model" if divisible(w, tp) else None)
    return {
        "w_branch": ParamDef((d, w), init="scaled", spec=in_spec,
                             dtype=cfg.param_dtype, fan_in=d),
        "w_gate": ParamDef((d, w), init="scaled", spec=in_spec,
                           dtype=cfg.param_dtype, fan_in=d),
        "conv_w": ParamDef((CONV_K, w), init="scaled", spec=P(None, None),
                           dtype=cfg.param_dtype, fan_in=CONV_K),
        "conv_b": ParamDef((w,), init="zeros", spec=P(None),
                           dtype=cfg.param_dtype),
        "w_a": ParamDef((w, w), init="scaled", spec=P(None, None) if w > 4096
                        else P(None, None), dtype=cfg.param_dtype, fan_in=w),
        "b_a": ParamDef((w,), init="zeros", spec=diag, dtype=cfg.param_dtype),
        "w_x": ParamDef((w, w), init="scaled", spec=P(None, None),
                        dtype=cfg.param_dtype, fan_in=w),
        "b_x": ParamDef((w,), init="zeros", spec=diag, dtype=cfg.param_dtype),
        "lam": ParamDef((w,), init="ones", spec=diag, dtype=jnp.float32),
        "w_out": ParamDef((w, d), init="scaled",
                          spec=P("model" if divisible(w, tp) else None,
                                 "data" if divisible(d, tp) else None),
                          dtype=cfg.param_dtype, fan_in=w),
    }


def init_rglru_state(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    w = _width(cfg)
    return {
        "h": jnp.zeros((batch, w), dtype),
        "conv": jnp.zeros((batch, CONV_K - 1, w), dtype),
    }


def _gates(p, x):
    """x [.., W] -> (log_a, gated_input) in float32."""
    xf = x.astype(jnp.float32)
    r = jax.nn.sigmoid(xf @ p["w_a"].astype(jnp.float32)
                       + p["b_a"].astype(jnp.float32))
    i = jax.nn.sigmoid(xf @ p["w_x"].astype(jnp.float32)
                       + p["b_x"].astype(jnp.float32))
    log_a = C_FACTOR * r * jax.nn.log_sigmoid(p["lam"])    # <= 0
    gated = i * xf
    return log_a, gated


def _lru_scan(log_a, gated, h0):
    """Linear recurrence h_t = a_t h_{t-1} + sqrt(1-a_t^2) x_t over axis 1."""
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * gated
    if h0 is not None:
        # fold the carried state in as a virtual step 0
        a = jnp.concatenate([jnp.zeros_like(a[:, :1]), a], axis=1)
        b = jnp.concatenate([h0[:, None, :], b], axis=1)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    aa, hh = jax.lax.associative_scan(combine, (a, b), axis=1)
    return hh[:, 1:] if h0 is not None else hh


def rglru_apply(p, x, cfg: ModelConfig, *, state=None, decode: bool = False):
    """x [B,S,D] -> (y [B,S,D], new_state)."""
    bsz, s, d = x.shape
    w = _width(cfg)
    ct = cfg.compute_dtype

    branch = jnp.einsum("bsd,dw->bsw", x.astype(ct), p["w_branch"].astype(ct))
    gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", x.astype(ct),
                                  p["w_gate"].astype(ct)))

    cw = p["conv_w"].astype(jnp.float32)
    cb = p["conv_b"].astype(jnp.float32)
    if decode:
        assert state is not None and s == 1
        conv_in = jnp.concatenate(
            [state["conv"], branch.astype(state["conv"].dtype)], axis=1)
        new_conv = conv_in[:, 1:, :]
        z = jnp.einsum("bkw,kw->bw", conv_in.astype(jnp.float32), cw) + cb
        log_a, gated = _gates(p, z)
        a = jnp.exp(log_a)
        h = (a * state["h"].astype(jnp.float32)
             + jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * gated)
        y = h[:, None, :]
        new_state = {"h": h, "conv": new_conv}
    else:
        pad = jnp.pad(branch.astype(jnp.float32), ((0, 0), (CONV_K - 1, 0),
                                                   (0, 0)))
        z = sum(pad[:, i:i + s, :] * cw[i][None, None, :]
                for i in range(CONV_K)) + cb
        log_a, gated = _gates(p, z)
        h0 = state["h"].astype(jnp.float32) if state is not None else None
        h = _lru_scan(log_a, gated, h0)
        y = h
        if state is not None:
            new_state = {"h": h[:, -1, :],
                         "conv": branch[:, -(CONV_K - 1):, :].astype(
                             state["conv"].dtype)}
        else:
            new_state = None

    y = y.astype(ct) * gate
    out = jnp.einsum("bsw,wd->bsd", y, p["w_out"].astype(ct))
    return out, new_state
