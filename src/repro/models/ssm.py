"""Mamba-2 (SSD — state-space duality) mixer.

Chunked SSD algorithm (arXiv:2405.21060): the sequence is split into chunks
of length Q; within a chunk the quadratic (attention-like) form runs on the
MXU; across chunks a linear recurrence carries the [H, P, N] state.  Decode
is a single O(1) state update — this is why mamba2 runs the long_500k shape.

Layer structure (mamba_ssm reference): in_proj -> (z, xBC, dt);
causal depthwise conv over xBC; SSD core; gated RMSNorm; out_proj.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.config import ModelConfig
from repro.models.layers import rmsnorm
from repro.models.param import ParamDef, divisible


def _dims(cfg: ModelConfig):
    d_inner = cfg.ssm_expand * cfg.d_model
    nheads = d_inner // cfg.ssm_headdim
    conv_dim = d_inner + 2 * cfg.ssm_ngroups * cfg.ssm_state
    return d_inner, nheads, conv_dim


def ssd_def(cfg: ModelConfig, tp: int = 16):
    d = cfg.d_model
    d_inner, nheads, conv_dim = _dims(cfg)
    n, g = cfg.ssm_state, cfg.ssm_ngroups
    d_in_proj = 2 * d_inner + 2 * g * n + nheads
    return {
        "in_proj": ParamDef((d, d_in_proj), init="scaled",
                            spec=P("data" if divisible(d, tp) else None,
                                   "model" if divisible(d_in_proj, tp) else None),
                            dtype=cfg.param_dtype, fan_in=d),
        "conv_w": ParamDef((cfg.ssm_conv, conv_dim), init="scaled",
                           spec=P(None, None), dtype=cfg.param_dtype,
                           fan_in=cfg.ssm_conv),
        "conv_b": ParamDef((conv_dim,), init="zeros", spec=P(None),
                           dtype=cfg.param_dtype),
        "a_log": ParamDef((nheads,), init="zeros", spec=P(None),
                          dtype=jnp.float32),
        "dt_bias": ParamDef((nheads,), init="zeros", spec=P(None),
                            dtype=jnp.float32),
        "d_skip": ParamDef((nheads,), init="ones", spec=P(None),
                           dtype=jnp.float32),
        "norm": ParamDef((d_inner,), init="ones", spec=P(None),
                         dtype=cfg.param_dtype),
        "out_proj": ParamDef((d_inner, d), init="scaled",
                             spec=P("model" if divisible(d_inner, tp) else None,
                                    "data" if divisible(d, tp) else None),
                             dtype=cfg.param_dtype, fan_in=d_inner),
    }


def init_ssd_state(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    d_inner, nheads, conv_dim = _dims(cfg)
    return {
        "ssm": jnp.zeros((batch, nheads, cfg.ssm_headdim, cfg.ssm_state),
                         dtype),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim), dtype),
    }


def _causal_conv(xbc, w, b):
    """xbc [B,L,C]; depthwise causal conv, kernel [K,C]."""
    k = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xbc.shape[1], :] * w[i][None, None, :]
              for i in range(k))
    return jax.nn.silu(out + b[None, None, :])


def _split_proj(cfg: ModelConfig, zxbcdt):
    d_inner, nheads, _ = _dims(cfg)
    g, n = cfg.ssm_ngroups, cfg.ssm_state
    z, xbc, dt = jnp.split(zxbcdt, [d_inner, 2 * d_inner + 2 * g * n], -1)
    return z, xbc, dt


def ssd_chunked(x, dt, a_neg, b_mat, c_mat, chunk: int, state0=None):
    """Core SSD scan.

    x [B,L,H,P]; dt [B,L,H] (>0); a_neg [H] (negative);
    b_mat, c_mat [B,L,G,N] (G divides H).
    Returns (y [B,L,H,P], final_state [B,H,P,N]).
    """
    bsz, l, h, p_dim = x.shape
    g = b_mat.shape[2]
    n = b_mat.shape[3]
    assert l % chunk == 0, f"L={l} % chunk={chunk}"
    nc = l // chunk
    rep = h // g

    # per-step log decay
    dA = dt * a_neg[None, None, :]                      # [B,L,H] (<0)
    xw = x * dt[..., None]                              # dt-weighted input

    def resh(t, extra):
        return t.reshape((bsz, nc, chunk) + extra)

    xw_c = resh(xw, (h, p_dim))
    dA_c = resh(dA, (h,))
    b_c = resh(b_mat, (g, n))
    c_c = resh(c_mat, (g, n))

    cum = jnp.cumsum(dA_c, axis=2)                      # [B,NC,Q,H]
    seg_end = cum[:, :, -1:, :]                         # total chunk decay

    # ---- intra-chunk (quadratic / MXU) ----
    # att[i,j] = exp(cum_i - cum_j) * (C_i . B_j), i >= j
    bh_c = jnp.repeat(b_c, rep, axis=3) if g != h else b_c   # [B,NC,Q,H,N]
    ch_c = jnp.repeat(c_c, rep, axis=3) if g != h else c_c
    scores = jnp.einsum("bcihn,bcjhn->bchij", ch_c.astype(jnp.float32),
                        bh_c.astype(jnp.float32))
    decay = cum[:, :, :, None, :].transpose(0, 1, 4, 2, 3) \
        - cum[:, :, None, :, :].transpose(0, 1, 4, 2, 3)     # [B,NC,H,Q(i),Q(j)]
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    att = jnp.where(mask[None, None, None], scores * jnp.exp(decay), 0.0)
    y_intra = jnp.einsum("bchij,bcjhp->bcihp", att, xw_c.astype(jnp.float32))

    # ---- chunk states ----
    # S_c(local) = sum_j exp(seg_end - cum_j) * B_j (x) xw_j   [B,NC,H,P,N]
    w_in = jnp.exp(seg_end - cum)                        # [B,NC,Q,H]
    s_local = jnp.einsum("bcjh,bcjhn,bcjhp->bchpn",
                         w_in, bh_c.astype(jnp.float32),
                         xw_c.astype(jnp.float32))

    # ---- inter-chunk recurrence: S_k = exp(seg_end_k) S_{k-1} + local_k ----
    seg_decay = jnp.exp(seg_end[:, :, 0, :])             # [B,NC,H]
    if state0 is None:
        state0 = jnp.zeros((bsz, h, p_dim, n), jnp.float32)

    def step(s_prev, inp):
        dec, loc = inp                                   # [B,H], [B,H,P,N]
        s_new = s_prev * dec[..., None, None] + loc
        return s_new, s_prev                             # emit state *entering* chunk

    dec_t = seg_decay.transpose(1, 0, 2)                 # [NC,B,H]
    loc_t = s_local.transpose(1, 0, 2, 3, 4)             # [NC,B,H,P,N]
    final_state, s_in = jax.lax.scan(step, state0.astype(jnp.float32),
                                     (dec_t, loc_t))
    s_in = s_in.transpose(1, 0, 2, 3, 4)                 # [B,NC,H,P,N]

    # ---- inter-chunk output: y_i += C_i . (exp(cum_i) * S_in) ----
    w_out = jnp.exp(cum)                                 # [B,NC,Q,H]
    y_inter = jnp.einsum("bcihn,bchpn,bcih->bcihp",
                         ch_c.astype(jnp.float32), s_in, w_out)

    y = (y_intra + y_inter).reshape(bsz, l, h, p_dim)
    return y, final_state


def ssd_apply(p, x, cfg: ModelConfig, *, state=None, decode: bool = False):
    """Mamba-2 mixer. x [B,S,D] -> (y [B,S,D], new_state)."""
    bsz, s, d = x.shape
    d_inner, nheads, conv_dim = _dims(cfg)
    g, n = cfg.ssm_ngroups, cfg.ssm_state
    hd = cfg.ssm_headdim
    ct = cfg.compute_dtype

    zxbcdt = jnp.einsum("bsd,dk->bsk", x.astype(ct), p["in_proj"].astype(ct))
    z, xbc, dt = _split_proj(cfg, zxbcdt)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"][None, None, :])   # [B,S,H]
    a_neg = -jnp.exp(p["a_log"])                          # [H] < 0

    if decode:
        assert state is not None and s == 1
        # conv ring: shift in the new xBC row
        conv_in = jnp.concatenate([state["conv"],
                                   xbc.astype(state["conv"].dtype)], axis=1)
        new_conv = conv_in[:, 1:, :]
        w = p["conv_w"].astype(jnp.float32)
        xbc_t = jax.nn.silu(jnp.einsum("bkc,kc->bc",
                                       conv_in.astype(jnp.float32), w)
                            + p["conv_b"].astype(jnp.float32))
        xs, b_t, c_t = jnp.split(xbc_t, [d_inner, d_inner + g * n], -1)
        xh = xs.reshape(bsz, nheads, hd)
        b_t = b_t.reshape(bsz, g, n)
        c_t = c_t.reshape(bsz, g, n)
        rep = nheads // g
        bh = jnp.repeat(b_t, rep, axis=1)                 # [B,H,N]
        chh = jnp.repeat(c_t, rep, axis=1)
        dt1 = dt[:, 0, :]                                 # [B,H]
        da = jnp.exp(dt1 * a_neg[None, :])                # [B,H]
        s_new = (state["ssm"] * da[..., None, None]
                 + jnp.einsum("bh,bhn,bhp->bhpn", dt1, bh,
                              xh.astype(jnp.float32)))
        y = jnp.einsum("bhn,bhpn->bhp", chh, s_new)
        y = y + p["d_skip"][None, :, None] * xh.astype(jnp.float32)
        y = y.reshape(bsz, 1, d_inner)
        new_state = {"ssm": s_new, "conv": new_conv}
    else:
        xbc_conv = _causal_conv(xbc.astype(jnp.float32),
                                p["conv_w"].astype(jnp.float32),
                                p["conv_b"].astype(jnp.float32))
        xs, b_mat, c_mat = jnp.split(xbc_conv, [d_inner, d_inner + g * n], -1)
        xh = xs.reshape(bsz, s, nheads, hd)
        b_mat = b_mat.reshape(bsz, s, g, n)
        c_mat = c_mat.reshape(bsz, s, g, n)
        state0 = state["ssm"] if state is not None else None
        # pad to a chunk multiple; dt = 0 on padding keeps the state exact
        # (decay exp(0)=1, input weight dt=0)
        chunk = min(cfg.ssm_chunk, s)
        pad = (-s) % chunk
        if pad:
            pad4 = ((0, 0), (0, pad), (0, 0), (0, 0))
            xh_p = jnp.pad(xh, pad4)
            b_p = jnp.pad(b_mat, pad4)
            c_p = jnp.pad(c_mat, pad4)
            dt_p = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        else:
            xh_p, b_p, c_p, dt_p = xh, b_mat, c_mat, dt
        y, s_fin = ssd_chunked(xh_p, dt_p, a_neg, b_p, c_p, chunk,
                               state0=state0)
        y = y[:, :s]
        y = y + p["d_skip"][None, None, :, None] * xh.astype(jnp.float32)
        y = y.reshape(bsz, s, d_inner)
        new_conv = None
        if state is not None:  # prefill: stash trailing conv window
            k = cfg.ssm_conv - 1
            new_conv = xbc[:, -k:, :].astype(state["conv"].dtype)
            new_state = {"ssm": s_fin, "conv": new_conv}
        else:
            new_state = None

    # gated RMSNorm + out projection
    y = y.astype(ct) * jax.nn.silu(z)
    y = rmsnorm(p["norm"], y, cfg.norm_eps)
    out = jnp.einsum("bsi,id->bsd", y.astype(ct), p["out_proj"].astype(ct))
    return out, new_state
