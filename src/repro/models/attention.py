"""Attention mixers: GQA (with qk-norm / QKV-bias / sliding-window / local
ring-cache variants), MLA (DeepSeek-V3 latent attention), and cross-attention
for the encoder-decoder family.

Memory-bounded softmax: for long sequences the query axis is processed in
blocks via lax.map so the materialized score tile is O(block_q * S_k), which
keeps the 32k-prefill lowering within per-chip HBM on the production mesh.
Decode (S_q = 1) reads a KV cache: linear cache for full attention, ring
buffer (size = window) for sliding/local attention so long_500k decode stays
O(window) in both memory and FLOPs.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import distributed as dist
from repro.models.config import ModelConfig
from repro.models.layers import apply_rope, dense, dense_def, rmsnorm, rmsnorm_def
from repro.models.param import ParamDef, divisible

NEG_INF = -1e30

# When True, grouped_attention materializes full scores instead of
# lax.map-blocking the query axis.  Used ONLY by launch/cost.py analysis
# lowerings: XLA's cost_analysis counts loop bodies once, so the blocked
# (lax.map) form under-reports attention FLOPs by the block count.  The
# production compile keeps blocking (memory-bounded); the analysis compile
# trades memory honesty for FLOP honesty.
ANALYSIS_DIRECT_ATTENTION = False


# ---------------------------------------------------------------------------
# Core masked softmax attention (grouped heads, blocked queries)
# ---------------------------------------------------------------------------

def _scores_mask(qpos, kpos, causal: bool, window: Optional[int]):
    """[Sq, Sk] boolean mask of allowed attention."""
    m = jnp.ones((qpos.shape[0], kpos.shape[0]), dtype=bool)
    if causal:
        m &= kpos[None, :] <= qpos[:, None]
    if window is not None:
        m &= kpos[None, :] > (qpos[:, None] - window)
    return m


def grouped_attention(q, k, v, qpos, kpos, *, causal: bool,
                      window: Optional[int], block_k: int = 1024):
    """q: [B,Sq,H,Dh]; k,v: [B,Sk,KH,Dh(v)]; returns [B,Sq,H,Dv].

    H = KH * G (grouped-query attention). Softmax in float32.

    Long sequences run an online-softmax scan over K-BLOCKS (flash-style):
    the query tensor is never re-tiled, so whatever sharding it carries
    (heads over 'model', or — for head counts that don't divide the TP
    axis — the sequence axis over 'model', see §Perf it.3) is preserved;
    k/v blocks are static slices, free under SPMD.
    """
    b, sq, h, dh = q.shape
    kh = k.shape[2]
    sk = k.shape[1]
    g = h // kh
    dv = v.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, jnp.float32))
    qg = q.reshape(b, sq, kh, g, dh)

    def attend(q_all, qpos_all):
        # direct: scores [B,KH,G,Sq,Sk]
        s = jnp.einsum("bqkgd,bskd->bkgqs", q_all.astype(jnp.float32),
                       k.astype(jnp.float32)) * scale
        mask = _scores_mask(qpos_all, kpos, causal, window)
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
        return o.reshape(b, sq, h, dv)

    if (sq * sk <= 2048 * 2048) or ANALYSIS_DIRECT_ATTENTION:
        return attend(qg, qpos).astype(q.dtype)

    pad_k = (-sk) % block_k
    kf = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    vf = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    # padded keys get position max(qpos)+1: masked by causal/window
    kpos_f = jnp.concatenate(
        [kpos, jnp.broadcast_to(jnp.max(qpos) + 1, (pad_k,))])
    nb = (sk + pad_k) // block_k
    kb = kf.reshape(b, nb, block_k, kh, dh).transpose(1, 0, 2, 3, 4)
    vb = vf.reshape(b, nb, block_k, kh, dv).transpose(1, 0, 2, 3, 4)
    kpb = kpos_f.reshape(nb, block_k)
    qf = qg.astype(jnp.float32)

    def step(carry, blk):
        m_p, l_p, acc_p = carry
        k_blk, v_blk, kpos_blk = blk
        s = jnp.einsum("bqkgd,bskd->bkgqs", qf,
                       k_blk.astype(jnp.float32)) * scale
        mask = _scores_mask(qpos, kpos_blk, causal, window)
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m_p, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m_p - m_new)
        l_new = l_p * alpha + jnp.sum(p, axis=-1)
        acc_new = acc_p * alpha[..., None] + jnp.einsum(
            "bkgqs,bskd->bkgqd", p, v_blk.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, kh, g, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, kh, g, sq), jnp.float32)
    a0 = jnp.zeros((b, kh, g, sq, dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), (kb, vb, kpb))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, dv)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA projections
# ---------------------------------------------------------------------------

def gqa_def(cfg: ModelConfig, tp: int = 16):
    dh = cfg.resolved_head_dim
    d = cfg.d_model
    # §Perf it.2: k and v fused into one column-parallel matmul on an
    # unsharded 2-axis — backward emits ONE d_x partial all-reduce for k+v
    # instead of two (the baseline HLO showed a 3-tuple all-reduce of
    # [B,S,D] per layer for q,k,v).  A full qkv fusion would split across
    # the model-sharded output axis (q and kv segments are not slice-
    # aligned at tp=16), so only the equal-shaped k/v pair is fused.
    kv = cfg.n_kv_heads * dh
    defs = {
        "wq": dense_def(d, cfg.n_heads * dh, cfg, tp_out=True,
                        bias=cfg.qkv_bias, tp=tp),
        "wkv": ParamDef(
            (d, 2, kv), init="scaled",
            spec=P("data" if divisible(d, tp) else None, None,
                   "model" if divisible(kv, tp) else None),
            dtype=cfg.param_dtype, fan_in=d),
        "wo": dense_def(cfg.n_heads * dh, d, cfg, tp_out=False, tp=tp),
    }
    if cfg.qkv_bias:
        defs["bkv"] = ParamDef(
            (2, kv), init="zeros",
            spec=P(None, "model" if divisible(kv, tp) else None),
            dtype=cfg.param_dtype)
    if cfg.qk_norm:
        defs["q_norm"] = rmsnorm_def(dh, cfg.param_dtype)
        defs["k_norm"] = rmsnorm_def(dh, cfg.param_dtype)
    return defs


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int, kind: str,
                  dtype=None):
    """Abstract/zero KV cache for one attention layer."""
    dh = cfg.resolved_head_dim
    dtype = dtype or cfg.compute_dtype
    if kind in ("swa", "local") and cfg.window and max_len > cfg.window:
        max_len = cfg.window            # ring buffer
    return {
        "k": jnp.zeros((batch, max_len, cfg.n_kv_heads, dh), dtype),
        "v": jnp.zeros((batch, max_len, cfg.n_kv_heads, dh), dtype),
    }


def _cache_write(cache, k_new, v_new, pos, ring: bool):
    """Insert [B,S,KH,Dh] at position ``pos`` (scalar int array)."""
    s = k_new.shape[1]
    cap = cache["k"].shape[1]
    k_new = k_new.astype(cache["k"].dtype)
    v_new = v_new.astype(cache["v"].dtype)
    if ring and s == 1:
        idx = jnp.mod(pos, cap)
        k = jax.lax.dynamic_update_slice(cache["k"], k_new, (0, idx, 0, 0))
        v = jax.lax.dynamic_update_slice(cache["v"], v_new, (0, idx, 0, 0))
    elif ring and s >= cap:
        # prefill into a ring buffer: keep the trailing ``cap`` entries, laid
        # out so that slot j holds the entry with absolute position ≡ j (cap).
        first_pos = pos + s - cap
        shift = jnp.mod(first_pos, cap)
        k = jnp.roll(k_new[:, -cap:], shift, axis=1)
        v = jnp.roll(v_new[:, -cap:], shift, axis=1)
    else:
        k = jax.lax.dynamic_update_slice(cache["k"], k_new, (0, pos, 0, 0))
        v = jax.lax.dynamic_update_slice(cache["v"], v_new, (0, pos, 0, 0))
    return {"k": k, "v": v}


def gqa_apply(p, x, cfg: ModelConfig, *, kind: str = "attn",
              pos_offset=0, cache=None, decode: bool = False,
              positions=None):
    """Self-attention. Returns (out, new_cache).

    kind: attn (full causal) | swa | local (both sliding-window causal).
    decode: S_q == 1, reads+updates cache.
    """
    b, s, _ = x.shape
    dh = cfg.resolved_head_dim
    ct = cfg.compute_dtype
    window = cfg.window if kind in ("swa", "local") else None
    causal = kind != "enc_attn"

    q = dense(p["wq"], x, ct).reshape(b, s, cfg.n_heads, dh)
    kv2 = jnp.einsum("...d,dgk->...gk", x.astype(ct), p["wkv"].astype(ct))
    if "bkv" in p:
        kv2 = kv2 + p["bkv"].astype(ct)
    k = kv2[..., 0, :].reshape(b, s, cfg.n_kv_heads, dh)
    v = kv2[..., 1, :].reshape(b, s, cfg.n_kv_heads, dh)
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q, cfg.norm_eps)
        k = rmsnorm(p["k_norm"], k, cfg.norm_eps)

    if positions is None:
        positions = pos_offset + jnp.arange(s)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)

    # §Perf it.3: when the head count does not divide the TP axis (e.g.
    # qwen2.5's 40 heads on a 16-way model axis) auto-SPMD splits the
    # head_dim contraction and ALL-REDUCES the full SqxSk score tensor
    # (~43 GB/layer measured at 32k).  Shard the query SEQUENCE over
    # 'model' instead and replicate k/v: attention becomes fully local,
    # at the cost of one [B,S,D] all-gather after wo.
    mesh = dist.active_mesh()
    if (not decode and s > 1 and mesh is not None
            and "model" in mesh.axis_names
            and cfg.n_heads % mesh.shape["model"]
            and s % mesh.shape["model"] == 0):
        bl = dist.batch_logical()
        q = dist.constrain(q, (bl, ("model",), None, None))
        k = dist.constrain(k, (bl, None, None, None))
        v = dist.constrain(v, (bl, None, None, None))

    if decode:
        assert cache is not None and s == 1
        cap = cache["k"].shape[1]
        ring = window is not None and cap <= window
        cache = _cache_write(cache, k, v, positions[0], ring)
        if ring:
            # ring buffer: absolute position of slot i is recovered from the
            # write pointer; everything in the buffer is within the window.
            kpos = positions[0] - jnp.mod(positions[0] - jnp.arange(cap), cap)
            # warmup slots (never written) decode to negative positions —
            # push them into the future so the causal mask blocks them.
            kpos = jnp.where(kpos < 0, positions[0] + 1, kpos)
        else:
            kpos = jnp.arange(cap)
        out = grouped_attention(q, cache["k"], cache["v"], positions, kpos,
                                causal=causal, window=window)
    else:
        if cache is not None:  # prefill: write the whole segment
            ring = window is not None and cache["k"].shape[1] <= window
            cache = _cache_write(cache, k, v, jnp.asarray(pos_offset), ring)
        out = grouped_attention(q, k, v, positions, positions,
                                causal=causal, window=window)

    out = out.reshape(b, s, cfg.n_heads * dh)
    return dense(p["wo"], out, ct), cache


# ---------------------------------------------------------------------------
# Cross-attention (encoder-decoder)
# ---------------------------------------------------------------------------

def cross_def(cfg: ModelConfig, tp: int = 16):
    dh = cfg.resolved_head_dim
    d = cfg.d_model
    return {
        "wq": dense_def(d, cfg.n_heads * dh, cfg, tp_out=True, tp=tp),
        "wk": dense_def(d, cfg.n_kv_heads * dh, cfg, tp_out=True, tp=tp),
        "wv": dense_def(d, cfg.n_kv_heads * dh, cfg, tp_out=True, tp=tp),
        "wo": dense_def(cfg.n_heads * dh, d, cfg, tp_out=False, tp=tp),
    }


def cross_apply(p, x, memory, cfg: ModelConfig, *, cache=None):
    """x: [B,Sq,D] decoder states; memory: [B,Sk,D] encoder output.

    cache (optional): precomputed {k, v} over memory (decode path).
    """
    b, s, _ = x.shape
    dh = cfg.resolved_head_dim
    ct = cfg.compute_dtype
    q = dense(p["wq"], x, ct).reshape(b, s, cfg.n_heads, dh)
    if cache is None:
        sk = memory.shape[1]
        k = dense(p["wk"], memory, ct).reshape(b, sk, cfg.n_kv_heads, dh)
        v = dense(p["wv"], memory, ct).reshape(b, sk, cfg.n_kv_heads, dh)
    else:
        k, v = cache["k"], cache["v"]
        sk = k.shape[1]
    qpos = jnp.zeros(s, jnp.int32)
    kpos = jnp.zeros(sk, jnp.int32)
    out = grouped_attention(q, k, v, qpos, kpos, causal=False, window=None)
    out = out.reshape(b, s, cfg.n_heads * dh)
    return dense(p["wo"], out, ct)


def cross_cache(p, memory, cfg: ModelConfig):
    """Precompute encoder-side K/V once per request (decode path)."""
    b, sk, _ = memory.shape
    dh = cfg.resolved_head_dim
    ct = cfg.compute_dtype
    k = dense(p["wk"], memory, ct).reshape(b, sk, cfg.n_kv_heads, dh)
    v = dense(p["wv"], memory, ct).reshape(b, sk, cfg.n_kv_heads, dh)
    return {"k": k, "v": v}


# ---------------------------------------------------------------------------
# MLA — Multi-head Latent Attention (DeepSeek-V3)
# ---------------------------------------------------------------------------

def mla_def(cfg: ModelConfig, tp: int = 16):
    d = cfg.d_model
    h = cfg.n_heads
    qk_dim = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
    defs = {}
    if cfg.q_lora_rank:
        defs["wq_a"] = dense_def(d, cfg.q_lora_rank, cfg, tp_out=True, tp=tp)
        defs["q_norm"] = rmsnorm_def(cfg.q_lora_rank, cfg.param_dtype)
        defs["wq_b"] = dense_def(cfg.q_lora_rank, h * qk_dim, cfg,
                                 tp_out=True, tp=tp)
    else:
        defs["wq"] = dense_def(d, h * qk_dim, cfg, tp_out=True, tp=tp)
    defs["wkv_a"] = dense_def(d, cfg.kv_lora_rank + cfg.qk_rope_head_dim, cfg,
                              tp_out=True, tp=tp)
    defs["kv_norm"] = rmsnorm_def(cfg.kv_lora_rank, cfg.param_dtype)
    defs["wkv_b"] = dense_def(
        cfg.kv_lora_rank, h * (cfg.qk_nope_head_dim + cfg.v_head_dim), cfg,
        tp_out=True, tp=tp)
    defs["wo"] = dense_def(h * cfg.v_head_dim, d, cfg, tp_out=False, tp=tp)
    return defs


def init_mla_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None):
    """Compressed latent cache — the point of MLA: O(kv_rank + rope_dim)."""
    dtype = dtype or cfg.compute_dtype
    return {
        "ckv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
        "krope": jnp.zeros((batch, max_len, cfg.qk_rope_head_dim), dtype),
    }


def _mla_q(p, x, cfg: ModelConfig, positions):
    b, s, _ = x.shape
    h = cfg.n_heads
    ct = cfg.compute_dtype
    qk = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
    if cfg.q_lora_rank:
        cq = rmsnorm(p["q_norm"], dense(p["wq_a"], x, ct), cfg.norm_eps)
        q = dense(p["wq_b"], cq, ct)
    else:
        q = dense(p["wq"], x, ct)
    q = q.reshape(b, s, h, qk)
    q_nope = q[..., :cfg.qk_nope_head_dim]
    q_rope = apply_rope(q[..., cfg.qk_nope_head_dim:], positions,
                        cfg.rope_theta)
    return q_nope, q_rope


def mla_apply(p, x, cfg: ModelConfig, *, pos_offset=0, cache=None,
              decode: bool = False, positions=None):
    """Returns (out, new_cache). Cache stores the compressed latents.

    Train/prefill: expanded (naive) form. Decode: weight-absorbed form —
    scores/values computed directly against the latent cache.
    """
    b, s, _ = x.shape
    h = cfg.n_heads
    ct = cfg.compute_dtype
    if positions is None:
        positions = pos_offset + jnp.arange(s)

    kv_a = dense(p["wkv_a"], x, ct)
    ckv = rmsnorm(p["kv_norm"], kv_a[..., :cfg.kv_lora_rank], cfg.norm_eps)
    krope = apply_rope(kv_a[..., None, cfg.kv_lora_rank:], positions,
                       cfg.rope_theta)[..., 0, :]          # [B,S,rope]

    q_nope, q_rope = _mla_q(p, x, cfg, positions)
    wkv_b = p["wkv_b"]["w"].astype(ct).reshape(
        cfg.kv_lora_rank, h, cfg.qk_nope_head_dim + cfg.v_head_dim)
    wk_b = wkv_b[..., :cfg.qk_nope_head_dim]               # [R,H,Dn]
    wv_b = wkv_b[..., cfg.qk_nope_head_dim:]               # [R,H,Dv]

    scale = 1.0 / jnp.sqrt(jnp.asarray(
        cfg.qk_nope_head_dim + cfg.qk_rope_head_dim, jnp.float32))

    if decode:
        assert cache is not None and s == 1
        cache = {
            "ckv": jax.lax.dynamic_update_slice(
                cache["ckv"], ckv.astype(cache["ckv"].dtype), (0, positions[0], 0)),
            "krope": jax.lax.dynamic_update_slice(
                cache["krope"], krope.astype(cache["krope"].dtype),
                (0, positions[0], 0)),
        }
        ckv_all, krope_all = cache["ckv"], cache["krope"]
        sk = ckv_all.shape[1]
        # absorbed: q_nope -> latent space
        q_lat = jnp.einsum("bqhd,rhd->bqhr", q_nope.astype(jnp.float32),
                           wk_b.astype(jnp.float32))
        sc = (jnp.einsum("bqhr,bsr->bhqs", q_lat, ckv_all.astype(jnp.float32))
              + jnp.einsum("bqhd,bsd->bhqs", q_rope.astype(jnp.float32),
                           krope_all.astype(jnp.float32))) * scale
        kpos = jnp.arange(sk)
        mask = kpos[None, :] <= positions[:, None]
        sc = jnp.where(mask[None, None], sc, NEG_INF)
        pr = jax.nn.softmax(sc, axis=-1)
        o_lat = jnp.einsum("bhqs,bsr->bqhr", pr, ckv_all.astype(jnp.float32))
        out = jnp.einsum("bqhr,rhd->bqhd", o_lat, wv_b.astype(jnp.float32))
    else:
        if cache is not None:
            cache = {
                "ckv": jax.lax.dynamic_update_slice(
                    cache["ckv"], ckv.astype(cache["ckv"].dtype),
                    (0, pos_offset, 0)),
                "krope": jax.lax.dynamic_update_slice(
                    cache["krope"], krope.astype(cache["krope"].dtype),
                    (0, pos_offset, 0)),
            }
        # expanded form: materialize per-head K/V from latents
        k_nope = jnp.einsum("bsr,rhd->bshd", ckv.astype(ct), wk_b)
        vv = jnp.einsum("bsr,rhd->bshd", ckv.astype(ct), wv_b)
        k_full = jnp.concatenate(
            [k_nope, jnp.broadcast_to(krope[:, :, None, :],
                                      (b, s, h, cfg.qk_rope_head_dim))], -1)
        q_full = jnp.concatenate([q_nope, q_rope], -1)
        out = grouped_attention(q_full, k_full, vv, positions, positions,
                                causal=True, window=None)

    out = out.reshape(b, s, h * cfg.v_head_dim)
    return dense(p["wo"], out, ct), cache
