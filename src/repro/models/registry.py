"""Model bundle: uniform interface over all architecture families.

A ModelBundle exposes defs / loss / prefill / decode for one ModelConfig so
the FL runtime, dry-run launcher and tests never branch on family.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.models import encdec as encdec_mod
from repro.models import transformer as tfm
from repro.models.config import ModelConfig
from repro.models.param import abstract_params, init_params, param_count


@dataclasses.dataclass
class ModelBundle:
    cfg: ModelConfig
    defs: Any
    loss: Callable                  # (params, batch) -> scalar
    prefill: Callable               # (params, inputs, caches) -> (logits, caches)
    decode: Callable                # (params, caches, token, pos) -> (logits, caches)
    init_caches: Callable           # (batch, max_len) -> cache pytree
    num_params: int = 0

    def init(self, key):
        return init_params(self.defs, key)

    def abstract(self):
        return abstract_params(self.defs)


def _decoder_bundle(cfg: ModelConfig, tp: int, dp: int) -> ModelBundle:
    defs = tfm.model_defs(cfg, tp, dp)

    def loss(params, batch, sample_weights=None):
        return tfm.lm_loss(params, batch, cfg, sample_weights=sample_weights)

    def prefill(params, inputs, caches):
        logits, caches, _ = tfm.forward(params, inputs, cfg, caches=caches)
        return logits, caches

    def decode(params, caches, token, pos):
        logits, caches, _ = tfm.forward(params, token, cfg, pos_offset=pos,
                                        caches=caches, decode=True)
        return logits, caches

    def init_caches(batch, max_len):
        return tfm.init_caches(cfg, batch, max_len)

    return ModelBundle(cfg=cfg, defs=defs, loss=loss, prefill=prefill,
                       decode=decode, init_caches=init_caches,
                       num_params=param_count(defs))


def _encdec_bundle(cfg: ModelConfig, tp: int, dp: int) -> ModelBundle:
    defs = encdec_mod.encdec_defs(cfg, tp, dp)

    def loss(params, batch, sample_weights=None):
        frames, tokens = batch
        return encdec_mod.seq2seq_loss(params, frames, tokens, cfg,
                                       sample_weights=sample_weights)

    def prefill(params, inputs, caches):
        """inputs = (frames, dec_tokens); returns (logits, (self, cross))."""
        frames, dec_tokens = inputs
        memory = encdec_mod.encode(params, frames, cfg)
        cross = encdec_mod.build_cross_caches(params, memory, cfg)
        logits, self_c = encdec_mod.decode_train(params, memory, dec_tokens,
                                                 cfg, caches=caches)
        return logits, (self_c, cross)

    def decode(params, caches, token, pos):
        self_c, cross_c = caches
        logits, self_c = encdec_mod.decode_step(params, self_c, cross_c,
                                                token, pos, cfg)
        return logits, (self_c, cross_c)

    def init_caches(batch, max_len):
        return encdec_mod.init_decode_caches(cfg, batch, max_len)

    return ModelBundle(cfg=cfg, defs=defs, loss=loss, prefill=prefill,
                       decode=decode, init_caches=init_caches,
                       num_params=param_count(defs))


def build_bundle(cfg: ModelConfig, tp: int = 16, dp: int = 16) -> ModelBundle:
    if cfg.is_enc_dec:
        return _encdec_bundle(cfg, tp, dp)
    return _decoder_bundle(cfg, tp, dp)
