"""The paper's experiment model (§IV): one-hidden-layer MLP for 10-class
28x28 image classification.

784 -> 1024 (ReLU) -> 10, with l2-regularized cross-entropy (coef 0.01).
Parameter count: 784*1024 + 1024 + 1024*10 + 10 = 814,090 = d  (paper's d).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.param import ParamDef

INPUT_DIM = 784
HIDDEN_DIM = 1024
NUM_CLASSES = 10
L2_COEF = 0.01
PARAM_DIM = INPUT_DIM * HIDDEN_DIM + HIDDEN_DIM + HIDDEN_DIM * NUM_CLASSES + NUM_CLASSES


def mlp_defs(hidden: int = HIDDEN_DIM, num_classes: int = NUM_CLASSES,
             input_dim: int = INPUT_DIM):
    return {
        "w1": ParamDef((input_dim, hidden), init="scaled",
                       spec=P("data", "model"), dtype=jnp.float32,
                       fan_in=input_dim),
        "b1": ParamDef((hidden,), init="zeros", spec=P("model"),
                       dtype=jnp.float32),
        "w2": ParamDef((hidden, num_classes), init="scaled",
                       spec=P("model", None), dtype=jnp.float32,
                       fan_in=hidden),
        "b2": ParamDef((num_classes,), init="zeros", spec=P(None),
                       dtype=jnp.float32),
    }


def mlp_forward(params, x):
    """x: [B, 784] -> logits [B, 10]."""
    h = jax.nn.relu(x @ params["w1"] + params["b1"])
    return h @ params["w2"] + params["b2"]


def mlp_loss(params, batch, l2: float = L2_COEF):
    """l2-regularized mean cross-entropy; batch = (x [B,784], y [B])."""
    x, y = batch
    logits = mlp_forward(params, x)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, y[:, None], axis=-1)[:, 0]
    xent = jnp.mean(logz - gold)
    reg = sum(jnp.sum(p.astype(jnp.float32) ** 2)
              for p in jax.tree.leaves(params))
    return xent + 0.5 * l2 * reg


def accuracy(params, x, y):
    logits = mlp_forward(params, x)
    return jnp.mean((jnp.argmax(logits, -1) == y).astype(jnp.float32))
