"""Encoder-decoder backbone (SeamlessM4T family).

Per the assignment carve-out, the audio frontend (mel-spectrogram +
conv feature extractor) is a STUB: the encoder consumes precomputed frame
embeddings [B, S_frames, D] supplied by input_specs().  Everything from the
encoder stack onward — bidirectional encoder, causal decoder with
cross-attention, caches, loss — is fully implemented.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import distributed as dist
from repro.models import attention as attn_mod
from repro.models.config import ModelConfig
from repro.models.layers import embed, embedding_def, rmsnorm, rmsnorm_def, unembed, unembed_def
from repro.models.param import ParamDef
from repro.models.transformer import _stack_defs, apply_layer, layer_def, softmax_xent


def encdec_defs(cfg: ModelConfig, tp: int = 16, dp: int = 16):
    enc_layer = layer_def(cfg, ("enc_attn", "dense"), tp, dp)
    dec_layer = layer_def(cfg, ("attn", "dense"), tp, dp, cross=True)
    return {
        "enc_scan": _stack_defs({"u0": enc_layer}, cfg.encoder_layers),
        "enc_ln_f": rmsnorm_def(cfg.d_model, cfg.param_dtype),
        "embed": embedding_def(cfg, tp),          # decoder token embeddings
        "dec_scan": _stack_defs({"u0": dec_layer}, cfg.n_layers),
        "ln_f": rmsnorm_def(cfg.d_model, cfg.param_dtype),
        "unembed": unembed_def(cfg, tp),
    }


def _scan_stack(stacked_params, x, cfg: ModelConfig, sig, *, memory=None,
                caches=None, cross_caches=None, pos_offset=0, decode=False):
    def body(carry, xs):
        p_unit, c_unit, cc_unit = xs
        xc, nc, _ = apply_layer(p_unit["u0"], carry, cfg, sig,
                                pos_offset=pos_offset, cache=c_unit,
                                decode=decode, memory=memory,
                                cross_cache=cc_unit)
        return xc, nc

    x, new_caches = jax.lax.scan(jax.checkpoint(body), x,
                                 (stacked_params, caches, cross_caches))
    return x, new_caches


def encode(params, frames, cfg: ModelConfig):
    """frames: [B, S_frames, D] stub-frontend embeddings -> [B,S,D]."""
    x = frames.astype(cfg.compute_dtype)
    x = dist.constrain(x, (dist.batch_logical(), "seq", None))
    x, _ = _scan_stack(params["enc_scan"], x, cfg, ("enc_attn", "dense"))
    return rmsnorm(params["enc_ln_f"], x, cfg.norm_eps)


def decode_train(params, memory, tokens, cfg: ModelConfig, caches=None):
    """Teacher-forced decoder: tokens [B,S] -> logits [B,S,V].

    With ``caches`` (stacked per-layer KV), also fills them — the prefill
    path of the serving stack.
    """
    x = embed(params["embed"], tokens, cfg.compute_dtype)
    x = dist.constrain(x, (dist.batch_logical(), "seq", None))
    x, new_caches = _scan_stack(params["dec_scan"], x, cfg,
                                ("attn", "dense"), memory=memory,
                                caches=caches)
    h = rmsnorm(params["ln_f"], x, cfg.norm_eps)
    logits = unembed(params["unembed"], h, cfg)
    if caches is not None:
        return logits, new_caches
    return logits


def seq2seq_loss(params, frames, tokens, cfg: ModelConfig,
                 sample_weights=None):
    """Encoder frames + teacher-forced next-token decoder loss."""
    memory = encode(params, frames, cfg)
    logits = decode_train(params, memory, tokens[:, :-1], cfg)
    return softmax_xent(logits, tokens[:, 1:], cfg.padded_vocab,
                        sample_weights)


# ---------------------------------------------------------------------------
# Serving path
# ---------------------------------------------------------------------------

def init_decode_caches(cfg: ModelConfig, batch: int, max_len: int):
    """Stacked self-attention caches for the decoder scan."""
    one = attn_mod.init_kv_cache(cfg, batch, max_len, "attn")
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (cfg.n_layers,) + x.shape).copy(),
        one)


def build_cross_caches(params, memory, cfg: ModelConfig):
    """Precompute per-layer encoder K/V (scanned over stacked params)."""
    def body(_, p_unit):
        return None, attn_mod.cross_cache(p_unit["u0"]["cross"], memory, cfg)

    _, caches = jax.lax.scan(body, None, params["dec_scan"])
    return caches


def decode_step(params, caches, cross_caches, token, pos, cfg: ModelConfig):
    """One decode step: token [B,1] -> (logits [B,1,V], new self caches)."""
    x = embed(params["embed"], token, cfg.compute_dtype)

    def body(carry, xs):
        p_unit, c_unit, cc_unit = xs
        xc, nc, _ = apply_layer(p_unit["u0"], carry, cfg, ("attn", "dense"),
                                pos_offset=pos, cache=c_unit, decode=True,
                                cross_cache=cc_unit)
        return xc, nc

    x, new_caches = jax.lax.scan(jax.checkpoint(body), x,
                                 (params["dec_scan"], caches, cross_caches))
    h = rmsnorm(params["ln_f"], x, cfg.norm_eps)
    return unembed(params["unembed"], h, cfg), new_caches
