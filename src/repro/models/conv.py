"""Small convnet for the CIFAR-class image task (DESIGN.md §Tasks).

Built from the same ParamDef primitives as every other model in models/
(one definition serves init, abstract lowering and param counting):

    conv 3x3 (3 -> c1) -> ReLU -> 2x2 avg-pool
    conv 3x3 (c1 -> c2) -> ReLU -> 2x2 avg-pool
    flatten -> dense hidden -> ReLU -> dense num_classes

All parameters are float32, so under the fleet engine's ``flat=True``
fused aggregation (kernels.ops.ota_aggregate_pytree) the raveled gradient
matrix accumulates in f32 with no mixed-dtype casts — the "f32-safe"
contract the cifar_conv task relies on.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.param import ParamDef, param_count

INPUT_SHAPE = (32, 32, 3)
NUM_CLASSES = 10
L2_COEF = 1e-4

# NHWC activations x HWIO kernels -> NHWC
_DIMNUMS = ("NHWC", "HWIO", "NHWC")


def conv_defs(channels: tuple = (16, 32), hidden: int = 128,
              num_classes: int = NUM_CLASSES,
              input_shape: tuple = INPUT_SHAPE):
    """ParamDef tree for the convnet (all f32)."""
    h, w, c_in = input_shape
    c1, c2 = channels
    pooled = (h // 4) * (w // 4) * c2        # two 2x2 pools
    return {
        "conv1": ParamDef((3, 3, c_in, c1), init="scaled", spec=P(),
                          dtype=jnp.float32, fan_in=3 * 3 * c_in),
        "bc1": ParamDef((c1,), init="zeros", spec=P(), dtype=jnp.float32),
        "conv2": ParamDef((3, 3, c1, c2), init="scaled", spec=P(),
                          dtype=jnp.float32, fan_in=3 * 3 * c1),
        "bc2": ParamDef((c2,), init="zeros", spec=P(), dtype=jnp.float32),
        "w1": ParamDef((pooled, hidden), init="scaled",
                       spec=P("data", "model"), dtype=jnp.float32,
                       fan_in=pooled),
        "b1": ParamDef((hidden,), init="zeros", spec=P("model"),
                       dtype=jnp.float32),
        "w2": ParamDef((hidden, num_classes), init="scaled",
                       spec=P("model", None), dtype=jnp.float32,
                       fan_in=hidden),
        "b2": ParamDef((num_classes,), init="zeros", spec=P(None),
                       dtype=jnp.float32),
    }


def conv_dim(channels: tuple = (16, 32), hidden: int = 128,
             num_classes: int = NUM_CLASSES,
             input_shape: tuple = INPUT_SHAPE) -> int:
    return param_count(conv_defs(channels, hidden, num_classes, input_shape))


def _avg_pool2(x: jax.Array) -> jax.Array:
    """2x2/2 average pool on NHWC."""
    b, h, w, c = x.shape
    return jnp.mean(x.reshape(b, h // 2, 2, w // 2, 2, c), axis=(2, 4))


def conv_forward(params, x: jax.Array) -> jax.Array:
    """x: [B, 32, 32, 3] -> logits [B, num_classes]."""
    h = jax.lax.conv_general_dilated(x, params["conv1"], (1, 1), "SAME",
                                     dimension_numbers=_DIMNUMS)
    h = _avg_pool2(jax.nn.relu(h + params["bc1"]))
    h = jax.lax.conv_general_dilated(h, params["conv2"], (1, 1), "SAME",
                                     dimension_numbers=_DIMNUMS)
    h = _avg_pool2(jax.nn.relu(h + params["bc2"]))
    h = h.reshape(h.shape[0], -1)
    h = jax.nn.relu(h @ params["w1"] + params["b1"])
    return h @ params["w2"] + params["b2"]


def conv_loss(params, batch, l2: float = L2_COEF):
    """l2-regularized mean cross-entropy; batch = (x [B,32,32,3], y [B])."""
    x, y = batch
    logits = conv_forward(params, x)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, y[:, None], axis=-1)[:, 0]
    xent = jnp.mean(logz - gold)
    reg = sum(jnp.sum(p.astype(jnp.float32) ** 2)
              for p in jax.tree.leaves(params))
    return xent + 0.5 * l2 * reg


def accuracy(params, x, y):
    logits = conv_forward(params, x)
    return jnp.mean((jnp.argmax(logits, -1) == y).astype(jnp.float32))
