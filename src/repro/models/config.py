"""Unified model configuration for all six assigned architecture families."""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    arch_type: str = "dense"          # dense|moe|ssm|hybrid|vlm|audio|mlp
    n_layers: int = 2
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    d_ff: int = 512
    vocab_size: int = 1024
    head_dim: Optional[int] = None

    # --- block structure -------------------------------------------------
    # cycled over layers; kinds: attn | swa | local | rglru | ssd
    block_pattern: Tuple[str, ...] = ("attn",)
    ffn_kind: str = "swiglu"          # swiglu | geglu | gelu | none

    # --- MoE --------------------------------------------------------------
    moe_num_experts: int = 0
    moe_top_k: int = 2
    moe_shared_experts: int = 0
    moe_d_ff: Optional[int] = None    # expert hidden dim (defaults to d_ff)
    moe_first_dense: int = 0          # leading layers with dense FFN
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01

    # --- attention --------------------------------------------------------
    qkv_bias: bool = False
    qk_norm: bool = False
    window: Optional[int] = None      # sliding/local attention window
    rope_theta: float = 10000.0
    attn_kind: str = "gqa"            # gqa | mla

    # --- MLA (DeepSeek-V3) --------------------------------------------------
    q_lora_rank: int = 0
    kv_lora_rank: int = 512
    qk_rope_head_dim: int = 64
    qk_nope_head_dim: int = 128
    v_head_dim: int = 128

    # --- SSM (Mamba-2) ------------------------------------------------------
    ssm_state: int = 128
    ssm_headdim: int = 64
    ssm_ngroups: int = 1
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_chunk: int = 128

    # --- RG-LRU (RecurrentGemma) ---------------------------------------------
    lru_width: Optional[int] = None

    # --- encoder-decoder (Seamless) -------------------------------------------
    encoder_layers: int = 0           # > 0 => enc-dec

    # --- input frontend ---------------------------------------------------
    # tokens: ids -> embedding table; frames: continuous embeddings provided
    # by the (stubbed) modality frontend.
    input_mode: str = "tokens"

    # --- multi-token prediction (DeepSeek-V3) ----------------------------------
    mtp_depth: int = 0
    mtp_loss_weight: float = 0.3

    # --- misc ---------------------------------------------------------------
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    param_dtype: Any = jnp.bfloat16
    compute_dtype: Any = jnp.bfloat16
    vocab_pad_to: int = 256
    logit_softcap: Optional[float] = None

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        p = self.vocab_pad_to
        return (self.vocab_size + p - 1) // p * p

    @property
    def expert_d_ff(self) -> int:
        return self.moe_d_ff if self.moe_d_ff else self.d_ff

    @property
    def is_enc_dec(self) -> bool:
        return self.encoder_layers > 0

    def block_kinds(self, n_layers: Optional[int] = None) -> Tuple[str, ...]:
        """Per-layer mixer kinds, cycling block_pattern."""
        n = n_layers if n_layers is not None else self.n_layers
        pat = self.block_pattern
        return tuple(pat[i % len(pat)] for i in range(n))

    def layer_is_moe(self, idx: int) -> bool:
        return self.moe_num_experts > 0 and idx >= self.moe_first_dense

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def smoke(self, **kw) -> "ModelConfig":
        """Reduced variant of the same family: 2 layers, d_model<=512,
        <=4 experts — runnable on CPU for smoke tests."""
        small = dict(
            n_layers=2,
            d_model=min(self.d_model, 256),
            n_heads=min(self.n_heads, 4),
            n_kv_heads=min(self.n_kv_heads, 2),
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            head_dim=64 if self.resolved_head_dim >= 64 else self.resolved_head_dim,
            window=min(self.window, 64) if self.window else None,
            param_dtype=jnp.float32,
            compute_dtype=jnp.float32,
        )
        if self.moe_num_experts:
            small.update(moe_num_experts=4, moe_top_k=min(self.moe_top_k, 2),
                         moe_shared_experts=min(self.moe_shared_experts, 1),
                         moe_d_ff=128, moe_first_dense=min(self.moe_first_dense, 1))
        if self.attn_kind == "mla":
            small.update(q_lora_rank=64 if self.q_lora_rank else 0,
                         kv_lora_rank=64, qk_rope_head_dim=16,
                         qk_nope_head_dim=32, v_head_dim=32)
        if self.arch_type in ("ssm", "hybrid"):
            small.update(ssm_state=32, ssm_headdim=32, ssm_chunk=32,
                         lru_width=min(self.lru_width or 256, 256))
        if self.encoder_layers:
            small.update(encoder_layers=2)
        if self.mtp_depth:
            small.update(mtp_depth=1)
        small.update(kw)
        return self.replace(**small)
