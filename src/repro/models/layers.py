"""Shared neural-net layers: norms, RoPE, MLPs, embeddings.

All layers are pure functions over explicit param pytrees; param structure is
declared via ParamDef trees (see models/param.py) so the same definition
serves CPU smoke tests and 512-chip abstract lowering.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.config import ModelConfig
from repro.models.param import ParamDef, divisible

# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm_def(dim: int, dtype) -> ParamDef:
    return ParamDef((dim,), init="ones", spec=P(None), dtype=dtype)


def rmsnorm(w: jax.Array, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32)
    return out.astype(dt)


def layernorm_def(dim: int, dtype):
    return {"scale": ParamDef((dim,), init="ones", spec=P(None), dtype=dtype),
            "bias": ParamDef((dim,), init="zeros", spec=P(None), dtype=dtype)}


def layernorm(p, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    out = out * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return out.astype(dt)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    """Inverse frequencies [head_dim//2] (float32)."""
    i = jnp.arange(0, head_dim, 2, dtype=jnp.float32)
    return 1.0 / (theta ** (i / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., S, H, Dh] (rotate last dim), positions: [..., S] or [S]."""
    dh = x.shape[-1]
    inv = rope_freqs(dh, theta)                         # [Dh/2]
    ang = positions[..., :, None].astype(jnp.float32) * inv  # [..., S, Dh/2]
    cos = jnp.cos(ang)[..., :, None, :]                 # [..., S, 1, Dh/2]
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Dense / MLP
# ---------------------------------------------------------------------------

def dense_def(d_in: int, d_out: int, cfg: ModelConfig, *, tp_out: bool,
              bias: bool = False, tp: int = 16):
    """Weight [d_in, d_out]; tp_out: shard out-dim over 'model' (col-parallel)
    else in-dim over 'model' (row-parallel); the other dim is FSDP 'data'."""
    if tp_out:
        spec = P("data" if divisible(d_in, tp) else None,
                 "model" if divisible(d_out, tp) else None)
        bspec = P("model" if divisible(d_out, tp) else None)
    else:
        spec = P("model" if divisible(d_in, tp) else None,
                 "data" if divisible(d_out, tp) else None)
        bspec = P(None)
    d = {"w": ParamDef((d_in, d_out), init="scaled", spec=spec,
                       dtype=cfg.param_dtype, fan_in=d_in)}
    if bias:
        d["b"] = ParamDef((d_out,), init="zeros", spec=bspec,
                          dtype=cfg.param_dtype)
    return d


def dense(p, x: jax.Array, compute_dtype) -> jax.Array:
    out = jnp.einsum("...i,io->...o", x.astype(compute_dtype),
                     p["w"].astype(compute_dtype))
    if "b" in p:
        out = out + p["b"].astype(compute_dtype)
    return out


def mlp_def(cfg: ModelConfig, d_ff: Optional[int] = None, tp: int = 16):
    d_ff = d_ff or cfg.d_ff
    kind = cfg.ffn_kind
    defs = {}
    if kind in ("swiglu", "geglu"):
        # §Perf: gate and up projections fused into one [D, 2, F] matmul —
        # backward then emits ONE d_x partial all-reduce instead of two
        # (measured on the production mesh; EXPERIMENTS.md §Perf it.2).
        # The gate/up axis is a separate unsharded dim so the split after
        # the matmul never crosses the model-sharded F axis.
        defs["wi"] = {"w": ParamDef(
            (cfg.d_model, 2, d_ff), init="scaled",
            spec=P("data" if divisible(cfg.d_model, tp) else None, None,
                   "model" if divisible(d_ff, tp) else None),
            dtype=cfg.param_dtype, fan_in=cfg.d_model)}
    else:
        defs["wi"] = dense_def(cfg.d_model, d_ff, cfg, tp_out=True, tp=tp)
    defs["wo"] = dense_def(d_ff, cfg.d_model, cfg, tp_out=False, tp=tp)
    return defs


def mlp(p, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    ct = cfg.compute_dtype
    if cfg.ffn_kind in ("swiglu", "geglu"):
        h2 = jnp.einsum("...d,dgf->...gf", x.astype(ct),
                        p["wi"]["w"].astype(ct))
        up, gate = h2[..., 0, :], h2[..., 1, :]
        act = jax.nn.silu if cfg.ffn_kind == "swiglu" else jax.nn.gelu
        h = act(up) * gate
    else:
        h = dense(p["wi"], x, ct)
        h = jax.nn.gelu(h) if cfg.ffn_kind == "gelu" else jax.nn.relu(h)
    return dense(p["wo"], h, ct)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def embedding_def(cfg: ModelConfig, tp: int = 16):
    v = cfg.padded_vocab
    return ParamDef((v, cfg.d_model), init="embed",
                    spec=P("model" if divisible(v, tp) else None,
                           "data" if divisible(cfg.d_model, tp) else None),
                    dtype=cfg.param_dtype)


def embed(table: jax.Array, ids: jax.Array, compute_dtype) -> jax.Array:
    return jnp.take(table, ids, axis=0).astype(compute_dtype)


def unembed_def(cfg: ModelConfig, tp: int = 16):
    v = cfg.padded_vocab
    return ParamDef((cfg.d_model, v), init="scaled",
                    spec=P("data" if divisible(cfg.d_model, tp) else None,
                           "model" if divisible(v, tp) else None),
                    dtype=cfg.param_dtype, fan_in=cfg.d_model)


def unembed(w: jax.Array, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Logits in float32 (softmax numerics)."""
    logits = jnp.einsum("...d,dv->...v", x.astype(cfg.compute_dtype),
                        w.astype(cfg.compute_dtype)).astype(jnp.float32)
    if cfg.logit_softcap:
        c = cfg.logit_softcap
        logits = c * jnp.tanh(logits / c)
    return logits
