"""Mixture-of-Experts FFN: top-k routing with sort-based dispatch.

TPU-native design decision (DESIGN.md §7): instead of the GShard dense
one-hot dispatch einsum — whose [tokens, E, capacity] tensors explode for
DeepSeek-V3's 256 experts — we use sort-based dispatch (argsort of expert
assignments + capacity-bounded scatter/gather), the MaxText-style approach.
Expert FLOPs in the compiled HLO then reflect the *active* (top-k) compute,
which is what the roofline's MODEL_FLOPS ratio wants to see.

Supports: top-k normalized combine weights, capacity factor with token
dropping, shared (always-on) experts (DeepSeek), and the switch-style
load-balance auxiliary loss.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import distributed as dist
from repro.models.config import ModelConfig
from repro.models.layers import dense, dense_def, mlp, mlp_def
from repro.models.param import ParamDef, divisible


def expert_capacity(cfg: ModelConfig, seq: int) -> int:
    cap = math.ceil(cfg.moe_top_k * seq * cfg.capacity_factor
                    / cfg.moe_num_experts)
    return max(4, ((cap + 3) // 4) * 4)


def moe_def(cfg: ModelConfig, tp: int = 16, dp: int = 16):
    e, d, f = cfg.moe_num_experts, cfg.d_model, cfg.expert_d_ff
    e_ax = "data" if divisible(e, dp) else None
    d_ax = None if e_ax == "data" else ("data" if divisible(d, dp) else None)
    f_ax = "model" if divisible(f, tp) else None
    defs = {
        "router": ParamDef((d, e), init="scaled", spec=P(None, None),
                           dtype=jnp.float32, fan_in=d),
        # up+gate fused on an unsharded axis (§Perf it.2): one d_ein
        # all-reduce in backward instead of two
        "wi": ParamDef((e, d, 2, f), init="scaled",
                       spec=P(e_ax, d_ax, None, f_ax),
                       dtype=cfg.param_dtype, fan_in=d),
        "wo": ParamDef((e, f, d), init="scaled", spec=P(e_ax, f_ax, d_ax),
                       dtype=cfg.param_dtype, fan_in=f),
    }
    if cfg.moe_shared_experts:
        defs["shared"] = mlp_def(cfg, d_ff=cfg.expert_d_ff
                                 * cfg.moe_shared_experts, tp=tp)
    return defs


def _dispatch_indices(expert_id: jax.Array, capacity: int, num_experts: int):
    """expert_id: [A] flat assignments. Returns (slot[A], keep[A]).

    slot = expert * capacity + rank-within-expert (rank by token order).
    """
    a = expert_id.shape[0]
    order = jnp.argsort(expert_id, stable=True)          # sorted assignment ids
    sorted_eid = expert_id[order]
    # rank within expert group = position - first index of that expert value
    first = jnp.searchsorted(sorted_eid, sorted_eid, side="left")
    rank_sorted = jnp.arange(a) - first
    rank = jnp.zeros((a,), jnp.int32).at[order].set(rank_sorted.astype(jnp.int32))
    keep = rank < capacity
    slot = jnp.where(keep, expert_id * capacity + rank, num_experts * capacity)
    return slot, keep


def moe_apply(p, x: jax.Array, cfg: ModelConfig):
    """x: [B, S, D] -> (y [B,S,D], aux_loss scalar)."""
    b, s, d = x.shape
    e, k = cfg.moe_num_experts, cfg.moe_top_k
    cap = expert_capacity(cfg, s)
    ct = cfg.compute_dtype

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, k)               # [B,S,K]
    top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)

    # switch-style load-balance loss
    frac_tokens = jnp.mean(
        jax.nn.one_hot(top_e[..., 0], e, dtype=jnp.float32), axis=(0, 1))
    mean_probs = jnp.mean(probs, axis=(0, 1))
    aux = e * jnp.sum(frac_tokens * mean_probs)

    def dispatch_one(xb, eid):
        # xb [S,D]; eid [S,K] -> (expert_in [E,C,D], slot [S*K], keep [S*K])
        flat_e = eid.reshape(-1)                          # [S*K] (s-major)
        slot, keep = _dispatch_indices(flat_e, cap, e)
        tok = jnp.repeat(jnp.arange(s), k)
        buf = jnp.zeros((e * cap + 1, d), ct)
        buf = buf.at[slot].add(jnp.where(keep[:, None], xb[tok].astype(ct), 0))
        return buf[:e * cap].reshape(e, cap, d), slot, keep

    def combine_one(eout, wgt, slot, keep):
        # eout [E,C,D] -> y [S,D]
        tok = jnp.repeat(jnp.arange(s), k)
        flat_out = eout.reshape(e * cap, d)
        contrib = jnp.where(keep[:, None],
                            flat_out[jnp.minimum(slot, e * cap - 1)]
                            * wgt.reshape(-1)[:, None].astype(ct), 0)
        return jnp.zeros((s, d), ct).at[tok].add(contrib)

    # §Perf note: the dispatch/combine scatters must run as *local* per-
    # batch-shard ops.  Left to auto-SPMD, XLA replicates the scatter across
    # the data axis (batch sharding lost), which then drags the expert
    # matmuls into replicated-batch form with ~100 GB/layer of activation
    # all-reduces (measured; see EXPERIMENTS.md §Perf mixtral iteration 1).
    # Wrapping them in shard_map over the batch axes pins them local; the
    # expert einsums stay in auto-SPMD so XLA picks weight-gather sharding.
    mesh = dist.active_mesh()
    if mesh is not None:
        baxes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        bsz_total = 1
        for a in baxes:
            bsz_total *= mesh.shape[a]
        if b % bsz_total:
            baxes = ()
        bspec = P(baxes if baxes else None)
        rep = P(*([None] * 2))

        dispatch = dist.shard_map(
            jax.vmap(dispatch_one),
            mesh=mesh,
            in_specs=(P(bspec[0], None, None), P(bspec[0], None, None)),
            out_specs=(P(bspec[0], None, None, None),
                       P(bspec[0], None), P(bspec[0], None)))
        combine = dist.shard_map(
            jax.vmap(combine_one),
            mesh=mesh,
            in_specs=(P(bspec[0], None, None, None), P(bspec[0], None, None),
                      P(bspec[0], None), P(bspec[0], None)),
            out_specs=P(bspec[0], None, None))
        ein, slot, keep = dispatch(x, top_e)
    else:
        ein, slot, keep = jax.vmap(dispatch_one)(x, top_e)
        combine = jax.vmap(combine_one)

    # (§Perf it.4 tried sharding the capacity axis over 'model' here to
    # localize the expert matmuls — REFUTED: measured collective bytes rose
    # 2.3x because the constraint forced resharding at the shard_map
    # boundaries instead of the hoped-for weight gathers. Reverted.)
    h2 = jnp.einsum("becd,edgf->becgf", ein, p["wi"].astype(ct))
    h = jax.nn.silu(h2[..., 0, :]) * h2[..., 1, :]
    eout = jnp.einsum("becf,efd->becd", h, p["wo"].astype(ct))
    eout = dist.constrain(eout, (dist.batch_logical(), None, None, None))
    y = combine(eout, top_w, slot, keep)

    if cfg.moe_shared_experts:
        y = y + mlp(p["shared"], x, cfg)
    return y.astype(x.dtype), aux
