"""Parameter-definition system.

Models declare a pytree of :class:`ParamDef` (shape + initializer + sharding
spec).  From the same tree we derive:

  * ``init_params``      — materialized arrays (reduced/smoke configs, CPU)
  * ``abstract_params``  — jax.ShapeDtypeStruct stand-ins (dry-run: the full
                           multi-hundred-B configs are lowered without ever
                           allocating a byte)
  * ``param_specs``      — PartitionSpec tree for pjit in_shardings
  * ``param_count``      — exact parameter count for roofline MODEL_FLOPS

This indirection is what lets one model definition serve both the CPU test
path and the 512-chip AOT compilation path.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

PyTree = Any


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: tuple
    init: str = "normal"          # normal | zeros | ones | embed | scaled
    spec: P = P()                 # PartitionSpec over ("data", "model")
    dtype: Any = jnp.float32
    fan_in: Optional[int] = None  # for 'scaled' init: 1/sqrt(fan_in)

    @property
    def size(self) -> int:
        return int(np.prod(self.shape)) if self.shape else 1


def is_def(x) -> bool:
    return isinstance(x, ParamDef)


def _leaves(defs: PyTree):
    return jax.tree.leaves(defs, is_leaf=is_def)


def param_count(defs: PyTree) -> int:
    return sum(d.size for d in _leaves(defs))


def param_bytes(defs: PyTree) -> int:
    return sum(d.size * jnp.dtype(d.dtype).itemsize for d in _leaves(defs))


def param_specs(defs: PyTree) -> PyTree:
    return jax.tree.map(lambda d: d.spec, defs, is_leaf=is_def)


def abstract_params(defs: PyTree) -> PyTree:
    return jax.tree.map(lambda d: jax.ShapeDtypeStruct(d.shape, d.dtype),
                        defs, is_leaf=is_def)


def _init_one(d: ParamDef, key: jax.Array) -> jax.Array:
    if d.init == "zeros":
        return jnp.zeros(d.shape, d.dtype)
    if d.init == "ones":
        return jnp.ones(d.shape, d.dtype)
    fan_in = d.fan_in
    if fan_in is None:
        fan_in = d.shape[-2] if len(d.shape) >= 2 else max(1, d.shape[-1])
    if d.init == "embed":
        # 1/sqrt(d_model): keeps tied-unembedding logits O(1) at init
        scale = 1.0 / math.sqrt(d.shape[-1])
    elif d.init in ("normal", "scaled"):
        scale = 1.0 / math.sqrt(fan_in)
    else:
        raise ValueError(f"unknown init {d.init!r}")
    return (jax.random.normal(key, d.shape, jnp.float32) * scale).astype(d.dtype)


def init_params(defs: PyTree, key: jax.Array) -> PyTree:
    flat, treedef = jax.tree.flatten(defs, is_leaf=is_def)
    keys = jax.random.split(key, len(flat))
    vals = [_init_one(d, k) for d, k in zip(flat, keys)]
    return jax.tree.unflatten(treedef, vals)


# ---------------------------------------------------------------------------
# Sharding-rule helpers (mesh axes: "data" = FSDP, "model" = TP; the "pod"
# axis of the multi-pod mesh only shards the batch).
# ---------------------------------------------------------------------------

def matmul_spec(d_in_shardable: bool, d_out_shardable: bool,
                transpose: bool = False) -> P:
    """Standard 2-D weight sharding: in-dim over 'data', out-dim over 'model'
    (or the Megatron row-parallel transpose)."""
    if transpose:
        return P("model" if d_in_shardable else None,
                 "data" if d_out_shardable else None)
    return P("data" if d_in_shardable else None,
             "model" if d_out_shardable else None)


def divisible(n: int, by: int) -> bool:
    return n % by == 0
