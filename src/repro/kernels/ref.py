"""Pure-jnp oracles for every Pallas kernel (the ground truth in tests)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def ota_aggregate_ref(g: jax.Array, s: jax.Array, z: jax.Array,
                      noise_scale: jax.Array) -> jax.Array:
    """out = sum_m s_m g_m + noise_scale * z  (g: [N, D]).

    Accumulates in f32 and casts on write, matching the Pallas kernel (and
    core.ota.weighted_sum): casting s to a low-precision g dtype before the
    reduction would lose coefficient precision.
    """
    acc = jnp.sum(g.astype(jnp.float32) * s[:, None].astype(jnp.float32),
                  axis=0)
    return (acc + noise_scale.astype(jnp.float32)
            * z.astype(jnp.float32)).astype(g.dtype)


def ota_round_step_ref(g: jax.Array, s: jax.Array, z: jax.Array,
                       noise_scale: jax.Array, params: jax.Array,
                       eta: jax.Array,
                       q_scale: Optional[jax.Array] = None) -> jax.Array:
    """Fused OTA round step on flat arrays (g: [N, D] wire-dtype grads,
    params: [D] f32):

        ghat = sum_m qs_m g_m s_m + noise_scale * z
        out  = params - eta * ghat

    ``q_scale`` is the per-device symmetric dequantization scale of a
    quantized uplink (None for f32/bf16 — the f32 cast dequantizes those).
    Accumulates in f32 end-to-end and casts once on write, matching the
    Pallas kernel.  With an f32 uplink the aggregation expression is
    ``ota_aggregate_ref`` verbatim, which is what keeps the fused path
    bitwise with the unfused flat path.
    """
    gf = g.astype(jnp.float32)
    if q_scale is not None:
        gf = gf * q_scale[:, None].astype(jnp.float32)
    acc = jnp.sum(gf * s[:, None].astype(jnp.float32), axis=0)
    ghat = acc + noise_scale.astype(jnp.float32) * z.astype(jnp.float32)
    return (params.astype(jnp.float32)
            - eta.astype(jnp.float32) * ghat).astype(params.dtype)


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True,
                  window: Optional[int] = None) -> jax.Array:
    """Naive full-score GQA attention. q: [B,Sq,H,Dh]; k,v: [B,Sk,KH,Dh]."""
    b, sq, h, dh = q.shape
    _, sk, kh, _ = k.shape
    g = h // kh
    qg = q.reshape(b, sq, kh, g, dh).astype(jnp.float32)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k.astype(jnp.float32))
    s = s / jnp.sqrt(jnp.asarray(dh, jnp.float32))
    qpos = jnp.arange(sq)
    kpos = jnp.arange(sk)
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window is not None:
        mask &= kpos[None, :] > (qpos[:, None] - window)
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return o.reshape(b, sq, h, dh).astype(q.dtype)


def ssd_ref(x: jax.Array, dt: jax.Array, a_neg: jax.Array, b_mat: jax.Array,
            c_mat: jax.Array) -> jax.Array:
    """Sequential SSD recurrence (the mathematical definition):

        S_t = exp(dt_t a) S_{t-1} + dt_t B_t (x) x_t
        y_t = C_t . S_t

    x: [B,S,H,P]; dt: [B,S,H]; a_neg: [H]; b_mat/c_mat: [B,S,G,N].
    """
    bsz, s, h, p_dim = x.shape
    g = b_mat.shape[2]
    n_dim = b_mat.shape[3]
    rep = h // g
    bh = jnp.repeat(b_mat, rep, axis=2) if rep > 1 else b_mat
    ch = jnp.repeat(c_mat, rep, axis=2) if rep > 1 else c_mat

    def step(state, inp):
        xt, dtt, bt, ct = inp                       # [B,H,P],[B,H],[B,H,N],..
        da = jnp.exp(dtt * a_neg[None, :])          # [B,H]
        state = state * da[..., None, None] + jnp.einsum(
            "bh,bhn,bhp->bhpn", dtt, bt, xt)
        y = jnp.einsum("bhn,bhpn->bhp", ct, state)
        return state, y

    xs = (x.transpose(1, 0, 2, 3).astype(jnp.float32),
          dt.transpose(1, 0, 2).astype(jnp.float32),
          bh.transpose(1, 0, 2, 3).astype(jnp.float32),
          ch.transpose(1, 0, 2, 3).astype(jnp.float32))
    state0 = jnp.zeros((bsz, h, p_dim, n_dim), jnp.float32)
    _, ys = jax.lax.scan(step, state0, xs)
    return ys.transpose(1, 0, 2, 3).astype(x.dtype)
