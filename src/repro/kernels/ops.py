"""Jit'd public wrappers for the Pallas kernels.

On CPU (this container) the kernels execute with interpret=True — the kernel
body runs as traced JAX ops, validating indexing/masking/accumulation logic;
on TPU (the target) the same pallas_call lowers to Mosaic.  Wrappers handle
padding to hardware-aligned tile sizes.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import flash_attention as fa
from repro.kernels import ota_aggregate as oa
from repro.kernels import ssd_scan as ss


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def _pad_to(x: jax.Array, axis: int, mult: int):
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x, size
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), size


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def ota_aggregate(g: jax.Array, s: jax.Array, z: jax.Array,
                  noise_scale: jax.Array, *, block_d: int = 64 * 1024,
                  interpret: Optional[bool] = None) -> jax.Array:
    """Fused OTA aggregation over [N, D] gradients (see ota_aggregate.py)."""
    interpret = _on_cpu() if interpret is None else interpret
    gp, d0 = _pad_to(g, 1, 8 * 128)
    zp, _ = _pad_to(z, 0, 8 * 128)
    blk = min(block_d, gp.shape[1])
    while gp.shape[1] % blk:
        blk //= 2
    out = oa.ota_aggregate_pallas(gp, s, zp,
                                  jnp.asarray(noise_scale, gp.dtype),
                                  block_d=blk, interpret=interpret)
    return out[:d0]


def ota_aggregate_pytree(stacked: jax.Array, s: jax.Array, noise_scale,
                         key: jax.Array, *, block_d: int = 64 * 1024,
                         use_kernel: Optional[bool] = None,
                         interpret: Optional[bool] = None):
    """Fused OTA aggregation over a whole gradient *pytree* in one launch.

    ``stacked`` is a pytree whose every leaf has a leading client axis
    [N, ...].  The leaves are raveled once into a single [N, D] matrix and
    the per-round hot path — sum_m s_m g_m + noise_scale * z, f32
    accumulation — runs as ONE flattened reduction instead of a tree of
    per-leaf weighted sums plus per-leaf noise draws.

    Dispatch: on TPU the reduction is the Pallas ``ota_aggregate`` kernel;
    on CPU it is the pure-jnp oracle ``ref.ota_aggregate_ref`` on the same
    flattened arrays — Pallas interpret mode is a correctness emulator,
    orders of magnitude slower at runtime, so it is only entered when
    ``use_kernel=True`` is forced (as the kernel equivalence tests do).

    The receiver noise is a single fused draw, but it is keyed per leaf
    exactly like ``core.ota.add_receiver_noise`` (split(key, n_leaves),
    leaf l reads normal(keys[l], leaf_size)): the flattened path therefore
    consumes the same randomness and produces the same noise *realizations*
    as the tree-map oracle, so the two paths agree to float rounding.

    Leaf shapes need no alignment — the [N, D] matrix is lane-padded by
    ``ota_aggregate`` below.  Mixed leaf dtypes are accumulated in the
    widest input dtype and cast back per leaf on unflatten.
    """
    from repro.kernels import ref

    leaves, treedef = jax.tree.flatten(stacked)
    sizes = [int(np.prod(l.shape[1:])) for l in leaves]
    dtype = jnp.result_type(*[l.dtype for l in leaves])
    n = leaves[0].shape[0]
    g = jnp.concatenate([l.reshape(n, -1).astype(dtype) for l in leaves],
                        axis=1)
    keys = jax.random.split(key, len(leaves))
    z = jnp.concatenate([jax.random.normal(k, (sz,))
                         for k, sz in zip(keys, sizes)]).astype(dtype)
    if use_kernel is None:
        use_kernel = not _on_cpu()
    if use_kernel:
        out = ota_aggregate(g, s, z, noise_scale, block_d=block_d,
                            interpret=interpret)
    else:
        out = ref.ota_aggregate_ref(g, s, z,
                                    jnp.asarray(noise_scale, dtype))
    offsets = np.cumsum([0] + sizes)
    parts = [out[offsets[i]:offsets[i + 1]].reshape(l.shape[1:]).astype(
        l.dtype) for i, l in enumerate(leaves)]
    return jax.tree.unflatten(treedef, parts)


@functools.partial(jax.jit,
                   static_argnames=("causal", "window", "block_q", "block_k",
                                    "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: Optional[int] = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: Optional[bool] = None) -> jax.Array:
    """Blocked attention [B,Sq,H,Dh] x [B,Sk,KH,Dh] -> [B,Sq,H,Dh]."""
    interpret = _on_cpu() if interpret is None else interpret
    sq, sk = q.shape[1], k.shape[1]
    bq = min(block_q, sq)
    bk = min(block_k, sk)
    qp, sq0 = _pad_to(q, 1, bq)
    kp, _ = _pad_to(k, 1, bk)
    vp, _ = _pad_to(v, 1, bk)
    if not causal and kp.shape[1] != sk:
        raise ValueError("non-causal attention requires Sk % block_k == 0 "
                         "(padded keys would be attended)")
    # padded k positions are masked out by causal (they sit in the future)
    out = fa.flash_attention_pallas(qp, kp, vp, causal=causal, window=window,
                                    block_q=bq, block_k=bk,
                                    interpret=interpret)
    return out[:, :sq0]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x: jax.Array, dt: jax.Array, a_neg: jax.Array,
             b_mat: jax.Array, c_mat: jax.Array, *, chunk: int = 128,
             interpret: Optional[bool] = None) -> jax.Array:
    """Mamba-2 SSD scan [B,S,H,P] -> [B,S,H,P] (see ssd_scan.py)."""
    interpret = _on_cpu() if interpret is None else interpret
    s = x.shape[1]
    ch = min(chunk, s)
    while s % ch:
        ch //= 2
    return ss.ssd_scan_pallas(x, dt, a_neg, b_mat, c_mat, chunk=ch,
                              interpret=interpret)
