"""Jit'd public wrappers for the Pallas kernels.

On CPU (this container) the kernels execute with interpret=True — the kernel
body runs as traced JAX ops, validating indexing/masking/accumulation logic;
on TPU (the target) the same pallas_call lowers to Mosaic.  Wrappers handle
padding to hardware-aligned tile sizes.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import flash_attention as fa
from repro.kernels import ota_aggregate as oa
from repro.kernels import round_step as rs
from repro.kernels import ssd_scan as ss

UPLINK_DTYPES = ("f32", "bf16", "int8")

# int8 symmetric quantization: values map to [-127, 127] (the -128 code is
# unused so the grid is symmetric around zero — standard for weights/grads)
INT8_LEVELS = 127.0


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def _pad_to(x: jax.Array, axis: int, mult: int):
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x, size
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), size


def quantize_uplink(g: jax.Array, uplink_dtype: str):
    """Device-side uplink quantization of the [N, D] precoded gradients.

    Returns ``(wire, q_scale)`` — the array as transmitted plus the
    per-device symmetric dequantization scale (None when the wire dtype
    dequantizes by cast alone):

      f32   passthrough — ``wire is g`` exactly, so the f32 uplink cannot
            move a bit anywhere downstream.
      bf16  round-to-nearest-even cast; dequant is the f32 upcast.
      int8  per-device symmetric scale over the device's full raveled
            gradient: scale_m = max_d |g[m, d]| / 127, wire = round(g /
            scale) clipped to [-127, 127].  Quantization error per element
            is bounded by scale_m / 2.

    The scale rides the round operands next to ``s`` — it is data the
    receiver needs per round, not a compile-time constant.
    """
    if uplink_dtype == "f32":
        return g, None
    if uplink_dtype == "bf16":
        return g.astype(jnp.bfloat16), None
    if uplink_dtype == "int8":
        amax = jnp.max(jnp.abs(g.astype(jnp.float32)), axis=1)
        scale = jnp.maximum(amax, jnp.finfo(jnp.float32).tiny) / INT8_LEVELS
        q = jnp.round(g.astype(jnp.float32) / scale[:, None])
        return jnp.clip(q, -INT8_LEVELS, INT8_LEVELS).astype(jnp.int8), scale
    raise ValueError(f"uplink_dtype must be one of {UPLINK_DTYPES}, "
                     f"got {uplink_dtype!r}")


def dequantize_uplink(wire: jax.Array, q_scale) -> jax.Array:
    """Receiver-side inverse of ``quantize_uplink`` (always f32 out)."""
    gf = wire.astype(jnp.float32)
    if q_scale is None:
        return gf
    return gf * q_scale[:, None].astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def ota_aggregate(g: jax.Array, s: jax.Array, z: jax.Array,
                  noise_scale: jax.Array, *, block_d: int = 64 * 1024,
                  interpret: Optional[bool] = None) -> jax.Array:
    """Fused OTA aggregation over [N, D] gradients (see ota_aggregate.py)."""
    interpret = _on_cpu() if interpret is None else interpret
    gp, d0 = _pad_to(g, 1, 8 * 128)
    zp, _ = _pad_to(z, 0, 8 * 128)
    blk = min(block_d, gp.shape[1])
    while gp.shape[1] % blk:
        blk //= 2
    out = oa.ota_aggregate_pallas(gp, s, zp,
                                  jnp.asarray(noise_scale, gp.dtype),
                                  block_d=blk, interpret=interpret)
    return out[:d0]


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def ota_round_step(g: jax.Array, s: jax.Array, z: jax.Array,
                   noise_scale: jax.Array, params: jax.Array,
                   eta: jax.Array, q_scale=None, *,
                   block_d: int = 64 * 1024,
                   interpret: Optional[bool] = None) -> jax.Array:
    """Fused OTA round step over [N, D] wire-dtype gradients + [D] params
    (see round_step.py): dequantize, weighted-superpose, noise-inject and
    SGD-update in one Pallas launch."""
    interpret = _on_cpu() if interpret is None else interpret
    gp, d0 = _pad_to(g, 1, 8 * 128)
    zp, _ = _pad_to(z, 0, 8 * 128)
    pp, _ = _pad_to(params, 0, 8 * 128)
    blk = min(block_d, gp.shape[1])
    while gp.shape[1] % blk:
        blk //= 2
    qs = jnp.ones_like(s, jnp.float32) if q_scale is None \
        else q_scale.astype(jnp.float32)
    out = rs.ota_round_step_pallas(gp, qs, s, zp,
                                   jnp.asarray(noise_scale, jnp.float32),
                                   pp, jnp.asarray(eta, jnp.float32),
                                   block_d=blk, interpret=interpret)
    return out[:d0]


def ota_round_step_pytree(stacked, s: jax.Array, noise_scale,
                          key: jax.Array, params, eta, *,
                          uplink_dtype: str = "f32",
                          block_d: int = 64 * 1024,
                          use_kernel: Optional[bool] = None,
                          interpret: Optional[bool] = None):
    """The whole flat-path round body — quantized uplink, OTA aggregation,
    receiver noise, SGD step — as ONE fused launch over the raveled model.

    ``stacked`` is the gradient pytree with leading client axis [N, ...];
    ``params`` is the matching parameter pytree (no client axis).  Both are
    raveled to single [N, D] / [D] arrays, devices quantize the precoded
    gradient per ``uplink_dtype`` (``quantize_uplink``), and one kernel
    launch dequantizes, f32-accumulates sum_m s_m g_m + noise_scale * z and
    applies ``p - eta * ghat`` — four XLA ops and two extra HBM round-trips
    collapsed into one pass.  Returns the updated parameter pytree, cast
    back to each leaf's dtype.

    Noise keying is byte-identical to ``ota_aggregate_pytree``: split(key,
    n_leaves), leaf l draws normal(keys[l], leaf_size), concatenated — so
    an f32 uplink consumes the same randomness and computes the same
    expression as the unfused flat path and stays bitwise with it (pinned
    in tests/test_kernels.py).

    Dispatch follows ``ota_aggregate_pytree`` exactly: TPU → Pallas kernel;
    CPU → the pure-jnp flattened oracle ``ref.ota_round_step_ref``
    (interpret mode only when ``use_kernel=True`` is forced, as the
    equivalence tests do).
    """
    from repro.kernels import ref

    g_leaves, _ = jax.tree.flatten(stacked)
    p_leaves, p_def = jax.tree.flatten(params)
    if len(g_leaves) != len(p_leaves):
        raise ValueError("gradient and parameter pytrees do not match")
    sizes = [int(np.prod(l.shape[1:])) for l in g_leaves]
    dtype = jnp.result_type(*[l.dtype for l in g_leaves])
    n = g_leaves[0].shape[0]
    g = jnp.concatenate([l.reshape(n, -1).astype(dtype) for l in g_leaves],
                        axis=1)
    keys = jax.random.split(key, len(g_leaves))
    z = jnp.concatenate([jax.random.normal(k, (sz,))
                         for k, sz in zip(keys, sizes)]).astype(dtype)
    p_flat = jnp.concatenate([jnp.ravel(l).astype(jnp.float32)
                              for l in p_leaves])
    wire, q_scale = quantize_uplink(g, uplink_dtype)
    ns = jnp.asarray(noise_scale, dtype)
    eta32 = jnp.asarray(eta, jnp.float32)
    if use_kernel is None:
        use_kernel = not _on_cpu()
    if use_kernel:
        out = ota_round_step(wire, s, z, ns, p_flat, eta32, q_scale,
                             block_d=block_d, interpret=interpret)
    else:
        out = ref.ota_round_step_ref(wire, s, z, ns, p_flat, eta32,
                                     q_scale=q_scale)
    offsets = np.cumsum([0] + sizes)
    parts = [out[offsets[i]:offsets[i + 1]].reshape(np.shape(l)).astype(
        l.dtype) for i, l in enumerate(p_leaves)]
    return jax.tree.unflatten(p_def, parts)


def ota_aggregate_pytree(stacked: jax.Array, s: jax.Array, noise_scale,
                         key: jax.Array, *, uplink_dtype: str = "f32",
                         block_d: int = 64 * 1024,
                         use_kernel: Optional[bool] = None,
                         interpret: Optional[bool] = None):
    """Fused OTA aggregation over a whole gradient *pytree* in one launch.

    ``stacked`` is a pytree whose every leaf has a leading client axis
    [N, ...].  The leaves are raveled once into a single [N, D] matrix and
    the per-round hot path — sum_m s_m g_m + noise_scale * z, f32
    accumulation — runs as ONE flattened reduction instead of a tree of
    per-leaf weighted sums plus per-leaf noise draws.

    Dispatch: on TPU the reduction is the Pallas ``ota_aggregate`` kernel;
    on CPU it is the pure-jnp oracle ``ref.ota_aggregate_ref`` on the same
    flattened arrays — Pallas interpret mode is a correctness emulator,
    orders of magnitude slower at runtime, so it is only entered when
    ``use_kernel=True`` is forced (as the kernel equivalence tests do).

    The receiver noise is a single fused draw, but it is keyed per leaf
    exactly like ``core.ota.add_receiver_noise`` (split(key, n_leaves),
    leaf l reads normal(keys[l], leaf_size)): the flattened path therefore
    consumes the same randomness and produces the same noise *realizations*
    as the tree-map oracle, so the two paths agree to float rounding.

    Leaf shapes need no alignment — the [N, D] matrix is lane-padded by
    ``ota_aggregate`` below.  Mixed leaf dtypes are accumulated in the
    widest input dtype and cast back per leaf on unflatten.

    ``uplink_dtype`` simulates the quantized uplink on the unfused path:
    the raveled gradients round-trip through ``quantize_uplink`` /
    ``dequantize_uplink`` before aggregation (``"f32"`` is a literal
    no-op — same array object, bitwise today's path).  The fused
    ``ota_round_step_pytree`` applies the identical quantization, so the
    fused and unfused paths see the same wire values for every dtype.
    """
    from repro.kernels import ref

    leaves, treedef = jax.tree.flatten(stacked)
    sizes = [int(np.prod(l.shape[1:])) for l in leaves]
    dtype = jnp.result_type(*[l.dtype for l in leaves])
    n = leaves[0].shape[0]
    g = jnp.concatenate([l.reshape(n, -1).astype(dtype) for l in leaves],
                        axis=1)
    if uplink_dtype != "f32":
        wire, q_scale = quantize_uplink(g, uplink_dtype)
        g = dequantize_uplink(wire, q_scale).astype(dtype)
    keys = jax.random.split(key, len(leaves))
    z = jnp.concatenate([jax.random.normal(k, (sz,))
                         for k, sz in zip(keys, sizes)]).astype(dtype)
    if use_kernel is None:
        use_kernel = not _on_cpu()
    if use_kernel:
        out = ota_aggregate(g, s, z, noise_scale, block_d=block_d,
                            interpret=interpret)
    else:
        out = ref.ota_aggregate_ref(g, s, z,
                                    jnp.asarray(noise_scale, dtype))
    offsets = np.cumsum([0] + sizes)
    parts = [out[offsets[i]:offsets[i + 1]].reshape(l.shape[1:]).astype(
        l.dtype) for i, l in enumerate(leaves)]
    return jax.tree.unflatten(treedef, parts)


@functools.partial(jax.jit,
                   static_argnames=("causal", "window", "block_q", "block_k",
                                    "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: Optional[int] = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: Optional[bool] = None) -> jax.Array:
    """Blocked attention [B,Sq,H,Dh] x [B,Sk,KH,Dh] -> [B,Sq,H,Dh]."""
    interpret = _on_cpu() if interpret is None else interpret
    sq, sk = q.shape[1], k.shape[1]
    bq = min(block_q, sq)
    bk = min(block_k, sk)
    qp, sq0 = _pad_to(q, 1, bq)
    kp, _ = _pad_to(k, 1, bk)
    vp, _ = _pad_to(v, 1, bk)
    if not causal and kp.shape[1] != sk:
        raise ValueError("non-causal attention requires Sk % block_k == 0 "
                         "(padded keys would be attended)")
    # padded k positions are masked out by causal (they sit in the future)
    out = fa.flash_attention_pallas(qp, kp, vp, causal=causal, window=window,
                                    block_q=bq, block_k=bk,
                                    interpret=interpret)
    return out[:, :sq0]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x: jax.Array, dt: jax.Array, a_neg: jax.Array,
             b_mat: jax.Array, c_mat: jax.Array, *, chunk: int = 128,
             interpret: Optional[bool] = None) -> jax.Array:
    """Mamba-2 SSD scan [B,S,H,P] -> [B,S,H,P] (see ssd_scan.py)."""
    interpret = _on_cpu() if interpret is None else interpret
    s = x.shape[1]
    ch = min(chunk, s)
    while s % ch:
        ch //= 2
    return ss.ssd_scan_pallas(x, dt, a_neg, b_mat, c_mat, chunk=ch,
                              interpret=interpret)
