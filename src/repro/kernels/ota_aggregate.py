"""Pallas TPU kernel: fused OTA gradient aggregation.

The paper's per-round hot path (eq. (6)): given per-client gradient shards
g[N, D], per-client coefficients s[N] (= chi_{m,t} * gamma_m / alpha, or any
PowerControl scheme's round coefficients) and a receiver-noise vector z[D]:

    out[d] = sum_m s[m] * g[m, d] + noise_scale * z[d]

TPU-native design (DESIGN.md §7): the gradient axis is tiled into
lane-aligned VMEM blocks (multiples of 8*128); the client axis N is small
(10..32) and lives entirely in each block, so the kernel is a single
VMEM-resident reduction per tile — purely HBM-bandwidth-bound, which is the
roofline this op lives on.  The per-client scalars ride in SMEM via a
(1, N)-blocked spec.

Validated on CPU with interpret=True against ref.ota_aggregate_ref.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128
SUBLANE = 8
DEFAULT_BLOCK_D = 64 * 1024          # elements per tile (256 KB f32)


def _kernel(s_ref, g_ref, z_ref, ns_ref, out_ref):
    # g_ref: [N, BD]; s_ref: [1, N] (SMEM-ish small block); z_ref: [BD]
    s = s_ref[0, :].astype(jnp.float32)          # [N]
    g = g_ref[...].astype(jnp.float32)           # [N, BD]
    acc = jnp.sum(g * s[:, None], axis=0)
    noisy = acc + ns_ref[0].astype(jnp.float32) * z_ref[...].astype(
        jnp.float32)
    out_ref[...] = noisy.astype(out_ref.dtype)


def ota_aggregate_pallas(g: jax.Array, s: jax.Array, z: jax.Array,
                         noise_scale: jax.Array, *,
                         block_d: int = DEFAULT_BLOCK_D,
                         interpret: bool = False) -> jax.Array:
    """g: [N, D] (D a multiple of 8*128 after padding by ops.py);
    s: [N]; z: [D]; noise_scale: scalar. Returns [D]."""
    n, d = g.shape
    block_d = min(block_d, d)
    assert d % block_d == 0, (d, block_d)
    grid = (d // block_d,)

    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, n), lambda i: (0, 0)),          # s (broadcast)
            pl.BlockSpec((n, block_d), lambda i: (0, i)),    # g tile
            pl.BlockSpec((block_d,), lambda i: (i,)),        # z tile
            pl.BlockSpec((1,), lambda i: (0,)),              # noise_scale
        ],
        out_specs=pl.BlockSpec((block_d,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((d,), g.dtype),
        interpret=interpret,
    )(s.reshape(1, n), g, z, noise_scale.reshape(1))
