"""Pallas TPU kernel: the fused OTA round step.

The per-round hot path of the flat aggregation mode used to execute as a
chain of four XLA ops — weighted OTA superposition, noise scaling, noise
injection, SGD parameter update — each making its own pass over the [D]
gradient vector.  This kernel fuses the whole post-gradient round body
into ONE launch (ROADMAP "raw-speed pass"):

    ghat[d]  = sum_m qs[m] * g[m, d] * s[m] + noise_scale * z[d]
    out[d]   = params[d] - eta * ghat[d]

g is the [N, D] matrix of raveled per-device precoded gradients, possibly
quantized for the uplink (a real OTA front-end transmits finite-precision
symbols): ``qs`` is the per-device symmetric dequantization scale riding
the round operands (all-ones for f32/bf16 uplinks — the cast alone
dequantizes those).  Everything accumulates in f32 regardless of the wire
dtype; the output is cast to the params dtype on write.

TPU-native design (DESIGN.md §Kernels): identical tiling to
``ota_aggregate`` — the gradient axis in lane-aligned VMEM blocks
(multiples of 8*128), the small client axis N (10..32) entirely inside
each block, per-device scalars in (1, N)-blocked SMEM-ish specs — but one
HBM round-trip instead of four: per tile the kernel reads the g block, a
z block and a params block and writes one params block, so the op stays
on the HBM-bandwidth roofline it was already bound by while moving ~2x
fewer bytes than the unfused chain (which materializes ghat between ops).

Validated on CPU with interpret=True against ref.ota_round_step_ref.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128
SUBLANE = 8
DEFAULT_BLOCK_D = 64 * 1024          # elements per tile (256 KB f32)


def _kernel(s_ref, qs_ref, g_ref, z_ref, ns_ref, p_ref, eta_ref, out_ref):
    # g_ref: [N, BD]; s_ref/qs_ref: [1, N]; z_ref/p_ref/out_ref: [BD]
    s = s_ref[0, :].astype(jnp.float32)                    # [N]
    qs = qs_ref[0, :].astype(jnp.float32)                  # [N] dequant scale
    g = g_ref[...].astype(jnp.float32) * qs[:, None]       # dequantized [N,BD]
    acc = jnp.sum(g * s[:, None], axis=0)
    ghat = acc + ns_ref[0].astype(jnp.float32) * z_ref[...].astype(jnp.float32)
    upd = p_ref[...].astype(jnp.float32) \
        - eta_ref[0].astype(jnp.float32) * ghat
    out_ref[...] = upd.astype(out_ref.dtype)


def ota_round_step_pallas(g: jax.Array, qs: jax.Array, s: jax.Array,
                          z: jax.Array, noise_scale: jax.Array,
                          params: jax.Array, eta: jax.Array, *,
                          block_d: int = DEFAULT_BLOCK_D,
                          interpret: bool = False) -> jax.Array:
    """g: [N, D] (D a multiple of 8*128 after padding by ops.py, any wire
    dtype incl. int8/bf16); qs/s: [N]; z/params: [D]; noise_scale/eta:
    scalars.  Returns the updated [D] params in params.dtype."""
    n, d = g.shape
    block_d = min(block_d, d)
    assert d % block_d == 0, (d, block_d)
    grid = (d // block_d,)

    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, n), lambda i: (0, 0)),          # s (broadcast)
            pl.BlockSpec((1, n), lambda i: (0, 0)),          # qs (broadcast)
            pl.BlockSpec((n, block_d), lambda i: (0, i)),    # g tile
            pl.BlockSpec((block_d,), lambda i: (i,)),        # z tile
            pl.BlockSpec((1,), lambda i: (0,)),              # noise_scale
            pl.BlockSpec((block_d,), lambda i: (i,)),        # params tile
            pl.BlockSpec((1,), lambda i: (0,)),              # eta
        ],
        out_specs=pl.BlockSpec((block_d,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((d,), params.dtype),
        interpret=interpret,
    )(s.reshape(1, n), qs.reshape(1, n), g, z, noise_scale.reshape(1),
      params, eta.reshape(1))
