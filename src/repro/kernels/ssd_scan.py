"""Pallas TPU kernel: Mamba-2 SSD chunk scan.

One grid cell per (batch, head): the kernel walks the sequence chunk by
chunk, carrying the [P, N] state in VMEM scratch.  Within a chunk the
quadratic form (C B^T with decay weighting) runs as [Q, N] x [N, Q] and
[Q, Q] x [Q, P] MXU matmuls — chunk = 128 aligns the systolic array; the
inter-chunk recurrence is a cheap decay + rank-Q update.

This adapts the SSD algorithm's GPU tiling to TPU: instead of warp-level
tensor-core fragments, whole (128, N) / (128, P) tiles live in VMEM and hit
the MXU directly; the sequential chunk loop stays in-kernel so the state
never round-trips HBM.

Validated with interpret=True against ref.ssd_ref (sequential recurrence).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_CHUNK = 128


def _ssd_kernel(x_ref, dt_ref, aneg_ref, b_ref, c_ref, y_ref, *,
                chunk: int, seq: int):
    # x_ref: [S, P]; dt_ref: [S, 1]; aneg_ref: [1, 1]; b_ref/c_ref: [S, N]
    p_dim = x_ref.shape[-1]
    n_dim = b_ref.shape[-1]
    a_neg = aneg_ref[0, 0]
    nc = seq // chunk

    def body(ci, state):
        sl = pl.ds(ci * chunk, chunk)
        x = pl.load(x_ref, (sl, slice(None))).astype(jnp.float32)
        dt = pl.load(dt_ref, (sl, slice(None)))[:, 0].astype(jnp.float32)
        bm = pl.load(b_ref, (sl, slice(None))).astype(jnp.float32)
        cm = pl.load(c_ref, (sl, slice(None))).astype(jnp.float32)

        da = dt * a_neg                              # [Q] (<= 0)
        cum = jnp.cumsum(da)                         # [Q]
        xw = x * dt[:, None]                         # dt-weighted input

        # intra-chunk: att[i,j] = exp(cum_i - cum_j) (C_i . B_j), i >= j
        scores = cm @ bm.T                           # [Q, Q] MXU
        decay = cum[:, None] - cum[None, :]
        ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
        jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
        att = jnp.where(ii >= jj, scores * jnp.exp(decay), 0.0)
        y = att @ xw                                 # [Q, P] MXU

        # inter-chunk: y_i += C_i . (exp(cum_i) * S_prev)
        y += (jnp.exp(cum)[:, None] * (cm @ state))  # [Q,N]x[N,P]

        # state update: S = exp(cum_Q) S + sum_j exp(cum_Q - cum_j) B_j xw_j^T
        seg = cum[chunk - 1]
        w_in = jnp.exp(seg - cum)                    # [Q]
        state = jnp.exp(seg) * state + (bm * w_in[:, None]).T @ xw  # [N, P]

        pl.store(y_ref, (sl, slice(None)), y.astype(y_ref.dtype))
        return state

    state0 = jnp.zeros((n_dim, p_dim), jnp.float32)
    jax.lax.fori_loop(0, nc, body, state0)


def ssd_scan_pallas(x: jax.Array, dt: jax.Array, a_neg: jax.Array,
                    b_mat: jax.Array, c_mat: jax.Array, *,
                    chunk: int = DEFAULT_CHUNK,
                    interpret: bool = False) -> jax.Array:
    """x: [B,S,H,P]; dt: [B,S,H] (>0); a_neg: [H] (<0);
    b_mat/c_mat: [B,S,G,N] with G dividing H.  Returns y [B,S,H,P].
    """
    bsz, s, h, p_dim = x.shape
    g = b_mat.shape[2]
    n_dim = b_mat.shape[3]
    rep = h // g
    assert s % chunk == 0, (s, chunk)

    xf = x.transpose(0, 2, 1, 3).reshape(bsz * h, s, p_dim)
    dtf = dt.transpose(0, 2, 1).reshape(bsz * h, s, 1)
    af = jnp.tile(a_neg.reshape(1, h), (bsz, 1)).reshape(bsz * h, 1, 1)
    bh = jnp.repeat(b_mat.transpose(0, 2, 1, 3), rep, axis=1) if rep > 1 \
        else b_mat.transpose(0, 2, 1, 3)
    ch = jnp.repeat(c_mat.transpose(0, 2, 1, 3), rep, axis=1) if rep > 1 \
        else c_mat.transpose(0, 2, 1, 3)
    bf = bh.reshape(bsz * h, s, n_dim)
    cf = ch.reshape(bsz * h, s, n_dim)

    kernel = functools.partial(_ssd_kernel, chunk=chunk, seq=s)
    yf = pl.pallas_call(
        kernel,
        grid=(bsz * h,),
        in_specs=[
            pl.BlockSpec((None, s, p_dim), lambda i: (i, 0, 0)),
            pl.BlockSpec((None, s, 1), lambda i: (i, 0, 0)),
            pl.BlockSpec((None, 1, 1), lambda i: (i, 0, 0)),
            pl.BlockSpec((None, s, n_dim), lambda i: (i, 0, 0)),
            pl.BlockSpec((None, s, n_dim), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, s, p_dim), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz * h, s, p_dim), x.dtype),
        interpret=interpret,
    )(xf, dtf, af, bf, cf)

    return yf.reshape(bsz, h, s, p_dim).transpose(0, 2, 1, 3)
