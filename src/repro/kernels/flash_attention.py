"""Pallas TPU kernel: blocked online-softmax attention with optional
causal + sliding-window masking.

MXU-aligned tiling: (block_q=128, block_k=128) score tiles, head_dim padded
to a lane multiple.  Grid = (batch*kv_heads*groups, num_q_blocks); the kv
axis is walked inside the kernel with running (max, denom, acc) online-
softmax state in VMEM scratch — the classic flash pattern rethought for the
(8,128) sublane/lane layout rather than CUDA warps.

Used by the SWA/local-attention archs (mixtral, recurrentgemma) and the
beyond-paper sub-quadratic dense variant.  Validated with interpret=True
against ref.attention_ref.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, block_k: int, seq_k: int,
                 causal: bool, window: Optional[int], scale: float):
    # q_ref: [BQ, Dh]; k_ref/v_ref: [SK, Dh]; o_ref: [BQ, Dh]
    bq, dh = q_ref.shape
    qi = pl.program_id(1)
    q = q_ref[...].astype(jnp.float32) * scale
    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, 1), 0)[:, 0]

    m = jnp.full((bq,), NEG_INF, jnp.float32)
    l = jnp.zeros((bq,), jnp.float32)
    acc = jnp.zeros((bq, dh), jnp.float32)

    num_kb = seq_k // block_k

    def body(kb, carry):
        m_p, l_p, acc_p = carry
        k_blk = pl.load(k_ref, (pl.ds(kb * block_k, block_k),
                                slice(None))).astype(jnp.float32)
        v_blk = pl.load(v_ref, (pl.ds(kb * block_k, block_k),
                                slice(None))).astype(jnp.float32)
        s = q @ k_blk.T                                      # [BQ, BK]
        k_pos = kb * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (1, block_k), 1)[0]
        mask = jnp.ones((bq, block_k), bool)
        if causal:
            mask &= k_pos[None, :] <= q_pos[:, None]
        if window is not None:
            mask &= k_pos[None, :] > (q_pos[:, None] - window)
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m_p, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_p - m_new)
        l_new = l_p * alpha + jnp.sum(p, axis=1)
        acc_new = acc_p * alpha[:, None] + p @ v_blk
        return m_new, l_new, acc_new

    m, l, acc = jax.lax.fori_loop(0, num_kb, body, (m, l, acc))
    o_ref[...] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True,
                           window: Optional[int] = None,
                           block_q: int = DEFAULT_BLOCK_Q,
                           block_k: int = DEFAULT_BLOCK_K,
                           interpret: bool = False) -> jax.Array:
    """q: [B, Sq, H, Dh]; k, v: [B, Sk, KH, Dh]; H = KH * G.

    Returns [B, Sq, H, Dh].  Sq % block_q == 0, Sk % block_k == 0 expected
    (ops.py pads).
    """
    b, sq, h, dh = q.shape
    _, sk, kh, _ = k.shape
    g = h // kh
    scale = 1.0 / (dh ** 0.5)

    # flatten (b, kh, g) into one grid axis; kv is shared within a group
    qf = q.reshape(b, sq, kh, g, dh).transpose(0, 2, 3, 1, 4)
    qf = qf.reshape(b * kh * g, sq, dh)
    kf = jnp.repeat(k.transpose(0, 2, 1, 3).reshape(b * kh, sk, dh), g,
                    axis=0)
    vf = jnp.repeat(v.transpose(0, 2, 1, 3).reshape(b * kh, sk, dh), g,
                    axis=0)

    grid = (b * kh * g, sq // block_q)
    kernel = functools.partial(_attn_kernel, block_k=block_k, seq_k=sk,
                               causal=causal, window=window, scale=scale)
    of = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, block_q, dh), lambda bh, qi: (bh, qi, 0)),
            pl.BlockSpec((None, sk, dh), lambda bh, qi: (bh, 0, 0)),
            pl.BlockSpec((None, sk, dh), lambda bh, qi: (bh, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_q, dh),
                               lambda bh, qi: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * kh * g, sq, dh), q.dtype),
        interpret=interpret,
    )(qf, kf, vf)

    return of.reshape(b, kh, g, sq, dh).transpose(0, 3, 1, 2, 4) \
             .reshape(b, sq, h, dh)
