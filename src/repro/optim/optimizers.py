"""Pytree optimizers (no optax in this container).

The paper's method is plain SGD (eq. (7)); momentum and AdamW are provided
for the non-paper training paths.  API mirrors optax: (init, update) where
update returns (new_params, new_state).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[PyTree], PyTree]
    update: Callable[..., tuple]     # (grads, state, params, lr?) -> (params, state)
    name: str = "opt"


def _zeros_like_f32(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def sgd(lr: float) -> Optimizer:
    def init(params):
        return ()

    def update(grads, state, params, lr_now: Optional[float] = None):
        step = lr_now if lr_now is not None else lr
        new = jax.tree.map(
            lambda p, g: (p.astype(jnp.float32)
                          - step * g.astype(jnp.float32)).astype(p.dtype),
            params, grads)
        return new, state

    return Optimizer(init, update, "sgd")


def sgd_momentum(lr: float, beta: float = 0.9) -> Optimizer:
    def init(params):
        return _zeros_like_f32(params)

    def update(grads, state, params, lr_now: Optional[float] = None):
        step = lr_now if lr_now is not None else lr
        new_m = jax.tree.map(
            lambda m, g: beta * m + g.astype(jnp.float32), state, grads)
        new_p = jax.tree.map(
            lambda p, m: (p.astype(jnp.float32) - step * m).astype(p.dtype),
            params, new_m)
        return new_p, new_m

    return Optimizer(init, update, "sgd_momentum")


class AdamState(NamedTuple):
    mu: PyTree
    nu: PyTree
    count: jax.Array


def adamw(lr: float, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        return AdamState(_zeros_like_f32(params), _zeros_like_f32(params),
                         jnp.zeros((), jnp.int32))

    def update(grads, state, params, lr_now: Optional[float] = None):
        step = lr_now if lr_now is not None else lr
        cnt = state.count + 1
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                          state.mu, grads)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2)
                          * jnp.square(g.astype(jnp.float32)),
                          state.nu, grads)
        bc1 = 1 - b1 ** cnt.astype(jnp.float32)
        bc2 = 1 - b2 ** cnt.astype(jnp.float32)

        def upd(p, m, v):
            mhat = m / bc1
            vhat = v / bc2
            delta = mhat / (jnp.sqrt(vhat) + eps)
            if weight_decay:
                delta = delta + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - step * delta).astype(p.dtype)

        return jax.tree.map(upd, params, mu, nu), AdamState(mu, nu, cnt)

    return Optimizer(init, update, "adamw")


def clip_by_global_norm(grads: PyTree, max_norm: float):
    """Scale grads so that the global l2 norm is <= max_norm.

    Used to enforce Assumption 2 (||g_m|| <= G_max) on the FL clients.
    """
    sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
             for g in jax.tree.leaves(grads))
    norm = jnp.sqrt(sq)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: (g * scale).astype(g.dtype), grads), norm


def get_optimizer(name: str, lr: float, **kw) -> Optimizer:
    if name == "sgd":
        return sgd(lr)
    if name == "sgd_momentum":
        return sgd_momentum(lr, **kw)
    if name == "adamw":
        return adamw(lr, **kw)
    raise ValueError(f"unknown optimizer {name!r}")
