"""repro.optim"""
