"""Fleet telemetry subsystem (DESIGN.md §Telemetry).

Three layers, all opt-in:

``trace``        structured JSONL span/event writer (run id, monotonic
                 clocks, line-atomic appends, kill-and-resume pruning).
``diagnostics``  in-graph Theorem-1 collectors — realized OTA bias power
                 and effective noise variance per [K, S] cell, riding the
                 engine's ``hist.traces`` mechanism.
``report``       ``python -m repro.telemetry.report <run_dir>`` renders
                 the staging-overlap timeline, bias-variance trajectory,
                 staleness histograms and a recompilation audit.

The whole subsystem hangs off one knob: ``fl.driver.run_fleet(...,
telemetry=Telemetry(run_dir))``.  Left at the default ``None``, every
hook stays unset and the compiled programs, key streams and walls are
byte-identical to a build without this package (the bitwise-off
guarantee, pinned by tests/test_telemetry.py).
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Optional

from repro.telemetry.diagnostics import (DIAG_PREFIX, is_diagnostic,
                                         make_metrics_hook)
from repro.telemetry.trace import EVENTS_FILE, Tracer, read_events

__all__ = [
    "DIAG_PREFIX", "EVENTS_FILE", "Telemetry", "Tracer",
    "assert_no_recompile", "chunk_cache_size", "is_diagnostic",
    "make_metrics_hook", "read_events",
]


@dataclasses.dataclass(frozen=True)
class Telemetry:
    """Telemetry configuration handed to ``fl.driver.run_fleet``.

    run_dir      where ``events.jsonl`` lives; the report tool reads the
                 same directory (put the fleet checkpoint next to it to
                 get the bias-variance trajectory in the report too).
    trace        emit the structured event stream (spans for chunk exec,
                 cohort staging, redesign, checkpoint I/O, SCA solves).
    diagnostics  add the in-graph ``bv_*`` Theorem-1 traces to every
                 round's metrics (recorded into FLResult.traces and any
                 fleet checkpoint; keep the setting consistent across a
                 kill-and-resume so trace keys line up).
    kappa_sq     the paper's kappa^2 gradient-dissimilarity constant, so
                 the traced bias power is in the SCA objective's units.

    Overhead contract: diagnostics are a handful of extra scalar
    reductions fused into the already-compiled chunk (no host syncs, no
    extra dispatches); tracing adds one ``block_until_ready`` per chunk
    for honest exec attribution plus O(events) tiny host writes — walls
    may shift, math never does (stream/serial and resume stay bitwise).
    """
    run_dir: str
    trace: bool = True
    diagnostics: bool = True
    kappa_sq: float = 1.0


def chunk_cache_size(chunk) -> Optional[int]:
    """Compiled-program cache size of a placement-built chunk: the jit
    trace cache for ``VmapPlacement`` chunks, the explicit per-(length,
    grid) compile dict for ``ShardedPlacement`` chunks.  None when the
    object exposes neither (nothing to audit)."""
    fn = getattr(chunk, "_cache_size", None)
    return int(fn()) if callable(fn) else None


@contextlib.contextmanager
def assert_no_recompile(*chunks, allowed: int = 0):
    """Assert the compile caches of ``chunks`` grow by at most ``allowed``
    entries across the scope — the reusable form of the inline
    ``chunk._cache_size()`` checks the population tests pinned: operands
    (cohort draws, design leaves) must swap through ONE compiled program.

    Warm the expected shapes before entering (the first call at a new
    chunk length legitimately compiles); then any growth inside the scope
    is a recompilation regression.
    """
    before = []
    for c in chunks:
        size = chunk_cache_size(c)
        if size is None:
            raise ValueError(f"{c!r} exposes no compile cache to audit")
        before.append(size)
    yield
    for c, b in zip(chunks, before):
        now = chunk_cache_size(c)
        if now - b > allowed:
            raise AssertionError(
                f"chunk recompiled: compile cache grew {b} -> {now} "
                f"(allowed growth {allowed}) for {c!r}")
