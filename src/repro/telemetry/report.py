"""Render a fleet run directory's telemetry (DESIGN.md §Telemetry).

    PYTHONPATH=src python -m repro.telemetry.report <run_dir> [--npz PATH]
        [--rounds N]

``run_dir`` holds the ``events.jsonl`` a ``telemetry=``-enabled
``fl.driver.run_fleet`` wrote (for ``benchmarks.fig2 --telemetry`` that
is the task's artifact dir, e.g. ``experiments/fig2``).  Sections:

  timeline     per-chunk staging-lane profile: stage wall, the visible
               wait on the double buffer, the latency hidden behind the
               previous chunk's execution, compile and exec walls — the
               stream-vs-serialized overlap story of ONE run, per chunk.
  solver       SCA redesign summary (count / iters / objective /
               convergence) from the ``sca_solve`` events the staging
               worker emits.
  bias--variance  per-scheme realized Theorem-1 trajectory from the
               ``bv_*`` diagnostic traces riding the newest fleet
               checkpoint in the run dir (``--npz`` overrides).
  staleness    cohort participation + re-entry staleness histograms from
               ``cohort`` events (per-device rounds-since-last-seen).
  recompiles   every ``chunk_compile`` span; lengths that compiled more
               than once are flagged — the recompilation audit.

Everything is plain text on stdout; the tool only reads the run dir.
"""
from __future__ import annotations

import argparse
import glob
import os
import sys
from collections import defaultdict

import numpy as np

from repro.telemetry.trace import EVENTS_FILE, read_events

# staleness buckets: rounds since the drawn device last participated
_BUCKETS = ((0, 0, "0"), (1, 1, "1"), (2, 3, "2-3"), (4, 7, "4-7"),
            (8, np.inf, "8+"))


def _fmt_s(x) -> str:
    return "-" if x is None else f"{x:8.3f}"


def _bar(frac: float, width: int = 24) -> str:
    return "#" * int(round(frac * width))


def header(events) -> None:
    start = next((e for e in events if e["ev"] == "run_start"), None)
    cfg = next((e for e in events if e["ev"] == "fleet_config"), None)
    resumes = [e for e in events if e["ev"] == "run_resume"]
    end = next((e for e in reversed(events) if e["ev"] == "run_end"), None)
    print("run".ljust(12), start["run"] if start else "?")
    if cfg:
        print("fleet".ljust(12),
              f"{len(cfg.get('names', []))} schemes x "
              f"{len(cfg.get('seeds', []))} seeds, "
              f"{cfg.get('num_rounds')} rounds in {cfg.get('chunks')} chunks "
              f"on {cfg.get('placement')}")
        if cfg.get("scenarios"):
            print("scenarios".ljust(12),
                  f"{len(cfg['scenarios'])} stacked: "
                  + ", ".join(cfg["scenarios"]))
        if cfg.get("population"):
            print("population".ljust(12),
                  f"{cfg['population']} devices, cohort "
                  f"{cfg.get('cohort_size')}"
                  f" every {cfg.get('cohort_rounds') or 'chunk'} rounds, "
                  f"stream={cfg.get('stream')}")
    print("resumes".ljust(12), len(resumes),
          ("(at chunks " + ", ".join(str(e.get("start_chunk"))
                                     for e in resumes) + ")"
           if resumes else ""))
    if end:
        print("wall".ljust(12), f"{end.get('wall_s')}s "
              f"({end.get('rounds_done')} rounds, "
              f"{end.get('chunks_done')} chunks)")


def timeline(events) -> None:
    by_chunk: dict = defaultdict(dict)
    for e in events:
        ci = e.get("chunk")
        if not isinstance(ci, int):
            continue
        if e["ev"] == "stage":
            by_chunk[ci]["stage"] = e.get("dur")
            by_chunk[ci]["redesigned"] = e.get("redesigned")
        elif e["ev"] == "stage_wait":
            by_chunk[ci]["wait"] = e.get("dur")
        elif e["ev"] == "chunk_exec":
            by_chunk[ci]["exec"] = e.get("dur")
            by_chunk[ci]["length"] = e.get("length")
        elif e["ev"] == "chunk_compile":
            by_chunk[ci]["compile"] = e.get("dur")
        elif e["ev"] == "ckpt_save":
            by_chunk[ci]["ckpt"] = e.get("dur")
    if not by_chunk:
        print("(no chunk events)")
        return
    print("chunk  len   stage_s   wait_s  hidden_s compile_s    exec_s"
          "    ckpt_s")
    tot = defaultdict(float)
    for ci in sorted(by_chunk):
        row = by_chunk[ci]
        hidden = None
        if row.get("stage") is not None:
            # visible wait < full stage wall => the difference overlapped
            # the previous chunk's device execution (the streaming win);
            # chunks staged inline (no wait event: chunk 0, serialized
            # mode, first chunk after a resume) hid nothing
            hidden = max(row["stage"] - row["wait"], 0.0) \
                if row.get("wait") is not None else 0.0
        cells = [row.get("stage"), row.get("wait"), hidden,
                 row.get("compile"), row.get("exec"), row.get("ckpt")]
        for key, val in zip(("stage", "wait", "hidden", "compile", "exec",
                             "ckpt"), cells):
            if val is not None:
                tot[key] += val
        mark = " *" if row.get("redesigned") else ""
        print(f"{ci:5d} {row.get('length', 0):4d} "
              + " ".join(_fmt_s(c) for c in cells) + mark)
    print("total       "
          + " ".join(_fmt_s(tot.get(k)) for k in
                     ("stage", "wait", "hidden", "compile", "exec", "ckpt")))
    if tot.get("stage"):
        frac = tot["hidden"] / tot["stage"]
        print(f"staging overlap: {tot['hidden']:.3f}s of {tot['stage']:.3f}s"
              f" staging hidden behind execution ({100 * frac:.0f}%)"
              "  [* = cohort redesign in that stage]")


def solver(events) -> None:
    solves = [e for e in events if e["ev"] == "sca_solve"]
    if not solves:
        print("(no sca_solve events)")
        return
    durs = [e.get("dur", 0.0) for e in solves]
    objs = [e["objective_mean"] for e in solves if "objective_mean" in e]
    conv = sum(e.get("converged", 0) for e in solves)
    batch = sum(e.get("batch", 1) for e in solves)
    print(f"{len(solves)} SCA solves ({batch} scenarios), "
          f"{sum(durs):.3f}s total, {np.mean(durs):.4f}s mean")
    if objs:
        print(f"objective mean {np.mean(objs):.4f} "
              f"(range {min(objs):.4f} .. {max(objs):.4f}), "
              f"{conv}/{batch} converged")


def _newest_npz(run_dir: str):
    paths = sorted(glob.glob(os.path.join(run_dir, "*.npz")),
                   key=os.path.getmtime)
    return paths[-1] if paths else None


def bias_variance(npz_path: str, sample_rounds: int) -> None:
    from repro.checkpoint import checkpoint as ckpt
    meta = ckpt.load_meta(npz_path)
    flat = ckpt.load_flat(npz_path)
    names = meta.get("names") or []
    bv = {k[len("traces/"):]: np.asarray(v) for k, v in flat.items()
          if k.startswith("traces/bv_")}
    if not bv:
        print(f"(no bv_* traces in {npz_path} — run with "
              "telemetry diagnostics on)")
        return flat
    t_axis = next(iter(bv.values())).shape[-1]
    pts = sorted(set(np.linspace(0, t_axis - 1, sample_rounds,
                                 dtype=int).tolist()))
    print(f"from {os.path.basename(npz_path)} "
          f"({t_axis} recorded rounds; mean over seeds)")
    names = list(names or range(next(iter(bv.values())).shape[0]))

    def scheme_block(ki, label, indent="  "):
        print(f"{indent}scheme {label}")
        for key in sorted(bv):
            series = bv[key][ki].mean(axis=0)          # [T] over seeds
            vals = " ".join(f"{series[t]:11.4e}" for t in pts)
            print(f"{indent}  {key:<14} {vals}")

    # a scenario-grid run (DESIGN.md §Grid) carries the scenario axis in
    # the checkpoint identity and scenario-major "scenario/scheme" cell
    # names — segment the trajectory per scenario instead of one flat list
    scens = meta.get("scenarios")
    if isinstance(scens, (list, tuple)) and scens \
            and len(names) % len(scens) == 0:
        kb = len(names) // len(scens)
        for ci, sc_name in enumerate(scens):
            print(f"  scenario {sc_name}")
            for ki in range(ci * kb, (ci + 1) * kb):
                label = str(names[ki])
                label = label.split("/", 1)[1] if "/" in label else label
                scheme_block(ki, label, indent="    ")
    else:
        for ki, name in enumerate(names):
            scheme_block(ki, name)
    print("    rounds        "
          + " ".join(f"{t:11d}" for t in pts))
    return flat


def staleness(events, flat) -> None:
    cohort_ev = [e for e in events if e["ev"] == "cohort"
                 and e.get("staleness") is not None]
    if cohort_ev:
        stale = np.concatenate(
            [np.asarray(e["staleness"]).ravel() for e in cohort_ev])
        never = int(np.sum(stale < 0))
        seen = stale[stale >= 0]
        total = stale.size
        print(f"{len(cohort_ev)} cohorts, {total} draws "
              f"({never} first-time participants)")
        rows = [("never", never)]
        rows += [(label, int(np.sum((seen >= lo) & (seen <= hi))))
                 for lo, hi, label in _BUCKETS]
        for label, count in rows:
            frac = count / max(total, 1)
            print(f"  {label:>6} {count:6d} {_bar(frac)}")
        return
    # fallback: participation counts from the checkpoint's cohort record
    if flat is not None and "cohorts_idx" in flat:
        idx = np.asarray(flat["cohorts_idx"])          # [C, S, N]
        uniq, counts = np.unique(idx, return_counts=True)
        print(f"(no cohort events; participation from checkpoint) "
              f"{uniq.size} distinct devices over {idx.shape[0]} cohorts, "
              f"seen {counts.min()}..{counts.max()} times")
        return
    print("(no cohort events — not a population run?)")


def recompiles(events) -> None:
    # a resumed process starts with a cold jit cache, so compiles repeat
    # across run_resume boundaries by design — only a length compiled
    # twice WITHIN one process is a real recompilation
    seg, comp = 0, []
    for e in events:
        if e["ev"] == "run_resume":
            seg += 1
        elif e["ev"] == "chunk_compile":
            comp.append((seg, e))
    if not comp:
        print("(no compiles recorded)")
        return
    by_key = defaultdict(list)
    for sg, e in comp:
        by_key[(sg, e.get("length"))].append(e)
    dupes = 0
    for sg, length in sorted(by_key,
                             key=lambda x: (x[0], x[1] is None, x[1])):
        evs = by_key[(sg, length)]
        flag = "  <-- RECOMPILED" if len(evs) > 1 else ""
        dupes += len(evs) > 1
        print(f"  process {sg} length={length}: {len(evs)} compile(s), "
              + ", ".join(f"{e.get('dur', 0):.2f}s" for e in evs) + flag)
    print(f"{len(comp)} compiles over {len(by_key)} (process, length) "
          "cells" + (f"; {dupes} recompiled" if dupes
                     else " — no recompilation"))


def report(run_dir: str, npz: str = None, sample_rounds: int = 6) -> None:
    events_path = os.path.join(run_dir, EVENTS_FILE)
    if not os.path.exists(events_path):
        raise SystemExit(f"no {EVENTS_FILE} in {run_dir!r} — run with "
                         "telemetry on (e.g. benchmarks.fig2 --telemetry)")
    events = read_events(events_path)
    sections = (("run", lambda: header(events)),
                ("staging-lane timeline", lambda: timeline(events)),
                ("SCA solver", lambda: solver(events)))
    for title, fn in sections:
        print(f"== {title} " + "=" * max(1, 60 - len(title)))
        fn()
        print()
    npz = npz or _newest_npz(run_dir)
    flat = None
    print("== bias--variance trajectory " + "=" * 32)
    if npz:
        flat = bias_variance(npz, sample_rounds)
    else:
        print(f"(no fleet checkpoint .npz in {run_dir} — pass --npz)")
    print()
    print("== cohort staleness " + "=" * 41)
    staleness(events, flat)
    print()
    print("== recompilation audit " + "=" * 38)
    recompiles(events)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        description="Render a telemetry run directory (events.jsonl + "
                    "fleet checkpoint) as a plain-text report.")
    ap.add_argument("run_dir", help="directory holding events.jsonl")
    ap.add_argument("--npz", default=None,
                    help="fleet checkpoint to read bv_* traces from "
                         "(default: newest *.npz in run_dir)")
    ap.add_argument("--rounds", type=int, default=6,
                    help="sampled rounds in the bias--variance table")
    args = ap.parse_args(argv)
    report(args.run_dir, npz=args.npz, sample_rounds=args.rounds)


if __name__ == "__main__":
    main(sys.argv[1:])
