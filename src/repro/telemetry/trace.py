"""Structured JSONL event tracing (DESIGN.md §Telemetry).

One run directory holds one ``events.jsonl``: a flat, append-only stream
of events, one JSON object per line.  Every event carries the run id, a
wall clock (``wall``, epoch seconds — for humans and cross-process
ordering) and a monotonic clock (``mono`` — for in-process durations;
span events additionally carry ``dur``, measured monotonically so NTP
steps can never produce negative spans).

Appends are line-atomic by construction: each event is a single
``write()`` of one ``\\n``-terminated line to a file opened with
``O_APPEND``, behind a process-wide lock — the streaming driver's staging
worker and the main chunk loop interleave whole lines, never bytes.  A
kill can at worst truncate the final line; the resume path drops partial
trailing lines.

Kill-and-resume contract: ``Tracer(run_dir, fresh=False)`` re-opens an
existing log preserving its run id, and ``resume(start_chunk)`` prunes it
to exactly the events of completed chunks — every event tagged with
``chunk >= start_chunk`` is dropped (those chunks re-run and re-emit),
untagged non-lifecycle events are dropped too (they cannot be attributed,
so they may not be double-counted), and a ``run_resume`` marker is
appended.  A resumed run therefore produces ONE consistent log: no
duplicated chunk spans, no lost completed spans, a single run id.
"""
from __future__ import annotations

import contextlib
import json
import os
import threading
import time
import uuid
from typing import List, Optional

EVENTS_FILE = "events.jsonl"

# lifecycle events survive resume pruning even though they carry no chunk
# tag: they record the history of the run, not per-chunk work
_LIFECYCLE = ("run_start", "run_resume")


def _jsonify(obj):
    """json.dumps default= hook: numpy scalars/arrays -> python."""
    if hasattr(obj, "tolist"):
        return obj.tolist()
    if hasattr(obj, "item"):
        return obj.item()
    return str(obj)


def read_events(path: str) -> List[dict]:
    """Parse an events.jsonl (or the run dir holding one) into a list,
    skipping partial (killed-mid-write) lines."""
    if os.path.isdir(path):
        path = os.path.join(path, EVENTS_FILE)
    out = []
    with open(path) as f:
        for line in f:
            if not line.endswith("\n"):
                continue                   # partial trailing line
            try:
                out.append(json.loads(line))
            except json.JSONDecodeError:
                continue
    return out


class Tracer:
    """Low-overhead span/event writer for one run directory.

    fresh=True   truncate any existing log and start a new run id.
    fresh=False  re-open the existing log (kill-and-resume): the run id
                 is read back from its ``run_start`` line; call
                 ``resume(start_chunk)`` once the driver knows which
                 chunk it fast-forwarded to.  A missing log degrades to
                 a fresh start.
    """

    def __init__(self, run_dir: str, fresh: bool = True):
        os.makedirs(run_dir, exist_ok=True)
        self.run_dir = run_dir
        self.path = os.path.join(run_dir, EVENTS_FILE)
        self._lock = threading.Lock()
        self._local = threading.local()
        self.run_id: Optional[str] = None
        if not fresh or not os.path.exists(self.path):
            if os.path.exists(self.path):
                for ev in read_events(self.path):
                    if ev.get("ev") == "run_start":
                        self.run_id = ev.get("run")
                        break
        if self.run_id is None:
            self.run_id = uuid.uuid4().hex[:12]
            with open(self.path, "w"):
                pass                       # truncate: this is a new run
            self.event("run_start")

    # -- context tags -------------------------------------------------------

    @contextlib.contextmanager
    def ctx(self, **fields):
        """Thread-local default fields merged into every event emitted
        inside the scope — how the driver tags solver events fired deep
        inside a staging thread with the chunk they belong to."""
        old = getattr(self._local, "ctx", {})
        self._local.ctx = {**old, **fields}
        try:
            yield
        finally:
            self._local.ctx = old

    # -- emission -----------------------------------------------------------

    def event(self, kind: str, **fields) -> None:
        rec = {"ev": kind, "run": self.run_id,
               **getattr(self._local, "ctx", {}), **fields}
        rec["wall"] = round(time.time(), 6)
        rec["mono"] = round(time.monotonic(), 6)
        line = json.dumps(rec, default=_jsonify) + "\n"
        with self._lock:
            with open(self.path, "a") as f:
                f.write(line)

    @contextlib.contextmanager
    def span(self, kind: str, **fields):
        """Emit ``kind`` with a monotonic ``dur`` on scope exit."""
        t0 = time.monotonic()
        try:
            yield
        finally:
            self.event(kind, dur=round(time.monotonic() - t0, 6), **fields)

    # -- resume -------------------------------------------------------------

    def resume(self, start_chunk: int) -> None:
        """Prune the re-opened log to completed chunks (< ``start_chunk``)
        and mark the resume.  Atomic: the pruned log replaces the old one
        via ``os.replace``, so a kill during pruning loses nothing."""
        kept = [ev for ev in read_events(self.path)
                if ev.get("ev") in _LIFECYCLE
                or (isinstance(ev.get("chunk"), int)
                    and ev["chunk"] < start_chunk)]
        tmp = self.path + ".tmp"
        with open(tmp, "w") as f:
            for ev in kept:
                f.write(json.dumps(ev, default=_jsonify) + "\n")
        os.replace(tmp, self.path)
        self.event("run_resume", start_chunk=int(start_chunk))
