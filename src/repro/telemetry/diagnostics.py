"""In-graph Theorem-1 diagnostics (DESIGN.md §Telemetry).

The paper's convergence bound trades a *bias* term — the realized
aggregation weights deviating from uniform 1/N — against a *variance*
term: effective receiver noise plus participation randomness.  The
runtime's loss/accuracy traces validate that trade-off only indirectly;
this module computes the two sides per round, ON the realized design and
the drawn channel, inside the compiled chunk.

``make_metrics_hook`` returns a collector the engine's round body calls
right after the OTA coefficients are fixed: the realized per-device
weights ``s`` and the realized ``noise_scale`` — the exact quantities the
aggregation consumed, so the diagnostics can never disagree with the
update they describe.  The hook reuses ``solvers.theory_jax.bias_term``
so the traced bias power is the same map the SCA objective optimizes,
evaluated at the realized participation pattern instead of its
expectation.

Everything is a scalar f32 riding the existing ``hist.traces`` mechanism
([K, S, T] per metric): no new outputs shapes, no host syncs, and — with
the hook left at its default ``None`` — no change to the compiled program
at all (the bitwise-off guarantee).
"""
from __future__ import annotations

import types
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.solvers import theory_jax

# every diagnostic trace is namespaced so tools (and the report renderer)
# can select them without a registry
DIAG_PREFIX = "bv_"


def make_metrics_hook(kappa_sq: float = 1.0) -> Callable:
    """Build the per-round collector.

    ``kappa_sq`` is the paper's gradient-dissimilarity bound (kappa^2 in
    Theorem 1) so the traced bias power is in the objective's units; the
    default 1.0 degrades gracefully to the pure geometric deviation when
    the caller doesn't know the constant.

    The hook signature matches the engine's call site:

        hook(s=..., noise_scale=..., h=..., params=...) -> {name: scalar}

    with ``s`` [N] the realized aggregation weights, ``noise_scale`` the
    realized receiver-noise multiplier, ``h`` [N] the drawn channel, and
    ``params`` the (pre-update) model pytree — used only for its static
    leaf sizes, to convert per-coordinate noise into the d-dimensional
    effective variance of Theorem 1.
    """
    # bias_term only reads kappa_sq off its parameter container, so a
    # one-field namespace stands in for a full SolverParams
    kprm = types.SimpleNamespace(kappa_sq=jnp.float32(float(kappa_sq)))

    def hook(s, noise_scale, h, params):
        n = s.shape[-1]
        d = sum(int(leaf.size) for leaf in jax.tree.leaves(params))
        tot = jnp.sum(s)
        # realized participation weights; an all-truncated round (s == 0)
        # realizes the uniform point, i.e. zero bias by convention
        pm = jnp.where(tot > 0, s / jnp.where(tot == 0, 1.0, tot), 1.0 / n)
        return {
            # Theorem-1 bias power at the REALIZED participation pattern
            DIAG_PREFIX + "bias_power": jnp.asarray(
                theory_jax.bias_term(pm, kprm), jnp.float32),
            # raw deviation of the realized weights from uniform (captures
            # scaling bias that the normalized pm hides)
            DIAG_PREFIX + "weight_dev": jnp.asarray(
                jnp.sum(jnp.square(s - 1.0 / n)), jnp.float32),
            # effective noise variance of the update: E||noise||^2 over
            # the d model coordinates at the realized noise multiplier
            DIAG_PREFIX + "noise_var": jnp.asarray(
                d * jnp.square(noise_scale), jnp.float32),
            # realized channel power entering the round (mean over devices)
            DIAG_PREFIX + "chan_power": jnp.asarray(
                jnp.mean(jnp.square(jnp.abs(h))), jnp.float32),
        }

    return hook


def is_diagnostic(trace_name: str) -> bool:
    return trace_name.startswith(DIAG_PREFIX)
