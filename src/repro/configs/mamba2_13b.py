"""mamba2-1.3b — attention-free SSM with SSD (state-space duality)
[arXiv:2405.21060].

48L d_model=2048 vocab=50280, ssm_state=128, headdim=64, expand=2 — no
attention, no MLP (the Mamba-2 block IS the layer).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    arch_type="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=1,                  # unused (attention-free)
    n_kv_heads=1,
    d_ff=0,
    vocab_size=50280,
    block_pattern=("ssd",),
    ffn_kind="none",
    ssm_state=128,
    ssm_headdim=64,
    ssm_ngroups=1,
    ssm_expand=2,
    ssm_conv=4,
    ssm_chunk=128,
    tie_embeddings=True,
)

LONG_CONTEXT_OK = True          # O(1)-state decode
