"""The paper's own experiment configuration (§IV).

MNIST-like 10-class 28x28 task, 1-hidden-layer MLP (d = 814,090), N = 10
devices in a 1750 m disk, non-iid 2-labels-per-device split, full-batch
local gradients, G_max = 10.
"""
from __future__ import annotations

import dataclasses

from repro.core.channel import WirelessConfig


@dataclasses.dataclass(frozen=True)
class PaperExperiment:
    num_devices: int = 10
    samples_per_class: int = 1000
    num_classes: int = 10
    labels_per_device: int = 2
    max_devices_per_label: int = 2
    gmax: float = 10.0
    local_batch: int = 0          # 0 = full batch (sigma_m = 0, as in §IV)
    num_rounds: int = 400
    eta: float = 0.05             # grid-searched per scheme in benchmarks
    seed: int = 0

    def wireless(self) -> WirelessConfig:
        return WirelessConfig(num_devices=self.num_devices, seed=self.seed)


CONFIG = PaperExperiment()
