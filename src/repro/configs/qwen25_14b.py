"""qwen2.5-14b — dense GQA with QKV bias [hf:Qwen/Qwen2.5 family].

48L d_model=5120 40H (GQA kv=8) d_ff=13824 vocab=152064.

Note: 40 heads do not divide the 16-way model axis; attention projections
fall back to FSDP-only sharding (see models/param.divisible) — this is one
of the roofline hillclimb candidates.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-14b",
    arch_type="dense",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=13824,
    vocab_size=152064,
    block_pattern=("attn",),
    ffn_kind="swiglu",
    qkv_bias=True,
    rope_theta=1e6,
)

LONG_CONTEXT_OK = False
