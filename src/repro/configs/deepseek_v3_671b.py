"""deepseek-v3-671b — MoE 256 routed + 1 shared (top-8), MLA, MTP
[arXiv:2412.19437].

61L d_model=7168 128H (MLA) moe_d_ff=2048 vocab=129280; first 3 layers dense
(d_ff=18432); q_lora=1536, kv_lora=512, rope=64, nope=128, v=128; 1 MTP module.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    arch_type="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    d_ff=18432,                 # dense layers (first 3)
    vocab_size=129280,
    block_pattern=("attn",),
    ffn_kind="swiglu",
    attn_kind="mla",
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_rope_head_dim=64,
    qk_nope_head_dim=128,
    v_head_dim=128,
    moe_num_experts=256,
    moe_top_k=8,
    moe_shared_experts=1,
    moe_d_ff=2048,
    moe_first_dense=3,
    mtp_depth=1,
)

LONG_CONTEXT_OK = False         # MLA compresses KV but attention stays O(seq)
