"""seamless-m4t-medium — enc-dec multimodal (audio) backbone [arXiv:2308.11596].

12L d_model=1024 16H (GQA kv=16) d_ff=4096 vocab=256206.

The audio frontend (mel-spectrogram + conv feature extractor) is a STUB per
the assignment carve-out: input_specs() supplies precomputed frame embeddings
[B, S_frames, d_model]; this config is the transformer backbone that
consumes them (12 encoder + 12 decoder layers).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    arch_type="audio",
    n_layers=12,                # decoder layers
    encoder_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=256206,
    block_pattern=("attn",),
    ffn_kind="gelu",
    input_mode="frames",        # encoder consumes stub frame embeddings
)

LONG_CONTEXT_OK = False         # full attention
