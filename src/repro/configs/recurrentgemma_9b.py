"""recurrentgemma-9b — hybrid RG-LRU + local attention, 1 attn : 2 recurrent
[arXiv:2402.19427].

38L d_model=4096 16H (MQA kv=1) d_ff=12288 vocab=256000, lru_width=4096,
local attention window 2048.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    arch_type="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab_size=256000,
    block_pattern=("rglru", "rglru", "local"),
    window=2048,
    lru_width=4096,
    ffn_kind="geglu",
    logit_softcap=30.0,
)

LONG_CONTEXT_OK = True          # recurrent state + bounded local window
