"""Architecture registry: ``--arch <id>`` -> ModelConfig.

Every entry cites its source paper / model card in the module docstring.
"""
from __future__ import annotations

import importlib
from typing import Optional

from repro.configs.shapes import SHAPES, InputShape, get_shape  # noqa: F401

_MODULES = {
    "granite-8b": "repro.configs.granite_8b",
    "seamless-m4t-medium": "repro.configs.seamless_m4t_medium",
    "mixtral-8x22b": "repro.configs.mixtral_8x22b",
    "recurrentgemma-9b": "repro.configs.recurrentgemma_9b",
    "deepseek-v3-671b": "repro.configs.deepseek_v3_671b",
    "chameleon-34b": "repro.configs.chameleon_34b",
    "qwen1.5-0.5b": "repro.configs.qwen15_05b",
    "qwen3-1.7b": "repro.configs.qwen3_17b",
    "mamba2-1.3b": "repro.configs.mamba2_13b",
    "qwen2.5-14b": "repro.configs.qwen25_14b",
}

ARCH_IDS = tuple(_MODULES)


def _module(arch: str):
    if arch not in _MODULES:
        raise ValueError(f"unknown arch {arch!r}; available: {ARCH_IDS}")
    return importlib.import_module(_MODULES[arch])


def get_config(arch: str):
    return _module(arch).CONFIG


def long_context_ok(arch: str) -> bool:
    return bool(getattr(_module(arch), "LONG_CONTEXT_OK", False))


def long_context_config(arch: str):
    """Config used for the long_500k shape (may be a sub-quadratic variant)."""
    mod = _module(arch)
    cfg = mod.CONFIG
    variant = getattr(mod, "LONG_CONTEXT_VARIANT", None)
    return cfg.replace(**variant) if variant else cfg


def supported_shapes(arch: str) -> tuple:
    """Shapes this arch runs, per DESIGN.md §Arch-applicability."""
    names = ["train_4k", "prefill_32k", "decode_32k"]
    if long_context_ok(arch):
        names.append("long_500k")
    return tuple(names)
