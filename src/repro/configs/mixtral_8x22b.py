"""mixtral-8x22b — sparse MoE, 8 experts top-2, sliding-window attention
[arXiv:2401.04088].

56L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=32768, MoE 8e top-2, SWA.
"""
from repro.models.config import ModelConfig

SWA_WINDOW = 4096

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    arch_type="moe",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=32768,
    block_pattern=("swa",),
    window=SWA_WINDOW,
    ffn_kind="swiglu",
    moe_num_experts=8,
    moe_top_k=2,
    rope_theta=1e6,
)

LONG_CONTEXT_OK = True          # native SWA => bounded KV ring cache
