"""qwen1.5-0.5b — dense with QKV bias [hf:Qwen/Qwen1.5-0.5B].

24L d_model=1024 16H (GQA kv=16) d_ff=2816 vocab=151936.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-0.5b",
    arch_type="dense",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=2816,
    vocab_size=151936,
    block_pattern=("attn",),
    ffn_kind="swiglu",
    qkv_bias=True,
    rope_theta=1e6,
)

# Dense full attention, but this arch carries the beyond-paper sub-quadratic
# variant: long_500k runs with a sliding-window (4096) attention config.
LONG_CONTEXT_OK = True
LONG_CONTEXT_VARIANT = dict(block_pattern=("swa",), window=4096)
