"""chameleon-34b — early-fusion VLM, VQ image tokens [arXiv:2405.09818].

48L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=65536.

Early fusion: images are VQ-quantized into tokens drawn from the SAME 65536
vocabulary as text, so the backbone is token-in/token-out — the VQ-VAE image
tokenizer is the stubbed frontend (input_specs() interleaves image-token
spans into the stream). Uses qk-norm for training stability (paper §2.2).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b",
    arch_type="vlm",
    n_layers=48,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=22016,
    vocab_size=65536,
    block_pattern=("attn",),
    ffn_kind="swiglu",
    qk_norm=True,
)

LONG_CONTEXT_OK = False
