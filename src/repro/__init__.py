"""repro: Non-Convex Over-the-Air Heterogeneous Federated Learning in JAX.

Paper: Abrar & Michelusi, 2025 — biased OTA-FL SGD, bias-variance trade-off,
SCA power control. See DESIGN.md.
"""
__version__ = "1.0.0"
