"""Scan-compiled batched FL experiment engine (DESIGN.md §Engine).

The paper's experiments are sweeps — schemes x seeds x scenarios — but a
host Python loop over rounds pays per-round dispatch, host->device batch
copies, and one compilation per scheme, and can never batch the grid.  This
module folds the FL round loop into XLA:

* ``make_round_body`` — one round as a pure function (gradients, fading,
  OTA aggregation, PS update), shared by every runtime below and by the
  legacy ``fl.server.make_round_fn`` wrapper.  Minibatches are sampled
  *on device* from the round key's ``k_batch`` lane.
* ``run_rounds`` — single (scheme, seed) run with the round loop compiled
  as chunked ``lax.scan`` (chunk boundaries = the eval cadence, so at most
  three chunk lengths ever compile).  Bit-identical to the legacy Python
  loop on the default path: the key stream, fading draws and update math
  are the same ops in the same order.
* ``run_fleet`` — a [K-scheme x S-seed] grid in ONE compiled program:
  schemes are stacked into a pytree (``power_control.stack_schemes``) and
  the scanned round body is vmapped over (scheme, seed) cells.  Each cell
  reproduces the corresponding single run run-for-run.

Per-round metric traces (grad-norm mean, active devices, noise scale) come
back as stacked arrays straight from the scan — no per-round host sync.

Aggregation inside the round body is switchable: ``flat=False`` uses the
per-leaf tree-map oracle (bitwise-stable reference), ``flat=True`` ravels
the gradient pytree once and runs one fused flattened aggregation
(``kernels.ops.ota_aggregate_pytree`` — the Pallas ``ota_aggregate``
kernel on TPU, the flattened jnp oracle on CPU) with f32 accumulation and
a single fused noise draw whose per-leaf keying reproduces the tree path's
realizations, so the two paths agree to float rounding.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ota
from repro.core.power_control import PowerControl, stack_schemes
from repro.optim.optimizers import clip_by_global_norm

PyTree = Any

# key folded into the run seed for FadingProcess state init (must match
# fl.server.run_fl_legacy so engine and legacy runs share state streams)
FADING_INIT_SALT = 0x5CE7A810


@dataclasses.dataclass
class FLResult:
    """What a compiled run returns.

    params        final parameters; leading [K, S] axes for fleet runs
    traces        per-round metric traces as arrays: {name: [T]} for single
                  runs, {name: [K, S, T]} for fleets
    evals         [(round, {name: scalar-or-[K, S] array})] at the eval
                  cadence (empty when no eval_fn was given)
    names         scheme names, length K (single runs: (scheme.name,))
    seeds         seeds swept, length S
    wall          wall-clock seconds, compile included
    fading_state  final FadingProcess state (None on the i.i.d. path)
    designs       adaptive-scheme design trace: [(round, gamma [K, S, N])]
                  with entry (t, g) meaning design g is in effect from
                  round t (None for non-adaptive runs)
    """
    params: PyTree
    traces: dict
    evals: list
    names: tuple
    seeds: tuple
    wall: float
    fading_state: Any = None
    designs: Optional[list] = None


def make_round_body(loss_fn: Callable, gains: np.ndarray, run,
                    fading=None, flat: bool = False,
                    sample_on_device: bool = True) -> Callable:
    """One FL round as a pure function.

        body(scheme, eta, params, fading_state, key, data)
            -> (params, fading_state, metrics)

    ``scheme`` is a PowerControl pytree (so it may be a vmapped row of a
    stacked fleet), ``eta`` a scalar step size (vmappable per scheme),
    ``data`` the stacked per-device datasets (x [N, D, ...], y [N, D]).

    The round key is split exactly like the legacy loop —
    (k_fade, k_ota, k_batch) — with k_batch now actually consumed: when
    ``sample_on_device`` and 0 < run.batch_size < D, each device's
    minibatch is gathered on device (uniform with replacement, the same
    sampling law as the legacy host-numpy path).  The default full-batch
    path consumes keys and data identically to the legacy round function,
    so trajectories are bit-for-bit reproducible against it.
    """
    gains_j = jnp.asarray(gains)

    def device_grad(params, batch):
        g = jax.grad(loss_fn)(params, batch)
        if run.clip_to_gmax:
            g, norm = clip_by_global_norm(g, run.gmax)
        else:
            norm = jnp.sqrt(sum(jnp.sum(jnp.square(l))
                                for l in jax.tree.leaves(g)))
        return g, norm

    def sample(data, k_batch):
        x_dev, y_dev = data
        d = x_dev.shape[1]
        if not sample_on_device or run.batch_size <= 0 \
                or run.batch_size >= d:
            return data
        idx = jax.random.randint(k_batch, (x_dev.shape[0], run.batch_size),
                                 0, d)
        xb = jnp.take_along_axis(
            x_dev, idx.reshape(idx.shape + (1,) * (x_dev.ndim - 2)), axis=1)
        yb = jnp.take_along_axis(y_dev, idx, axis=1)
        return xb, yb

    def body(scheme, eta, params, fading_state, key, data):
        k_fade, k_ota, k_batch = jax.random.split(key, 3)
        batch = sample(data, k_batch)
        grads, norms = jax.vmap(lambda b: device_grad(params, b))(batch)
        if fading is None:
            h = ota.draw_fading(k_fade, gains_j)
        else:
            fading_state, h = fading.step(fading_state, k_fade)
        # coefficients once, threaded into both the aggregation and the
        # metrics — they can never disagree (bbfl_alternative randomizes
        # round_coeffs, so recomputing from a different key split would).
        k_coeff, k_noise = ota.split_ota_key(k_ota)
        s, noise_scale = scheme.round_coeffs(h, k_coeff)
        g_hat = ota.apply_round_coeffs(grads, s, noise_scale, k_noise,
                                       flat=flat)
        params = jax.tree.map(
            lambda p, g: (p.astype(jnp.float32)
                          - eta * g.astype(jnp.float32)).astype(p.dtype),
            params, g_hat)
        metrics = {
            "grad_norm_mean": jnp.mean(norms),
            "active_devices": jnp.sum((s > 0).astype(jnp.float32)),
            "noise_scale": jnp.asarray(noise_scale, jnp.float32),
        }
        return params, fading_state, metrics

    return body


def chunk_lengths(num_rounds: int, eval_every: int,
                  with_eval: bool) -> list:
    """Scan chunk lengths whose boundaries hit the legacy eval cadence
    (t % eval_every == 0 or t == num_rounds - 1).  At most three distinct
    lengths occur — {1, eval_every, tail} — so at most three scan programs
    ever compile per engine."""
    if num_rounds <= 0:
        return []
    if not with_eval:
        return [num_rounds]
    pts = sorted(set(range(0, num_rounds, eval_every)) | {num_rounds - 1})
    lengths, prev = [], -1
    for t in pts:
        lengths.append(t - prev)
        prev = t
    return lengths


def _scan_chunk(round_body, scheme, eta, params, fading_state, key, data,
                length: int):
    """``length`` rounds of ``round_body`` under lax.scan; returns stacked
    per-round metrics.  The main key is split once per round, exactly like
    the legacy host loop."""
    def step(carry, _):
        params, fading_state, key = carry
        key, sub = jax.random.split(key)
        params, fading_state, metrics = round_body(scheme, eta, params,
                                                   fading_state, sub, data)
        return (params, fading_state, key), metrics

    (params, fading_state, key), metrics = jax.lax.scan(
        step, (params, fading_state, key), None, length=length)
    return params, fading_state, key, metrics


def _concat_traces(chunks: list) -> dict:
    if not chunks:
        return {}
    return {k: np.concatenate([np.asarray(c[k]) for c in chunks], axis=-1)
            for k in chunks[0]}


def run_rounds(loss_fn: Callable, params: PyTree, scheme: PowerControl,
               gains: np.ndarray, data: tuple, run,
               eval_fn: Optional[Callable] = None, fading=None,
               flat: bool = False, log: bool = False) -> FLResult:
    """Single (scheme, seed) run with the round loop compiled as chunked
    lax.scan.  Bit-identical to ``fl.server.run_fl_legacy`` on the default
    full-batch path; with 0 < run.batch_size < D minibatches are sampled on
    device from the round key (the legacy host-numpy sampling stream is
    retired with the host loop)."""
    t0 = time.time()
    round_body = make_round_body(loss_fn, gains, run, fading=fading,
                                 flat=flat)
    # scheme and eta are *closed over*, not passed as operands: the legacy
    # per-round jit embeds them as constants, and constant-vs-operand flips
    # XLA constant folding enough to break bitwise equality with it.
    chunk = jax.jit(
        functools.partial(_scan_chunk, round_body, scheme, run.eta),
        static_argnames=("length",))
    data = tuple(jnp.asarray(a) for a in data)
    key = jax.random.PRNGKey(run.seed)
    fading_state = None
    if fading is not None:
        fading_state = fading.init(jax.random.fold_in(key, FADING_INIT_SALT))

    evals, metric_chunks, t = [], [], 0
    for length in chunk_lengths(run.num_rounds, run.eval_every,
                                eval_fn is not None):
        params, fading_state, key, metrics = chunk(
            params, fading_state, key, data, length=length)
        metric_chunks.append(metrics)
        t += length
        if eval_fn is not None:
            ev = {k: float(v) for k, v in eval_fn(params).items()}
            evals.append((t - 1, ev))
            if log:
                print({"round": t - 1, "scheme": scheme.name,
                       **{k: round(v, 4) for k, v in ev.items()}})
    return FLResult(params=params, traces=_concat_traces(metric_chunks),
                    evals=evals, names=(scheme.name,), seeds=(run.seed,),
                    wall=time.time() - t0, fading_state=fading_state)


def run_fleet(loss_fn: Callable, params: PyTree, schemes, gains: np.ndarray,
              data: tuple, run, eval_fn: Optional[Callable] = None, *,
              etas=None, seeds: Optional[Sequence[int]] = None, fading=None,
              flat: bool = True, log: bool = False) -> FLResult:
    """A [K-scheme x S-seed] experiment grid as ONE compiled scan program.

    ``schemes``: a list of PowerControl objects (stacked here via
    ``stack_schemes`` — heterogeneous mixes dispatch through the
    SchemeBatch union) or an already-stacked fleet.  ``etas``: per-scheme
    step sizes [K] (default run.eta everywhere).  ``seeds``: the seed axis
    (default (run.seed,)); each (k, s) cell consumes the exact key/fading
    streams of a standalone run with that seed, so the fleet matches the
    per-scheme loop run-for-run.

    Every cell shares ``data`` (device-resident once) and the initial
    ``params``.  eval_fn is vmapped across the grid at each eval boundary;
    traces/evals come back with leading [K, S] axes (see FLResult).

    Adaptive schemes (``power_control.AdaptiveSCA``: a ``redesign_fn``
    attribute) re-design their power control BETWEEN scan chunks from the
    live fading state: their design leaves are tiled to the full [K, S]
    grid (each cell tracks its own channel trajectory), chunk boundaries
    follow the eval cadence even without an eval_fn (the re-design
    cadence), and the per-chunk designs come back as ``FLResult.designs``.
    Without a fading process (static CSI) the redesign hook is a no-op and
    the run is identical to the plain ``sca`` scheme's.
    """
    t0 = time.time()
    stacked = schemes if not isinstance(schemes, (list, tuple)) \
        else stack_schemes(schemes)
    names = tuple(getattr(stacked, "names", (stacked.name,)))
    k = len(names)
    seeds = tuple(int(s) for s in (seeds if seeds is not None
                                   else (run.seed,)))
    s_axis = len(seeds)
    if etas is None:
        etas = np.full(k, run.eta, np.float64)
    etas = np.asarray(etas, np.float64)
    if etas.shape != (k,):
        raise ValueError(f"etas shape {etas.shape} != ({k},)")

    redesign = getattr(stacked, "redesign_fn", None)
    adaptive = redesign is not None and fading is not None
    if adaptive:
        # every (scheme, seed) cell owns its design: tile the design state
        # over the seed axis and vmap the scheme at both grid levels
        stacked = jax.tree.map(
            lambda a: np.repeat(np.asarray(a)[:, None], s_axis, axis=1),
            stacked)

    round_body = make_round_body(loss_fn, gains, run, fading=fading,
                                 flat=flat)

    def fleet_chunk(stacked, etas, params_b, fstate_b, keys_b, data,
                    length):
        def cell(scheme, eta, params, fstate, key):
            return _scan_chunk(round_body, scheme, eta, params, fstate,
                               key, data, length)
        per_seed = jax.vmap(cell, in_axes=(0 if adaptive else None, None,
                                           0, 0, 0))
        per_cell = jax.vmap(per_seed, in_axes=(0, 0, 0, 0, 0))
        return per_cell(stacked, etas, params_b, fstate_b, keys_b)

    chunk = jax.jit(fleet_chunk, static_argnames=("length",))

    data = tuple(jnp.asarray(a) for a in data)
    params_b = jax.tree.map(
        lambda a: jnp.tile(jnp.asarray(a)[None, None],
                           (k, s_axis) + (1,) * jnp.ndim(a)), params)
    keys0 = jnp.stack([jax.random.PRNGKey(s) for s in seeds])      # [S, 2]
    keys_b = jnp.tile(keys0[None], (k, 1, 1))                      # [K, S, 2]
    fading_state = None
    if fading is not None:
        init_keys = jax.vmap(
            lambda kk: jax.random.fold_in(kk, FADING_INIT_SALT))(keys0)
        state_s = fading.init_batch(init_keys)                     # [S, N]
        fading_state = jnp.tile(state_s[None], (k,) + (1,) * state_s.ndim)

    eval_b = None
    if eval_fn is not None:
        eval_b = jax.jit(jax.vmap(jax.vmap(eval_fn)))

    designs = [(0, np.asarray(stacked.gamma))] if adaptive else None
    evals, metric_chunks, t = [], [], 0
    for length in chunk_lengths(run.num_rounds, run.eval_every,
                                eval_fn is not None or adaptive):
        params_b, fading_state, keys_b, metrics = chunk(
            stacked, etas, params_b, fading_state, keys_b, data,
            length=length)
        metric_chunks.append(metrics)
        t += length
        if adaptive and t < run.num_rounds:
            stacked = redesign(stacked, fading, fading_state)
            designs.append((t, np.asarray(stacked.gamma)))
        if eval_b is not None:
            ev = {kk: np.asarray(v) for kk, v in eval_b(params_b).items()}
            evals.append((t - 1, ev))
            if log:
                lead = next(iter(ev))
                print({"round": t - 1,
                       **{n: round(float(ev[lead][i, 0]), 4)
                          for i, n in enumerate(names)}})
    return FLResult(params=params_b, traces=_concat_traces(metric_chunks),
                    evals=evals, names=names, seeds=seeds,
                    wall=time.time() - t0, fading_state=fading_state,
                    designs=designs)
