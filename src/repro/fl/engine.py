"""Scan-compiled batched FL experiment engine (DESIGN.md §Engine).

The paper's experiments are sweeps — schemes x seeds x scenarios — but a
host Python loop over rounds pays per-round dispatch, host->device batch
copies, and one compilation per scheme, and can never batch the grid.  This
module folds the FL round loop into XLA:

* ``make_round_body`` — one round as a pure function (gradients, fading,
  OTA aggregation, PS update), shared by every runtime below and by the
  legacy ``fl.server.make_round_fn`` wrapper.  Minibatches are sampled
  *on device* from the round key's ``k_batch`` lane.
* ``run_rounds`` — single (scheme, seed) run with the round loop compiled
  as chunked ``lax.scan`` (chunk boundaries = the eval cadence, so at most
  three chunk lengths ever compile).  Bit-identical to the legacy Python
  loop on the default path: the key stream, fading draws and update math
  are the same ops in the same order.
* ``run_fleet`` — a [K-scheme x S-seed] grid as one compiled program per
  chunk: schemes are stacked into a pytree (``power_control
  .stack_schemes``) and the scanned round body runs over (scheme, seed)
  cells.  Each cell reproduces the corresponding single run run-for-run.
  The grid machinery lives one layer up: ``fl.placement`` decides WHERE
  the cells run (vmap on one device — the default, bit-identical to the
  pre-placement engine — or shard_map over a ("data", "model") mesh) and
  ``fl.driver`` owns the chunk loop, adaptive re-design hook, and
  checkpointed resume; ``run_fleet`` here is the single-device alias that
  delegates to them (DESIGN.md §Placement).

Per-round metric traces (grad-norm mean, active devices, noise scale) come
back as stacked arrays straight from the scan — no per-round host sync.

Aggregation inside the round body is switchable: ``flat=False`` uses the
per-leaf tree-map oracle (bitwise-stable reference), ``flat=True`` ravels
the gradient pytree once and runs one fused flattened aggregation
(``kernels.ops.ota_aggregate_pytree`` — the Pallas ``ota_aggregate``
kernel on TPU, the flattened jnp oracle on CPU) with f32 accumulation and
a single fused noise draw whose per-leaf keying reproduces the tree path's
realizations, so the two paths agree to float rounding.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ota
from repro.core.power_control import PowerControl
from repro.optim.optimizers import clip_by_global_norm

PyTree = Any

# key folded into the run seed for FadingProcess state init (must match
# fl.server.run_fl_legacy so engine and legacy runs share state streams)
FADING_INIT_SALT = 0x5CE7A810


@dataclasses.dataclass
class FLResult:
    """What a compiled run returns.

    params        final parameters; leading [K, S] axes for fleet runs
    traces        per-round metric traces as arrays: {name: [T]} for single
                  runs, {name: [K, S, T]} for fleets
    evals         [(round, {name: scalar-or-[K, S] array})] at the eval
                  cadence (empty when no eval_fn was given)
    names         scheme names, length K (single runs: (scheme.name,))
    seeds         seeds swept, length S
    wall          total wall-clock seconds (= wall_compile + wall_exec)
    wall_compile  seconds through the end of the FIRST chunk call — setup
                  plus the dominant XLA compile; benchmark speedups quote
                  it separately so compile never inflates throughput
    wall_exec     seconds after the first chunk — steady-state execution
                  (later chunk lengths may still add smaller compiles)
    fading_state  final FadingProcess state (None on the i.i.d. path)
    designs       adaptive-scheme design trace: [(round, gamma [K, S, N])]
                  with entry (t, g) meaning design g is in effect from
                  round t (None for non-adaptive runs)
    wall_stage    seconds spent staging cohorts (draw + gain
                  materialization + cohort redesign); under the streaming
                  driver this work overlaps chunk execution, so it shows
                  up here but mostly not in wall_exec
    cohorts       population-run cohort trace: [(round, idx [S, N])] with
                  entry (t, idx) meaning those device indices are active
                  from round t (None for full-participation runs)
    stage_walls   per-chunk staging seconds (``wall_stage`` is their sum)
                  for the chunks THIS invocation executed — the
                  streaming-lane profile benchmarks and telemetry consume
                  (None for full-participation runs)
    scenario_names scenario axis of a [C x K x S] grid run, length C
                  (None for single-scenario fleets); ``names`` is then the
                  flattened scenario-major cell axis, length C*K
    """
    params: PyTree
    traces: dict
    evals: list
    names: tuple
    seeds: tuple
    wall: float
    wall_compile: float = 0.0
    wall_exec: float = 0.0
    fading_state: Any = None
    designs: Optional[list] = None
    wall_stage: float = 0.0
    cohorts: Optional[list] = None
    stage_walls: Optional[list] = None
    scenario_names: Optional[tuple] = None


def make_round_body(loss_fn: Callable, gains: np.ndarray, run,
                    fading=None, flat: bool = False,
                    sample_on_device: bool = True,
                    cohort: bool = False,
                    scenario: bool = False,
                    metrics_hook: Optional[Callable] = None,
                    uplink_dtype: Optional[str] = None,
                    fuse_round: Optional[bool] = None) -> Callable:
    """One FL round as a pure function.

        body(scheme, eta, params, fading_state, key, data)
            -> (params, fading_state, metrics)

    ``scheme`` is a PowerControl pytree (so it may be a vmapped row of a
    stacked fleet), ``eta`` a scalar step size (vmappable per scheme),
    ``data`` the stacked per-device datasets (x [N, D, ...], y [N, D]).

    The round key is split exactly like the legacy loop —
    (k_fade, k_ota, k_batch) — with k_batch now actually consumed: when
    ``sample_on_device`` and 0 < run.batch_size < D, each device's
    minibatch is gathered on device (uniform with replacement, the same
    sampling law as the legacy host-numpy path).  The default full-batch
    path consumes keys and data identically to the legacy round function,
    so trajectories are bit-for-bit reproducible against it.

    With ``cohort=True`` the body takes one extra operand —
    ``co = {"gains": [N] active gains, "data_idx": [N] shard indices}`` —
    and the round runs on the gathered active set instead of the closed-
    over ``gains``/full ``data`` (DESIGN.md §Population).  Cohort arrays
    are fixed-size [N] operands, never constants, so the compiled chunk is
    reused across every cohort draw; the key stream is untouched, and a
    cohort equal to the full device set gathers identity — bitwise the
    non-cohort program's values.

    With ``scenario=True`` the body instead takes a per-cell
    ``core.scenarios.ScenarioStack`` row as its extra operand —
    ``body(..., data, sc)`` — and both the channel draw and its state
    update come from ``sc.step`` (gains live in the row, so ``gains`` may
    be None and ``fading`` must be: the row IS the fading process).  A
    [C x K x S] grid is then just a [C*K, S] fleet whose cells carry their
    scenario row alongside their scheme row (DESIGN.md §Grid); each cell's
    key split and update math are unchanged, so every cell is bitwise the
    single-scenario fleet's.

    ``metrics_hook`` (DESIGN.md §Telemetry) extends the per-round metrics
    dict: called as ``hook(s=..., noise_scale=..., h=..., params=...)``
    with the realized OTA coefficients right after they are fixed, it
    returns extra scalar traces (the in-graph bias-variance diagnostics).
    The default ``None`` leaves the round body — and therefore the
    compiled chunk — literally unchanged: the bitwise-off guarantee.

    ``uplink_dtype`` (default: ``run.uplink_dtype``, itself "f32") picks
    the wire precision devices transmit — f32, bf16 or int8 with a
    per-device symmetric scale (kernels.ops.quantize_uplink); the receiver
    always dequantizes and accumulates in f32.  Quantized uplinks require
    the flat path (there is no wire on the tree-map oracle).

    ``fuse_round`` controls whether the flat round tail runs as the ONE
    fused ``ota.fused_round_step`` launch (aggregate + noise + SGD step,
    kernels/round_step.py) or as the historical aggregate-then-update op
    chain.  Default ``None`` = fuse exactly when ``flat`` — with an f32
    uplink the fused launch is bitwise the unfused chain (pinned in
    tests/test_kernels.py), so flipping the default changes no numbers.
    ``fuse_round=False`` keeps the unfused reference for parity tests and
    the fused-vs-unfused benchmark.
    """
    gains_j = None if gains is None else jnp.asarray(gains)
    if uplink_dtype is None:
        uplink_dtype = getattr(run, "uplink_dtype", "f32") or "f32"
    if uplink_dtype not in ota.UPLINK_DTYPES:
        raise ValueError(f"uplink_dtype must be one of {ota.UPLINK_DTYPES}, "
                         f"got {uplink_dtype!r}")
    if uplink_dtype != "f32" and not flat:
        raise ValueError(f"uplink_dtype={uplink_dtype!r} requires the flat "
                         "aggregation path (flat=True)")
    fuse = bool(flat) if fuse_round is None else bool(fuse_round)
    if fuse and not flat:
        raise ValueError("fuse_round=True requires flat=True")
    if scenario and cohort:
        raise ValueError("scenario grids and cohort sampling are exclusive "
                         "(a cohort row would need per-scenario gathers)")
    if scenario and fading is not None:
        raise ValueError("scenario=True owns the channel process; "
                         "pass fading=None")

    def device_grad(params, batch):
        g = jax.grad(loss_fn)(params, batch)
        if run.clip_to_gmax:
            g, norm = clip_by_global_norm(g, run.gmax)
        else:
            norm = jnp.sqrt(sum(jnp.sum(jnp.square(l))
                                for l in jax.tree.leaves(g)))
        return g, norm

    def sample(data, k_batch):
        x_dev, y_dev = data
        d = x_dev.shape[1]
        if not sample_on_device or run.batch_size <= 0 \
                or run.batch_size >= d:
            return data
        idx = jax.random.randint(k_batch, (x_dev.shape[0], run.batch_size),
                                 0, d)
        xb = jnp.take_along_axis(
            x_dev, idx.reshape(idx.shape + (1,) * (x_dev.ndim - 2)), axis=1)
        yb = jnp.take_along_axis(y_dev, idx, axis=1)
        return xb, yb

    def finish(scheme, eta, params, fading_state, k_ota, h, grads, norms):
        # coefficients once, threaded into both the aggregation and the
        # metrics — they can never disagree (bbfl_alternative randomizes
        # round_coeffs, so recomputing from a different key split would).
        k_coeff, k_noise = ota.split_ota_key(k_ota)
        s, noise_scale = scheme.round_coeffs(h, k_coeff)
        if fuse:
            params = ota.fused_round_step(grads, s, noise_scale, k_noise,
                                          params, eta,
                                          uplink_dtype=uplink_dtype)
        else:
            g_hat = ota.apply_round_coeffs(grads, s, noise_scale, k_noise,
                                           flat=flat,
                                           uplink_dtype=uplink_dtype)
            params = jax.tree.map(
                lambda p, g: (p.astype(jnp.float32)
                              - eta * g.astype(jnp.float32)).astype(p.dtype),
                params, g_hat)
        metrics = {
            "grad_norm_mean": jnp.mean(norms),
            "active_devices": jnp.sum((s > 0).astype(jnp.float32)),
            "noise_scale": jnp.asarray(noise_scale, jnp.float32),
        }
        if metrics_hook is not None:
            metrics.update(metrics_hook(s=s, noise_scale=noise_scale, h=h,
                                        params=params))
        return params, fading_state, metrics

    def body(scheme, eta, params, fading_state, key, data):
        k_fade, k_ota, k_batch = jax.random.split(key, 3)
        batch = sample(data, k_batch)
        grads, norms = jax.vmap(lambda b: device_grad(params, b))(batch)
        if fading is None:
            h = ota.draw_fading(k_fade, gains_j)
        else:
            fading_state, h = fading.step(fading_state, k_fade)
        return finish(scheme, eta, params, fading_state, k_ota, h, grads,
                      norms)

    def cohort_body(scheme, eta, params, fading_state, key, data, co):
        k_fade, k_ota, k_batch = jax.random.split(key, 3)
        active = jax.tree.map(lambda a: jnp.take(a, co["data_idx"], axis=0),
                              data)
        batch = sample(active, k_batch)
        grads, norms = jax.vmap(lambda b: device_grad(params, b))(batch)
        if fading is None:
            h = ota.draw_fading(k_fade, co["gains"])
        else:
            fading_state, h = fading.step_cohort(fading_state, k_fade,
                                                 co["gains"])
        return finish(scheme, eta, params, fading_state, k_ota, h, grads,
                      norms)

    def scenario_body(scheme, eta, params, fading_state, key, data, sc):
        k_fade, k_ota, k_batch = jax.random.split(key, 3)
        batch = sample(data, k_batch)
        grads, norms = jax.vmap(lambda b: device_grad(params, b))(batch)
        fading_state, h = sc.step(fading_state, k_fade)
        return finish(scheme, eta, params, fading_state, k_ota, h, grads,
                      norms)

    if scenario:
        return scenario_body
    return cohort_body if cohort else body


def chunk_lengths(num_rounds: int, eval_every: int, with_eval: bool,
                  cohort_rounds: Optional[int] = None) -> list:
    """Scan chunk lengths whose boundaries hit the legacy eval cadence
    (t % eval_every == 0 or t == num_rounds - 1).  At most three distinct
    lengths occur — {1, eval_every, tail} — so at most three scan programs
    ever compile per engine.

    ``cohort_rounds`` adds population-cohort boundaries: the active set
    changes BEFORE every round t with t % cohort_rounds == 0, so chunks
    also end at rounds c*cohort_rounds - 1 (a cohort never straddles a
    chunk).  The default schedule (None) leaves the chunk grid untouched —
    cohort runs then redraw per chunk, i.e. at the eval cadence."""
    if num_rounds <= 0:
        return []
    pts = set(range(0, num_rounds, eval_every)) if with_eval else set()
    if cohort_rounds:
        pts |= set(range(cohort_rounds - 1, num_rounds, cohort_rounds))
    if not pts:
        return [num_rounds]
    pts = sorted(pts | {num_rounds - 1})
    lengths, prev = [], -1
    for t in pts:
        lengths.append(t - prev)
        prev = t
    return lengths


def _scan_chunk(round_body, scheme, eta, params, fading_state, key, data,
                length: int, cohort=None, scenario=None):
    """``length`` rounds of ``round_body`` under lax.scan; returns stacked
    per-round metrics.  The main key is split once per round, exactly like
    the legacy host loop.  ``cohort`` (a cohort-body operand dict, see
    ``make_round_body``) and ``scenario`` (a ScenarioStack cell row) ride
    along as scan constants — operands of the compiled chunk, so changing
    cohorts or scenario parameters never recompiles."""
    def step(carry, _):
        params, fading_state, key = carry
        key, sub = jax.random.split(key)
        if cohort is not None:
            params, fading_state, metrics = round_body(
                scheme, eta, params, fading_state, sub, data, cohort)
        elif scenario is not None:
            params, fading_state, metrics = round_body(
                scheme, eta, params, fading_state, sub, data, scenario)
        else:
            params, fading_state, metrics = round_body(
                scheme, eta, params, fading_state, sub, data)
        return (params, fading_state, key), metrics

    (params, fading_state, key), metrics = jax.lax.scan(
        step, (params, fading_state, key), None, length=length)
    return params, fading_state, key, metrics


def _concat_traces(chunks: list) -> dict:
    if not chunks:
        return {}
    # intersect on the first chunk's keys: a resume that toggled the
    # telemetry diagnostics mid-run degrades to the common traces instead
    # of KeyError-ing (the diagnostic keys are additive, never load-bearing)
    keys = [k for k in chunks[0] if all(k in c for c in chunks)]
    return {k: np.concatenate([np.asarray(c[k]) for c in chunks], axis=-1)
            for k in keys}


def run_rounds(loss_fn: Callable, params: PyTree, scheme: PowerControl,
               gains: np.ndarray, data: tuple, run,
               eval_fn: Optional[Callable] = None, fading=None,
               flat: bool = False, log: bool = False) -> FLResult:
    """Single (scheme, seed) run with the round loop compiled as chunked
    lax.scan.  Bit-identical to ``fl.server.run_fl_legacy`` on the default
    full-batch path; with 0 < run.batch_size < D minibatches are sampled on
    device from the round key (the legacy host-numpy sampling stream is
    retired with the host loop)."""
    t0 = time.time()
    round_body = make_round_body(loss_fn, gains, run, fading=fading,
                                 flat=flat)
    # scheme and eta are *closed over*, not passed as operands: the legacy
    # per-round jit embeds them as constants, and constant-vs-operand flips
    # XLA constant folding enough to break bitwise equality with it.
    chunk = jax.jit(
        functools.partial(_scan_chunk, round_body, scheme, run.eta),
        static_argnames=("length",))
    data = tuple(jnp.asarray(a) for a in data)
    key = jax.random.PRNGKey(run.seed)
    fading_state = None
    if fading is not None:
        fading_state = fading.init(jax.random.fold_in(key, FADING_INIT_SALT))

    evals, metric_chunks, t = [], [], 0
    wall_compile, first = 0.0, True
    for length in chunk_lengths(run.num_rounds, run.eval_every,
                                eval_fn is not None):
        params, fading_state, key, metrics = chunk(
            params, fading_state, key, data, length=length)
        if first:
            jax.block_until_ready(params)
            wall_compile = time.time() - t0
            first = False
        metric_chunks.append(metrics)
        t += length
        if eval_fn is not None:
            ev = {k: float(v) for k, v in eval_fn(params).items()}
            evals.append((t - 1, ev))
            if log:
                print({"round": t - 1, "scheme": scheme.name,
                       **{k: round(v, 4) for k, v in ev.items()}})
    wall = time.time() - t0
    return FLResult(params=params, traces=_concat_traces(metric_chunks),
                    evals=evals, names=(scheme.name,), seeds=(run.seed,),
                    wall=wall, wall_compile=wall_compile,
                    wall_exec=wall - wall_compile,
                    fading_state=fading_state)


def run_fleet(loss_fn: Callable, params: PyTree, schemes, gains: np.ndarray,
              data: tuple, run, eval_fn: Optional[Callable] = None, *,
              etas=None, seeds: Optional[Sequence[int]] = None, fading=None,
              flat: bool = True, log: bool = False, **driver_kw) -> FLResult:
    """A [K-scheme x S-seed] experiment grid as ONE compiled scan program.

    The single-device alias of the layered executor: delegates to
    ``fl.driver.run_fleet`` on the default ``VmapPlacement`` (bit-identical
    to the pre-placement engine); extra keyword args — ``placement``,
    ``checkpoint_path``, ``resume``, ``max_chunks`` — pass through to the
    driver (DESIGN.md §Placement).

    ``schemes``: a list of PowerControl objects (stacked via
    ``stack_schemes`` — heterogeneous mixes dispatch through the
    SchemeBatch union) or an already-stacked fleet.  ``etas``: per-scheme
    step sizes [K] (default run.eta everywhere).  ``seeds``: the seed axis
    (default (run.seed,)); each (k, s) cell consumes the exact key/fading
    streams of a standalone run with that seed, so the fleet matches the
    per-scheme loop run-for-run.

    Every cell shares ``data`` (device-resident once) and the initial
    ``params``.  eval_fn is vmapped across the grid at each eval boundary;
    traces/evals come back with leading [K, S] axes (see FLResult).

    Adaptive schemes (``power_control.AdaptiveSCA``: a ``redesign_fn``
    attribute) re-design their power control BETWEEN scan chunks from the
    live fading state: their design leaves are tiled to the full [K, S]
    grid (each cell tracks its own channel trajectory), chunk boundaries
    follow the eval cadence even without an eval_fn (the re-design
    cadence), and the per-chunk designs come back as ``FLResult.designs``.
    Without a fading process (static CSI) the redesign hook is a no-op and
    the run is identical to the plain ``sca`` scheme's.
    """
    from repro.fl import driver  # deferred: driver imports this module
    return driver.run_fleet(loss_fn, params, schemes, gains, data, run,
                            eval_fn, etas=etas, seeds=seeds, fading=fading,
                            flat=flat, log=log, **driver_kw)


def run_fleet_task(task, schemes, gains: np.ndarray, run=None,
                   **kw) -> FLResult:
    """Task-first alias of ``run_fleet`` (DESIGN.md §Tasks): the workload's
    loss/params/data/eval come from a ``repro.tasks`` bundle; delegates to
    ``fl.driver.run_fleet_task`` (same keyword surface)."""
    from repro.fl import driver  # deferred: driver imports this module
    return driver.run_fleet_task(task, schemes, gains, run, **kw)
