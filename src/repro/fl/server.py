"""FL orchestration: the paper's training loop (broadcast -> local SGD grad
-> OTA upload -> PS update), as a single jit'd round function.

Works for any (loss_fn, params) pair — the paper's MLP and the transformer
examples share this runtime.  Devices are vmapped over stacked local
datasets [N, D, ...]; gradients are norm-clipped to G_max (Assumption 2),
uploaded through a PowerControl scheme via core.ota, and the PS applies the
plain SGD update of eq. (7).

The wireless side is scenario-pluggable (DESIGN.md §Scenarios): by default
rounds draw i.i.d. Rayleigh fading from ``gains``; pass a
scenarios.FadingProcess to run any registered scenario family (Rician,
Nakagami, Gauss-Markov correlated rounds, device dropout) through the same
jit'd round function.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ota
from repro.core.power_control import PowerControl
from repro.optim.optimizers import clip_by_global_norm

PyTree = Any


@dataclasses.dataclass
class FLRunConfig:
    eta: float = 0.05
    num_rounds: int = 200
    gmax: float = 10.0
    batch_size: int = 0            # 0 = full batch (paper §IV)
    eval_every: int = 10
    seed: int = 0
    clip_to_gmax: bool = True


def make_round_fn(loss_fn: Callable, scheme: PowerControl,
                  gains: np.ndarray, run: FLRunConfig, fading=None):
    """Returns the jit'd round function.

    Default (fading None — the paper's i.i.d. Rayleigh channel):
        (params, stacked_batch, key) -> (params, metrics).
    With ``fading`` (a scenarios.FadingProcess or any object exposing
    ``step(state, key) -> (state, h)``), the per-round channel comes from the
    process and its state is threaded through:
        (params, stacked_batch, key, fading_state)
            -> (params, metrics, fading_state).
    For an i.i.d. process the two paths consume keys identically, so the
    baseline scenario reproduces the default path bit-for-bit.
    """
    gains_j = jnp.asarray(gains)

    def device_grad(params, batch):
        g = jax.grad(loss_fn)(params, batch)
        if run.clip_to_gmax:
            g, norm = clip_by_global_norm(g, run.gmax)
        else:
            norm = jnp.sqrt(sum(jnp.sum(jnp.square(l))
                                for l in jax.tree.leaves(g)))
        return g, norm

    def finish_round(params, grads, norms, h, k_ota):
        g_hat = ota.ota_aggregate(grads, scheme, h, k_ota)
        new_params = jax.tree.map(
            lambda p, g: (p.astype(jnp.float32)
                          - run.eta * g.astype(jnp.float32)).astype(p.dtype),
            params, g_hat)
        s, _ = scheme.round_coeffs(h, k_ota)
        metrics = {
            "grad_norm_mean": jnp.mean(norms),
            "active_devices": jnp.sum((s > 0).astype(jnp.float32)),
        }
        return new_params, metrics

    if fading is None:
        def round_fn(params, stacked_batch, key):
            k_fade, k_ota, k_batch = jax.random.split(key, 3)
            grads, norms = jax.vmap(lambda b: device_grad(params, b))(
                stacked_batch)
            h = ota.draw_fading(k_fade, gains_j)
            return finish_round(params, grads, norms, h, k_ota)

        return jax.jit(round_fn)

    def round_fn(params, stacked_batch, key, fading_state):
        k_fade, k_ota, k_batch = jax.random.split(key, 3)
        grads, norms = jax.vmap(lambda b: device_grad(params, b))(
            stacked_batch)
        fading_state, h = fading.step(fading_state, k_fade)
        new_params, metrics = finish_round(params, grads, norms, h, k_ota)
        return new_params, metrics, fading_state

    return jax.jit(round_fn)


def _sample_batches(x_dev, y_dev, batch_size: int, rng: np.random.Generator):
    if batch_size <= 0 or batch_size >= x_dev.shape[1]:
        return x_dev, y_dev
    n, d = x_dev.shape[0], x_dev.shape[1]
    idx = rng.integers(0, d, size=(n, batch_size))
    xb = np.take_along_axis(x_dev, idx[..., None], axis=1)
    yb = np.take_along_axis(y_dev, idx, axis=1)
    return xb, yb


def run_fl(loss_fn: Callable, params: PyTree, scheme: PowerControl,
           gains: np.ndarray, data: tuple, run: FLRunConfig,
           eval_fn: Optional[Callable] = None, log: bool = False,
           fading=None):
    """Run the full FL loop.

    data = (x_dev [N,D,...], y_dev [N,D]) stacked per-device datasets.
    eval_fn(params) -> dict of scalars, called every run.eval_every rounds.
    fading: optional scenarios.FadingProcess drawing the per-round channel
    (None = the paper's i.i.d. Rayleigh on ``gains``); its state is
    initialized from a key folded out of the run seed so the main key
    stream is untouched.
    Returns (params, history list of dicts).
    """
    round_fn = make_round_fn(loss_fn, scheme, gains, run, fading=fading)
    x_dev, y_dev = data
    rng = np.random.default_rng(run.seed)
    key = jax.random.PRNGKey(run.seed)
    fading_state = None
    if fading is not None:
        fading_state = fading.init(jax.random.fold_in(key, 0x5CE7A810))
    history = []
    t0 = time.time()
    for t in range(run.num_rounds):
        key, sub = jax.random.split(key)
        xb, yb = _sample_batches(x_dev, y_dev, run.batch_size, rng)
        batch = (jnp.asarray(xb), jnp.asarray(yb))
        if fading is None:
            params, metrics = round_fn(params, batch, sub)
        else:
            params, metrics, fading_state = round_fn(params, batch, sub,
                                                     fading_state)
        if eval_fn is not None and (t % run.eval_every == 0
                                    or t == run.num_rounds - 1):
            ev = {k: float(v) for k, v in eval_fn(params).items()}
            ev.update(round=t, scheme=scheme.name,
                      active=float(metrics["active_devices"]),
                      wall=time.time() - t0)
            history.append(ev)
            if log:
                print({k: (round(v, 4) if isinstance(v, float) else v)
                       for k, v in ev.items()})
    return params, history
