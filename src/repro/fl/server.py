"""FL orchestration: the paper's training loop (broadcast -> local SGD grad
-> OTA upload -> PS update).

``run_fl`` is a thin wrapper over the scan-compiled experiment engine
(``fl.engine``, DESIGN.md §Engine): the round loop runs as chunked
``lax.scan`` on device, minibatches are sampled on device from the round
key, and per-round metric traces come back as stacked arrays.  On the
default full-batch path it is bit-identical to the historical host loop,
which is preserved verbatim as ``run_fl_legacy`` (the benchmark baseline
and the equivalence oracle in tests/test_engine.py).

Works for any (loss_fn, params) pair — the paper's MLP and the transformer
examples share this runtime.  Devices are vmapped over stacked local
datasets [N, D, ...]; gradients are norm-clipped to G_max (Assumption 2),
uploaded through a PowerControl scheme via core.ota, and the PS applies the
plain SGD update of eq. (7).

The wireless side is scenario-pluggable (DESIGN.md §Scenarios): by default
rounds draw i.i.d. Rayleigh fading from ``gains``; pass a
scenarios.FadingProcess to run any registered scenario family (Rician,
Nakagami, Gauss-Markov correlated rounds, device dropout) through the same
compiled round body.  For whole scheme x seed grids, use the layered fleet
executor (DESIGN.md §Placement): ``fl.engine.run_fleet`` on one device, or
``fl.driver.run_fleet`` with a ``fl.placement.ShardedPlacement`` to shard
the grid over a mesh with checkpointed resume.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.power_control import PowerControl
from repro.fl import engine as engine_mod

PyTree = Any


@dataclasses.dataclass
class FLRunConfig:
    eta: float = 0.05
    num_rounds: int = 200
    gmax: float = 10.0
    batch_size: int = 0            # 0 = full batch (paper §IV)
    eval_every: int = 10
    seed: int = 0
    clip_to_gmax: bool = True
    uplink_dtype: str = "f32"      # wire precision devices transmit on the
    #                                uplink: f32 | bf16 | int8 (per-device
    #                                symmetric scale); non-f32 requires the
    #                                flat aggregation path.  See
    #                                kernels.ops.quantize_uplink.


class History(list):
    """Legacy eval-cadence history (list of dicts) with the engine's
    per-round metric traces attached: ``history.traces`` maps metric name
    (grad_norm_mean / active_devices / noise_scale) to a [num_rounds]
    array — every round, not just eval rounds."""

    def __init__(self, *args):
        super().__init__(*args)
        self.traces = {}


def make_round_fn(loss_fn: Callable, scheme: PowerControl,
                  gains: np.ndarray, run: FLRunConfig, fading=None):
    """Returns the jit'd single-round function (legacy-shaped API).

    Default (fading None — the paper's i.i.d. Rayleigh channel):
        (params, stacked_batch, key) -> (params, metrics).
    With ``fading`` (a scenarios.FadingProcess or any object exposing
    ``step(state, key) -> (state, h)``), the per-round channel comes from the
    process and its state is threaded through:
        (params, stacked_batch, key, fading_state)
            -> (params, metrics, fading_state).
    For an i.i.d. process the two paths consume keys identically, so the
    baseline scenario reproduces the default path bit-for-bit.

    The body is the engine's round body with on-device batch sampling
    disabled (the caller owns the batch), so a host loop over this function
    and the scan engine execute identical per-round programs.
    """
    body = engine_mod.make_round_body(loss_fn, gains, run, fading=fading,
                                      sample_on_device=False)

    if fading is None:
        def round_fn(params, stacked_batch, key):
            params, _, metrics = body(scheme, run.eta, params, None, key,
                                      stacked_batch)
            return params, metrics
        return jax.jit(round_fn)

    def round_fn(params, stacked_batch, key, fading_state):
        params, fading_state, metrics = body(scheme, run.eta, params,
                                             fading_state, key,
                                             stacked_batch)
        return params, metrics, fading_state

    return jax.jit(round_fn)


def _history_from_result(res: engine_mod.FLResult, scheme_name: str,
                         t0: float) -> History:
    hist = History()
    active = res.traces.get("active_devices")
    for t, ev in res.evals:
        row = dict(ev)
        row.update(round=t, scheme=scheme_name,
                   active=float(active[t]), wall=time.time() - t0)
        hist.append(row)
    hist.traces = res.traces
    return hist


def run_fl(loss_fn: Callable, params: PyTree, scheme: PowerControl,
           gains: np.ndarray, data: tuple, run: FLRunConfig,
           eval_fn: Optional[Callable] = None, log: bool = False,
           fading=None, flat: bool = False):
    """Run the full FL loop on the scan engine.

    data = (x_dev [N,D,...], y_dev [N,D]) stacked per-device datasets.
    eval_fn(params) -> dict of scalars, called every run.eval_every rounds.
    fading: optional scenarios.FadingProcess drawing the per-round channel
    (None = the paper's i.i.d. Rayleigh on ``gains``); its state is
    initialized from a key folded out of the run seed so the main key
    stream is untouched.
    flat: route the aggregation through the fused Pallas kernel
    (kernels.ops.ota_aggregate_pytree) instead of the per-leaf tree-map
    oracle; same noise realizations, float-rounding-level differences.

    Bit-identical to ``run_fl_legacy`` for the default full-batch path.
    With 0 < batch_size < D, minibatches are sampled **on device** from the
    round key (the legacy host-numpy sampling is retired with the host
    loop), so minibatch trajectories differ from run_fl_legacy's while
    following the same sampling law.

    Returns (params, history): history is the legacy eval-cadence list of
    dicts, with per-round metric traces attached as ``history.traces``.
    """
    t0 = time.time()
    res = engine_mod.run_rounds(loss_fn, params, scheme, gains, data, run,
                                eval_fn=eval_fn, fading=fading, flat=flat,
                                log=log)
    return res.params, _history_from_result(res, scheme.name, t0)


def run_fl_task(task, scheme: PowerControl, gains: np.ndarray, run=None,
                *, task_data=None, params: Optional[PyTree] = None,
                eval_fn: Optional[Callable] = None,
                seed: Optional[int] = None, data_kw: Optional[dict] = None,
                **kw):
    """Task-first single-run entry (DESIGN.md §Tasks): loss/params/data/
    eval come from a ``repro.tasks`` bundle (duck-typed, like
    ``fl.driver.run_fleet_task``); defaults resolve the same way —
    run = task.run_config(), seed = run.seed feeding both build_data and
    the init PRNGKey.  Returns (params, history) like :func:`run_fl`."""
    from repro.fl.driver import resolve_task_bundle  # deferred: no cycle
    run, td, params, eval_fn = resolve_task_bundle(
        task, run, task_data=task_data, params=params, eval_fn=eval_fn,
        seed=seed, data_kw=data_kw)
    return run_fl(task.loss_fn, params, scheme, gains, td.train, run,
                  eval_fn, **kw)


# ---------------------------------------------------------------------------
# The historical host loop, preserved as the benchmark baseline and the
# equivalence oracle for the scan engine.
# ---------------------------------------------------------------------------

def _sample_batches(x_dev, y_dev, batch_size: int, rng: np.random.Generator):
    if batch_size <= 0 or batch_size >= x_dev.shape[1]:
        return x_dev, y_dev
    n, d = x_dev.shape[0], x_dev.shape[1]
    idx = rng.integers(0, d, size=(n, batch_size))
    xb = np.take_along_axis(x_dev, idx[..., None], axis=1)
    yb = np.take_along_axis(y_dev, idx, axis=1)
    return xb, yb


def run_fl_legacy(loss_fn: Callable, params: PyTree, scheme: PowerControl,
                  gains: np.ndarray, data: tuple, run: FLRunConfig,
                  eval_fn: Optional[Callable] = None, log: bool = False,
                  fading=None):
    """The pre-engine host loop: one jitted round call per round, numpy
    batch sampling, host->device batch copy every round.  Kept as the
    wall-clock baseline for benchmarks/fig2.py and as the oracle the scan
    engine is tested bit-identical against (default path).

    Returns (params, history list of dicts).
    """
    round_fn = make_round_fn(loss_fn, scheme, gains, run, fading=fading)
    x_dev, y_dev = data
    rng = np.random.default_rng(run.seed)
    key = jax.random.PRNGKey(run.seed)
    fading_state = None
    if fading is not None:
        fading_state = fading.init(
            jax.random.fold_in(key, engine_mod.FADING_INIT_SALT))
    history = []
    t0 = time.time()
    for t in range(run.num_rounds):
        key, sub = jax.random.split(key)
        xb, yb = _sample_batches(x_dev, y_dev, run.batch_size, rng)
        batch = (jnp.asarray(xb), jnp.asarray(yb))
        if fading is None:
            params, metrics = round_fn(params, batch, sub)
        else:
            params, metrics, fading_state = round_fn(params, batch, sub,
                                                     fading_state)
        if eval_fn is not None and (t % run.eval_every == 0
                                    or t == run.num_rounds - 1):
            ev = {k: float(v) for k, v in eval_fn(params).items()}
            ev.update(round=t, scheme=scheme.name,
                      active=float(metrics["active_devices"]),
                      wall=time.time() - t0)
            history.append(ev)
            if log:
                print({k: (round(v, 4) if isinstance(v, float) else v)
                       for k, v in ev.items()})
    return params, history
