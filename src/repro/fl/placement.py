"""Placement layer of the fleet executor (DESIGN.md §Placement).

The fleet is three layers:

* **cell program** (``fl.engine``): the chunked-scan single-cell runtime —
  ``make_round_body`` + ``_scan_chunk`` — pure and placement-agnostic.
* **placement** (this module): maps the [K-scheme x S-seed] grid onto
  hardware.  ``VmapPlacement`` is the single-device path — the exact
  vmap-over-cells program the engine has always compiled, bit-identical.
  ``ShardedPlacement`` flattens the grid to a [K*S] cell axis and shards
  it over a ``("data", "model")`` mesh via ``distributed.shard_vmap``:
  cells are independent so the shard_map is psum-free, the grid is padded
  with copies of cell 0 when K*S doesn't divide the device count (padded
  outputs sliced off), and traces/evals/designs gather to host at chunk
  boundaries.
* **host driver** (``fl.driver``): the chunk loop, adaptive re-design
  hook, and checkpointed resume — consumes either placement through the
  same two-method interface.

A placement exposes:

    prepare_schemes(stacked, s_axis, adaptive) -> stacked'
        layout the stacked schemes' design leaves for this placement
        (vmap broadcasts non-adaptive designs over seeds; sharding tiles
        every leaf to the full [K, S] grid so it can flatten to cells).
    build_chunk(round_body, adaptive, cohort=False, scenario=False,
                tracer=None) -> chunk
        chunk(stacked, etas, params_b, fstate_b, keys_b, data, length)
        -> (params_b, fstate_b, keys_b, metrics), everything with leading
        [K, S] grid axes either way — the driver never knows where the
        cells ran.  With ``cohort=True`` the chunk takes one extra operand
        before ``length`` — the staged cohort dict with [S, N] leaves
        (per-seed active sets, shared across schemes) — and the cell
        program is the engine's cohort body (DESIGN.md §Population).
        With ``scenario=True`` the extra operand is instead a
        ``ScenarioStack`` tiled to the cell axis (leaves [K, ...], one row
        per cell) and the cell program is the engine's scenario body: the
        [C x K x S] grid is just a [C*K, S] fleet whose cells carry their
        channel world as an operand (DESIGN.md §Grid).
        Every chunk exposes ``_cache_size()`` — the number of compiled
        programs behind it (the jit trace cache here, the explicit
        per-(length, grid) dict on the sharded path) — which
        ``telemetry.assert_no_recompile`` audits.  ``tracer`` (a
        ``telemetry.Tracer``) emits a ``chunk_compile`` span whenever a
        call grows that cache; ``None`` (default) returns the exact
        pre-telemetry callable, bitwise.

        The carry buffers (``params_b``/``fstate_b``/``keys_b``) are
        DONATED to the compiled chunk (``jax.jit(...,
        donate_argnums=(2, 3, 4))``): the chunk returns same-shaped
        replacements, so XLA aliases them in place and a big grid never
        holds two copies of every carry.  Callers must treat the passed-in
        carries as consumed — the driver's linear chunk chain already
        does.  ``donate=False`` on a placement restores the copying
        behaviour (the RSS A/B probe in benchmarks/scenario_sweep.py).
    map_batch(fn, batch_tree) -> out_tree
        generic per-row map over a leading [B] batch axis — how
        ``solvers.solve_batch`` shards thousand-scenario SCA design
        batches over the same mesh.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any

import jax
import jax.numpy as jnp

from repro import distributed
from repro.core.power_control import tile_over_seeds
from repro.fl.engine import _scan_chunk
from repro.launch.mesh import grid_axes

PyTree = Any


def _traced_compiles(chunk, tracer):
    """Wrap a chunk so calls that grow its compile cache emit a
    ``chunk_compile`` span (the jit call traces + compiles synchronously;
    execution stays async, so the call duration on a cache-miss call IS
    the compile wall to within dispatch noise).  The wrapper changes no
    operand, shape or key stream — only observation.

    Chunks that pad the cell grid to the device count (the sharded
    placement) expose ``_pad_frac()``; the span then carries
    ``padded_frac`` — the fraction of compiled cells that are cell-0
    masking waste — so a 1000-cell grid on 8·P devices reports what the
    padding burns instead of hiding it in the exec wall."""
    def traced(*args, length):
        before = chunk._cache_size()
        t0 = time.monotonic()
        out = chunk(*args, length=length)
        after = chunk._cache_size()
        if after > before:
            extra = {}
            pad = getattr(chunk, "_pad_frac", None)
            frac = pad() if pad is not None else None
            if frac is not None:
                extra["padded_frac"] = round(frac, 6)
            tracer.event("chunk_compile", dur=round(time.monotonic() - t0, 6),
                         length=int(length), cache_size=after, **extra)
        return out

    traced._cache_size = chunk._cache_size
    if hasattr(chunk, "_pad_frac"):
        traced._pad_frac = chunk._pad_frac
    return traced


class Placement:
    """Interface marker; see module docstring for the contract."""

    def prepare_schemes(self, stacked, s_axis: int, adaptive: bool):
        raise NotImplementedError

    def build_chunk(self, round_body, adaptive: bool, cohort: bool = False,
                    scenario: bool = False, tracer=None):
        raise NotImplementedError

    def compile_batch(self, fn):
        """Compiled per-row map over a leading [B] axis.  Callers that
        invoke the result repeatedly should hold on to it (or cache keyed
        on this placement — both placements hash stably), so the jit trace
        cache survives across calls."""
        raise NotImplementedError

    def map_batch(self, fn, batch_tree):
        return self.compile_batch(fn)(batch_tree)

    def describe(self, cells=None) -> str:
        """Stable identity string, recorded in fleet checkpoints so a
        resume on a different placement is rejected (the bitwise-resume
        contract holds per placement).  ``cells`` (the flattened grid
        size, when the caller knows it) lets padding placements report
        their cell-0 waste in the string; placements that never pad
        ignore it."""
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class VmapPlacement(Placement):
    """The single-device grid: vmap over (scheme, seed) cells.

    This is byte-for-byte the fleet program ``engine.run_fleet`` has
    always compiled — non-adaptive schemes broadcast over the seed axis
    (in_axes None), adaptive schemes tile per cell — so the refactor keeps
    the default path run-for-run identical.  ``donate=False`` disables
    carry-buffer donation (see module docstring).
    """
    donate: bool = True

    def _donate(self):
        return (2, 3, 4) if self.donate else ()

    def prepare_schemes(self, stacked, s_axis: int, adaptive: bool):
        # every (scheme, seed) cell owns its design: tile the design state
        # over the seed axis and vmap the scheme at both grid levels
        return tile_over_seeds(stacked, s_axis) if adaptive else stacked

    def build_chunk(self, round_body, adaptive: bool, cohort: bool = False,
                    scenario: bool = False, tracer=None):
        if cohort and scenario:
            raise ValueError("cohort and scenario chunks are exclusive")
        if scenario:
            # scenario rows ride the cell axis next to the scheme rows:
            # mapped per cell, broadcast over seeds (every seed of a cell
            # lives in the same channel world)
            def scenario_chunk(stacked, etas, params_b, fstate_b, keys_b,
                               data, scen_b, length):
                def cell(scheme, eta, params, fstate, key, sc):
                    return _scan_chunk(round_body, scheme, eta, params,
                                       fstate, key, data, length,
                                       scenario=sc)
                per_seed = jax.vmap(cell, in_axes=(0 if adaptive else None,
                                                   None, 0, 0, 0, None))
                per_cell = jax.vmap(per_seed, in_axes=(0, 0, 0, 0, 0, 0))
                return per_cell(stacked, etas, params_b, fstate_b, keys_b,
                                scen_b)

            chunk = jax.jit(scenario_chunk, static_argnames=("length",),
                            donate_argnums=self._donate())
            return chunk if tracer is None \
                else _traced_compiles(chunk, tracer)

        if not cohort:
            def fleet_chunk(stacked, etas, params_b, fstate_b, keys_b, data,
                            length):
                def cell(scheme, eta, params, fstate, key):
                    return _scan_chunk(round_body, scheme, eta, params,
                                       fstate, key, data, length)
                per_seed = jax.vmap(cell, in_axes=(0 if adaptive else None,
                                                   None, 0, 0, 0))
                per_cell = jax.vmap(per_seed, in_axes=(0, 0, 0, 0, 0))
                return per_cell(stacked, etas, params_b, fstate_b, keys_b)

            chunk = jax.jit(fleet_chunk, static_argnames=("length",),
                            donate_argnums=self._donate())
            return chunk if tracer is None \
                else _traced_compiles(chunk, tracer)

        # cohort leaves are [S, N]: per-seed active sets (each seed row
        # draws its own cohort), broadcast across the scheme axis
        def cohort_chunk(stacked, etas, params_b, fstate_b, keys_b, data,
                         cohort_b, length):
            def cell(scheme, eta, params, fstate, key, co):
                return _scan_chunk(round_body, scheme, eta, params, fstate,
                                   key, data, length, cohort=co)
            per_seed = jax.vmap(cell, in_axes=(0 if adaptive else None,
                                               None, 0, 0, 0, 0))
            per_cell = jax.vmap(per_seed, in_axes=(0, 0, 0, 0, 0, None))
            return per_cell(stacked, etas, params_b, fstate_b, keys_b,
                            cohort_b)

        chunk = jax.jit(cohort_chunk, static_argnames=("length",),
                        donate_argnums=self._donate())
        return chunk if tracer is None else _traced_compiles(chunk, tracer)

    def compile_batch(self, fn):
        return jax.jit(jax.vmap(fn))

    def describe(self, cells=None) -> str:
        return "vmap"


@dataclasses.dataclass(frozen=True)
class ShardedPlacement(Placement):
    """Shard the flattened [K*S] cell axis over mesh axes.

    ``mesh`` is any jax Mesh (``launch.mesh.make_debug_mesh(2, 2)`` for
    the forced-8-CPU-device CI path, ``make_production_mesh()`` on real
    hardware); ``axes`` defaults to every mesh axis — fleet cells are
    independent single-device programs, so "data" and "model" both serve
    as cell slots.  Each device scans its local block of cells; results
    come back as global arrays with the grid axes restored, so the host
    driver (and its checkpoint format) is identical to the vmap path.
    ``donate=False`` disables carry-buffer donation (see module
    docstring).
    """
    mesh: Any
    axes: tuple = None  # default: every axis of ``mesh``
    donate: bool = True

    def __post_init__(self):
        if self.axes is None:
            object.__setattr__(self, "axes", grid_axes(self.mesh))

    @property
    def num_devices(self) -> int:
        return distributed.grid_devices(self.mesh, self.axes)

    def _donate(self):
        return (2, 3, 4) if self.donate else ()

    def _pad(self, cells: int):
        """(padded grid size, padded-cell fraction) for a flattened grid
        of ``cells`` rows — the cell-0 copies shard_vmap adds so the grid
        divides the device count."""
        n = self.num_devices
        gp = -(-cells // n) * n
        return gp, (gp - cells) / gp

    def prepare_schemes(self, stacked, s_axis: int, adaptive: bool):
        # sharding flattens the grid to cells, so every design leaf must
        # carry the full [K, S] axes — adaptive or not
        return tile_over_seeds(stacked, s_axis)

    def build_chunk(self, round_body, adaptive: bool, cohort: bool = False,
                    scenario: bool = False, tracer=None):
        if cohort and scenario:
            raise ValueError("cohort and scenario chunks are exclusive")
        compiled = {}
        pad_info = {"frac": None}

        def lookup(length, keys_b, compile_fn):
            k, s = int(keys_b.shape[0]), int(keys_b.shape[1])
            pad_info["frac"] = self._pad(k * s)[1]
            fn = compiled.get((length, k, s))
            if fn is None:
                fn = compiled[(length, k, s)] = compile_fn(
                    round_body, length, k, s)
            return fn

        if scenario:
            def scenario_chunk(stacked, etas, params_b, fstate_b, keys_b,
                               data, scen_b, length):
                fn = lookup(length, keys_b, self._compile_scenario)
                return fn(stacked, etas, params_b, fstate_b, keys_b, data,
                          scen_b)

            scenario_chunk._cache_size = lambda: len(compiled)
            scenario_chunk._pad_frac = lambda: pad_info["frac"]
            return scenario_chunk if tracer is None \
                else _traced_compiles(scenario_chunk, tracer)

        if not cohort:
            def chunk(stacked, etas, params_b, fstate_b, keys_b, data,
                      length):
                fn = lookup(length, keys_b, self._compile)
                return fn(stacked, etas, params_b, fstate_b, keys_b, data)

            chunk._cache_size = lambda: len(compiled)
            chunk._pad_frac = lambda: pad_info["frac"]
            return chunk if tracer is None \
                else _traced_compiles(chunk, tracer)

        def cohort_chunk(stacked, etas, params_b, fstate_b, keys_b, data,
                         cohort_b, length):
            fn = lookup(length, keys_b, self._compile_cohort)
            return fn(stacked, etas, params_b, fstate_b, keys_b, data,
                      cohort_b)

        cohort_chunk._cache_size = lambda: len(compiled)
        cohort_chunk._pad_frac = lambda: pad_info["frac"]
        return cohort_chunk if tracer is None \
            else _traced_compiles(cohort_chunk, tracer)

    def _compile(self, round_body, length: int, k: int, s: int):
        def cell(scheme, eta, params, fstate, key, data):
            return _scan_chunk(round_body, scheme, eta, params, fstate, key,
                               data, length)

        grid_call = distributed.shard_vmap(cell, self.mesh, self.axes,
                                           num_sharded=5)

        def run(stacked, etas, params_b, fstate_b, keys_b, data):
            def flat(tree):
                return jax.tree.map(
                    lambda a: jnp.reshape(a, (k * s,) + a.shape[2:]), tree)

            def unflat(tree):
                return jax.tree.map(
                    lambda a: jnp.reshape(a, (k, s) + a.shape[1:]), tree)

            etas_f = jnp.reshape(
                jnp.broadcast_to(jnp.asarray(etas)[:, None], (k, s)), (k * s,))
            out = grid_call(flat(stacked), etas_f, flat(params_b),
                            flat(fstate_b), flat(keys_b), data)
            return unflat(out)

        return jax.jit(run, donate_argnums=self._donate())

    def _compile_scenario(self, round_body, length: int, k: int, s: int):
        # scenario rows are per CELL ([K, ...] leaves, K = C*schemes): tile
        # over the seed axis and flatten to the same [K*S] cell axis as the
        # carry, so each cell ships its channel world through the mesh
        def cell(scheme, eta, params, fstate, key, sc, data):
            return _scan_chunk(round_body, scheme, eta, params, fstate, key,
                               data, length, scenario=sc)

        grid_call = distributed.shard_vmap(cell, self.mesh, self.axes,
                                           num_sharded=6)

        def run(stacked, etas, params_b, fstate_b, keys_b, data, scen_b):
            def flat(tree):
                return jax.tree.map(
                    lambda a: jnp.reshape(a, (k * s,) + a.shape[2:]), tree)

            def unflat(tree):
                return jax.tree.map(
                    lambda a: jnp.reshape(a, (k, s) + a.shape[1:]), tree)

            etas_f = jnp.reshape(
                jnp.broadcast_to(jnp.asarray(etas)[:, None], (k, s)), (k * s,))
            scen_f = jax.tree.map(
                lambda a: jnp.reshape(
                    jnp.broadcast_to(jnp.asarray(a)[:, None],
                                     (k, s) + jnp.shape(a)[1:]),
                    (k * s,) + jnp.shape(a)[1:]), scen_b)
            out = grid_call(flat(stacked), etas_f, flat(params_b),
                            flat(fstate_b), flat(keys_b), scen_f, data)
            return unflat(out)

        return jax.jit(run, donate_argnums=self._donate())

    def _compile_cohort(self, round_body, length: int, k: int, s: int):
        # the [S, N] cohort leaves tile across the scheme axis and flatten
        # to the same [K*S] cell axis as the carry, so each cell ships its
        # own active set through the mesh (padded with cell 0 like every
        # other sharded operand when K*S doesn't divide the device count)
        def cell(scheme, eta, params, fstate, key, co, data):
            return _scan_chunk(round_body, scheme, eta, params, fstate, key,
                               data, length, cohort=co)

        grid_call = distributed.shard_vmap(cell, self.mesh, self.axes,
                                           num_sharded=6)

        def run(stacked, etas, params_b, fstate_b, keys_b, data, cohort_b):
            def flat(tree):
                return jax.tree.map(
                    lambda a: jnp.reshape(a, (k * s,) + a.shape[2:]), tree)

            def unflat(tree):
                return jax.tree.map(
                    lambda a: jnp.reshape(a, (k, s) + a.shape[1:]), tree)

            etas_f = jnp.reshape(
                jnp.broadcast_to(jnp.asarray(etas)[:, None], (k, s)), (k * s,))
            cohort_f = jax.tree.map(
                lambda a: jnp.reshape(
                    jnp.broadcast_to(jnp.asarray(a)[None],
                                     (k,) + jnp.shape(a)),
                    (k * s,) + jnp.shape(a)[1:]), cohort_b)
            out = grid_call(flat(stacked), etas_f, flat(params_b),
                            flat(fstate_b), flat(keys_b), cohort_f, data)
            return unflat(out)

        return jax.jit(run, donate_argnums=self._donate())

    def compile_batch(self, fn):
        return jax.jit(distributed.shard_vmap(fn, self.mesh, self.axes))

    def describe(self, cells=None) -> str:
        shape = ",".join(f"{a}={self.mesh.shape[a]}" for a in self.axes)
        if cells is None:
            return f"sharded[{shape}]"
        gp, _ = self._pad(int(cells))
        return f"sharded[{shape},cells={int(cells)},pad={gp - int(cells)}/{gp}]"
