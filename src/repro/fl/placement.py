"""Placement layer of the fleet executor (DESIGN.md §Placement).

The fleet is three layers:

* **cell program** (``fl.engine``): the chunked-scan single-cell runtime —
  ``make_round_body`` + ``_scan_chunk`` — pure and placement-agnostic.
* **placement** (this module): maps the [K-scheme x S-seed] grid onto
  hardware.  ``VmapPlacement`` is the single-device path — the exact
  vmap-over-cells program the engine has always compiled, bit-identical.
  ``ShardedPlacement`` flattens the grid to a [K*S] cell axis and shards
  it over a ``("data", "model")`` mesh via ``distributed.shard_vmap``:
  cells are independent so the shard_map is psum-free, the grid is padded
  with copies of cell 0 when K*S doesn't divide the device count (padded
  outputs sliced off), and traces/evals/designs gather to host at chunk
  boundaries.
* **host driver** (``fl.driver``): the chunk loop, adaptive re-design
  hook, and checkpointed resume — consumes either placement through the
  same two-method interface.

A placement exposes:

    prepare_schemes(stacked, s_axis, adaptive) -> stacked'
        layout the stacked schemes' design leaves for this placement
        (vmap broadcasts non-adaptive designs over seeds; sharding tiles
        every leaf to the full [K, S] grid so it can flatten to cells).
    build_chunk(round_body, adaptive, cohort=False, tracer=None) -> chunk
        chunk(stacked, etas, params_b, fstate_b, keys_b, data, length)
        -> (params_b, fstate_b, keys_b, metrics), everything with leading
        [K, S] grid axes either way — the driver never knows where the
        cells ran.  With ``cohort=True`` the chunk takes one extra operand
        before ``length`` — the staged cohort dict with [S, N] leaves
        (per-seed active sets, shared across schemes) — and the cell
        program is the engine's cohort body (DESIGN.md §Population).
        Every chunk exposes ``_cache_size()`` — the number of compiled
        programs behind it (the jit trace cache here, the explicit
        per-(length, grid) dict on the sharded path) — which
        ``telemetry.assert_no_recompile`` audits.  ``tracer`` (a
        ``telemetry.Tracer``) emits a ``chunk_compile`` span whenever a
        call grows that cache; ``None`` (default) returns the exact
        pre-telemetry callable, bitwise.
    map_batch(fn, batch_tree) -> out_tree
        generic per-row map over a leading [B] batch axis — how
        ``solvers.solve_batch`` shards thousand-scenario SCA design
        batches over the same mesh.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any

import jax
import jax.numpy as jnp

from repro import distributed
from repro.core.power_control import tile_over_seeds
from repro.fl.engine import _scan_chunk
from repro.launch.mesh import grid_axes

PyTree = Any


def _traced_compiles(chunk, tracer):
    """Wrap a chunk so calls that grow its compile cache emit a
    ``chunk_compile`` span (the jit call traces + compiles synchronously;
    execution stays async, so the call duration on a cache-miss call IS
    the compile wall to within dispatch noise).  The wrapper changes no
    operand, shape or key stream — only observation."""
    def traced(*args, length):
        before = chunk._cache_size()
        t0 = time.monotonic()
        out = chunk(*args, length=length)
        after = chunk._cache_size()
        if after > before:
            tracer.event("chunk_compile", dur=round(time.monotonic() - t0, 6),
                         length=int(length), cache_size=after)
        return out

    traced._cache_size = chunk._cache_size
    return traced


class Placement:
    """Interface marker; see module docstring for the contract."""

    def prepare_schemes(self, stacked, s_axis: int, adaptive: bool):
        raise NotImplementedError

    def build_chunk(self, round_body, adaptive: bool, cohort: bool = False,
                    tracer=None):
        raise NotImplementedError

    def compile_batch(self, fn):
        """Compiled per-row map over a leading [B] axis.  Callers that
        invoke the result repeatedly should hold on to it (or cache keyed
        on this placement — both placements hash stably), so the jit trace
        cache survives across calls."""
        raise NotImplementedError

    def map_batch(self, fn, batch_tree):
        return self.compile_batch(fn)(batch_tree)

    def describe(self) -> str:
        """Stable identity string, recorded in fleet checkpoints so a
        resume on a different placement is rejected (the bitwise-resume
        contract holds per placement)."""
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class VmapPlacement(Placement):
    """The single-device grid: vmap over (scheme, seed) cells.

    This is byte-for-byte the fleet program ``engine.run_fleet`` has
    always compiled — non-adaptive schemes broadcast over the seed axis
    (in_axes None), adaptive schemes tile per cell — so the refactor keeps
    the default path run-for-run identical.
    """

    def prepare_schemes(self, stacked, s_axis: int, adaptive: bool):
        # every (scheme, seed) cell owns its design: tile the design state
        # over the seed axis and vmap the scheme at both grid levels
        return tile_over_seeds(stacked, s_axis) if adaptive else stacked

    def build_chunk(self, round_body, adaptive: bool, cohort: bool = False,
                    tracer=None):
        if not cohort:
            def fleet_chunk(stacked, etas, params_b, fstate_b, keys_b, data,
                            length):
                def cell(scheme, eta, params, fstate, key):
                    return _scan_chunk(round_body, scheme, eta, params,
                                       fstate, key, data, length)
                per_seed = jax.vmap(cell, in_axes=(0 if adaptive else None,
                                                   None, 0, 0, 0))
                per_cell = jax.vmap(per_seed, in_axes=(0, 0, 0, 0, 0))
                return per_cell(stacked, etas, params_b, fstate_b, keys_b)

            chunk = jax.jit(fleet_chunk, static_argnames=("length",))
            return chunk if tracer is None \
                else _traced_compiles(chunk, tracer)

        # cohort leaves are [S, N]: per-seed active sets (each seed row
        # draws its own cohort), broadcast across the scheme axis
        def cohort_chunk(stacked, etas, params_b, fstate_b, keys_b, data,
                         cohort_b, length):
            def cell(scheme, eta, params, fstate, key, co):
                return _scan_chunk(round_body, scheme, eta, params, fstate,
                                   key, data, length, cohort=co)
            per_seed = jax.vmap(cell, in_axes=(0 if adaptive else None,
                                               None, 0, 0, 0, 0))
            per_cell = jax.vmap(per_seed, in_axes=(0, 0, 0, 0, 0, None))
            return per_cell(stacked, etas, params_b, fstate_b, keys_b,
                            cohort_b)

        chunk = jax.jit(cohort_chunk, static_argnames=("length",))
        return chunk if tracer is None else _traced_compiles(chunk, tracer)

    def compile_batch(self, fn):
        return jax.jit(jax.vmap(fn))

    def describe(self) -> str:
        return "vmap"


@dataclasses.dataclass(frozen=True)
class ShardedPlacement(Placement):
    """Shard the flattened [K*S] cell axis over mesh axes.

    ``mesh`` is any jax Mesh (``launch.mesh.make_debug_mesh(2, 2)`` for
    the forced-8-CPU-device CI path, ``make_production_mesh()`` on real
    hardware); ``axes`` defaults to every mesh axis — fleet cells are
    independent single-device programs, so "data" and "model" both serve
    as cell slots.  Each device scans its local block of cells; results
    come back as global arrays with the grid axes restored, so the host
    driver (and its checkpoint format) is identical to the vmap path.
    """
    mesh: Any
    axes: tuple = None  # default: every axis of ``mesh``

    def __post_init__(self):
        if self.axes is None:
            object.__setattr__(self, "axes", grid_axes(self.mesh))

    @property
    def num_devices(self) -> int:
        return distributed.grid_devices(self.mesh, self.axes)

    def prepare_schemes(self, stacked, s_axis: int, adaptive: bool):
        # sharding flattens the grid to cells, so every design leaf must
        # carry the full [K, S] axes — adaptive or not
        return tile_over_seeds(stacked, s_axis)

    def build_chunk(self, round_body, adaptive: bool, cohort: bool = False,
                    tracer=None):
        compiled = {}

        if not cohort:
            def chunk(stacked, etas, params_b, fstate_b, keys_b, data,
                      length):
                k, s = int(keys_b.shape[0]), int(keys_b.shape[1])
                fn = compiled.get((length, k, s))
                if fn is None:
                    fn = compiled[(length, k, s)] = self._compile(
                        round_body, length, k, s)
                return fn(stacked, etas, params_b, fstate_b, keys_b, data)

            chunk._cache_size = lambda: len(compiled)
            return chunk if tracer is None \
                else _traced_compiles(chunk, tracer)

        def cohort_chunk(stacked, etas, params_b, fstate_b, keys_b, data,
                         cohort_b, length):
            k, s = int(keys_b.shape[0]), int(keys_b.shape[1])
            fn = compiled.get((length, k, s))
            if fn is None:
                fn = compiled[(length, k, s)] = self._compile_cohort(
                    round_body, length, k, s)
            return fn(stacked, etas, params_b, fstate_b, keys_b, data,
                      cohort_b)

        cohort_chunk._cache_size = lambda: len(compiled)
        return cohort_chunk if tracer is None \
            else _traced_compiles(cohort_chunk, tracer)

    def _compile(self, round_body, length: int, k: int, s: int):
        def cell(scheme, eta, params, fstate, key, data):
            return _scan_chunk(round_body, scheme, eta, params, fstate, key,
                               data, length)

        grid_call = distributed.shard_vmap(cell, self.mesh, self.axes,
                                           num_sharded=5)

        def run(stacked, etas, params_b, fstate_b, keys_b, data):
            def flat(tree):
                return jax.tree.map(
                    lambda a: jnp.reshape(a, (k * s,) + a.shape[2:]), tree)

            def unflat(tree):
                return jax.tree.map(
                    lambda a: jnp.reshape(a, (k, s) + a.shape[1:]), tree)

            etas_f = jnp.reshape(
                jnp.broadcast_to(jnp.asarray(etas)[:, None], (k, s)), (k * s,))
            out = grid_call(flat(stacked), etas_f, flat(params_b),
                            flat(fstate_b), flat(keys_b), data)
            return unflat(out)

        return jax.jit(run)

    def _compile_cohort(self, round_body, length: int, k: int, s: int):
        # the [S, N] cohort leaves tile across the scheme axis and flatten
        # to the same [K*S] cell axis as the carry, so each cell ships its
        # own active set through the mesh (padded with cell 0 like every
        # other sharded operand when K*S doesn't divide the device count)
        def cell(scheme, eta, params, fstate, key, co, data):
            return _scan_chunk(round_body, scheme, eta, params, fstate, key,
                               data, length, cohort=co)

        grid_call = distributed.shard_vmap(cell, self.mesh, self.axes,
                                           num_sharded=6)

        def run(stacked, etas, params_b, fstate_b, keys_b, data, cohort_b):
            def flat(tree):
                return jax.tree.map(
                    lambda a: jnp.reshape(a, (k * s,) + a.shape[2:]), tree)

            def unflat(tree):
                return jax.tree.map(
                    lambda a: jnp.reshape(a, (k, s) + a.shape[1:]), tree)

            etas_f = jnp.reshape(
                jnp.broadcast_to(jnp.asarray(etas)[:, None], (k, s)), (k * s,))
            cohort_f = jax.tree.map(
                lambda a: jnp.reshape(
                    jnp.broadcast_to(jnp.asarray(a)[None],
                                     (k,) + jnp.shape(a)),
                    (k * s,) + jnp.shape(a)[1:]), cohort_b)
            out = grid_call(flat(stacked), etas_f, flat(params_b),
                            flat(fstate_b), flat(keys_b), cohort_f, data)
            return unflat(out)

        return jax.jit(run)

    def compile_batch(self, fn):
        return jax.jit(distributed.shard_vmap(fn, self.mesh, self.axes))

    def describe(self) -> str:
        shape = ",".join(f"{a}={self.mesh.shape[a]}" for a in self.axes)
        return f"sharded[{shape}]"
