"""Host-driver layer of the fleet executor (DESIGN.md §Placement).

Owns everything around the compiled grid chunks of a [K-scheme x S-seed]
fleet: the chunk loop over ``engine.chunk_lengths``, the adaptive
re-design hook between chunks, the eval cadence, the compile/exec wall
split, and the checkpointed-resume path.  WHERE the cells run is the
placement layer's business (``fl.placement``): the driver hands every
chunk a [K, S]-shaped carry and gets one back, whether the cells ran as
one vmapped program on a single device or sharded over a
``("data", "model")`` mesh.

Checkpointed resume: pass ``checkpoint_path`` and the driver persists the
full fleet carry — params_b, fading_state, keys_b, the stacked schemes'
design leaves, plus the metric traces / evals / ``FLResult.designs``
accumulated so far — through ``checkpoint/checkpoint.py`` at every chunk
boundary.  A preempted sweep rerun with ``resume=True`` fast-forwards to
the first incomplete chunk and finishes bit-identically to an
uninterrupted run (same carries, same key streams, same chunk schedule);
AdaptiveSCA design trajectories survive the restart.

Population mode (DESIGN.md §Population): pass a ``scenarios.Population``
and the driver becomes a streaming serving loop — each chunk runs on a
per-round-drawn cohort of ``cohort_size`` devices out of up to ~1M, with
the draw, gain materialization and ``adaptive_sca`` cohort redesign staged
on the host WHILE the previous chunk executes on device (double-buffered;
``stream=False`` serializes the same stages — identical math, different
walls).  Staging is pure in (population, run seed, tick), never in chunk
outputs, which is both why overlap cannot change results and why resume
needs no RNG cursor: a restart re-derives every draw from the chunk index.
"""
from __future__ import annotations

import contextlib
import hashlib
import os
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Callable, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import telemetry as tlm
from repro.checkpoint import checkpoint as ckpt
from repro.core.power_control import _scheme_n, stack_schemes
from repro.fl.engine import (FADING_INIT_SALT, FLResult, _concat_traces,
                             chunk_lengths, make_round_body)
from repro.fl.placement import Placement, VmapPlacement

PyTree = Any


class _Staged(NamedTuple):
    """One staged cohort: everything chunk ``ci`` needs that can be
    computed before chunk ``ci - 1`` finishes (the double buffer)."""
    ci: int
    tick: int
    idx: np.ndarray      # [S, N] drawn device indices (per seed row)
    cohort: dict         # chunk operand: gains [S, N], data_idx [S, N]
    stacked: Any         # cohort-redesigned schemes (None if non-adaptive)
    wall: float


def _ckpt_file(path: str) -> str:
    return path if path.endswith(".npz") else path + ".npz"


def _carry_tree(stacked, params_b, fading_state, keys_b) -> dict:
    carry = {"carry": {"params": params_b, "keys": keys_b},
             "scheme": stacked}
    if fading_state is not None:
        carry["carry"]["fstate"] = fading_state
    return carry


def _array_digest(*arrays) -> str:
    h = hashlib.sha1()
    for a in arrays:
        a = np.ascontiguousarray(np.asarray(a))
        h.update(str(a.dtype).encode())
        h.update(str(a.shape).encode())
        h.update(a.tobytes())
    return h.hexdigest()[:16]


def _fading_desc(fading) -> str:
    if fading is None:
        return "none"
    return (f"{type(fading).__name__}(family={getattr(fading, 'family', '?')}"
            f",rho={float(getattr(fading, 'rho', 0.0))}"
            f",p_dropout={float(getattr(fading, 'p_dropout', 0.0))})")


def _fleet_identity(names, seeds, run, etas, flat, placement, gains, data,
                    fading, population=None, cohort_size=None,
                    cohort_rounds=None, uplink_dtype="f32",
                    scenarios=None) -> dict:
    """Everything that must match for a resumed run to be bit-identical
    to the uninterrupted one: the grid, the full run config (dynamics:
    eta/batch_size/gmax/clipping), the per-scheme etas, the aggregation
    path, the placement (the bitwise contract holds per placement), and
    the physics/data — gains and dataset content hashes plus the fading
    process descriptor and the population/cohort schedule — so a resume
    against a different world is rejected, not silently mixed.  The
    ``stream`` flag is deliberately absent: overlap changes walls, never
    math, so resuming across stream modes is legal — as is ``fuse_round``
    (fused and unfused round tails agree bitwise for f32 and share the
    wire values for quantized uplinks).  ``uplink_dtype`` IS identity:
    quantization changes every trajectory.

    ``scenarios`` (a ``core.scenarios.ScenarioStack``) joins the identity
    twice: the scenario NAMES as a list (so telemetry/report can segment
    the cell axis) and the full stack digest — gains, families, dynamics
    parameters — via ``ScenarioStack.describe()``, so a thousand-cell grid
    resume against a different scenario axis is rejected, not silently
    mixed.  In scenario mode ``gains`` is None (the rows own their gains)
    and the gains digest covers the stacked [C, N] matrix instead."""
    return {"uplink_dtype": str(uplink_dtype),
            "names": list(names), "seeds": list(seeds),
            "num_rounds": run.num_rounds, "eval_every": run.eval_every,
            "eta": run.eta, "batch_size": run.batch_size, "gmax": run.gmax,
            "clip_to_gmax": bool(run.clip_to_gmax), "seed": run.seed,
            "etas": [float(e) for e in np.asarray(etas)],
            "flat": bool(flat), "placement": placement.describe(),
            "gains": _array_digest(gains if gains is not None
                                   else scenarios.gains),
            "data": _array_digest(*data),
            "fading": _fading_desc(fading),
            "population": ("none" if population is None
                           else population.describe()),
            "cohort_size": int(cohort_size or 0),
            "cohort_rounds": int(cohort_rounds or 0),
            "scenarios": ("none" if scenarios is None
                          else list(scenarios.names)),
            "scenario_world": ("none" if scenarios is None
                               else scenarios.describe())}


def _save_fleet_state(path: str, chunks_done: int, t: int, stacked,
                      params_b, fading_state, keys_b, metric_chunks,
                      evals, designs, identity: dict, pop_table=None,
                      cohorts=None) -> None:
    state = _carry_tree(jax.tree.map(np.asarray, stacked),
                        jax.tree.map(np.asarray, params_b),
                        None if fading_state is None
                        else np.asarray(fading_state),
                        np.asarray(keys_b))
    if metric_chunks:
        state["traces"] = _concat_traces(metric_chunks)
    if evals:
        state["evals_t"] = np.asarray([tt for tt, _ in evals], np.int64)
        state["evals"] = {kk: np.stack([np.asarray(ev[kk])
                                        for _, ev in evals])
                          for kk in evals[0][1]}
    if designs:
        state["designs_t"] = np.asarray([tt for tt, _ in designs], np.int64)
        state["designs_g"] = np.stack([np.asarray(g) for _, g in designs])
    if pop_table is not None:
        # the population cursor: which devices a resumed stream has seen,
        # and their Gauss-Markov states — cohort draws themselves need no
        # cursor (they re-derive from (population seed, run seed, tick))
        state["pop_last"] = pop_table["last"]
        state["pop_state"] = pop_table["state"]
    if cohorts:
        state["cohorts_t"] = np.asarray([tt for tt, _ in cohorts], np.int64)
        state["cohorts_idx"] = np.stack([np.asarray(i) for _, i in cohorts])
    ckpt.save(path, state, meta={
        "chunks_done": chunks_done, "rounds_done": t, **identity})


def _load_fleet_state(path: str, stacked, params_b, fading_state, keys_b,
                      identity: dict, adaptive: bool, pop_table=None):
    meta = ckpt.load_meta(path)
    got = {k: meta.get(k) for k in identity}
    mismatch = {k: (got[k], identity[k]) for k in identity
                if got[k] != identity[k]}
    if mismatch:
        raise ValueError(f"checkpoint {path!r} does not match this fleet "
                         f"(saved vs running): {mismatch}")
    flat = ckpt.load_flat(path)          # one read serves carry + extras
    state = ckpt.restore_flat(flat, _carry_tree(stacked, params_b,
                                                fading_state, keys_b))
    traces = {kk[len("traces/"):]: v for kk, v in flat.items()
              if kk.startswith("traces/")}
    metric_chunks = [traces] if traces else []
    evals = []
    if "evals_t" in flat:
        ev_names = [kk[len("evals/"):] for kk in flat
                    if kk.startswith("evals/")]
        evals = [(int(tt), {nm: flat[f"evals/{nm}"][i] for nm in ev_names})
                 for i, tt in enumerate(flat["evals_t"])]
    designs = None
    if adaptive:
        designs = [(int(tt), flat["designs_g"][i])
                   for i, tt in enumerate(flat["designs_t"])]
    if pop_table is not None and "pop_last" in flat:
        pop_table["last"][...] = flat["pop_last"]
        pop_table["state"][...] = flat["pop_state"]
    cohorts = None
    if "cohorts_t" in flat:
        cohorts = [(int(tt), np.asarray(flat["cohorts_idx"][i]))
                   for i, tt in enumerate(flat["cohorts_t"])]
    fstate = state["carry"].get("fstate") if fading_state is not None \
        else None
    return (int(meta["chunks_done"]), int(meta["rounds_done"]),
            state["scheme"], state["carry"]["params"], fstate,
            state["carry"]["keys"], metric_chunks, evals, designs, cohorts)


def run_fleet(loss_fn: Callable, params: PyTree, schemes, gains: np.ndarray,
              data: tuple, run, eval_fn: Optional[Callable] = None, *,
              etas=None, seeds: Optional[Sequence[int]] = None, fading=None,
              flat: bool = True, log: bool = False,
              placement: Optional[Placement] = None,
              checkpoint_path: Optional[str] = None, resume: bool = False,
              max_chunks: Optional[int] = None, population=None,
              cohort_size: Optional[int] = None,
              cohort_rounds: Optional[int] = None,
              stream: bool = True, telemetry=None,
              uplink_dtype: Optional[str] = None,
              fuse_round: Optional[bool] = None,
              scenarios=None) -> FLResult:
    """A [K-scheme x S-seed] experiment grid through a hardware placement.

    The grid/scheme/seed/eta semantics are ``engine.run_fleet``'s (which
    now delegates here): each (k, s) cell consumes the exact key/fading
    streams of a standalone run with that seed.  New driver-level knobs:

    placement        fl.placement.VmapPlacement() (default — one device,
                     bit-identical to the pre-refactor engine) or
                     ShardedPlacement(mesh) to shard the flattened cell
                     grid over a mesh.
    checkpoint_path  persist the fleet carry (params_b, fading_state,
                     keys_b, scheme design leaves, traces/evals/designs)
                     at every chunk boundary via checkpoint/checkpoint.py.
    resume           fast-forward from checkpoint_path if it exists: the
                     completed chunks are skipped and the final FLResult
                     is bit-identical to an uninterrupted run's.
    max_chunks       stop (with a checkpoint saved) after this many chunks
                     this invocation — the preemption hook sweeps and the
                     resume tests use.

    Population mode (DESIGN.md §Population):

    population       a ``scenarios.Population``: each chunk runs on a
                     drawn cohort instead of the full device set.  Data
                     shards are assigned by device index mod the shard
                     count; gains come from the population, lazily.  When
                     ``fading`` is None it defaults to the population's
                     own process (``Population.fading_process``).
    cohort_size      active devices per round, default = the schemes'
                     device count (which it must equal either way).
    cohort_rounds    redraw cadence in rounds; None = once per chunk
                     (i.e. the eval cadence).  Cohorts never straddle a
                     chunk: ``chunk_lengths`` inserts boundaries.
    stream           double-buffer staging (default True): the next
                     cohort's draw + gains + ``adaptive_sca`` cohort
                     redesign run on a host worker thread WHILE the
                     current chunk executes, so redesign latency hides
                     behind device time.  ``stream=False`` runs the same
                     stages serially — bitwise-identical results.
    telemetry        a ``telemetry.Telemetry`` (or a bare run-dir string)
                     turns on structured JSONL run tracing and the
                     in-graph bias–variance diagnostics riding
                     ``traces`` (DESIGN.md §Telemetry).  ``None``
                     (default) compiles and runs the exact pre-telemetry
                     program — bitwise, not just numerically.
    uplink_dtype     wire precision devices transmit — "f32" | "bf16" |
                     "int8" (per-device symmetric scale; DESIGN.md
                     §Kernels).  ``None`` (default) takes
                     ``run.uplink_dtype``.  Non-f32 requires ``flat``.
                     Part of the checkpoint identity: it changes the
                     numbers, so resuming across uplink dtypes is
                     rejected.
    fuse_round       force the flat round tail fused (one
                     ``ota_round_step`` launch) or unfused (the
                     historical aggregate-then-update chain); ``None`` =
                     fused exactly when ``flat``.  NOT part of the
                     checkpoint identity — with an f32 uplink the two are
                     bitwise-identical, and quantized uplinks share the
                     same wire values either way.
    scenarios        a ``core.scenarios.ScenarioStack`` of C deployments:
                     the fleet becomes the [C x K x S] grid of DESIGN.md
                     §Grid, laid out as [C*K, S] cells with the scenario
                     rows riding the cell axis.  ``schemes`` must then be
                     the scenario-major flattened list (scenario c's K
                     schemes at rows c*K..c*K+K-1 — every scenario gets
                     its own power-control designs, solved against ITS
                     gains), ``gains``/``fading`` must be None (each row
                     owns its channel world), and cell (c, k, s) is
                     bitwise the (k, s) cell of a plain fleet run on
                     scenario c alone.  ``FLResult.names`` come back as
                     "scenario/scheme"; the scenario axis joins the
                     checkpoint identity.  Exclusive with population mode
                     and adaptive (redesign_fn) schemes.

    Adaptive schemes (``power_control.AdaptiveSCA``) re-design BETWEEN
    chunks from the live fading state, whatever the placement: the state
    gathers to host at the chunk boundary, the batched SCA solver re-solves
    per cell, and the new [K, S] design leaves ship with the next chunk.
    In population mode the redesign input is the INCOMING cohort's
    stationary statistical CSI instead (``redesign_cohort_fn`` — pure in
    the cohort gains, hence overlappable); Gauss-Markov state still
    threads through rounds via the population's re-entry table.
    """
    t0 = time.time()
    placement = placement if placement is not None else VmapPlacement()
    stacked = schemes if not isinstance(schemes, (list, tuple)) \
        else stack_schemes(schemes)
    names = tuple(getattr(stacked, "names", (stacked.name,)))
    k = len(names)
    seeds = tuple(int(s) for s in (seeds if seeds is not None
                                   else (run.seed,)))
    s_axis = len(seeds)
    if etas is None:
        etas = np.full(k, run.eta, np.float64)
    etas = np.asarray(etas, np.float64)
    if etas.shape != (k,):
        raise ValueError(f"etas shape {etas.shape} != ({k},)")
    # resolve here (not just in make_round_body): the checkpoint identity
    # must record the wire precision actually used
    if uplink_dtype is None:
        uplink_dtype = getattr(run, "uplink_dtype", "f32") or "f32"

    redesign = getattr(stacked, "redesign_fn", None)
    pop_mode = population is not None
    scen_mode = scenarios is not None
    scen_b = None
    if scen_mode:
        c = len(scenarios)
        if pop_mode:
            raise ValueError("scenario grids and population mode are "
                             "exclusive (a cohort would need per-scenario "
                             "device worlds)")
        if fading is not None:
            raise ValueError("scenario grids own the channel process; "
                             "pass fading=None")
        if gains is not None:
            raise ValueError("scenario grids own the gains; pass gains=None")
        if redesign is not None:
            raise ValueError("adaptive (redesign_fn) schemes are not "
                             "supported on scenario grids")
        if k % c:
            raise ValueError(f"{k} stacked schemes don't tile over {c} "
                             f"scenarios (need a multiple of {c})")
        if scenarios.num_devices != _scheme_n(stacked):
            raise ValueError(
                f"scenario stack is a {scenarios.num_devices}-device world "
                f"but the schemes are designed for {_scheme_n(stacked)}")
        k_schemes = k // c
        # cell axis is scenario-major: names scope to "scenario/scheme"
        names = tuple(f"{sn}/{nm}" for sn, nm
                      in zip(np.repeat(list(scenarios.names), k_schemes),
                             names))
        scen_b = scenarios.tile_over_schemes(k_schemes)   # [K, ...] rows
    n_cohort = cohort_cadence = None
    if pop_mode:
        n_cohort = int(cohort_size) if cohort_size else _scheme_n(stacked)
        if not 0 < n_cohort <= population.size:
            raise ValueError(f"cohort size {n_cohort} not in "
                             f"[1, {population.size}]")
        if _scheme_n(stacked) != n_cohort:
            raise ValueError(
                f"schemes are designed for {_scheme_n(stacked)} devices "
                f"but the cohort draws {n_cohort} — build the power "
                f"control for the cohort-sized world")
        cohort_cadence = int(cohort_rounds) if cohort_rounds else None
        if fading is None:
            fading = population.fading_process()
    adaptive = redesign is not None and fading is not None and not pop_mode
    redesign_cohort = getattr(stacked, "redesign_cohort_fn", None)
    pop_adaptive = pop_mode and redesign_cohort is not None
    stacked = placement.prepare_schemes(stacked, s_axis,
                                        adaptive or pop_adaptive)

    tel = tlm.Telemetry(run_dir=telemetry) if isinstance(telemetry, str) \
        else telemetry
    resuming = bool(checkpoint_path and resume
                    and os.path.exists(_ckpt_file(checkpoint_path)))
    # fresh=False keeps the existing event log: the resumed process reads
    # the run id back and ``tracer.resume`` prunes the superseded suffix
    tracer = tlm.Tracer(tel.run_dir, fresh=not resuming) \
        if tel is not None and tel.trace else None
    metrics_hook = tlm.make_metrics_hook(tel.kappa_sq) \
        if tel is not None and tel.diagnostics else None

    def _span(kind, **fields):
        return tracer.span(kind, **fields) if tracer is not None \
            else contextlib.nullcontext()

    def _ctx(**fields):
        return tracer.ctx(**fields) if tracer is not None \
            else contextlib.nullcontext()

    round_body = make_round_body(loss_fn, gains, run, fading=fading,
                                 flat=flat, cohort=pop_mode,
                                 scenario=scen_mode,
                                 metrics_hook=metrics_hook,
                                 uplink_dtype=uplink_dtype,
                                 fuse_round=fuse_round)
    chunk = placement.build_chunk(round_body, adaptive or pop_adaptive,
                                  cohort=pop_mode, scenario=scen_mode,
                                  tracer=tracer)

    data = tuple(jnp.asarray(a) for a in data)
    params_b = jax.tree.map(
        lambda a: jnp.tile(jnp.asarray(a)[None, None],
                           (k, s_axis) + (1,) * jnp.ndim(a)), params)
    keys0 = jnp.stack([jax.random.PRNGKey(s) for s in seeds])      # [S, 2]
    keys_b = jnp.tile(keys0[None], (k, 1, 1))                      # [K, S, 2]
    fading_state = None
    pop_table = None
    if scen_mode:
        # each scenario row inits its own channel state from the SAME
        # per-seed salted keys a standalone fleet on that scenario uses,
        # then repeats over its schemes — cell (c, k, s) starts bitwise
        # where scenario c's plain fleet does
        init_keys = jax.vmap(
            lambda kk: jax.random.fold_in(kk, FADING_INIT_SALT))(keys0)
        state_cs = scenarios.init_grid(init_keys)                # [C, S, N]
        fading_state = jnp.repeat(state_cs, k // len(scenarios), axis=0)
    elif fading is not None and not pop_mode:
        init_keys = jax.vmap(
            lambda kk: jax.random.fold_in(kk, FADING_INIT_SALT))(keys0)
        state_s = fading.init_batch(init_keys)                     # [S, N]
        fading_state = jnp.tile(state_s[None], (k,) + (1,) * state_s.ndim)
    elif pop_mode and fading is not None:
        # cohort states are staged per chunk from the re-entry table
        pop_table = population.init_table(s_axis)

    eval_b = None
    if eval_fn is not None:
        eval_b = jax.jit(jax.vmap(jax.vmap(eval_fn)))

    designs = None
    if adaptive:
        designs = [(0, np.asarray(stacked.gamma))]
    elif pop_adaptive:
        designs = []
    cohorts = [] if pop_mode else None
    evals, metric_chunks, t = [], [], 0
    lengths = chunk_lengths(run.num_rounds, run.eval_every,
                            eval_fn is not None or adaptive or pop_adaptive,
                            cohort_cadence)
    starts = np.concatenate([[0], np.cumsum(lengths)])[:-1].astype(int)

    def _tick_of(ci: int) -> int:
        return int(starts[ci]) // cohort_cadence if cohort_cadence else ci

    n_shards = int(jnp.shape(data[0])[0]) if pop_mode else 0

    # the staging lane: devices execute queued computations in FIFO order,
    # so a redesign solve dispatched to the device running the chunk waits
    # for the whole chunk instead of overlapping it.  With more than one
    # device visible the solve runs on the LAST one (the vmap fleet only
    # occupies the first); CPU executables are identical across host
    # devices, so the lane cannot change a single bit — only walls.
    stage_dev = None
    if pop_adaptive and len(jax.devices()) > 1:
        stage_dev = jax.devices()[-1]

    def _stage(ci: int, base) -> _Staged:
        # everything here is pure in (population, seeds, tick) and the
        # schemes' static problem constants — NEVER in chunk outputs — so
        # running it concurrently with the executing chunk (stream=True)
        # cannot change any number, only walls.  The tracer ctx tags the
        # worker thread's events (the cohort redesign's ``sca_solve``)
        # with this chunk index, which is what lets ``tracer.resume``
        # prune them correctly after a preemption.
        ts = time.time()
        with _ctx(chunk=ci):
            tick = _tick_of(ci)
            idx = np.stack([population.draw_cohort(n_cohort, tick, s)
                            for s in seeds])                      # [S, N]
            gains_sn = np.stack([population.gains_of(r) for r in idx])
            cohort_b = {"gains": jnp.asarray(gains_sn),
                        "data_idx": jnp.asarray((idx % n_shards)
                                                .astype(np.int32))}
            new_stacked = None
            fresh = ci == 0 or tick != _tick_of(ci - 1)
            if pop_adaptive and fresh:
                gains_ksn = np.broadcast_to(
                    gains_sn[None], (k,) + gains_sn.shape).copy()
                if stage_dev is not None:
                    with jax.default_device(stage_dev):
                        new_stacked = redesign_cohort(base, gains_ksn)
                else:
                    new_stacked = redesign_cohort(base, gains_ksn)
        staged = _Staged(ci=ci, tick=tick, idx=idx, cohort=cohort_b,
                         stacked=new_stacked, wall=time.time() - ts)
        if tracer is not None:
            tracer.event("stage", chunk=ci, tick=tick,
                         dur=round(staged.wall, 6),
                         redesigned=new_stacked is not None)
        return staged

    identity = None
    if checkpoint_path is not None:
        identity = _fleet_identity(names, seeds, run, etas, flat, placement,
                                   gains, data, fading, population,
                                   n_cohort, cohort_cadence, uplink_dtype,
                                   scenarios)
    start_chunk = 0
    if resuming:
        (start_chunk, t, stacked, params_b, fading_state, keys_b,
         metric_chunks, evals, designs, loaded_cohorts) = _load_fleet_state(
            checkpoint_path, stacked, params_b, fading_state, keys_b,
            identity, adaptive or pop_adaptive, pop_table)
        if loaded_cohorts is not None:
            cohorts = loaded_cohorts
        if log:
            print(f"# resumed fleet from {checkpoint_path} at chunk "
                  f"{start_chunk} (round {t})")
    if tracer is not None:
        if resuming:
            # drop events from chunks the preempted process started but
            # this one will re-run, so the log describes ONE consistent
            # execution (no duplicate chunk spans after a kill+resume)
            tracer.resume(start_chunk)
        tracer.event("fleet_config", names=list(names), seeds=list(seeds),
                     num_rounds=int(run.num_rounds),
                     eval_every=int(run.eval_every),
                     placement=placement.describe(cells=k * s_axis),
                     chunks=len(lengths),
                     population=(int(population.size) if pop_mode else None),
                     cohort_size=n_cohort, cohort_rounds=cohort_cadence,
                     scenarios=(list(scenarios.names) if scen_mode
                                else None),
                     stream=bool(stream), start_chunk=start_chunk)
    last_tick = _tick_of(start_chunk - 1) \
        if pop_mode and start_chunk > 0 else None

    executor = ThreadPoolExecutor(max_workers=1) \
        if pop_mode and stream else None
    staged = next_fut = None
    wall_stage = 0.0
    stage_walls = [] if pop_mode else None
    wall_compile, first = 0.0, True
    prev_hook, hook_set = None, False
    if tracer is not None:
        from repro.solvers import sca_jax
        prev_hook = sca_jax.set_trace_hook(
            lambda rec: tracer.event("sca_solve", **rec))
        hook_set = True
    try:
        for ci, length in enumerate(lengths):
            if ci < start_chunk:
                continue
            if pop_mode:
                if next_fut is not None:
                    tw = time.time()
                    staged, next_fut = next_fut.result(), None
                    if tracer is not None:
                        # visible staging latency: how long the driver sat
                        # waiting on the double buffer (0 when staging hid
                        # completely behind the previous chunk)
                        tracer.event("stage_wait", chunk=staged.ci,
                                     dur=round(time.time() - tw, 6))
                if staged is None or staged.ci != ci:
                    staged = _stage(ci, stacked)
                wall_stage += staged.wall
                stage_walls.append(staged.wall)
                t_start = int(starts[ci])
                if staged.tick != last_tick:
                    last_tick = staged.tick
                    cohorts.append((t_start, staged.idx))
                    if pop_adaptive:
                        stacked = staged.stacked
                        designs.append((t_start, np.asarray(stacked.gamma)))
                    if tracer is not None:
                        rec = {"chunk": ci, "t": t_start,
                               "tick": staged.tick,
                               "cohort_size": int(staged.idx.shape[1])}
                        if pop_table is not None:
                            # per-device staleness off the re-entry table
                            # BEFORE staging touches it: rounds since each
                            # drawn device last participated (-1 = never)
                            seen = np.stack(
                                [pop_table["last"][si, staged.idx[si]]
                                 for si in range(s_axis)])
                            rec["staleness"] = np.where(
                                seen < 0, -1,
                                np.maximum(t_start - 1 - seen, 0))
                            rec["never_seen"] = int(np.sum(seen < 0))
                        tracer.event("cohort", **rec)
                if fading is not None:
                    # re-entry staging reads the table committed by the
                    # PREVIOUS chunk, so it stays serialized (it is a [N]
                    # gather + aging arithmetic — cheap by construction)
                    state_sn = np.stack([
                        population.stage_states(pop_table, si,
                                                staged.idx[si], t_start,
                                                seed=seeds[si])
                        for si in range(s_axis)])                 # [S, N]
                    fading_state = jnp.asarray(np.broadcast_to(
                        state_sn[None], (k,) + state_sn.shape))
                will_stop = (max_chunks is not None
                             and ci + 1 - start_chunk >= max_chunks
                             and ci + 1 < len(lengths))
                if executor is not None and ci + 1 < len(lengths) \
                        and not will_stop:
                    # the double buffer: stage chunk ci+1 on the worker
                    # BEFORE dispatching chunk ci, then collect it after
                    # the chunk returns — the cohort draw and SCA redesign
                    # overlap device execution instead of serializing
                    next_fut = executor.submit(_stage, ci + 1, stacked)
            with _ctx(chunk=ci):
                t_ex = time.monotonic()
                if pop_mode:
                    params_b, fading_state, keys_b, metrics = chunk(
                        stacked, etas, params_b, fading_state, keys_b, data,
                        staged.cohort, length=length)
                elif scen_mode:
                    params_b, fading_state, keys_b, metrics = chunk(
                        stacked, etas, params_b, fading_state, keys_b, data,
                        scen_b, length=length)
                else:
                    params_b, fading_state, keys_b, metrics = chunk(
                        stacked, etas, params_b, fading_state, keys_b, data,
                        length=length)
                if tracer is not None:
                    # the block makes dur the true device wall (dispatch is
                    # async); telemetry-off keeps the async pipeline as-is
                    jax.block_until_ready(params_b)
                    tracer.event("chunk_exec", chunk=ci, length=int(length),
                                 t_start=t,
                                 cache_size=tlm.chunk_cache_size(chunk),
                                 dur=round(time.monotonic() - t_ex, 6))
            if first:
                jax.block_until_ready(params_b)
                wall_compile = time.time() - t0
                first = False
            metric_chunks.append(metrics)
            t += length
            if pop_mode and fading is not None:
                # scheme rows share keys, so states agree across K: commit
                # row 0 of the [K, S, N] state per seed
                fs = np.asarray(fading_state)
                for si in range(s_axis):
                    population.commit_states(pop_table, si, staged.idx[si],
                                             t - 1, fs[0, si])
            if adaptive and t < run.num_rounds:
                # gather the live state to host first: the re-design solve
                # must see one replicated array, not a mesh-sharded one, so
                # the new design is bitwise the same whatever placement ran
                # the chunk
                with _ctx(chunk=ci), _span("redesign", chunk=ci, t=t):
                    stacked = redesign(stacked, fading,
                                       np.asarray(fading_state))
                designs.append((t, np.asarray(stacked.gamma)))
            if eval_b is not None:
                with _span("eval", chunk=ci, t=t - 1):
                    ev = {kk: np.asarray(v)
                          for kk, v in eval_b(params_b).items()}
                evals.append((t - 1, ev))
                if log:
                    lead = next(iter(ev))
                    print({"round": t - 1,
                           **{n: round(float(ev[lead][i, 0]), 4)
                              for i, n in enumerate(names)}})
            if checkpoint_path is not None:
                with _span("ckpt_save", chunk=ci):
                    _save_fleet_state(checkpoint_path, ci + 1, t, stacked,
                                      params_b, fading_state, keys_b,
                                      metric_chunks, evals, designs, identity,
                                      pop_table, cohorts)
            if max_chunks is not None and ci + 1 - start_chunk >= max_chunks \
                    and ci + 1 < len(lengths):
                break        # preempted on purpose; resume=True continues
    finally:
        if hook_set:
            sca_jax.set_trace_hook(prev_hook)
        if executor is not None:
            executor.shutdown(wait=True)

    wall = time.time() - t0
    if tracer is not None:
        tracer.event("run_end", rounds_done=int(t),
                     chunks_done=(ci + 1 if lengths else 0),
                     wall_s=round(wall, 3), wall_stage=round(wall_stage, 3))
    return FLResult(params=params_b, traces=_concat_traces(metric_chunks),
                    evals=evals, names=names, seeds=seeds, wall=wall,
                    wall_compile=wall_compile, wall_exec=wall - wall_compile,
                    fading_state=fading_state, designs=designs,
                    wall_stage=wall_stage, cohorts=cohorts,
                    stage_walls=stage_walls,
                    scenario_names=(scenarios.names if scen_mode else None))


def _scheme_names(schemes) -> list:
    if isinstance(schemes, (list, tuple)):
        return [pc.name for pc in schemes]
    return list(getattr(schemes, "names", (schemes.name,)))


def resolve_task_bundle(task, run, *, task_data=None, params=None,
                        eval_fn=None, seed=None, data_kw=None):
    """Default resolution shared by every task-first entry point
    (``run_fleet_task`` here, ``fl.server.run_fl_task``) so the
    load-bearing conventions live in ONE place: run = task.run_config()
    unless given, and seed = run.seed feeds BOTH build_data and the
    param-init PRNGKey — the historical wiring the paper_mlp bit-identity
    contract pins.  Returns (run, task_data, params, eval_fn)."""
    run = run if run is not None else task.run_config()
    seed = run.seed if seed is None else seed
    td = task_data if task_data is not None \
        else task.build_data(seed, **(data_kw or {}))
    if params is None:
        params = task.init_params(seed)
    if eval_fn is None:
        eval_fn = task.make_eval(td)
    return run, td, params, eval_fn


def run_fleet_task(task, schemes, gains: np.ndarray, run=None, *,
                   task_data=None, params: Optional[PyTree] = None,
                   eval_fn: Optional[Callable] = None, etas=None,
                   seed: Optional[int] = None, data_kw: Optional[dict] = None,
                   **driver_kw) -> FLResult:
    """Task-first fleet entry point (DESIGN.md §Tasks).

    ``task`` is any object honouring the ``repro.tasks.base.Task``
    contract (duck-typed — the fl layer never imports the registry): the
    workload's data / param-init / loss / eval and its preferred run
    config all come from the bundle, so callers only supply the wireless
    side (``schemes``, ``gains``) and placement/checkpoint knobs.

    Defaults resolve exactly like the pre-task hand-wired path, so
    ``paper_mlp`` through here is bit-identical to
    ``run_fleet(mlp.mlp_loss, init_params(...), ...)``:

    run        task.run_config() unless given.
    seed       run.seed unless given — feeds BOTH build_data and the
               param-init PRNGKey, the historical convention.
    task_data  a pre-built TaskData (skip build_data — e.g. to share one
               materialized dataset across placements or scheme grids).
    params     explicit initial params (skip task.init_params).
    eval_fn    explicit eval (else task.make_eval on the built data).
    etas       per-scheme step sizes [K]; defaults to the task's
               grid-searched ``scheme_etas`` with run.eta as fallback.
    data_kw    extra kwargs for build_data (e.g. steps= for LM tasks).

    Everything else (``seeds``, ``fading``, ``flat``, ``placement``,
    ``checkpoint_path``, ``resume``, ``max_chunks``, ``log``) passes
    through to :func:`run_fleet`.
    """
    run, td, params, eval_fn = resolve_task_bundle(
        task, run, task_data=task_data, params=params, eval_fn=eval_fn,
        seed=seed, data_kw=data_kw)
    if etas is None:
        etas = [task.eta_for(n, run.eta) for n in _scheme_names(schemes)]
    return run_fleet(task.loss_fn, params, schemes, gains, td.train, run,
                     eval_fn, etas=etas, **driver_kw)
