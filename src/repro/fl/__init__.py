"""repro.fl — the paper-scale FL runtimes.

``server``: single-run API (run_fl on the scan engine; run_fl_legacy host
loop preserved as oracle/baseline).  ``engine``: the scan/vmap-compiled
experiment engine — run_rounds for one (scheme, seed), run_fleet for a
[K-scheme x S-seed] grid in one compiled program (DESIGN.md §Engine).
"""
from repro.fl.engine import FLResult, run_fleet, run_rounds  # noqa: F401
from repro.fl.server import (FLRunConfig, History, make_round_fn,  # noqa: F401
                             run_fl, run_fl_legacy)
