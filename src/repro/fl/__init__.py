"""repro.fl — the paper-scale FL runtimes.

``server``: single-run API (run_fl on the scan engine; run_fl_legacy host
loop preserved as oracle/baseline).  The fleet executor is three layers
(DESIGN.md §Placement): ``engine`` — the chunked-scan cell program
(run_rounds for one (scheme, seed) cell; run_fleet as the single-device
alias); ``placement`` — where the [K-scheme x S-seed] grid runs
(VmapPlacement on one device, ShardedPlacement over a ("data", "model")
mesh); ``driver`` — the host chunk loop with the adaptive re-design hook
and checkpointed resume.
"""
from repro.fl.engine import FLResult, run_fleet, run_rounds  # noqa: F401
from repro.fl.placement import (Placement, ShardedPlacement,  # noqa: F401
                                VmapPlacement)
from repro.fl import driver  # noqa: F401
from repro.fl.server import (FLRunConfig, History, make_round_fn,  # noqa: F401
                             run_fl, run_fl_legacy)
