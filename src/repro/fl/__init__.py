"""repro.fl"""
