"""Synthetic datasets (no downloads in this container).

* ``mnist_like`` — deterministic 10-class 28x28 image task standing in for
  MNIST in the paper's §IV experiment: each class is a smoothed random
  template; samples are template + Gaussian pixel noise.  Linearly separable
  enough to show clean accuracy-vs-round curves, hard enough (with non-iid
  splits) that participation bias visibly hurts generalization — the
  property Fig. 2 exercises.

* ``cifar_like`` — deterministic 10-class 32x32x3 image task standing in
  for CIFAR-10 (this container downloads nothing): each class is a smoothed
  random color-blob template plus a class-specific low-frequency color wave;
  samples are template + Gaussian pixel noise.  Markedly harder than
  ``mnist_like`` under non-iid splits (three channels, more intra-class
  variation), which is the regime heterogeneous-data OTA-FL work cares
  about (Sery et al.).

* ``token_stream`` — deterministic synthetic LM corpus (Zipf unigrams with
  a Markov flavour) for the transformer FL examples.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

IMG_DIM = 784
NUM_CLASSES = 10


def mnist_like(samples_per_class: int = 1000, num_classes: int = NUM_CLASSES,
               noise: float = 0.35, seed: int = 0,
               test_per_class: int = 100):
    """Returns (x_train, y_train, x_test, y_test); x in [0,1]^784."""
    rng = np.random.default_rng(seed)
    # class templates: sparse blobs smoothed by a box filter
    templates = []
    for _ in range(num_classes):
        img = np.zeros((28, 28))
        for _ in range(6):
            cx, cy = rng.integers(4, 24, size=2)
            img[max(0, cx - 3):cx + 3, max(0, cy - 3):cy + 3] += rng.uniform(0.5, 1.0)
        # cheap smoothing
        k = np.ones((3, 3)) / 9.0
        pad = np.pad(img, 1)
        img = sum(pad[i:i + 28, j:j + 28] * k[i, j]
                  for i in range(3) for j in range(3))
        templates.append(img.reshape(-1))
    templates = np.stack(templates)
    templates /= templates.max(axis=1, keepdims=True) + 1e-9

    def make(n_per):
        xs, ys = [], []
        for c in range(num_classes):
            x = templates[c][None] + noise * rng.standard_normal((n_per, IMG_DIM))
            xs.append(np.clip(x, 0.0, 1.0))
            ys.append(np.full(n_per, c, dtype=np.int32))
        x = np.concatenate(xs).astype(np.float32)
        y = np.concatenate(ys)
        perm = rng.permutation(len(y))
        return x[perm], y[perm]

    x_tr, y_tr = make(samples_per_class)
    x_te, y_te = make(test_per_class)
    return x_tr, y_tr, x_te, y_te


CIFAR_SHAPE = (32, 32, 3)
CIFAR_CLASSES = 10


def _smooth2d(img: np.ndarray, passes: int = 1) -> np.ndarray:
    """Cheap 3x3 box smoothing per channel (same trick as mnist_like)."""
    k = np.ones((3, 3)) / 9.0
    for _ in range(passes):
        pad = np.pad(img, ((1, 1), (1, 1), (0, 0)))
        img = sum(pad[i:i + img.shape[0], j:j + img.shape[1]] * k[i, j]
                  for i in range(3) for j in range(3))
    return img


def cifar_like(samples_per_class: int = 500,
               num_classes: int = CIFAR_CLASSES, noise: float = 0.25,
               seed: int = 0, test_per_class: int = 100):
    """Returns (x_train, y_train, x_test, y_test); x in [0,1]^(32,32,3).

    Per class: 8 random color blobs smoothed into a template, plus a
    class-indexed sinusoidal color wave (distinct dominant orientation and
    hue per class) so classes differ in both texture and global structure.
    Everything derives from ``seed`` — fully deterministic, no downloads.
    """
    rng = np.random.default_rng(seed)
    h, w, c = CIFAR_SHAPE
    yy, xx = np.meshgrid(np.arange(h), np.arange(w), indexing="ij")
    templates = []
    for cls in range(num_classes):
        img = np.zeros((h, w, c))
        for _ in range(8):
            cx, cy = rng.integers(4, h - 4, size=2)
            color = rng.uniform(0.3, 1.0, size=c)
            img[max(0, cx - 4):cx + 4, max(0, cy - 4):cy + 4] += color
        # class-specific low-frequency wave: orientation indexed by class,
        # hue phase-shifted per channel
        theta = np.pi * cls / num_classes
        wave = np.sin((xx * np.cos(theta) + yy * np.sin(theta))
                      * (2 * np.pi / 16.0))
        phases = rng.uniform(0, 2 * np.pi, size=c)
        img += 0.35 * np.cos(wave[..., None] * np.pi + phases)
        img = _smooth2d(img, passes=2)
        img -= img.min()
        img /= img.max() + 1e-9
        templates.append(img)
    templates = np.stack(templates)                     # [C, 32, 32, 3]

    def make(n_per):
        xs, ys = [], []
        for cls in range(num_classes):
            x = templates[cls][None] \
                + noise * rng.standard_normal((n_per, h, w, c))
            xs.append(np.clip(x, 0.0, 1.0))
            ys.append(np.full(n_per, cls, dtype=np.int32))
        x = np.concatenate(xs).astype(np.float32)
        y = np.concatenate(ys)
        perm = rng.permutation(len(y))
        return x[perm], y[perm]

    x_tr, y_tr = make(samples_per_class)
    x_te, y_te = make(test_per_class)
    return x_tr, y_tr, x_te, y_te


def token_stream(num_tokens: int, vocab_size: int, seed: int = 0,
                 order: float = 1.2) -> np.ndarray:
    """Zipf-distributed token stream with short-range repetition structure."""
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, vocab_size + 1, dtype=np.float64)
    probs = ranks ** (-order)
    probs /= probs.sum()
    toks = rng.choice(vocab_size, size=num_tokens, p=probs).astype(np.int32)
    # inject bigram structure: with prob .3, repeat the token 2 back
    mask = rng.uniform(size=num_tokens) < 0.3
    toks[2:][mask[2:]] = toks[:-2][mask[2:]]
    return toks
