"""Non-iid federated data partitioning.

Two protocols:

* **Ring** (paper §IV): each device holds samples of exactly
  ``labels_per_device`` digits, and any given label appears in the local
  datasets of at most ``max_devices_per_label`` devices.  With N = 10,
  2 labels/device and <= 2 devices/label this is the exact bipartite
  matching of the paper: device m <- {m, (m+1) mod 10}.

* **Dirichlet(α)** (``partition_dirichlet``, the Hsu-et-al. protocol the
  heterogeneous-data OTA-FL literature sweeps): for every label, device
  shares are drawn from Dirichlet(α 1_N) — α -> 0 gives one-device-per-
  label shards, α -> inf recovers the i.i.d. split.  Sample-conserving:
  every sample lands on exactly one device.
"""
from __future__ import annotations

from typing import List, Tuple

import numpy as np


def label_assignment(num_devices: int, num_classes: int,
                     labels_per_device: int = 2,
                     max_devices_per_label: int = 2) -> List[Tuple[int, ...]]:
    """Ring assignment: device m gets labels {m, m+1, ...} mod num_classes."""
    total_slots = num_devices * labels_per_device
    if total_slots > num_classes * max_devices_per_label:
        raise ValueError("infeasible: label slots exceed device-per-label cap")
    out = []
    for m in range(num_devices):
        out.append(tuple((m + j) % num_classes
                         for j in range(labels_per_device)))
    # verify the cap
    counts = np.zeros(num_classes, int)
    for labs in out:
        for l in labs:
            counts[l] += 1
    assert counts.max() <= max_devices_per_label, counts
    return out


def partition_by_label(x: np.ndarray, y: np.ndarray, num_devices: int,
                       labels_per_device: int = 2,
                       max_devices_per_label: int = 2, seed: int = 0):
    """Split (x, y) across devices per the paper's non-iid protocol.

    Returns list of (x_m, y_m); each label's samples are split evenly among
    the devices owning it.  All devices end up with equal-size datasets when
    samples/class are uniform.
    """
    num_classes = int(y.max()) + 1
    assign = label_assignment(num_devices, num_classes, labels_per_device,
                              max_devices_per_label)
    rng = np.random.default_rng(seed)
    owners = {c: [m for m, labs in enumerate(assign) if c in labs]
              for c in range(num_classes)}
    shards = [[] for _ in range(num_devices)]
    for c, devs in owners.items():
        idx = np.where(y == c)[0]
        rng.shuffle(idx)
        for j, m in enumerate(devs):
            shards[m].append(idx[j::len(devs)])
    out = []
    for m in range(num_devices):
        idx = np.concatenate(shards[m]) if shards[m] else np.array([], int)
        rng.shuffle(idx)
        out.append((x[idx], y[idx]))
    return out


def partition_dirichlet(x: np.ndarray, y: np.ndarray, num_devices: int,
                        alpha: float = 0.5, seed: int = 0,
                        min_per_device: int = 1):
    """Dirichlet(α) label partition across ``num_devices`` devices.

    For each class c the class's (shuffled) samples are split into
    contiguous chunks sized by a draw pi_c ~ Dirichlet(α 1_N), so the
    total sample count is conserved exactly.  Small α concentrates each
    class on few devices (strong label skew); large α approaches uniform
    per-device label histograms.

    ``min_per_device`` repairs pathological draws (a device with fewer
    than that many samples steals from the largest shard) so downstream
    ``stack_shards`` never sees an empty device.  Returns a list of
    (x_m, y_m), one per device.
    """
    if alpha <= 0:
        raise ValueError(f"alpha must be > 0, got {alpha}")
    num_classes = int(y.max()) + 1
    rng = np.random.default_rng(seed)
    assign = [[] for _ in range(num_devices)]
    for c in range(num_classes):
        idx = np.where(y == c)[0]
        rng.shuffle(idx)
        pi = rng.dirichlet(np.full(num_devices, float(alpha)))
        # contiguous-chunk split by cumulative shares: conserves samples
        cuts = np.floor(np.cumsum(pi) * len(idx)).astype(int)
        cuts[-1] = len(idx)
        start = 0
        for m, stop in enumerate(cuts):
            if stop > start:
                assign[m].append(idx[start:stop])
            start = stop
    shards_idx = [np.concatenate(a) if a else np.array([], dtype=int)
                  for a in assign]
    # repair: every device keeps at least min_per_device samples
    for m in range(num_devices):
        while len(shards_idx[m]) < min_per_device:
            donor = int(np.argmax([len(s) for s in shards_idx]))
            if len(shards_idx[donor]) <= min_per_device:
                raise ValueError("not enough samples to give every device "
                                 f"{min_per_device}")
            shards_idx[m] = np.concatenate([shards_idx[m],
                                            shards_idx[donor][-1:]])
            shards_idx[donor] = shards_idx[donor][:-1]
    out = []
    for m in range(num_devices):
        idx = shards_idx[m]
        rng.shuffle(idx)
        out.append((x[idx], y[idx]))
    return out


def stack_shards(shards, pad: bool = False):
    """Stack shards into arrays with leading device axis [N, ...]
    (rectangular, vmap-able across devices).

    pad=False (default) truncates to the minimum shard size — lossless for
    the ring protocol's equal shards, the historical behavior.  For
    unequal shards (Dirichlet), pad=True rectangularizes to the LARGEST
    shard by cyclic repetition of each shard's rows instead, so no sample
    is discarded; repeated rows get proportionally higher weight under
    the engine's uniform-with-replacement minibatch sampling (and under
    full-batch means), which is the standard way to square off skewed
    federated shards.
    """
    sizes = [len(s[1]) for s in shards]
    n = max(sizes) if pad else min(sizes)

    def fit(a):
        if len(a) >= n:
            return a[:n]
        reps = -(-n // len(a))
        return np.concatenate([a] * reps)[:n]

    xs = np.stack([fit(s[0]) for s in shards])
    ys = np.stack([fit(s[1]) for s in shards])
    return xs, ys
