"""Non-iid federated data partitioning (paper §IV protocol).

Each device holds samples of exactly ``labels_per_device`` digits, and any
given label appears in the local datasets of at most ``max_devices_per_label``
devices.  With N = 10, 2 labels/device and <= 2 devices/label this is the
exact bipartite matching of the paper: device m <- {m, (m+1) mod 10}.
"""
from __future__ import annotations

from typing import List, Tuple

import numpy as np


def label_assignment(num_devices: int, num_classes: int,
                     labels_per_device: int = 2,
                     max_devices_per_label: int = 2) -> List[Tuple[int, ...]]:
    """Ring assignment: device m gets labels {m, m+1, ...} mod num_classes."""
    total_slots = num_devices * labels_per_device
    if total_slots > num_classes * max_devices_per_label:
        raise ValueError("infeasible: label slots exceed device-per-label cap")
    out = []
    for m in range(num_devices):
        out.append(tuple((m + j) % num_classes
                         for j in range(labels_per_device)))
    # verify the cap
    counts = np.zeros(num_classes, int)
    for labs in out:
        for l in labs:
            counts[l] += 1
    assert counts.max() <= max_devices_per_label, counts
    return out


def partition_by_label(x: np.ndarray, y: np.ndarray, num_devices: int,
                       labels_per_device: int = 2,
                       max_devices_per_label: int = 2, seed: int = 0):
    """Split (x, y) across devices per the paper's non-iid protocol.

    Returns list of (x_m, y_m); each label's samples are split evenly among
    the devices owning it.  All devices end up with equal-size datasets when
    samples/class are uniform.
    """
    num_classes = int(y.max()) + 1
    assign = label_assignment(num_devices, num_classes, labels_per_device,
                              max_devices_per_label)
    rng = np.random.default_rng(seed)
    owners = {c: [m for m, labs in enumerate(assign) if c in labs]
              for c in range(num_classes)}
    shards = [[] for _ in range(num_devices)]
    for c, devs in owners.items():
        idx = np.where(y == c)[0]
        rng.shuffle(idx)
        for j, m in enumerate(devs):
            shards[m].append(idx[j::len(devs)])
    out = []
    for m in range(num_devices):
        idx = np.concatenate(shards[m]) if shards[m] else np.array([], int)
        rng.shuffle(idx)
        out.append((x[idx], y[idx]))
    return out


def stack_shards(shards):
    """Stack equal-size shards into arrays with leading device axis [N, ...].

    Truncates to the minimum shard size so the result is rectangular
    (vmap-able across devices).
    """
    n_min = min(len(s[1]) for s in shards)
    xs = np.stack([s[0][:n_min] for s in shards])
    ys = np.stack([s[1][:n_min] for s in shards])
    return xs, ys
