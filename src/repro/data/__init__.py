"""repro.data"""
