"""Logical-axis sharding constraints + the grid shard_map primitive.

Model code annotates activations with *logical* axes (e.g. ("batch", None,
None)); the launcher binds a mesh + rules, and `constrain` lowers to
with_sharding_constraint.  Outside a bound mesh (CPU smoke tests) it is a
no-op, so the same model code serves both paths.

``shard_vmap`` is the embarrassingly-parallel counterpart: it shards a
flattened grid of independent cells (fleet [K x S] cells, SCA scenario
batches) over the mesh with per-device vmap and no collectives — the
substrate of the fleet placement layer (fl.placement, DESIGN.md
§Placement).
"""
from __future__ import annotations

import contextlib
import os
import threading
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()

DEFAULT_RULES = {
    "batch": ("data",),
    "batch_pod": ("pod", "data"),
    "seq": None,
    "kv_seq": None,         # overridden to ("data",) for long-context decode
    "heads": ("model",),
    "ff": ("model",),
    "embed": None,
    "vocab": ("model",),
    "expert": None,
}


def bind(mesh: Mesh, rules: Optional[dict] = None):
    _state.mesh = mesh
    _state.rules = dict(DEFAULT_RULES)
    if rules:
        _state.rules.update(rules)


def unbind():
    _state.mesh = None
    _state.rules = None


@contextlib.contextmanager
def mesh_rules(mesh: Mesh, rules: Optional[dict] = None):
    prev = (getattr(_state, "mesh", None), getattr(_state, "rules", None))
    bind(mesh, rules)
    try:
        yield
    finally:
        _state.mesh, _state.rules = prev


def active_mesh() -> Optional[Mesh]:
    return getattr(_state, "mesh", None)


def logical_to_spec(logical) -> P:
    rules = getattr(_state, "rules", None) or DEFAULT_RULES
    mesh = active_mesh()
    axes = []
    for ax in logical:
        mapped = rules.get(ax) if isinstance(ax, str) else ax
        if mapped is None:
            axes.append(None)
            continue
        if isinstance(mapped, str):
            mapped = (mapped,)
        present = tuple(a for a in mapped if mesh is None
                        or a in mesh.axis_names)
        axes.append(present if present else None)
    return P(*axes)


def constrain(x, logical):
    """Apply a sharding constraint by logical axis names; no-op w/o a mesh."""
    mesh = active_mesh()
    if mesh is None:
        return x
    spec = logical_to_spec(logical)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def batch_logical():
    """'batch' or 'batch_pod' depending on the bound mesh."""
    mesh = active_mesh()
    if mesh is not None and "pod" in mesh.axis_names:
        return "batch_pod"
    return "batch"


def shard_map(f, mesh: Mesh, in_specs, out_specs):
    """jax.shard_map across jax versions (unchecked-replication mode).

    Newer jax exposes ``jax.shard_map(..., check_vma=False)``; older releases
    only have ``jax.experimental.shard_map.shard_map(..., check_rep=False)``.
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as _shmap
    return _shmap(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_rep=False)


def grid_devices(mesh: Mesh, axes=("data", "model")) -> int:
    """Number of devices a flattened grid axis shards over: the product of
    the named mesh axis sizes."""
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return int(n)


def shard_vmap(fn, mesh: Mesh, axes=("data", "model"), num_sharded: int = 1):
    """Map ``fn`` over a leading grid axis, sharded jointly over mesh axes.

    The workhorse of the fleet placement layer (fl.placement, DESIGN.md
    §Placement): ``fn(cell_args..., bcast_args...) -> cell_out`` is a
    per-cell program with NO collectives (cells are independent; the
    shard_map is psum-free).  The returned callable takes the same
    arguments where the first ``num_sharded`` carry a leading grid axis
    [G, ...] on every array leaf and the rest are broadcast (replicated) to
    all devices.  The grid axis is sharded over the *flattened* ``axes`` of
    ``mesh`` — each device vmaps ``fn`` over its local block of cells.

    Padding/masking rule: when G doesn't divide the device count P, the
    grid is right-padded with copies of cell 0 up to the next multiple of P
    (valid inputs, so the padded cells compute real — discarded — work and
    can never poison anything with NaNs), and the padded rows are sliced
    off the outputs.  Outputs come back with the same sharded [G] leading
    axis.
    """
    spec, repl = P(tuple(axes)), P()
    n_dev = grid_devices(mesh, axes)

    def call(*args):
        sharded, bcast = args[:num_sharded], args[num_sharded:]
        g = jax.tree.leaves(sharded[0])[0].shape[0]
        gp = -(-g // n_dev) * n_dev

        def pad(tree):
            return jax.tree.map(
                lambda a: jnp.concatenate(
                    [a, jnp.broadcast_to(a[:1], (gp - g,) + a.shape[1:])],
                    axis=0), tree)

        def local(*a):
            s_l, b_l = a[:num_sharded], a[num_sharded:]
            return jax.vmap(fn, in_axes=(0,) * num_sharded
                            + (None,) * len(b_l))(*s_l, *b_l)

        sm = shard_map(local, mesh,
                       in_specs=(spec,) * num_sharded + (repl,) * len(bcast),
                       out_specs=spec)
        out = sm(*(sharded if gp == g else tuple(map(pad, sharded))), *bcast)
        if gp != g:
            out = jax.tree.map(lambda a: a[:g], out)
        return out

    return call


# ---------------------------------------------------------------------------
# Multi-process bring-up (DESIGN.md §Grid).
#
# ``jax.distributed.initialize`` wires P processes to one coordinator:
# after it, every process sees the GLOBAL device set and shares the
# coordination service's key-value store.  On the CPU backend, however,
# one XLA computation cannot span processes (XLA raises "Multiprocess
# computations aren't implemented on the CPU backend"), so the bring-up
# rule for grids is PROCESS-SLICED execution: each process runs a
# contiguous slice of the flattened cell axis on a mesh of its LOCAL
# devices, and cross-process agreement is verified by exchanging result
# digests through ``kv_put``/``kv_get`` (benchmarks/grid_smoke.py is the
# 2-process forced-CPU proof).  On accelerator backends the same
# initialize call is the prerequisite for true global-array meshes.
# ---------------------------------------------------------------------------


def initialize_multiprocess(coordinator_address: str, num_processes: int,
                            process_id: int,
                            local_device_count: Optional[int] = None):
    """Join this process to a ``jax.distributed`` cluster.

    Must run before any jax computation touches the backend.
    ``local_device_count`` forces N host-platform (CPU) devices per
    process via XLA_FLAGS — the CI smoke path; leave None on real
    accelerators.  Returns (process_count, local_device_count) as jax
    sees them after initialization.
    """
    if local_device_count:
        flags = os.environ.get("XLA_FLAGS", "")
        forced = f"--xla_force_host_platform_device_count={local_device_count}"
        if forced not in flags:
            os.environ["XLA_FLAGS"] = f"{flags} {forced}".strip()
    jax.distributed.initialize(coordinator_address=coordinator_address,
                               num_processes=num_processes,
                               process_id=process_id)
    return jax.process_count(), jax.local_device_count()


def process_grid_slice(g: int, process_id: Optional[int] = None,
                       num_processes: Optional[int] = None) -> slice:
    """This process's contiguous slice of a flattened grid axis of size
    ``g``: rows [i*ceil(g/P), min((i+1)*ceil(g/P), g)).  Process-major and
    deterministic, so P processes partition the axis exactly; defaults
    come from the initialized jax.distributed runtime."""
    p = jax.process_count() if num_processes is None else int(num_processes)
    i = jax.process_index() if process_id is None else int(process_id)
    if not 0 <= i < p:
        raise ValueError(f"process {i} outside [0, {p})")
    per = -(-g // p)
    return slice(min(i * per, g), min((i + 1) * per, g))


def _coordination_client():
    from jax._src import distributed as _dist  # no public KV API yet
    client = getattr(_dist.global_state, "client", None)
    if client is None:
        raise RuntimeError("jax.distributed is not initialized; call "
                           "initialize_multiprocess first")
    return client


def kv_put(key: str, value: str) -> None:
    """Publish a string under ``key`` in the coordination service's
    key-value store (visible to every process in the cluster)."""
    _coordination_client().key_value_set(key, value)


def kv_get(key: str, timeout_s: float = 60.0) -> str:
    """Block until some process publishes ``key``; returns its value."""
    value = _coordination_client().blocking_key_value_get(
        key, int(timeout_s * 1000))
    return value.decode() if isinstance(value, bytes) else value
