"""Logical-axis sharding constraints.

Model code annotates activations with *logical* axes (e.g. ("batch", None,
None)); the launcher binds a mesh + rules, and `constrain` lowers to
with_sharding_constraint.  Outside a bound mesh (CPU smoke tests) it is a
no-op, so the same model code serves both paths.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()

DEFAULT_RULES = {
    "batch": ("data",),
    "batch_pod": ("pod", "data"),
    "seq": None,
    "kv_seq": None,         # overridden to ("data",) for long-context decode
    "heads": ("model",),
    "ff": ("model",),
    "embed": None,
    "vocab": ("model",),
    "expert": None,
}


def bind(mesh: Mesh, rules: Optional[dict] = None):
    _state.mesh = mesh
    _state.rules = dict(DEFAULT_RULES)
    if rules:
        _state.rules.update(rules)


def unbind():
    _state.mesh = None
    _state.rules = None


@contextlib.contextmanager
def mesh_rules(mesh: Mesh, rules: Optional[dict] = None):
    prev = (getattr(_state, "mesh", None), getattr(_state, "rules", None))
    bind(mesh, rules)
    try:
        yield
    finally:
        _state.mesh, _state.rules = prev


def active_mesh() -> Optional[Mesh]:
    return getattr(_state, "mesh", None)


def logical_to_spec(logical) -> P:
    rules = getattr(_state, "rules", None) or DEFAULT_RULES
    mesh = active_mesh()
    axes = []
    for ax in logical:
        mapped = rules.get(ax) if isinstance(ax, str) else ax
        if mapped is None:
            axes.append(None)
            continue
        if isinstance(mapped, str):
            mapped = (mapped,)
        present = tuple(a for a in mapped if mesh is None
                        or a in mesh.axis_names)
        axes.append(present if present else None)
    return P(*axes)


def constrain(x, logical):
    """Apply a sharding constraint by logical axis names; no-op w/o a mesh."""
    mesh = active_mesh()
    if mesh is None:
        return x
    spec = logical_to_spec(logical)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def batch_logical():
    """'batch' or 'batch_pod' depending on the bound mesh."""
    mesh = active_mesh()
    if mesh is not None and "pod" in mesh.axis_names:
        return "batch_pod"
    return "batch"


def shard_map(f, mesh: Mesh, in_specs, out_specs):
    """jax.shard_map across jax versions (unchecked-replication mode).

    Newer jax exposes ``jax.shard_map(..., check_vma=False)``; older releases
    only have ``jax.experimental.shard_map.shard_map(..., check_rep=False)``.
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as _shmap
    return _shmap(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_rep=False)
