"""Logical-axis sharding constraints + the grid shard_map primitive.

Model code annotates activations with *logical* axes (e.g. ("batch", None,
None)); the launcher binds a mesh + rules, and `constrain` lowers to
with_sharding_constraint.  Outside a bound mesh (CPU smoke tests) it is a
no-op, so the same model code serves both paths.

``shard_vmap`` is the embarrassingly-parallel counterpart: it shards a
flattened grid of independent cells (fleet [K x S] cells, SCA scenario
batches) over the mesh with per-device vmap and no collectives — the
substrate of the fleet placement layer (fl.placement, DESIGN.md
§Placement).
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()

DEFAULT_RULES = {
    "batch": ("data",),
    "batch_pod": ("pod", "data"),
    "seq": None,
    "kv_seq": None,         # overridden to ("data",) for long-context decode
    "heads": ("model",),
    "ff": ("model",),
    "embed": None,
    "vocab": ("model",),
    "expert": None,
}


def bind(mesh: Mesh, rules: Optional[dict] = None):
    _state.mesh = mesh
    _state.rules = dict(DEFAULT_RULES)
    if rules:
        _state.rules.update(rules)


def unbind():
    _state.mesh = None
    _state.rules = None


@contextlib.contextmanager
def mesh_rules(mesh: Mesh, rules: Optional[dict] = None):
    prev = (getattr(_state, "mesh", None), getattr(_state, "rules", None))
    bind(mesh, rules)
    try:
        yield
    finally:
        _state.mesh, _state.rules = prev


def active_mesh() -> Optional[Mesh]:
    return getattr(_state, "mesh", None)


def logical_to_spec(logical) -> P:
    rules = getattr(_state, "rules", None) or DEFAULT_RULES
    mesh = active_mesh()
    axes = []
    for ax in logical:
        mapped = rules.get(ax) if isinstance(ax, str) else ax
        if mapped is None:
            axes.append(None)
            continue
        if isinstance(mapped, str):
            mapped = (mapped,)
        present = tuple(a for a in mapped if mesh is None
                        or a in mesh.axis_names)
        axes.append(present if present else None)
    return P(*axes)


def constrain(x, logical):
    """Apply a sharding constraint by logical axis names; no-op w/o a mesh."""
    mesh = active_mesh()
    if mesh is None:
        return x
    spec = logical_to_spec(logical)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def batch_logical():
    """'batch' or 'batch_pod' depending on the bound mesh."""
    mesh = active_mesh()
    if mesh is not None and "pod" in mesh.axis_names:
        return "batch_pod"
    return "batch"


def shard_map(f, mesh: Mesh, in_specs, out_specs):
    """jax.shard_map across jax versions (unchecked-replication mode).

    Newer jax exposes ``jax.shard_map(..., check_vma=False)``; older releases
    only have ``jax.experimental.shard_map.shard_map(..., check_rep=False)``.
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as _shmap
    return _shmap(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_rep=False)


def grid_devices(mesh: Mesh, axes=("data", "model")) -> int:
    """Number of devices a flattened grid axis shards over: the product of
    the named mesh axis sizes."""
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return int(n)


def shard_vmap(fn, mesh: Mesh, axes=("data", "model"), num_sharded: int = 1):
    """Map ``fn`` over a leading grid axis, sharded jointly over mesh axes.

    The workhorse of the fleet placement layer (fl.placement, DESIGN.md
    §Placement): ``fn(cell_args..., bcast_args...) -> cell_out`` is a
    per-cell program with NO collectives (cells are independent; the
    shard_map is psum-free).  The returned callable takes the same
    arguments where the first ``num_sharded`` carry a leading grid axis
    [G, ...] on every array leaf and the rest are broadcast (replicated) to
    all devices.  The grid axis is sharded over the *flattened* ``axes`` of
    ``mesh`` — each device vmaps ``fn`` over its local block of cells.

    Padding/masking rule: when G doesn't divide the device count P, the
    grid is right-padded with copies of cell 0 up to the next multiple of P
    (valid inputs, so the padded cells compute real — discarded — work and
    can never poison anything with NaNs), and the padded rows are sliced
    off the outputs.  Outputs come back with the same sharded [G] leading
    axis.
    """
    spec, repl = P(tuple(axes)), P()
    n_dev = grid_devices(mesh, axes)

    def call(*args):
        sharded, bcast = args[:num_sharded], args[num_sharded:]
        g = jax.tree.leaves(sharded[0])[0].shape[0]
        gp = -(-g // n_dev) * n_dev

        def pad(tree):
            return jax.tree.map(
                lambda a: jnp.concatenate(
                    [a, jnp.broadcast_to(a[:1], (gp - g,) + a.shape[1:])],
                    axis=0), tree)

        def local(*a):
            s_l, b_l = a[:num_sharded], a[num_sharded:]
            return jax.vmap(fn, in_axes=(0,) * num_sharded
                            + (None,) * len(b_l))(*s_l, *b_l)

        sm = shard_map(local, mesh,
                       in_specs=(spec,) * num_sharded + (repl,) * len(bcast),
                       out_specs=spec)
        out = sm(*(sharded if gp == g else tuple(map(pad, sharded))), *bcast)
        if gp != g:
            out = jax.tree.map(lambda a: a[:g], out)
        return out

    return call
