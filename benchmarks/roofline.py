"""Roofline analysis (deliverable g): three terms per (arch x mesh) from the
dry-run artifacts, dominant bottleneck, MODEL_FLOPS ratio.

    compute    = HLO_FLOPs_per_device / peak_FLOP/s          (197 TF bf16)
    memory     = HLO_bytes_per_device / HBM_bw               (819 GB/s)
    collective = collective_bytes_per_device / link_bw       (~50 GB/s)

HLO quantities are the loop-corrected per-device values (launch/cost.py).
Caveats recorded in EXPERIMENTS.md: 'bytes accessed' is an upper bound on
HBM traffic (XLA counts every operand access; VMEM reuse is not modeled),
and collective bytes assume a single ICI link per hop.
"""
from __future__ import annotations

import glob
import json
import os

from repro import configs
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16
from repro.models.config import ModelConfig

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                            "dryrun")


# ---------------------------------------------------------------------------
# MODEL_FLOPS: 6 N D (dense train) / 6 N_active D (MoE train) / 2 N D decode
# ---------------------------------------------------------------------------

def active_params(cfg: ModelConfig) -> int:
    """Parameters touched per token (embedding lookup excluded, unembed
    matmul included — it executes as a matmul)."""
    d, dh = cfg.d_model, cfg.resolved_head_dim
    total = 0
    kinds = cfg.block_kinds()
    for i, kind in enumerate(kinds):
        if kind in ("attn", "swa", "local", "enc_attn"):
            if cfg.attn_kind == "mla":
                qk = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
                q = (cfg.q_lora_rank * (d + cfg.n_heads * qk)
                     if cfg.q_lora_rank else d * cfg.n_heads * qk)
                kv = d * (cfg.kv_lora_rank + cfg.qk_rope_head_dim) \
                    + cfg.kv_lora_rank * cfg.n_heads * (
                        cfg.qk_nope_head_dim + cfg.v_head_dim)
                o = cfg.n_heads * cfg.v_head_dim * d
                total += q + kv + o
            else:
                total += d * dh * (cfg.n_heads + 2 * cfg.n_kv_heads) \
                    + cfg.n_heads * dh * d
        elif kind == "ssd":
            d_inner = cfg.ssm_expand * d
            nheads = d_inner // cfg.ssm_headdim
            gn = cfg.ssm_ngroups * cfg.ssm_state
            total += d * (2 * d_inner + 2 * gn + nheads) + d_inner * d
        elif kind == "rglru":
            w = cfg.lru_width or d
            total += 2 * d * w + 2 * w * w + w * d
        # ffn
        if kind == "ssd" and cfg.ffn_kind == "none":
            continue
        n_mats = 3 if cfg.ffn_kind in ("swiglu", "geglu") else 2
        if cfg.layer_is_moe(i):
            active_e = cfg.moe_top_k + cfg.moe_shared_experts
            total += cfg.moe_num_experts * d \
                + active_e * n_mats * d * cfg.expert_d_ff
        else:
            total += n_mats * d * cfg.d_ff
    if cfg.is_enc_dec:
        # encoder layers + decoder cross-attention
        enc = cfg.encoder_layers * (d * dh * (cfg.n_heads + 2 * cfg.n_kv_heads)
                                    + cfg.n_heads * dh * d
                                    + 2 * d * cfg.d_ff)
        cross = cfg.n_layers * (d * dh * (cfg.n_heads + 2 * cfg.n_kv_heads)
                                + cfg.n_heads * dh * d)
        total += enc + cross
    total += d * cfg.padded_vocab           # unembed matmul
    return total


def model_flops(arch: str, shape_name: str) -> float:
    shape = configs.get_shape(shape_name)
    cfg = (configs.long_context_config(arch) if shape_name == "long_500k"
           else configs.get_config(arch))
    n_act = active_params(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_act * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_act * tokens
    return 2.0 * n_act * shape.global_batch        # decode: 1 token/request


def roofline_row(record: dict) -> dict:
    n_dev = record["devices"]
    flops = record.get("flops_per_device_corrected",
                       record["flops_per_device"])
    byts = record.get("bytes_per_device_corrected",
                      record["bytes_accessed_per_device"])
    coll = record.get("collective_bytes_corrected",
                      record["collective_bytes_per_device"]["total"])
    t_compute = flops / PEAK_FLOPS_BF16
    t_memory = byts / HBM_BW
    t_coll = coll / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory,
             "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(record["arch"], record["shape"])
    mf_dev = mf / n_dev
    return {
        "arch": record["arch"],
        "shape": record["shape"],
        "devices": n_dev,
        "compute_s": t_compute,
        "memory_s": t_memory,
        "collective_s": t_coll,
        "dominant": dominant,
        "model_flops_per_device": mf_dev,
        "useful_flops_ratio": mf_dev / flops if flops > 0 else 0.0,
        "hbm_gb_per_device": record["memory_analysis"].get(
            "argument_bytes", 0) / 1e9,
    }


# ---------------------------------------------------------------------------
# Fused OTA round-step kernel (DESIGN.md §Kernels): analytic roofline of one
# ota_round_step launch per uplink dtype, vs the unfused four-op chain.
# ---------------------------------------------------------------------------

_UPLINK_WIRE_BYTES = {"f32": 4, "bf16": 2, "int8": 1}


def ota_round_step_roofline(n: int = 10, d: int = 814_090) -> list:
    """Compute/memory terms of the fused round-step kernel at [N, D].

    Traffic of one fused launch: the [N, D] uplink at wire precision in,
    z + params in and params out at f32 — the unfused chain adds a ghat
    f32 write + read between the aggregate and step launches.  FLOPs:
    dequantize + precode-weight + accumulate over N (~3ND) plus the
    noise-add and SGD step (~4D).  At the paper's scale the arithmetic
    intensity is ~0.7–1.5 FLOPs/byte — far below the compute/memory
    ridge — so the kernel is memory-bound for every wire dtype and the
    fusion's saved ghat round-trip — and a narrower uplink — convert
    directly into wall time.
    """
    rows = []
    for ud, wire in _UPLINK_WIRE_BYTES.items():
        fused_bytes = n * d * wire + 3 * d * 4
        unfused_bytes = fused_bytes + 2 * d * 4
        flops = 3.0 * n * d + 4.0 * d
        t_compute = flops / PEAK_FLOPS_BF16
        t_memory = fused_bytes / HBM_BW
        rows.append({
            "kernel": "ota_round_step", "uplink_dtype": ud,
            "n": n, "d": d,
            "compute_s": t_compute,
            "memory_s": t_memory,
            "unfused_memory_s": unfused_bytes / HBM_BW,
            "dominant": "compute" if t_compute > t_memory else "memory",
            "flops_per_byte": flops / fused_bytes,
            "fused_bytes_mb": fused_bytes / 1e6,
            "unfused_bytes_mb": unfused_bytes / 1e6,
        })
    return rows


def load_records(pattern: str = "*_pod.json") -> list:
    rows = []
    for path in sorted(glob.glob(os.path.join(ARTIFACT_DIR, pattern))):
        with open(path) as f:
            rows.append(json.load(f))
    return rows


def run() -> list:
    return [roofline_row(r) for r in load_records()]


if __name__ == "__main__":
    for row in run():
        print(row)
    for row in ota_round_step_roofline():
        print(row)
