"""Perf-debug tool: compile one scan-unit body and list every collective op
with its shape/bytes, sorted — the 'profile' for the §Perf hillclimb.

    XLA_FLAGS=--xla_force_host_platform_device_count=512 PYTHONPATH=src \
        python -m benchmarks.collective_detail --arch mixtral-8x22b \
        --shape train_4k
"""
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

# ruff: noqa: E402
import argparse
import re

import jax
import jax.numpy as jnp

from repro import configs, distributed as dist
from repro.launch import cost as cost_lib
from repro.launch import mesh as mesh_lib
from repro.launch import steps as steps_lib
from repro.launch.hlo import shape_bytes
from repro.models import transformer as tfm
from repro.models.param import abstract_params, param_specs

_COLL_LINE = re.compile(
    r"%\S+ = ([^=]*?)(all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)[^\n]*")


def list_collectives(hlo: str, top: int = 25):
    rows = []
    for m in _COLL_LINE.finditer(hlo):
        rows.append((shape_bytes(m.group(1)), m.group(2),
                     m.group(0)[:160]))
    rows.sort(reverse=True)
    agg = {}
    for b, op, _ in rows:
        agg[op] = agg.get(op, 0) + b
    return rows[:top], agg


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--kind", default=None, choices=[None, "fwd", "grad"])
    args = ap.parse_args()

    mesh = mesh_lib.make_production_mesh()
    shape = configs.get_shape(args.shape)
    cfg = (configs.long_context_config(args.arch)
           if args.shape == "long_500k" else configs.get_config(args.arch))
    tp, dp = mesh.shape["model"], mesh.shape["data"]
    lead, unit, n_rep, tail = tfm.layer_plan(cfg)
    unit_defs = {f"u{i}": tfm.layer_def(cfg, s, tp, dp)
                 for i, s in enumerate(unit)}
    gb, s = shape.global_batch, shape.seq_len
    with dist.mesh_rules(mesh):
        bspec = steps_lib.named(mesh, steps_lib.batch_spec(mesh, gb, 2))
        x_abs = jax.ShapeDtypeStruct((gb, s, cfg.d_model), cfg.compute_dtype)

        def unit_fwd(p, x):
            for i, sig in enumerate(unit):
                x, _, _ = tfm.apply_layer(p[f"u{i}"], x, cfg, sig)
            return x

        def unit_grad(p, x):
            return jax.grad(lambda p_, x_: jnp.sum(
                unit_fwd(p_, x_).astype(jnp.float32)),
                argnums=(0, 1))(p, x)

        fn = unit_fwd if args.kind == "fwd" else unit_grad
        p_abs = abstract_params(unit_defs)
        p_sh = jax.tree.map(lambda sp: steps_lib.named(mesh, sp),
                            param_specs(unit_defs))
        with cost_lib._direct_attention():
            compiled = jax.jit(fn, in_shardings=(p_sh, bspec)).lower(
                p_abs, x_abs).compile()
    rows, agg = list_collectives(compiled.as_text())
    print("== aggregate bytes by op (per device, one layer unit) ==")
    for op, b in sorted(agg.items(), key=lambda kv: -kv[1]):
        print(f"  {op:22s} {b / 1e9:8.3f} GB")
    print("== top collectives ==")
    for b, op, line in rows:
        print(f"  {b / 1e6:10.1f} MB  {line}")


if __name__ == "__main__":
    main()
