"""Paper Fig. 2 reproduction: test accuracy (2a) and global loss (2b) vs
FL rounds for all seven schemes on the non-iid MNIST-like task.

    PYTHONPATH=src python -m benchmarks.fig2 [--bench] [--sharded] [--rounds N]

All seven schemes run as ONE compiled scan program (fl.engine.run_fleet,
DESIGN.md §Engine): the schemes are stacked into a SchemeBatch pytree and
the round loop is a chunked lax.scan vmapped over the scheme axis.  On the
default full-batch path the fleet reproduces the pre-engine per-scheme host
loop (kept as ``engine="legacy"``) to float rounding, with identical
key/fading/noise streams.

``--bench`` records the engine-vs-legacy wall-clock comparison for the full
7-scheme x ``--rounds`` grid into experiments/fig2/engine_benchmark.json:
the legacy host loop (one jitted call per round per scheme, full batch) vs
the scan fleet in full-batch equivalence mode vs the scan fleet in
minibatch throughput mode (on-device sampling + flattened Pallas
aggregation) — the configuration the per-PR sweeps use.

Claims validated (paper §IV):
  * Ideal FedAvg best everywhere.
  * OPC (global CSI) fastest practical; the proposed SCA design (statistical
    CSI only) closely tracks it.
  * SCA beats Vanilla OTA-FL and LCPC.
  * BB-FL Alternative > BB-FL Interior (interior misses labels).
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.paper_mlp import CONFIG as PAPER
from repro.core import channel, power_control as pcm
from repro.core.theory import OTAParams
from repro.data import partition, synthetic
from repro.fl.driver import run_fleet
from repro.fl.server import FLRunConfig, run_fl_legacy
from repro.models import mlp
from repro.models.param import init_params

SCHEMES = ["ideal", "opc", "sca", "lcpc", "vanilla", "bbfl_interior",
           "bbfl_alternative"]
# constant step sizes per scheme (grid-searched once, as in the paper)
ETAS = {"ideal": 0.08, "opc": 0.06, "sca": 0.06, "lcpc": 0.05,
        "vanilla": 0.05, "bbfl_interior": 0.06, "bbfl_alternative": 0.06}
# minibatch size of the engine's throughput mode (--bench; per-PR sweeps)
BENCH_BATCH = 128

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                            "fig2")


def build_world(seed: int = 0, noise: float = 0.75,
                samples_per_class: int = 1000):
    wcfg = PAPER.wireless()
    dep = channel.deploy(wcfg)
    x, y, xt, yt = synthetic.mnist_like(samples_per_class, noise=noise,
                                        seed=seed)
    shards = partition.partition_by_label(x, y, PAPER.num_devices,
                                          PAPER.labels_per_device,
                                          PAPER.max_devices_per_label,
                                          seed=seed)
    xd, yd = partition.stack_shards(shards)
    prm = OTAParams(d=mlp.PARAM_DIM, gmax=PAPER.gmax,
                    es=wcfg.energy_per_sample, n0=wcfg.noise_psd,
                    gains=dep.gains,
                    sigma_sq=np.zeros(PAPER.num_devices),
                    eta=0.05, lsmooth=1.0, kappa_sq=4.0)
    return dep, prm, (xd, yd), (x, y), (xt, yt)


def _make_eval(x, y, xt, yt):
    xt_j, yt_j = jnp.asarray(xt), jnp.asarray(yt)
    xg, yg = jnp.asarray(x[:4000]), jnp.asarray(y[:4000])

    def evals(params):
        return {"acc": mlp.accuracy(params, xt_j, yt_j),
                "global_loss": mlp.mlp_loss(params, (xg, yg))}
    return evals


def _fleet_histories(res, wall_total: float):
    """FLResult (seed axis S=1) -> legacy-shaped {scheme: history list}."""
    histories = {}
    for i, name in enumerate(res.names):
        hist = []
        for t, ev in res.evals:
            hist.append({
                "acc": float(ev["acc"][i, 0]),
                "global_loss": float(ev["global_loss"][i, 0]),
                "round": t, "scheme": name,
                "active": float(res.traces["active_devices"][i, 0, t]),
                "wall": wall_total,
            })
        histories[name] = hist
    return histories


def run(num_rounds: int = 150, eval_every: int = 10, seed: int = 0,
        schemes=SCHEMES, log=False, engine: str = "fleet",
        batch_size: int = 0, save: bool = True, placement=None,
        with_result: bool = False):
    """Fig. 2 histories for all schemes.

    engine="fleet": one compiled scan program for the whole scheme grid,
    through the placement-aware host driver (fl.driver, DESIGN.md
    §Placement); ``placement`` routes the grid onto hardware (None = the
    single-device vmap path, ShardedPlacement(mesh) to shard the scheme
    cells over a mesh).
    engine="legacy": the pre-engine host loop, one scheme at a time (the
    wall-clock baseline; bit-reproduces the committed pre-engine curves).
    batch_size=0 is the paper's full-batch §IV protocol — on it the fleet
    matches the legacy loop's trajectories (same seeds) to float rounding.
    batch_size>0 switches the fleet to on-device minibatch sampling and the
    flattened Pallas aggregation (the cheap per-PR sweep mode).
    with_result=True also returns the driver's FLResult (the honest
    wall_compile/wall_exec split for --bench).
    """
    dep, prm, data, (x, y), (xt, yt) = build_world(seed)
    params0 = init_params(mlp.mlp_defs(), jax.random.PRNGKey(seed))
    evals = jax.jit(_make_eval(x, y, xt, yt))

    res = None
    if engine == "fleet":
        run_cfg = FLRunConfig(num_rounds=num_rounds, eval_every=eval_every,
                              gmax=PAPER.gmax, seed=seed,
                              batch_size=batch_size)
        pcs = [pcm.make_power_control(n, dep, prm.replace(
            eta=ETAS.get(n, 0.05))) for n in schemes]
        res = run_fleet(mlp.mlp_loss, params0, pcs, dep.gains, data,
                        run_cfg, evals,
                        etas=[ETAS.get(n, 0.05) for n in schemes],
                        flat=batch_size > 0, log=log, placement=placement)
        histories = _fleet_histories(res, res.wall)
    elif engine == "legacy":
        histories = {}
        for name in schemes:
            pc = pcm.make_power_control(name, dep,
                                        prm.replace(eta=ETAS.get(name, 0.05)))
            run_cfg = FLRunConfig(eta=ETAS.get(name, 0.05),
                                  num_rounds=num_rounds,
                                  eval_every=eval_every, gmax=PAPER.gmax,
                                  seed=seed, batch_size=batch_size)
            t0 = time.time()
            _, hist = run_fl_legacy(mlp.mlp_loss, params0, pc, dep.gains,
                                    data, run_cfg, evals, log=log)
            histories[name] = hist
            if log:
                print(f"  {name}: {time.time() - t0:.1f}s")
    else:
        raise ValueError(f"unknown engine {engine!r}")

    if save:
        os.makedirs(ARTIFACT_DIR, exist_ok=True)
        with open(os.path.join(ARTIFACT_DIR, f"histories_seed{seed}.json"),
                  "w") as f:
            json.dump(histories, f, indent=1)
    if with_result:
        return histories, res
    return histories


def rounds_to_accuracy(hist, target: float):
    for h in hist:
        if h["acc"] >= target:
            return h["round"]
    return None


def summarize(histories) -> list:
    rows = []
    for name, hist in histories.items():
        final = hist[-1]
        rows.append({
            "scheme": name,
            "final_acc": round(final["acc"], 4),
            "final_loss": round(final["global_loss"], 4),
            "rounds_to_80": rounds_to_accuracy(hist, 0.80),
            "csi": ("global" if name in ("opc", "vanilla", "bbfl_interior",
                                         "bbfl_alternative")
                    else ("none" if name == "ideal" else "statistical")),
        })
    return rows


def _history_deltas(a: dict, b: dict) -> dict:
    """Max |delta| between two scheme->history maps at each eval metric."""
    out = {}
    for metric in ("acc", "global_loss"):
        out[metric] = max(
            abs(ra[metric] - rb[metric])
            for name in a for ra, rb in zip(a[name], b[name]))
    return out


def benchmark(num_rounds: int = 150, eval_every: int = 15, seed: int = 0,
              batch_size: int = BENCH_BATCH, log: bool = True) -> dict:
    """Engine-vs-legacy wall clock for the full scheme grid; writes
    experiments/fig2/engine_benchmark.json.

    Three runs of the 7-scheme x num_rounds grid:
      legacy          pre-engine host loop, full batch (the old fig2 path)
      fleet_fullbatch one scan program, full batch — same arithmetic and
                      streams as legacy, history deltas recorded
      fleet_minibatch one scan program, on-device batch_size sampling +
                      Pallas flattened aggregation — the per-PR sweep mode

    Fleet walls are split into ``compile`` (through the end of the first
    chunk — setup + the dominant XLA compile) and ``exec`` (steady-state),
    straight from FLResult.wall_compile / wall_exec, so the JSON speedups
    are honest about what amortizes over longer sweeps; the legacy loop
    compiles per round and has no meaningful split.
    """
    cfg = dict(num_rounds=num_rounds, eval_every=eval_every, seed=seed,
               save=False)
    t0 = time.time()
    legacy = run(engine="legacy", **cfg)
    wall_legacy = time.time() - t0
    if log:
        print(f"legacy loop (full batch): {wall_legacy:.1f}s")

    fleet_full, res_full = run(engine="fleet", with_result=True, **cfg)
    wall_full = res_full.wall
    if log:
        print(f"scan fleet (full batch):  {wall_full:.1f}s "
              f"(compile {res_full.wall_compile:.1f}s"
              f" + exec {res_full.wall_exec:.1f}s)")

    fleet_mb, res_mb = run(engine="fleet", batch_size=batch_size,
                           with_result=True, **cfg)
    wall_mb = res_mb.wall
    if log:
        print(f"scan fleet (minibatch {batch_size}): {wall_mb:.1f}s "
              f"(compile {res_mb.wall_compile:.1f}s"
              f" + exec {res_mb.wall_exec:.1f}s)")

    deltas = _history_deltas(legacy, fleet_full)
    report = {
        "grid": {"schemes": SCHEMES, "num_rounds": num_rounds,
                 "eval_every": eval_every, "seed": seed,
                 "bench_batch_size": batch_size,
                 "device": jax.devices()[0].device_kind,
                 "backend": jax.default_backend()},
        "wall_s": {"legacy_loop_fullbatch": round(wall_legacy, 2),
                   "fleet_fullbatch": round(wall_full, 2),
                   "fleet_fullbatch_compile": round(res_full.wall_compile, 2),
                   "fleet_fullbatch_exec": round(res_full.wall_exec, 2),
                   "fleet_minibatch": round(wall_mb, 2),
                   "fleet_minibatch_compile": round(res_mb.wall_compile, 2),
                   "fleet_minibatch_exec": round(res_mb.wall_exec, 2)},
        "speedup": {
            # headline: the engine's sweep mode vs the pre-engine fig2 path
            "engine_vs_legacy": round(wall_legacy / wall_mb, 2),
            "fullbatch_engine_vs_legacy": round(wall_legacy / wall_full, 2),
            # compile excluded: what a longer sweep actually amortizes to
            "engine_exec_vs_legacy": round(
                wall_legacy / max(res_mb.wall_exec, 1e-9), 2),
        },
        "equivalence": {
            "note": "fleet_fullbatch vs legacy at identical seeds/streams",
            "max_abs_delta": {k: float(v) for k, v in deltas.items()},
        },
        "final_acc": {
            "legacy": {n: legacy[n][-1]["acc"] for n in legacy},
            "fleet_fullbatch": {n: fleet_full[n][-1]["acc"]
                                for n in fleet_full},
            "fleet_minibatch": {n: fleet_mb[n][-1]["acc"] for n in fleet_mb},
        },
    }
    os.makedirs(ARTIFACT_DIR, exist_ok=True)
    with open(os.path.join(ARTIFACT_DIR, "engine_benchmark.json"), "w") as f:
        json.dump(report, f, indent=1)
    if log:
        print(json.dumps(report["speedup"], indent=1))
    return report


def _sharded_placement():
    """Debug-mesh placement for --sharded (forced-8-CPU-device CI path or
    any real multi-device host)."""
    from repro.fl.placement import ShardedPlacement
    from repro.launch.mesh import make_debug_mesh

    if jax.device_count() < 4:
        raise SystemExit(
            "--sharded needs >= 4 devices; on CPU set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=8")
    return ShardedPlacement(make_debug_mesh(2, 2))


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--bench", action="store_true",
                    help="engine-vs-legacy wall-clock benchmark + JSON")
    ap.add_argument("--legacy", action="store_true",
                    help="run the pre-engine host loop instead of the fleet")
    ap.add_argument("--sharded", action="store_true",
                    help="shard the scheme grid over the ('data', 'model') "
                         "debug mesh (DESIGN.md §Placement)")
    ap.add_argument("--rounds", type=int, default=150)
    ap.add_argument("--every", type=int, default=None,
                    help="eval cadence (default: 10, or 15 under --bench)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--batch-size", type=int, default=0,
                    help="0 = full batch (paper); under --bench, the "
                         f"minibatch mode size (default {BENCH_BATCH})")
    args = ap.parse_args(argv)
    if args.sharded and (args.legacy or args.bench):
        raise SystemExit("--sharded applies to the fleet engine only; "
                         "drop --legacy/--bench")
    if args.bench:
        benchmark(num_rounds=args.rounds, eval_every=args.every or 15,
                  seed=args.seed,
                  batch_size=args.batch_size or BENCH_BATCH)
        return
    hist = run(num_rounds=args.rounds, eval_every=args.every or 10,
               seed=args.seed,
               engine="legacy" if args.legacy else "fleet",
               batch_size=args.batch_size, log=True,
               placement=_sharded_placement() if args.sharded else None)
    for row in summarize(hist):
        print(row)


if __name__ == "__main__":
    main()
