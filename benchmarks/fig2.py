"""Paper Fig. 2 reproduction: test accuracy (2a) and global loss (2b) vs
FL rounds for all seven schemes on the non-iid MNIST-like task.

Claims validated (paper §IV):
  * Ideal FedAvg best everywhere.
  * OPC (global CSI) fastest practical; the proposed SCA design (statistical
    CSI only) closely tracks it.
  * SCA beats Vanilla OTA-FL and LCPC.
  * BB-FL Alternative > BB-FL Interior (interior misses labels).
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.paper_mlp import CONFIG as PAPER
from repro.core import channel, power_control as pcm
from repro.core.theory import OTAParams
from repro.data import partition, synthetic
from repro.fl.server import FLRunConfig, run_fl
from repro.models import mlp
from repro.models.param import init_params

SCHEMES = ["ideal", "opc", "sca", "lcpc", "vanilla", "bbfl_interior",
           "bbfl_alternative"]
# constant step sizes per scheme (grid-searched once, as in the paper)
ETAS = {"ideal": 0.08, "opc": 0.06, "sca": 0.06, "lcpc": 0.05,
        "vanilla": 0.05, "bbfl_interior": 0.06, "bbfl_alternative": 0.06}

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                            "fig2")


def build_world(seed: int = 0, noise: float = 0.75,
                samples_per_class: int = 1000):
    wcfg = PAPER.wireless()
    dep = channel.deploy(wcfg)
    x, y, xt, yt = synthetic.mnist_like(samples_per_class, noise=noise,
                                        seed=seed)
    shards = partition.partition_by_label(x, y, PAPER.num_devices,
                                          PAPER.labels_per_device,
                                          PAPER.max_devices_per_label,
                                          seed=seed)
    xd, yd = partition.stack_shards(shards)
    prm = OTAParams(d=mlp.PARAM_DIM, gmax=PAPER.gmax,
                    es=wcfg.energy_per_sample, n0=wcfg.noise_psd,
                    gains=dep.gains,
                    sigma_sq=np.zeros(PAPER.num_devices),
                    eta=0.05, lsmooth=1.0, kappa_sq=4.0)
    return dep, prm, (xd, yd), (x, y), (xt, yt)


def run(num_rounds: int = 150, eval_every: int = 10, seed: int = 0,
        schemes=SCHEMES, log=False):
    dep, prm, data, (x, y), (xt, yt) = build_world(seed)
    params0 = init_params(mlp.mlp_defs(), jax.random.PRNGKey(seed))
    xt_j, yt_j = jnp.asarray(xt), jnp.asarray(yt)
    xg, yg = jnp.asarray(x[:4000]), jnp.asarray(y[:4000])

    @jax.jit
    def evals(params):
        return {"acc": mlp.accuracy(params, xt_j, yt_j),
                "global_loss": mlp.mlp_loss(params, (xg, yg))}

    histories = {}
    for name in schemes:
        prm_s = prm.replace(eta=ETAS.get(name, 0.05))
        pc = pcm.make_power_control(name, dep, prm_s)
        run_cfg = FLRunConfig(eta=ETAS.get(name, 0.05),
                              num_rounds=num_rounds, eval_every=eval_every,
                              gmax=PAPER.gmax, seed=seed)
        t0 = time.time()
        _, hist = run_fl(mlp.mlp_loss, params0, pc, dep.gains, data,
                         run_cfg, evals, log=log)
        histories[name] = hist
        if log:
            print(f"  {name}: {time.time() - t0:.1f}s")
    os.makedirs(ARTIFACT_DIR, exist_ok=True)
    with open(os.path.join(ARTIFACT_DIR, f"histories_seed{seed}.json"),
              "w") as f:
        json.dump(histories, f, indent=1)
    return histories


def rounds_to_accuracy(hist, target: float):
    for h in hist:
        if h["acc"] >= target:
            return h["round"]
    return None


def summarize(histories) -> list:
    rows = []
    for name, hist in histories.items():
        final = hist[-1]
        rows.append({
            "scheme": name,
            "final_acc": round(final["acc"], 4),
            "final_loss": round(final["global_loss"], 4),
            "rounds_to_80": rounds_to_accuracy(hist, 0.80),
            "csi": ("global" if name in ("opc", "vanilla", "bbfl_interior",
                                         "bbfl_alternative")
                    else ("none" if name == "ideal" else "statistical")),
        })
    return rows
