"""Fig.-2-style reproduction for any registered task: test accuracy (2a)
and global loss (2b) vs FL rounds for all seven schemes.

    PYTHONPATH=src python -m benchmarks.fig2 [--task paper_mlp|cifar_conv]
        [--bench] [--bench-placement] [--sharded] [--rounds N]
        [--checkpoint] [--resume]
        [--population P --cohort N [--cohort-rounds R] [--no-stream]]

The workload comes from the task registry (``repro.tasks``, DESIGN.md
§Tasks): ``paper_mlp`` (default) is the paper's §IV experiment and stays
bit-identical to the pre-task hand-wired path; ``cifar_conv`` is the
CIFAR-class Dirichlet-non-iid conv workload, writing its artifacts to
experiments/cifar/.  All seven schemes run as ONE compiled scan program
(``fl.driver.run_fleet_task``); ``--sharded`` shards the scheme grid over
the ("data", "model") debug mesh and ``--checkpoint`` / ``--resume`` turn
on chunk-boundary checkpointing with mid-grid resume.

``--population P`` switches the fleet to the streaming-cohort serving loop
(DESIGN.md §Population): each round runs on a ``--cohort``-sized draw from
a P-device parametric population (traffic-weighted Gumbel-top-k sampling),
redrawn every ``--cohort-rounds`` rounds, with the next cohort's draw /
gain materialization / SCA redesign double-buffered against the executing
chunk (``--no-stream`` serializes the same stages — identical numbers).

``--bench`` records the engine-vs-legacy wall-clock comparison into
<artifacts>/engine_benchmark.json.  ``--bench-placement`` (also implied by
``--bench``) adds the placement-vs-placement comparison — vmap vs sharded
at growing K*S — and refreshes the repo-root ``BENCH_engine.json`` summary
(headline walls + speedups, machine-readable across PRs; shape pinned by
``benchmarks/bench_schema.json`` via ``benchmarks.validate_bench``).
``--bench`` also runs :func:`population_benchmark` — sustained rounds/sec
of the 1M-population / 50-cohort streaming loop, stream vs serial — and
:func:`kernel_benchmark`, the fused-vs-unfused ``ota_round_step`` walls
per uplink dtype (f32/bf16/int8) with the f32 bitwise pin;
``--bench-kernel`` runs ONLY that section (seconds, not the multi-minute
legacy sweep).

Claims validated (paper §IV):
  * Ideal FedAvg best everywhere.
  * OPC (global CSI) fastest practical; the proposed SCA design (statistical
    CSI only) closely tracks it.
  * SCA beats Vanilla OTA-FL and LCPC.
  * BB-FL Alternative > BB-FL Interior (interior misses labels).
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np

from repro import tasks
from repro.core import channel, power_control as pcm, scenarios as scn
from repro.core.theory import OTAParams
from repro.fl.driver import run_fleet_task
from repro.fl.server import run_fl_legacy
from repro.tasks.base import Task

SCHEMES = ["ideal", "opc", "sca", "lcpc", "vanilla", "bbfl_interior",
           "bbfl_alternative"]
# minibatch size of the engine's throughput mode (--bench; per-PR sweeps)
BENCH_BATCH = 128

ROOT = os.path.join(os.path.dirname(__file__), "..")
BENCH_SUMMARY = os.path.join(ROOT, "BENCH_engine.json")


def _task(task) -> Task:
    """Resolve a task name/instance and require the fleet runtime.  Raises
    KeyError/ValueError (catchable from library callers); main() translates
    to SystemExit for the CLI."""
    if isinstance(task, str):
        return tasks.get(task, expect_runtime="fleet")
    if task.runtime != "fleet":
        raise ValueError(f"task {task.name!r} is a {task.runtime!r}-runtime "
                         f"workload; this benchmark needs a fleet task")
    return task


def artifact_dir(task) -> str:
    task = _task(task)
    return os.path.join(ROOT, "experiments", task.artifact_tag or task.name)


def build_world(task="paper_mlp", seed: int = 0, num_devices=None):
    """Wireless deployment + OTA design constants + materialized task data.

    The deployment geometry is seeded independently of the data seed (the
    paper fixes one wireless world across data seeds), matching the
    committed pre-task fig2 world bit-for-bit on ``paper_mlp``.
    ``num_devices`` overrides the task's device count — population runs
    design their schemes for a cohort-sized world, not the shard count.
    """
    task = _task(task)
    wcfg = channel.WirelessConfig(
        num_devices=num_devices or task.num_devices, seed=0)
    dep = channel.deploy(wcfg)
    td = task.build_data(seed)
    prm = OTAParams(d=task.param_dim,
                    gmax=float(task.defaults.get("gmax", 10.0)),
                    es=wcfg.energy_per_sample, n0=wcfg.noise_psd,
                    gains=dep.gains, sigma_sq=np.zeros(wcfg.num_devices),
                    eta=0.05, lsmooth=1.0, kappa_sq=4.0)
    return dep, prm, td


def make_population(size: int, sampling: str = "traffic",
                    seed: int = 0) -> scn.Population:
    """Parametric serving population for --population runs: disk geometry
    with log-normal shadowing, i.i.d. Rayleigh fading (the engine's
    fading=None fast path) and heavy-tailed traffic-weighted cohort draws.
    Lazy — 1M devices cost nothing until a cohort materializes them."""
    spec = scn.PopulationSpec(size=size, shadowing=scn.ShadowingSpec(),
                              sampling=sampling, seed=seed)
    return scn.Population(spec=spec)


def make_schemes(task: Task, dep, prm, names=SCHEMES) -> list:
    """One PowerControl per scheme, each designed at the task's
    grid-searched step size (eta enters the (P1) objective)."""
    return [pcm.make_power_control(
        n, dep, prm.replace(eta=task.eta_for(n, float(prm.eta))))
        for n in names]


def _fleet_histories(res, wall_total: float):
    """FLResult (seed axis S=1) -> legacy-shaped {scheme: history list}."""
    histories = {}
    for i, name in enumerate(res.names):
        hist = []
        for t, ev in res.evals:
            hist.append({
                "acc": float(ev["acc"][i, 0]),
                "global_loss": float(ev["global_loss"][i, 0]),
                "round": t, "scheme": name,
                "active": float(res.traces["active_devices"][i, 0, t]),
                "wall": wall_total,
            })
        histories[name] = hist
    return histories


def run(num_rounds: int = 150, eval_every: int = 10, seed: int = 0,
        schemes=SCHEMES, log=False, engine: str = "fleet",
        batch_size=0, save: bool = True, placement=None,
        with_result: bool = False, task="paper_mlp",
        checkpoint_path=None, resume: bool = False,
        population: int = 0, cohort=None, cohort_rounds=None,
        stream: bool = True, max_chunks=None, telemetry=None):
    """Fig.-2-style histories for all schemes on the given task.

    engine="fleet": one compiled scan program for the whole scheme grid,
    through the task-first host driver (fl.driver.run_fleet_task);
    ``placement`` routes the grid onto hardware (None = single-device
    vmap, ShardedPlacement(mesh) to shard the scheme cells over a mesh),
    ``checkpoint_path``/``resume`` persist and fast-forward the fleet at
    chunk boundaries.
    engine="legacy": the pre-engine host loop, one scheme at a time (the
    wall-clock baseline; bit-reproduces the committed pre-engine curves
    on paper_mlp).
    batch_size=0 is full batch (the paper's §IV protocol — on paper_mlp
    the fleet matches the legacy loop to float rounding); None takes the
    task's preferred batch size; batch_size>0 switches to on-device
    minibatch sampling and the flattened Pallas aggregation.
    population>0 runs the fleet in streaming-cohort mode (``cohort``
    devices per round drawn from a ``make_population(population)`` world,
    schemes designed for the cohort-sized deployment; see module
    docstring); cohort defaults to the task's device count.
    with_result=True also returns the driver's FLResult (the honest
    wall_compile/wall_exec split for --bench).
    telemetry turns on the fleet telemetry subsystem (fleet engine only):
    True writes events.jsonl + bias--variance diagnostics into the task's
    artifact dir with the task's kappa^2 (render with
    ``python -m repro.telemetry.report <artifact_dir>``); a string or a
    ``repro.telemetry.Telemetry`` selects the run dir explicitly.
    """
    task = _task(task)
    if batch_size is None:
        batch_size = int(task.defaults.get("batch_size", 0))
    pop_kw = {}
    if population:
        if engine != "fleet":
            raise ValueError("population mode needs the fleet engine")
        cohort = int(cohort or task.num_devices)
        pop_kw = dict(population=make_population(int(population)),
                      cohort_size=cohort, cohort_rounds=cohort_rounds,
                      stream=stream)
    dep, prm, td = build_world(task, seed, num_devices=cohort)
    params0 = task.init_params(seed)
    evals = task.make_eval(td)

    telemetry = telemetry or None
    if telemetry is not None and engine != "fleet":
        raise ValueError("telemetry needs the fleet engine")
    if telemetry is True:
        from repro.telemetry import Telemetry
        telemetry = Telemetry(run_dir=artifact_dir(task),
                              kappa_sq=float(prm.kappa_sq))

    res = None
    if engine == "fleet":
        run_cfg = task.run_config(num_rounds=num_rounds,
                                  eval_every=eval_every, seed=seed,
                                  batch_size=batch_size)
        pcs = make_schemes(task, dep, prm, schemes)
        res = run_fleet_task(task, pcs, dep.gains, run_cfg, task_data=td,
                             params=params0, eval_fn=evals,
                             flat=batch_size > 0, log=log,
                             placement=placement,
                             checkpoint_path=checkpoint_path, resume=resume,
                             max_chunks=max_chunks, telemetry=telemetry,
                             **pop_kw)
        histories = _fleet_histories(res, res.wall)
    elif engine == "legacy":
        histories = {}
        ev_jit = jax.jit(evals)
        for name in schemes:
            eta = task.eta_for(name, 0.05)
            pc = pcm.make_power_control(name, dep, prm.replace(eta=eta))
            run_cfg = task.run_config(eta=eta, num_rounds=num_rounds,
                                      eval_every=eval_every, seed=seed,
                                      batch_size=batch_size)
            t0 = time.time()
            _, hist = run_fl_legacy(task.loss_fn, params0, pc, dep.gains,
                                    td.train, run_cfg, ev_jit, log=log)
            histories[name] = hist
            if log:
                print(f"  {name}: {time.time() - t0:.1f}s")
    else:
        raise ValueError(f"unknown engine {engine!r}")

    if save:
        out = artifact_dir(task)
        os.makedirs(out, exist_ok=True)
        with open(os.path.join(out, f"histories_seed{seed}.json"),
                  "w") as f:
            json.dump(histories, f, indent=1)
    if with_result:
        return histories, res
    return histories


def rounds_to_accuracy(hist, target: float):
    for h in hist:
        if h["acc"] >= target:
            return h["round"]
    return None


def summarize(histories) -> list:
    rows = []
    for name, hist in histories.items():
        final = hist[-1]
        rows.append({
            "scheme": name,
            "final_acc": round(final["acc"], 4),
            "final_loss": round(final["global_loss"], 4),
            "rounds_to_80": rounds_to_accuracy(hist, 0.80),
            "csi": ("global" if name in ("opc", "vanilla", "bbfl_interior",
                                         "bbfl_alternative")
                    else ("none" if name == "ideal" else "statistical")),
        })
    return rows


def _history_deltas(a: dict, b: dict) -> dict:
    """Max |delta| between two scheme->history maps at each eval metric."""
    out = {}
    for metric in ("acc", "global_loss"):
        out[metric] = max(
            abs(ra[metric] - rb[metric])
            for name in a for ra, rb in zip(a[name], b[name]))
    return out


def benchmark(num_rounds: int = 150, eval_every: int = 15, seed: int = 0,
              batch_size: int = BENCH_BATCH, task="paper_mlp",
              log: bool = True) -> dict:
    """Engine-vs-legacy wall clock for the full scheme grid; writes
    <artifacts>/engine_benchmark.json.

    Three runs of the 7-scheme x num_rounds grid:
      legacy          pre-engine host loop, full batch (the old fig2 path)
      fleet_fullbatch one scan program, full batch — same arithmetic and
                      streams as legacy, history deltas recorded
      fleet_minibatch one scan program, on-device batch_size sampling +
                      Pallas flattened aggregation — the per-PR sweep mode

    All three top-line walls are measured with the SAME outer clock around
    the whole run() call (world build, data generation, eval jit included)
    so the speedup ratios compare like with like; the fleet rows
    additionally carry FLResult's compile/exec split of the engine portion
    — what amortizes over longer sweeps — while the legacy loop compiles
    per round and has no meaningful split.
    """
    task = _task(task)
    cfg = dict(num_rounds=num_rounds, eval_every=eval_every, seed=seed,
               save=False, task=task)
    t0 = time.time()
    legacy = run(engine="legacy", **cfg)
    wall_legacy = time.time() - t0
    if log:
        print(f"legacy loop (full batch): {wall_legacy:.1f}s")

    t0 = time.time()
    fleet_full, res_full = run(engine="fleet", with_result=True, **cfg)
    wall_full = time.time() - t0
    if log:
        print(f"scan fleet (full batch):  {wall_full:.1f}s "
              f"(compile {res_full.wall_compile:.1f}s"
              f" + exec {res_full.wall_exec:.1f}s)")

    t0 = time.time()
    fleet_mb, res_mb = run(engine="fleet", batch_size=batch_size,
                           with_result=True, **cfg)
    wall_mb = time.time() - t0
    if log:
        print(f"scan fleet (minibatch {batch_size}): {wall_mb:.1f}s "
              f"(compile {res_mb.wall_compile:.1f}s"
              f" + exec {res_mb.wall_exec:.1f}s)")

    deltas = _history_deltas(legacy, fleet_full)
    report = {
        "grid": {"task": task.name, "schemes": SCHEMES,
                 "num_rounds": num_rounds,
                 "eval_every": eval_every, "seed": seed,
                 "bench_batch_size": batch_size,
                 "device": jax.devices()[0].device_kind,
                 "backend": jax.default_backend()},
        "wall_s": {"legacy_loop_fullbatch": round(wall_legacy, 2),
                   "fleet_fullbatch": round(wall_full, 2),
                   "fleet_fullbatch_compile": round(res_full.wall_compile, 2),
                   "fleet_fullbatch_exec": round(res_full.wall_exec, 2),
                   "fleet_minibatch": round(wall_mb, 2),
                   "fleet_minibatch_compile": round(res_mb.wall_compile, 2),
                   "fleet_minibatch_exec": round(res_mb.wall_exec, 2)},
        "speedup": {
            # headline: the engine's sweep mode vs the pre-engine fig2 path
            "engine_vs_legacy": round(wall_legacy / wall_mb, 2),
            "fullbatch_engine_vs_legacy": round(wall_legacy / wall_full, 2),
            # compile excluded: what a longer sweep actually amortizes to
            "engine_exec_vs_legacy": round(
                wall_legacy / max(res_mb.wall_exec, 1e-9), 2),
        },
        "equivalence": {
            "note": "fleet_fullbatch vs legacy at identical seeds/streams",
            "max_abs_delta": {k: float(v) for k, v in deltas.items()},
        },
        "final_acc": {
            "legacy": {n: legacy[n][-1]["acc"] for n in legacy},
            "fleet_fullbatch": {n: fleet_full[n][-1]["acc"]
                                for n in fleet_full},
            "fleet_minibatch": {n: fleet_mb[n][-1]["acc"] for n in fleet_mb},
        },
    }
    _merge_benchmark_json(task, report)
    if log:
        print(json.dumps(report["speedup"], indent=1))
    return report


# ---------------------------------------------------------------------------
# Placement-vs-placement wall comparison (ROADMAP: vmap vs sharded at
# growing K*S) + the repo-root BENCH_engine.json summary.
# ---------------------------------------------------------------------------

def _wall_split(res) -> dict:
    return {"wall": round(res.wall, 2),
            "compile": round(res.wall_compile, 2),
            "exec": round(res.wall_exec, 2)}


def placement_benchmark(task="paper_mlp", num_rounds: int = 30,
                        eval_every: int = 15, seed: int = 0,
                        batch_size: int = BENCH_BATCH,
                        seeds_grid=(1, 2, 4), log: bool = True) -> dict:
    """vmap-vs-sharded wall clocks for the 7-scheme grid at growing K*S.

    Each grid point runs the same minibatch+flat fleet once per placement
    (sharded only when >= 4 devices are visible — on CPU force them with
    XLA_FLAGS=--xla_force_host_platform_device_count=8); walls come from
    FLResult's compile/exec split, and the exec-only speedup is the
    number that scales with sweep length.
    """
    task = _task(task)
    dep, prm, td = build_world(task, seed)
    params0 = task.init_params(seed)
    evals = task.make_eval(td)
    pcs = make_schemes(task, dep, prm)
    sharded = None
    if jax.device_count() >= 4:
        sharded = _sharded_placement()

    rows = []
    for s in seeds_grid:
        run_cfg = task.run_config(num_rounds=num_rounds,
                                  eval_every=eval_every, seed=seed,
                                  batch_size=batch_size)
        kw = dict(task_data=td, params=params0, eval_fn=evals,
                  seeds=tuple(range(s)), flat=True)
        res_v = run_fleet_task(task, pcs, dep.gains, run_cfg, **kw)
        row = {"k": len(SCHEMES), "s": s, "cells": len(SCHEMES) * s,
               "vmap": _wall_split(res_v)}
        if sharded is not None:
            res_s = run_fleet_task(task, pcs, dep.gains, run_cfg, **kw,
                                   placement=sharded)
            row["sharded"] = _wall_split(res_s)
            row["sharded_devices"] = sharded.num_devices
            row["exec_speedup_sharded_vs_vmap"] = round(
                res_v.wall_exec / max(res_s.wall_exec, 1e-9), 2)
        else:
            row["sharded"] = "skipped (needs >= 4 devices; set XLA_FLAGS="
            row["sharded"] += "--xla_force_host_platform_device_count=8)"
        if log:
            print(f"cells={row['cells']}: vmap exec "
                  f"{row['vmap']['exec']}s"
                  + (f", sharded exec {row['sharded']['exec']}s "
                     f"({row['exec_speedup_sharded_vs_vmap']}x)"
                     if sharded is not None else " (sharded skipped)"))
        rows.append(row)

    placement = {
        "config": {"task": task.name, "num_rounds": num_rounds,
                   "eval_every": eval_every, "seed": seed,
                   "batch_size": batch_size,
                   "device_count": jax.device_count(),
                   "backend": jax.default_backend()},
        "rows": rows,
    }
    _merge_benchmark_json(task, {"placement": placement})
    write_bench_summary(task)
    return placement


def population_benchmark(task="paper_mlp", size: int = 1_000_000,
                         cohort: int = 50, num_rounds: int = 48,
                         eval_every: int = 16, cohort_rounds: int = 1,
                         seed: int = 0, batch_size: int = BENCH_BATCH,
                         log: bool = True) -> dict:
    """Streaming-cohort serving throughput (DESIGN.md §Population).

    One ``adaptive_sca`` scheme over a ``size``-device traffic-weighted
    population at ``cohort`` devices/round, redrawn + SCA-redesigned on the
    incoming cohort's statistical CSI every ``cohort_rounds`` rounds (the
    default redraws EVERY round — the hardest streaming cadence).  The
    same fleet runs twice — stream=True (staging double-buffered against
    the executing chunk) and stream=False (identical stages, serialized) —
    so the exec-wall gap IS the hidden staging + redesign latency; results
    are checked bitwise-equal across the two modes.  Run with at least two
    visible devices (CI forces host devices via XLA_FLAGS) so the driver's
    staging lane keeps the redesign solve off the chunk's device — on one
    device the solve queues behind the chunk and overlap cannot win.
    Also re-verifies the full-participation contract: a cohort ==
    population run over the task's own deployment is bitwise the
    pre-population engine path.

    Records sustained rounds/sec (stream mode, compile excluded) into
    <artifacts>/engine_benchmark.json under "population" and refreshes
    BENCH_engine.json.
    """
    task = _task(task)
    pop = make_population(size)
    dep, prm, td = build_world(task, seed, num_devices=cohort)
    params0 = task.init_params(seed)
    evals = task.make_eval(td)
    pcs = make_schemes(task, dep, prm, ["adaptive_sca"])
    run_cfg = task.run_config(num_rounds=num_rounds, eval_every=eval_every,
                              seed=seed, batch_size=batch_size)
    kw = dict(task_data=td, params=params0, eval_fn=evals,
              flat=batch_size > 0, population=pop, cohort_size=cohort,
              cohort_rounds=cohort_rounds)
    res_st = run_fleet_task(task, pcs, dep.gains, run_cfg, **kw, stream=True)
    res_se = run_fleet_task(task, pcs, dep.gains, run_cfg, **kw,
                            stream=False)
    stream_eq = all(
        bool(np.array_equal(np.asarray(a), np.asarray(b)))
        for a, b in zip(jax.tree.leaves(res_st.params),
                        jax.tree.leaves(res_se.params)))
    if log:
        print(f"population {size} / cohort {cohort}: "
              f"stream exec {res_st.wall_exec:.1f}s "
              f"(staged {res_st.wall_stage:.1f}s overlapped), "
              f"serial exec {res_se.wall_exec:.1f}s")

    # full-participation identity: deployment-as-population, cohort == N
    dep0, prm0, _ = build_world(task, seed)
    pcs0 = make_schemes(task, dep0, prm0, ["sca"])
    run0 = task.run_config(num_rounds=6, eval_every=3, seed=seed,
                           batch_size=batch_size)
    kw0 = dict(task_data=td, params=params0, eval_fn=evals,
               flat=batch_size > 0)
    ref = run_fleet_task(task, pcs0, dep0.gains, run0, **kw0)
    full = run_fleet_task(task, pcs0, dep0.gains, run0, **kw0,
                          population=scn.Population.from_deployment(dep0),
                          cohort_size=task.num_devices, stream=False)
    full_bitwise = all(
        bool(np.array_equal(np.asarray(a), np.asarray(b)))
        for a, b in zip(jax.tree.leaves(ref.params),
                        jax.tree.leaves(full.params))) \
        and all(np.array_equal(ref.traces[k], full.traces[k])
                for k in ref.traces)

    report = {
        "config": {"task": task.name, "population": size, "cohort": cohort,
                   "num_rounds": num_rounds, "eval_every": eval_every,
                   "cohort_rounds": cohort_rounds, "seed": seed,
                   "batch_size": batch_size, "scheme": "adaptive_sca",
                   "sampling": "traffic", "backend": jax.default_backend()},
        "wall_s": {"stream_exec": round(res_st.wall_exec, 2),
                   "serial_exec": round(res_se.wall_exec, 2),
                   "stream_stage": round(res_st.wall_stage, 2),
                   "serial_stage": round(res_se.wall_stage, 2),
                   "stream_compile": round(res_st.wall_compile, 2)},
        # per-chunk staging walls (FLResult.stage_walls): where inside the
        # run the staging lane spent its time, stream vs serialized — the
        # chunk-resolved half of the wall_s aggregates above
        "stage_chunks_s": {
            "stream": [round(w, 4) for w in res_st.stage_walls],
            "serial": [round(w, 4) for w in res_se.stage_walls]},
        "rounds_per_sec": round(num_rounds / max(res_st.wall_exec, 1e-9), 3),
        "overlap_saving_s": round(res_se.wall_exec - res_st.wall_exec, 2),
        "stream_bitwise": bool(stream_eq),
        "full_cohort_bitwise": bool(full_bitwise),
    }
    _merge_benchmark_json(task, {"population": report})
    write_bench_summary(task)
    if log:
        print(json.dumps({k: report[k] for k in
                          ("rounds_per_sec", "overlap_saving_s",
                           "stream_bitwise", "full_cohort_bitwise")},
                         indent=1))
    return report


def kernel_benchmark(task="paper_mlp", num_rounds: int = 12,
                     eval_every: int = 6, seed: int = 0,
                     batch_size: int = BENCH_BATCH,
                     log: bool = True) -> dict:
    """Fused-vs-unfused round-step walls per uplink dtype (DESIGN.md
    §Kernels) — the measured side of the ``ota_round_step`` fusion.

    Two layers, both recorded under "round_step" in the task's
    engine_benchmark.json and surfaced into BENCH_engine.json:

    kernel  micro walls of the round tail alone at the paper's model
            scale (``kernel_bench.round_step_rows``): one fused launch vs
            the historical aggregate/ghat/step chain, plus uplink bytes
            per wire dtype — what the fusion and a low-precision uplink
            each save.
    fleet   the same comparison end-to-end through ``run_fleet_task`` on
            the 7-scheme grid: exec walls with ``fuse_round`` on/off at
            each ``uplink_dtype``, with the two trajectories checked
            bitwise-equal (f32's check is the acceptance pin — fusion
            must not move a single bit of the committed numbers).

    Also runs the interpret-mode Pallas-vs-oracle equivalence gate so the
    committed JSON records kernel agreement, not just jnp-path walls.
    """
    from benchmarks import kernel_bench

    task = _task(task)
    if log:
        print("round-step micro walls (paper scale, per uplink dtype):")
    micro = kernel_bench.round_step_rows()
    if log:
        for r in micro:
            print(f"  {r['uplink_dtype']}: fused {r['fused_us']}us vs "
                  f"unfused {r['unfused_us']}us ({r['speedup']}x), "
                  f"uplink {r['uplink_mb']}MB")
    interp_err = kernel_bench.round_step_equivalence()

    dep, prm, td = build_world(task, seed)
    params0 = task.init_params(seed)
    evals = task.make_eval(td)
    pcs = make_schemes(task, dep, prm)
    run_cfg = task.run_config(num_rounds=num_rounds, eval_every=eval_every,
                              seed=seed, batch_size=batch_size)
    kw = dict(task_data=td, params=params0, eval_fn=evals, seeds=(0,),
              flat=True)
    fleet = {}
    for ud in kernel_bench.UPLINKS:
        res_f = run_fleet_task(task, pcs, dep.gains, run_cfg, **kw,
                               uplink_dtype=ud, fuse_round=True)
        res_u = run_fleet_task(task, pcs, dep.gains, run_cfg, **kw,
                               uplink_dtype=ud, fuse_round=False)
        bitwise = all(
            bool(np.array_equal(np.asarray(a), np.asarray(b)))
            for a, b in zip(jax.tree.leaves(res_f.params),
                            jax.tree.leaves(res_u.params))) \
            and all(np.array_equal(res_f.traces[k], res_u.traces[k])
                    for k in res_f.traces)
        fleet[ud] = {"fused_exec_s": round(res_f.wall_exec, 2),
                     "unfused_exec_s": round(res_u.wall_exec, 2),
                     "bitwise_fused_vs_unfused": bool(bitwise)}
        if log:
            print(f"fleet grid ({ud}): fused exec "
                  f"{fleet[ud]['fused_exec_s']}s vs unfused "
                  f"{fleet[ud]['unfused_exec_s']}s, bitwise={bitwise}")

    report = {
        "config": {"task": task.name, "schemes": SCHEMES,
                   "num_rounds": num_rounds, "eval_every": eval_every,
                   "seed": seed, "batch_size": batch_size,
                   "backend": jax.default_backend()},
        "kernel": micro,
        "interpret_max_err": interp_err,
        "fleet": fleet,
        "f32_bitwise": fleet["f32"]["bitwise_fused_vs_unfused"],
    }
    _merge_benchmark_json(task, {"round_step": report})
    write_bench_summary(task)
    return report


def _benchmark_json_path(task) -> str:
    return os.path.join(artifact_dir(task), "engine_benchmark.json")


def _merge_benchmark_json(task, update: dict) -> dict:
    """Merge ``update`` into the task's engine_benchmark.json (so a
    placement-only rerun never clobbers the committed legacy-vs-engine
    walls, and vice versa)."""
    path = _benchmark_json_path(task)
    report = {}
    if os.path.exists(path):
        with open(path) as f:
            report = json.load(f)
    report.update(update)
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(report, f, indent=1)
    return report


def write_bench_summary(task="paper_mlp") -> dict:
    """Repo-root BENCH_engine.json: the machine-readable perf trajectory.

    Condenses the task's engine_benchmark.json to headline walls and
    speedups (engine-vs-legacy, sharded-vs-vmap per K*S point) so a later
    PR — or a reviewer — can diff throughput without parsing the full
    benchmark artifact.
    """
    task = _task(task)
    path = _benchmark_json_path(task)
    report = {}
    if os.path.exists(path):
        with open(path) as f:
            report = json.load(f)
    summary = {"source": os.path.relpath(path, ROOT), "task": task.name}
    if "grid" in report:
        summary["grid"] = {k: report["grid"][k]
                           for k in ("num_rounds", "eval_every",
                                     "bench_batch_size", "backend", "device")
                           if k in report["grid"]}
    if "wall_s" in report:
        summary["wall_s"] = report["wall_s"]
    if "speedup" in report:
        summary["speedup"] = report["speedup"]
    if "placement" in report:
        pl = report["placement"]
        summary["placement"] = {
            "config": pl["config"],
            "rows": [{"cells": r["cells"],
                      "vmap_exec_s": r["vmap"]["exec"],
                      **({"sharded_exec_s": r["sharded"]["exec"],
                          "exec_speedup":
                              r["exec_speedup_sharded_vs_vmap"]}
                         if isinstance(r.get("sharded"), dict) else
                         {"sharded": "skipped"})}
                     for r in pl["rows"]],
        }
    if "population" in report:
        summary["population"] = report["population"]
    if "scenario_grid" in report:
        summary["scenario_grid"] = report["scenario_grid"]
    if "round_step" in report:
        summary["round_step"] = report["round_step"]
    with open(BENCH_SUMMARY, "w") as f:
        json.dump(summary, f, indent=1)
    from benchmarks.validate_bench import validate
    errors = validate(BENCH_SUMMARY)
    if errors:
        raise ValueError(f"BENCH_engine.json violates "
                         f"benchmarks/bench_schema.json: {errors}")
    return summary


def _sharded_placement():
    """Debug-mesh placement for --sharded (forced-8-CPU-device CI path or
    any real multi-device host)."""
    from repro.fl.placement import ShardedPlacement
    from repro.launch.mesh import make_debug_mesh

    if jax.device_count() < 4:
        raise SystemExit(
            "--sharded needs >= 4 devices; on CPU set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=8")
    return ShardedPlacement(make_debug_mesh(2, 2))


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--task", default="paper_mlp",
        help="registered fleet workload "
             f"({'|'.join(tasks.names(runtime='fleet'))})")
    ap.add_argument("--bench", action="store_true",
                    help="engine-vs-legacy wall-clock benchmark + JSON "
                         "(also runs the placement comparison)")
    ap.add_argument("--bench-placement", action="store_true",
                    help="vmap-vs-sharded wall comparison at growing K*S; "
                         "refreshes repo-root BENCH_engine.json")
    ap.add_argument("--bench-kernel", action="store_true",
                    help="fused-vs-unfused round-step walls per uplink "
                         "dtype only (skips the multi-minute legacy "
                         "sweep); refreshes BENCH_engine.json")
    ap.add_argument("--legacy", action="store_true",
                    help="run the pre-engine host loop instead of the fleet")
    ap.add_argument("--sharded", action="store_true",
                    help="shard the scheme grid over the ('data', 'model') "
                         "debug mesh (DESIGN.md §Placement)")
    ap.add_argument("--checkpoint", action="store_true",
                    help="persist the fleet at chunk boundaries under the "
                         "task's artifact dir")
    ap.add_argument("--resume", action="store_true",
                    help="fast-forward from the task's checkpoint if present"
                         " (implies --checkpoint)")
    ap.add_argument("--max-chunks", type=int, default=None,
                    help="stop after N compiled chunks (with --checkpoint: "
                         "a clean mid-run kill the next --resume completes)")
    ap.add_argument("--population", type=int, default=0,
                    help="streaming-cohort mode: population size (devices); "
                         "0 = full participation (DESIGN.md §Population)")
    ap.add_argument("--cohort", type=int, default=None,
                    help="active devices per round under --population "
                         "(default: the task's device count)")
    ap.add_argument("--cohort-rounds", type=int, default=None,
                    help="redraw the cohort every R rounds (default: once "
                         "per chunk, i.e. the eval cadence)")
    ap.add_argument("--telemetry", action="store_true",
                    help="write events.jsonl + bias-variance diagnostics "
                         "into the task's artifact dir; render with "
                         "python -m repro.telemetry.report <dir>")
    ap.add_argument("--no-stream", action="store_true",
                    help="serialize cohort staging instead of double-"
                         "buffering it against the executing chunk "
                         "(identical numbers, different walls)")
    ap.add_argument("--rounds", type=int, default=150)
    ap.add_argument("--every", type=int, default=None,
                    help="eval cadence (default: 10, or 15 under --bench)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--batch-size", type=int, default=None,
                    help="0 = full batch (paper); default = the task's "
                         f"preferred size; under --bench, the minibatch "
                         f"mode size (default {BENCH_BATCH})")
    ap.add_argument("--coordinator", default=None, metavar="HOST:PORT",
                    help="join a jax.distributed cluster before any "
                         "backend touch (multi-process bring-up, "
                         "DESIGN.md §Grid)")
    ap.add_argument("--num-processes", type=int, default=None)
    ap.add_argument("--process-id", type=int, default=None)
    ap.add_argument("--local-devices", type=int, default=None,
                    help="force N host-platform (CPU) devices per "
                         "process (multi-process CPU smoke)")
    args = ap.parse_args(argv)
    if args.coordinator:
        if args.num_processes is None or args.process_id is None:
            raise SystemExit("--coordinator needs --num-processes and "
                             "--process-id")
        from repro.distributed import initialize_multiprocess
        nproc, ndev = initialize_multiprocess(
            args.coordinator, args.num_processes, args.process_id,
            local_device_count=args.local_devices)
        print(f"process {args.process_id}/{nproc}: {ndev} local devices "
              f"({jax.device_count()} global)", flush=True)
    try:
        task = _task(args.task)
    except (KeyError, ValueError) as e:
        raise SystemExit(str(e))
    if args.sharded and (args.legacy or args.bench or args.bench_kernel):
        raise SystemExit("--sharded applies to the fleet engine only; "
                         "drop --legacy/--bench/--bench-kernel")
    if (args.checkpoint or args.resume) \
            and (args.legacy or args.bench or args.bench_placement
                 or args.bench_kernel):
        raise SystemExit("--checkpoint/--resume apply to the fleet engine "
                         "only; drop --legacy/--bench/--bench-placement/"
                         "--bench-kernel")
    if args.population and (args.legacy or args.sharded):
        raise SystemExit("--population applies to the vmap fleet engine; "
                         "drop --legacy/--sharded")
    if args.telemetry and (args.legacy or args.bench or args.bench_placement
                           or args.bench_kernel):
        raise SystemExit("--telemetry applies to the fleet engine only; "
                         "drop --legacy/--bench/--bench-placement/"
                         "--bench-kernel")
    if args.bench_kernel and not args.bench:
        kernel_benchmark(task=task, num_rounds=min(args.rounds, 12),
                         eval_every=args.every or 6, seed=args.seed,
                         batch_size=args.batch_size or BENCH_BATCH)
        return
    if args.bench:
        benchmark(num_rounds=args.rounds, eval_every=args.every or 15,
                  seed=args.seed, task=task,
                  batch_size=args.batch_size or BENCH_BATCH)
        placement_benchmark(task=task, num_rounds=min(args.rounds, 30),
                            eval_every=args.every or 15, seed=args.seed,
                            batch_size=args.batch_size or BENCH_BATCH)
        population_benchmark(task=task,
                             size=args.population or 1_000_000,
                             cohort=args.cohort or 50, seed=args.seed,
                             batch_size=args.batch_size or BENCH_BATCH)
        kernel_benchmark(task=task, num_rounds=12,
                         eval_every=args.every or 6, seed=args.seed,
                         batch_size=args.batch_size or BENCH_BATCH)
        return
    if args.bench_placement:
        placement_benchmark(task=task, num_rounds=min(args.rounds, 30),
                            eval_every=args.every or 15, seed=args.seed,
                            batch_size=args.batch_size or BENCH_BATCH)
        return
    ckpt_path = None
    if args.checkpoint or args.resume:
        ckpt_path = os.path.join(artifact_dir(task),
                                 f"fleet_seed{args.seed}")
    hist = run(num_rounds=args.rounds, eval_every=args.every or 10,
               seed=args.seed, task=task,
               engine="legacy" if args.legacy else "fleet",
               batch_size=args.batch_size, log=True,
               placement=_sharded_placement() if args.sharded else None,
               checkpoint_path=ckpt_path, resume=args.resume,
               population=args.population, cohort=args.cohort,
               cohort_rounds=args.cohort_rounds,
               stream=not args.no_stream, max_chunks=args.max_chunks,
               telemetry=args.telemetry)
    for row in summarize(hist):
        print(row)


if __name__ == "__main__":
    main()
