"""Render EXPERIMENTS.md §Dry-run / §Roofline markdown tables from the
dry-run artifacts.

    PYTHONPATH=src python -m benchmarks.tables [--mesh pod|multipod]
"""
from __future__ import annotations

import argparse

from benchmarks import roofline


def dryrun_table(records: list) -> str:
    lines = ["| arch | shape | devices | params | HLO GFLOP/dev | HLO GB/dev "
             "| coll GB/dev (ar/ag/rs/a2a/cp) | args GB/dev | compile s |",
             "|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(records, key=lambda r: (r["arch"], r["shape"])):
        coll = r["collective_bytes_per_device"]
        fl = r.get("flops_per_device_corrected", r["flops_per_device"])
        cl = r.get("collective_bytes_corrected", coll["total"])
        by = r.get("bytes_per_device_corrected",
                   r["bytes_accessed_per_device"])
        detail = "/".join(f"{coll.get(k, 0) / 1e9:.2f}"
                          for k in ("all-reduce", "all-gather",
                                    "reduce-scatter", "all-to-all",
                                    "collective-permute"))
        arg_gb = r["memory_analysis"].get("argument_bytes", 0) / 1e9
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['devices']} "
            f"| {r['num_params'] / 1e9:.2f}B | {fl / 1e9:.0f} "
            f"| {by / 1e9:.1f} | {cl / 1e9:.2f} ({detail}) "
            f"| {arg_gb:.2f} | {r['compile_s']} |")
    return "\n".join(lines)


def roofline_table(records: list) -> str:
    lines = ["| arch | shape | compute s | memory s | collective s "
             "| dominant | MODEL_FLOPS/dev | useful ratio |",
             "|---|---|---|---|---|---|---|---|"]
    for r in records:
        row = roofline.roofline_row(r)
        lines.append(
            f"| {row['arch']} | {row['shape']} | {row['compute_s']:.3f} "
            f"| {row['memory_s']:.3f} | {row['collective_s']:.3f} "
            f"| **{row['dominant']}** "
            f"| {row['model_flops_per_device']:.3e} "
            f"| {row['useful_flops_ratio']:.3f} |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod"])
    args = ap.parse_args()
    records = roofline.load_records(f"*_{args.mesh}.json")
    print(f"### Dry-run ({args.mesh})\n")
    print(dryrun_table(records))
    if args.mesh == "pod":
        print("\n### Roofline (single-pod)\n")
        print(roofline_table(records))


if __name__ == "__main__":
    main()
