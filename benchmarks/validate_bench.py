"""Validate the repo-root BENCH_engine.json against bench_schema.json.

    PYTHONPATH=src python -m benchmarks.validate_bench [path]

The summary is the machine-readable perf trajectory diffed across PRs, so
its SHAPE is a contract: the CI `population-smoke` job runs this module
against the committed file, and ``fig2.write_bench_summary`` runs it on
every rewrite (a bench refresh that breaks the schema fails loudly at
write time, not at the next PR's diff).

Uses ``jsonschema`` when importable; otherwise falls back to a minimal
built-in checker covering the subset the schema actually uses (type,
required, properties, additionalProperties, items, minimum /
exclusiveMinimum, minItems) — no new dependencies either way.

Beyond the shape, two semantic invariants are checked: the per-chunk
staging breakdown ``population.stage_chunks_s`` (when present) must sum
back to the ``population.wall_s.{stream,serial}_stage`` aggregates it
refines — a breakdown that doesn't reconcile with its own total is a
recording bug, not a perf change — and the ``round_step`` section (when
present) must carry fused-vs-unfused walls for EVERY uplink dtype
(f32/bf16/int8) in both its kernel rows and its fleet grid: a partial
dtype sweep would silently read as "quantized uplink measured" when it
wasn't.

The ``scenario_grid`` section (when present) gets the same treatment:
its sequential per-scenario walls must cover every scenario named in the
config and sum back to ``sequential.total_s``, the headline
``speedup.grid_vs_sequential`` must reconcile with the recorded walls it
claims to summarize, and ``c1_slice_bitwise`` must be true — a grid
whose C=1 slice is not bitwise today's per-scenario fleet is broken
semantics, not a perf trade.
"""
from __future__ import annotations

import json
import os
import sys

ROOT = os.path.join(os.path.dirname(__file__), "..")
DEFAULT_SUMMARY = os.path.join(ROOT, "BENCH_engine.json")
SCHEMA = os.path.join(os.path.dirname(__file__), "bench_schema.json")

_TYPES = {"object": dict, "array": list, "string": str, "boolean": bool,
          "integer": int, "number": (int, float)}


def _check(obj, schema: dict, path: str, errors: list) -> None:
    """Minimal recursive draft-07 subset checker (fallback path)."""
    typ = schema.get("type")
    if typ is not None:
        pytype = _TYPES[typ]
        ok = isinstance(obj, pytype)
        if typ in ("integer", "number") and isinstance(obj, bool):
            ok = False
        if not ok:
            errors.append(f"{path or '$'}: expected {typ}, "
                          f"got {type(obj).__name__}")
            return
    if isinstance(obj, dict):
        for req in schema.get("required", ()):
            if req not in obj:
                errors.append(f"{path or '$'}: missing required key {req!r}")
        props = schema.get("properties", {})
        extra = schema.get("additionalProperties", True)
        for key, val in obj.items():
            if key in props:
                _check(val, props[key], f"{path}/{key}", errors)
            elif isinstance(extra, dict):
                _check(val, extra, f"{path}/{key}", errors)
            elif extra is False:
                errors.append(f"{path or '$'}: unexpected key {key!r}")
    elif isinstance(obj, list):
        if len(obj) < schema.get("minItems", 0):
            errors.append(f"{path or '$'}: fewer than "
                          f"{schema['minItems']} items")
        items = schema.get("items")
        if items is not None:
            for i, val in enumerate(obj):
                _check(val, items, f"{path}/{i}", errors)
    elif isinstance(obj, (int, float)) and not isinstance(obj, bool):
        if "minimum" in schema and obj < schema["minimum"]:
            errors.append(f"{path or '$'}: {obj} < min {schema['minimum']}")
        if "exclusiveMinimum" in schema \
                and obj <= schema["exclusiveMinimum"]:
            errors.append(f"{path or '$'}: {obj} <= exclusive min "
                          f"{schema['exclusiveMinimum']}")


def _check_stage_chunks(summary: dict, errors: list) -> None:
    """population.stage_chunks_s must reconcile with the wall_s aggregates
    (each chunk wall rounds to 4 decimals, the aggregate to 2)."""
    pop = summary.get("population")
    if not isinstance(pop, dict):
        return
    chunks = pop.get("stage_chunks_s")
    walls = pop.get("wall_s")
    if not isinstance(chunks, dict) or not isinstance(walls, dict):
        return
    for mode in ("stream", "serial"):
        per_chunk = chunks.get(mode)
        total = walls.get(f"{mode}_stage")
        if not isinstance(per_chunk, list) or total is None:
            continue
        tol = 0.01 + 5e-5 * len(per_chunk)       # rounding headroom
        if abs(sum(per_chunk) - total) > tol:
            errors.append(
                f"population/stage_chunks_s/{mode}: chunks sum to "
                f"{sum(per_chunk):.4f}s but wall_s.{mode}_stage is "
                f"{total}s")


_UPLINK_DTYPES = ("f32", "bf16", "int8")


def _check_round_step(summary: dict, errors: list) -> None:
    """round_step (when present) must cover every uplink dtype in both
    the kernel micro rows and the end-to-end fleet walls."""
    rs = summary.get("round_step")
    if not isinstance(rs, dict):
        return
    rows = rs.get("kernel")
    if isinstance(rows, list):
        seen = {r.get("uplink_dtype") for r in rows if isinstance(r, dict)}
        missing = set(_UPLINK_DTYPES) - seen
        if missing:
            errors.append(f"round_step/kernel: missing uplink dtypes "
                          f"{sorted(missing)}")
    fleet = rs.get("fleet")
    if isinstance(fleet, dict):
        missing = set(_UPLINK_DTYPES) - set(fleet)
        if missing:
            errors.append(f"round_step/fleet: missing uplink dtypes "
                          f"{sorted(missing)}")


def _check_scenario_grid(summary: dict, errors: list) -> None:
    """scenario_grid (when present) must reconcile with itself: one
    sequential wall per configured scenario, walls that sum to their
    total, a speedup that equals total/grid, and a bitwise C=1 slice."""
    sg = summary.get("scenario_grid")
    if not isinstance(sg, dict):
        return
    cfg = sg.get("config", {})
    seq = sg.get("sequential", {})
    rows = seq.get("per_scenario")
    names = cfg.get("scenarios")
    if isinstance(rows, list) and isinstance(names, list):
        got = [r.get("scenario") for r in rows if isinstance(r, dict)]
        if got != names:
            errors.append(f"scenario_grid/sequential: per_scenario covers "
                          f"{got} but config.scenarios is {names}")
    total = seq.get("total_s")
    if isinstance(rows, list) and isinstance(total, (int, float)):
        walls = [r.get("wall_s", 0) for r in rows if isinstance(r, dict)]
        tol = 0.01 + 5e-3 * len(walls)           # rounding headroom
        if abs(sum(walls) - total) > tol:
            errors.append(f"scenario_grid/sequential: walls sum to "
                          f"{sum(walls):.2f}s but total_s is {total}s")
    grid_wall = sg.get("grid", {}).get("wall_s")
    speedup = sg.get("speedup", {}).get("grid_vs_sequential")
    if isinstance(total, (int, float)) and isinstance(grid_wall,
                                                      (int, float)) \
            and isinstance(speedup, (int, float)) and grid_wall > 0:
        if abs(speedup - total / grid_wall) > 0.05 * max(speedup, 1.0):
            errors.append(f"scenario_grid/speedup: grid_vs_sequential "
                          f"{speedup} != total_s/grid.wall_s "
                          f"{total / grid_wall:.2f}")
    if sg.get("c1_slice_bitwise") is not True:
        errors.append("scenario_grid: c1_slice_bitwise must be true — "
                      "the grid's C=1 slice diverged from the "
                      "per-scenario fleet")


def validate(summary_path: str = DEFAULT_SUMMARY,
             schema_path: str = SCHEMA) -> list:
    """Return a list of violation strings (empty = valid)."""
    with open(summary_path) as f:
        summary = json.load(f)
    with open(schema_path) as f:
        schema = json.load(f)
    try:
        import jsonschema
    except ImportError:
        errors: list = []
        _check(summary, schema, "", errors)
        _check_stage_chunks(summary, errors)
        _check_round_step(summary, errors)
        _check_scenario_grid(summary, errors)
        return errors
    validator = jsonschema.Draft7Validator(schema)
    errors = [f"{'/'.join(str(p) for p in e.absolute_path) or '$'}: "
              f"{e.message}" for e in validator.iter_errors(summary)]
    _check_stage_chunks(summary, errors)
    _check_round_step(summary, errors)
    _check_scenario_grid(summary, errors)
    return errors


def main(argv=None) -> None:
    args = sys.argv[1:] if argv is None else argv
    path = args[0] if args else DEFAULT_SUMMARY
    errors = validate(path)
    if errors:
        for e in errors:
            print(f"SCHEMA VIOLATION {e}")
        raise SystemExit(1)
    print(f"{os.path.relpath(path, ROOT)}: OK "
          f"(schema {os.path.relpath(SCHEMA, ROOT)})")


if __name__ == "__main__":
    main()
