"""Scenario-family sweep: bias/variance/objective per power-control scheme
across heterogeneous wireless deployments (DESIGN.md §Scenarios).

    PYTHONPATH=src python -m benchmarks.scenario_sweep [--train] [--sharded]
                                                       [--rounds N]

For every scenario in the sweep grid (default: the four-family grid
``scenarios.SWEEP_FAMILIES`` — disk-Rayleigh baseline, Rician, shadowed,
two-cluster; ``--all`` sweeps the whole registry) and every statistical-CSI
scheme (sca / lcpc / zero_bias), this computes the Theorem-1 quantities with
the scenario's family-aware statistics:

    bias        2 N kappa^2 sum_m (p_m - 1/N)^2          (theory.bias_term)
    variance    zeta = transmission + minibatch + noise  (theory.zeta_terms)
    objective   2 eta L zeta + bias                      (the (P1) objective)

and emits one CSV row per (scenario, scheme).  With ``--train`` it also runs
an FL workload from the task registry (``--task``, default the paper's MLP;
DESIGN.md §Tasks) on each scenario's FadingProcess — the scheme axis as
one compiled scan fleet per scenario, through the task-first driver
(``fl.driver.run_fleet_task``; ``--sharded`` shards the cells over the
debug mesh) — and appends test accuracy.
"""
from __future__ import annotations

import argparse
import json
import os

import numpy as np

from repro.configs.paper_mlp import CONFIG as PAPER
from repro.core import power_control as pcm
from repro.core import scenarios as scn
from repro.core import theory

SCHEMES = ("sca", "lcpc", "zero_bias")
ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                            "scenario_sweep")


def scheme_theory_row(name: str, dep, prm) -> dict:
    """Theorem-1 decomposition for a truncated-inversion scheme."""
    pc = pcm.make_power_control(name, dep, prm)
    z = theory.zeta_terms(pc.gamma, prm)
    bias = theory.bias_term(pc.p, prm)
    return {
        "scheme": name,
        "bias": bias,
        "variance": z["total"],
        "var_transmission": z["transmission"],
        "var_noise": z["noise"],
        "objective": 2.0 * prm.eta * prm.lsmooth * z["total"] + bias,
        "p_spread": float(np.max(pc.p) - np.min(pc.p)),
        "mean_participation": float(np.mean(
            theory.expected_participation_indicator(pc.gamma, prm))),
    }


def sweep(scenario_names=scn.SWEEP_FAMILIES, schemes=SCHEMES,
          d: int = 814090, gmax: float = 10.0, eta: float = 0.05,
          kappa_sq: float = 4.0, seed: int = 0) -> list:
    """One theory row per (scenario, scheme)."""
    rows = []
    for sc_name in scenario_names:
        sc = scn.get_scenario(sc_name)
        dep = scn.realize(sc, seed=seed)
        prm = scn.make_ota_params(dep, d=d, gmax=gmax, eta=eta,
                                  kappa_sq=kappa_sq)
        for scheme in schemes:
            row = scheme_theory_row(scheme, dep, prm)
            row.update(scenario=sc_name, fading=dep.fading_spec.family,
                       gain_spread_db=float(10 * np.log10(
                           dep.gains.max() / dep.gains.min())))
            rows.append(row)
    return rows


def train_sweep(scenario_names=scn.SWEEP_FAMILIES, schemes=SCHEMES,
                num_rounds: int = 100, eval_every: int = 20,
                seed: int = 0, log: bool = False,
                batch_size=None, placement=None,
                task="paper_mlp") -> list:
    """Short FL runs of a registered task per (scenario, scheme).

    The workload — data, params, loss, eval, per-scheme step sizes —
    comes from the task registry (``repro.tasks``, DESIGN.md §Tasks) and
    is built ONCE, shared across every scenario fleet.  Per scenario, the
    whole scheme axis runs as ONE compiled scan fleet through the
    task-first host driver (fl.driver.run_fleet_task, DESIGN.md
    §Placement) on the scenario's FadingProcess — the default
    sca/lcpc/zero_bias grid is a homogeneous TruncatedInversion stack, so
    a single cell program covers it; aggregation rides the flattened
    Pallas hot path (DESIGN.md §Engine).  ``placement`` maps each
    scenario's scheme grid onto hardware (None = single-device vmap;
    fl.placement.ShardedPlacement(mesh) shards the cells over the
    ("data", "model") mesh).
    """
    from repro import tasks as task_registry
    from repro.fl.driver import run_fleet_task

    if isinstance(task, str):
        task = task_registry.get(task, expect_runtime="fleet")
    elif task.runtime != "fleet":
        raise ValueError(f"task {task.name!r} is not a fleet workload")
    if batch_size is None:   # the task's preferred sweep mode (fig2 ditto)
        batch_size = int(task.defaults.get("batch_size", 0))
    td = task.build_data(seed)
    params0 = task.init_params(seed)
    evals = task.make_eval(td)

    rows = []
    for sc_name in scenario_names:
        sc = scn.get_scenario(sc_name)
        dep = scn.realize(sc, seed=seed)
        if len(dep.gains) != task.num_devices:
            raise ValueError(
                f"scenario {sc_name!r} deploys {len(dep.gains)} devices "
                f"but task {task.name!r} partitions {task.num_devices}")
        prm = scn.make_ota_params(dep, d=task.param_dim,
                                  gmax=float(task.defaults.get("gmax",
                                                               PAPER.gmax)),
                                  eta=0.05, kappa_sq=4.0)
        fading = scn.make_fading_process(dep, sc.dynamics)
        # global-CSI schemes pick up dropout-awareness from dep.p_dropout
        pcs = [pcm.make_power_control(s, dep, prm) for s in schemes]
        run_cfg = task.run_config(eta=0.05, num_rounds=num_rounds,
                                  eval_every=eval_every, seed=seed,
                                  batch_size=batch_size)
        # schemes are designed at prm.eta above, so train at that same
        # operating point (the task's per-scheme eta map is fig2's concern)
        res = run_fleet_task(task, pcs, dep.gains, run_cfg, task_data=td,
                             params=params0, eval_fn=evals,
                             etas=[run_cfg.eta] * len(schemes),
                             fading=fading, flat=True, log=log,
                             placement=placement)
        final = res.evals[-1][1]["acc"]
        for i, scheme in enumerate(schemes):
            rows.append({"scenario": sc_name, "scheme": scheme,
                         "final_acc": round(float(final[i, 0]), 4),
                         "rounds": num_rounds})
    return rows


def _fmt(v):
    if isinstance(v, float):
        return f"{v:.4g}"
    return str(v)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--all", action="store_true",
                    help="sweep every registered scenario")
    ap.add_argument("--train", action="store_true",
                    help="also run short FL training per (scenario, scheme)")
    ap.add_argument("--task", default="paper_mlp",
                    help="registered workload for --train "
                         "(paper_mlp | cifar_conv; DESIGN.md §Tasks)")
    ap.add_argument("--sharded", action="store_true",
                    help="shard each scenario's scheme grid over the "
                         "('data', 'model') debug mesh (needs >= 4 devices)")
    ap.add_argument("--rounds", type=int, default=100)
    ap.add_argument("--batch-size", type=int, default=None,
                    help="minibatch size for --train (0 = full batch; "
                         "default = the task's preferred size)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    if args.sharded and not args.train:
        raise SystemExit("--sharded shards the training fleets; "
                         "pass --train with it")

    names = scn.scenario_names() if args.all else scn.SWEEP_FAMILIES
    rows = sweep(names, seed=args.seed)
    cols = ("scenario", "scheme", "bias", "variance", "objective",
            "p_spread", "mean_participation", "gain_spread_db")
    print(",".join(cols))
    for r in rows:
        print(",".join(_fmt(r[c]) for c in cols), flush=True)

    if args.train:
        placement = None
        if args.sharded:
            from benchmarks.fig2 import _sharded_placement
            placement = _sharded_placement()
        trows = train_sweep(names, num_rounds=args.rounds, seed=args.seed,
                            batch_size=args.batch_size,
                            placement=placement, task=args.task)
        print("scenario,scheme,final_acc,rounds")
        for r in trows:
            print(f"{r['scenario']},{r['scheme']},{r['final_acc']},"
                  f"{r['rounds']}", flush=True)
        rows = {"theory": rows, "train": trows}
    os.makedirs(ARTIFACT_DIR, exist_ok=True)
    with open(os.path.join(ARTIFACT_DIR,
                           f"sweep_seed{args.seed}.json"), "w") as f:
        json.dump(rows, f, indent=1)


if __name__ == "__main__":
    main()
