"""Scenario-family sweep: bias/variance/objective per power-control scheme
across heterogeneous wireless deployments (DESIGN.md §Scenarios).

    PYTHONPATH=src python -m benchmarks.scenario_sweep [--train] [--sharded]
                                                       [--grid] [--rounds N]

``--grid`` (with ``--train``) is the scenario-grid payoff benchmark
(DESIGN.md §Grid): the same (scenario, scheme) sweep run twice — once as
today's SEQUENTIAL per-scenario fleets (one compile + execute per
scenario) and once as ONE compiled [C x K x S] grid through
``core.scenarios.ScenarioStack`` — with both walls, the C=1
grid-vs-fleet bitwise check, and the donate/no-donate peak-RSS probe
recorded in the ``scenario_grid`` section of the repo-root
BENCH_engine.json.

Multi-process bring-up (``--coordinator HOST:PORT --num-processes P
--process-id I [--local-devices N]``) joins a ``jax.distributed``
cluster before any backend touch and restricts this process to its
contiguous slice of the scenario axis (distributed.process_grid_slice);
artifacts are written by process 0 only.  See benchmarks/grid_smoke.py
for the 2-process forced-CPU proof.

For every scenario in the sweep grid (default: the four-family grid
``scenarios.SWEEP_FAMILIES`` — disk-Rayleigh baseline, Rician, shadowed,
two-cluster; ``--all`` sweeps the whole registry) and every statistical-CSI
scheme (sca / lcpc / zero_bias), this computes the Theorem-1 quantities with
the scenario's family-aware statistics:

    bias        2 N kappa^2 sum_m (p_m - 1/N)^2          (theory.bias_term)
    variance    zeta = transmission + minibatch + noise  (theory.zeta_terms)
    objective   2 eta L zeta + bias                      (the (P1) objective)

and emits one CSV row per (scenario, scheme).  With ``--train`` it also runs
an FL workload from the task registry (``--task``, default the paper's MLP;
DESIGN.md §Tasks) on each scenario's FadingProcess — the scheme axis as
one compiled scan fleet per scenario, through the task-first driver
(``fl.driver.run_fleet_task``; ``--sharded`` shards the cells over the
debug mesh) — and appends test accuracy.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

import numpy as np

from repro.configs.paper_mlp import CONFIG as PAPER
from repro.core import power_control as pcm
from repro.core import scenarios as scn
from repro.core import theory

SCHEMES = ("sca", "lcpc", "zero_bias")
ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                            "scenario_sweep")


def scheme_theory_row(name: str, dep, prm) -> dict:
    """Theorem-1 decomposition for a truncated-inversion scheme."""
    pc = pcm.make_power_control(name, dep, prm)
    z = theory.zeta_terms(pc.gamma, prm)
    bias = theory.bias_term(pc.p, prm)
    return {
        "scheme": name,
        "bias": bias,
        "variance": z["total"],
        "var_transmission": z["transmission"],
        "var_noise": z["noise"],
        "objective": 2.0 * prm.eta * prm.lsmooth * z["total"] + bias,
        "p_spread": float(np.max(pc.p) - np.min(pc.p)),
        "mean_participation": float(np.mean(
            theory.expected_participation_indicator(pc.gamma, prm))),
    }


def sweep(scenario_names=scn.SWEEP_FAMILIES, schemes=SCHEMES,
          d: int = 814090, gmax: float = 10.0, eta: float = 0.05,
          kappa_sq: float = 4.0, seed: int = 0) -> list:
    """One theory row per (scenario, scheme)."""
    rows = []
    for sc_name in scenario_names:
        sc = scn.get_scenario(sc_name)
        dep = scn.realize(sc, seed=seed)
        prm = scn.make_ota_params(dep, d=d, gmax=gmax, eta=eta,
                                  kappa_sq=kappa_sq)
        for scheme in schemes:
            row = scheme_theory_row(scheme, dep, prm)
            row.update(scenario=sc_name, fading=dep.fading_spec.family,
                       gain_spread_db=float(10 * np.log10(
                           dep.gains.max() / dep.gains.min())))
            rows.append(row)
    return rows


def train_sweep(scenario_names=scn.SWEEP_FAMILIES, schemes=SCHEMES,
                num_rounds: int = 100, eval_every: int = 20,
                seed: int = 0, log: bool = False,
                batch_size=None, placement=None,
                task="paper_mlp") -> list:
    """Short FL runs of a registered task per (scenario, scheme).

    The workload — data, params, loss, eval, per-scheme step sizes —
    comes from the task registry (``repro.tasks``, DESIGN.md §Tasks) and
    is built ONCE, shared across every scenario fleet.  Per scenario, the
    whole scheme axis runs as ONE compiled scan fleet through the
    task-first host driver (fl.driver.run_fleet_task, DESIGN.md
    §Placement) on the scenario's FadingProcess — the default
    sca/lcpc/zero_bias grid is a homogeneous TruncatedInversion stack, so
    a single cell program covers it; aggregation rides the flattened
    Pallas hot path (DESIGN.md §Engine).  ``placement`` maps each
    scenario's scheme grid onto hardware (None = single-device vmap;
    fl.placement.ShardedPlacement(mesh) shards the cells over the
    ("data", "model") mesh).
    """
    from repro import tasks as task_registry
    from repro.fl.driver import run_fleet_task

    if isinstance(task, str):
        task = task_registry.get(task, expect_runtime="fleet")
    elif task.runtime != "fleet":
        raise ValueError(f"task {task.name!r} is not a fleet workload")
    if batch_size is None:   # the task's preferred sweep mode (fig2 ditto)
        batch_size = int(task.defaults.get("batch_size", 0))
    td = task.build_data(seed)
    params0 = task.init_params(seed)
    evals = task.make_eval(td)

    rows = []
    for sc_name in scenario_names:
        sc = scn.get_scenario(sc_name)
        dep = scn.realize(sc, seed=seed)
        if len(dep.gains) != task.num_devices:
            raise ValueError(
                f"scenario {sc_name!r} deploys {len(dep.gains)} devices "
                f"but task {task.name!r} partitions {task.num_devices}")
        prm = scn.make_ota_params(dep, d=task.param_dim,
                                  gmax=float(task.defaults.get("gmax",
                                                               PAPER.gmax)),
                                  eta=0.05, kappa_sq=4.0)
        fading = scn.make_fading_process(dep, sc.dynamics)
        # global-CSI schemes pick up dropout-awareness from dep.p_dropout
        pcs = [pcm.make_power_control(s, dep, prm) for s in schemes]
        run_cfg = task.run_config(eta=0.05, num_rounds=num_rounds,
                                  eval_every=eval_every, seed=seed,
                                  batch_size=batch_size)
        # schemes are designed at prm.eta above, so train at that same
        # operating point (the task's per-scheme eta map is fig2's concern)
        res = run_fleet_task(task, pcs, dep.gains, run_cfg, task_data=td,
                             params=params0, eval_fn=evals,
                             etas=[run_cfg.eta] * len(schemes),
                             fading=fading, flat=True, log=log,
                             placement=placement)
        final = res.evals[-1][1]["acc"]
        for i, scheme in enumerate(schemes):
            rows.append({"scenario": sc_name, "scheme": scheme,
                         "final_acc": round(float(final[i, 0]), 4),
                         "rounds": num_rounds})
    return rows


# ---------------------------------------------------------------------------
# --grid: sequential-per-scenario fleets vs ONE compiled [C x K x S] grid
# (DESIGN.md §Grid) -> scenario_grid section of BENCH_engine.json.
# ---------------------------------------------------------------------------

def _walls(res) -> dict:
    return {"wall_s": round(res.wall, 2),
            "compile_s": round(res.wall_compile, 2),
            "exec_s": round(res.wall_exec, 2)}


def _task_gmax(task) -> float:
    return float(task.defaults.get("gmax", PAPER.gmax))


def _scenario_fleet_inputs(task, sc_name: str, schemes, seed: int):
    """(dep, fading, pcs, etas placeholder source) for one scenario."""
    sc = scn.get_scenario(sc_name)
    dep = scn.realize(sc, seed=seed)
    prm = scn.make_ota_params(dep, d=task.param_dim, gmax=_task_gmax(task),
                              eta=0.05, kappa_sq=4.0)
    pcs = [pcm.make_power_control(s, dep, prm) for s in schemes]
    return sc, dep, pcs


def _grid_fleet(task, scenario_names, schemes, run_cfg, seeds, *,
                task_data, params, eval_fn, placement=None):
    """ONE [C x K x S] fleet over the stacked scenario axis: the schemes
    are flattened scenario-major (the driver's layout) and the channel
    comes from the ScenarioStack, not a FadingProcess."""
    from repro.fl.driver import run_fleet_task

    stack = scn.stack_scenarios(scenario_names, seed=run_cfg.seed)
    pcs = []
    for sc_name in scenario_names:
        pcs.extend(_scenario_fleet_inputs(task, sc_name, schemes,
                                          run_cfg.seed)[2])
    return run_fleet_task(task, pcs, None, run_cfg, task_data=task_data,
                          params=params, eval_fn=eval_fn,
                          etas=[run_cfg.eta] * len(pcs), seeds=seeds,
                          flat=True, placement=placement, scenarios=stack)


def _results_bitwise(a, b) -> bool:
    import jax

    pa = [np.asarray(x) for x in jax.tree.leaves(a.params)]
    pb = [np.asarray(x) for x in jax.tree.leaves(b.params)]
    ok = len(pa) == len(pb) and all(np.array_equal(x, y)
                                    for x, y in zip(pa, pb))
    ok = ok and set(a.traces) == set(b.traces)
    return bool(ok and all(np.array_equal(a.traces[t], b.traces[t])
                           for t in a.traces))


def _rss_probe_child(task, scenario_names, schemes, num_rounds: int,
                     seed: int, num_seeds: int, donate: bool) -> None:
    """Child side of the peak-RSS probe: run the grid once with carry
    donation on/off and print the process high-water mark (satellite:
    donated scan-chunk carries should lower it)."""
    import resource

    from repro.fl.placement import VmapPlacement

    from repro import tasks as task_registry

    task = task_registry.get(task, expect_runtime="fleet")
    td = task.build_data(seed)
    run_cfg = task.run_config(eta=0.05, num_rounds=num_rounds,
                              eval_every=num_rounds, seed=seed,
                              batch_size=int(task.defaults.get(
                                  "batch_size", 0)))
    _grid_fleet(task, scenario_names, schemes, run_cfg,
                tuple(range(num_seeds)), task_data=td,
                params=task.init_params(seed), eval_fn=task.make_eval(td),
                placement=VmapPlacement(donate=donate))
    peak_mb = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0
    print("RSS_PROBE " + json.dumps({"donate": donate,
                                     "peak_rss_mb": round(peak_mb, 1)}),
          flush=True)


def _run_rss_probe(task_name: str, scenario_names, num_rounds: int,
                   seed: int, num_seeds: int) -> dict:
    """Spawn one fresh process per donation mode (RSS high-water marks
    only mean something process-wide) and report the delta."""
    out = {}
    for mode in ("donate", "nodonate"):
        cmd = [sys.executable, "-m", "benchmarks.scenario_sweep",
               "--rss-probe", mode, "--task", task_name,
               "--rounds", str(num_rounds), "--seed", str(seed),
               "--grid-seeds", str(num_seeds),
               "--scenarios", ",".join(scenario_names)]
        proc = subprocess.run(cmd, capture_output=True, text=True,
                              cwd=os.path.join(os.path.dirname(__file__),
                                               ".."))
        line = next((ln for ln in proc.stdout.splitlines()
                     if ln.startswith("RSS_PROBE ")), None)
        if proc.returncode != 0 or line is None:
            raise RuntimeError(f"rss probe ({mode}) failed:\n{proc.stderr}")
        out[mode] = json.loads(line[len("RSS_PROBE "):])["peak_rss_mb"]
    return {"donate_peak_rss_mb": out["donate"],
            "nodonate_peak_rss_mb": out["nodonate"],
            "delta_mb": round(out["nodonate"] - out["donate"], 1)}


def grid_sweep(scenario_names=scn.SWEEP_FAMILIES, schemes=SCHEMES,
               num_rounds: int = 40, eval_every: int = 20, seed: int = 0,
               num_seeds: int = 2, batch_size=None, placement=None,
               task="paper_mlp", log: bool = True, rss_probe: bool = True,
               write_bench: bool = True) -> dict:
    """Sequential-per-scenario fleets vs one compiled grid, measured.

    Runs the identical (scenario, scheme, seed) sweep both ways on the
    same task world, checks the C=1 grid slice is bitwise today's fleet,
    optionally probes carry-donation peak RSS in subprocesses, and
    merges a ``scenario_grid`` section into the task's
    engine_benchmark.json + the repo-root BENCH_engine.json."""
    import jax

    from repro import tasks as task_registry

    if isinstance(task, str):
        task = task_registry.get(task, expect_runtime="fleet")
    if batch_size is None:
        batch_size = int(task.defaults.get("batch_size", 0))
    td = task.build_data(seed)
    params0 = task.init_params(seed)
    evals = task.make_eval(td)
    run_cfg = task.run_config(eta=0.05, num_rounds=num_rounds,
                              eval_every=eval_every, seed=seed,
                              batch_size=batch_size)
    seeds = tuple(range(num_seeds))
    kw = dict(task_data=td, params=params0, eval_fn=evals,
              placement=placement)

    from repro.fl.driver import run_fleet_task

    per_scenario, seq_first = [], None
    for sc_name in scenario_names:
        sc, dep, pcs = _scenario_fleet_inputs(task, sc_name, schemes, seed)
        fading = scn.make_fading_process(dep, sc.dynamics)
        res = run_fleet_task(task, pcs, dep.gains, run_cfg,
                             etas=[run_cfg.eta] * len(pcs), fading=fading,
                             seeds=seeds, flat=True, **kw)
        seq_first = seq_first if seq_first is not None else res
        per_scenario.append({"scenario": sc_name, **_walls(res)})
        if log:
            print(f"sequential {sc_name}: {per_scenario[-1]['wall_s']}s "
                  f"(exec {per_scenario[-1]['exec_s']}s)", flush=True)

    gres = _grid_fleet(task, scenario_names, schemes, run_cfg, seeds, **kw)
    cells = len(scenario_names) * len(schemes) * num_seeds
    grid = {**_walls(gres)}
    if placement is not None and hasattr(placement, "_pad"):
        grid["padded_frac"] = round(placement._pad(cells)[1], 6)
    if log:
        print(f"grid [{len(scenario_names)}x{len(schemes)}x{num_seeds}]: "
              f"{grid['wall_s']}s (exec {grid['exec_s']}s)", flush=True)

    c1 = _grid_fleet(task, scenario_names[:1], schemes, run_cfg, seeds,
                     **kw)
    c1_bitwise = _results_bitwise(c1, seq_first)

    seq_total = round(sum(r["wall_s"] for r in per_scenario), 2)
    report = {
        "config": {"task": task.name, "scenarios": list(scenario_names),
                   "schemes": list(schemes), "num_seeds": num_seeds,
                   "num_rounds": num_rounds, "eval_every": eval_every,
                   "batch_size": batch_size, "seed": seed, "cells": cells,
                   "placement": (placement.describe(cells=cells)
                                 if placement is not None else "vmap"),
                   "device_count": jax.device_count(),
                   "backend": jax.default_backend()},
        "sequential": {"per_scenario": per_scenario, "total_s": seq_total},
        "grid": grid,
        "speedup": {
            "grid_vs_sequential": round(
                seq_total / max(grid["wall_s"], 1e-9), 2),
            "exec_grid_vs_sequential": round(
                sum(r["exec_s"] for r in per_scenario)
                / max(grid["exec_s"], 1e-9), 2)},
        "c1_slice_bitwise": c1_bitwise,
    }
    if rss_probe:
        report["carry_donation"] = _run_rss_probe(
            task.name, scenario_names, min(num_rounds, 10), seed,
            num_seeds)
    if log:
        print(f"sequential total {seq_total}s vs grid {grid['wall_s']}s "
              f"({report['speedup']['grid_vs_sequential']}x); "
              f"C=1 slice bitwise: {c1_bitwise}", flush=True)
    if not c1_bitwise:
        raise RuntimeError("C=1 grid slice is NOT bitwise the "
                           "per-scenario fleet — grid semantics broken")
    if write_bench:
        from benchmarks.fig2 import _merge_benchmark_json, \
            write_bench_summary
        _merge_benchmark_json(task, {"scenario_grid": report})
        write_bench_summary(task)
    return report


def _fmt(v):
    if isinstance(v, float):
        return f"{v:.4g}"
    return str(v)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--all", action="store_true",
                    help="sweep every registered scenario")
    ap.add_argument("--train", action="store_true",
                    help="also run short FL training per (scenario, scheme)")
    ap.add_argument("--task", default="paper_mlp",
                    help="registered workload for --train "
                         "(paper_mlp | cifar_conv; DESIGN.md §Tasks)")
    ap.add_argument("--sharded", action="store_true",
                    help="shard each scenario's scheme grid over the "
                         "('data', 'model') debug mesh (needs >= 4 devices)")
    ap.add_argument("--grid", action="store_true",
                    help="with --train: benchmark sequential-per-scenario "
                         "fleets vs ONE compiled [C x K x S] grid and "
                         "record the scenario_grid BENCH section")
    ap.add_argument("--grid-seeds", type=int, default=2,
                    help="seed-axis width S of the --grid fleet")
    ap.add_argument("--scenarios", default=None,
                    help="comma-separated scenario names (overrides the "
                         "default sweep grid / --all)")
    ap.add_argument("--no-rss-probe", action="store_true",
                    help="skip the donate/no-donate peak-RSS subprocess "
                         "probe under --grid")
    ap.add_argument("--rss-probe", choices=("donate", "nodonate"),
                    default=None, help=argparse.SUPPRESS)  # probe child
    ap.add_argument("--rounds", type=int, default=100)
    ap.add_argument("--batch-size", type=int, default=None,
                    help="minibatch size for --train (0 = full batch; "
                         "default = the task's preferred size)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--coordinator", default=None, metavar="HOST:PORT",
                    help="jax.distributed coordinator address; joins a "
                         "multi-process cluster and runs only this "
                         "process's slice of the scenario axis")
    ap.add_argument("--num-processes", type=int, default=None)
    ap.add_argument("--process-id", type=int, default=None)
    ap.add_argument("--local-devices", type=int, default=None,
                    help="force N host-platform (CPU) devices per process "
                         "(multi-process CPU smoke)")
    args = ap.parse_args(argv)
    if args.sharded and not args.train:
        raise SystemExit("--sharded shards the training fleets; "
                         "pass --train with it")
    if args.grid and not args.train:
        raise SystemExit("--grid benchmarks the training fleets; "
                         "pass --train with it")

    names = scn.scenario_names() if args.all else scn.SWEEP_FAMILIES
    if args.scenarios:
        names = tuple(s.strip() for s in args.scenarios.split(","))

    if args.rss_probe:        # subprocess child of grid_sweep's RSS probe
        _rss_probe_child(args.task, names, SCHEMES, args.rounds, args.seed,
                         args.grid_seeds, donate=args.rss_probe == "donate")
        return

    process_id = 0
    if args.coordinator:
        from repro import distributed as dist
        if args.num_processes is None or args.process_id is None:
            raise SystemExit("--coordinator needs --num-processes and "
                             "--process-id")
        nproc, ndev = dist.initialize_multiprocess(
            args.coordinator, args.num_processes, args.process_id,
            local_device_count=args.local_devices)
        process_id = args.process_id
        sl = dist.process_grid_slice(len(names))
        print(f"process {process_id}/{nproc} ({ndev} local devices): "
              f"scenarios {list(names[sl])}", flush=True)
        names = tuple(names[sl])

    rows = sweep(names, seed=args.seed)
    cols = ("scenario", "scheme", "bias", "variance", "objective",
            "p_spread", "mean_participation", "gain_spread_db")
    print(",".join(cols))
    for r in rows:
        print(",".join(_fmt(r[c]) for c in cols), flush=True)

    if args.train:
        placement = None
        if args.sharded:
            from benchmarks.fig2 import _sharded_placement
            placement = _sharded_placement()
        if args.grid:
            grid_sweep(names, num_rounds=min(args.rounds, 40),
                       seed=args.seed, num_seeds=args.grid_seeds,
                       batch_size=args.batch_size, placement=placement,
                       task=args.task, rss_probe=not args.no_rss_probe,
                       write_bench=process_id == 0)
        trows = train_sweep(names, num_rounds=args.rounds, seed=args.seed,
                            batch_size=args.batch_size,
                            placement=placement, task=args.task)
        print("scenario,scheme,final_acc,rounds")
        for r in trows:
            print(f"{r['scenario']},{r['scheme']},{r['final_acc']},"
                  f"{r['rounds']}", flush=True)
        rows = {"theory": rows, "train": trows}
    if process_id != 0:
        return           # multi-process: only process 0 owns the artifacts
    os.makedirs(ARTIFACT_DIR, exist_ok=True)
    with open(os.path.join(ARTIFACT_DIR,
                           f"sweep_seed{args.seed}.json"), "w") as f:
        json.dump(rows, f, indent=1)


if __name__ == "__main__":
    main()
