"""Benchmark harness (deliverable d): one entry per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--fig2-rounds N] [--skip-fig2]

Emits ``name,us_per_call,derived`` CSV rows per the repo convention, plus a
human-readable summary.  Roofline rows appear when experiments/dryrun/
artifacts exist (produced by repro.launch.dryrun).
"""
from __future__ import annotations

import argparse
import time


def _csv(row: dict) -> str:
    name = row.pop("bench", None) or row.pop("scheme", None) \
        or f"{row.pop('arch', '?')}_{row.pop('shape', '')}"
    us = row.pop("us_per_call", "")
    derived = ";".join(f"{k}={v}" for k, v in row.items())
    return f"{name},{us},{derived}"


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fig2-rounds", type=int, default=150)
    ap.add_argument("--fig2-every", type=int, default=15)
    ap.add_argument("--skip-fig2", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    print("bench,us_per_call,derived")

    # --- SCA solver quality/timing (paper §III-B) ---
    from benchmarks import sca_bench
    for row in sca_bench.run(num_seeds=3, sizes=(10, 20)):
        print(_csv(row), flush=True)

    # --- bias-variance trade-off sweep (paper §III-A / Theorem 1) ---
    for row in sca_bench.tradeoff_sweep():
        print(_csv(row), flush=True)

    # --- Theorem-1 bound decomposition ---
    for row in sca_bench.bound_decomposition():
        print(_csv(row), flush=True)

    # --- scenario-family sweep (DESIGN.md §Scenarios) ---
    from benchmarks import scenario_sweep
    for row in scenario_sweep.sweep():
        row["bench"] = f"scenario_{row.pop('scenario')}_{row.pop('scheme')}"
        for k in ("bias", "variance", "var_transmission", "var_noise",
                  "objective", "p_spread", "mean_participation",
                  "gain_spread_db"):
            row[k] = f"{row[k]:.4g}"
        print(_csv(row), flush=True)

    # --- kernel micro-benches ---
    from benchmarks import kernel_bench
    for row in kernel_bench.run():
        print(_csv(row), flush=True)

    # --- Fig. 2 reproduction (the paper's main experiment) ---
    if not args.skip_fig2:
        from benchmarks import fig2
        t0 = time.time()
        hist = fig2.run(num_rounds=args.fig2_rounds,
                        eval_every=args.fig2_every, seed=args.seed)
        wall = time.time() - t0
        for row in fig2.summarize(hist):
            row["bench"] = "fig2_" + row.pop("scheme")
            print(_csv(row), flush=True)
        print(f"# fig2 wall time: {wall:.1f}s", flush=True)

    # --- roofline terms from dry-run artifacts (if present) ---
    from benchmarks import roofline
    rows = roofline.run()
    for row in rows:
        row["bench"] = f"roofline_{row.pop('arch')}_{row.pop('shape')}"
        for k in ("compute_s", "memory_s", "collective_s",
                  "model_flops_per_device"):
            row[k] = f"{row[k]:.4g}"
        row["useful_flops_ratio"] = f"{row['useful_flops_ratio']:.3f}"
        print(_csv(row), flush=True)
    if not rows:
        print("# no dryrun artifacts yet — run: "
              "PYTHONPATH=src python -m repro.launch.dryrun --all",
              flush=True)


if __name__ == "__main__":
    main()
