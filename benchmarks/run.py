"""Benchmark harness (deliverable d): one entry per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--fig2-rounds N] [--skip-fig2]
    PYTHONPATH=src python -m benchmarks.run --smoke

Emits ``name,us_per_call,derived`` CSV rows per the repo convention, plus a
human-readable summary.  Roofline rows appear when experiments/dryrun/
artifacts exist (produced by repro.launch.dryrun).

``--smoke`` is the CI engine-regression gate: it drives the scan/vmap
experiment engine end to end on CPU in a couple of minutes — the full
7-scheme fig2 fleet for a handful of minibatch rounds plus a short
scenario-sweep training fleet — and fails loudly if the compiled engine
stops producing finite, learning trajectories.
"""
from __future__ import annotations

import argparse
import time


def _csv(row: dict) -> str:
    name = row.pop("bench", None) or row.pop("scheme", None) \
        or f"{row.pop('arch', '?')}_{row.pop('shape', '')}"
    us = row.pop("us_per_call", "")
    derived = ";".join(f"{k}={v}" for k, v in row.items())
    return f"{name},{us},{derived}"


def smoke(seed: int = 0) -> None:
    """Minutes-scale engine smoke: compiled fig2 fleet + scenario fleet."""
    import numpy as np

    from benchmarks import fig2, scenario_sweep

    print("bench,us_per_call,derived")
    t0 = time.time()
    hist = fig2.run(num_rounds=8, eval_every=4, seed=seed, batch_size=64,
                    save=False)
    assert set(hist) == set(fig2.SCHEMES), sorted(hist)
    for name, rows in hist.items():
        accs = [r["acc"] for r in rows]
        assert np.all(np.isfinite(accs)), (name, accs)
        assert rows[-1]["active"] >= 1.0, (name, rows[-1])
        print(_csv({"bench": f"smoke_fig2_{name}",
                    "final_acc": round(accs[-1], 4)}), flush=True)
    print(f"# smoke fig2 fleet (7 schemes x 8 rounds): "
          f"{time.time() - t0:.1f}s", flush=True)

    t0 = time.time()
    rows = scenario_sweep.train_sweep(
        scenario_names=("disk_rayleigh", "disk_markov"), num_rounds=4,
        eval_every=2, seed=seed, batch_size=64)
    for r in rows:
        assert np.isfinite(r["final_acc"]), r
        print(_csv({"bench": f"smoke_{r['scenario']}_{r['scheme']}",
                    "final_acc": r["final_acc"]}), flush=True)
    print(f"# smoke scenario fleets: {time.time() - t0:.1f}s", flush=True)

    # --- batched SCA solver + AdaptiveSCA engine gate (DESIGN.md §Solvers):
    # a tiny batch solve must track the scipy oracle, and the adaptive
    # scheme must re-design inside a compiled Gauss-Markov fleet ---
    t0 = time.time()
    from repro import solvers
    from repro.core import sca as sca_mod, theory
    from benchmarks.sca_bench import make_prm as solver_prm
    prms = [solver_prm(6, s) for s in range(4)]
    br = solvers.solve_batch(prms)
    ref = sca_mod.solve_sca(prms[0]).objective
    gap = br.objective[0] / ref - 1.0
    assert abs(gap) < 1e-3, (br.objective[0], ref)
    assert np.all(np.isfinite(br.gamma)) and np.all(br.gamma > 0)
    print(_csv({"bench": "smoke_solver_batch4", "gap_vs_scipy": f"{gap:.2e}",
                "objective": round(float(br.objective[0]), 4)}), flush=True)

    import jax
    from repro.core import power_control as pcm, scenarios as scn
    from repro.data import partition, synthetic
    from repro.fl import engine as eng
    from repro.fl.server import FLRunConfig
    from repro.models import mlp
    from repro.models.param import init_params
    sc = scn.get_scenario("disk_markov")
    dep = scn.realize(sc)
    prm = scn.make_ota_params(dep, d=10000, gmax=10.0, eta=0.05, kappa_sq=4.0)
    fp = scn.make_fading_process(dep, sc.dynamics)
    x, y, xt, yt = synthetic.mnist_like(40, seed=seed)
    data = partition.stack_shards(partition.partition_by_label(x, y, 10,
                                                               seed=seed))
    params0 = init_params(mlp.mlp_defs(hidden=32), jax.random.PRNGKey(seed))
    run_cfg = FLRunConfig(eta=0.05, num_rounds=4, eval_every=2)
    pc = pcm.make_power_control("adaptive_sca", dep, prm)
    res = eng.run_fleet(mlp.mlp_loss, params0, [pc], dep.gains, data,
                        run_cfg, fading=fp, flat=False)
    assert res.designs is not None and len(res.designs) >= 2, res.designs
    g0, g1 = res.designs[0][1], res.designs[1][1]
    moved = float(np.max(np.abs(g1 - g0) / np.abs(g0)))
    assert moved > 1e-4, "adaptive re-design did not move the design"
    assert all(np.all(np.isfinite(np.asarray(v))) for v in
               jax.tree.leaves(res.params))
    print(_csv({"bench": "smoke_adaptive_sca",
                "design_moved_rel": round(moved, 4),
                "redesigns": len(res.designs) - 1}), flush=True)
    print(f"# smoke solver + adaptive engine: {time.time() - t0:.1f}s",
          flush=True)

    # --- task-registry gate (DESIGN.md §Tasks): grow a few-round
    # cifar_conv fleet through the fleet stack INCLUDING a kill-and-resume
    # step; on the forced >= 4-device mesh (the CI tasks-smoke job) the
    # grid shards over the debug mesh, otherwise it runs vmapped ---
    import os
    import tempfile

    from repro import tasks
    from repro.fl.driver import run_fleet_task

    t0 = time.time()
    task = tasks.get("cifar_conv", channels=(8, 16), hidden=32,
                     samples_per_class=24, test_per_class=10, alpha=1.0)
    dep_t, prm_t, td = fig2.build_world(task, seed=seed)
    pcs_t = fig2.make_schemes(task, dep_t, prm_t, ["ideal", "sca"])
    run_cfg = task.run_config(num_rounds=6, eval_every=2, batch_size=4,
                              seed=seed)
    placement, where = None, "vmap"
    if jax.device_count() >= 4:
        from repro.fl.placement import ShardedPlacement
        from repro.launch.mesh import make_debug_mesh
        placement = ShardedPlacement(make_debug_mesh(2, 2))
        where = f"sharded{placement.num_devices}"
    kw = dict(task_data=td, seeds=(0, 1), flat=True, placement=placement)
    with tempfile.TemporaryDirectory() as tmp:
        ck = os.path.join(tmp, "cifar_fleet")
        res_part = run_fleet_task(task, pcs_t, dep_t.gains, run_cfg, **kw,
                                  checkpoint_path=ck, max_chunks=1)  # kill
        rounds_part = res_part.traces["active_devices"].shape[-1]
        assert rounds_part < run_cfg.num_rounds, rounds_part
        res_res = run_fleet_task(task, pcs_t, dep_t.gains, run_cfg, **kw,
                                 checkpoint_path=ck, resume=True)
        res_full = run_fleet_task(task, pcs_t, dep_t.gains, run_cfg, **kw)
    assert all(np.array_equal(np.asarray(a), np.asarray(b))
               for a, b in zip(jax.tree.leaves(res_res.params),
                               jax.tree.leaves(res_full.params))), \
        "cifar_conv resume is not bitwise vs the uninterrupted fleet"
    final_acc = np.asarray(res_res.evals[-1][1]["acc"])
    assert final_acc.shape == (2, 2) and np.all(np.isfinite(final_acc))
    print(_csv({"bench": f"smoke_cifar_conv_{where}",
                "final_acc_ideal": round(float(final_acc[0].mean()), 4),
                "resumed_rounds_done": rounds_part,
                "resume_bitwise": 1}), flush=True)
    print(f"# smoke cifar_conv task fleet ({where}, kill+resume): "
          f"{time.time() - t0:.1f}s", flush=True)
    print("# smoke OK", flush=True)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fig2-rounds", type=int, default=150)
    ap.add_argument("--fig2-every", type=int, default=15)
    ap.add_argument("--skip-fig2", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="CI gate: short compiled-engine runs, asserts")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    if args.smoke:
        smoke(seed=args.seed)
        return

    print("bench,us_per_call,derived")

    # --- SCA solver quality/timing (paper §III-B) ---
    from benchmarks import sca_bench
    for row in sca_bench.run(num_seeds=3, sizes=(10, 20)):
        print(_csv(row), flush=True)

    # --- scipy-vs-batched-solver benchmark (DESIGN.md §Solvers); persists
    # experiments/sca/solver_benchmark.json ---
    for row in sca_bench.solver_rows(sca_bench.solver_benchmark()):
        print(_csv(row), flush=True)

    # --- bias-variance trade-off sweep (paper §III-A / Theorem 1) ---
    for row in sca_bench.tradeoff_sweep():
        print(_csv(row), flush=True)

    # --- Theorem-1 bound decomposition ---
    for row in sca_bench.bound_decomposition():
        print(_csv(row), flush=True)

    # --- scenario-family sweep (DESIGN.md §Scenarios) ---
    from benchmarks import scenario_sweep
    for row in scenario_sweep.sweep():
        row["bench"] = f"scenario_{row.pop('scenario')}_{row.pop('scheme')}"
        for k in ("bias", "variance", "var_transmission", "var_noise",
                  "objective", "p_spread", "mean_participation",
                  "gain_spread_db"):
            row[k] = f"{row[k]:.4g}"
        print(_csv(row), flush=True)

    # --- kernel micro-benches ---
    from benchmarks import kernel_bench
    for row in kernel_bench.run():
        print(_csv(row), flush=True)

    # --- Fig. 2 reproduction (the paper's main experiment): the whole
    # scheme grid through one compiled scan program (fl.engine) ---
    if not args.skip_fig2:
        from benchmarks import fig2
        t0 = time.time()
        hist = fig2.run(num_rounds=args.fig2_rounds,
                        eval_every=args.fig2_every, seed=args.seed)
        wall = time.time() - t0
        for row in fig2.summarize(hist):
            row["bench"] = "fig2_" + row.pop("scheme")
            print(_csv(row), flush=True)
        print(f"# fig2 wall time: {wall:.1f}s", flush=True)

    # --- roofline terms from dry-run artifacts (if present) ---
    from benchmarks import roofline
    rows = roofline.run()
    for row in rows:
        row["bench"] = f"roofline_{row.pop('arch')}_{row.pop('shape')}"
        for k in ("compute_s", "memory_s", "collective_s",
                  "model_flops_per_device"):
            row[k] = f"{row[k]:.4g}"
        row["useful_flops_ratio"] = f"{row['useful_flops_ratio']:.3f}"
        print(_csv(row), flush=True)
    if not rows:
        print("# no dryrun artifacts yet — run: "
              "PYTHONPATH=src python -m repro.launch.dryrun --all",
              flush=True)


if __name__ == "__main__":
    main()
