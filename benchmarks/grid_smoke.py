"""2-process ``jax.distributed`` bring-up smoke on forced-CPU devices.

    PYTHONPATH=src python -m benchmarks.grid_smoke --launch

The CI proof of the multi-process story (DESIGN.md §Grid): the parent
picks a free coordinator port and spawns 2 worker processes, each of
which

  1. joins the cluster via ``distributed.initialize_multiprocess``
     (forced to 4 local host-platform devices) and verifies the global
     view: 2 processes, 8 global devices;
  2. runs its ``distributed.process_grid_slice`` slice of the scenario
     axis as one compiled [C_slice x K x S] grid on a mesh of its LOCAL
     devices — on the CPU backend one XLA computation cannot span
     processes, so process-sliced execution IS the bring-up contract;
  3. runs a shared C=1 CANARY grid (same scenario, same config on every
     process) and exchanges result digests through the coordination
     service's key-value store (``kv_put``/``kv_get``): bitwise-equal
     canary digests prove the processes compute identical fleets, so
     their disjoint slices compose into one deterministic sweep.

Workers exit non-zero on any mismatch; the parent propagates failure.
"""
from __future__ import annotations

import argparse
import hashlib
import json
import os
import socket
import subprocess
import sys

import numpy as np

ROOT = os.path.join(os.path.dirname(__file__), "..")
SCENARIOS = ("disk_rayleigh", "disk_rician", "disk_markov", "disk_dropout")
SCHEMES = ("sca", "zero_bias")
SEEDS = (0, 1)
NUM_ROUNDS = 4
CANARY = SCENARIOS[0]


def _free_port() -> int:
    s = socket.socket()
    s.bind(("127.0.0.1", 0))
    port = s.getsockname()[1]
    s.close()
    return port


# ---------------------------------------------------------------------------
# worker: everything below runs AFTER initialize_multiprocess
# ---------------------------------------------------------------------------

def _local_mesh():
    """2x2 ("data", "model") mesh of this process's LOCAL devices —
    jax.make_mesh would grab the global device list, which the CPU
    backend cannot run one computation across."""
    import jax
    from jax.sharding import Mesh

    local = jax.local_devices()
    if len(local) < 4:
        raise SystemExit(f"need 4 local devices, have {len(local)}")
    return Mesh(np.asarray(local[:4]).reshape(2, 2), ("data", "model"))


def _world(seed: int = 0):
    """Tiny 10-device MLP world (the test-suite grid world, shrunk for a
    CI smoke)."""
    import jax
    import jax.numpy as jnp

    from repro.data import partition, synthetic
    from repro.fl.server import FLRunConfig
    from repro.models import mlp
    from repro.models.param import init_params

    x, y, xt, yt = synthetic.mnist_like(40, seed=seed)
    data = partition.stack_shards(partition.partition_by_label(
        x, y, 10, seed=seed))
    params0 = init_params(mlp.mlp_defs(hidden=16), jax.random.PRNGKey(seed))
    xt_j, yt_j = jnp.asarray(xt), jnp.asarray(yt)
    ev = jax.jit(lambda p: {"acc": mlp.accuracy(p, xt_j, yt_j)})
    run = FLRunConfig(eta=0.05, num_rounds=NUM_ROUNDS, eval_every=2,
                      seed=seed, batch_size=0)
    return data, params0, ev, run


def _run_grid(world, names, placement=None):
    from repro.core import power_control as pcm
    from repro.core import scenarios as scn
    from repro.fl.driver import run_fleet
    from repro.models import mlp

    data, params0, ev, run = world
    stack = scn.stack_scenarios(names, seed=0)
    pcs = []
    for name in names:
        dep = scn.realize(scn.get_scenario(name), seed=0)
        prm = scn.make_ota_params(dep, d=10000, gmax=10.0, eta=run.eta,
                                  kappa_sq=4.0)
        pcs.extend(pcm.make_power_control(s, dep, prm) for s in SCHEMES)
    return run_fleet(mlp.mlp_loss, params0, pcs, None, data, run, ev,
                     etas=[run.eta] * len(pcs), seeds=SEEDS, flat=True,
                     scenarios=stack, placement=placement)


def _digest(res) -> str:
    import jax

    h = hashlib.sha1()
    for leaf in jax.tree.leaves(res.params):
        h.update(np.asarray(leaf).tobytes())
    for t in sorted(res.traces):
        h.update(np.asarray(res.traces[t]).tobytes())
    return h.hexdigest()


def worker(args) -> None:
    from repro import distributed as dist
    from repro.fl.placement import ShardedPlacement

    nproc, ndev = dist.initialize_multiprocess(
        args.coordinator, args.num_processes, args.process_id,
        local_device_count=args.local_devices)
    import jax

    me = args.process_id
    print(f"[p{me}] joined: {nproc} processes, {ndev} local / "
          f"{jax.device_count()} global devices", flush=True)
    if nproc != args.num_processes or ndev != args.local_devices:
        raise SystemExit(f"[p{me}] cluster view wrong: {nproc} processes, "
                         f"{ndev} local devices")

    world = _world()
    placement = ShardedPlacement(_local_mesh())

    sl = dist.process_grid_slice(len(SCENARIOS))
    mine = SCENARIOS[sl]
    res = _run_grid(world, mine, placement=placement)
    slice_digest = _digest(res)
    dist.kv_put(f"slice/{me}", json.dumps(
        {"scenarios": list(mine), "digest": slice_digest,
         "cells": len(mine) * len(SCHEMES) * len(SEEDS)}))
    print(f"[p{me}] slice {list(mine)}: {slice_digest[:12]}", flush=True)

    canary = _run_grid(world, (CANARY,), placement=placement)
    mine_d = _digest(canary)
    dist.kv_put(f"canary/{me}", mine_d)
    for j in range(nproc):
        theirs = dist.kv_get(f"canary/{j}", timeout_s=120.0)
        if theirs != mine_d:
            raise SystemExit(f"[p{me}] canary digest mismatch vs p{j}: "
                             f"{mine_d[:12]} != {theirs[:12]}")
    print(f"[p{me}] canary bitwise across {nproc} processes: "
          f"{mine_d[:12]}", flush=True)

    if me == 0:       # gather the slice record: the composed sweep proof
        slices = [json.loads(dist.kv_get(f"slice/{j}", timeout_s=120.0))
                  for j in range(nproc)]
        covered = [s for rec in slices for s in rec["scenarios"]]
        if covered != list(SCENARIOS):
            raise SystemExit(f"[p0] slices {covered} do not compose the "
                             f"scenario axis {list(SCENARIOS)}")
        print(f"[p0] {len(SCENARIOS)} scenarios covered by {nproc} "
              f"disjoint process slices; "
              f"{sum(r['cells'] for r in slices)} cells total", flush=True)


# ---------------------------------------------------------------------------
# parent
# ---------------------------------------------------------------------------

def launch(num_processes: int = 2, local_devices: int = 4,
           timeout_s: float = 900.0) -> None:
    port = _free_port()
    env = dict(os.environ)
    # each worker forces its OWN device count via --local-devices; a
    # parent-level forced count would leak into both
    env.pop("XLA_FLAGS", None)
    procs = []
    for i in range(num_processes):
        cmd = [sys.executable, "-m", "benchmarks.grid_smoke",
               "--coordinator", f"127.0.0.1:{port}",
               "--num-processes", str(num_processes),
               "--process-id", str(i),
               "--local-devices", str(local_devices)]
        procs.append(subprocess.Popen(cmd, cwd=ROOT, env=env,
                                      stdout=subprocess.PIPE,
                                      stderr=subprocess.STDOUT, text=True))
    rc = 0
    for i, p in enumerate(procs):
        try:
            out, _ = p.communicate(timeout=timeout_s)
        except subprocess.TimeoutExpired:
            p.kill()
            out, _ = p.communicate()
            out += f"\n[p{i}] TIMEOUT after {timeout_s}s"
            rc = 1
        sys.stdout.write(out)
        rc = rc or p.returncode
    if rc:
        raise SystemExit(f"grid smoke FAILED (rc={rc})")
    print(f"grid smoke OK: {num_processes} processes x {local_devices} "
          "devices, process-sliced scenario grid + bitwise canary")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--launch", action="store_true",
                    help="spawn the workers and wait (the CI entry point)")
    ap.add_argument("--coordinator", default=None, metavar="HOST:PORT")
    ap.add_argument("--num-processes", type=int, default=2)
    ap.add_argument("--process-id", type=int, default=None)
    ap.add_argument("--local-devices", type=int, default=4)
    ap.add_argument("--timeout", type=float, default=900.0)
    args = ap.parse_args(argv)
    if args.launch:
        launch(args.num_processes, args.local_devices, args.timeout)
        return
    if args.coordinator is None or args.process_id is None:
        raise SystemExit("worker mode needs --coordinator and "
                         "--process-id (or pass --launch)")
    worker(args)


if __name__ == "__main__":
    main()
