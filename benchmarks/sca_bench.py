"""SCA power-control benchmarks: solution quality, convergence, timing."""
from __future__ import annotations

import time

import numpy as np

from repro.core import channel, sca, theory
from repro.core.theory import OTAParams


def make_prm(n: int, seed: int, d: int = 814090) -> OTAParams:
    wcfg = channel.WirelessConfig(num_devices=n, seed=seed)
    dep = channel.deploy(wcfg)
    return OTAParams(d=d, gmax=10.0, es=wcfg.energy_per_sample,
                     n0=wcfg.noise_psd, gains=dep.gains,
                     sigma_sq=np.zeros(n), eta=0.05, lsmooth=1.0,
                     kappa_sq=4.0)


def run(num_seeds: int = 5, sizes=(10, 20, 50)) -> list:
    rows = []
    for n in sizes:
        gaps, iters, times, vs_zb = [], [], [], []
        for seed in range(num_seeds):
            prm = make_prm(n, seed)
            t0 = time.time()
            res = sca.solve_sca(prm)
            dt = time.time() - t0
            oracle = sca.solve_direct(prm, num_starts=6, seed=seed)
            zb = theory.p1_objective(theory.zero_bias_gamma(prm), prm)
            gaps.append(res.objective / max(oracle.objective, 1e-30) - 1.0)
            vs_zb.append(res.objective / zb)
            iters.append(res.iterations)
            times.append(dt)
        rows.append({
            "bench": f"sca_n{n}",
            "us_per_call": round(np.mean(times) * 1e6, 1),
            "iters_mean": round(float(np.mean(iters)), 1),
            "gap_vs_oracle_max": round(float(np.max(gaps)), 5),
            "objective_vs_zero_bias": round(float(np.mean(vs_zb)), 4),
        })
    return rows


def tradeoff_sweep(n: int = 10, seed: int = 0, points: int = 9) -> list:
    """Bias-variance decomposition along gamma = f * gamma_max (paper §III-A
    discussion): noise falls and bias rises as f grows."""
    prm = make_prm(n, seed)
    gm = theory.gamma_max(prm)
    rows = []
    for f in np.linspace(0.2, 1.0, points):
        gamma = f * gm
        z = theory.zeta_terms(gamma, prm)
        _, _, p = theory.participation(gamma, prm)
        rows.append({
            "bench": f"tradeoff_f{f:.2f}",
            "noise_var": z["noise"],
            "tx_var": z["transmission"],
            "bias": theory.bias_term(p, prm),
            "objective": theory.p1_objective(gamma, prm),
        })
    return rows


def bound_decomposition(n: int = 10, seed: int = 0,
                        rounds=(50, 200, 1000)) -> list:
    """Theorem-1 bound components for the SCA and zero-bias designs."""
    prm = make_prm(n, seed)
    res = sca.solve_sca(prm)
    rows = []
    for name, gamma in [("sca", res.gamma),
                        ("zero_bias", theory.zero_bias_gamma(prm))]:
        for t in rounds:
            b = theory.theorem1_bound(gamma, prm, init_gap=5.0, num_rounds=t)
            rows.append({
                "bench": f"bound_{name}_T{t}",
                "optimization": round(b["optimization"], 4),
                "variance": round(b["variance"], 4),
                "bias": round(b["bias"], 6),
                "total": round(b["total"], 4),
            })
    return rows
