"""SCA power-control benchmarks: solution quality, convergence, timing.

``solver_benchmark`` compares the host scipy SLSQP loop (``core.sca``)
against the compiled batched solver (``repro.solvers``) across device
counts and scenario-batch sizes, and persists the rows to
``experiments/sca/solver_benchmark.json`` — the BENCH trajectory for the
solver subsystem (acceptance: the 64-scenario batch solve is >= 10x faster
than the looped scipy baseline at matching objective quality).
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from repro.core import channel, sca, theory
from repro.core.theory import OTAParams

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                            "sca")


def make_prm(n: int, seed: int, d: int = 814090) -> OTAParams:
    wcfg = channel.WirelessConfig(num_devices=n, seed=seed)
    dep = channel.deploy(wcfg)
    return OTAParams(d=d, gmax=10.0, es=wcfg.energy_per_sample,
                     n0=wcfg.noise_psd, gains=dep.gains,
                     sigma_sq=np.zeros(n), eta=0.05, lsmooth=1.0,
                     kappa_sq=4.0)


def run(num_seeds: int = 5, sizes=(10, 20, 50)) -> list:
    rows = []
    for n in sizes:
        gaps, iters, times, vs_zb = [], [], [], []
        for seed in range(num_seeds):
            prm = make_prm(n, seed)
            t0 = time.time()
            res = sca.solve_sca(prm)
            dt = time.time() - t0
            oracle = sca.solve_direct(prm, num_starts=6, seed=seed)
            zb = theory.p1_objective(theory.zero_bias_gamma(prm), prm)
            gaps.append(res.objective / max(oracle.objective, 1e-30) - 1.0)
            vs_zb.append(res.objective / zb)
            iters.append(res.iterations)
            times.append(dt)
        rows.append({
            "bench": f"sca_n{n}",
            "us_per_call": round(np.mean(times) * 1e6, 1),
            "iters_mean": round(float(np.mean(iters)), 1),
            "gap_vs_oracle_max": round(float(np.max(gaps)), 5),
            "objective_vs_zero_bias": round(float(np.mean(vs_zb)), 4),
        })
    return rows


def solver_benchmark(sizes=(10, 20, 50), batches=(1, 16, 64),
                     save: bool = True) -> dict:
    """scipy ``solve_sca`` loop vs compiled ``solvers.solve_batch``.

    Per device count: the objective gap on the reference scenario and
    per-batch wall clocks (compile excluded for the jax path — recorded
    separately — since the executable is reused across rounds/sweeps; the
    scipy baseline pays its full cost every call and is timed as such).
    Writes ``experiments/sca/solver_benchmark.json``.
    """
    from repro import solvers

    out = {"sizes": [], "config": dataclasses_asdict(solvers.DEFAULT_CONFIG)}
    for n in sizes:
        prms = [make_prm(n, seed) for seed in range(max(batches))]
        # objective quality on the reference scenario (seed 0)
        ref = sca.solve_sca(prms[0])
        res = solvers.solve(prms[0])
        row = {
            "num_devices": n,
            "scipy_objective": ref.objective,
            "jax_objective": res.objective,
            "objective_rel_gap": res.objective / ref.objective - 1.0,
            "batch": [],
        }
        for b in batches:
            sub = prms[:b]
            t0 = time.time()
            scipy_objs = [sca.solve_sca(p).objective for p in sub]
            t_scipy = time.time() - t0
            t0 = time.time()
            br = solvers.solve_batch(sub)
            t_compile = time.time() - t0       # includes compile on first use
            t0 = time.time()
            br = solvers.solve_batch(sub)
            t_jax = time.time() - t0
            gaps = [theory.p1_objective(br.gamma[i], sub[i])
                    / max(scipy_objs[i], 1e-30) - 1.0 for i in range(b)]
            row["batch"].append({
                "batch_size": b,
                "scipy_loop_s": round(t_scipy, 4),
                "jax_batch_s": round(t_jax, 4),
                "jax_first_call_s": round(t_compile, 4),
                "speedup": round(t_scipy / max(t_jax, 1e-9), 2),
                "objective_rel_gap_max": float(np.max(gaps)),
            })
        out["sizes"].append(row)
    if save:
        os.makedirs(ARTIFACT_DIR, exist_ok=True)
        path = os.path.join(ARTIFACT_DIR, "solver_benchmark.json")
        with open(path, "w") as f:
            json.dump(out, f, indent=2)
        print(f"# wrote {os.path.relpath(path)}")
    return out


def dataclasses_asdict(cfg) -> dict:
    import dataclasses
    return {k: (list(v) if isinstance(v, tuple) else v)
            for k, v in dataclasses.asdict(cfg).items()}


def solver_rows(result: dict) -> list:
    """Flatten solver_benchmark output into the repo's CSV row convention."""
    rows = []
    for size in result["sizes"]:
        n = size["num_devices"]
        for b in size["batch"]:
            rows.append({
                "bench": f"sca_solver_n{n}_b{b['batch_size']}",
                "us_per_call": round(b["jax_batch_s"] * 1e6
                                     / b["batch_size"], 1),
                "scipy_loop_s": b["scipy_loop_s"],
                "jax_batch_s": b["jax_batch_s"],
                "speedup": b["speedup"],
                "gap_max": f"{b['objective_rel_gap_max']:.2e}",
            })
    return rows


def tradeoff_sweep(n: int = 10, seed: int = 0, points: int = 9) -> list:
    """Bias-variance decomposition along gamma = f * gamma_max (paper §III-A
    discussion): noise falls and bias rises as f grows."""
    prm = make_prm(n, seed)
    gm = theory.gamma_max(prm)
    rows = []
    for f in np.linspace(0.2, 1.0, points):
        gamma = f * gm
        z = theory.zeta_terms(gamma, prm)
        _, _, p = theory.participation(gamma, prm)
        rows.append({
            "bench": f"tradeoff_f{f:.2f}",
            "noise_var": z["noise"],
            "tx_var": z["transmission"],
            "bias": theory.bias_term(p, prm),
            "objective": theory.p1_objective(gamma, prm),
        })
    return rows


def bound_decomposition(n: int = 10, seed: int = 0,
                        rounds=(50, 200, 1000)) -> list:
    """Theorem-1 bound components for the SCA and zero-bias designs."""
    prm = make_prm(n, seed)
    res = sca.solve_sca(prm)
    rows = []
    for name, gamma in [("sca", res.gamma),
                        ("zero_bias", theory.zero_bias_gamma(prm))]:
        for t in rounds:
            b = theory.theorem1_bound(gamma, prm, init_gap=5.0, num_rounds=t)
            rows.append({
                "bench": f"bound_{name}_T{t}",
                "optimization": round(b["optimization"], 4),
                "variance": round(b["variance"], 4),
                "bias": round(b["bias"], 6),
                "total": round(b["total"], 4),
            })
    return rows
