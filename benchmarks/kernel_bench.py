"""Kernel micro-benchmarks: wall time of the jnp reference path on CPU (the
Pallas kernels themselves target TPU; interpret mode timing is meaningless,
so we time the production jnp paths and report kernel/oracle agreement)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref


def _time(fn, *args, iters=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.time()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / iters * 1e6


def run() -> list:
    key = jax.random.PRNGKey(0)
    rows = []

    # OTA aggregation at the paper's scale (d = 814,090; N = 10)
    g = jax.random.normal(key, (10, 814_090))
    s = jax.random.uniform(key, (10,))
    z = jax.random.normal(key, (814_090,))
    ns = jnp.float32(0.2)
    t_ref = _time(jax.jit(ref.ota_aggregate_ref), g, s, z, ns)
    out_k = ops.ota_aggregate(g, s, z, ns)
    err = float(jnp.max(jnp.abs(out_k - ref.ota_aggregate_ref(g, s, z, ns))))
    rows.append({"bench": "ota_aggregate_d814k", "us_per_call": round(t_ref, 1),
                 "kernel_max_err": err})

    # blocked attention 2k, window vs full
    q = jax.random.normal(key, (1, 2048, 8, 64), jnp.float32)
    k = jax.random.normal(key, (1, 2048, 2, 64), jnp.float32)
    v = jax.random.normal(key, (1, 2048, 2, 64), jnp.float32)
    fn_full = jax.jit(lambda q, k, v: ref.attention_ref(q, k, v, causal=True))
    rows.append({"bench": "attention_ref_2k_full",
                 "us_per_call": round(_time(fn_full, q, k, v, iters=3), 1)})

    # SSD scan (model path) vs sequential oracle, S=1024
    b, s_, h, p, gsz, n = 1, 1024, 8, 64, 1, 64
    x = jax.random.normal(key, (b, s_, h, p))
    dt = jax.nn.softplus(jax.random.normal(key, (b, s_, h)))
    a_neg = -jnp.exp(jax.random.normal(key, (h,)) * 0.5)
    bm = jax.random.normal(key, (b, s_, gsz, n)) * 0.3
    cm = jax.random.normal(key, (b, s_, gsz, n)) * 0.3
    from repro.models.ssm import ssd_chunked
    f_chunk = jax.jit(lambda *a: ssd_chunked(*a, chunk=128)[0])
    f_seq = jax.jit(ref.ssd_ref)
    t_chunk = _time(f_chunk, x, dt, a_neg, bm, cm, iters=3)
    t_seq = _time(f_seq, x, dt, a_neg, bm, cm, iters=3)
    err = float(jnp.max(jnp.abs(f_chunk(x, dt, a_neg, bm, cm)
                                - f_seq(x, dt, a_neg, bm, cm))))
    rows.append({"bench": "ssd_chunked_1k", "us_per_call": round(t_chunk, 1),
                 "speedup_vs_sequential": round(t_seq / t_chunk, 2),
                 "max_err": err})
    return rows
