"""Kernel micro-benchmarks: wall time of the jnp reference path on CPU (the
Pallas kernels themselves target TPU; interpret mode timing is meaningless,
so we time the production jnp paths and report kernel/oracle agreement).

``round_step_rows`` is the fused-vs-unfused round-step section: the whole
flat round tail (dequantize + OTA superposition + noise + SGD step) as ONE
jit'd expression against the historical four-op chain with ``ghat``
materialized between launches, per uplink dtype (f32/bf16/int8) at the
paper's model scale — the walls and bytes-moved numbers that ride
BENCH_engine.json under "round_step" (schema-checked by
benchmarks.validate_bench).  ``python -m benchmarks.kernel_bench --smoke``
additionally runs the interpret-mode Pallas equivalence gate (CI's
benchmark-smoke job; no pytest needed)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref

UPLINKS = ("f32", "bf16", "int8")
_WIRE_BYTES = {"f32": 4, "bf16": 2, "int8": 1}


def _time(fn, *args, iters=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.time()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / iters * 1e6


def run() -> list:
    key = jax.random.PRNGKey(0)
    rows = []

    # OTA aggregation at the paper's scale (d = 814,090; N = 10)
    g = jax.random.normal(key, (10, 814_090))
    s = jax.random.uniform(key, (10,))
    z = jax.random.normal(key, (814_090,))
    ns = jnp.float32(0.2)
    t_ref = _time(jax.jit(ref.ota_aggregate_ref), g, s, z, ns)
    out_k = ops.ota_aggregate(g, s, z, ns)
    err = float(jnp.max(jnp.abs(out_k - ref.ota_aggregate_ref(g, s, z, ns))))
    rows.append({"bench": "ota_aggregate_d814k", "us_per_call": round(t_ref, 1),
                 "kernel_max_err": err})

    # blocked attention 2k, window vs full
    q = jax.random.normal(key, (1, 2048, 8, 64), jnp.float32)
    k = jax.random.normal(key, (1, 2048, 2, 64), jnp.float32)
    v = jax.random.normal(key, (1, 2048, 2, 64), jnp.float32)
    fn_full = jax.jit(lambda q, k, v: ref.attention_ref(q, k, v, causal=True))
    rows.append({"bench": "attention_ref_2k_full",
                 "us_per_call": round(_time(fn_full, q, k, v, iters=3), 1)})

    # SSD scan (model path) vs sequential oracle, S=1024
    b, s_, h, p, gsz, n = 1, 1024, 8, 64, 1, 64
    x = jax.random.normal(key, (b, s_, h, p))
    dt = jax.nn.softplus(jax.random.normal(key, (b, s_, h)))
    a_neg = -jnp.exp(jax.random.normal(key, (h,)) * 0.5)
    bm = jax.random.normal(key, (b, s_, gsz, n)) * 0.3
    cm = jax.random.normal(key, (b, s_, gsz, n)) * 0.3
    from repro.models.ssm import ssd_chunked
    f_chunk = jax.jit(lambda *a: ssd_chunked(*a, chunk=128)[0])
    f_seq = jax.jit(ref.ssd_ref)
    t_chunk = _time(f_chunk, x, dt, a_neg, bm, cm, iters=3)
    t_seq = _time(f_seq, x, dt, a_neg, bm, cm, iters=3)
    err = float(jnp.max(jnp.abs(f_chunk(x, dt, a_neg, bm, cm)
                                - f_seq(x, dt, a_neg, bm, cm))))
    rows.append({"bench": "ssd_chunked_1k", "us_per_call": round(t_chunk, 1),
                 "speedup_vs_sequential": round(t_seq / t_chunk, 2),
                 "max_err": err})
    return rows


def round_step_rows(n: int = 10, d: int = 814_090, iters: int = 5) -> list:
    """Fused vs unfused round-step walls + bytes moved per uplink dtype.

    The fused side is the production CPU expression behind
    ``ops.ota_round_step_pytree`` (one jit'd dequant→aggregate→noise→step);
    the unfused side is the pre-kernel chain — ``ota_aggregate_ref`` as its
    own launch, ``ghat`` materialized, then the separate SGD-update launch
    — which is exactly the extra HBM round-trip the fusion removes.
    Quantize time is excluded from both: it is device-side work that
    happens before the uplink either way.

    ``uplink_mb`` is what the N devices transmit (the over-the-air win of
    a low-precision wire); ``bytes_moved_mb`` is the receiver-side traffic
    of one fused pass (g + z + params in, params out).
    """
    key = jax.random.PRNGKey(0)
    kg, ks, kz, kp = jax.random.split(key, 4)
    g = jax.random.normal(kg, (n, d))
    s = jax.random.uniform(ks, (n,), minval=0.1, maxval=1.0)
    z = jax.random.normal(kz, (d,))
    p = jax.random.normal(kp, (d,))
    ns, eta = jnp.float32(0.2), jnp.float32(0.05)

    fused = jax.jit(lambda w, qs: ref.ota_round_step_ref(
        w, s, z, ns, p, eta, q_scale=qs))

    agg = jax.jit(lambda w, qs: ref.ota_aggregate_ref(
        ops.dequantize_uplink(w, qs), s, z, ns))

    @jax.jit
    def update(ghat):
        return (p - eta * ghat).astype(p.dtype)

    def unfused(w, qs):
        return update(agg(w, qs))

    rows = []
    base = None
    for ud in UPLINKS:
        wire, q_scale = ops.quantize_uplink(g, ud)
        wire = jax.block_until_ready(wire)
        t_f = _time(fused, wire, q_scale, iters=iters)
        t_u = _time(unfused, wire, q_scale, iters=iters)
        out = fused(wire, q_scale)
        if base is None:
            base = out
        err = float(jnp.max(jnp.abs(out - base)))
        uplink_mb = n * d * _WIRE_BYTES[ud] / 1e6
        # one fused pass: wire in + z in + params in + params out (f32)
        fused_mb = uplink_mb + 3 * d * 4 / 1e6
        # unfused adds a ghat write + read between the two launches
        unfused_mb = fused_mb + 2 * d * 4 / 1e6
        rows.append({"uplink_dtype": ud,
                     "fused_us": round(t_f, 1),
                     "unfused_us": round(t_u, 1),
                     "speedup": round(t_u / t_f, 2),
                     "uplink_mb": round(uplink_mb, 2),
                     "fused_bytes_mb": round(fused_mb, 2),
                     "unfused_bytes_mb": round(unfused_mb, 2),
                     "max_err_vs_f32": err})
    return rows


def round_step_equivalence(n: int = 4, d: int = 5000) -> float:
    """Interpret-mode Pallas ``ota_round_step`` vs the jnp oracle at a
    non-lane-aligned d, worst uplink error returned (CI smoke gate — the
    same check tests/test_kernels.py runs, without needing pytest)."""
    key = jax.random.PRNGKey(1)
    kg, ks, kz, kp = jax.random.split(key, 4)
    g = jax.random.normal(kg, (n, d))
    s = jax.random.uniform(ks, (n,), minval=0.1, maxval=1.0)
    z = jax.random.normal(kz, (d,))
    p = jax.random.normal(kp, (d,))
    ns, eta = jnp.float32(0.25), jnp.float32(0.05)
    worst = 0.0
    for ud in UPLINKS:
        wire, q_scale = ops.quantize_uplink(g, ud)
        out = ops.ota_round_step(wire, s, z, ns, p, eta, q_scale,
                                 interpret=True)
        exp = ref.ota_round_step_ref(wire, s, z, ns, p, eta,
                                     q_scale=q_scale)
        worst = max(worst, float(jnp.max(jnp.abs(out - exp))))
    return worst


def main(argv=None):
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced sizes + interpret-mode equivalence gate "
                         "(asserts; CI benchmark-smoke)")
    args = ap.parse_args(argv)
    if args.smoke:
        err = round_step_equivalence()
        assert err < 2e-5, f"interpret-mode round_step err {err}"
        print(f"round_step interpret-mode equivalence: max_err={err:.2e} OK")
        rows = round_step_rows(n=4, d=65_536, iters=2)
    else:
        rows = run() + [{"bench": f"ota_round_step_{r['uplink_dtype']}",
                         **r} for r in round_step_rows()]
    for row in rows:
        print(row)
    if args.smoke:
        assert all(r["fused_us"] > 0 and r["unfused_us"] > 0 for r in rows)
        assert {r["uplink_dtype"] for r in rows} == set(UPLINKS)
        print("kernel_bench smoke OK")


if __name__ == "__main__":
    main()
