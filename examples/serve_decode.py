"""Serving example: batched prefill + decode for three architecture families
(dense KV cache, SSM state, hybrid ring cache).

    PYTHONPATH=src python examples/serve_decode.py
"""
from repro.launch import serve as serve_mod

for arch in ["qwen1.5-0.5b", "mamba2-1.3b", "recurrentgemma-9b"]:
    print(f"\n=== {arch} ===")
    serve_mod.main(["--arch", arch, "--smoke", "--batch", "2",
                    "--prompt-len", "32", "--decode-tokens", "16"])
