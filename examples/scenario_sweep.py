"""Scenario engine quickstart: one FL task, four wireless worlds.

    PYTHONPATH=src python examples/scenario_sweep.py

The paper's experiment fixes a single scenario family (uniform disk,
Rayleigh, i.i.d. rounds).  The scenario engine (repro.core.scenarios)
composes deployment geometry x shadowing x fading family x round dynamics;
this example sweeps the default four-family grid, prints each scenario's
Theorem-1 bias/variance decomposition for the SCA design, and trains the
paper's MLP on the two extremes.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import power_control as pcm, scenarios as scn, theory
from repro.data import partition, synthetic
from repro.fl.server import FLRunConfig, run_fl
from repro.models import mlp
from repro.models.param import init_params

# 1. theory sweep: how does the bias-variance trade-off move per scenario?
print(f"{'scenario':16s} {'fading':10s} {'gainspread':>10s} "
      f"{'bias':>10s} {'variance':>10s} {'objective':>10s}")
for name in scn.SWEEP_FAMILIES:
    sc = scn.get_scenario(name)
    dep = scn.realize(sc)
    prm = scn.make_ota_params(dep, d=mlp.PARAM_DIM, gmax=10.0, eta=0.05,
                              kappa_sq=4.0)
    pc = pcm.make_power_control("sca", dep, prm)
    z = theory.zeta_terms(pc.gamma, prm)
    bias = theory.bias_term(pc.p, prm)
    spread_db = 10 * np.log10(dep.gains.max() / dep.gains.min())
    print(f"{name:16s} {dep.fading_spec.family:10s} {spread_db:9.1f}dB "
          f"{bias:10.3g} {z['total']:10.3g} "
          f"{2 * prm.eta * z['total'] + bias:10.3g}")

# 2. train the paper's MLP on the baseline vs the clustered extreme
#    (run_fl rides the scan-compiled engine: the round loop is lax.scan on
#    device and per-round metric traces come back on hist.traces)
x, y, xt, yt = synthetic.mnist_like(500, seed=0)
shards = partition.partition_by_label(x, y, 10, seed=0)
data = partition.stack_shards(shards)
params0 = init_params(mlp.mlp_defs(), jax.random.PRNGKey(0))
xt_j, yt_j = jnp.asarray(xt), jnp.asarray(yt)
evals = jax.jit(lambda p: {"acc": mlp.accuracy(p, xt_j, yt_j)})

for name in ["disk_rayleigh", "two_cluster"]:
    sc = scn.get_scenario(name)
    dep = scn.realize(sc)
    prm = scn.make_ota_params(dep, d=mlp.PARAM_DIM, gmax=10.0, eta=0.05,
                              kappa_sq=4.0)
    fading = scn.make_fading_process(dep, sc.dynamics)
    pc = pcm.make_power_control("sca", dep, prm)
    run_cfg = FLRunConfig(eta=0.05, num_rounds=60, eval_every=20)
    _, hist = run_fl(mlp.mlp_loss, params0, pc, dep.gains, data, run_cfg,
                     eval_fn=lambda p: evals(p), fading=fading)
    traj = " -> ".join(f"{h['acc']:.3f}" for h in hist)
    grad0 = float(hist.traces["grad_norm_mean"][0])
    print(f"sca on {name:16s} acc: {traj}  (round-0 grad norm {grad0:.2f})")
