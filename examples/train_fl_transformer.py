"""End-to-end driver (deliverable b): OTA-FL training of a ~100M-parameter
transformer for a few hundred steps.

    # quick CPU demo (~25M params, ~2 s/step):
    PYTHONPATH=src python examples/train_fl_transformer.py

    # the full ~100M few-hundred-step run:
    PYTHONPATH=src python examples/train_fl_transformer.py --full

Wraps repro.launch.train with a qwen-family config sized to the target
parameter count; the same train step pjit-shards on a real mesh.  The
workload — model bundle, non-iid vocab-band client shards, held-out eval —
comes from the ``token_stream`` task in the registry (repro.tasks,
DESIGN.md §Tasks); this script only picks sizes and a power-control scheme.
"""
import argparse
import sys

from repro.launch import train as train_mod

ap = argparse.ArgumentParser()
ap.add_argument("--full", action="store_true",
                help="~100M params, 300 steps (slower on CPU)")
ap.add_argument("--steps", type=int, default=0)
ap.add_argument("--scheme", default="sca")
args = ap.parse_args()

if args.full:
    argv = ["--arch", "qwen1.5-0.5b", "--smoke", "--d-model", "768",
            "--layers", "12", "--steps", str(args.steps or 300),
            "--seq", "128", "--clients", "4", "--scheme", args.scheme,
            "--eta", "0.05", "--log-every", "10"]
else:
    argv = ["--arch", "qwen1.5-0.5b", "--smoke", "--d-model", "512",
            "--layers", "8", "--steps", str(args.steps or 200),
            "--seq", "128", "--clients", "4", "--scheme", args.scheme,
            "--eta", "0.05", "--log-every", "10"]

losses = train_mod.main(argv)
steps = args.steps or (300 if args.full else 200)
if steps >= 50:
    # average a window: single-round OTA receiver noise is visible at the
    # per-step level by design (that's the paper's variance term)
    import numpy as np
    early, late = np.mean(losses[:10]), np.mean(losses[-10:])
    assert late < early, f"training did not reduce the loss: {early} -> {late}"
    print(f"OK: loss improved under OTA-FL SGD ({early:.3f} -> {late:.3f})")
else:
    print(f"short run ({steps} steps): loss {losses[0]:.3f} -> {losses[-1]:.3f}")
