"""SCA power-control design demo (paper §III-B; DESIGN.md §Solvers).

    PYTHONPATH=src python examples/sca_power_control.py

Designs (P1) power control for a BATCH of heterogeneous deployments in one
compiled solve (``repro.solvers.solve_batch``), prints the optimized
bias/variance split per scenario, and details the reference deployment
against the zero-bias and max-power baselines (with the scipy SLSQP oracle
as the cross-check).
"""
import numpy as np

from repro import solvers
from repro.core import channel, sca, theory
from repro.core.theory import OTAParams


def make_prm(seed: int, n: int = 10):
    """Returns (OTAParams, Deployment) for one realized disk deployment."""
    wcfg = channel.WirelessConfig(num_devices=n, seed=seed)
    dep = channel.deploy(wcfg)
    return OTAParams(d=814090, gmax=10.0, es=wcfg.energy_per_sample,
                     n0=wcfg.noise_psd, gains=dep.gains, sigma_sq=np.zeros(n),
                     eta=0.05, lsmooth=1.0, kappa_sq=4.0), dep


# --- one compiled solve over a batch of deployments --------------------------
seeds = range(8)
prms, deps = zip(*[make_prm(s) for s in seeds])
res = solvers.solve_batch(prms)

print("batched SCA designs (one compiled program, 8 deployments):")
print(f"{'seed':>5} {'objective':>10} {'bias':>10} {'variance':>10} "
      f"{'noise_var':>10} {'tx_var':>8} {'p_spread':>9}")
for i, (prm, dep) in enumerate(zip(prms, deps)):
    z = theory.zeta_terms(res.gamma[i], prm)
    bias = theory.bias_term(res.p[i], prm)
    var = 2.0 * prm.eta * prm.lsmooth * z["total"]
    print(f"{i:>5} {res.objective[i]:>10.4f} {bias:>10.5f} {var:>10.4f} "
          f"{2 * prm.eta * z['noise']:>10.4f} "
          f"{2 * prm.eta * z['transmission']:>8.4f} "
          f"{np.max(res.p[i]) - np.min(res.p[i]):>9.4f}")

# --- the reference deployment in detail --------------------------------------
prm, dep = prms[0], deps[0]
gamma = res.gamma[0]
print(f"\nreference deployment (seed 0): objective {res.objective[0]:.4f}")
oracle = sca.solve_sca(prm)
print(f"scipy SLSQP oracle: {oracle.objective:.4f} "
      f"(rel gap {res.objective[0] / oracle.objective - 1.0:+.2e})")

gm = theory.gamma_max(prm)
print(f"\n{'device':>6} {'dist(m)':>8} {'Lambda':>10} {'gamma/gmax':>10} "
      f"{'p_m':>7}")
for m in range(prm.num_devices):
    print(f"{m:>6} {dep.distances[m]:>8.0f} {dep.gains[m]:>10.2e} "
          f"{gamma[m] / gm[m]:>10.3f} {res.p[0][m]:>7.4f}")

print("\ndesign comparison (P1 objective = 2 eta L zeta + bias):")
designs = {
    "sca (optimized)": gamma,
    "zero-bias": theory.zero_bias_gamma(prm),
    "max-power": gm,
}
for name, g in designs.items():
    z = theory.zeta_terms(g, prm)
    _, _, p = theory.participation(g, prm)
    b = theory.bias_term(p, prm)
    print(f"  {name:16s} obj={theory.p1_objective(g, prm):8.4f} "
          f"noise={z['noise']:8.3f} tx_var={z['transmission']:7.3f} "
          f"bias={b:8.5f}")
print("\n=> SCA accepts a small structured bias to cut receiver-noise "
      "variance — the paper's trade-off, now designed for the whole "
      "deployment batch in one compiled solve.")
