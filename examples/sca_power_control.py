"""SCA power-control design demo (paper §III-B).

    PYTHONPATH=src python examples/sca_power_control.py

Solves (P1) for a heterogeneous deployment and compares the optimized
bias-variance trade-off against the zero-bias and max-power designs.
"""
import numpy as np

from repro.core import channel, sca, theory
from repro.core.theory import OTAParams

wcfg = channel.WirelessConfig(num_devices=10, seed=0)
dep = channel.deploy(wcfg)
prm = OTAParams(d=814090, gmax=10.0, es=wcfg.energy_per_sample,
                n0=wcfg.noise_psd, gains=dep.gains, sigma_sq=np.zeros(10),
                eta=0.05, lsmooth=1.0, kappa_sq=4.0)

res = sca.solve_sca(prm)
print(f"SCA converged in {res.iterations} iterations")
print("objective trajectory:", [f"{h:.3f}" for h in res.history])

print(f"\n{'device':>6} {'dist(m)':>8} {'Lambda':>10} {'gamma/gmax':>10} "
      f"{'p_m':>7}")
gm = theory.gamma_max(prm)
for m in range(10):
    print(f"{m:>6} {dep.distances[m]:>8.0f} {dep.gains[m]:>10.2e} "
          f"{res.gamma[m] / gm[m]:>10.3f} {res.p[m]:>7.4f}")

print("\ndesign comparison (P1 objective = 2 eta L zeta + bias):")
designs = {
    "sca (optimized)": res.gamma,
    "zero-bias": theory.zero_bias_gamma(prm),
    "max-power": theory.gamma_max(prm),
}
for name, gamma in designs.items():
    z = theory.zeta_terms(gamma, prm)
    _, _, p = theory.participation(gamma, prm)
    b = theory.bias_term(p, prm)
    print(f"  {name:16s} obj={theory.p1_objective(gamma, prm):8.4f} "
          f"noise={z['noise']:8.3f} tx_var={z['transmission']:7.3f} "
          f"bias={b:8.5f}")
print("\n=> SCA accepts a small structured bias to cut receiver-noise "
      "variance — the paper's trade-off.")
