"""Quickstart: the paper's experiment in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py

Trains the paper's MLP over a simulated heterogeneous wireless network with
three OTA power-control schemes — all three as ONE compiled scan program:
the schemes are stacked into a vmapped fleet (core.power_control
.stack_schemes) and the round loop runs as lax.scan on device
(fl.engine.run_fleet, DESIGN.md §Engine).
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import channel, power_control as pcm
from repro.core.theory import OTAParams
from repro.data import partition, synthetic
from repro.fl.engine import run_fleet
from repro.fl.server import FLRunConfig
from repro.models import mlp
from repro.models.param import init_params

# 1. wireless world: 10 devices, log-distance path loss, Rayleigh fading
wcfg = channel.WirelessConfig(num_devices=10, seed=0)
dep = channel.deploy(wcfg)
print("device distances (m):", np.round(dep.distances, 0))

# 2. non-iid data: 2 digits per device, <= 2 devices per digit (paper §IV)
x, y, xt, yt = synthetic.mnist_like(500, seed=0)
shards = partition.partition_by_label(x, y, 10, seed=0)
xd, yd = partition.stack_shards(shards)

# 3. problem constants for the Theorem-1-driven power control design
prm = OTAParams(d=mlp.PARAM_DIM, gmax=10.0, es=wcfg.energy_per_sample,
                n0=wcfg.noise_psd, gains=dep.gains, sigma_sq=np.zeros(10),
                eta=0.05, lsmooth=1.0, kappa_sq=4.0)

params0 = init_params(mlp.mlp_defs(), jax.random.PRNGKey(0))
xt_j, yt_j = jnp.asarray(xt), jnp.asarray(yt)
evals = jax.jit(lambda p: {"acc": mlp.accuracy(p, xt_j, yt_j)})

# 4. three schemes, one compiled program: noiseless reference, the paper's
#    SCA design, and the zero-instantaneous-bias weakest-channel baseline.
#    The heterogeneous mix dispatches through the SchemeBatch union; the
#    aggregation rides the flattened Pallas kernel path.
names = ["ideal", "sca", "vanilla"]
schemes = [pcm.make_power_control(n, dep, prm) for n in names]
run_cfg = FLRunConfig(eta=0.05, num_rounds=60, eval_every=20, batch_size=64)
res = run_fleet(mlp.mlp_loss, params0, schemes, dep.gains, (xd, yd),
                run_cfg, evals, flat=True)
for i, name in enumerate(names):
    traj = " -> ".join(f"{float(ev['acc'][i, 0]):.3f}"
                       for _, ev in res.evals)
    print(f"{name:8s} acc: {traj}")
print(f"one compiled fleet, wall {res.wall:.1f}s; per-round traces: "
      f"{sorted(res.traces)} shape {res.traces['active_devices'].shape}")

# 5. the SAME fleet through the placement layer (DESIGN.md §Placement):
#    on one device this is exactly the vmap fleet above; with >= 4 devices
#    (e.g. XLA_FLAGS=--xla_force_host_platform_device_count=8, or a real
#    accelerator mesh) the [scheme x seed] cells shard over the
#    ("data", "model") mesh — the script is unchanged either way.
from repro.fl.driver import run_fleet as run_fleet_placed
from repro.fl.placement import ShardedPlacement, VmapPlacement
from repro.launch.mesh import make_debug_mesh

if jax.device_count() >= 4:
    placement = ShardedPlacement(make_debug_mesh(2, 2))
    where = f"sharded over {placement.num_devices} devices"
else:
    placement = VmapPlacement()
    where = "vmapped on 1 device"
res2 = run_fleet_placed(mlp.mlp_loss, params0, schemes, dep.gains, (xd, yd),
                        run_cfg, evals, flat=True, seeds=(0, 1),
                        placement=placement)
final = res2.evals[-1][1]["acc"]
print(f"[scheme x seed] grid {where}: final acc per cell "
      f"{np.round(np.asarray(final), 3).tolist()}")
