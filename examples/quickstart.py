"""Quickstart: any registered FL workload in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py [--task paper_mlp|cifar_conv]

Trains a task from the workload registry (repro.tasks, DESIGN.md §Tasks)
over a simulated heterogeneous wireless network with three OTA
power-control schemes — all three as ONE compiled scan program: the
schemes are stacked into a vmapped fleet (core.power_control
.stack_schemes) and the round loop runs as lax.scan on device through the
task-first driver (fl.driver.run_fleet_task, DESIGN.md §Engine).
"""
import argparse

import jax
import numpy as np

from repro import tasks
from repro.core import channel, power_control as pcm
from repro.core.theory import OTAParams

ap = argparse.ArgumentParser()
ap.add_argument("--task", default="paper_mlp",
                help="registered fleet workload "
                     f"({'|'.join(tasks.names(runtime='fleet'))})")
args = ap.parse_args()

# 1. the workload: dataset builder + non-iid partitioner + model + eval,
#    bundled behind one name.  paper_mlp = the paper's §IV experiment
#    (ring label split, 814k-param MLP); cifar_conv = 32x32x3 Dirichlet
#    non-iid convnet.  The registry's factory overrides shrink cifar to
#    demo scale here (CPU convs are slow); the full-size workload runs
#    through `python -m benchmarks.fig2 --task cifar_conv`.
DEMO = {
    "paper_mlp": dict(overrides={}, rounds=60, every=20, batch=64),
    "cifar_conv": dict(overrides=dict(channels=(8, 16), hidden=64,
                                      samples_per_class=150),
                       rounds=12, every=4, batch=32),
}.get(args.task, dict(overrides={}, rounds=30, every=10, batch=32))
try:
    task = tasks.get(args.task, expect_runtime="fleet", **DEMO["overrides"])
except (KeyError, ValueError) as e:
    raise SystemExit(str(e))
td = task.build_data(seed=0)
print(f"task {task.name}: d={task.param_dim} params, "
      f"{task.num_devices} devices, shard length {td.train[1].shape[1]}")

# 2. wireless world: log-distance path loss, Rayleigh fading
wcfg = channel.WirelessConfig(num_devices=task.num_devices, seed=0)
dep = channel.deploy(wcfg)
print("device distances (m):", np.round(dep.distances, 0))

# 3. problem constants for the Theorem-1-driven power control design
prm = OTAParams(d=task.param_dim, gmax=task.defaults["gmax"],
                es=wcfg.energy_per_sample, n0=wcfg.noise_psd,
                gains=dep.gains, sigma_sq=np.zeros(task.num_devices),
                eta=0.05, lsmooth=1.0, kappa_sq=4.0)

# 4. three schemes, one compiled program: noiseless reference, the paper's
#    SCA design, and the zero-instantaneous-bias weakest-channel baseline.
#    The heterogeneous mix dispatches through the SchemeBatch union; the
#    aggregation rides the flattened Pallas kernel path.
names = ["ideal", "sca", "vanilla"]
schemes = [pcm.make_power_control(n, dep, prm) for n in names]
run_cfg = task.run_config(num_rounds=DEMO["rounds"],
                          eval_every=DEMO["every"],
                          batch_size=DEMO["batch"])

from repro.fl.driver import run_fleet_task

# the schemes were designed at prm.eta above, so train at that same
# operating point (run_fleet_task would otherwise default to the task's
# per-scheme eta map, which belongs with fig2's per-scheme designs)
etas = [run_cfg.eta] * len(names)
res = run_fleet_task(task, schemes, dep.gains, run_cfg, task_data=td,
                     etas=etas, flat=True)
for i, name in enumerate(names):
    traj = " -> ".join(f"{float(ev['acc'][i, 0]):.3f}"
                       for _, ev in res.evals)
    print(f"{name:8s} acc: {traj}")
print(f"one compiled fleet, wall {res.wall:.1f}s; per-round traces: "
      f"{sorted(res.traces)} shape {res.traces['active_devices'].shape}")

# 5. the SAME fleet through the placement layer (DESIGN.md §Placement):
#    on one device this is exactly the vmap fleet above; with >= 4 devices
#    (e.g. XLA_FLAGS=--xla_force_host_platform_device_count=8, or a real
#    accelerator mesh) the [scheme x seed] cells shard over the
#    ("data", "model") mesh — the script is unchanged either way.
from repro.fl.placement import ShardedPlacement, VmapPlacement
from repro.launch.mesh import make_debug_mesh

if jax.device_count() >= 4:
    placement = ShardedPlacement(make_debug_mesh(2, 2))
    where = f"sharded over {placement.num_devices} devices"
else:
    placement = VmapPlacement()
    where = "vmapped on 1 device"
res2 = run_fleet_task(task, schemes, dep.gains, run_cfg, task_data=td,
                      etas=etas, flat=True, seeds=(0, 1),
                      placement=placement)
final = res2.evals[-1][1]["acc"]
print(f"[scheme x seed] grid {where}: final acc per cell "
      f"{np.round(np.asarray(final), 3).tolist()}")
