"""Scan/vmap experiment engine (fl.engine, DESIGN.md §Engine).

Equivalence contract:
  * scan engine vs legacy host loop: BITWISE on the default Rayleigh path
    and on a stateful (Gauss-Markov) scenario — same key streams, same
    compiled constants, same op order.
  * vmapped [scheme x seed] fleet vs per-scheme runs: run-for-run to float
    rounding (scheme state rides as vmapped operands, so XLA constant
    folding differs; trajectories agree to ~1e-5 over tens of rounds).
  * flattened (Pallas-dispatch) aggregation vs per-leaf tree oracle:
    identical noise realizations, float-rounding agreement, across
    non-lane-aligned parameter shapes, kernel exercised in interpret mode.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import channel, ota, power_control as pcm, scenarios as scn
from repro.data import partition, synthetic
from repro.fl import engine as eng
from repro.fl.server import FLRunConfig, make_round_fn, run_fl, run_fl_legacy
from repro.kernels import ops as kops
from repro.models import mlp
from repro.models.param import init_params
from tests.helpers import make_prm

HIDDEN = 32


def small_loss(params, batch):
    return mlp.mlp_loss(params, batch)


@pytest.fixture(scope="module")
def world():
    dep = channel.deploy(channel.WirelessConfig(num_devices=10, seed=0))
    x, y, xt, yt = synthetic.mnist_like(40, seed=0)
    shards = partition.partition_by_label(x, y, 10, seed=0)
    data = partition.stack_shards(shards)
    prm = make_prm(dep.gains, d=10000)
    params0 = init_params(mlp.mlp_defs(hidden=HIDDEN), jax.random.PRNGKey(0))
    xt_j, yt_j = jnp.asarray(xt), jnp.asarray(yt)
    ev = jax.jit(lambda p: {"acc": mlp.accuracy(p, xt_j, yt_j)})
    return dep, prm, data, params0, ev


def _tree_equal(a, b):
    return all(bool(jnp.all(a[k] == b[k])) for k in a)


def _tree_maxdiff(a, b):
    return max(float(jnp.max(jnp.abs(a[k] - b[k]))) for k in a)


# ---------------------------------------------------------------------------
# chunking
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("t,e", [(1, 10), (10, 10), (13, 5), (150, 10),
                                 (7, 3), (20, 20)])
def test_chunk_lengths_match_legacy_eval_cadence(t, e):
    legacy_evals = [r for r in range(t) if r % e == 0 or r == t - 1]
    lengths = eng.chunk_lengths(t, e, with_eval=True)
    assert sum(lengths) == t
    ends = np.cumsum(lengths) - 1
    assert list(ends) == legacy_evals
    assert len(set(lengths)) <= 3          # at most 3 compiled scan lengths
    assert eng.chunk_lengths(t, e, with_eval=False) == [t]


# ---------------------------------------------------------------------------
# scan engine vs legacy host loop
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("scheme", ["sca", "bbfl_alternative"])
def test_scan_engine_bitwise_default_path(world, scheme):
    dep, prm, data, params0, ev = world
    pc = pcm.make_power_control(scheme, dep, prm)
    run = FLRunConfig(eta=0.05, num_rounds=11, eval_every=4)
    p_legacy, h_legacy = run_fl_legacy(small_loss, params0, pc, dep.gains,
                                       data, run, ev)
    p_scan, h_scan = run_fl(small_loss, params0, pc, dep.gains, data, run,
                            ev)
    assert _tree_equal(p_legacy, p_scan)
    assert [r["acc"] for r in h_legacy] == [r["acc"] for r in h_scan]
    assert [r["round"] for r in h_legacy] == [r["round"] for r in h_scan]
    # satellite: per-round traces surfaced, not just eval rounds
    for name in ("grad_norm_mean", "active_devices", "noise_scale"):
        assert h_scan.traces[name].shape == (run.num_rounds,)
    assert np.all(np.isfinite(h_scan.traces["grad_norm_mean"]))


def test_scan_engine_bitwise_stateful_scenario(world):
    """Gauss-Markov fading state threads through the scan carry."""
    _, _, data, params0, ev = world
    sc = scn.get_scenario("disk_markov")
    dep = scn.realize(sc)
    prm = scn.make_ota_params(dep, d=10000, gmax=10.0)
    fp = scn.make_fading_process(dep, sc.dynamics)
    pc = pcm.make_power_control("zero_bias", dep, prm)
    run = FLRunConfig(eta=0.05, num_rounds=9, eval_every=4)
    p_legacy, _ = run_fl_legacy(small_loss, params0, pc, dep.gains, data,
                                run, ev, fading=fp)
    p_scan, h_scan = run_fl(small_loss, params0, pc, dep.gains, data, run,
                            ev, fading=fp)
    assert _tree_equal(p_legacy, p_scan)
    assert h_scan.traces["active_devices"].shape == (run.num_rounds,)


def test_metrics_derive_from_applied_coefficients(world):
    """Satellite fix: active_devices must come from the same (s, ns) the
    aggregation applied.  bbfl_alternative randomizes round_coeffs, so the
    old unsplit-key recomputation disagreed on rounds where the two
    bernoulli draws differed."""
    dep, prm, data, params0, _ = world
    pc = pcm.make_power_control("bbfl_alternative", dep, prm)
    run = FLRunConfig(eta=0.05, gmax=10.0)
    round_fn = make_round_fn(small_loss, pc, dep.gains, run)
    batch = tuple(jnp.asarray(a) for a in data)
    gains_j = jnp.asarray(dep.gains)
    interior = int(pc.mask.sum())
    saw = set()
    for i in range(12):
        sub = jax.random.PRNGKey(100 + i)
        _, metrics = round_fn(params0, batch, sub)
        k_fade, k_ota, _ = jax.random.split(sub, 3)
        k_coeff, _ = ota.split_ota_key(k_ota)
        h = ota.draw_fading(k_fade, gains_j)
        s, _ = pc.round_coeffs(h, k_coeff)
        expect = float(jnp.sum((s > 0).astype(jnp.float32)))
        assert float(metrics["active_devices"]) == expect
        saw.add(expect)
    # both branches of the alternation actually exercised
    assert saw == {float(interior), float(dep.num_devices)}


def test_minibatch_sampled_on_device(world):
    """0 < batch_size < D consumes the k_batch lane: deterministic per
    seed, different from the full-batch trajectory, still learning-shaped
    (finite grads, all devices active for ideal)."""
    dep, prm, data, params0, ev = world
    pc = pcm.make_power_control("ideal", dep, prm)
    run_mb = FLRunConfig(eta=0.05, num_rounds=6, eval_every=5, batch_size=8)
    p1, h1 = run_fl(small_loss, params0, pc, dep.gains, data, run_mb, ev)
    p2, h2 = run_fl(small_loss, params0, pc, dep.gains, data, run_mb, ev)
    assert _tree_equal(p1, p2)                      # same seed -> same run
    run_fb = FLRunConfig(eta=0.05, num_rounds=6, eval_every=5)
    p3, _ = run_fl(small_loss, params0, pc, dep.gains, data, run_fb, ev)
    assert not _tree_equal(p1, p3)                  # minibatch != full batch
    assert np.all(np.isfinite(h1.traces["grad_norm_mean"]))
    assert np.all(h1.traces["active_devices"] == dep.num_devices)


# ---------------------------------------------------------------------------
# vmapped fleet vs per-scheme runs
# ---------------------------------------------------------------------------

def test_fleet_matches_per_scheme_runs(world):
    dep, prm, data, params0, ev = world
    names = ["ideal", "sca", "vanilla", "bbfl_alternative"]
    schemes = [pcm.make_power_control(n, dep, prm) for n in names]
    seeds = (0, 3)
    run = FLRunConfig(eta=0.05, num_rounds=10, eval_every=4)
    res = eng.run_fleet(small_loss, params0, schemes, dep.gains, data, run,
                        ev, seeds=seeds, flat=False)
    assert res.names == tuple(names)
    assert res.traces["active_devices"].shape == (4, 2, run.num_rounds)
    for i, name in enumerate(names):
        for j, seed in enumerate(seeds):
            run_ij = FLRunConfig(eta=0.05, num_rounds=10, eval_every=4,
                                 seed=seed)
            p_ref, h_ref = run_fl(small_loss, params0, schemes[i],
                                  dep.gains, data, run_ij, ev)
            cell = jax.tree.map(lambda a: a[i, j], res.params)
            assert _tree_maxdiff(p_ref, cell) < 1e-4, (name, seed)
            # integer-valued trace must agree exactly
            assert np.array_equal(res.traces["active_devices"][i, j],
                                  h_ref.traces["active_devices"])
            for t_idx, (t, evd) in enumerate(res.evals):
                assert abs(float(evd["acc"][i, j])
                           - h_ref[t_idx]["acc"]) < 5e-3
    # seed axis is real: different seeds, different trajectories
    a = jax.tree.map(lambda x: x[1, 0], res.params)
    b = jax.tree.map(lambda x: x[1, 1], res.params)
    assert not _tree_equal(a, b)


def test_fleet_per_scheme_etas(world):
    dep, prm, data, params0, ev = world
    schemes = [pcm.make_power_control("ideal", dep, prm) for _ in range(2)]
    run = FLRunConfig(eta=0.05, num_rounds=4, eval_every=3)
    res = eng.run_fleet(small_loss, params0, schemes, dep.gains, data, run,
                        ev, etas=[0.05, 0.01], flat=False)
    a = jax.tree.map(lambda x: x[0, 0], res.params)
    b = jax.tree.map(lambda x: x[1, 0], res.params)
    assert not _tree_equal(a, b)
    run2 = FLRunConfig(eta=0.01, num_rounds=4, eval_every=3)
    p_ref, _ = run_fl(small_loss, params0, schemes[1], dep.gains, data,
                      run2, ev)
    assert _tree_maxdiff(p_ref, b) < 1e-5


def test_fleet_stateful_scenario_matches_single_runs(world):
    """[K x S] fleet on a dropout scenario: per-cell fading/dropout streams
    match the standalone runs (scenarios state carries the batch axes)."""
    _, _, data, params0, ev = world
    sc = scn.get_scenario("disk_dropout")
    dep = scn.realize(sc)
    prm = scn.make_ota_params(dep, d=10000, gmax=10.0)
    fp = scn.make_fading_process(dep, sc.dynamics)
    schemes = [pcm.make_power_control(n, dep, prm)
               for n in ("sca", "vanilla")]
    run = FLRunConfig(eta=0.05, num_rounds=8, eval_every=7)
    res = eng.run_fleet(small_loss, params0, schemes, dep.gains, data, run,
                        ev, fading=fp, flat=False)
    assert res.fading_state.shape == (2, 1, dep.num_devices)
    for i in range(2):
        p_ref, h_ref = run_fl(small_loss, params0, schemes[i], dep.gains,
                              data, run, ev, fading=fp)
        cell = jax.tree.map(lambda a: a[i, 0], res.params)
        assert _tree_maxdiff(p_ref, cell) < 1e-4
        # dropout pattern is key-determined -> must agree exactly
        assert np.array_equal(res.traces["active_devices"][i, 0],
                              h_ref.traces["active_devices"])


# ---------------------------------------------------------------------------
# flattened aggregation vs tree oracle
# ---------------------------------------------------------------------------

def _odd_tree(key, n=10):
    """Leaves with deliberately non-lane-aligned trailing dims."""
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w": jax.random.normal(k1, (n, 33, 17)),
        "b": jax.random.normal(k2, (n, 29)),
        "t": jax.random.normal(k3, (n, 5, 3, 7)),
    }


@pytest.mark.parametrize("use_kernel", [False, True])
def test_flat_aggregation_matches_tree_oracle(use_kernel):
    """Flattened path (jnp fused on CPU / Pallas interpret when forced) vs
    the per-leaf tree oracle: same noise realizations, fp-level agreement,
    across non-aligned shapes."""
    tree = _odd_tree(jax.random.PRNGKey(0))
    s = jax.random.uniform(jax.random.PRNGKey(1), (10,))
    ns = jnp.float32(0.37)
    key = jax.random.PRNGKey(2)
    ref = ota.apply_round_coeffs(tree, s, ns, key, flat=False)
    out = kops.ota_aggregate_pytree(tree, s, ns, key,
                                    use_kernel=use_kernel, interpret=True)
    for k in ref:
        np.testing.assert_allclose(np.asarray(ref[k]), np.asarray(out[k]),
                                   rtol=2e-6, atol=2e-6)
        assert out[k].shape == ref[k].shape
    # identical *realizations*: the residual is tiny relative to the noise
    zero = jax.tree.map(jnp.zeros_like, tree)
    noise_ref = ota.apply_round_coeffs(zero, s, ns, key, flat=False)
    noise_flat = kops.ota_aggregate_pytree(zero, s, ns, key,
                                           use_kernel=use_kernel,
                                           interpret=True)
    for k in noise_ref:
        np.testing.assert_allclose(np.asarray(noise_ref[k]),
                                   np.asarray(noise_flat[k]), rtol=1e-6,
                                   atol=1e-7)
        assert float(jnp.max(jnp.abs(noise_ref[k]))) > 0.01 * float(ns)


def test_flat_engine_run_close_to_tree_engine_run(world):
    dep, prm, data, params0, ev = world
    pc = pcm.make_power_control("sca", dep, prm)
    run = FLRunConfig(eta=0.05, num_rounds=6, eval_every=5)
    p_tree, _ = run_fl(small_loss, params0, pc, dep.gains, data, run, ev)
    p_flat, _ = run_fl(small_loss, params0, pc, dep.gains, data, run, ev,
                       flat=True)
    assert _tree_maxdiff(p_tree, p_flat) < 1e-4


def test_weighted_sum_accumulates_f32():
    """Satellite fix: bf16 leaves must not quantize the coefficients before
    the reduction."""
    n, d = 10, 64
    g32 = jax.random.normal(jax.random.PRNGKey(0), (n, d))
    s = jnp.linspace(1e-3, 1.7e-3, n)       # spacing below bf16 resolution
    g16 = g32.astype(jnp.bfloat16)
    out = ota.weighted_sum({"g": g16}, s)["g"]
    assert out.dtype == jnp.bfloat16
    exact = jnp.sum(s[:, None] * g32, axis=0)
    old = jnp.sum(s.astype(jnp.bfloat16)[:, None] * g16, axis=0)
    err_new = float(jnp.max(jnp.abs(out.astype(jnp.float32) - exact)))
    err_old = float(jnp.max(jnp.abs(old.astype(jnp.float32) - exact)))
    assert err_new < err_old


# ---------------------------------------------------------------------------
# scheme stacking
# ---------------------------------------------------------------------------

def test_stack_schemes_representations(world):
    dep, prm, _, _, _ = world
    homo = [pcm.make_power_control(n, dep, prm)
            for n in ("sca", "lcpc", "zero_bias")]
    st = pcm.stack_schemes(homo)
    assert type(st) is pcm.TruncatedInversion
    assert st.names == ("sca", "lcpc", "zero_bias")
    assert st.gamma.shape == (3, dep.num_devices)

    hetero = [pcm.make_power_control(n, dep, prm)
              for n in ("ideal", "opc", "vanilla")]
    sb = pcm.stack_schemes(hetero)
    assert type(sb) is pcm.SchemeBatch
    assert len(sb) == 3

    # bbfl interior vs alternative differ in static config -> union
    bb = [pcm.make_power_control("bbfl_interior", dep, prm),
          pcm.make_power_control("bbfl_alternative", dep, prm)]
    assert type(pcm.stack_schemes(bb)) is pcm.SchemeBatch


def test_stacked_coeffs_bitwise_all_schemes(world):
    """Every scheme through the vmapped union == its standalone
    round_coeffs, bitwise."""
    dep, prm, _, _, _ = world
    names = list(pcm.SCHEMES)
    schemes = [pcm.make_power_control(n, dep, prm) for n in names]
    sb = pcm.stack_schemes(schemes)
    h = ota.draw_fading(jax.random.PRNGKey(5), jnp.asarray(dep.gains))
    keys = jax.random.split(jax.random.PRNGKey(6), len(names))
    s_b, ns_b = pcm.round_coeffs_fleet(sb, h, keys)
    for i, pc in enumerate(schemes):
        s_ref, ns_ref = pc.round_coeffs(h, keys[i])
        assert bool(jnp.all(s_ref == s_b[i])), pc.name
        assert bool(jnp.all(ns_ref == ns_b[i])), pc.name


def test_fading_process_batch_axes():
    """init_batch/step_batch carry [K, S] grid axes and reproduce the
    per-cell scalar init/step streams exactly."""
    sc = scn.get_scenario("disk_markov")
    dep = scn.realize(sc)
    fp = scn.make_fading_process(dep, sc.dynamics)
    keys = jax.random.split(jax.random.PRNGKey(0), 6).reshape(2, 3, 2)
    state = fp.init_batch(keys)
    assert state.shape == (2, 3, dep.num_devices)
    step_keys = jax.random.split(jax.random.PRNGKey(1), 6).reshape(2, 3, 2)
    new_state, h = fp.step_batch(state, step_keys)
    assert new_state.shape == state.shape
    assert h.shape == (2, 3, dep.num_devices)
    for i in range(2):
        for j in range(3):
            s_ref = fp.init(keys[i, j])
            assert bool(jnp.all(s_ref == state[i, j]))
            s1, h1 = fp.step(s_ref, step_keys[i, j])
            assert bool(jnp.all(s1 == new_state[i, j]))
            assert bool(jnp.all(h1 == h[i, j]))


def test_scheme_pytree_roundtrip(world):
    dep, prm, _, _, _ = world
    pc = pcm.make_power_control("sca", dep, prm)
    leaves, treedef = jax.tree.flatten(pc)
    rebuilt = jax.tree.unflatten(treedef, leaves)
    assert rebuilt.name == "sca"
    assert np.array_equal(rebuilt.gamma, pc.gamma)
    h = ota.draw_fading(jax.random.PRNGKey(1), jnp.asarray(dep.gains))
    k = jax.random.PRNGKey(2)
    s1, n1 = pc.round_coeffs(h, k)
    s2, n2 = rebuilt.round_coeffs(h, k)
    assert bool(jnp.all(s1 == s2)) and bool(jnp.all(n1 == n2))
