"""Data pipeline, optimizers, checkpoint substrates."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.checkpoint import checkpoint as ckpt
from repro.data import partition, synthetic
from repro.optim.optimizers import (adamw, clip_by_global_norm, get_optimizer,
                                    sgd, sgd_momentum)
from repro.optim.schedules import warmup_cosine


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------

def test_mnist_like_deterministic_and_separable():
    x1, y1, xt, yt = synthetic.mnist_like(100, seed=0)
    x2, y2, _, _ = synthetic.mnist_like(100, seed=0)
    assert np.array_equal(x1, x2) and np.array_equal(y1, y2)
    assert x1.shape == (1000, 784) and xt.shape == (1000, 784)
    assert x1.min() >= 0.0 and x1.max() <= 1.0
    # nearest-template classification must beat chance by a lot
    centroids = np.stack([x1[y1 == c].mean(0) for c in range(10)])
    pred = np.argmin(((xt[:, None] - centroids[None]) ** 2).sum(-1), axis=1)
    assert (pred == yt).mean() > 0.6


def test_partition_paper_protocol():
    x, y, _, _ = synthetic.mnist_like(100, seed=0)
    shards = partition.partition_by_label(x, y, 10, labels_per_device=2,
                                          max_devices_per_label=2)
    assert len(shards) == 10
    label_owner_count = np.zeros(10, int)
    for xm, ym in shards:
        labs = np.unique(ym)
        assert len(labs) == 2                     # exactly two digits
        for l in labs:
            label_owner_count[l] += 1
        assert len(ym) == 100                     # equal split
    assert np.all(label_owner_count <= 2)         # <= 2 devices per label
    # partition covers every sample exactly once
    total = sum(len(ym) for _, ym in shards)
    assert total == len(y)


@settings(max_examples=15, deadline=None)
@given(st.integers(2, 10))
def test_label_assignment_property(n_dev):
    assign = partition.label_assignment(n_dev, 10, 2, 2)
    counts = np.zeros(10, int)
    for labs in assign:
        assert len(set(labs)) == 2
        for l in labs:
            counts[l] += 1
    assert counts.max() <= 2


def test_token_stream():
    t = synthetic.token_stream(10000, 100, seed=1)
    assert t.shape == (10000,) and t.min() >= 0 and t.max() < 100
    # Zipf: most common token should dominate
    assert np.bincount(t).max() > 500


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["sgd", "sgd_momentum", "adamw"])
def test_optimizer_quadratic_convergence(name):
    opt = get_optimizer(name, lr=0.1)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = opt.init(params)
    for _ in range(200):
        grads = jax.tree.map(lambda p: 2 * p, params)   # d/dp ||p||^2
        params, state = opt.update(grads, state, params)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_clip_by_global_norm():
    g = {"a": jnp.ones(100) * 10.0}
    clipped, norm = clip_by_global_norm(g, 5.0)
    total = jnp.sqrt(sum(jnp.sum(l ** 2) for l in jax.tree.leaves(clipped)))
    assert float(total) == pytest.approx(5.0, rel=1e-5)
    assert float(norm) == pytest.approx(100.0)
    # norms below the cap are untouched
    g2 = {"a": jnp.ones(4) * 0.1}
    c2, _ = clip_by_global_norm(g2, 5.0)
    assert jnp.allclose(c2["a"], g2["a"])


def test_warmup_cosine_schedule():
    fn = warmup_cosine(1.0, warmup=10, total=100)
    assert float(fn(0)) == 0.0
    assert float(fn(10)) == pytest.approx(1.0, rel=1e-3)
    assert float(fn(100)) == pytest.approx(0.1, rel=1e-2)


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path, key):
    tree = {"layer": {"w": jax.random.normal(key, (4, 8)),
                      "b": jnp.zeros(8)},
            "stack": [jnp.ones(3), jnp.arange(5)]}
    path = os.path.join(tmp_path, "ck")
    ckpt.save(path, tree, meta={"step": 7})
    restored = ckpt.restore(path, jax.tree.map(jnp.zeros_like, tree))
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert ckpt.load_meta(path)["step"] == 7


def test_checkpoint_shape_mismatch_raises(tmp_path, key):
    tree = {"w": jnp.zeros((2, 2))}
    path = os.path.join(tmp_path, "ck2")
    ckpt.save(path, tree)
    with pytest.raises(ValueError):
        ckpt.restore(path, {"w": jnp.zeros((3, 3))})
