"""Population layer + streaming cohort driver (DESIGN.md §Population).

Contracts pinned here:
  * ``Population.draw_cohort`` is a pure counter-keyed function of
    (population seed, run seed, tick): sorted, duplicate-free, in-range,
    re-derivable on resume; n == size is the arange identity path.
  * Under traffic weighting every device keeps a nonzero long-run
    selection probability — the heavy tail biases draws, it never
    starves anyone (the hypothesis property generalizes over sigma).
  * Cohort chunks recompile NEVER: the cohort dict is a jit operand, so
    five different draws hit one compiled program (cache-size assertion).
  * cohort == population over a deployment-as-population is BITWISE the
    pre-population ``run_fleet_task`` path on shrunk paper_mlp.
  * stream=True (double-buffered staging) is BITWISE stream=False, and a
    kill-and-resume mid-stream is BITWISE the uninterrupted run —
    including Gauss-Markov re-entry states and adaptive_sca cohort
    designs.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import tasks, telemetry
from repro.core import channel, power_control as pcm, scenarios as scn
from repro.data import partition, synthetic
from repro.fl import driver, engine as eng
from repro.fl.placement import VmapPlacement
from repro.fl.server import FLRunConfig
from repro.models import mlp
from repro.models.param import init_params
from tests.helpers import make_prm


def _params_equal(a, b):
    return all(bool(np.array_equal(np.asarray(x), np.asarray(y)))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def _histories_bitwise(res_a, res_b):
    assert set(res_a.traces) == set(res_b.traces)
    for k in res_a.traces:
        assert np.array_equal(res_a.traces[k], res_b.traces[k]), k
    assert [t for t, _ in res_a.evals] == [t for t, _ in res_b.evals]
    for (_, ea), (_, eb) in zip(res_a.evals, res_b.evals):
        for k in ea:
            assert np.array_equal(np.asarray(ea[k]), np.asarray(eb[k])), k


def _cohorts_equal(a, b):
    assert len(a) == len(b)
    for (ta, ia), (tb, ib) in zip(a, b):
        assert ta == tb and np.array_equal(ia, ib)


def _traffic_pop(size=500, seed=7, rho=0.0, fading=None):
    spec = scn.PopulationSpec(
        size=size, shadowing=scn.ShadowingSpec(sigma_db=6.0),
        fading=fading if fading is not None else channel.RAYLEIGH,
        dynamics=scn.DynamicsSpec(rho=rho), sampling="traffic",
        traffic_sigma=1.0, seed=seed)
    return scn.Population(spec=spec)


# ---------------------------------------------------------------------------
# cohort draws: pure, conserving, re-derivable
# ---------------------------------------------------------------------------

def test_draw_cohort_sample_conserving_and_pure():
    pop = _traffic_pop(size=300)
    for tick in (0, 1, 17):
        for seed in (0, 3):
            idx = pop.draw_cohort(20, tick, seed)
            assert idx.shape == (20,) and idx.dtype == np.int64
            assert len(np.unique(idx)) == 20          # without replacement
            assert np.array_equal(idx, np.sort(idx))
            assert 0 <= idx.min() and idx.max() < 300
            # counter-keyed: a resumed driver re-derives the same draw
            assert np.array_equal(idx, pop.draw_cohort(20, tick, seed))
    a = pop.draw_cohort(20, 0, 0)
    assert not np.array_equal(a, pop.draw_cohort(20, 1, 0))
    assert not np.array_equal(a, pop.draw_cohort(20, 0, 1))


def test_draw_cohort_full_population_is_identity():
    for pop in (_traffic_pop(size=40),
                scn.Population(gains_table=np.ones(40))):
        assert np.array_equal(pop.draw_cohort(40, tick=5, seed=9),
                              np.arange(40))


def test_draw_cohort_bounds():
    pop = _traffic_pop(size=10)
    with pytest.raises(ValueError, match="cohort size"):
        pop.draw_cohort(0, 0)
    with pytest.raises(ValueError, match="cohort size"):
        pop.draw_cohort(11, 0)


def test_weighted_sampling_never_starves():
    """Traffic weighting is heavy-tailed but every device has nonzero
    long-run selection probability: the union of draws covers the whole
    population."""
    pop = _traffic_pop(size=60)
    seen = set()
    for tick in range(400):
        seen.update(pop.draw_cohort(12, tick).tolist())
        if len(seen) == 60:
            break
    assert len(seen) == 60, f"{60 - len(seen)} devices never selected"


def test_weighted_sampling_property():
    """Hypothesis generalization: any (size, cohort, sigma, tick) draw is
    duplicate-free, sorted, in range, and deterministic in its key."""
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hyp.settings(max_examples=30, deadline=None)
    @hyp.given(size=st.integers(2, 200), frac=st.floats(0.05, 1.0),
               sigma=st.floats(0.0, 3.0), tick=st.integers(0, 10_000),
               seed=st.integers(0, 2**31 - 1))
    def prop(size, frac, sigma, tick, seed):
        n = max(1, min(size, int(size * frac)))
        spec = scn.PopulationSpec(size=size, sampling="traffic",
                                  traffic_sigma=sigma, seed=3)
        pop = scn.Population(spec=spec)
        idx = pop.draw_cohort(n, tick, seed)
        assert idx.shape == (n,)
        assert len(np.unique(idx)) == n
        assert np.array_equal(idx, np.sort(idx))
        assert 0 <= idx.min() and idx.max() < size
        assert np.array_equal(idx, pop.draw_cohort(n, tick, seed))

    prop()


def test_lazy_gains_are_index_pure():
    """gains_of hashes per device index: any index subset/order returns
    the same per-device value (laziness can't depend on batch shape)."""
    pop = _traffic_pop(size=1000)
    idx = np.array([0, 7, 999, 512, 7])
    g = pop.gains_of(idx)
    assert g.shape == (5,) and np.all(g > 0)
    assert g[1] == g[4]
    for i, d in enumerate(idx):
        assert g[i] == pop.gains_of(np.array([d]))[0]
    full = pop.gains_of(np.arange(1000))
    assert np.array_equal(full[idx], g)


# ---------------------------------------------------------------------------
# Gauss-Markov re-entry aging
# ---------------------------------------------------------------------------

def test_reentry_table_aging():
    rho = 0.9
    pop = _traffic_pop(size=50, rho=rho,
                       fading=channel.FadingSpec(family="rician",
                                                 rician_k=2.0))
    table = pop.init_table(1)
    idx = np.array([3, 10, 42])

    # never-seen devices get a fresh stationary draw, not zeros
    s0 = pop.stage_states(table, 0, idx, t0=0, seed=1)
    assert s0.dtype == np.complex64 and np.all(np.abs(s0) > 0)
    pop.commit_states(table, 0, idx, t_end=4, state=s0)

    # m = 0 (re-entering the round right after last seen): pass-through
    back = pop.stage_states(table, 0, idx, t0=5, seed=1)
    assert np.array_equal(back, s0)

    # m missed rounds: decay rho^m toward a fresh stationary innovation,
    # reproducible (counter-keyed) and different from pass-through
    aged = pop.stage_states(table, 0, idx, t0=9, seed=1)
    assert np.array_equal(aged, pop.stage_states(table, 0, idx, 9, seed=1))
    assert not np.array_equal(aged, s0)
    decay = rho ** 4
    innov = (aged.astype(np.complex128) - decay * s0.astype(np.complex128)) \
        / np.sqrt(1 - decay**2)
    # the implied innovation is stationary-scaled: |w| ~ sqrt(diffuse)
    diffuse = pop.gains_of(idx) / (2.0 + 1.0)
    assert np.all(np.abs(innov) < 6 * np.sqrt(diffuse))

    # a device another device's absence never ages: untouched rows stay -1
    assert np.all(table["last"][0, [0, 1, 2]] == -1)


# ---------------------------------------------------------------------------
# recompilation-free cohort chunks
# ---------------------------------------------------------------------------

def test_cohort_chunks_do_not_recompile():
    """Five different cohort draws through one fixed-shape compiled chunk:
    the cohort dict is an operand, so the jit cache holds ONE entry."""
    dep = channel.deploy(channel.WirelessConfig(num_devices=6, seed=0))
    x, y, _, _ = synthetic.mnist_like(20, seed=0)
    data = partition.stack_shards(partition.partition_by_label(x, y, 6,
                                                               seed=0))
    data = tuple(jnp.asarray(a) for a in data)
    prm = make_prm(dep.gains, d=1000)
    pc = pcm.make_power_control("sca", dep, prm)
    stacked = pcm.stack_schemes([pc])
    run = FLRunConfig(eta=0.05, num_rounds=2, eval_every=2)
    params0 = init_params(mlp.mlp_defs(hidden=8), jax.random.PRNGKey(0))
    body = eng.make_round_body(mlp.mlp_loss, dep.gains, run, flat=False,
                               cohort=True)
    # donate=False: the step closure re-feeds one carry across ticks
    chunk = VmapPlacement(donate=False).build_chunk(body, adaptive=False,
                                                    cohort=True)

    pop = _traffic_pop(size=100)
    params_b = jax.tree.map(
        lambda a: jnp.tile(jnp.asarray(a)[None, None],
                           (1, 1) + (1,) * jnp.ndim(a)), params0)
    keys_b = jnp.tile(jax.random.PRNGKey(0)[None, None], (1, 1, 1))
    etas = np.array([run.eta])

    def step(tick):
        idx = pop.draw_cohort(6, tick)[None]              # [S=1, N]
        cohort = {"gains": jnp.asarray(pop.gains_of(idx[0])[None]),
                  "data_idx": jnp.asarray((idx % 6).astype(np.int32))}
        return chunk(stacked, etas, params_b, None, keys_b, data, cohort,
                     length=2)

    outs = []
    params_b, _, keys_b, m = step(0)                      # warm-up compile
    outs.append(np.asarray(m["active_devices"]))
    with telemetry.assert_no_recompile(chunk):
        for tick in range(1, 5):
            params_b, _, keys_b, m = step(tick)
            outs.append(np.asarray(m["active_devices"]))
    assert chunk._cache_size() == 1, \
        f"cohort swap recompiled: {chunk._cache_size()} cache entries"
    assert len(outs) == 5


# ---------------------------------------------------------------------------
# cohort == population is the pre-population engine path, bitwise
# ---------------------------------------------------------------------------

def test_full_cohort_bitwise_matches_run_fleet_task():
    task = tasks.get("paper_mlp", hidden=32, samples_per_class=20,
                     test_per_class=10)
    dep = channel.deploy(channel.WirelessConfig(
        num_devices=task.num_devices, seed=0))
    prm = make_prm(dep.gains, d=min(task.param_dim, 10000))
    schemes = [pcm.make_power_control(n, dep, prm) for n in ("sca", "ideal")]
    run = FLRunConfig(eta=0.05, num_rounds=6, eval_every=3)
    kw = dict(flat=False, seeds=(0, 2))
    res_ref = driver.run_fleet_task(task, schemes, dep.gains, run, **kw)
    pop = scn.Population.from_deployment(dep)
    # The cohort body is a DIFFERENT compiled program (gains/data arrive
    # as operands, not baked constants).  On the default topology it is
    # bitwise the pre-population path — the acceptance contract, pinned
    # here under tier-1.  Forced multi-device topologies
    # (--xla_force_host_platform_device_count) split the host's intra-op
    # threads differently per program, so large reductions may round at
    # ~1 ulp there; the key-stream traces must stay exact regardless.
    exact = jax.device_count() == 1
    for stream in (False, True):
        res_pop = driver.run_fleet_task(
            task, schemes, dep.gains, run, **kw, population=pop,
            cohort_size=task.num_devices, stream=stream)
        if exact:
            assert _params_equal(res_ref.params, res_pop.params)
            _histories_bitwise(res_ref, res_pop)
        else:
            assert np.array_equal(res_ref.traces["active_devices"],
                                  res_pop.traces["active_devices"])
            for k in res_ref.traces:
                np.testing.assert_allclose(res_ref.traces[k],
                                           res_pop.traces[k], rtol=1e-5,
                                           atol=1e-6, err_msg=k)
            for a, b in zip(jax.tree.leaves(res_ref.params),
                            jax.tree.leaves(res_pop.params)):
                np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                           rtol=1e-5, atol=1e-6)
        # one draw per chunk, stamped at each chunk's first round (the
        # eval-at-0/3/5 schedule chunks as [1, 3, 2] -> starts [0, 1, 4])
        assert [t for t, _ in res_pop.cohorts] == [0, 1, 4]
        for _, idx in res_pop.cohorts:
            assert np.array_equal(idx,
                                  np.tile(np.arange(task.num_devices),
                                          (2, 1)))


# ---------------------------------------------------------------------------
# streaming driver: overlap and preemption change nothing
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def cohort_world():
    dep = channel.deploy(channel.WirelessConfig(num_devices=10, seed=0))
    x, y, xt, yt = synthetic.mnist_like(40, seed=0)
    data = partition.stack_shards(partition.partition_by_label(x, y, 10,
                                                               seed=0))
    prm = make_prm(dep.gains, d=10000)
    params0 = init_params(mlp.mlp_defs(hidden=32), jax.random.PRNGKey(0))
    xt_j, yt_j = jnp.asarray(xt), jnp.asarray(yt)
    ev = jax.jit(lambda p: {"acc": mlp.accuracy(p, xt_j, yt_j)})
    pop = _traffic_pop(size=200, rho=0.9,
                       fading=channel.FadingSpec(family="rician",
                                                 rician_k=3.0))
    return dep, prm, data, params0, ev, pop


def test_stream_on_off_bitwise(cohort_world):
    """Double-buffered staging vs serialized staging: same params, traces,
    cohorts and Gauss-Markov re-entry — overlap only moves walls."""
    dep, prm, data, params0, ev, pop = cohort_world
    schemes = [pcm.make_power_control(n, dep, prm) for n in ("sca", "ideal")]
    run = FLRunConfig(eta=0.05, num_rounds=9, eval_every=3)
    kw = dict(seeds=(0, 2), flat=False, population=pop, cohort_size=10,
              cohort_rounds=3)
    res_on = driver.run_fleet(mlp.mlp_loss, params0, schemes, dep.gains,
                              data, run, ev, **kw, stream=True)
    res_off = driver.run_fleet(mlp.mlp_loss, params0, schemes, dep.gains,
                               data, run, ev, **kw, stream=False)
    assert _params_equal(res_on.params, res_off.params)
    _histories_bitwise(res_on, res_off)
    _cohorts_equal(res_on.cohorts, res_off.cohorts)
    # three distinct cohorts actually ran
    assert len(res_on.cohorts) == 3
    assert not np.array_equal(res_on.cohorts[0][1], res_on.cohorts[1][1])


def test_stream_kill_and_resume_bitwise(cohort_world, tmp_path):
    """Preempt the streaming loop at a chunk boundary mid-stream; the
    resumed run re-derives the cohort draws and re-entry states and ends
    bitwise identical to the uninterrupted stream."""
    dep, prm, data, params0, ev, pop = cohort_world
    schemes = [pcm.make_power_control(n, dep, prm) for n in ("sca", "ideal")]
    run = FLRunConfig(eta=0.05, num_rounds=9, eval_every=3)
    kw = dict(seeds=(0, 2), flat=False, population=pop, cohort_size=10,
              cohort_rounds=3, stream=True)
    args = (mlp.mlp_loss, params0, schemes, dep.gains, data, run, ev)
    path = os.path.join(tmp_path, "fleet")
    res_full = driver.run_fleet(*args, **kw)
    res_part = driver.run_fleet(*args, **kw, checkpoint_path=path,
                                max_chunks=1)
    assert res_part.traces["active_devices"].shape[-1] < run.num_rounds
    res_res = driver.run_fleet(*args, **kw, checkpoint_path=path,
                               resume=True)
    assert _params_equal(res_full.params, res_res.params)
    _histories_bitwise(res_full, res_res)
    _cohorts_equal(res_full.cohorts, res_res.cohorts)


def test_adaptive_cohort_redesign_streams_bitwise(cohort_world, tmp_path):
    """adaptive_sca in population mode re-solves (P1) on each incoming
    cohort's statistical CSI: the design trajectory moves across cohorts,
    is identical stream on/off, and survives kill-and-resume bitwise."""
    dep, prm, data, params0, ev, pop = cohort_world
    pc = pcm.make_power_control("adaptive_sca", dep, prm)
    assert pc.redesign_cohort_fn is not None
    run = FLRunConfig(eta=0.05, num_rounds=8, eval_every=4)
    kw = dict(seeds=(0,), flat=False, population=pop, cohort_size=10,
              cohort_rounds=2)
    args = (mlp.mlp_loss, params0, [pc], dep.gains, data, run, ev)
    res_on = driver.run_fleet(*args, **kw, stream=True)
    res_off = driver.run_fleet(*args, **kw, stream=False)
    assert _params_equal(res_on.params, res_off.params)
    assert len(res_on.designs) == len(res_off.designs) == 4
    for (ta, ga), (tb, gb) in zip(res_on.designs, res_off.designs):
        assert ta == tb and np.array_equal(ga, gb)
    g0 = np.asarray(res_on.designs[0][1])
    assert not all(np.array_equal(g0, np.asarray(g))
                   for _, g in res_on.designs[1:])

    path = os.path.join(tmp_path, "fleet")
    driver.run_fleet(*args, **kw, stream=True, checkpoint_path=path,
                     max_chunks=2)
    res_res = driver.run_fleet(*args, **kw, stream=True,
                               checkpoint_path=path, resume=True)
    assert _params_equal(res_on.params, res_res.params)
    assert len(res_on.designs) == len(res_res.designs)
    for (ta, ga), (tb, gb) in zip(res_on.designs, res_res.designs):
        assert ta == tb and np.array_equal(ga, gb)


def test_population_checkpoint_identity_rejects_mismatch(cohort_world,
                                                         tmp_path):
    """The population schedule is part of the checkpoint identity: a
    resume with a different cohort size or population is rejected."""
    dep, prm, data, params0, ev, pop = cohort_world
    schemes = [pcm.make_power_control("sca", dep, prm)]
    run = FLRunConfig(eta=0.05, num_rounds=4, eval_every=2)
    args = (mlp.mlp_loss, params0, schemes, dep.gains, data, run, ev)
    kw = dict(flat=False, population=pop, cohort_size=10)
    path = os.path.join(tmp_path, "fleet")
    driver.run_fleet(*args, **kw, checkpoint_path=path, max_chunks=1)
    other = _traffic_pop(size=201, rho=0.9,
                         fading=channel.FadingSpec(family="rician",
                                                   rician_k=3.0))
    with pytest.raises(ValueError, match="population"):
        driver.run_fleet(*args, flat=False, population=other,
                         cohort_size=10, checkpoint_path=path, resume=True)
    with pytest.raises(ValueError, match="cohort_rounds"):
        driver.run_fleet(*args, **kw, cohort_rounds=2,
                         checkpoint_path=path, resume=True)


def test_cohort_size_must_match_scheme_design(cohort_world):
    dep, prm, data, params0, ev, pop = cohort_world
    schemes = [pcm.make_power_control("sca", dep, prm)]    # 10-device world
    run = FLRunConfig(eta=0.05, num_rounds=2, eval_every=2)
    with pytest.raises(ValueError, match="cohort"):
        driver.run_fleet(mlp.mlp_loss, params0, schemes, dep.gains, data,
                         run, ev, flat=False, population=pop, cohort_size=7)


# ---------------------------------------------------------------------------
# chunk schedule: cohorts never straddle a chunk
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("t,e,c", [(9, 3, 3), (10, 4, 3), (12, 5, 4),
                                   (7, 10, 2), (6, 2, 6)])
def test_chunk_lengths_insert_cohort_boundaries(t, e, c):
    lengths = eng.chunk_lengths(t, e, with_eval=True, cohort_rounds=c)
    assert sum(lengths) == t and all(ln >= 1 for ln in lengths)
    ends = set(np.cumsum(lengths).tolist())
    # every eval round and every cohort's last round ends a chunk
    assert {r + 1 for r in range(t) if r % e == 0 or r == t - 1} <= ends
    assert {min(k + c, t) for k in range(0, t, c)} <= ends
    # and cohort_rounds=None keeps the old schedule exactly
    assert eng.chunk_lengths(t, e, True) == eng.chunk_lengths(
        t, e, True, cohort_rounds=None)
