"""Scenario-axis grid fleet (DESIGN.md §Grid).

Contract under test:

  * ``ScenarioStack`` rows reproduce the standalone ``FadingProcess`` for
    the row's (family, dynamics) BITWISE — init and step, including the
    Gauss-Markov state and dropout masks — even in a family-heterogeneous
    stack where vmap turns the per-row ``lax.switch`` into a select over
    every branch.
  * A [C x K x S] grid run (``run_fleet(..., scenarios=stack)``) is
    bitwise identical, cell for cell, to C separate per-scenario fleet
    runs: params, traces, evals.  In particular the C=1 grid IS today's
    fleet.
  * ShardedPlacement on the debug mesh reproduces the vmap grid per cell:
    key-stream traces bitwise, float traces/evals to the usual reduction
    tolerance (the same parity contract test_placement pins for plain
    fleets).
  * Mid-grid kill-and-resume is bitwise, and a resume against a DIFFERENT
    scenario axis (same scenario names, different realized gains) is
    rejected via the checkpoint identity.
  * Carry donation (params_b/fstate_b/keys_b) emits no donation warnings
    on either placement, and the sharded chunk reports its padded-cell
    fraction in ``chunk_compile`` telemetry and ``describe(cells=...)``.

The sharded tests need >= 4 host devices
(XLA_FLAGS=--xla_force_host_platform_device_count=8; the CI ``grid-smoke``
job forces them) and skip otherwise.
"""
import os
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import telemetry
from repro.core import power_control as pcm, scenarios as scn
from repro.data import partition, synthetic
from repro.fl import driver
from repro.fl.placement import ShardedPlacement, VmapPlacement
from repro.fl.server import FLRunConfig
from repro.launch.mesh import make_debug_mesh
from repro.models import mlp
from repro.models.param import init_params

needs_mesh = pytest.mark.skipif(
    jax.device_count() < 4,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")

# heterogeneous on purpose: an i.i.d. Rician row, a Gauss-Markov row and a
# dropout row exercise three different switch branches in ONE stack
SCENS = ("disk_rician", "disk_markov", "disk_dropout")
SCHEMES = ("sca", "zero_bias")
HIDDEN = 16


@pytest.fixture(scope="module")
def grid_world():
    x, y, xt, yt = synthetic.mnist_like(40, seed=0)
    data = partition.stack_shards(partition.partition_by_label(x, y, 10,
                                                               seed=0))
    params0 = init_params(mlp.mlp_defs(hidden=HIDDEN), jax.random.PRNGKey(0))
    xt_j, yt_j = jnp.asarray(xt), jnp.asarray(yt)
    ev = jax.jit(lambda p: {"acc": mlp.accuracy(p, xt_j, yt_j)})
    run = FLRunConfig(eta=0.05, num_rounds=7, eval_every=3, seed=0,
                      batch_size=0)
    return data, params0, ev, run


def _scenario_pcs(name, seed=0):
    sc = scn.get_scenario(name)
    dep = scn.realize(sc, seed=seed)
    prm = scn.make_ota_params(dep, d=10000, gmax=10.0, eta=0.05,
                              kappa_sq=4.0)
    return sc, dep, [pcm.make_power_control(s, dep, prm) for s in SCHEMES]


def _grid_inputs(scens=SCENS, seed=0):
    stack = scn.stack_scenarios(scens, seed=seed)
    flat_pcs = []
    for name in scens:
        flat_pcs += _scenario_pcs(name, seed=seed)[2]
    return stack, flat_pcs


def _run_grid(world, stack, flat_pcs, **kw):
    data, params0, ev, run = world
    kw.setdefault("etas", [run.eta] * len(flat_pcs))
    kw.setdefault("seeds", (0, 1))
    return driver.run_fleet(mlp.mlp_loss, params0, flat_pcs, None, data,
                            run, ev, flat=True, scenarios=stack, **kw)


def _leaves_equal(a, b):
    return all(bool(np.array_equal(np.asarray(x), np.asarray(y)))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


# ---------------------------------------------------------------------------
# stack vs standalone FadingProcess (the lax.switch union)
# ---------------------------------------------------------------------------

def test_stack_rows_match_fading_processes_bitwise():
    names = ["disk_rayleigh", "disk_rician", "disk_markov", "disk_dropout",
             "disk_nakagami"]
    stack = scn.stack_scenarios(names, seed=0)
    key = jax.random.PRNGKey(7)
    init_keys = jnp.stack(
        [jax.random.fold_in(jax.random.PRNGKey(s), 0x5CE7A810)
         for s in (0, 1)])
    state = stack.init_grid(init_keys)                       # [C, S, N]
    step_grid = jax.jit(jax.vmap(
        lambda row, st: jax.vmap(row.step, in_axes=(0, None))(st, key)))
    st2, h2 = step_grid(stack, state)
    for c, name in enumerate(names):
        sc = scn.get_scenario(name)
        dep = scn.realize(sc, seed=0)
        fp = scn.make_fading_process(dep, sc.dynamics)
        st_ref = fp.init_batch(init_keys)
        assert bool(jnp.all(state[c] == st_ref)), f"{name}: init"
        str_, hr = jax.jit(jax.vmap(lambda st: fp.step(st, key)))(st_ref)
        assert bool(jnp.all(st2[c] == str_)), f"{name}: state"
        assert bool(jnp.all(h2[c] == hr)), f"{name}: h"


def test_stack_builder_validation():
    import dataclasses as dc
    with pytest.raises(ValueError, match="at least one"):
        scn.stack_deployments([])
    sc = scn.get_scenario("disk_nakagami")
    dep = scn.realize(sc, seed=0)
    with pytest.raises(ValueError, match="nakagami"):
        scn.stack_deployments([dep], [scn.DynamicsSpec(rho=0.9)])
    shrunk = dc.replace(dep, gains=dep.gains[:5])
    with pytest.raises(ValueError, match="device count"):
        scn.stack_deployments([dep, shrunk])


def test_row_and_tile_layout():
    stack = scn.stack_scenarios(SCENS, seed=0)
    tiled = stack.tile_over_schemes(2)
    assert np.asarray(tiled.gains).shape[0] == len(SCENS) * 2
    # scenario-major: rows 2c and 2c+1 are scenario c
    for c in range(len(SCENS)):
        for j in (0, 1):
            assert np.array_equal(np.asarray(tiled.gains)[2 * c + j],
                                  np.asarray(stack.gains)[c])
    one = stack.row(1)
    assert one.names == (SCENS[1],)
    assert np.array_equal(np.asarray(one.gains)[0],
                          np.asarray(stack.gains)[1])


# ---------------------------------------------------------------------------
# grid fleet vs per-scenario fleets (vmap)
# ---------------------------------------------------------------------------

def test_grid_matches_per_scenario_fleets_bitwise(grid_world):
    data, params0, ev, run = grid_world
    stack, flat_pcs = _grid_inputs()
    grid = _run_grid(grid_world, stack, flat_pcs)
    assert grid.scenario_names == SCENS
    assert grid.names == tuple(f"{s}/{k}" for s in SCENS for k in SCHEMES)
    k_schemes = len(SCHEMES)
    for c, name in enumerate(SCENS):
        sc, dep, pcs = _scenario_pcs(name)
        fp = scn.make_fading_process(dep, sc.dynamics)
        res = driver.run_fleet(mlp.mlp_loss, params0, pcs, dep.gains, data,
                               run, ev, etas=[run.eta] * k_schemes,
                               seeds=(0, 1), flat=True, fading=fp)
        for ki in range(k_schemes):
            row = c * k_schemes + ki
            for lg, lr in zip(jax.tree.leaves(grid.params),
                              jax.tree.leaves(res.params)):
                assert np.array_equal(np.asarray(lg)[row],
                                      np.asarray(lr)[ki]), (name, ki)
            for tr in grid.traces:
                assert np.array_equal(grid.traces[tr][row],
                                      res.traces[tr][ki]), (name, ki, tr)
            for (tg, eg), (tr_, er) in zip(grid.evals, res.evals):
                assert tg == tr_
                assert np.array_equal(np.asarray(eg["acc"])[row],
                                      np.asarray(er["acc"])[ki]), (name, ki)


def test_c1_grid_is_todays_fleet_bitwise(grid_world):
    """The single-scenario slice of the grid machinery IS the plain fleet:
    a C=1 grid and a scenarios=None run produce identical bits."""
    data, params0, ev, run = grid_world
    name = SCENS[1]                                   # the stateful one
    stack, flat_pcs = _grid_inputs(scens=(name,))
    grid = _run_grid(grid_world, stack, flat_pcs)
    sc, dep, pcs = _scenario_pcs(name)
    fp = scn.make_fading_process(dep, sc.dynamics)
    res = driver.run_fleet(mlp.mlp_loss, params0, pcs, dep.gains, data, run,
                           ev, etas=[run.eta] * len(pcs), seeds=(0, 1),
                           flat=True, fading=fp)
    assert _leaves_equal(grid.params, res.params)
    assert set(grid.traces) == set(res.traces)
    for tr in grid.traces:
        assert np.array_equal(grid.traces[tr], res.traces[tr]), tr
    for (tg, eg), (tr_, er) in zip(grid.evals, res.evals):
        assert tg == tr_ and np.array_equal(np.asarray(eg["acc"]),
                                            np.asarray(er["acc"]))


def test_grid_input_validation(grid_world):
    data, params0, ev, run = grid_world
    stack, flat_pcs = _grid_inputs()
    with pytest.raises(ValueError, match="tile over"):
        _run_grid(grid_world, stack, flat_pcs[:-1],
                  etas=[run.eta] * (len(flat_pcs) - 1))
    with pytest.raises(ValueError, match="own the gains"):
        driver.run_fleet(mlp.mlp_loss, params0, flat_pcs,
                         np.ones(10), data, run, ev,
                         etas=[run.eta] * len(flat_pcs), flat=True,
                         scenarios=stack)
    fp = scn.make_fading_process(scn.realize(scn.get_scenario(SCENS[0]),
                                             seed=0),
                                 scn.get_scenario(SCENS[0]).dynamics)
    with pytest.raises(ValueError, match="channel process"):
        _run_grid(grid_world, stack, flat_pcs, fading=fp)


# ---------------------------------------------------------------------------
# checkpointed resume on the grid
# ---------------------------------------------------------------------------

def test_grid_kill_and_resume_bitwise(grid_world, tmp_path):
    stack, flat_pcs = _grid_inputs()
    cp = os.path.join(tmp_path, "grid")
    full = _run_grid(grid_world, stack, flat_pcs,
                     checkpoint_path=os.path.join(tmp_path, "full"))
    _run_grid(grid_world, stack, flat_pcs, checkpoint_path=cp, max_chunks=1)
    res = _run_grid(grid_world, stack, flat_pcs, checkpoint_path=cp,
                    resume=True)
    assert _leaves_equal(full.params, res.params)
    for tr in full.traces:
        assert np.array_equal(full.traces[tr], res.traces[tr]), tr
    for (tf, ef), (tr_, er) in zip(full.evals, res.evals):
        assert tf == tr_ and np.array_equal(np.asarray(ef["acc"]),
                                            np.asarray(er["acc"]))


def test_grid_resume_rejects_scenario_axis_mismatch(grid_world, tmp_path):
    """Same scenario NAMES, different realized world (seed) — only the
    gains digest and ScenarioStack descriptor differ, and the identity
    check must still refuse to mix them."""
    cp = os.path.join(tmp_path, "grid")
    stack, flat_pcs = _grid_inputs(seed=0)
    _run_grid(grid_world, stack, flat_pcs, checkpoint_path=cp, max_chunks=1)
    stack2, flat_pcs2 = _grid_inputs(seed=1)
    with pytest.raises(ValueError, match="does not match"):
        _run_grid(grid_world, stack2, flat_pcs2, checkpoint_path=cp,
                  resume=True)


# ---------------------------------------------------------------------------
# carry donation + pad-waste reporting
# ---------------------------------------------------------------------------

def test_vmap_grid_donation_emits_no_warning(grid_world):
    stack, flat_pcs = _grid_inputs()
    with warnings.catch_warnings(record=True) as wlog:
        warnings.simplefilter("always")
        _run_grid(grid_world, stack, flat_pcs)
    donation = [w for w in wlog if "donat" in str(w.message).lower()]
    assert not donation, [str(w.message) for w in donation]


@needs_mesh
def test_sharded_grid_donation_emits_no_warning(grid_world):
    stack, flat_pcs = _grid_inputs()
    pl = ShardedPlacement(make_debug_mesh(2, 2))
    with warnings.catch_warnings(record=True) as wlog:
        warnings.simplefilter("always")
        _run_grid(grid_world, stack, flat_pcs, placement=pl)
    donation = [w for w in wlog if "donat" in str(w.message).lower()]
    assert not donation, [str(w.message) for w in donation]


def test_describe_reports_pad_waste():
    assert VmapPlacement().describe(cells=12) == "vmap"
    if jax.device_count() >= 4:
        pl = ShardedPlacement(make_debug_mesh(2, 2))
        assert pl.describe() == "sharded[data=2,model=2]"
        assert pl.describe(cells=12) == "sharded[data=2,model=2," \
                                        "cells=12,pad=0/12]"
        assert pl.describe(cells=10) == "sharded[data=2,model=2," \
                                        "cells=10,pad=2/12]"


@needs_mesh
def test_sharded_chunk_compile_event_carries_padded_frac(grid_world,
                                                         tmp_path):
    """[C=3, K=2, S=3] = 18 cells on a 2x2 mesh pads to 20: the compile
    telemetry must say 10% of the compiled cells are masking waste."""
    stack, flat_pcs = _grid_inputs()
    pl = ShardedPlacement(make_debug_mesh(2, 2))
    tel = telemetry.Telemetry(run_dir=str(tmp_path / "run"))
    _run_grid(grid_world, stack, flat_pcs, placement=pl, seeds=(0, 1, 2),
              telemetry=tel)
    events = telemetry.read_events(tel.run_dir)
    compiles = [e for e in events if e.get("ev") == "chunk_compile"]
    assert compiles, "no chunk_compile events recorded"
    for e in compiles:
        assert e.get("padded_frac") == pytest.approx(2 / 20)


# ---------------------------------------------------------------------------
# sharded grid parity
# ---------------------------------------------------------------------------

@needs_mesh
def test_sharded_grid_matches_vmap(grid_world):
    """[C=3, K, S] family-heterogeneous grid: key-stream traces bitwise
    across placements, float traces/evals to the reduction tolerance
    (test_placement's plain-fleet parity contract, on the grid)."""
    stack, flat_pcs = _grid_inputs()
    vres = _run_grid(grid_world, stack, flat_pcs)
    sres = _run_grid(grid_world, stack, flat_pcs,
                     placement=ShardedPlacement(make_debug_mesh(2, 2)))
    assert set(vres.traces) == set(sres.traces)
    for tr in ("active_devices", "noise_scale"):
        assert np.array_equal(vres.traces[tr], sres.traces[tr]), tr
    # Norm-derived traces drift: the per-device block size changes the
    # reduction order inside each cell's global-norm (observed 2e-4 at
    # round 0 for this world's 12.7k-param reduction), and SGD compounds
    # it to a few 1e-3 over 7 rounds.
    for tr in vres.traces:
        np.testing.assert_allclose(vres.traces[tr], sres.traces[tr],
                                   rtol=2e-2, atol=1e-6, err_msg=tr)
    assert [t for t, _ in vres.evals] == [t for t, _ in sres.evals]
    for (_, ea), (_, eb) in zip(vres.evals, sres.evals):
        np.testing.assert_allclose(np.asarray(ea["acc"]),
                                   np.asarray(eb["acc"]), rtol=1e-5,
                                   atol=3e-3)


@needs_mesh
def test_sharded_grid_kill_and_resume_bitwise(grid_world, tmp_path):
    stack, flat_pcs = _grid_inputs()
    pl = ShardedPlacement(make_debug_mesh(2, 2))
    full = _run_grid(grid_world, stack, flat_pcs, placement=pl)
    cp = os.path.join(tmp_path, "sgrid")
    _run_grid(grid_world, stack, flat_pcs, placement=pl,
              checkpoint_path=cp, max_chunks=1)
    res = _run_grid(grid_world, stack, flat_pcs, placement=pl,
                    checkpoint_path=cp, resume=True)
    assert _leaves_equal(full.params, res.params)
    for tr in full.traces:
        assert np.array_equal(full.traces[tr], res.traces[tr]), tr


# ---------------------------------------------------------------------------
# engine-level guards
# ---------------------------------------------------------------------------

def test_round_body_scenario_exclusions():
    from repro.fl import engine as eng
    run = FLRunConfig(eta=0.05, num_rounds=2, eval_every=2)
    with pytest.raises(ValueError, match="exclusive"):
        eng.make_round_body(mlp.mlp_loss, None, run, scenario=True,
                            cohort=True)
    fp = scn.make_fading_process(
        scn.realize(scn.get_scenario("disk_rayleigh"), seed=0),
        scn.DynamicsSpec())
    with pytest.raises(ValueError, match="fading=None"):
        eng.make_round_body(mlp.mlp_loss, None, run, scenario=True,
                            fading=fp)


# ---------------------------------------------------------------------------
# report rendering: the bias-variance trajectory segments per scenario
# ---------------------------------------------------------------------------

def test_report_segments_bias_variance_per_scenario(grid_world, tmp_path,
                                                    capsys):
    """A telemetry-enabled grid run's checkpoint carries the scenario
    axis; the report tool must group the bv_* trajectory per scenario
    with the per-cell scheme labels stripped of their scope prefix."""
    stack, flat_pcs = _grid_inputs()
    cp = os.path.join(tmp_path, "grid")
    _run_grid(grid_world, stack, flat_pcs, checkpoint_path=cp,
              telemetry=telemetry.Telemetry(run_dir=str(tmp_path)))
    from repro.telemetry import report as rpt
    rpt.bias_variance(cp + ".npz", 3)
    out = capsys.readouterr().out
    for name in SCENS:
        assert f"scenario {name}" in out
    assert "scheme sca" in out and "scheme zero_bias" in out
    assert "disk_rician/sca" not in out       # prefix lives on the header
