"""Fleet telemetry subsystem (DESIGN.md §Telemetry).

Contracts pinned here:
  * the JSONL tracer appends whole lines, tolerates killed-mid-write
    partial lines, and ``resume(start_chunk)`` prunes a re-opened log to
    ONE consistent execution — run id preserved, completed chunks kept,
    superseded/untagged events dropped, one run_resume marker.
  * telemetry OFF is the default and the driver's results are bitwise
    identical with telemetry ON — the diagnostics ride extra ``bv_*``
    trace keys; every pre-existing key and the params are unchanged.
  * the bv_* diagnostics realize Theorem 1 per round: Ideal FedAvg has
    exactly zero noise variance and ~zero bias power; noisy schemes
    don't.
  * a telemetry-enabled kill-and-resume produces one event log: single
    run id, exactly one run_resume, no duplicated chunk_exec spans, and
    numerics bitwise vs the uninterrupted telemetry-on run.
  * the report tool renders a real run directory without error.
"""
import io
import json
import os
from contextlib import redirect_stdout

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import telemetry
from repro.core import channel, power_control as pcm, scenarios as scn
from repro.data import partition, synthetic
from repro.fl import driver, engine as eng
from repro.fl.server import FLRunConfig
from repro.models import mlp
from repro.models.param import init_params
from repro.telemetry import report as tlm_report
from tests.helpers import make_prm


def _params_equal(a, b):
    return all(bool(np.array_equal(np.asarray(x), np.asarray(y)))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


# ---------------------------------------------------------------------------
# tracer: append, read-back, kill-tolerance, resume pruning
# ---------------------------------------------------------------------------

def test_tracer_roundtrip_and_partial_lines(tmp_path):
    run_dir = str(tmp_path / "run")
    tr = telemetry.Tracer(run_dir)
    with tr.ctx(chunk=0):
        tr.event("stage", dur=0.5, tick=np.int64(3))      # numpy jsonifies
    with tr.span("eval", chunk=0):
        pass
    # a kill mid-write leaves a partial trailing line: reader skips it
    with open(tr.path, "a") as f:
        f.write('{"ev": "chunk_exec", "chunk": 1, "trunc')
    events = telemetry.read_events(run_dir)
    assert [e["ev"] for e in events] == ["run_start", "stage", "eval"]
    assert events[1]["chunk"] == 0 and events[1]["tick"] == 3
    assert events[2]["dur"] >= 0
    assert len({e["run"] for e in events}) == 1
    # monotonic clock is ordered even if wall steps
    assert events[0]["mono"] <= events[1]["mono"] <= events[2]["mono"]


def test_tracer_resume_prunes_to_completed_chunks(tmp_path):
    run_dir = str(tmp_path / "run")
    tr = telemetry.Tracer(run_dir)
    run_id = tr.run_id
    for ci in range(3):
        tr.event("chunk_exec", chunk=ci)
    tr.event("sca_solve", chunk=2)       # staging-thread event, re-run chunk
    tr.event("run_end")                  # untagged, superseded by the resume
    # killed here; a new process re-opens and fast-forwards to chunk 2
    tr2 = telemetry.Tracer(run_dir, fresh=False)
    assert tr2.run_id == run_id
    tr2.resume(start_chunk=2)
    tr2.event("chunk_exec", chunk=2)
    events = telemetry.read_events(run_dir)
    assert [e["ev"] for e in events] == [
        "run_start", "chunk_exec", "chunk_exec", "run_resume", "chunk_exec"]
    assert [e.get("chunk") for e in events if e["ev"] == "chunk_exec"] \
        == [0, 1, 2]
    assert {e["run"] for e in events} == {run_id}
    # fresh=True on the same dir starts over with a new id
    tr3 = telemetry.Tracer(run_dir)
    assert tr3.run_id != run_id
    assert [e["ev"] for e in telemetry.read_events(run_dir)] == ["run_start"]


def test_tracer_missing_log_degrades_to_fresh(tmp_path):
    tr = telemetry.Tracer(str(tmp_path / "nothing"), fresh=False)
    events = telemetry.read_events(tr.run_dir)
    assert [e["ev"] for e in events] == ["run_start"]


# ---------------------------------------------------------------------------
# driver integration: bitwise-off guarantee + diagnostics + resume log
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def pop_world():
    dep = channel.deploy(channel.WirelessConfig(num_devices=10, seed=0))
    x, y, xt, yt = synthetic.mnist_like(40, seed=0)
    data = partition.stack_shards(partition.partition_by_label(x, y, 10,
                                                               seed=0))
    prm = make_prm(dep.gains, d=10000)
    params0 = init_params(mlp.mlp_defs(hidden=32), jax.random.PRNGKey(0))
    xt_j, yt_j = jnp.asarray(xt), jnp.asarray(yt)
    ev = jax.jit(lambda p: {"acc": mlp.accuracy(p, xt_j, yt_j)})
    spec = scn.PopulationSpec(
        size=200, shadowing=scn.ShadowingSpec(sigma_db=6.0),
        fading=channel.FadingSpec(family="rician", rician_k=3.0),
        dynamics=scn.DynamicsSpec(rho=0.9), sampling="traffic",
        traffic_sigma=1.0, seed=7)
    pop = scn.Population(spec=spec)
    return dep, prm, data, params0, ev, pop


def test_telemetry_on_is_bitwise_off_plus_diagnostics(pop_world, tmp_path):
    """telemetry=None vs telemetry=Telemetry(...): identical params and
    pre-existing traces; ON adds the per-round bv_* Theorem-1 cells —
    Ideal FedAvg with exactly zero realized noise variance and ~zero bias
    power, the noisy SCA design with neither."""
    dep, prm, data, params0, ev, pop = pop_world
    schemes = [pcm.make_power_control(n, dep, prm) for n in ("sca", "ideal")]
    run = FLRunConfig(eta=0.05, num_rounds=6, eval_every=3)
    kw = dict(seeds=(0, 2), flat=False, population=pop, cohort_size=10,
              cohort_rounds=3)
    args = (mlp.mlp_loss, params0, schemes, dep.gains, data, run, ev)
    res_off = driver.run_fleet(*args, **kw)
    tel = telemetry.Telemetry(run_dir=str(tmp_path / "run"),
                              kappa_sq=float(prm.kappa_sq))
    res_on = driver.run_fleet(*args, **kw, telemetry=tel)

    assert _params_equal(res_off.params, res_on.params)
    for k in res_off.traces:
        assert np.array_equal(res_off.traces[k], res_on.traces[k]), k
    bv = sorted(k for k in res_on.traces if telemetry.is_diagnostic(k))
    assert bv == ["bv_bias_power", "bv_chan_power", "bv_noise_var",
                  "bv_weight_dev"]
    for k in bv:
        assert res_on.traces[k].shape == (2, 2, run.num_rounds)
        assert k not in res_off.traces
    # Theorem-1 sanity: ideal aggregation is the zero-bias zero-noise cell
    sca, ideal = 0, 1
    assert np.all(res_on.traces["bv_noise_var"][ideal] == 0.0)
    assert np.all(res_on.traces["bv_bias_power"][ideal] < 1e-10)
    assert np.all(res_on.traces["bv_noise_var"][sca] > 0.0)
    assert np.any(res_on.traces["bv_bias_power"][sca] > 0.0)
    # stage_walls: the per-chunk lane profile the bench breakdown reads
    lengths = eng.chunk_lengths(run.num_rounds, run.eval_every, True, 3)
    assert res_on.stage_walls is not None
    assert len(res_on.stage_walls) == len(lengths)
    assert all(w >= 0 for w in res_on.stage_walls)


def test_telemetry_off_adds_no_traces_and_no_files(pop_world, tmp_path):
    dep, prm, data, params0, ev, pop = pop_world
    schemes = [pcm.make_power_control("ideal", dep, prm)]
    run = FLRunConfig(eta=0.05, num_rounds=2, eval_every=2)
    res = driver.run_fleet(mlp.mlp_loss, params0, schemes, dep.gains, data,
                           run, ev, flat=False, population=pop,
                           cohort_size=10)
    assert not any(telemetry.is_diagnostic(k) for k in res.traces)
    assert list(tmp_path.iterdir()) == []


def test_telemetry_kill_and_resume_single_log(pop_world, tmp_path):
    """adaptive_sca streaming run preempted after 2 chunks, resumed with
    the SAME run dir: numerics bitwise vs the uninterrupted telemetry-on
    run; the event log keeps one run id, gains exactly one run_resume,
    and no chunk_exec span is duplicated or lost."""
    dep, prm, data, params0, ev, pop = pop_world
    pc = pcm.make_power_control("adaptive_sca", dep, prm)
    run = FLRunConfig(eta=0.05, num_rounds=8, eval_every=4)
    kw = dict(seeds=(0,), flat=False, population=pop, cohort_size=10,
              cohort_rounds=2, stream=True)
    args = (mlp.mlp_loss, params0, [pc], dep.gains, data, run, ev)

    full_dir = str(tmp_path / "full")
    res_full = driver.run_fleet(
        *args, **kw, telemetry=telemetry.Telemetry(run_dir=full_dir))
    full_events = telemetry.read_events(full_dir)
    chunks_full = sorted(e["chunk"] for e in full_events
                         if e["ev"] == "chunk_exec")

    res_dir = str(tmp_path / "resumed")
    tel = telemetry.Telemetry(run_dir=res_dir)
    path = str(tmp_path / "fleet")
    driver.run_fleet(*args, **kw, checkpoint_path=path, max_chunks=2,
                     telemetry=tel)
    pre = telemetry.read_events(res_dir)
    res_res = driver.run_fleet(*args, **kw, checkpoint_path=path,
                               resume=True, telemetry=tel)

    assert _params_equal(res_full.params, res_res.params)
    for k in res_full.traces:
        assert np.array_equal(res_full.traces[k], res_res.traces[k]), k

    events = telemetry.read_events(res_dir)
    assert {e["run"] for e in events} == {pre[0]["run"]}   # id preserved
    assert sum(1 for e in events if e["ev"] == "run_start") == 1
    assert sum(1 for e in events if e["ev"] == "run_resume") == 1
    chunks = [e["chunk"] for e in events if e["ev"] == "chunk_exec"]
    assert len(chunks) == len(set(chunks)), "duplicated chunk span"
    assert sorted(chunks) == chunks_full, "lost chunk span"
    # sca_solve events from the staging worker are chunk-tagged, so the
    # pruned log attributes every solve to exactly one surviving chunk
    solves = [e for e in events if e["ev"] == "sca_solve"]
    assert solves and all(isinstance(e.get("chunk"), int) for e in solves)


def test_resume_telemetry_does_not_change_numbers_vs_off(pop_world,
                                                         tmp_path):
    """The same kill-and-resume WITHOUT telemetry: bitwise equal to the
    telemetry-on resumed run (the observability never leaks into math)."""
    dep, prm, data, params0, ev, pop = pop_world
    pc = pcm.make_power_control("adaptive_sca", dep, prm)
    run = FLRunConfig(eta=0.05, num_rounds=8, eval_every=4)
    kw = dict(seeds=(0,), flat=False, population=pop, cohort_size=10,
              cohort_rounds=2, stream=True)
    args = (mlp.mlp_loss, params0, [pc], dep.gains, data, run, ev)
    p_off = str(tmp_path / "off")
    driver.run_fleet(*args, **kw, checkpoint_path=p_off, max_chunks=2)
    res_off = driver.run_fleet(*args, **kw, checkpoint_path=p_off,
                               resume=True)
    p_on = str(tmp_path / "on")
    tel = telemetry.Telemetry(run_dir=str(tmp_path / "run"))
    driver.run_fleet(*args, **kw, checkpoint_path=p_on, max_chunks=2,
                     telemetry=tel)
    res_on = driver.run_fleet(*args, **kw, checkpoint_path=p_on,
                              resume=True, telemetry=tel)
    assert _params_equal(res_off.params, res_on.params)
    for k in res_off.traces:
        assert np.array_equal(res_off.traces[k], res_on.traces[k]), k


# ---------------------------------------------------------------------------
# report tool
# ---------------------------------------------------------------------------

def test_report_renders_run_dir(pop_world, tmp_path):
    dep, prm, data, params0, ev, pop = pop_world
    pc = pcm.make_power_control("adaptive_sca", dep, prm)
    run = FLRunConfig(eta=0.05, num_rounds=6, eval_every=3)
    run_dir = str(tmp_path / "run")
    tel = telemetry.Telemetry(run_dir=run_dir,
                              kappa_sq=float(prm.kappa_sq))
    driver.run_fleet(mlp.mlp_loss, params0, [pc], dep.gains, data, run, ev,
                     seeds=(0,), flat=False, population=pop, cohort_size=10,
                     cohort_rounds=2,
                     checkpoint_path=os.path.join(run_dir, "fleet"),
                     telemetry=tel)
    out = io.StringIO()
    with redirect_stdout(out):
        tlm_report.main([run_dir])
    text = out.getvalue()
    for section in ("staging-lane timeline", "SCA solver",
                    "bias--variance trajectory", "cohort staleness",
                    "recompilation audit"):
        assert section in text, section
    assert "bv_bias_power" in text and "bv_noise_var" in text
    assert "staging overlap" in text
    with pytest.raises(SystemExit, match="events.jsonl"):
        tlm_report.main([str(tmp_path / "empty")])


def test_run_dir_string_shorthand(pop_world, tmp_path):
    """run_fleet(telemetry=<str>) builds a default Telemetry — the CLI
    convenience path."""
    dep, prm, data, params0, ev, pop = pop_world
    schemes = [pcm.make_power_control("ideal", dep, prm)]
    run = FLRunConfig(eta=0.05, num_rounds=2, eval_every=2)
    run_dir = str(tmp_path / "run")
    res = driver.run_fleet(mlp.mlp_loss, params0, schemes, dep.gains, data,
                           run, ev, flat=False, population=pop,
                           cohort_size=10, telemetry=run_dir)
    assert any(telemetry.is_diagnostic(k) for k in res.traces)
    assert os.path.exists(os.path.join(run_dir, telemetry.EVENTS_FILE))
