"""SCA power-control solver: descent, convergence, solution quality."""
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import channel, sca, theory
from tests.helpers import make_prm


@pytest.fixture(scope="module")
def prm():
    dep = channel.deploy(channel.WirelessConfig(num_devices=10, seed=0))
    return make_prm(dep.gains, d=814090)


def test_sca_monotone_descent(prm):
    res = sca.solve_sca(prm)
    assert res.converged
    diffs = np.diff(res.history)
    assert np.all(diffs <= 1e-9), res.history


def test_sca_beats_zero_bias(prm):
    """The optimized bias-variance trade-off must beat the zero-bias design
    under heterogeneity — the paper's core claim."""
    res = sca.solve_sca(prm)
    zb = theory.p1_objective(theory.zero_bias_gamma(prm), prm)
    assert res.objective < zb * 0.99


def test_sca_matches_direct_oracle(prm):
    res = sca.solve_sca(prm)
    oracle = sca.solve_direct(prm)
    assert res.objective <= oracle.objective * 1.02


def test_sca_solution_feasible(prm):
    res = sca.solve_sca(prm)
    assert np.all(res.gamma > 0)
    assert np.all(res.gamma <= theory.gamma_max(prm) * (1 + 1e-9))
    assert abs(res.p.sum() - 1.0) < 1e-9
    am = theory.alpha_of_gamma(res.gamma, prm)
    assert np.allclose(am, res.alpha * res.p, rtol=1e-9)   # coupling (i)


def test_sca_homogeneous_recovers_uniform():
    """Equal path loss => the optimum is (near-)uniform participation."""
    gains = np.full(8, 1e-12)
    prm = make_prm(gains)
    res = sca.solve_sca(prm)
    assert np.allclose(res.p, 1.0 / 8, atol=1e-3)


@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=0, max_value=10_000),
       st.integers(min_value=3, max_value=12))
def test_sca_descent_property(seed, n):
    rng = np.random.default_rng(seed)
    dists = rng.uniform(100.0, 1750.0, size=n)
    gains = channel.average_gain(dists)
    prm = make_prm(gains)
    res = sca.solve_sca(prm, max_iters=15)
    assert np.all(np.diff(res.history) <= 1e-9)
    assert res.objective <= res.history[0] + 1e-12
    assert abs(res.p.sum() - 1.0) < 1e-9


def test_sca_kappa_controls_bias():
    """Larger data heterogeneity (kappa) pushes the optimum toward uniform
    participation (less bias tolerated)."""
    dep = channel.deploy(channel.WirelessConfig(num_devices=10, seed=1))
    lo = sca.solve_sca(make_prm(dep.gains, kappa_sq=0.01))
    hi = sca.solve_sca(make_prm(dep.gains, kappa_sq=400.0))
    dev_lo = np.sum((lo.p - 0.1) ** 2)
    dev_hi = np.sum((hi.p - 0.1) ** 2)
    assert dev_hi < dev_lo
