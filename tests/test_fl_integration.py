"""End-to-end FL behaviour on the paper's task (reduced rounds)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import channel, power_control as pcm
from repro.data import partition, synthetic
from repro.fl.server import FLRunConfig, make_round_fn, run_fl
from repro.models import mlp
from repro.models.param import init_params
from tests.helpers import make_prm


@pytest.fixture(scope="module")
def world():
    wcfg = channel.WirelessConfig(num_devices=10, seed=0)
    dep = channel.deploy(wcfg)
    x, y, xt, yt = synthetic.mnist_like(120, seed=0)
    shards = partition.partition_by_label(x, y, 10, seed=0)
    xd, yd = partition.stack_shards(shards)
    prm = make_prm(dep.gains, d=mlp.PARAM_DIM)
    params0 = init_params(mlp.mlp_defs(), jax.random.PRNGKey(0))
    return dep, prm, (xd, yd), (xt, yt), params0


def _eval(xt, yt):
    xt, yt = jnp.asarray(xt), jnp.asarray(yt)

    @jax.jit
    def fn(params):
        return {"acc": mlp.accuracy(params, xt, yt)}
    return fn


def test_paper_dimension():
    assert mlp.PARAM_DIM == 814090                 # paper's d


@pytest.mark.parametrize("scheme", ["ideal", "sca"])
def test_fl_learns(world, scheme):
    dep, prm, data, (xt, yt), params0 = world
    pc = pcm.make_power_control(scheme, dep, prm)
    run = FLRunConfig(eta=0.05, num_rounds=40, eval_every=39)
    _, hist = run_fl(mlp.mlp_loss, params0, pc, dep.gains, data, run,
                     _eval(xt, yt))
    assert hist[-1]["acc"] > 0.8, hist


def test_interior_scheduler_generalizes_worse(world):
    """BB-FL Interior misses labels under non-iid split (paper Fig. 2)."""
    dep, prm, data, (xt, yt), params0 = world
    run = FLRunConfig(eta=0.05, num_rounds=40, eval_every=39)
    accs = {}
    for scheme in ["sca", "bbfl_interior"]:
        pc = pcm.make_power_control(scheme, dep, prm)
        _, hist = run_fl(mlp.mlp_loss, params0, pc, dep.gains, data, run,
                         _eval(xt, yt))
        accs[scheme] = hist[-1]["acc"]
    assert accs["bbfl_interior"] < accs["sca"] - 0.2


def test_round_fn_clips_to_gmax(world):
    dep, prm, data, _, params0 = world
    pc = pcm.make_power_control("ideal", dep, prm)
    run = FLRunConfig(eta=0.05, gmax=10.0)
    round_fn = make_round_fn(mlp.mlp_loss, pc, dep.gains, run)
    xd, yd = data
    _, metrics = round_fn(params0, (jnp.asarray(xd), jnp.asarray(yd)),
                          jax.random.PRNGKey(0))
    assert float(metrics["grad_norm_mean"]) > 0.0
    assert float(metrics["active_devices"]) == 10.0
