"""Shared test helpers (no optional dependencies — importable everywhere)."""
import numpy as np

from repro.core import channel
from repro.core.theory import OTAParams


def make_prm(gains, d=10000, gmax=10.0, sigma=0.0, eta=0.05, kappa_sq=4.0,
             fading=None):
    gains = np.asarray(gains, dtype=np.float64)
    wcfg = channel.WirelessConfig(num_devices=len(gains))
    return OTAParams(d=d, gmax=gmax, es=wcfg.energy_per_sample,
                     n0=wcfg.noise_psd, gains=gains,
                     sigma_sq=np.full(len(gains), sigma), eta=eta,
                     lsmooth=1.0, kappa_sq=kappa_sq, fading=fading)
