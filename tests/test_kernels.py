"""Per-kernel shape/dtype sweeps vs pure-jnp oracles (deliverable c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)


def _tol(dtype):
    # bf16: the kernel accumulates in fp32, the oracle in bf16 — the kernel
    # is the more accurate side, so tolerance covers oracle rounding
    return dict(rtol=6e-2, atol=6e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# ota_aggregate
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [1, 10, 32])
@pytest.mark.parametrize("d", [128, 1024, 5000, 65536])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ota_aggregate_sweep(n, d, dtype):
    k1, k2, k3 = jax.random.split(KEY, 3)
    g = jax.random.normal(k1, (n, d), dtype)
    s = jax.random.uniform(k2, (n,), jnp.float32)
    z = jax.random.normal(k3, (d,), jnp.float32)
    out = ops.ota_aggregate(g, s, z, jnp.float32(0.25))
    exp = ref.ota_aggregate_ref(g, s, z, jnp.float32(0.25))
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), **_tol(dtype))


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 16), st.integers(1, 2000), st.integers(0, 2**31 - 1))
def test_ota_aggregate_property(n, d, seed):
    k = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(k, 3)
    g = jax.random.normal(k1, (n, d))
    s = jax.random.uniform(k2, (n,))
    z = jax.random.normal(k3, (d,))
    out = ops.ota_aggregate(g, s, z, jnp.float32(0.0))
    exp = ref.ota_aggregate_ref(g, s, z, jnp.float32(0.0))
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("sq,sk", [(128, 128), (256, 256), (64, 256),
                                   (1, 512), (100, 100)])
@pytest.mark.parametrize("h,kh", [(4, 4), (4, 2), (8, 1)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(sq, sk, h, kh, dtype):
    k1, k2, k3 = jax.random.split(KEY, 3)
    dh = 64
    q = jax.random.normal(k1, (2, sq, h, dh), dtype)
    k = jax.random.normal(k2, (2, sk, kh, dh), dtype)
    v = jax.random.normal(k3, (2, sk, kh, dh), dtype)
    out = ops.flash_attention(q, k, v, causal=True)
    exp = ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), **_tol(dtype))


@pytest.mark.parametrize("window", [16, 64, 128])
def test_flash_attention_window(window):
    k1, k2, k3 = jax.random.split(KEY, 3)
    q = jax.random.normal(k1, (1, 256, 4, 32))
    k = jax.random.normal(k2, (1, 256, 2, 32))
    v = jax.random.normal(k3, (1, 256, 2, 32))
    out = ops.flash_attention(q, k, v, causal=True, window=window)
    exp = ref.attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_noncausal():
    k1, k2, k3 = jax.random.split(KEY, 3)
    q = jax.random.normal(k1, (1, 128, 2, 32))
    k = jax.random.normal(k2, (1, 128, 2, 32))
    v = jax.random.normal(k3, (1, 128, 2, 32))
    out = ops.flash_attention(q, k, v, causal=False)
    exp = ref.attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=2e-5, atol=2e-5)


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 3), st.sampled_from([32, 64, 128]),
       st.sampled_from([1, 2, 4]), st.integers(0, 2**31 - 1))
def test_flash_attention_property(b, s, kh, seed):
    kk = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(kk, 3)
    h, dh = kh * 2, 32
    q = jax.random.normal(k1, (b, s, h, dh))
    k = jax.random.normal(k2, (b, s, kh, dh))
    v = jax.random.normal(k3, (b, s, kh, dh))
    out = ops.flash_attention(q, k, v, causal=True)
    exp = ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=3e-5, atol=3e-5)


# ---------------------------------------------------------------------------
# ssd_scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("s,chunk", [(64, 16), (128, 128), (96, 32)])
@pytest.mark.parametrize("h,g", [(4, 1), (4, 2), (8, 8)])
def test_ssd_scan_sweep(s, chunk, h, g):
    k1, k2, k3, k4 = jax.random.split(KEY, 4)
    b, p, n = 2, 16, 16
    x = jax.random.normal(k1, (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(k2, (b, s, h)))
    a_neg = -jnp.exp(jax.random.normal(k3, (h,)) * 0.5)
    bm = jax.random.normal(k4, (b, s, g, n)) * 0.5
    cm = jax.random.normal(k1, (b, s, g, n)) * 0.5
    out = ops.ssd_scan(x, dt, a_neg, bm, cm, chunk=chunk)
    exp = ref.ssd_ref(x, dt, a_neg, bm, cm)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=2e-4, atol=2e-4)


def test_ssd_model_path_matches_ref():
    """models/ssm.ssd_chunked (the production path) == sequential oracle."""
    from repro.models.ssm import ssd_chunked
    k1, k2, k3, k4 = jax.random.split(KEY, 4)
    b, s, h, p, g, n = 2, 64, 4, 16, 2, 8
    x = jax.random.normal(k1, (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(k2, (b, s, h)))
    a_neg = -jnp.exp(jax.random.normal(k3, (h,)) * 0.5)
    bm = jax.random.normal(k4, (b, s, g, n)) * 0.5
    cm = jax.random.normal(k1, (b, s, g, n)) * 0.5
    y, _ = ssd_chunked(x, dt, a_neg, bm, cm, chunk=16)
    exp = ref.ssd_ref(x, dt, a_neg, bm, cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(exp),
                               rtol=2e-4, atol=2e-4)


def test_ssd_state_carry_consistency():
    """Splitting the sequence and carrying state == processing it whole."""
    from repro.models.ssm import ssd_chunked
    k1, k2, k3, k4 = jax.random.split(KEY, 4)
    b, s, h, p, g, n = 1, 64, 2, 8, 1, 8
    x = jax.random.normal(k1, (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(k2, (b, s, h)))
    a_neg = -jnp.exp(jax.random.normal(k3, (h,)) * 0.5)
    bm = jax.random.normal(k4, (b, s, g, n)) * 0.5
    cm = jax.random.normal(k1, (b, s, g, n)) * 0.5
    y_full, st_full = ssd_chunked(x, dt, a_neg, bm, cm, chunk=16)
    half = s // 2
    y1, st1 = ssd_chunked(x[:, :half], dt[:, :half], a_neg, bm[:, :half],
                          cm[:, :half], chunk=16)
    y2, st2 = ssd_chunked(x[:, half:], dt[:, half:], a_neg, bm[:, half:],
                          cm[:, half:], chunk=16, state0=st1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(st2), np.asarray(st_full),
                               rtol=1e-4, atol=1e-4)
