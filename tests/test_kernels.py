"""Per-kernel shape/dtype sweeps vs pure-jnp oracles (deliverable c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    # hypothesis is optional: the deterministic equivalence sweeps must
    # run everywhere (they are the kernel correctness gate); only the
    # property tests skip without it
    def _skip_prop(*args, **kwargs):
        def deco(fn):
            return pytest.mark.skip(reason="needs hypothesis")(fn)
        return deco

    given = settings = _skip_prop

    class st:  # noqa: N801 — placeholder so strategies parse at import
        def __getattr__(self, _):
            return lambda *a, **k: None
        integers = floats = sampled_from = booleans = lists = \
            staticmethod(lambda *a, **k: None)

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)


def _tol(dtype):
    # bf16: the kernel accumulates in fp32, the oracle in bf16 — the kernel
    # is the more accurate side, so tolerance covers oracle rounding
    return dict(rtol=6e-2, atol=6e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# ota_aggregate
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [1, 10, 32])
@pytest.mark.parametrize("d", [128, 1024, 5000, 65536])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ota_aggregate_sweep(n, d, dtype):
    k1, k2, k3 = jax.random.split(KEY, 3)
    g = jax.random.normal(k1, (n, d), dtype)
    s = jax.random.uniform(k2, (n,), jnp.float32)
    z = jax.random.normal(k3, (d,), jnp.float32)
    out = ops.ota_aggregate(g, s, z, jnp.float32(0.25))
    exp = ref.ota_aggregate_ref(g, s, z, jnp.float32(0.25))
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), **_tol(dtype))


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 16), st.integers(1, 2000), st.integers(0, 2**31 - 1))
def test_ota_aggregate_property(n, d, seed):
    k = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(k, 3)
    g = jax.random.normal(k1, (n, d))
    s = jax.random.uniform(k2, (n,))
    z = jax.random.normal(k3, (d,))
    out = ops.ota_aggregate(g, s, z, jnp.float32(0.0))
    exp = ref.ota_aggregate_ref(g, s, z, jnp.float32(0.0))
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# ota_round_step (fused round tail: dequant + aggregate + noise + SGD step)
# ---------------------------------------------------------------------------

def _round_operands(n, d, seed=0, wire=jnp.float32):
    k1, k2, k3, k4 = jax.random.split(jax.random.PRNGKey(seed), 4)
    g = jax.random.normal(k1, (n, d), jnp.float32)
    q_scale = None
    if wire == jnp.int8:
        g, q_scale = ops.quantize_uplink(g, "int8")
    elif wire != jnp.float32:
        g = g.astype(wire)
    s = jax.random.uniform(k2, (n,), jnp.float32)
    z = jax.random.normal(k3, (d,), jnp.float32)
    p = jax.random.normal(k4, (d,), jnp.float32)
    return g, s, z, p, q_scale


@pytest.mark.parametrize("n", [1, 10])
@pytest.mark.parametrize("d", [128, 1024, 5000])       # 5000: non-aligned
@pytest.mark.parametrize("wire", [jnp.float32, jnp.bfloat16, jnp.int8])
def test_ota_round_step_kernel_vs_ref(n, d, wire):
    """Interpret-mode Pallas kernel vs the flat jnp oracle, including the
    lane-padding edge (d=5000 is not a multiple of 8*128: padded g/z/params
    columns must never leak into the first d outputs)."""
    g, s, z, p, q_scale = _round_operands(n, d, wire=wire)
    ns, eta = jnp.float32(0.25), jnp.float32(0.05)
    out = ops.ota_round_step(g, s, z, ns, p, eta, q_scale,
                             interpret=True)
    exp = ref.ota_round_step_ref(g, s, z, ns, p, eta, q_scale=q_scale)
    assert out.shape == (d,)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=2e-5, atol=2e-5)


def _tree_oracle(grads, params, s, ns, k_noise, eta):
    # the historical per-leaf round tail: tree-map weighted sum, per-leaf
    # keyed receiver noise, per-leaf SGD update
    from repro.core import ota
    agg = ota.weighted_sum(grads, s)
    ghat = ota.add_receiver_noise(agg, ns, k_noise)
    return jax.tree.map(
        lambda p, g: (p.astype(jnp.float32)
                      - eta * g.astype(jnp.float32)).astype(p.dtype),
        params, ghat)


@pytest.mark.parametrize("shapes", [
    {"w": (17, 9), "b": (23,)},                 # non-aligned leaf sizes
    {"w": (64, 128), "b": (128,), "o": (3,)},
])
def test_ota_round_step_pytree_vs_tree_oracle(shapes):
    n = 6
    kg, kp, ks, kn = jax.random.split(KEY, 4)
    grads = {k: jax.random.normal(jax.random.fold_in(kg, i), (n,) + s)
             for i, (k, s) in enumerate(shapes.items())}
    params = {k: jax.random.normal(jax.random.fold_in(kp, i), s)
              for i, (k, s) in enumerate(shapes.items())}
    s = jax.random.uniform(ks, (n,), jnp.float32)
    ns, eta = jnp.float32(0.3), jnp.float32(0.05)
    exp = _tree_oracle(grads, params, s, ns, kn, eta)
    for kwargs in ({}, {"use_kernel": True, "interpret": True}):
        got = ops.ota_round_step_pytree(grads, s, ns, kn, params, eta,
                                        **kwargs)
        for k in shapes:
            np.testing.assert_allclose(np.asarray(got[k]),
                                       np.asarray(exp[k]),
                                       rtol=2e-5, atol=2e-5)


def test_ota_round_step_pytree_mixed_leaf_dtypes():
    """bf16 + f32 leaves: the fused path accumulates in the widest dtype
    and casts per leaf on unflatten; the kernel must agree with the CPU
    oracle, and both must track the tree oracle to bf16 tolerance."""
    n = 4
    kg, kp, ks, kn = jax.random.split(KEY, 4)
    grads = {"w": jax.random.normal(kg, (n, 40, 3), jnp.bfloat16),
             "b": jax.random.normal(jax.random.fold_in(kg, 1), (n, 50))}
    params = {"w": jax.random.normal(kp, (40, 3), jnp.bfloat16),
              "b": jax.random.normal(jax.random.fold_in(kp, 1), (50,))}
    s = jax.random.uniform(ks, (n,), jnp.float32)
    ns, eta = jnp.float32(0.3), jnp.float32(0.05)
    cpu = ops.ota_round_step_pytree(grads, s, ns, kn, params, eta)
    kern = ops.ota_round_step_pytree(grads, s, ns, kn, params, eta,
                                     use_kernel=True, interpret=True)
    exp = _tree_oracle(grads, params, s, ns, kn, eta)
    for k in grads:
        assert cpu[k].dtype == params[k].dtype
        np.testing.assert_allclose(np.asarray(kern[k], np.float32),
                                   np.asarray(cpu[k], np.float32),
                                   rtol=2e-2, atol=2e-2)
        np.testing.assert_allclose(np.asarray(cpu[k], np.float32),
                                   np.asarray(exp[k], np.float32),
                                   **_tol(params[k].dtype))


def test_ota_round_step_f32_bitwise_with_unfused_flat():
    """uplink_dtype="f32" fused == the pre-kernel flat path (aggregate
    via ota_aggregate_pytree, then the tree-map SGD update) — bitwise."""
    n = 10
    kg, kp, ks, kn = jax.random.split(KEY, 4)
    shapes = {"w": (31, 7), "b": (13,)}
    grads = {k: jax.random.normal(jax.random.fold_in(kg, i), (n,) + s)
             for i, (k, s) in enumerate(shapes.items())}
    params = {k: jax.random.normal(jax.random.fold_in(kp, i), s)
              for i, (k, s) in enumerate(shapes.items())}
    s = jax.random.uniform(ks, (n,), jnp.float32)
    ns, eta = jnp.float32(0.3), jnp.float32(0.05)
    ghat = ops.ota_aggregate_pytree(grads, s, ns, kn)
    old = jax.tree.map(
        lambda p, g: (p.astype(jnp.float32)
                      - eta * g.astype(jnp.float32)).astype(p.dtype),
        params, ghat)
    new = ops.ota_round_step_pytree(grads, s, ns, kn, params, eta)
    for k in shapes:
        assert np.array_equal(np.asarray(old[k]), np.asarray(new[k]))


def test_uplink_quantized_fused_matches_unfused():
    """bf16/int8: the fused step and the unfused quantized aggregation +
    update see the same wire values and the same f32 math — identical."""
    n = 5
    kg, kp, ks, kn = jax.random.split(KEY, 4)
    grads = {"w": jax.random.normal(kg, (n, 41, 5))}
    params = {"w": jax.random.normal(kp, (41, 5))}
    s = jax.random.uniform(ks, (n,), jnp.float32)
    ns, eta = jnp.float32(0.3), jnp.float32(0.05)
    for ud in ("bf16", "int8"):
        ghat = ops.ota_aggregate_pytree(grads, s, ns, kn, uplink_dtype=ud)
        unf = jax.tree.map(
            lambda p, g: (p.astype(jnp.float32)
                          - eta * g.astype(jnp.float32)).astype(p.dtype),
            params, ghat)
        fus = ops.ota_round_step_pytree(grads, s, ns, kn, params, eta,
                                        uplink_dtype=ud)
        np.testing.assert_array_equal(np.asarray(unf["w"]),
                                      np.asarray(fus["w"]))


def test_uplink_dtype_validation():
    g = jnp.ones((2, 8))
    with pytest.raises(ValueError):
        ops.quantize_uplink(g, "f16")
    from repro.core import ota
    with pytest.raises(ValueError):
        ota.apply_round_coeffs({"w": jnp.ones((2, 4))}, jnp.ones(2),
                               0.1, KEY, flat=False, uplink_dtype="int8")


@settings(max_examples=50, deadline=None)
@given(st.integers(1, 8), st.integers(1, 300), st.integers(0, 2**31 - 1),
       st.floats(1e-6, 1e4))
def test_int8_uplink_roundtrip_property(n, d, seed, scale_mag):
    """Quantize→dequantize error is bounded by half a quantization step
    per element (per-device symmetric scale = amax/127), at every
    magnitude: the scale must adapt per device, not globally."""
    k = jax.random.PRNGKey(seed)
    mags = jnp.logspace(-1, 1, n).reshape(n, 1) * scale_mag
    g = jax.random.normal(k, (n, d)) * mags
    wire, q_scale = ops.quantize_uplink(g, "int8")
    assert wire.dtype == jnp.int8
    back = ops.dequantize_uplink(wire, q_scale)
    step = np.asarray(q_scale)[:, None]
    err = np.abs(np.asarray(back) - np.asarray(g, np.float32))
    assert np.all(err <= 0.5 * step * (1 + 1e-5) + 1e-30)
    # and the wire really is symmetric: codes stay in [-127, 127]
    assert np.abs(np.asarray(wire)).max() <= 127


def test_run_fleet_f32_fused_bitwise_parity():
    """End-to-end acceptance pin: through ``driver.run_fleet`` the fused
    default (flat=True) is bitwise the pre-kernel unfused flat path
    (fuse_round=False) — params AND every per-round trace."""
    from repro.core import power_control as pcm, scenarios as scn
    from repro.data import partition, synthetic
    from repro.fl import driver
    from repro.fl.server import FLRunConfig
    from repro.models import mlp
    from repro.models.param import init_params

    dep = scn.realize(scn.get_scenario("disk_markov"))
    prm = scn.make_ota_params(dep, d=10000, gmax=10.0, eta=0.05,
                              kappa_sq=4.0)
    x, y, _, _ = synthetic.mnist_like(40, seed=0)
    data = partition.stack_shards(partition.partition_by_label(
        x, y, 10, seed=0))
    params0 = init_params(mlp.mlp_defs(hidden=16), jax.random.PRNGKey(0))
    schemes = [pcm.make_power_control(nm, dep, prm)
               for nm in ("vanilla", "ideal")]
    run = FLRunConfig(eta=0.05, num_rounds=4, eval_every=2, batch_size=8)
    args = (mlp.mlp_loss, params0, schemes, dep.gains, data, run)
    fused = driver.run_fleet(*args, flat=True, seeds=(0,))
    unfused = driver.run_fleet(*args, flat=True, seeds=(0,),
                               fuse_round=False)
    for a, b in zip(jax.tree.leaves(fused.params),
                    jax.tree.leaves(unfused.params)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    assert set(fused.traces) == set(unfused.traces)
    for k in fused.traces:
        assert np.array_equal(np.asarray(fused.traces[k]),
                              np.asarray(unfused.traces[k])), k


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("sq,sk", [(128, 128), (256, 256), (64, 256),
                                   (1, 512), (100, 100)])
@pytest.mark.parametrize("h,kh", [(4, 4), (4, 2), (8, 1)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(sq, sk, h, kh, dtype):
    k1, k2, k3 = jax.random.split(KEY, 3)
    dh = 64
    q = jax.random.normal(k1, (2, sq, h, dh), dtype)
    k = jax.random.normal(k2, (2, sk, kh, dh), dtype)
    v = jax.random.normal(k3, (2, sk, kh, dh), dtype)
    out = ops.flash_attention(q, k, v, causal=True)
    exp = ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), **_tol(dtype))


@pytest.mark.parametrize("window", [16, 64, 128])
def test_flash_attention_window(window):
    k1, k2, k3 = jax.random.split(KEY, 3)
    q = jax.random.normal(k1, (1, 256, 4, 32))
    k = jax.random.normal(k2, (1, 256, 2, 32))
    v = jax.random.normal(k3, (1, 256, 2, 32))
    out = ops.flash_attention(q, k, v, causal=True, window=window)
    exp = ref.attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_noncausal():
    k1, k2, k3 = jax.random.split(KEY, 3)
    q = jax.random.normal(k1, (1, 128, 2, 32))
    k = jax.random.normal(k2, (1, 128, 2, 32))
    v = jax.random.normal(k3, (1, 128, 2, 32))
    out = ops.flash_attention(q, k, v, causal=False)
    exp = ref.attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=2e-5, atol=2e-5)


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 3), st.sampled_from([32, 64, 128]),
       st.sampled_from([1, 2, 4]), st.integers(0, 2**31 - 1))
def test_flash_attention_property(b, s, kh, seed):
    kk = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(kk, 3)
    h, dh = kh * 2, 32
    q = jax.random.normal(k1, (b, s, h, dh))
    k = jax.random.normal(k2, (b, s, kh, dh))
    v = jax.random.normal(k3, (b, s, kh, dh))
    out = ops.flash_attention(q, k, v, causal=True)
    exp = ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=3e-5, atol=3e-5)


# ---------------------------------------------------------------------------
# ssd_scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("s,chunk", [(64, 16), (128, 128), (96, 32)])
@pytest.mark.parametrize("h,g", [(4, 1), (4, 2), (8, 8)])
def test_ssd_scan_sweep(s, chunk, h, g):
    k1, k2, k3, k4 = jax.random.split(KEY, 4)
    b, p, n = 2, 16, 16
    x = jax.random.normal(k1, (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(k2, (b, s, h)))
    a_neg = -jnp.exp(jax.random.normal(k3, (h,)) * 0.5)
    bm = jax.random.normal(k4, (b, s, g, n)) * 0.5
    cm = jax.random.normal(k1, (b, s, g, n)) * 0.5
    out = ops.ssd_scan(x, dt, a_neg, bm, cm, chunk=chunk)
    exp = ref.ssd_ref(x, dt, a_neg, bm, cm)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=2e-4, atol=2e-4)


def test_ssd_model_path_matches_ref():
    """models/ssm.ssd_chunked (the production path) == sequential oracle."""
    from repro.models.ssm import ssd_chunked
    k1, k2, k3, k4 = jax.random.split(KEY, 4)
    b, s, h, p, g, n = 2, 64, 4, 16, 2, 8
    x = jax.random.normal(k1, (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(k2, (b, s, h)))
    a_neg = -jnp.exp(jax.random.normal(k3, (h,)) * 0.5)
    bm = jax.random.normal(k4, (b, s, g, n)) * 0.5
    cm = jax.random.normal(k1, (b, s, g, n)) * 0.5
    y, _ = ssd_chunked(x, dt, a_neg, bm, cm, chunk=16)
    exp = ref.ssd_ref(x, dt, a_neg, bm, cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(exp),
                               rtol=2e-4, atol=2e-4)


def test_ssd_state_carry_consistency():
    """Splitting the sequence and carrying state == processing it whole."""
    from repro.models.ssm import ssd_chunked
    k1, k2, k3, k4 = jax.random.split(KEY, 4)
    b, s, h, p, g, n = 1, 64, 2, 8, 1, 8
    x = jax.random.normal(k1, (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(k2, (b, s, h)))
    a_neg = -jnp.exp(jax.random.normal(k3, (h,)) * 0.5)
    bm = jax.random.normal(k4, (b, s, g, n)) * 0.5
    cm = jax.random.normal(k1, (b, s, g, n)) * 0.5
    y_full, st_full = ssd_chunked(x, dt, a_neg, bm, cm, chunk=16)
    half = s // 2
    y1, st1 = ssd_chunked(x[:, :half], dt[:, :half], a_neg, bm[:, :half],
                          cm[:, :half], chunk=16)
    y2, st2 = ssd_chunked(x[:, half:], dt[:, half:], a_neg, bm[:, half:],
                          cm[:, half:], chunk=16, state0=st1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(st2), np.asarray(st_full),
                               rtol=1e-4, atol=1e-4)
