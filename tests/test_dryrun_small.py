"""Dry-run machinery on a small forced-device-count mesh (subprocess).

The production 512-device dry-run is exercised by launch/dryrun.py itself
(EXPERIMENTS.md §Dry-run); here we prove the same code path — lower, compile,
memory/cost analysis, collective parsing — on an 8-device debug mesh with
reduced configs, inside pytest.
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json, jax, jax.numpy as jnp
    from repro import configs, distributed as dist
    from repro.launch import mesh as mesh_lib, steps as steps_lib
    from repro.launch.hlo import collective_bytes, cost_analysis_dict
    from repro.launch.dryrun import _scheme_for
    from repro.models.registry import build_bundle
    from repro.configs.shapes import InputShape

    results = {}
    mesh = mesh_lib.make_debug_mesh(2, 2, multi_pod=True)   # (2,2,2)
    for arch, kind in [("granite-8b", "train"), ("mamba2-1.3b", "decode"),
                       ("mixtral-8x22b", "train"),
                       ("seamless-m4t-medium", "prefill")]:
        cfg = configs.get_config(arch).smoke()
        bundle = build_bundle(cfg, tp=2, dp=2)
        shape = InputShape("t", 64, 16, kind)
        with dist.mesh_rules(mesh):
            pshard = steps_lib.param_shardings(bundle, mesh)
            args, shardings = steps_lib.input_specs(bundle, shape, mesh)
            if kind == "train":
                scheme, dep = _scheme_for(bundle, mesh, "sca", 0.01)
                step = steps_lib.make_train_step(
                    bundle, scheme, dep.gains, steps_lib.TrainStepConfig())
            elif kind == "prefill":
                step = steps_lib.make_prefill_step(bundle)
            else:
                step = steps_lib.make_serve_step(bundle)
            jitted = jax.jit(step, in_shardings=(pshard,) + tuple(shardings))
            compiled = jitted.lower(bundle.abstract(), *args).compile()
        cost = cost_analysis_dict(compiled)
        coll = collective_bytes(compiled.as_text())
        mem = compiled.memory_analysis()
        results[arch + ":" + kind] = {
            "flops": float(cost.get("flops", -1)),
            "coll_total": coll["total"],
            "arg_bytes": int(mem.argument_size_in_bytes),
        }
    print("RESULTS" + json.dumps(results))
""")


@pytest.mark.slow
def test_debug_mesh_dryrun_all_kinds():
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=1200)
    assert out.returncode == 0, out.stderr[-4000:]
    line = [l for l in out.stdout.splitlines() if l.startswith("RESULTS")][0]
    results = json.loads(line[len("RESULTS"):])
    assert len(results) == 4
    for k, v in results.items():
        assert v["flops"] > 0, (k, v)
        assert v["coll_total"] > 0, (k, v)   # sharded => collectives exist
        assert v["arg_bytes"] > 0, (k, v)


def test_collective_bytes_parser():
    from repro.launch.hlo import collective_bytes, cost_analysis_dict
    hlo = """
      %ar = bf16[1024,32]{1,0} all-reduce(bf16[1024,32] %x), replica_groups={}
      %ag.1 = f32[64]{0} all-gather(f32[16] %y), dimensions={0}
      %cp = (f32[8]{0}, f32[8]{0}) collective-permute-start(f32[8] %z)
      %cpd = f32[8]{0} collective-permute-done(%cp)
      %a2a = f32[128,4]{1,0} all-to-all(f32[128,4] %w), dimensions={1}
    """
    out = collective_bytes(hlo)
    assert out["all-reduce"] == 1024 * 32 * 2
    assert out["all-gather"] == 64 * 4
    # start tuple (in+out buffers) counted once; -done skipped
    assert out["collective-permute"] == 8 * 4 * 2
    assert out["all-to-all"] == 128 * 4 * 4
    assert out["total"] == sum(v for k, v in out.items() if k != "total")
