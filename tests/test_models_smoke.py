"""Per-architecture smoke tests (deliverable f): reduced variants of each
assigned family — one forward/train step on CPU, asserting shapes + no NaNs,
plus prefill->decode consistency against the full forward.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models.registry import build_bundle

B, S = 2, 64


def _bundle(arch):
    cfg = configs.get_config(arch).smoke()
    return cfg, build_bundle(cfg, tp=1, dp=1)


def _batch(cfg, key, seq=S):
    if cfg.is_enc_dec:
        frames = jax.random.normal(key, (B, seq, cfg.d_model))
        toks = jax.random.randint(key, (B, seq + 1), 0, cfg.vocab_size)
        return (frames, toks)
    return jax.random.randint(key, (B, seq + 1), 0, cfg.vocab_size)


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_forward_and_train_step(arch, key):
    cfg, b = _bundle(arch)
    params = b.init(key)
    batch = _batch(cfg, key)
    loss, grads = jax.jit(jax.value_and_grad(b.loss))(params, batch)
    assert jnp.isfinite(loss), arch
    assert loss.shape == ()
    leaves = jax.tree.leaves(grads)
    assert all(jnp.all(jnp.isfinite(l)) for l in leaves), arch
    # one SGD step changes the loss
    new_params = jax.tree.map(lambda p, g: p - 0.1 * g, params, grads)
    loss2 = jax.jit(b.loss)(new_params, batch)
    assert jnp.isfinite(loss2)
    assert float(loss2) != float(loss)


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_logit_shapes(arch, key):
    cfg, b = _bundle(arch)
    params = b.init(key)
    caches = b.init_caches(B, S)
    if cfg.is_enc_dec:
        frames = jax.random.normal(key, (B, S, cfg.d_model))
        toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
        logits, _ = jax.jit(b.prefill)(params, (frames, toks), caches)
    else:
        toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
        logits, _ = jax.jit(b.prefill)(params, toks, caches)
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert jnp.all(jnp.isfinite(logits))


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_prefill_decode_matches_full_forward(arch, key):
    """serve path correctness: prefill S tokens, decode token S; its logits
    must match the full forward on S+1 tokens at the last position."""
    cfg = configs.get_config(arch).smoke()
    if cfg.moe_num_experts:
        # generous capacity: token-dropping legitimately differs between a
        # 33-token batch and a 32+1 split, which is not what this test probes
        cfg = cfg.replace(capacity_factor=8.0)
    b = build_bundle(cfg, tp=1, dp=1)
    params = b.init(key)
    seq = 32
    if cfg.is_enc_dec:
        frames = jax.random.normal(key, (B, seq, cfg.d_model))
        toks = jax.random.randint(key, (B, seq + 1), 0, cfg.vocab_size)
        from repro.models import encdec as em
        memory = em.encode(params, frames, cfg)
        full = em.decode_train(params, memory, toks, cfg)
        caches = b.init_caches(B, seq + 1)
        _, caches2 = b.prefill(params, (frames, toks[:, :seq]),
                               caches)
        logits1, _ = b.decode(params, caches2, toks[:, seq:seq + 1],
                              jnp.asarray(seq))
    else:
        toks = jax.random.randint(key, (B, seq + 1), 0, cfg.vocab_size)
        from repro.models import transformer as tfm
        full, _, _ = tfm.forward(params, toks, cfg)
        caches = b.init_caches(B, seq + 1)
        _, caches2 = b.prefill(params, toks[:, :seq], caches)
        logits1, _ = b.decode(params, caches2, toks[:, seq:seq + 1],
                              jnp.asarray(seq))
    ref = full[:, -1, :]
    got = logits1[:, -1, :]
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-2, atol=2e-2)
    # argmax agreement is the serving-relevant property
    assert np.mean(np.argmax(got, -1) == np.argmax(ref, -1)) >= 0.9


@pytest.mark.parametrize("arch", ["mamba2-1.3b", "recurrentgemma-9b",
                                  "mixtral-8x22b", "qwen1.5-0.5b"])
def test_long_context_decode_state_bounded(arch, key):
    """long_500k-capable archs: decode cache/state size must not scale with
    context length (ring buffer / recurrent state)."""
    if arch == "qwen1.5-0.5b":
        cfg = configs.long_context_config(arch).smoke(
            window=32, block_pattern=("swa",))
    else:
        cfg = configs.long_context_config(arch).smoke()
    b = build_bundle(cfg, tp=1, dp=1)
    short = jax.eval_shape(lambda: b.init_caches(B, 64))
    long = jax.eval_shape(lambda: b.init_caches(B, 4096))
    sz = lambda t: sum(np.prod(l.shape) for l in jax.tree.leaves(t))
    if arch in ("mamba2-1.3b",):
        assert sz(long) == sz(short)            # pure state
    else:
        assert sz(long) <= sz(short) * 70       # only full-attn layers grow
        # ring-buffered local/swa layers must be capped at the window
        win = cfg.window
        for leaf in jax.tree.leaves(long):
            if leaf.ndim == 4:                  # kv caches
                assert leaf.shape[1] <= 4096


def test_chameleon_early_fusion_interleave(key):
    """VLM early fusion: image VQ tokens and text tokens share the stream."""
    cfg, b = _bundle("chameleon-34b")
    params = b.init(key)
    text = jax.random.randint(key, (B, 32), 0, 256)
    image = jax.random.randint(key, (B, 33), 256, cfg.vocab_size)  # VQ span
    toks = jnp.concatenate([text, image], axis=1)
    loss = jax.jit(b.loss)(params, toks)
    assert jnp.isfinite(loss)


def test_deepseek_mtp_loss_added(key):
    cfg, b = _bundle("deepseek-v3-671b")
    params = b.init(key)
    toks = jax.random.randint(key, (B, S + 1), 0, cfg.vocab_size)
    loss_mtp = jax.jit(b.loss)(params, toks)
    cfg0 = cfg.replace(mtp_depth=0)
    b0 = build_bundle(cfg0, tp=1, dp=1)
    loss0 = jax.jit(b0.loss)({k: v for k, v in params.items()
                              if k != "mtp"}, toks)
    assert float(loss_mtp) > float(loss0)       # MTP adds weighted loss
