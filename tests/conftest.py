# NOTE: no XLA_FLAGS here on purpose — smoke tests and benches must see the
# single real CPU device; only launch/dryrun.py (separate process) forces 512.
import numpy as np
import pytest

import jax


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)
