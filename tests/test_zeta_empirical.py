"""Empirical validation of the paper's variance formula (eq. (10)).

Theorem 1 rests on E||g_hat - E[g_hat | w]||^2 <= zeta with

    zeta = Gmax^2 sum_m (p_m gamma_m/alpha - p_m^2)   (transmission)
         + sum_m p_m^2 sigma_m^2                       (mini-batch)
         + d N0 / alpha^2                              (receiver noise)

We draw many OTA rounds with FIXED per-client gradients (sigma_m = 0, as in
the paper's full-batch experiments) and check that the measured variance of
the aggregate matches the transmission + noise terms — i.e. the simulator,
the power-control schemes and the theory module agree about the same
physical quantity.  This is the strongest internal-consistency check of the
reproduction: eq. (6) dynamics against eq. (10) algebra.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import channel, ota, power_control as pcm, theory
from tests.helpers import make_prm

N, D = 10, 4000
ROUNDS = 4000


@pytest.fixture(scope="module")
def world():
    dep = channel.deploy(channel.WirelessConfig(num_devices=N, seed=0))
    prm = make_prm(dep.gains, d=D, gmax=10.0)
    # fixed client gradients with ||g_m|| = Gmax exactly (worst case of
    # Assumption 2, which is where the transmission-variance term is tight)
    g = jax.random.normal(jax.random.PRNGKey(0), (N, D))
    g = g / jnp.linalg.norm(g, axis=1, keepdims=True) * prm.gmax
    return dep, prm, g


def _empirical_variance(scheme, dep, g, rounds=ROUNDS):
    keys = jax.random.split(jax.random.PRNGKey(42), rounds)
    gains = jnp.asarray(dep.gains)

    @jax.vmap
    def one(k):
        h = ota.draw_fading(k, gains)
        return ota.ota_aggregate(g, scheme, h, k)

    outs = one(keys)
    mean = jnp.mean(outs, axis=0)
    return float(jnp.mean(jnp.sum((outs - mean) ** 2, axis=1)))


@pytest.mark.slow
@pytest.mark.parametrize("scheme_name", ["sca", "zero_bias", "lcpc"])
def test_variance_matches_zeta(world, scheme_name):
    """Measured var(g_hat) ~= transmission + noise terms of eq. (10).

    The transmission term in (10) uses ||g_m|| <= Gmax as an upper bound;
    with ||g_m|| = Gmax exactly it is tight up to cross-client terms, so we
    accept [0.5x, 1.1x] of the bound (it must also never be exceeded
    beyond sampling error).
    """
    dep, prm, g = world
    scheme = pcm.make_power_control(scheme_name, dep, prm)
    z = theory.zeta_terms(scheme.gamma, prm)
    predicted = z["transmission"] + z["noise"]       # sigma_m = 0
    measured = _empirical_variance(scheme, dep, g)
    assert measured <= predicted * 1.10, (measured, predicted)
    assert measured >= predicted * 0.50, (measured, predicted)


@pytest.mark.slow
def test_expected_aggregate_matches_p(world):
    """E[g_hat] = sum_m p_m g_m with p_m = alpha_m / alpha (eq. (8))."""
    dep, prm, g = world
    scheme = pcm.make_power_control("sca", dep, prm)
    keys = jax.random.split(jax.random.PRNGKey(7), ROUNDS)
    gains = jnp.asarray(dep.gains)

    @jax.vmap
    def one(k):
        h = ota.draw_fading(k, gains)
        return ota.ota_aggregate(g, scheme, h, k)

    mean = jnp.mean(one(keys), axis=0)
    expected = jnp.sum(jnp.asarray(scheme.p)[:, None] * g, axis=0)
    # cosine alignment of the bias direction
    cos = float(jnp.vdot(mean, expected)
                / (jnp.linalg.norm(mean) * jnp.linalg.norm(expected)))
    assert cos > 0.99, cos


@pytest.mark.slow
def test_sca_lower_variance_than_zero_bias(world):
    """The empirical counterpart of the paper's core claim: the optimized
    biased design has strictly lower update variance than the zero-bias
    design under heterogeneity."""
    dep, prm, g = world
    v_sca = _empirical_variance(
        pcm.make_power_control("sca", dep, prm), dep, g)
    v_zb = _empirical_variance(
        pcm.make_power_control("zero_bias", dep, prm), dep, g)
    assert v_sca < v_zb * 0.9, (v_sca, v_zb)