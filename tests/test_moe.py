"""MoE dispatch invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.models.config import ModelConfig
from repro.models.moe import _dispatch_indices, expert_capacity, moe_apply, moe_def
from repro.models.param import init_params


@settings(max_examples=40, deadline=None)
@given(st.integers(2, 8), st.integers(4, 64), st.integers(1, 8),
       st.integers(0, 2**31 - 1))
def test_dispatch_indices_invariants(e, a, cap, seed):
    rng = np.random.default_rng(seed)
    eid = jnp.asarray(rng.integers(0, e, size=a))
    slot, keep = _dispatch_indices(eid, cap, e)
    slot, keep, eid = np.asarray(slot), np.asarray(keep), np.asarray(eid)
    # kept slots are unique and within range
    kept = slot[keep]
    assert len(np.unique(kept)) == len(kept)
    assert np.all(kept < e * cap)
    # slot // cap equals the expert id for kept assignments
    assert np.all(kept // cap == eid[keep])
    # per-expert kept count never exceeds capacity
    for ex in range(e):
        assert np.sum(eid[keep] == ex) <= cap
    # earlier tokens win under overflow (rank by token order)
    for ex in range(e):
        idx = np.where(eid == ex)[0]
        expect_keep = idx[:cap]
        assert np.array_equal(idx[keep[idx]], expect_keep)


def _moe_cfg(e=4, k=2, shared=0):
    return ModelConfig(d_model=32, d_ff=64, moe_num_experts=e, moe_top_k=k,
                       moe_shared_experts=shared, moe_d_ff=48,
                       param_dtype=jnp.float32, compute_dtype=jnp.float32)


def test_moe_apply_shapes_and_aux(key):
    cfg = _moe_cfg()
    params = init_params(moe_def(cfg, tp=1, dp=1), key)
    x = jax.random.normal(key, (2, 16, cfg.d_model))
    y, aux = jax.jit(lambda p, x: moe_apply(p, x, cfg))(params, x)
    assert y.shape == x.shape
    assert jnp.all(jnp.isfinite(y))
    assert float(aux) >= 1.0 - 1e-3      # E * sum(f*P) >= 1 (balance optimum)


def test_moe_capacity_drops_tokens():
    cfg = _moe_cfg().replace(capacity_factor=0.1)
    assert expert_capacity(cfg, 1024) < 1024 * 2 // 4


def test_moe_shared_expert_always_on(key):
    cfg = _moe_cfg(shared=1)
    params = init_params(moe_def(cfg, tp=1, dp=1), key)
    x = jax.random.normal(key, (1, 8, cfg.d_model))
    y1, _ = moe_apply(params, x, cfg)
    # zero the routed experts: output must still be nonzero (shared path)
    p2 = dict(params)
    p2["wo"] = jnp.zeros_like(params["wo"])
    y2, _ = moe_apply(p2, x, cfg)
    assert float(jnp.max(jnp.abs(y2))) > 0.0


def test_moe_matches_dense_sum_when_k_equals_e(key):
    """top-k == num_experts with huge capacity => every expert processes
    every token; combine weights sum to 1."""
    cfg = _moe_cfg(e=2, k=2).replace(capacity_factor=4.0)
    params = init_params(moe_def(cfg, tp=1, dp=1), key)
    x = jax.random.normal(key, (1, 8, cfg.d_model))
    y, _ = moe_apply(params, x, cfg)

    # manual dense mixture with softmaxed weights
    logits = jnp.einsum("bsd,de->bse", x, params["router"])
    w = jax.nn.softmax(logits, -1)

    def expert(i):
        h = jnp.einsum("bsd,df->bsf", x, params["wi"][i, :, 0, :])
        h = jax.nn.silu(h) * jnp.einsum("bsd,df->bsf", x,
                                        params["wi"][i, :, 1, :])
        return jnp.einsum("bsf,fd->bsd", h, params["wo"][i])

    dense = sum(w[..., i:i + 1] * expert(i) for i in range(2))
    np.testing.assert_allclose(np.asarray(y), np.asarray(dense),
                               rtol=1e-4, atol=1e-4)
