"""Wireless channel model: statistics and units."""
import numpy as np
import pytest

from repro.core import channel


def test_path_loss_reference():
    assert channel.path_loss_db(1.0) == pytest.approx(50.0)
    # +22 dB per decade (exponent 2.2)
    assert (channel.path_loss_db(100.0)
            - channel.path_loss_db(10.0)) == pytest.approx(22.0)


def test_physical_constants():
    cfg = channel.WirelessConfig()
    assert cfg.ptx_watt == pytest.approx(1e-3)          # 0 dBm
    assert cfg.energy_per_sample == pytest.approx(1e-9)  # Ptx/B
    assert cfg.noise_psd == pytest.approx(10 ** (-17.3), rel=1e-6)


def test_deploy_deterministic():
    cfg = channel.WirelessConfig(num_devices=10, seed=3)
    d1, d2 = channel.deploy(cfg), channel.deploy(cfg)
    assert np.array_equal(d1.distances, d2.distances)
    assert np.all(d1.distances <= cfg.r_max)
    assert np.all(d1.distances >= 1.0)


def test_fading_second_moment():
    """E|h|^2 = Lambda under CN(0, Lambda)."""
    gains = np.array([1e-12, 5e-12, 2e-11])
    rng = np.random.default_rng(0)
    h = channel.draw_fading(rng, gains, num_rounds=200_000)
    emp = np.mean(np.abs(h) ** 2, axis=0)
    assert np.allclose(emp, gains, rtol=0.02)


def test_fading_quantile_matches_rayleigh():
    gains = np.array([1e-12])
    rng = np.random.default_rng(1)
    h = np.abs(channel.draw_fading(rng, gains, num_rounds=200_000))[:, 0]
    for q in (0.1, 0.5, 0.9):
        xq = channel.fading_magnitude_quantile(gains, q)[0]
        assert np.mean(h <= xq) == pytest.approx(q, abs=0.01)


def test_truncation_probability_matches_theory():
    """P(chi=1) = exp(-thr^2/Lambda) — the alpha_m formula's core."""
    from repro.core import theory
    from tests.helpers import make_prm
    gains = np.array([1e-12, 4e-12])
    prm = make_prm(gains)
    gamma = 0.7 * theory.gamma_max(prm)
    thr = theory.chi_threshold(gamma, prm)
    rng = np.random.default_rng(2)
    h = np.abs(channel.draw_fading(rng, gains, num_rounds=300_000))
    emp = (h >= thr[None, :]).mean(axis=0)
    assert np.allclose(emp, theory.expected_participation_indicator(gamma, prm),
                       atol=0.01)
