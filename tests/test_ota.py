"""OTA aggregation operators: expectation semantics + equivalences."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import channel, ota, power_control as pcm
from repro.kernels import ops as kops
from repro.kernels import ref as kref
from tests.helpers import make_prm

N, D = 10, 400


@pytest.fixture(scope="module")
def setup():
    dep = channel.deploy(channel.WirelessConfig(num_devices=N, seed=0))
    prm = make_prm(dep.gains, d=814090)
    g = jax.random.normal(jax.random.PRNGKey(7), (N, D))
    return dep, prm, g


def test_expected_aggregate_is_biased_combination(setup):
    """E[g_hat] = sum_m p_m g_m (eq. (8)) — the structured bias."""
    dep, prm, g = setup
    pc = pcm.make_power_control("sca", dep, prm)
    keys = jax.random.split(jax.random.PRNGKey(8), 6000)

    def one(k):
        h = ota.draw_fading(k, jnp.asarray(dep.gains))
        return ota.ota_aggregate(g, pc, h, k)

    mean = jnp.mean(jax.vmap(one)(keys), axis=0)
    expected = jnp.sum(jnp.asarray(pc.p)[:, None] * g, axis=0)
    resid = float(jnp.max(jnp.abs(mean - expected)))
    scale = float(jnp.max(jnp.abs(expected)))
    assert resid < 0.15 * max(scale, 1.0)


def test_ideal_aggregate_exact(setup):
    dep, prm, g = setup
    pc = pcm.make_power_control("ideal", dep, prm)
    key = jax.random.PRNGKey(9)
    h = ota.draw_fading(key, jnp.asarray(dep.gains))
    out = ota.ota_aggregate(g, pc, h, key)
    assert jnp.allclose(out, jnp.mean(g, axis=0), atol=1e-6)


def test_weighted_loss_formulation_equivalence(setup):
    """Per-client loss weights reproduce sum_m s_m grad f_m exactly
    (the pjit-native train-step path)."""
    dep, prm, _ = setup
    pc = pcm.make_power_control("sca", dep, prm)
    key = jax.random.PRNGKey(10)
    h = ota.draw_fading(key, jnp.asarray(dep.gains))
    s, _ = pc.round_coeffs(h, key)

    w_param = jax.random.normal(key, (D,))
    x = jax.random.normal(jax.random.PRNGKey(11), (N, 4, D))

    def local_loss(w, xm):                      # per-client quadratic
        return jnp.mean((xm @ w) ** 2)

    # explicit: sum_m s_m grad f_m
    grads = jax.vmap(lambda xm: jax.grad(local_loss)(w_param, xm))(x)
    explicit = jnp.sum(s[:, None] * grads, axis=0)

    # weighted-loss: grad of mean_m (N s_m) f_m
    wts = ota.per_client_loss_weights(s)

    def weighted(w):
        per = jax.vmap(lambda xm: local_loss(w, xm))(x)
        return jnp.mean(wts * per)

    implicit = jax.grad(weighted)(w_param)
    assert jnp.allclose(explicit, implicit, rtol=1e-5, atol=1e-6)


def test_pallas_kernel_matches_ota_semantics(setup):
    """kernels/ota_aggregate == core semantics given the same z draw."""
    dep, prm, g = setup
    pc = pcm.make_power_control("sca", dep, prm)
    key = jax.random.PRNGKey(12)
    h = ota.draw_fading(key, jnp.asarray(dep.gains))
    s, ns = pc.round_coeffs(h, key)
    z = jax.random.normal(key, (D,))
    out_kernel = kops.ota_aggregate(g, s, z, ns)
    out_ref = kref.ota_aggregate_ref(g, s, z, ns)
    assert jnp.allclose(out_kernel, out_ref, atol=1e-5)


def test_noise_variance_scaling(setup):
    """Receiver-noise power in the aggregate matches d * noise_scale^2."""
    dep, prm, _ = setup
    pc = pcm.make_power_control("zero_bias", dep, prm)
    key = jax.random.PRNGKey(13)
    h = ota.draw_fading(key, jnp.asarray(dep.gains))
    _, ns = pc.round_coeffs(h, key)
    zeros = jnp.zeros((N, D))
    keys = jax.random.split(key, 2000)
    outs = jax.vmap(lambda k: ota.ota_aggregate(zeros, pc, h, k))(keys)
    emp_var = float(jnp.mean(jnp.sum(outs ** 2, axis=1)))
    assert emp_var == pytest.approx(D * float(ns) ** 2, rel=0.1)
