"""Property tests for the non-iid partitioners (data/partition.py).

Ring protocol: the paper's N=10 bipartite matching, exactly.
Dirichlet(α): sample conservation, per-device minimums, and the
α-concentration law — per-device label histograms approach the global
histogram monotonically as α grows.  Properties are checked over
deterministic parameter grids (no optional deps) so this file runs in
the tier-1 suite everywhere.
"""
import numpy as np
import pytest

from repro.data import partition, synthetic


def _toy_labels(samples_per_class: int, num_classes: int = 10):
    y = np.repeat(np.arange(num_classes, dtype=np.int64), samples_per_class)
    x = np.arange(len(y), dtype=np.float32)[:, None]   # distinct rows
    return x, y


# ---------------------------------------------------------------------------
# Ring protocol (paper §IV)
# ---------------------------------------------------------------------------

def test_ring_matches_paper_n10_matching_exactly():
    """N=10, 2 labels/device, <= 2 devices/label: device m <- {m, m+1 mod 10}
    — the exact matching of the paper, not just any feasible assignment."""
    assign = partition.label_assignment(10, 10, labels_per_device=2,
                                        max_devices_per_label=2)
    assert assign == [tuple(((m + j) % 10) for j in range(2))
                      for m in range(10)]
    counts = np.zeros(10, int)
    for labs in assign:
        for l in labs:
            counts[l] += 1
    assert counts.max() == counts.min() == 2


@pytest.mark.parametrize("n_dev,lpd", [(2, 1), (3, 2), (5, 2), (7, 1),
                                       (10, 2)])
def test_ring_partition_conserves_and_respects_ownership(n_dev, lpd):
    """Every sample of an *owned* label lands on exactly one device (labels
    no device owns — possible when n_dev * lpd < num_classes — contribute
    nothing), and each device only holds its assigned labels."""
    x, y = _toy_labels(8)
    cap = max(2, (n_dev * lpd + 9) // 10)
    shards = partition.partition_by_label(x, y, n_dev, labels_per_device=lpd,
                                          max_devices_per_label=cap, seed=1)
    assign = partition.label_assignment(n_dev, 10, lpd, cap)
    owned = {l for labs in assign for l in labs}
    seen = np.concatenate([s[0][:, 0] for s in shards])
    n_owned = int(np.isin(y, sorted(owned)).sum())
    assert len(seen) == n_owned
    assert len(np.unique(seen)) == n_owned         # exactly once each
    for m, (_, ym) in enumerate(shards):
        assert set(np.unique(ym)) <= set(assign[m])


# ---------------------------------------------------------------------------
# Dirichlet(α)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("alpha,n_dev,seed",
                         [(0.05, 2, 0), (0.05, 16, 7), (0.3, 10, 3),
                          (1.0, 5, 11), (5.0, 8, 42), (50.0, 13, 99)])
def test_dirichlet_conserves_samples(alpha, n_dev, seed):
    x, y = _toy_labels(30)
    shards = partition.partition_dirichlet(x, y, n_dev, alpha=alpha,
                                           seed=seed)
    assert len(shards) == n_dev
    seen = np.concatenate([s[0][:, 0] for s in shards])
    assert len(seen) == len(y)                     # conserved ...
    assert len(np.unique(seen)) == len(y)          # ... and disjoint
    assert all(len(s[1]) >= 1 for s in shards)     # min_per_device repair
    for xm, ym in shards:
        # labels still match their samples after the shuffles
        assert np.array_equal(y[xm[:, 0].astype(int)], ym)


def _heterogeneity(alpha: float, n_dev: int = 10, seeds=range(6)) -> float:
    """Mean total-variation distance between per-device label histograms
    and the global histogram, averaged over partition seeds."""
    x, y = _toy_labels(100)
    num_classes = int(y.max()) + 1
    global_hist = np.bincount(y, minlength=num_classes) / len(y)
    tvs = []
    for seed in seeds:
        shards = partition.partition_dirichlet(x, y, n_dev, alpha=alpha,
                                               seed=seed)
        for _, ym in shards:
            hist = np.bincount(ym, minlength=num_classes) / max(len(ym), 1)
            tvs.append(0.5 * np.abs(hist - global_hist).sum())
    return float(np.mean(tvs))


def test_dirichlet_alpha_concentration_monotone():
    """Heterogeneity (TV to the global label law) decreases monotonically
    along a well-separated α ladder: small α = strong label skew, large α
    recovers the i.i.d. split."""
    ladder = [0.05, 0.3, 2.0, 20.0, 200.0]
    het = [_heterogeneity(a) for a in ladder]
    assert all(a > b for a, b in zip(het, het[1:])), het
    assert het[0] > 0.5          # strong skew at α = 0.05
    assert het[-1] < 0.1         # near-iid at α = 200


def test_dirichlet_min_per_device_infeasible_raises():
    x, y = _toy_labels(1, num_classes=2)     # 2 samples, 8 devices
    with pytest.raises(ValueError, match="not enough samples"):
        partition.partition_dirichlet(x, y, 8, alpha=1.0, seed=0,
                                      min_per_device=2)


def test_dirichlet_invalid_alpha_raises():
    x, y = _toy_labels(4)
    with pytest.raises(ValueError, match="alpha"):
        partition.partition_dirichlet(x, y, 4, alpha=0.0)


def test_dirichlet_stacks_for_fleet():
    """The Dirichlet shards rectangularize through stack_shards like the
    ring shards do (the fleet engine needs [N, D, ...] arrays)."""
    x, y, _, _ = synthetic.cifar_like(20, seed=0, test_per_class=5)
    shards = partition.partition_dirichlet(x, y, 10, alpha=0.5, seed=0)
    xd, yd = partition.stack_shards(shards)
    assert xd.shape[0] == 10 and xd.shape[2:] == (32, 32, 3)
    assert yd.shape == xd.shape[:2]
    assert xd.shape[1] == min(len(s[1]) for s in shards)


def test_stack_shards_pad_keeps_every_sample():
    """pad=True rectangularizes to the LARGEST shard by cyclic repetition:
    every original sample survives (no Dirichlet truncation loss), padded
    rows are exact repeats, and labels stay aligned with their samples."""
    x, y = _toy_labels(40)
    shards = partition.partition_dirichlet(x, y, 10, alpha=0.3, seed=5)
    sizes = [len(s[1]) for s in shards]
    xd, yd = partition.stack_shards(shards, pad=True)
    assert xd.shape[1] == max(sizes)
    for m, (xm, ym) in enumerate(shards):
        # the first len(shard) rows are the shard itself ...
        assert np.array_equal(xd[m, :len(ym), 0], xm[:, 0])
        # ... so no sample is lost, and the tail is cyclic repetition
        assert set(xd[m, :, 0]) == set(xm[:, 0])
        assert np.array_equal(y[xd[m, :, 0].astype(int)], yd[m])
    # default (truncating) behavior is unchanged
    xt, _ = partition.stack_shards(shards)
    assert xt.shape[1] == min(sizes)
