"""Scenario engine: per-family statistics, geometry, dynamics, FL plumbing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import channel, ota, power_control as pcm, scenarios as scn
from repro.core import theory
from repro.core.channel import FadingSpec
from tests.helpers import make_prm

GAINS = np.array([1e-12, 5e-12, 2e-11, 8e-11])


# ---------------------------------------------------------------------------
# Small-scale families: mean power, quantiles, participation statistics
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("spec", [
    None,
    FadingSpec("rician", rician_k=4.0),
    FadingSpec("rician", rician_k=np.array([0.5, 2.0, 8.0, 20.0])),
    FadingSpec("nakagami", nakagami_m=2.0),
    FadingSpec("nakagami", nakagami_m=np.array([0.6, 1.0, 2.0, 4.0])),
], ids=["rayleigh", "rician", "rician_per_device", "nakagami",
        "nakagami_per_device"])
def test_mean_power_matches_gains(spec):
    """E|h_m|^2 = Lambda_m for every family (numpy sampler)."""
    rng = np.random.default_rng(0)
    h = channel.draw_fading(rng, GAINS, num_rounds=200_000, spec=spec)
    emp = np.mean(np.abs(h) ** 2, axis=0)
    assert np.allclose(emp, GAINS, rtol=0.03)


@pytest.mark.parametrize("family,kw", [
    ("rician", dict(rician_k=3.0)),
    ("nakagami", dict(nakagami_m=1.8)),
])
def test_jax_samplers_mean_power(family, kw):
    """The jit-path samplers in core.ota preserve E|h|^2 = Lambda too."""
    gains = jnp.asarray(GAINS)
    keys = jax.random.split(jax.random.PRNGKey(1), 40_000)
    if family == "rician":
        draw = lambda k: ota.draw_fading_rician(k, gains, kw["rician_k"])
    else:
        draw = lambda k: ota.draw_fading_nakagami(k, gains, kw["nakagami_m"])
    h = jax.vmap(draw)(keys)
    emp = np.asarray(jnp.mean(jnp.abs(h) ** 2, axis=0))
    assert np.allclose(emp, GAINS, rtol=0.05)


@pytest.mark.parametrize("spec", [
    None,
    FadingSpec("rician", rician_k=4.0),
    FadingSpec("nakagami", nakagami_m=2.5),
], ids=["rayleigh", "rician", "nakagami"])
def test_magnitude_quantiles_match_empirical(spec):
    """Closed-form fading_magnitude_quantile == empirical MC quantiles."""
    for q in (0.1, 0.5, 0.9):
        cf = channel.fading_magnitude_quantile(GAINS, q, spec)
        mc = channel.fading_magnitude_quantile_mc(GAINS, q, spec,
                                                  num_draws=200_000, seed=2)
        assert np.allclose(cf, mc, rtol=0.02), (q, cf, mc)


@pytest.mark.parametrize("spec", [
    FadingSpec("rician", rician_k=4.0),
    FadingSpec("nakagami", nakagami_m=2.5),
], ids=["rician", "nakagami"])
def test_participation_indicator_off_rayleigh(spec):
    """E[chi] = SF(threshold) matches Monte Carlo for non-Rayleigh families."""
    prm = make_prm(GAINS, fading=spec)
    gamma = 0.8 * theory.gamma_max(prm)
    thr = theory.chi_threshold(gamma, prm)
    rng = np.random.default_rng(3)
    h = np.abs(channel.draw_fading(rng, GAINS, 300_000, spec=spec))
    emp = (h >= thr[None, :]).mean(axis=0)
    assert np.allclose(emp, theory.expected_participation_indicator(gamma, prm),
                       atol=0.01)


@pytest.mark.parametrize("spec", [
    FadingSpec("rician", rician_k=4.0),
    FadingSpec("nakagami", nakagami_m=2.5),
], ids=["rician", "nakagami"])
def test_gamma_max_is_argmax_off_rayleigh(spec):
    """The numeric gamma_max maximizes alpha_m(gamma) for each device."""
    prm = make_prm(GAINS, fading=spec)
    gm = theory.gamma_max(prm)
    am = theory.alpha_max(prm)
    for f in (0.8, 0.95, 1.05, 1.25):
        assert np.all(theory.alpha_of_gamma(f * gm, prm) <= am * (1 + 1e-6))


def test_nakagami_m1_reduces_to_rayleigh():
    """Nakagami-1 IS Rayleigh: closed forms must agree."""
    spec = FadingSpec("nakagami", nakagami_m=1.0)
    x = np.sqrt(GAINS) * 0.7
    assert np.allclose(channel.fading_magnitude_sf(GAINS, x, spec),
                       channel.fading_magnitude_sf(GAINS, x, None), rtol=1e-10)
    for q in (0.2, 0.8):
        assert np.allclose(channel.fading_magnitude_quantile(GAINS, q, spec),
                           channel.fading_magnitude_quantile(GAINS, q),
                           rtol=1e-10)


# ---------------------------------------------------------------------------
# Geometry and large-scale effects
# ---------------------------------------------------------------------------

def test_disk_baseline_bitwise_identical():
    """realize(disk_rayleigh) == channel.deploy bit-for-bit."""
    dep0 = channel.deploy(channel.WirelessConfig())
    dep = scn.realize(scn.get_scenario("disk_rayleigh"))
    assert np.array_equal(dep0.distances, dep.distances)
    assert np.array_equal(dep0.gains, dep.gains)


def test_geometries_respect_bounds():
    cfg = channel.WirelessConfig(num_devices=200, seed=1)
    rng = np.random.default_rng(1)
    ring = scn.sample_distances(scn.GeometrySpec("ring", r_min=1000.0), cfg,
                                np.random.default_rng(1))
    assert ring.min() >= 1000.0 and ring.max() <= cfg.r_max
    tc = scn.sample_distances(scn.GeometrySpec("two_cluster"), cfg,
                              np.random.default_rng(2))
    near = tc[tc < 800]
    far = tc[tc >= 800]
    assert len(near) and len(far)
    assert abs(near.mean() - 150.0) < 30 and abs(far.mean() - 1600.0) < 30
    grid = scn.sample_distances(
        scn.GeometrySpec("grid", distances=(10.0, 20.0) * 100), cfg, rng)
    assert np.array_equal(grid, np.array((10.0, 20.0) * 100))


def test_shadowing_db_std_matches_config():
    sc = scn.Scenario(name="tmp_shadow",
                      shadowing=scn.ShadowingSpec(sigma_db=8.0),
                      wireless=channel.WirelessConfig(num_devices=4000))
    dep = scn.realize(sc)
    assert dep.shadowing_db is not None
    assert dep.shadowing_db.std() == pytest.approx(8.0, rel=0.1)
    # shadowing is folded into gains multiplicatively
    base = channel.average_gain(dep.distances, dep.cfg.pl0_db,
                                dep.cfg.pl_exponent)
    resid_db = -10 * np.log10(dep.gains / base)
    assert np.allclose(resid_db, dep.shadowing_db)


def test_realize_deterministic_and_seed_override():
    sc = scn.get_scenario("two_cluster")
    d1, d2 = scn.realize(sc), scn.realize(sc)
    assert np.array_equal(d1.gains, d2.gains)
    d3 = scn.realize(sc, seed=99)
    assert not np.array_equal(d1.gains, d3.gains)


# ---------------------------------------------------------------------------
# Dynamics: Gauss-Markov correlation, dropout
# ---------------------------------------------------------------------------

def test_gauss_markov_autocorrelation():
    """Lag-1 autocorrelation of the fading process ~= rho; marginal power
    stays Lambda (stationarity)."""
    rho = 0.9
    dep = scn.realize(scn.get_scenario("disk_rayleigh"))
    fp = scn.make_fading_process(dep, scn.DynamicsSpec(rho=rho))
    state = fp.init(jax.random.PRNGKey(0))

    def step(state, key):
        state, h = fp.step(state, key)
        return state, h

    keys = jax.random.split(jax.random.PRNGKey(1), 4000)
    _, hs = jax.lax.scan(step, state, keys)
    hs = np.asarray(hs)  # [T, N] complex
    a, b = hs[:-1], hs[1:]
    emp_rho = np.real(np.mean(a.conj() * b, axis=0)) \
        / np.mean(np.abs(hs) ** 2, axis=0)
    assert np.allclose(emp_rho, rho, atol=0.05)
    assert np.allclose(np.mean(np.abs(hs) ** 2, axis=0), dep.gains, rtol=0.1)


def test_gauss_markov_rician_keeps_los():
    dep = scn.realize(scn.get_scenario("disk_rician"))
    fp = scn.make_fading_process(dep, scn.DynamicsSpec(rho=0.95))
    state = fp.init(jax.random.PRNGKey(0))
    keys = jax.random.split(jax.random.PRNGKey(1), 3000)
    _, hs = jax.lax.scan(lambda s, k: fp.step(s, k), state, keys)
    emp = np.mean(np.abs(np.asarray(hs)) ** 2, axis=0)
    assert np.allclose(emp, dep.gains, rtol=0.15)


def test_nakagami_markov_rejected():
    with pytest.raises(ValueError):
        scn.Scenario(name="bad", fading=FadingSpec("nakagami"),
                     dynamics=scn.DynamicsSpec(rho=0.5))
    dep = scn.realize(scn.get_scenario("disk_nakagami"))
    with pytest.raises(ValueError):
        scn.make_fading_process(dep, scn.DynamicsSpec(rho=0.5))


def test_dropout_rate_and_scheme_handling():
    p_drop = 0.3
    sc = scn.Scenario(name="tmp_dropout",
                      dynamics=scn.DynamicsSpec(p_dropout=p_drop))
    dep = scn.realize(sc)
    assert dep.p_dropout == p_drop
    fp = scn.make_fading_process(dep, sc.dynamics)
    state = fp.init(jax.random.PRNGKey(0))
    keys = jax.random.split(jax.random.PRNGKey(1), 2000)
    _, hs = jax.lax.scan(lambda s, k: fp.step(s, k), state, keys)
    hs = np.asarray(hs)
    assert np.mean(hs == 0) == pytest.approx(p_drop, abs=0.03)
    # global-CSI schemes auto-derive dropout-awareness from the deployment
    # and stay finite with h = 0 present
    prm = scn.make_ota_params(dep, d=814090, gmax=10.0)
    h = jnp.asarray(hs[np.argmax((hs == 0).sum(axis=1))])  # round w/ dropouts
    for name in ("vanilla", "opc", "bbfl_interior"):
        pc = pcm.make_power_control(name, dep, prm)
        assert pc.dropout_aware, name
        s, ns = pc.round_coeffs(h, jax.random.PRNGKey(2))
        assert bool(jnp.all(jnp.isfinite(s))) and bool(jnp.isfinite(ns)), name
        assert np.allclose(np.asarray(s)[np.asarray(h) == 0], 0.0), name
    # baseline deployments keep the exact pre-scenario code path
    base = scn.realize(scn.get_scenario("disk_rayleigh"))
    assert not pcm.make_power_control("vanilla", base, prm).dropout_aware
    # truncated inversion silences dropped devices with no special handling
    pc = pcm.make_power_control("zero_bias", dep, prm)
    s, _ = pc.round_coeffs(h, jax.random.PRNGKey(2))
    assert np.allclose(np.asarray(s)[np.asarray(h) == 0], 0.0)


def test_dropout_enters_statistical_csi():
    """E[chi] and alpha scale by (1 - p_dropout); empirical participation
    of a truncated scheme under dropout matches the designed p."""
    sc = scn.Scenario(name="tmp_dropout_csi",
                      dynamics=scn.DynamicsSpec(p_dropout=0.25))
    dep = scn.realize(sc)
    prm = scn.make_ota_params(dep, d=814090, gmax=10.0)
    prm0 = prm.replace(dropout=0.0)
    gamma = 0.7 * theory.gamma_max(prm0)
    assert np.allclose(theory.expected_participation_indicator(gamma, prm),
                       0.75 * theory.expected_participation_indicator(gamma,
                                                                      prm0))
    assert np.allclose(theory.alpha_max(prm), 0.75 * theory.alpha_max(prm0))
    assert np.allclose(theory.log_alpha_of_gamma(gamma, prm),
                       np.log(theory.alpha_of_gamma(gamma, prm)))
    # empirical: chi = 1{|h_eff| >= thr} with h_eff from the dropout process
    fp = scn.make_fading_process(dep, sc.dynamics)
    keys = jax.random.split(jax.random.PRNGKey(3), 20_000)
    _, hs = jax.lax.scan(lambda s, k: fp.step(s, k), fp.init(keys[0]), keys)
    thr = theory.chi_threshold(gamma, prm)
    emp = (np.abs(np.asarray(hs)) >= thr[None, :]).mean(axis=0)
    assert np.allclose(emp, theory.expected_participation_indicator(gamma, prm),
                       atol=0.02)


# ---------------------------------------------------------------------------
# Registry + FL integration
# ---------------------------------------------------------------------------

def test_registry_realizes_everywhere():
    for name in scn.scenario_names():
        sc = scn.get_scenario(name)
        dep = scn.realize(sc)
        assert dep.num_devices == sc.wireless.num_devices
        assert np.all(dep.gains > 0) and np.all(np.isfinite(dep.gains))
        prm = scn.make_ota_params(dep, d=814090, gmax=10.0)
        _, a, pm = theory.participation(0.7 * theory.gamma_max(prm), prm)
        assert a > 0 and abs(pm.sum() - 1.0) < 1e-9, name
        fp = scn.make_fading_process(dep, sc.dynamics)
        st = fp.init(jax.random.PRNGKey(0))
        st, h = fp.step(st, jax.random.PRNGKey(1))
        assert h.shape == (dep.num_devices,), name


def test_all_dropped_round_is_noop_not_nan():
    """Every global-CSI scheme survives a round where all devices dropped:
    s = 0 and noise_scale = 0 (a no-op PS update), never NaN/inf coeffs."""
    sc = scn.Scenario(name="tmp_all_drop",
                      dynamics=scn.DynamicsSpec(p_dropout=0.5))
    dep = scn.realize(sc)
    prm = scn.make_ota_params(dep, d=814090, gmax=10.0)
    h = jnp.zeros(dep.num_devices, jnp.complex64)
    for name in ("vanilla", "opc", "bbfl_interior", "bbfl_alternative"):
        pc = pcm.make_power_control(name, dep, prm)
        s, ns = pc.round_coeffs(h, jax.random.PRNGKey(0))
        assert np.allclose(np.asarray(s), 0.0), name
        assert float(ns) == 0.0, name


def test_per_device_fading_params_validated_against_num_devices():
    with pytest.raises(ValueError, match="per-device"):
        scn.get_scenario("disk_rician_mixed").replace(
            wireless=channel.WirelessConfig(num_devices=20))
    # matching length is fine
    scn.get_scenario("disk_rician_mixed").replace(
        wireless=channel.WirelessConfig(num_devices=10))


def test_registry_rejects_unknown_and_duplicates():
    with pytest.raises(ValueError):
        scn.get_scenario("nope")
    with pytest.raises(ValueError):
        scn.register_scenario(scn.get_scenario("disk_rayleigh"))


def test_fl_round_scenario_matches_default_path():
    """The stateful (FadingProcess) round path is bit-identical to the
    default i.i.d. Rayleigh path on the baseline scenario."""
    from repro.fl.server import FLRunConfig, make_round_fn

    dep = scn.realize(scn.get_scenario("disk_rayleigh"))
    prm = scn.make_ota_params(dep, d=50, gmax=10.0)
    pc = pcm.make_power_control("zero_bias", dep, prm)

    def loss(params, batch):
        x, y = batch
        return jnp.mean((x @ params["w"] - y) ** 2)

    params = {"w": jnp.zeros((5,), jnp.float32)}
    n = dep.num_devices
    x = jax.random.normal(jax.random.PRNGKey(0), (n, 8, 5))
    y = jax.random.normal(jax.random.PRNGKey(1), (n, 8))
    run = FLRunConfig(eta=0.05, gmax=10.0)

    default_fn = make_round_fn(loss, pc, dep.gains, run)
    fp = scn.make_fading_process(dep, scn.DynamicsSpec())
    scenario_fn = make_round_fn(loss, pc, dep.gains, run, fading=fp)

    key = jax.random.PRNGKey(42)
    p1, m1 = default_fn(params, (x, y), key)
    state = fp.init(jax.random.PRNGKey(7))
    p2, m2, _ = scenario_fn(params, (x, y), key, state)
    assert np.array_equal(np.asarray(p1["w"]), np.asarray(p2["w"]))
    assert float(m1["active_devices"]) == float(m2["active_devices"])


@pytest.mark.parametrize("name", ["disk_rician", "urban_canyon"])
def test_fl_runs_on_scenarios(name):
    """run_fl trains through arbitrary scenarios without special-casing."""
    from repro.fl.server import FLRunConfig, run_fl

    sc = scn.get_scenario(name)
    dep = scn.realize(sc)
    prm = scn.make_ota_params(dep, d=50, gmax=10.0)
    pc = pcm.make_power_control("zero_bias", dep, prm)
    fp = scn.make_fading_process(dep, sc.dynamics)

    def loss(params, batch):
        x, y = batch
        return jnp.mean((x @ params["w"] - y) ** 2)

    n = dep.num_devices
    w_true = np.ones(5, np.float32)
    x = np.random.default_rng(0).normal(size=(n, 32, 5)).astype(np.float32)
    y = (x @ w_true).astype(np.float32)
    params = {"w": jnp.zeros((5,), jnp.float32)}
    run = FLRunConfig(eta=0.1, num_rounds=30, eval_every=29, gmax=10.0)
    final, hist = run_fl(loss, params, pc, dep.gains, (x, y), run,
                         eval_fn=lambda p: {"mse": loss(p, (jnp.asarray(
                             x.reshape(-1, 5)), jnp.asarray(y.reshape(-1))))},
                         fading=fp)
    assert np.all(np.isfinite(np.asarray(final["w"])))
    assert hist[-1]["mse"] < hist[0]["mse"]
