"""Launch-layer step functions on a single device (semantics, not scale)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core import channel, ota, power_control as pcm
from repro.launch import steps as steps_lib
from repro.models.registry import build_bundle
from tests.helpers import make_prm

ARCH = "qwen1.5-0.5b"
N_CLIENTS = 4


@pytest.fixture(scope="module")
def world(key=jax.random.PRNGKey(0)):
    cfg = configs.get_config(ARCH).smoke()
    bundle = build_bundle(cfg, tp=1, dp=1)
    params = bundle.init(key)
    dep = channel.deploy(channel.WirelessConfig(num_devices=N_CLIENTS,
                                                seed=0))
    prm = make_prm(dep.gains, d=bundle.num_params)
    return cfg, bundle, params, dep, prm


def test_train_step_runs_and_updates(world, key):
    cfg, bundle, params, dep, prm = world
    scheme = pcm.make_power_control("sca", dep, prm)
    step = steps_lib.make_train_step(bundle, scheme, dep.gains,
                                     steps_lib.TrainStepConfig(eta=0.01))
    toks = jax.random.randint(key, (8, 33), 0, cfg.vocab_size)
    new_params, metrics = jax.jit(step)(params, toks, key)
    assert jnp.isfinite(metrics["loss"])
    changed = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                           params, new_params)
    assert max(jax.tree.leaves(changed)) > 0.0


def test_train_step_matches_explicit_ota(world, key):
    """The weighted-loss train step == explicit per-client grads + OTA
    aggregation (noise keyed identically), parameter by parameter."""
    cfg, bundle, params, dep, prm = world
    scheme = pcm.make_power_control("sca", dep, prm)
    eta = 0.01
    step = steps_lib.make_train_step(bundle, scheme, dep.gains,
                                     steps_lib.TrainStepConfig(eta=eta))
    toks = jax.random.randint(key, (8, 33), 0, cfg.vocab_size)
    new_params, _ = jax.jit(step)(params, toks, key)

    # explicit reference path, mirroring make_train_step's key usage
    k_fade, k_coeff, k_noise = jax.random.split(key, 3)
    h = ota.draw_fading(k_fade, jnp.asarray(dep.gains))
    s, ns = scheme.round_coeffs(h, k_coeff)
    per_client = toks.reshape(N_CLIENTS, 2, 33)
    grads = jax.vmap(lambda b: jax.grad(bundle.loss)(params, b))(per_client)
    agg = jax.tree.map(
        lambda g: jnp.sum(s.reshape(-1, *([1] * (g.ndim - 1))).astype(g.dtype)
                          * g, axis=0), grads)
    agg = ota.add_receiver_noise(agg, ns, k_noise)
    expect = jax.tree.map(lambda p, g: p - eta * g, params, agg)

    flat_a = jax.tree.leaves(new_params)
    flat_b = jax.tree.leaves(expect)
    for a, b in zip(flat_a, flat_b):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-5)


def test_ideal_step_is_plain_sgd(world, key):
    cfg, bundle, params, dep, prm = world
    step = steps_lib.make_ideal_train_step(
        bundle, steps_lib.TrainStepConfig(eta=0.01))
    toks = jax.random.randint(key, (4, 33), 0, cfg.vocab_size)
    new_params, m = jax.jit(step)(params, toks, key)
    loss, grads = jax.value_and_grad(bundle.loss)(params, toks)
    expect = jax.tree.map(lambda p, g: p - 0.01 * g, params, grads)
    for a, b in zip(jax.tree.leaves(new_params), jax.tree.leaves(expect)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                                   atol=1e-6)
    assert float(m["loss"]) == pytest.approx(float(loss))


def test_serve_step_emits_tokens(world, key):
    cfg, bundle, params, dep, prm = world
    serve = steps_lib.make_serve_step(bundle)
    caches = bundle.init_caches(2, 64)
    _, caches = bundle.prefill(
        params, jax.random.randint(key, (2, 32), 0, cfg.vocab_size), caches)
    tok = jax.random.randint(key, (2, 1), 0, cfg.vocab_size)
    nxt, caches = jax.jit(serve)(params, caches, tok, jnp.asarray(32))
    assert nxt.shape == (2, 1)
    assert int(nxt.min()) >= 0 and int(nxt.max()) < cfg.padded_vocab


def test_rglru_state_carry_consistency(key):
    """hybrid arch: prefill+decode over a split == full forward (state)."""
    from repro.models import rglru
    cfg = configs.get_config("recurrentgemma-9b").smoke()
    p = rglru.rglru_def(cfg, tp=1)
    from repro.models.param import init_params
    params = init_params(p, key)
    x = jax.random.normal(key, (1, 16, cfg.d_model))
    y_full, _ = rglru.rglru_apply(params, x, cfg)
    st = rglru.init_rglru_state(cfg, 1)
    y1, st = rglru.rglru_apply(params, x[:, :8], cfg, state=st)
    ys = [y1]
    for t in range(8, 16):
        yt, st = rglru.rglru_apply(params, x[:, t:t + 1], cfg, state=st,
                                   decode=True)
        ys.append(yt)
    y_split = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_split), np.asarray(y_full),
                               rtol=2e-3, atol=2e-3)
