"""Power-control schemes: per-round coefficient semantics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import channel, ota, power_control as pcm
from tests.helpers import make_prm

N = 10


@pytest.fixture(scope="module")
def setup():
    dep = channel.deploy(channel.WirelessConfig(num_devices=N, seed=0))
    prm = make_prm(dep.gains, d=814090)
    return dep, prm


@pytest.mark.parametrize("name", pcm.SCHEMES)
def test_coeff_shapes_and_finiteness(setup, name):
    dep, prm = setup
    pc = pcm.make_power_control(name, dep, prm)
    key = jax.random.PRNGKey(0)
    h = ota.draw_fading(key, jnp.asarray(dep.gains))
    s, ns = pc.round_coeffs(h, key)
    assert s.shape == (N,)
    assert jnp.all(jnp.isfinite(s)) and jnp.isfinite(ns)
    assert float(ns) >= 0.0


def test_truncated_expected_coeff_is_p(setup):
    """E[s_m] = E[chi] gamma / alpha = p_m for the SCA scheme."""
    dep, prm = setup
    pc = pcm.make_power_control("sca", dep, prm)
    keys = jax.random.split(jax.random.PRNGKey(1), 4000)

    def one(k):
        h = ota.draw_fading(k, jnp.asarray(dep.gains))
        s, _ = pc.round_coeffs(h, k)
        return s

    s_mean = np.asarray(jnp.mean(jax.vmap(one)(keys), axis=0))
    assert np.allclose(s_mean, pc.p, atol=0.02)


def test_vanilla_unbiased_and_csi_flags(setup):
    dep, prm = setup
    van = pcm.make_power_control("vanilla", dep, prm)
    key = jax.random.PRNGKey(2)
    h = ota.draw_fading(key, jnp.asarray(dep.gains))
    s, _ = van.round_coeffs(h, key)
    assert np.allclose(np.asarray(s), 1.0 / N)       # zero instantaneous bias
    assert van.requires_global_csi
    assert not pcm.make_power_control("sca", dep, prm).requires_global_csi
    assert not pcm.make_power_control("lcpc", dep, prm).requires_global_csi


def test_opc_mse_not_worse_than_vanilla(setup):
    """OPC optimizes the per-round MSE objective vanilla implicitly uses."""
    dep, prm = setup
    opc = pcm.make_power_control("opc", dep, prm)
    van = pcm.make_power_control("vanilla", dep, prm)
    gmax, n0 = prm.gmax, prm.n0

    def mse(s, ns):
        return float(gmax ** 2 * jnp.sum((s - 1.0 / N) ** 2) + ns ** 2)

    keys = jax.random.split(jax.random.PRNGKey(3), 200)
    worse = 0
    for k in keys:
        h = ota.draw_fading(k, jnp.asarray(dep.gains))
        mo = mse(*opc.round_coeffs(h, k))
        mv = mse(*van.round_coeffs(h, k))
        worse += mo > mv * 1.05
    assert worse < 10      # grid resolution allows rare tiny regressions


def test_bbfl_interior_masks_far_devices(setup):
    dep, prm = setup
    bb = pcm.make_power_control("bbfl_interior", dep, prm)
    key = jax.random.PRNGKey(4)
    h = ota.draw_fading(key, jnp.asarray(dep.gains))
    s, _ = bb.round_coeffs(h, key)
    far = dep.distances > 0.6 * dep.cfg.r_max
    assert np.all(np.asarray(s)[far] == 0.0)
    assert np.asarray(s).sum() == pytest.approx(1.0)


def test_ideal_is_noiseless(setup):
    dep, prm = setup
    pc = pcm.make_power_control("ideal", dep, prm)
    key = jax.random.PRNGKey(5)
    h = ota.draw_fading(key, jnp.asarray(dep.gains))
    s, ns = pc.round_coeffs(h, key)
    assert float(ns) == 0.0
    assert np.allclose(np.asarray(s), 1.0 / N)


def test_lcpc_common_prescaler(setup):
    dep, prm = setup
    pc = pcm.make_power_control("lcpc", dep, prm)
    assert np.allclose(pc.gamma, pc.gamma[0])        # common gamma
