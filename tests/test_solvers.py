"""JAX-native SCA solver subsystem (repro.solvers, DESIGN.md §Solvers).

Three contracts:
  * theory parity: the jnp port of the Theorem-1 quantities agrees with
    the float64 numpy/scipy reference (core/theory.py) to 1e-6 relative
    across all fading families and random OTAParams (hypothesis);
  * solver quality: ``solve``/``solve_batch`` match the scipy SLSQP
    oracle's (P1) objective (1e-3 required, ~1e-6 typical), with monotone
    descent history;
  * adaptive engine: ``AdaptiveSCA`` inside ``run_fleet`` re-designs from
    the drifting Gauss-Markov CSI (operating point moves) while static-CSI
    runs stay bit-identical to the plain ``sca`` scheme.
"""
import numpy as np
import pytest

try:        # only the property test needs hypothesis (CI installs it)
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

import jax
import jax.numpy as jnp
from jax.experimental import enable_x64

from repro import solvers
from repro.core import channel, sca, theory
from repro.core.channel import FadingSpec
from repro.solvers import theory_jax as tj
from tests.helpers import make_prm


def _rel(a, b):
    a, b = np.asarray(a, np.float64), np.asarray(b, np.float64)
    return np.max(np.abs(a - b) / np.maximum(np.abs(b), 1e-30))


def _random_prm(seed, n, family):
    rng = np.random.default_rng(seed)
    dists = rng.uniform(80.0, 1750.0, size=n)
    gains = channel.average_gain(dists)
    if family == "rayleigh":
        fading = None
    elif family == "rician":
        fading = FadingSpec(family="rician",
                            rician_k=rng.uniform(0.2, 12.0, size=n))
    else:
        fading = FadingSpec(family="nakagami",
                            nakagami_m=rng.uniform(0.6, 4.0, size=n))
    return make_prm(gains, d=814090, sigma=float(rng.uniform(0.0, 2.0)),
                    kappa_sq=float(rng.uniform(0.5, 16.0)), fading=fading)


# ---------------------------------------------------------------------------
# jnp-vs-numpy theory parity (satellite: 1e-6 across families)
# ---------------------------------------------------------------------------

def _check_theory_parity(seed, n, family):
    prm = _random_prm(seed, n, family)
    with enable_x64():
        pj = tj.from_ota(prm)
        gm_np = theory.gamma_max(prm)
        gm_j = np.asarray(tj.gamma_max(pj))
        assert _rel(gm_j, gm_np) < 1e-6

        gamma = 0.7 * gm_np
        assert _rel(np.asarray(tj.log_alpha_of_gamma(jnp.asarray(gamma), pj)),
                    theory.log_alpha_of_gamma(gamma, prm)) < 1e-6
        z_np = theory.zeta_terms(gamma, prm)
        z_j = tj.zeta_terms(jnp.asarray(gamma), pj)
        for k in ("transmission", "minibatch", "noise", "total"):
            assert abs(float(z_j[k]) - z_np[k]) \
                <= 1e-6 * max(1e-30, abs(z_np["total"])), k
        assert _rel(float(tj.p1_objective(jnp.asarray(gamma), pj)),
                    theory.p1_objective(gamma, prm)) < 1e-6


@pytest.mark.parametrize("family", ["rayleigh", "rician", "nakagami"])
@pytest.mark.parametrize("seed,n", [(0, 5), (7, 10)])
def test_theory_parity_fixed(seed, n, family):
    _check_theory_parity(seed, n, family)


if HAVE_HYPOTHESIS:
    @settings(max_examples=8, deadline=None)
    @given(st.integers(min_value=0, max_value=10_000),
           st.integers(min_value=3, max_value=12),
           st.sampled_from(["rayleigh", "rician", "nakagami"]))
    def test_theory_parity_property(seed, n, family):
        _check_theory_parity(seed, n, family)


@pytest.mark.parametrize("family", ["rayleigh", "rician", "nakagami"])
def test_theory_parity_with_dropout(family):
    prm = _random_prm(3, 8, family).replace(dropout=0.15)
    with enable_x64():
        pj = tj.from_ota(prm)
        gm = theory.gamma_max(prm)
        assert _rel(np.asarray(tj.alpha_max(pj)), theory.alpha_max(prm)) < 1e-6
        gamma = 0.5 * gm
        assert _rel(np.asarray(tj.alpha_of_gamma(jnp.asarray(gamma), pj)),
                    theory.alpha_of_gamma(gamma, prm)) < 1e-6
        assert _rel(float(tj.p1_objective(jnp.asarray(gamma), pj)),
                    theory.p1_objective(gamma, prm)) < 1e-6


def test_marcum_q1_matches_scipy_rice():
    from scipy.stats import rice
    with enable_x64():
        a = jnp.asarray([0.0, 0.3, 1.0, 3.0, 7.0], jnp.float64)[:, None]
        b = jnp.asarray([0.1, 0.5, 1.0, 2.0, 5.0], jnp.float64)[None, :]
        q = np.asarray(tj.marcum_q1(jnp.broadcast_to(a, (5, 5)),
                                    jnp.broadcast_to(b, (5, 5))))
    ref = rice.sf(np.broadcast_to(np.asarray(b), (5, 5)),
                  np.broadcast_to(np.asarray(a), (5, 5)))
    np.testing.assert_allclose(q, ref, rtol=1e-9, atol=1e-12)


def test_stack_params_rejects_mixed_families():
    p1 = _random_prm(0, 6, "rayleigh")
    p2 = _random_prm(0, 6, "rician")
    with pytest.raises(ValueError, match="mixed fading families"):
        tj.stack_params([p1, p2])


# ---------------------------------------------------------------------------
# solver quality vs the scipy oracle
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def prm10():
    dep = channel.deploy(channel.WirelessConfig(num_devices=10, seed=0))
    return make_prm(dep.gains, d=814090)


def test_solve_matches_scipy_reference(prm10):
    """Acceptance: <= 1e-3 relative on the 10-device Rayleigh reference."""
    ref = sca.solve_sca(prm10)
    res = solvers.solve(prm10)
    assert res.objective <= ref.objective * (1 + 1e-3)
    assert abs(res.objective / ref.objective - 1.0) < 1e-3


def test_solve_monotone_history(prm10):
    res = solvers.solve(prm10)
    assert np.all(np.diff(res.history) <= 1e-9), res.history[:5]
    assert res.converged


def test_solve_solution_feasible(prm10):
    res = solvers.solve(prm10)
    gm = theory.gamma_max(prm10)
    assert np.all(res.gamma > 0)
    assert np.all(res.gamma <= gm * (1 + 1e-9))
    assert abs(res.p.sum() - 1.0) < 1e-9
    am = theory.alpha_of_gamma(res.gamma, prm10)
    assert np.allclose(am, res.alpha * res.p, rtol=1e-9)


def test_solve_beats_zero_bias(prm10):
    res = solvers.solve(prm10)
    zb = theory.p1_objective(theory.zero_bias_gamma(prm10), prm10)
    assert res.objective < zb * 0.99


@pytest.mark.parametrize("family", ["rician", "nakagami"])
def test_solve_off_rayleigh_matches_scipy(family):
    prm = _random_prm(1, 8, family)
    ref = sca.solve_sca(prm)
    res = solvers.solve(prm)
    assert abs(res.objective / ref.objective - 1.0) < 1e-3


def test_solve_batch_matches_loop():
    prms = [_random_prm(s, 8, "rayleigh") for s in range(5)]
    br = solvers.solve_batch(prms)
    assert br.gamma.shape == (5, 8)
    for i, prm in enumerate(prms):
        single = solvers.solve(prm)
        assert abs(br.objective[i] / single.objective - 1.0) < 1e-9
        # true objective re-evaluated on the numpy side agrees
        assert abs(theory.p1_objective(br.gamma[i], prm)
                   / br.objective[i] - 1.0) < 1e-9


def test_make_sca_jax_vs_scipy_design(prm10):
    from repro.core import power_control as pcm
    dep = channel.deploy(channel.WirelessConfig(num_devices=10, seed=0))
    pc_j = pcm.make_power_control("sca", dep, prm10)
    pc_s = pcm.make_power_control("sca", dep, prm10, method="scipy")
    oj = theory.p1_objective(pc_j.gamma, prm10)
    os_ = theory.p1_objective(pc_s.gamma, prm10)
    assert abs(oj / os_ - 1.0) < 1e-3


# ---------------------------------------------------------------------------
# AdaptiveSCA in the engine
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def fl_world():
    from repro.data import partition, synthetic
    from repro.models import mlp
    from repro.models.param import init_params
    x, y, xt, yt = synthetic.mnist_like(40, seed=0)
    shards = partition.partition_by_label(x, y, 10, seed=0)
    data = partition.stack_shards(shards)
    params0 = init_params(mlp.mlp_defs(hidden=32), jax.random.PRNGKey(0))
    xt_j, yt_j = jnp.asarray(xt), jnp.asarray(yt)
    ev = jax.jit(lambda p: {"acc": mlp.accuracy(p, xt_j, yt_j)})
    return mlp.mlp_loss, data, params0, ev


def test_adaptive_sca_static_bit_identical_to_sca(fl_world):
    """Acceptance: static-CSI AdaptiveSCA == plain sca, bitwise."""
    from repro.core import power_control as pcm
    from repro.fl import engine as eng
    from repro.fl.server import FLRunConfig
    loss, data, params0, ev = fl_world
    dep = channel.deploy(channel.WirelessConfig(num_devices=10, seed=0))
    prm = make_prm(dep.gains, d=10000)
    run = FLRunConfig(eta=0.05, num_rounds=7, eval_every=3)
    pc_sca = pcm.make_power_control("sca", dep, prm)
    pc_ad = pcm.make_power_control("adaptive_sca", dep, prm)
    assert np.array_equal(pc_sca.gamma, pc_ad.gamma)
    r1 = eng.run_fleet(loss, params0, [pc_sca], dep.gains, data, run, ev,
                       flat=False)
    r2 = eng.run_fleet(loss, params0, [pc_ad], dep.gains, data, run, ev,
                       flat=False)
    assert all(bool(jnp.all(r1.params[k] == r2.params[k]))
               for k in r1.params)
    assert r2.designs is None     # no fading process -> no redesign


def test_adaptive_sca_tracks_markov_drift(fl_world):
    """Acceptance: on a Gauss-Markov scenario the re-design moves the
    operating point per chunk and per seed, and changes the trajectory."""
    from repro.core import power_control as pcm, scenarios as scn
    from repro.fl import engine as eng
    from repro.fl.server import FLRunConfig
    loss, data, params0, ev = fl_world
    sc = scn.get_scenario("disk_markov")
    dep = scn.realize(sc)
    prm = scn.make_ota_params(dep, d=10000, gmax=10.0)
    fp = scn.make_fading_process(dep, sc.dynamics)
    run = FLRunConfig(eta=0.05, num_rounds=6, eval_every=3)
    pc_ad = pcm.make_power_control("adaptive_sca", dep, prm)
    pc_st = pcm.make_power_control("sca", dep, prm)
    res = eng.run_fleet(loss, params0, [pc_ad], dep.gains, data, run, ev,
                        fading=fp, flat=False, seeds=(0, 1))
    assert res.designs is not None and len(res.designs) >= 2
    t0, g0 = res.designs[0]
    t1, g1 = res.designs[1]
    assert t0 == 0 and t1 > 0
    assert g1.shape == (1, 2, dep.num_devices)
    # the operating point moved with the drifting CSI ...
    assert np.max(np.abs(g1 - g0) / np.abs(g0)) > 1e-3
    # ... independently per seed (each cell tracks its own channel)
    assert not np.array_equal(g1[0, 0], g1[0, 1])
    # ... and the trained params differ from the static design's
    res_st = eng.run_fleet(loss, params0, [pc_st], dep.gains, data, run, ev,
                           fading=fp, flat=False, seeds=(0, 1))
    assert any(not bool(jnp.all(res.params[k] == res_st.params[k]))
               for k in res.params)


def test_solve_batch_accepts_prestacked_f32_params():
    """stack_params outside an x64 scope yields f32 leaves; solve_batch
    must recast instead of crashing the scan carry dtype check."""
    prms = [_random_prm(s, 6, "rayleigh") for s in range(3)]
    stacked = tj.stack_params(prms)       # built OUTSIDE enable_x64
    br = solvers.solve_batch(stacked)
    ref = solvers.solve_batch(prms)
    np.testing.assert_allclose(br.objective, ref.objective, rtol=1e-6)


def test_make_sca_accepts_legacy_solve_sca_kwargs():
    from repro.core import power_control as pcm
    dep = channel.deploy(channel.WirelessConfig(num_devices=8, seed=2))
    prm = make_prm(dep.gains, d=10000)
    pc = pcm.make_power_control("sca", dep, prm, max_iters=8, tol=1e-5)
    assert np.all(pc.gamma > 0)


def test_adaptive_sca_stack_k2():
    """Two same-class AdaptiveSCA schemes stack treedef-preserving (the
    first scheme's redesign hook serves both rows)."""
    from repro.core import power_control as pcm
    dep = channel.deploy(channel.WirelessConfig(num_devices=10, seed=0))
    prm = make_prm(dep.gains, d=10000)
    a1 = pcm.make_power_control("adaptive_sca", dep, prm)
    a2 = pcm.make_power_control("adaptive_sca", dep, prm)
    st_ = pcm.stack_schemes([a1, a2])
    assert type(st_) is pcm.AdaptiveSCA
    assert st_.gamma.shape == (2, dep.num_devices)
    assert st_.redesign_fn is a1.redesign_fn


def test_adaptive_sca_cannot_join_union():
    from repro.core import power_control as pcm
    dep = channel.deploy(channel.WirelessConfig(num_devices=10, seed=0))
    prm = make_prm(dep.gains, d=10000)
    ad = pcm.make_power_control("adaptive_sca", dep, prm)
    ideal = pcm.make_power_control("ideal", dep, prm)
    with pytest.raises(ValueError, match="AdaptiveSCA"):
        pcm.stack_schemes([ad, ideal])
