"""Task subsystem (DESIGN.md §Tasks): registry contract, the paper_mlp
bit-identity regression against the pre-task hand-wired path, and the
cifar_conv workload end to end through the fleet executor (vmap resume
everywhere; sharded parity under the forced multi-device mesh).
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import tasks
from repro.core import channel, power_control as pcm
from repro.data import partition, synthetic
from repro.fl import driver, engine as eng, server
from repro.fl.server import FLRunConfig
from repro.models import mlp
from repro.models.param import init_params
from tests.helpers import make_prm

needs_mesh = pytest.mark.skipif(
    jax.device_count() < 4,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")

# cheap factory overrides per task so the whole registry smokes in seconds
SMOKE_KW = {
    "paper_mlp": dict(hidden=32, samples_per_class=20, test_per_class=10),
    "cifar_conv": dict(channels=(8, 16), hidden=32, samples_per_class=20,
                       test_per_class=10, alpha=1.0),
    "token_stream": dict(),       # factory defaults are already CPU-tiny
}


def _world(task, seed=0):
    dep = channel.deploy(channel.WirelessConfig(
        num_devices=task.num_devices, seed=0))
    prm = make_prm(dep.gains, d=min(task.param_dim, 10000))
    return dep, prm


# ---------------------------------------------------------------------------
# registry contract
# ---------------------------------------------------------------------------

def test_registry_lists_builtin_tasks():
    assert set(tasks.names()) >= {"paper_mlp", "cifar_conv", "token_stream"}


def test_registry_unknown_task_raises():
    with pytest.raises(KeyError, match="unknown task"):
        tasks.get("no_such_task")


def test_registry_expect_runtime_guards_before_factory():
    """A runtime mismatch is rejected from the registration record, BEFORE
    the factory sees (and TypeErrors on) runtime-specific overrides."""
    with pytest.raises(ValueError, match="'steps'-runtime"):
        tasks.get("token_stream", expect_runtime="fleet")
    with pytest.raises(ValueError, match="'fleet'-runtime"):
        # arch= would TypeError inside make_paper_mlp if the guard ran late
        tasks.get("paper_mlp", expect_runtime="steps", arch="qwen1.5-0.5b")
    assert tasks.names(runtime="fleet") == ("cifar_conv", "paper_mlp")
    assert tasks.names(runtime="steps") == ("token_stream",)


def test_registry_rejects_duplicate_and_misnamed():
    with pytest.raises(ValueError, match="already registered"):
        tasks.register("paper_mlp", tasks.make_paper_mlp)
    tasks.register("misnamed_tmp", tasks.make_paper_mlp)
    try:
        with pytest.raises(ValueError, match="built task"):
            tasks.get("misnamed_tmp")
    finally:
        tasks.registry._FACTORIES.pop("misnamed_tmp")


@pytest.mark.parametrize("name", sorted(SMOKE_KW))
def test_registry_task_inits_losses_evals_under_jit(name):
    """The ISSUE-5 registry gate: every registered task builds data, inits
    params, and runs loss_fn and eval_fn under jax.jit with finite
    outputs."""
    task = tasks.get(name, **SMOKE_KW[name])
    td = task.build_data(seed=0)
    params = task.init_params(seed=0)
    assert task.param_dim == sum(int(np.prod(np.shape(l)))
                                 for l in jax.tree.leaves(params))
    batch = task.sample_batch(td)
    loss = jax.jit(task.loss_fn)(params, batch)
    assert np.isfinite(float(loss))
    ev = jax.jit(task.make_eval(td))(params)
    assert ev and all(np.isfinite(float(v)) for v in ev.values()), ev
    run = task.run_config(num_rounds=7)
    assert isinstance(run, FLRunConfig) and run.num_rounds == 7
    # determinism: same seed -> same data and params, bitwise
    td2 = task.build_data(seed=0)
    assert all(np.array_equal(a, b) for a, b in
               zip(jax.tree.leaves(td.train), jax.tree.leaves(td2.train)))
    p2 = task.init_params(seed=0)
    assert all(np.array_equal(np.asarray(a), np.asarray(b)) for a, b in
               zip(jax.tree.leaves(params), jax.tree.leaves(p2)))


def test_task_eta_map():
    task = tasks.get("paper_mlp")
    assert task.eta_for("ideal", 0.05) == pytest.approx(0.08)
    assert task.eta_for("unknown_scheme", 0.07) == pytest.approx(0.07)


# ---------------------------------------------------------------------------
# bit-identity regression: paper_mlp through run_fleet_task reproduces the
# pre-refactor run_fleet(mlp.mlp_loss, ...) wiring exactly
# ---------------------------------------------------------------------------

def _params_equal(a, b):
    return all(bool(np.array_equal(np.asarray(x), np.asarray(y)))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def test_paper_mlp_task_bit_identical_to_prerefactor_fleet():
    task = tasks.get("paper_mlp", hidden=32, samples_per_class=40)
    dep, prm = _world(task)
    schemes = [pcm.make_power_control(n, dep, prm)
               for n in ("ideal", "sca", "vanilla")]
    run = FLRunConfig(eta=0.05, num_rounds=6, eval_every=3, seed=0)

    res_t = driver.run_fleet_task(task, schemes, dep.gains, run, flat=False)

    # the pre-task hand-wiring, reproduced verbatim (this is what
    # benchmarks/fig2.py compiled before the refactor)
    x, y, xt, yt = synthetic.mnist_like(40, noise=0.75, seed=0)
    shards = partition.partition_by_label(x, y, 10, 2, 2, seed=0)
    data = partition.stack_shards(shards)
    params0 = init_params(mlp.mlp_defs(hidden=32), jax.random.PRNGKey(0))
    xt_j, yt_j = jnp.asarray(xt), jnp.asarray(yt)
    xg, yg = jnp.asarray(x[:4000]), jnp.asarray(y[:4000])
    ev = jax.jit(lambda p: {"acc": mlp.accuracy(p, xt_j, yt_j),
                            "global_loss": mlp.mlp_loss(p, (xg, yg))})
    etas = [task.eta_for(pc.name, run.eta) for pc in schemes]
    res_o = eng.run_fleet(mlp.mlp_loss, params0, schemes, dep.gains, data,
                          run, ev, etas=etas, flat=False)

    assert _params_equal(res_t.params, res_o.params)
    assert set(res_t.traces) == set(res_o.traces)
    for k in res_t.traces:
        assert np.array_equal(res_t.traces[k], res_o.traces[k]), k
    assert [t for t, _ in res_t.evals] == [t for t, _ in res_o.evals]
    for (_, ea), (_, eb) in zip(res_t.evals, res_o.evals):
        for k in ea:
            assert np.array_equal(np.asarray(ea[k]), np.asarray(eb[k])), k


def test_run_fl_task_matches_run_fl():
    """The single-run task entry (fl.server.run_fl_task) is the same
    program as run_fl on the hand-built bundle."""
    task = tasks.get("paper_mlp", hidden=32, samples_per_class=20)
    dep, prm = _world(task)
    pc = pcm.make_power_control("sca", dep, prm)
    run = FLRunConfig(eta=0.05, num_rounds=4, eval_every=2, seed=0)
    params_t, hist_t = server.run_fl_task(task, pc, dep.gains, run)
    td = task.build_data(0)
    params_o, hist_o = server.run_fl(task.loss_fn, task.init_params(0), pc,
                                     dep.gains, td.train, run,
                                     task.make_eval(td))
    assert _params_equal(params_t, params_o)
    assert len(hist_t) == len(hist_o)
    for ra, rb in zip(hist_t, hist_o):
        assert {k: v for k, v in ra.items() if k != "wall"} \
            == {k: v for k, v in rb.items() if k != "wall"}


# ---------------------------------------------------------------------------
# cifar_conv through the whole fleet stack
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def cifar_world():
    task = tasks.get("cifar_conv", **SMOKE_KW["cifar_conv"])
    dep, prm = _world(task)
    schemes = [pcm.make_power_control(n, dep, prm)
               for n in ("ideal", "sca")]
    return task, dep, schemes


def test_cifar_conv_fleet_runs_flat_minibatch(cifar_world):
    """[2 schemes x 2 seeds] cifar fleet on the minibatch + flat hot path
    (the task's preferred sweep mode): finite learning trajectories with
    the grid axes in place."""
    task, dep, schemes = cifar_world
    run = task.run_config(num_rounds=6, eval_every=3, batch_size=4, seed=0)
    res = driver.run_fleet_task(task, schemes, dep.gains, run,
                                seeds=(0, 1), flat=True)
    assert res.traces["active_devices"].shape == (2, 2, 6)
    assert res.evals and set(res.evals[-1][1]) == {"acc", "global_loss"}
    assert all(np.all(np.isfinite(np.asarray(v)))
               for _, e in res.evals for v in e.values())
    assert all(np.all(np.isfinite(np.asarray(l)))
               for l in jax.tree.leaves(res.params))


def test_cifar_conv_resume_bitwise_vmap(cifar_world, tmp_path):
    """Kill the cifar fleet after chunk 1, resume from the checkpoint:
    params/traces/evals bitwise equal to the uninterrupted run."""
    task, dep, schemes = cifar_world
    run = task.run_config(num_rounds=9, eval_every=3, batch_size=4, seed=0)
    path = os.path.join(tmp_path, "cifar_fleet")
    kw = dict(seeds=(0, 2), flat=True)
    res_full = driver.run_fleet_task(task, schemes, dep.gains, run, **kw)
    res_part = driver.run_fleet_task(task, schemes, dep.gains, run, **kw,
                                     checkpoint_path=path, max_chunks=1)
    assert res_part.traces["active_devices"].shape[-1] < run.num_rounds
    res_res = driver.run_fleet_task(task, schemes, dep.gains, run, **kw,
                                    checkpoint_path=path, resume=True)
    assert _params_equal(res_full.params, res_res.params)
    for k in res_full.traces:
        assert np.array_equal(res_full.traces[k], res_res.traces[k]), k
    for (ta, ea), (tb, eb) in zip(res_full.evals, res_res.evals):
        assert ta == tb
        for k in ea:
            assert np.array_equal(np.asarray(ea[k]), np.asarray(eb[k])), k


def test_checkpoint_meta_rides_inside_npz(tmp_path):
    """The fleet-resume atomicity contract: meta (chunks_done etc.) lives
    INSIDE the npz archive, atomic with the arrays — a checkpoint is
    readable with no manifest at all, and load_flat never leaks the meta
    key into the restored state."""
    from repro.checkpoint import checkpoint as ckpt

    path = os.path.join(tmp_path, "fleet")
    tree = {"a": np.arange(4.0), "b": {"c": np.ones((2, 2))}}
    ckpt.save(path, tree, meta={"chunks_done": 3, "names": ["sca"]})
    os.remove(path + ".manifest.json")        # manifest is advisory only
    assert ckpt.load_meta(path) == {"chunks_done": 3, "names": ["sca"]}
    flat = ckpt.load_flat(path)
    assert "__meta__" not in flat and set(flat) == {"a", "b/c"}
    got = ckpt.restore_flat(flat, jax.tree.map(np.zeros_like, tree))
    assert np.array_equal(got["a"], tree["a"])
    assert np.array_equal(got["b"]["c"], tree["b"]["c"])


@needs_mesh
def test_cifar_conv_sharded_matches_vmap(cifar_world):
    """The cifar grid sharded over the debug mesh reproduces the
    single-device fleet: key-stream traces bitwise, norm-derived
    traces/evals/params to float rounding (the §Placement contract,
    now exercised by a conv workload)."""
    from repro.fl.placement import ShardedPlacement
    from repro.launch.mesh import make_debug_mesh

    task, dep, schemes = cifar_world
    run = task.run_config(num_rounds=6, eval_every=3, batch_size=4, seed=0)
    kw = dict(seeds=(0, 1), flat=True)
    res_v = driver.run_fleet_task(task, schemes, dep.gains, run, **kw)
    res_s = driver.run_fleet_task(task, schemes, dep.gains, run, **kw,
                                  placement=ShardedPlacement(
                                      make_debug_mesh(2, 2)))
    for k in ("active_devices", "noise_scale"):
        assert np.array_equal(res_v.traces[k], res_s.traces[k]), k
    np.testing.assert_allclose(res_v.traces["grad_norm_mean"],
                               res_s.traces["grad_norm_mean"],
                               rtol=1e-5, atol=1e-6)
    for (_, ea), (_, eb) in zip(res_v.evals, res_s.evals):
        for k in ea:
            np.testing.assert_allclose(np.asarray(ea[k]), np.asarray(eb[k]),
                                       rtol=1e-5, atol=3e-3, err_msg=k)
    diff = max(float(np.max(np.abs(np.asarray(a) - np.asarray(b))))
               for a, b in zip(jax.tree.leaves(res_v.params),
                               jax.tree.leaves(res_s.params)))
    assert diff < 1e-5, diff
