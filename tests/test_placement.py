"""Fleet placement layer + host driver (DESIGN.md §Placement).

Parity contract:
  * ShardedPlacement on a ("data", "model") debug mesh reproduces the
    single-device run_fleet per [K, S] cell: every key-stream-derived
    quantity — active devices, noise scales, dropout patterns, eval
    cadence — BITWISE, and norm-derived float traces / params to ~1 ulp
    (XLA lowers each cell's large reductions slightly differently per
    local block size, so e.g. the global-norm clip can round differently;
    everything driven purely by per-cell keys and elementwise math is
    exact).
  * checkpoint-resume is BITWISE against the uninterrupted run *on the
    same placement* — same carries, key streams, chunk schedule, same
    compiled programs — including AdaptiveSCA design trajectories across
    the restart.
  * solvers.solve_batch sharded over the mesh matches the vmap batch to
    <= 1e-7 relative.
  * population mode (cohort gains/data as jit operands, DESIGN.md
    §Population) shards like any other fleet: host cohort draws are
    placement-independent (bitwise), including grids that pad the mesh
    and cohort sizes that don't divide the device count.

The sharded tests need >= 4 host devices
(XLA_FLAGS=--xla_force_host_platform_device_count=8; the CI
``sharded-smoke`` job forces them) and skip otherwise; the vmap-placement
resume tests run everywhere.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import distributed, telemetry
from repro.core import channel, power_control as pcm, scenarios as scn
from repro.data import partition, synthetic
from repro.fl import driver, engine as eng
from repro.fl.placement import ShardedPlacement, VmapPlacement
from repro.fl.server import FLRunConfig
from repro.launch.mesh import make_debug_mesh
from repro.models import mlp
from repro.models.param import init_params
from tests.helpers import make_prm

HIDDEN = 32

needs_mesh = pytest.mark.skipif(
    jax.device_count() < 4,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")


@pytest.fixture(scope="module")
def world():
    dep = channel.deploy(channel.WirelessConfig(num_devices=10, seed=0))
    x, y, xt, yt = synthetic.mnist_like(40, seed=0)
    shards = partition.partition_by_label(x, y, 10, seed=0)
    data = partition.stack_shards(shards)
    prm = make_prm(dep.gains, d=10000)
    params0 = init_params(mlp.mlp_defs(hidden=HIDDEN), jax.random.PRNGKey(0))
    xt_j, yt_j = jnp.asarray(xt), jnp.asarray(yt)
    ev = jax.jit(lambda p: {"acc": mlp.accuracy(p, xt_j, yt_j)})
    return dep, prm, data, params0, ev


@pytest.fixture(scope="module")
def markov_world():
    sc = scn.get_scenario("disk_markov")
    dep = scn.realize(sc)
    prm = scn.make_ota_params(dep, d=10000, gmax=10.0, eta=0.05,
                              kappa_sq=4.0)
    fp = scn.make_fading_process(dep, sc.dynamics)
    x, y, _, _ = synthetic.mnist_like(40, seed=0)
    data = partition.stack_shards(partition.partition_by_label(x, y, 10,
                                                               seed=0))
    params0 = init_params(mlp.mlp_defs(hidden=HIDDEN), jax.random.PRNGKey(0))
    return dep, prm, fp, data, params0


def _params_equal(a, b):
    return all(bool(np.array_equal(np.asarray(x), np.asarray(y)))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def _params_maxdiff(a, b):
    return max(float(np.max(np.abs(np.asarray(x) - np.asarray(y))))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


# traces that are pure functions of the per-cell key streams and the design
# state must agree bitwise across placements; traces derived from large
# float reductions (the global-norm clip) may legitimately differ at ~1 ulp
_EXACT_TRACES = ("active_devices", "noise_scale")


def _results_bitwise_histories(res_a, res_b):
    """traces + evals + designs bitwise between two FLResults (same
    placement on both sides: identical compiled programs)."""
    _compare_histories(res_a, res_b, exact=True)


def _compare_histories(res_a, res_b, exact: bool,
                       exact_traces=_EXACT_TRACES):
    assert set(res_a.traces) == set(res_b.traces)
    for k in res_a.traces:
        if exact or k in exact_traces:
            assert np.array_equal(res_a.traces[k], res_b.traces[k]), k
        else:
            np.testing.assert_allclose(res_a.traces[k], res_b.traces[k],
                                       rtol=1e-6, atol=1e-6, err_msg=k)
    assert [t for t, _ in res_a.evals] == [t for t, _ in res_b.evals]
    for (_, ea), (_, eb) in zip(res_a.evals, res_b.evals):
        for k in ea:
            if exact:
                assert np.array_equal(np.asarray(ea[k]),
                                      np.asarray(eb[k])), k
            else:
                np.testing.assert_allclose(np.asarray(ea[k]),
                                           np.asarray(eb[k]), rtol=1e-5,
                                           atol=3e-3, err_msg=k)
    if res_a.designs is not None or res_b.designs is not None:
        assert len(res_a.designs) == len(res_b.designs)
        for (ta, ga), (tb, gb) in zip(res_a.designs, res_b.designs):
            assert ta == tb
            if exact:
                assert np.array_equal(np.asarray(ga), np.asarray(gb))
            else:
                np.testing.assert_allclose(np.asarray(ga), np.asarray(gb),
                                           rtol=1e-6)


# ---------------------------------------------------------------------------
# chunk_lengths edge cases (cell-program layer)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("t,e", [(3, 10),    # num_rounds < eval_every
                                 (5, 1),     # eval_every == 1
                                 (1, 1), (1, 5),  # num_rounds == 1
                                 (2, 10)])
def test_chunk_lengths_edge_cases(t, e):
    legacy_evals = [r for r in range(t) if r % e == 0 or r == t - 1]
    lengths = eng.chunk_lengths(t, e, with_eval=True)
    assert sum(lengths) == t
    assert all(ln >= 1 for ln in lengths)
    assert list(np.cumsum(lengths) - 1) == legacy_evals
    assert len(set(lengths)) <= 3
    assert eng.chunk_lengths(t, e, with_eval=False) == [t]
    assert eng.chunk_lengths(0, e, with_eval=True) == []


# ---------------------------------------------------------------------------
# shard_vmap primitive
# ---------------------------------------------------------------------------

@needs_mesh
def test_shard_vmap_padding_and_masking():
    """G that doesn't divide the device pool: padded with copies of row 0,
    padded outputs sliced off, per-row results equal the plain vmap."""
    mesh = make_debug_mesh(2, 2)
    xs = jnp.arange(7 * 3, dtype=jnp.float32).reshape(7, 3)   # G=7 over P=4
    bias = jnp.float32(2.0)

    def f(x, b):
        return {"out": x * x + b, "norm": jnp.sum(x)}

    got = jax.jit(distributed.shard_vmap(f, mesh, num_sharded=1))(xs, bias)
    want = jax.vmap(f, in_axes=(0, None))(xs, bias)
    assert got["out"].shape == (7, 3)
    np.testing.assert_array_equal(np.asarray(got["out"]),
                                  np.asarray(want["out"]))
    np.testing.assert_array_equal(np.asarray(got["norm"]),
                                  np.asarray(want["norm"]))


@needs_mesh
def test_grid_devices():
    mesh = make_debug_mesh(2, 2)
    assert distributed.grid_devices(mesh, ("data", "model")) == 4
    assert distributed.grid_devices(mesh, ("data",)) == 2
    placement = ShardedPlacement(mesh)
    assert placement.num_devices == 4
    assert placement.axes == ("data", "model")   # launch.mesh.grid_axes


# ---------------------------------------------------------------------------
# sharded fleet vs single-device vmap fleet
# ---------------------------------------------------------------------------

@needs_mesh
def test_sharded_fleet_matches_vmap_bitwise_traces(world):
    """[3 schemes x 2 seeds] heterogeneous fleet on a 2x2 mesh (grid 6 pads
    to 8): key-stream traces bitwise per cell vs the single-device fleet,
    norm-derived traces/params to float rounding."""
    dep, prm, data, params0, ev = world
    names = ["ideal", "sca", "vanilla"]
    schemes = [pcm.make_power_control(n, dep, prm) for n in names]
    run = FLRunConfig(eta=0.05, num_rounds=9, eval_every=4)
    kw = dict(seeds=(0, 3), flat=False)
    res_v = eng.run_fleet(mlp.mlp_loss, params0, schemes, dep.gains, data,
                          run, ev, **kw)
    res_s = driver.run_fleet(mlp.mlp_loss, params0, schemes, dep.gains,
                             data, run, ev, **kw,
                             placement=ShardedPlacement(make_debug_mesh(2, 2)))
    assert res_s.names == res_v.names == tuple(names)
    assert res_s.traces["active_devices"].shape == (3, 2, run.num_rounds)
    _compare_histories(res_v, res_s, exact=False)
    assert _params_maxdiff(res_v.params, res_s.params) < 1e-6


@needs_mesh
def test_sharded_fleet_stateful_scenario(markov_world):
    """Gauss-Markov fading state shards with the cells; key-stream traces
    (dropout/active patterns, noise scales) match the vmap fleet bitwise."""
    dep, prm, fp, data, params0 = markov_world
    schemes = [pcm.make_power_control(n, dep, prm)
               for n in ("sca", "zero_bias")]
    run = FLRunConfig(eta=0.05, num_rounds=6, eval_every=3)
    kw = dict(seeds=(0, 1), fading=fp, flat=False)
    res_v = eng.run_fleet(mlp.mlp_loss, params0, schemes, dep.gains, data,
                          run, None, **kw)
    res_s = driver.run_fleet(mlp.mlp_loss, params0, schemes, dep.gains,
                             data, run, None, **kw,
                             placement=ShardedPlacement(make_debug_mesh(2, 2)))
    _compare_histories(res_v, res_s, exact=False)
    np.testing.assert_allclose(np.asarray(res_v.fading_state),
                               np.asarray(res_s.fading_state), rtol=1e-5,
                               atol=1e-6)
    assert _params_maxdiff(res_v.params, res_s.params) < 1e-6


# ---------------------------------------------------------------------------
# checkpointed resume (host-driver layer)
# ---------------------------------------------------------------------------

def test_resume_bitwise_vmap_placement(world, tmp_path):
    """Kill after chunk 1, resume: final params/traces/evals bitwise equal
    the uninterrupted run (single-device placement, runs everywhere)."""
    dep, prm, data, params0, ev = world
    schemes = [pcm.make_power_control(n, dep, prm) for n in ("sca", "ideal")]
    run = FLRunConfig(eta=0.05, num_rounds=9, eval_every=3)
    args = (mlp.mlp_loss, params0, schemes, dep.gains, data, run, ev)
    path = os.path.join(tmp_path, "fleet")
    res_full = driver.run_fleet(*args, seeds=(0, 2), flat=False)
    res_part = driver.run_fleet(*args, seeds=(0, 2), flat=False,
                                checkpoint_path=path, max_chunks=1)
    # genuinely interrupted: only the first chunk's rounds ran
    assert res_part.traces["active_devices"].shape[-1] < run.num_rounds
    res_res = driver.run_fleet(*args, seeds=(0, 2), flat=False,
                               checkpoint_path=path, resume=True)
    assert _params_equal(res_full.params, res_res.params)
    _results_bitwise_histories(res_full, res_res)


def test_resume_checkpoint_mismatch_raises(world, tmp_path):
    dep, prm, data, params0, ev = world
    schemes = [pcm.make_power_control("ideal", dep, prm)]
    run = FLRunConfig(eta=0.05, num_rounds=4, eval_every=2)
    path = os.path.join(tmp_path, "fleet")
    driver.run_fleet(mlp.mlp_loss, params0, schemes, dep.gains, data, run,
                     ev, flat=False, checkpoint_path=path, max_chunks=1)
    other = FLRunConfig(eta=0.05, num_rounds=8, eval_every=2)
    with pytest.raises(ValueError, match="does not match"):
        driver.run_fleet(mlp.mlp_loss, params0, schemes, dep.gains, data,
                         other, ev, flat=False, checkpoint_path=path,
                         resume=True)
    # the whole run configuration is part of the checkpoint identity, not
    # just the grid shape: dynamics (batch_size/eta), aggregation path,
    # and per-scheme etas all reject a mismatched resume
    mb = FLRunConfig(eta=0.05, num_rounds=4, eval_every=2, batch_size=16)
    with pytest.raises(ValueError, match="batch_size"):
        driver.run_fleet(mlp.mlp_loss, params0, schemes, dep.gains, data,
                         mb, ev, flat=False, checkpoint_path=path,
                         resume=True)
    with pytest.raises(ValueError, match="flat"):
        driver.run_fleet(mlp.mlp_loss, params0, schemes, dep.gains, data,
                         run, ev, flat=True, checkpoint_path=path,
                         resume=True)
    with pytest.raises(ValueError, match="etas"):
        driver.run_fleet(mlp.mlp_loss, params0, schemes, dep.gains, data,
                         run, ev, flat=False, etas=[0.01],
                         checkpoint_path=path, resume=True)


def test_resume_completed_run_is_noop(world, tmp_path):
    """Resuming a checkpoint of a finished sweep re-runs nothing and
    reassembles the same result."""
    dep, prm, data, params0, ev = world
    schemes = [pcm.make_power_control("ideal", dep, prm)]
    run = FLRunConfig(eta=0.05, num_rounds=5, eval_every=2)
    path = os.path.join(tmp_path, "fleet")
    args = (mlp.mlp_loss, params0, schemes, dep.gains, data, run, ev)
    res_full = driver.run_fleet(*args, flat=False, checkpoint_path=path)
    res_res = driver.run_fleet(*args, flat=False, checkpoint_path=path,
                               resume=True)
    assert _params_equal(res_full.params, res_res.params)
    _results_bitwise_histories(res_full, res_res)


@needs_mesh
def test_sharded_adaptive_resume_bitwise(markov_world, tmp_path):
    """The acceptance gate: adaptive_sca fleet SHARDED over the debug mesh,
    killed after chunk 1 and resumed — final params and the re-design
    trajectory (FLResult.designs) bitwise equal the uninterrupted sharded
    run; traces/evals/designs also bitwise vs the single-device fleet."""
    dep, prm, fp, data, params0 = markov_world
    pc = pcm.make_power_control("adaptive_sca", dep, prm)
    run = FLRunConfig(eta=0.05, num_rounds=6, eval_every=2)
    pl = ShardedPlacement(make_debug_mesh(2, 2))
    args = (mlp.mlp_loss, params0, [pc], dep.gains, data, run)
    kw = dict(fading=fp, flat=False, seeds=(0, 1))
    path = os.path.join(tmp_path, "fleet")

    res_full = driver.run_fleet(*args, **kw, placement=pl)
    assert len(res_full.designs) >= 3          # re-designed between chunks
    res_part = driver.run_fleet(*args, **kw, placement=pl,
                                checkpoint_path=path, max_chunks=1)
    assert len(res_part.designs) < len(res_full.designs)
    res_res = driver.run_fleet(*args, **kw, placement=pl,
                               checkpoint_path=path, resume=True)
    assert _params_equal(res_full.params, res_res.params)
    _results_bitwise_histories(res_full, res_res)

    res_v = eng.run_fleet(*args, **kw)         # single-device reference
    _compare_histories(res_v, res_full, exact=False)
    assert _params_maxdiff(res_v.params, res_full.params) < 1e-6


# ---------------------------------------------------------------------------
# cohort axis through the placement layer (population mode)
# ---------------------------------------------------------------------------

# in population mode the per-round noise scales are computed INSIDE the
# chunk from the cohort-gain operands (not precomputed host-side design
# state), so like the other norm-derived traces they may round differently
# per placement; only the key-stream dropout patterns stay bitwise
_COHORT_EXACT_TRACES = ("active_devices",)


@needs_mesh
def test_sharded_cohort_fleet_matches_vmap(world):
    """Population-mode adaptive_sca fleet (cohort gains/data as jit
    operands, per-cohort host redesign) sharded over the 2x2 mesh vs the
    single-device vmap placement: identical host cohort draws + design
    trajectory, key-stream traces bitwise, norm-derived traces/params to
    float rounding."""
    dep, prm, data, params0, ev = world
    spec = scn.PopulationSpec(
        size=120, shadowing=scn.ShadowingSpec(sigma_db=6.0),
        fading=channel.FadingSpec(family="rician", rician_k=3.0),
        dynamics=scn.DynamicsSpec(rho=0.9), sampling="traffic", seed=11)
    pop = scn.Population(spec=spec)
    schemes = [pcm.make_power_control("adaptive_sca", dep, prm)]
    run = FLRunConfig(eta=0.05, num_rounds=6, eval_every=3)
    kw = dict(seeds=(0, 1), flat=False, population=pop, cohort_size=10,
              cohort_rounds=2)
    res_v = driver.run_fleet(mlp.mlp_loss, params0, schemes, dep.gains,
                             data, run, ev, **kw)
    res_s = driver.run_fleet(mlp.mlp_loss, params0, schemes, dep.gains,
                             data, run, ev, **kw,
                             placement=ShardedPlacement(make_debug_mesh(2, 2)))
    assert res_s.traces["active_devices"].shape == (1, 2, run.num_rounds)
    # cohort draws + redesigns are host-side and placement-independent
    assert len(res_v.cohorts) == len(res_s.cohorts) == 3
    for (ta, ia), (tb, ib) in zip(res_v.cohorts, res_s.cohorts):
        assert ta == tb and np.array_equal(ia, ib)
    assert len(res_v.designs) == len(res_s.designs) == 3
    _compare_histories(res_v, res_s, exact=False,
                       exact_traces=_COHORT_EXACT_TRACES)
    assert _params_maxdiff(res_v.params, res_s.params) < 1e-6


@needs_mesh
def test_sharded_cohort_padding(world):
    """Grid that doesn't fill the mesh (3 cells pad to 4 devices) with a
    cohort size (10) that doesn't divide the device count (4): padded
    cells are sliced off and the run matches the vmap placement."""
    dep, prm, data, params0, ev = world
    spec = scn.PopulationSpec(size=23, sampling="traffic", seed=5)
    pop = scn.Population(spec=spec)
    schemes = [pcm.make_power_control(n, dep, prm)
               for n in ("sca", "ideal", "vanilla")]
    run = FLRunConfig(eta=0.05, num_rounds=5, eval_every=2)
    kw = dict(seeds=(3,), flat=False, population=pop, cohort_size=10,
              cohort_rounds=2)
    res_v = driver.run_fleet(mlp.mlp_loss, params0, schemes, dep.gains,
                             data, run, ev, **kw)
    res_s = driver.run_fleet(mlp.mlp_loss, params0, schemes, dep.gains,
                             data, run, ev, **kw,
                             placement=ShardedPlacement(make_debug_mesh(2, 2)))
    assert res_s.traces["active_devices"].shape == (3, 1, run.num_rounds)
    for (ta, ia), (tb, ib) in zip(res_v.cohorts, res_s.cohorts):
        assert ta == tb and np.array_equal(ia, ib)
    _compare_histories(res_v, res_s, exact=False,
                       exact_traces=_COHORT_EXACT_TRACES)
    assert _params_maxdiff(res_v.params, res_s.params) < 1e-6


# ---------------------------------------------------------------------------
# solve_batch through the placement layer
# ---------------------------------------------------------------------------

@needs_mesh
def test_solve_batch_sharded_matches_vmap():
    """A 6-scenario SCA design batch (pads to 8 over the 2x2 mesh) sharded
    via ShardedPlacement matches the single-device vmap batch <= 1e-7
    relative."""
    from benchmarks.sca_bench import make_prm as solver_prm
    from repro import solvers

    prms = [solver_prm(6, s) for s in range(6)]
    ref = solvers.solve_batch(prms)
    got = solvers.solve_batch(
        prms, placement=ShardedPlacement(make_debug_mesh(2, 2)))
    np.testing.assert_allclose(got.gamma, ref.gamma, rtol=1e-7)
    np.testing.assert_allclose(got.objective, ref.objective, rtol=1e-7)
    np.testing.assert_allclose(got.alpha, ref.alpha, rtol=1e-7)


# ---------------------------------------------------------------------------
# recompilation audit: telemetry.assert_no_recompile over placement chunks
# ---------------------------------------------------------------------------

def _fleet_chunk_operands(world):
    dep, prm, data, params0, _ = world
    stacked = pcm.stack_schemes([pcm.make_power_control("sca", dep, prm)])
    run = FLRunConfig(eta=0.05, num_rounds=4, eval_every=2)
    body = eng.make_round_body(mlp.mlp_loss, dep.gains, run, flat=False)
    data = tuple(jnp.asarray(a) for a in data)
    params_b = jax.tree.map(
        lambda a: jnp.tile(jnp.asarray(a)[None, None],
                           (1, 1) + (1,) * jnp.ndim(a)), params0)
    keys_b = jnp.tile(jax.random.PRNGKey(0)[None, None], (1, 1, 1))
    etas = np.array([run.eta])
    return body, (stacked, etas, params_b, None, keys_b, data)


def test_assert_no_recompile_vmap_chunk(world):
    """Both chunk lengths warmed: repeated calls inside the audit scope
    stay on the two compiled programs; an unwarmed length inside the
    scope trips the assertion (the failure mode the audit exists for)."""
    body, ops = _fleet_chunk_operands(world)
    # donate=False: this test re-feeds the SAME carry buffers, which the
    # default donating chunk would consume (see placement module docstring)
    chunk = VmapPlacement(donate=False).build_chunk(body, adaptive=False)
    chunk(*ops, length=2)
    chunk(*ops, length=1)                                  # warm both
    with telemetry.assert_no_recompile(chunk):
        chunk(*ops, length=2)
        chunk(*ops, length=1)
    assert chunk._cache_size() == 2
    with pytest.raises(AssertionError, match="compile cache grew"):
        with telemetry.assert_no_recompile(chunk):
            chunk(*ops, length=3)
    # allowed= raises the budget for stages that legitimately compile
    with telemetry.assert_no_recompile(chunk, allowed=1):
        chunk(*ops, length=4)


@needs_mesh
def test_assert_no_recompile_sharded_chunk(world):
    """The sharded chunk's explicit (length, k, s) program dict honours
    the same ``_cache_size`` audit contract as the jit path."""
    body, ops = _fleet_chunk_operands(world)
    placement = ShardedPlacement(make_debug_mesh(2, 2), donate=False)
    stacked = placement.prepare_schemes(ops[0], 1, adaptive=False)
    ops = (stacked,) + ops[1:]
    chunk = placement.build_chunk(body, adaptive=False)
    chunk(*ops, length=2)
    with telemetry.assert_no_recompile(chunk):
        chunk(*ops, length=2)
    assert chunk._cache_size() == 1
    with pytest.raises(AssertionError, match="compile cache grew"):
        with telemetry.assert_no_recompile(chunk):
            chunk(*ops, length=1)


def test_checkpoint_restored_operands_hit_warm_cache(world, tmp_path):
    """The resumed-retrace soft spot, at the operand level: a carry
    restored through ``checkpoint.restore_flat`` must be compile-cache-
    indistinguishable from the live carry it was saved from.  Pre-fix,
    restore returned raw npz ``np.ndarray`` leaves while the running chunk
    produces ``jax.Array`` carries — identical avals, but jit keys the
    container class, so the first resumed chunk call recompiled."""
    from repro.checkpoint import checkpoint as ckpt
    from repro.fl.driver import _carry_tree

    body, ops = _fleet_chunk_operands(world)
    chunk = VmapPlacement(donate=False).build_chunk(body, adaptive=False)
    stacked, etas, params_b, _, keys_b, data = ops
    params_b, _, keys_b, _ = chunk(*ops, length=2)       # live carry
    live = (stacked, etas, params_b, None, keys_b, data)
    chunk(*live, length=2)                               # warm (live form)
    path = os.path.join(tmp_path, "carry")
    ckpt.save(path, _carry_tree(stacked, params_b, None, keys_b), meta={})
    state = ckpt.restore_flat(ckpt.load_flat(path),
                              _carry_tree(stacked, params_b, None, keys_b))
    restored = (state["scheme"], etas, state["carry"]["params"], None,
                state["carry"]["keys"], data)
    with telemetry.assert_no_recompile(chunk):
        chunk(*restored, length=2)


def test_resumed_adaptive_run_compiles_once_per_length(markov_world,
                                                      tmp_path,
                                                      monkeypatch):
    """End-to-end pin of the ROADMAP soft spot: a RESUMED ``adaptive_sca``
    run's second same-length chunk hits the compile cache — the chunk
    compiles exactly one program per distinct chunk length, no retrace
    between checkpoint-loaded and redesign-produced scheme leaves."""
    dep, prm, fp, data, params0 = markov_world
    pc = pcm.make_power_control("adaptive_sca", dep, prm)
    run = FLRunConfig(eta=0.05, num_rounds=8, eval_every=2)
    chunks = []
    orig = VmapPlacement.build_chunk

    def capture(self, *a, **kw):
        c = orig(self, *a, **kw)
        chunks.append(c)
        return c

    monkeypatch.setattr(VmapPlacement, "build_chunk", capture)
    path = os.path.join(tmp_path, "fleet")
    args = (mlp.mlp_loss, params0, [pc], dep.gains, data, run)
    kw = dict(fading=fp, flat=False, seeds=(0,))
    driver.run_fleet(*args, **kw, checkpoint_path=path, max_chunks=2)
    driver.run_fleet(*args, **kw, checkpoint_path=path, resume=True)
    # chunk_lengths(8, 2, True) = [1, 2, 2, 2, 1]; the resumed process
    # executes [2, 2, 1] -> exactly two distinct lengths, two programs
    resumed = chunks[-1]
    assert resumed._cache_size() == 2


def test_assert_no_recompile_rejects_uninstrumented():
    with pytest.raises(ValueError, match="compile cache"):
        with telemetry.assert_no_recompile(lambda: None):
            pass


def test_solve_batch_vmap_placement_matches_default():
    """placement=VmapPlacement() is the same program as the default."""
    from benchmarks.sca_bench import make_prm as solver_prm
    from repro import solvers

    prms = [solver_prm(6, s) for s in range(2)]
    ref = solvers.solve_batch(prms)
    got = solvers.solve_batch(prms, placement=VmapPlacement())
    np.testing.assert_array_equal(got.gamma, ref.gamma)
    np.testing.assert_array_equal(got.objective, ref.objective)
