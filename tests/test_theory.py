"""Theorem-1 quantities: invariants and property tests."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import channel, theory
from repro.core.theory import OTAParams
from tests.helpers import make_prm  # re-export: kept for older imports


@pytest.fixture(scope="module")
def prm():
    dep = channel.deploy(channel.WirelessConfig(num_devices=10, seed=0))
    return make_prm(dep.gains)


def test_alpha_max_is_max(prm):
    """alpha_m(gamma) attains its maximum at gamma_max."""
    gm = theory.gamma_max(prm)
    am = theory.alpha_max(prm)
    assert np.allclose(theory.alpha_of_gamma(gm, prm), am, rtol=1e-12)
    for f in (0.5, 0.9, 1.1, 2.0):
        assert np.all(theory.alpha_of_gamma(f * gm, prm) <= am + 1e-30)


def test_participation_is_simplex(prm):
    gm = theory.gamma_max(prm)
    _, a, p = theory.participation(0.7 * gm, prm)
    assert a > 0
    assert np.all(p >= 0)
    assert abs(p.sum() - 1.0) < 1e-12


def test_invert_alpha_roundtrip(prm):
    gm = theory.gamma_max(prm)
    gamma = 0.6 * gm
    am = theory.alpha_of_gamma(gamma, prm)
    g2 = theory.invert_alpha(am, prm)
    assert np.allclose(g2, gamma, rtol=1e-9)


def test_zero_bias_gives_uniform_p(prm):
    g0 = theory.zero_bias_gamma(prm)
    _, _, p = theory.participation(g0, prm)
    assert np.allclose(p, 0.1, atol=1e-9)
    assert theory.bias_term(p, prm) < 1e-18


def test_zero_bias_binds_weakest_device(prm):
    """The common alpha target equals the weakest device's alpha_max."""
    g0 = theory.zero_bias_gamma(prm)
    am = theory.alpha_of_gamma(g0, prm)
    assert np.allclose(am, np.min(theory.alpha_max(prm)), rtol=1e-9)


def test_zeta_decomposition_positive(prm):
    gm = theory.gamma_max(prm)
    z = theory.zeta_terms(0.8 * gm, prm)
    assert z["transmission"] >= -1e-12
    assert z["minibatch"] == 0.0
    assert z["noise"] > 0
    assert z["total"] == pytest.approx(
        z["transmission"] + z["minibatch"] + z["noise"])


def test_bound_decreases_with_rounds(prm):
    gm = theory.gamma_max(prm)
    b1 = theory.theorem1_bound(gm, prm, init_gap=5.0, num_rounds=10)
    b2 = theory.theorem1_bound(gm, prm, init_gap=5.0, num_rounds=1000)
    assert b2["total"] < b1["total"]
    assert b1["variance"] == pytest.approx(b2["variance"])
    assert b1["bias"] == pytest.approx(b2["bias"])


def test_bias_variance_tradeoff_visible(prm):
    """Scaling all gammas up increases bias-side terms and reduces noise:
    the trade-off of §III-A."""
    g0 = theory.zero_bias_gamma(prm)          # uniform p, higher noise
    gm = theory.gamma_max(prm)                # max alpha, nonzero bias
    z0 = theory.zeta_terms(g0, prm)
    zm = theory.zeta_terms(gm, prm)
    assert zm["noise"] < z0["noise"]          # bigger alpha kills noise
    _, _, p0 = theory.participation(g0, prm)
    _, _, pm = theory.participation(gm, prm)
    assert theory.bias_term(pm, prm) > theory.bias_term(p0, prm)


@settings(max_examples=30, deadline=None)
@given(st.lists(st.floats(min_value=50.0, max_value=1750.0),
                min_size=2, max_size=16))
def test_participation_simplex_property(dists):
    gains = channel.average_gain(np.asarray(dists))
    prm = make_prm(gains)
    gm = theory.gamma_max(prm)
    for frac in (0.3, 1.0):
        _, a, p = theory.participation(frac * gm, prm)
        assert np.all(p >= 0) and abs(p.sum() - 1.0) < 1e-9
        assert np.all(theory.alpha_of_gamma(frac * gm, prm)
                      <= theory.alpha_max(prm) * (1 + 1e-12))


@settings(max_examples=20, deadline=None)
@given(st.integers(min_value=2, max_value=12),
       st.integers(min_value=0, max_value=10_000))
def test_kappa_bound_assumption4(n, seed):
    """kappa <= 2 G_max whenever per-device gradients are G_max-bounded."""
    rng = np.random.default_rng(seed)
    gmax = 10.0
    grads = rng.normal(size=(n, 32))
    grads /= np.maximum(np.linalg.norm(grads, axis=1, keepdims=True) / gmax,
                        1.0)
    gbar = grads.mean(0)
    kappa_sq = np.mean(np.sum((grads - gbar) ** 2, axis=1))
    assert kappa_sq <= (2 * gmax) ** 2 + 1e-9
